"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import bsfp
from compile.kernels import ref
from compile.kernels.bsfp_quant import encode as k_encode
from compile.kernels.full_matmul import matmul as k_matmul
from compile.kernels.qmatmul import qmatmul as k_qmatmul


def quantized_inputs(seed, k, n, amp=0.1):
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((k, n)) * amp).astype(np.float32)
    qt = bsfp.quantize_tensor(w)
    return w, qt


class TestQmatmul:
    @given(st.integers(0, 2**31), st.sampled_from([128, 256, 384]),
           st.sampled_from([8, 33, 64]), st.sampled_from([1, 2, 5]))
    @settings(max_examples=12, deadline=None)
    def test_matches_reference(self, seed, k, n, b):
        rng = np.random.default_rng(seed ^ 7)
        _, qt = quantized_inputs(seed, k, n)
        x = rng.standard_normal((b, k)).astype(np.float32)
        wq = jnp.asarray(qt.packed_wq())
        sc = jnp.asarray(qt.scales)
        y_kernel = np.asarray(k_qmatmul(jnp.asarray(x), wq, sc))
        y_ref = np.asarray(ref.qmatmul(jnp.asarray(x), wq, sc))
        np.testing.assert_allclose(y_kernel, y_ref, rtol=1e-5, atol=1e-5)

    def test_matches_dequantized_matmul(self):
        _, qt = quantized_inputs(0, 256, 16)
        x = np.random.default_rng(1).standard_normal((2, 256)).astype(np.float32)
        y_kernel = np.asarray(
            k_qmatmul(jnp.asarray(x), jnp.asarray(qt.packed_wq()), jnp.asarray(qt.scales))
        )
        y_deq = x @ qt.dequant_draft()
        np.testing.assert_allclose(y_kernel, y_deq, rtol=1e-4, atol=1e-4)

    def test_rejects_bad_group_size(self):
        x = jnp.zeros((1, 130), dtype=jnp.float32)
        wq = jnp.zeros((65, 4), dtype=jnp.uint8)
        sc = jnp.zeros((1, 4), dtype=jnp.float32)
        with pytest.raises(AssertionError):
            k_qmatmul(x, wq, sc)


class TestFullMatmul:
    @given(st.integers(0, 2**31), st.sampled_from([128, 256]),
           st.sampled_from([16, 96]), st.sampled_from([1, 2, 128, 256]))
    @settings(max_examples=10, deadline=None)
    def test_matches_reference(self, seed, k, n, b):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((b, k)).astype(np.float32)
        w = rng.standard_normal((k, n)).astype(np.float32)
        y = np.asarray(k_matmul(jnp.asarray(x), jnp.asarray(w)))
        np.testing.assert_allclose(y, x @ w, rtol=2e-5, atol=2e-5)


class TestEncodeKernel:
    def test_exhaustive_against_numpy_codec(self):
        s = np.arange(2, dtype=np.uint32)
        e = np.arange(16, dtype=np.uint32)
        m = np.arange(1024, dtype=np.uint32)
        bits = ((s[:, None, None] << 15) | (e[None, :, None] << 10) | m).ravel()
        bits = bits.astype(np.uint16).reshape(256, 128)
        wq_np, wr_np = bsfp.encode(bits)
        wq_k, wr_k = k_encode(jnp.asarray(bits))
        assert np.array_equal(np.asarray(wq_k), wq_np)
        assert np.array_equal(np.asarray(wr_k), wr_np)

    def test_matches_jnp_oracle(self):
        rng = np.random.default_rng(5)
        w = (rng.standard_normal((128, 32)) * 0.2).astype(np.float32)
        bits = bsfp.f32_to_bits(w)
        wq_k, wr_k = k_encode(jnp.asarray(bits))
        wq_o, wr_o = ref.quantize_bits(jnp.asarray(bits))
        assert np.array_equal(np.asarray(wq_k), np.asarray(wq_o))
        assert np.array_equal(np.asarray(wr_k), np.asarray(wr_o))
