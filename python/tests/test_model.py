"""L2 model tests: shapes, masking, KV-cache consistency, draft routing."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus
from compile.model import (
    MODEL_ZOO,
    S_SLOTS,
    init_params,
    kv_shape,
    linear_names,
    make_decode,
    make_decode_draft,
    make_eval,
    make_prefill,
    make_verify,
    param_shapes,
    quantize_params,
    state_len,
    train_logits,
)

CFG = dataclasses.replace(MODEL_ZOO[0], cache_len=64, prefill_len=32)


@pytest.fixture(scope="module")
def params():
    return {k: jnp.asarray(v) for k, v in init_params(CFG).items()}


@pytest.fixture(scope="module")
def qparams(params):
    np_params = {k: np.asarray(v) for k, v in params.items()}
    qp, _ = quantize_params(np_params, CFG)
    return {k: jnp.asarray(v) for k, v in qp.items()}


def toks(n, seed=0):
    return jnp.asarray(corpus.make_stream(n, seed), dtype=jnp.int32)


class TestShapes:
    def test_param_shapes_cover_all_linears(self):
        names = {n for n, _ in param_shapes(CFG)}
        for lin in linear_names(CFG):
            assert lin in names

    def test_in_dims_are_group_multiples(self):
        shapes = dict(param_shapes(CFG))
        for lin in linear_names(CFG):
            assert shapes[lin][0] % 128 == 0, lin

    def test_state_len(self):
        assert state_len(CFG) == S_SLOTS * CFG.vocab + int(np.prod(kv_shape(CFG)))

    def test_train_logits_shape(self, params):
        logits = train_logits(params, toks(64).reshape(2, 32), CFG)
        assert logits.shape == (2, 32, CFG.vocab)


class TestPrefill:
    def test_padding_does_not_change_logits(self, params):
        """Tokens after `length` must not affect the last-position logits."""
        pf = make_prefill(CFG, use_pallas=False)
        t = toks(CFG.prefill_len)
        s1 = pf(params, t, 16)
        t2 = t.at[20:].set(99)  # corrupt only the padded tail
        s2 = pf(params, t2, 16)
        v = CFG.vocab
        np.testing.assert_allclose(
            np.asarray(s1[:v]), np.asarray(s2[:v]), rtol=1e-5, atol=1e-6
        )

    def test_eval_matches_prefill_last_position(self, params):
        pf = make_prefill(CFG, use_pallas=False)
        ev = make_eval(CFG, use_pallas=False)
        t = toks(CFG.prefill_len)
        length = 24
        state = pf(params, t, length)
        logits = ev(params, t, length)
        v = CFG.vocab
        np.testing.assert_allclose(
            np.asarray(state[:v]),
            np.asarray(logits[length - 1]),
            rtol=1e-4, atol=1e-5,
        )


class TestDecode:
    def test_decode_continues_prefill(self, params):
        """decode(t, pos) after prefill == eval over the extended sequence."""
        pf = make_prefill(CFG, use_pallas=False)
        ev = make_eval(CFG, use_pallas=False)
        dec = make_decode(CFG, use_pallas=False)
        t = toks(CFG.prefill_len)
        length = 20
        state = pf(params, t, length)
        nxt = int(t[length])  # feed the true next token
        state2 = dec(params, nxt, length, state)
        v = CFG.vocab
        ref_logits = ev(params, t, length + 1)[length]
        np.testing.assert_allclose(
            np.asarray(state2[:v]), np.asarray(ref_logits), rtol=1e-3, atol=1e-4
        )

    def test_verify_matches_sequential_decode(self, params):
        pf = make_prefill(CFG, use_pallas=False)
        dec = make_decode(CFG, use_pallas=False)
        ver = make_verify(CFG, use_pallas=False)
        t = toks(CFG.prefill_len)
        length = 10
        state0 = pf(params, t, length)
        chain = [int(x) for x in np.asarray(toks(S_SLOTS, seed=3))]
        # Sequential.
        state = state0
        seq_rows = []
        v = CFG.vocab
        for i, tok in enumerate(chain):
            state = dec(params, tok, length + i, state)
            seq_rows.append(np.asarray(state[:v]))
        # Parallel.
        vstate = ver(params, jnp.asarray(chain, dtype=jnp.int32), length, state0)
        for i in range(S_SLOTS):
            np.testing.assert_allclose(
                np.asarray(vstate[i * v:(i + 1) * v]), seq_rows[i],
                rtol=1e-3, atol=1e-4,
            )


class TestDraft:
    def test_draft_close_to_full(self, params, qparams):
        pf = make_prefill(CFG, use_pallas=False)
        dec = make_decode(CFG, use_pallas=False)
        dec_d = make_decode_draft(CFG)
        t = toks(CFG.prefill_len)
        state = pf(params, t, 16)
        v = CFG.vocab
        full = dec(params, 65, 16, state)
        draft = dec_d(params, qparams, 65, 16, state)
        # Same argmax on a random init most of the time; at minimum the
        # logits must correlate strongly.
        a = np.asarray(full[:v], dtype=np.float64)
        b = np.asarray(draft[:v], dtype=np.float64)
        corr = np.corrcoef(a, b)[0, 1]
        assert corr > 0.9, f"draft/full logit correlation {corr}"

    def test_quantize_params_emits_packed_shapes(self, qparams):
        shapes = dict(param_shapes(CFG))
        for lin in linear_names(CFG):
            k, n = shapes[lin]
            assert qparams[lin + ".wq"].shape == (k // 2, n)
            assert qparams[lin + ".scales"].shape == (k // 128, n)
            assert qparams[lin + ".wq"].dtype == jnp.uint8
