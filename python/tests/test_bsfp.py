"""BSFP codec correctness: exhaustive bit-level checks + hypothesis sweeps."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import bsfp


def all_valid_bits():
    """All 32768 FP16 patterns with exponent <= 15."""
    s = np.arange(2, dtype=np.uint32)
    e = np.arange(16, dtype=np.uint32)
    m = np.arange(1024, dtype=np.uint32)
    grid = (s[:, None, None] << 15) | (e[None, :, None] << 10) | m[None, None, :]
    return grid.ravel().astype(np.uint16)


class TestLossless:
    def test_roundtrip_exhaustive(self):
        bits = all_valid_bits()
        w_q, w_r = bsfp.encode(bits)
        assert np.array_equal(bsfp.decode_full(w_q, w_r), bits)

    def test_encode_rejects_out_of_range(self):
        with pytest.raises(ValueError):
            bsfp.encode(np.array([0x7000], dtype=np.uint16))  # exp = 28

    def test_bit_budget(self):
        bits = all_valid_bits()
        w_q, w_r = bsfp.encode(bits)
        assert int(w_q.max()) <= 0xF, "W_q exceeds 4 bits"
        assert int(w_r.max()) <= 0xFFF, "W_r exceeds 12 bits"


class TestRemapTable:
    def test_fig3_rows(self):
        # (E, quantized value, flag) straight from Fig. 3.
        rows = [
            (0, 2, 1), (1, 2, 1), (2, 2, 0), (3, 2, 0),
            (4, 6, 1), (5, 6, 1), (6, 6, 0), (7, 6, 0),
            (8, 8, 0), (9, 9, 1), (10, 10, 0), (11, 11, 1),
            (12, 12, 0), (13, 12, 0), (14, 14, 0), (15, 14, 0),
        ]
        for e, qval, flag in rows:
            code = bsfp.REMAP_CODE[e]
            assert bsfp.CODE_TO_QEXP[code] == qval, f"E={e}"
            assert bsfp.REMAP_FLAG[e] == flag, f"E={e}"

    def test_critical_exponents_have_unique_codes(self):
        # 9 and 11 own the stolen codes 000 and 010.
        assert bsfp.REMAP_CODE[9] == 0
        assert bsfp.REMAP_CODE[11] == 2
        # No other exponent maps to those codes.
        for e in range(16):
            if e not in (9, 11):
                assert bsfp.REMAP_CODE[e] not in (0, 2)


class TestAlgorithm1:
    def test_no_scale_for_small_tensors(self):
        w = np.array([[0.5, -1.2]], dtype=np.float32)
        _, scale = bsfp.algorithm1_prescale(w)
        assert scale == 1.0

    def test_outlier_triggers_scale(self):
        # The paper's Llama2-13B down_proj case: lone 2.4062.
        w = np.full((4, 4), 0.1, dtype=np.float32)
        w[0, 0] = 2.4062
        scaled, scale = bsfp.algorithm1_prescale(w)
        assert scale == pytest.approx(1.999 / 2.4062)
        assert np.abs(scaled).max() < 2.0

    @given(st.floats(min_value=2.001, max_value=1e4))
    @settings(max_examples=50, deadline=None)
    def test_scale_always_brings_in_range(self, wmax):
        w = np.array([wmax, -0.3], dtype=np.float32)
        scaled, _ = bsfp.algorithm1_prescale(w)
        assert np.abs(scaled).max() < 2.0


class TestQuantizeTensor:
    @given(st.integers(0, 2**32 - 1), st.sampled_from([128, 256, 384]),
           st.integers(1, 8), st.sampled_from([0.02, 0.2, 1.0]))
    @settings(max_examples=25, deadline=None)
    def test_lossless_random_tensors(self, seed, k, n, amp):
        rng = np.random.default_rng(seed)
        w = (rng.standard_normal((k, n)) * amp).astype(np.float32)
        qt = bsfp.quantize_tensor(w)
        scaled, _ = bsfp.algorithm1_prescale(w)
        assert np.array_equal(qt.reconstruct_fp16_bits(), bsfp.f32_to_bits(scaled))

    def test_eq4_scale_is_mse_optimal(self):
        rng = np.random.default_rng(3)
        w = (rng.standard_normal((128, 1)) * 0.1).astype(np.float32)
        qt = bsfp.quantize_tensor(w)
        q = bsfp.draft_values(qt.w_q).reshape(-1)
        t = bsfp.bits_to_f32(bsfp.f32_to_bits(w)).reshape(-1)
        def mse(s):
            return float(np.mean((q * s - t) ** 2))
        s0 = float(qt.scales[0, 0])
        assert mse(s0) <= mse(s0 * 1.01) + 1e-15
        assert mse(s0) <= mse(s0 * 0.99) + 1e-15

    def test_packed_layout(self):
        w = np.zeros((2, 1), dtype=np.float32)
        w[0, 0] = 0.5   # sign 0
        w[1, 0] = -0.5  # sign 1
        qt = bsfp.quantize_tensor(np.tile(w, (64, 1)).astype(np.float32))
        packed = qt.packed_wq()
        lo = packed[0, 0] & 0xF
        hi = (packed[0, 0] >> 4) & 0xF
        assert lo == qt.w_q[0, 0] and hi == qt.w_q[1, 0]
        assert (hi >> 3) == 1 and (lo >> 3) == 0  # signs preserved


class TestVariants:
    def test_ordering_on_top_magnitude_mse(self):
        rng = np.random.default_rng(9)
        w = (rng.standard_normal((512, 16)) * 0.07).astype(np.float32)
        absw = np.abs(w)
        thr = np.quantile(absw, 0.9)
        def top_mse(q):
            d = (q - w)[absw > thr]
            return float(np.mean(d.astype(np.float64) ** 2))
        errs = {v: top_mse(bsfp.quantize_variant(w, v))
                for v in ["bsfp", "e3m0", "e2m1", "e1m2"]}
        assert errs["bsfp"] < errs["e3m0"] < errs["e2m1"] < errs["e1m2"]

    def test_unknown_variant_raises(self):
        with pytest.raises(ValueError):
            bsfp.quantize_variant(np.zeros((128, 1), dtype=np.float32), "int3")


class TestExponentHistogram:
    def test_trained_like_weights_confined(self):
        rng = np.random.default_rng(1)
        w = rng.standard_normal(4096).astype(np.float32) * 0.05
        hist = bsfp.exponent_histogram(w)
        assert hist[16:].sum() == 0
        assert hist.sum() == 4096
