"""Corpus generator: determinism, task structure, prompt shapes."""

import numpy as np

from compile import corpus


class TestStream:
    def test_deterministic(self):
        a = corpus.make_stream(4096, seed=1)
        b = corpus.make_stream(4096, seed=1)
        assert np.array_equal(a, b)
        c = corpus.make_stream(4096, seed=2)
        assert not np.array_equal(a, c)

    def test_length_and_dtype(self):
        s = corpus.make_stream(1000, seed=0)
        assert s.dtype == np.uint8 and len(s) == 1000

    def test_contains_all_three_families(self):
        text = corpus.make_stream(1 << 16, seed=3).tobytes().decode()
        assert "Q: " in text and "def " in text and "USER: " in text


class TestPrompts:
    def test_fixed_length(self):
        for task in corpus.TASKS:
            for p in corpus.make_prompts(task, 5, seed=1, prompt_len=128):
                assert len(p) == 128
                assert all(0 <= t < 256 for t in p)

    def test_prompts_end_at_answer_stems(self):
        math = bytes(corpus.make_prompts("math", 1, 1, 160)[0])
        code = bytes(corpus.make_prompts("code", 1, 1, 160)[0])
        chat = bytes(corpus.make_prompts("chat", 1, 1, 160)[0])
        assert math.endswith(b"\nA: ") and b"Q: " in math
        assert code.endswith(b"return ") and b"def " in code
        assert chat.endswith(b"BOT: ") and b"USER: " in chat

    def test_heldout_disjoint_from_train_seed(self):
        train = corpus.make_stream(4096, seed=99)
        held = corpus.heldout(4096, seed=99)
        assert not np.array_equal(train, held)
