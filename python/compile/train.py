"""Tiny-corpus training of the target models (build-time only).

Trains each MODEL_ZOO config on the synthetic three-task corpus with Adam,
producing FP16-storable weights whose exponent distribution matches the
paper's Fig. 2(c) premise (weight decay + normalization confine exponents to
[0, 15]).  Run once by ``aot.py``; results are cached under ``artifacts/``.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, init_params, train_logits

BATCH = 8
SEQ = 96
STEPS = 900
LR = 3e-3
WEIGHT_DECAY = 0.02
CORPUS_BYTES = 1 << 20


def loss_fn(params, tokens, cfg: ModelConfig):
    logits = train_logits(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(params, opt, tokens, cfg: ModelConfig):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, cfg)
    t = opt["t"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        m = b1 * opt["m"][k] + (1 - b1) * grads[k]
        v = b2 * opt["v"][k] + (1 - b2) * grads[k] ** 2
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        upd = mhat / (jnp.sqrt(vhat) + eps)
        # Decoupled weight decay on matrix params only — this is what keeps
        # the exponents confined to [0, 15] (the paper's Fig. 2(c) premise).
        decay = WEIGHT_DECAY if params[k].ndim == 2 else 0.0
        new_p[k] = params[k] - LR * (upd + decay * params[k])
        new_m[k], new_v[k] = m, v
    return new_p, {"m": new_m, "v": new_v, "t": t}, loss


def train_model(cfg: ModelConfig, *, steps: int = STEPS, log=print):
    """Train one config; returns (params, loss_history)."""
    stream = corpus.make_stream(CORPUS_BYTES, seed=cfg.seed)
    params = {k: jnp.asarray(v) for k, v in init_params(cfg).items()}
    opt = adam_init(params)
    rng = np.random.default_rng(cfg.seed + 1)
    losses = []
    t0 = time.time()
    for step in range(steps):
        starts = rng.integers(0, len(stream) - SEQ - 1, size=BATCH)
        batch = np.stack([stream[s : s + SEQ + 1] for s in starts]).astype(np.int32)
        params, opt, loss = train_step(params, opt, jnp.asarray(batch), cfg)
        losses.append(float(loss))
        if step % 50 == 0 or step == steps - 1:
            log(
                f"  [{cfg.name}] step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)"
            )
    return {k: np.asarray(v) for k, v in params.items()}, losses
