"""Bit-Sharing Floating Point (BSFP) reference codec — numpy, vectorized.

Implements the SPEQ paper's core algorithm (Sections III-A/III-B):

* FP16 weights of trained LLMs confine their exponents to [0, 15] (the top
  exponent bit ``e4`` is wasted).  After the Algorithm-1 per-tensor pre-scale
  (``scale = 1.999 / max|W|`` whenever ``max|W| > 2.0``) this holds for every
  finite weight.
* Each FP16 weight ``s eeeee mmmmmmmmmm`` is re-encoded as

      W_q  (4 bits)  = [sign | c2 c1 c0]          -- the remapped E3M0 code
      W_r  (12 bits) = [flag | e0 | m9..m0]       -- remainder

  where ``flag`` lives in the bit position of the wasted ``e4`` and is set
  whenever the stored exponent bits differ from the original (Fig. 3).
  ``W_q ∥ W_r`` is exactly 16 bits: zero storage overhead, and the original
  FP16 value is reconstructed losslessly by the Fig. 5(b) decoder.
* The *remap* gives the critical exponents 9 and 11 their own codes (3'b000
  and 3'b010, stolen from the low-magnitude pairs {0,1} and {4,5}):

      E: 0 1 2 3 4 5 6 7 8 9 10 11 12 13 14 15
      Q: 2 2 2 2 6 6 6 6 8 9 10 11 12 12 14 14      (quantized exponent)

* Per-group (128 weights) scale ``s = Σ w·Q(w) / Σ Q(w)²`` (Eq. 4) minimizes
  the group MSE; the draft weight is ``(-1)^sign · 2^(Q(E)-15) · s``.

This module is the single source of truth for the Python side; the Rust side
(``rust/src/bsfp``) mirrors it bit-for-bit and is cross-checked through golden
vectors emitted by ``aot.py``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

GROUP_SIZE = 128
FP16_BIAS = 15

# ---- Fig. 3 remap tables -------------------------------------------------
# Indexed by original exponent E in [0, 15].
REMAP_CODE = np.array(
    [1, 1, 1, 1, 3, 3, 3, 3, 4, 0, 5, 2, 6, 6, 7, 7], dtype=np.uint8
)
REMAP_FLAG = np.array(
    [1, 1, 0, 0, 1, 1, 0, 0, 0, 1, 0, 1, 0, 0, 0, 0], dtype=np.uint8
)
# Indexed by the 3-bit code: the quantized exponent value Q(E)
# (= the Fig. 5(a) draft decoder output).
CODE_TO_QEXP = np.array([9, 2, 11, 6, 8, 10, 12, 14], dtype=np.int32)
# Fig. 5(b) full decoder MUX: for flagged values, keyed by (c1, c0), the top
# four exponent bits  E[4:1]  (E = mux<<1 | e0).  c2 is always 0 when flagged.
FLAG_MUX_EHIGH = np.array([4, 0, 5, 2], dtype=np.uint8)  # (c1c0)=00,01,10,11


def _require_u16(bits: np.ndarray) -> np.ndarray:
    bits = np.asarray(bits)
    if bits.dtype != np.uint16:
        raise TypeError(f"expected uint16 bit patterns, got {bits.dtype}")
    return bits


def split_fields(bits: np.ndarray):
    """Split FP16 bit patterns into (sign, exponent, mantissa)."""
    bits = _require_u16(bits)
    sign = (bits >> 15).astype(np.uint8)
    exp = ((bits >> 10) & 0x1F).astype(np.uint8)
    man = (bits & 0x3FF).astype(np.uint16)
    return sign, exp, man


def encode(bits: np.ndarray):
    """Encode FP16 bit patterns into (w_q, w_r).

    ``w_q`` is uint8 holding 4 significant bits ``[sign c2 c1 c0]``;
    ``w_r`` is uint16 holding 12 significant bits ``[flag e0 m9..m0]``.

    Precondition: every exponent is in [0, 15] (i.e. |w| < 2.0, guaranteed
    after the Algorithm-1 pre-scale).  Raises ValueError otherwise.
    """
    sign, exp, man = split_fields(bits)
    if np.any(exp > 15):
        bad = int(np.sum(exp > 15))
        raise ValueError(
            f"{bad} weights have exponent > 15 (|w| >= 2.0); "
            "apply the Algorithm-1 pre-scale first"
        )
    code = REMAP_CODE[exp]
    flag = REMAP_FLAG[exp]
    e0 = (exp & 1).astype(np.uint16)
    w_q = ((sign << 3) | code).astype(np.uint8)
    w_r = ((flag.astype(np.uint16) << 11) | (e0 << 10) | man).astype(np.uint16)
    return w_q, w_r


def decode_full(w_q: np.ndarray, w_r: np.ndarray) -> np.ndarray:
    """Losslessly reconstruct the original FP16 bit patterns (Fig. 5(b))."""
    w_q = np.asarray(w_q, dtype=np.uint8)
    w_r = np.asarray(w_r, dtype=np.uint16)
    sign = (w_q >> 3).astype(np.uint16) & 1
    code = (w_q & 0x7).astype(np.uint16)
    flag = (w_r >> 11) & 1
    e0 = (w_r >> 10) & 1
    man = w_r & 0x3FF
    # Unflagged: exponent = code·2 + e0.  Flagged: MUX on (c1, c0).
    ehigh_plain = code  # E[4:1] == code when unflagged (and e4 == 0)
    ehigh_flagged = FLAG_MUX_EHIGH[(code & 0x3).astype(np.uint8)].astype(np.uint16)
    ehigh = np.where(flag == 1, ehigh_flagged, ehigh_plain)
    exp = (ehigh << 1) | e0
    return ((sign << 15) | (exp << 10) | man).astype(np.uint16)


def decode_draft_qexp(w_q: np.ndarray):
    """Fig. 5(a) draft decoder: 3-bit code -> quantized exponent value."""
    w_q = np.asarray(w_q, dtype=np.uint8)
    sign = (w_q >> 3) & 1
    code = w_q & 0x7
    return sign, CODE_TO_QEXP[code]


def draft_magnitude(w_q: np.ndarray) -> np.ndarray:
    """Unscaled draft value magnitude: 2^(Q(E) - 15)."""
    _, qexp = decode_draft_qexp(w_q)
    return np.exp2(qexp.astype(np.float64) - FP16_BIAS)


def draft_values(w_q: np.ndarray) -> np.ndarray:
    """Signed, unscaled draft values Q(w)."""
    sign, qexp = decode_draft_qexp(w_q)
    mag = np.exp2(qexp.astype(np.float64) - FP16_BIAS)
    return np.where(sign == 1, -mag, mag)


def eq4_scales(w: np.ndarray, q: np.ndarray, group_size: int = GROUP_SIZE):
    """Per-group MSE-optimal scales (Eq. 4), groups along axis 0.

    ``w``: true values, shape (in, out) (or (n,) treated as (n, 1));
    ``q``: unscaled quantized values, same shape.  ``in`` must be a multiple
    of ``group_size``.  Returns scales of shape (in // group_size, out).
    """
    w = np.asarray(w, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    squeeze = w.ndim == 1
    if squeeze:
        w = w[:, None]
        q = q[:, None]
    n, m = w.shape
    if n % group_size != 0:
        raise ValueError(f"in-dim {n} not a multiple of group size {group_size}")
    wg = w.reshape(n // group_size, group_size, m)
    qg = q.reshape(n // group_size, group_size, m)
    num = np.sum(wg * qg, axis=1)
    den = np.sum(qg * qg, axis=1)
    scales = np.where(den > 0, num / np.maximum(den, 1e-30), 1.0)
    return scales[:, 0] if squeeze else scales


@dataclasses.dataclass
class QuantizedTensor:
    """A BSFP-quantized linear weight (in, out)."""

    w_q: np.ndarray          # uint8 (in, out), 4 significant bits
    w_r: np.ndarray          # uint16 (in, out), 12 significant bits
    scales: np.ndarray       # float32 (in // 128, out)
    tensor_scale: float      # Algorithm-1 pre-scale (1.0 if none needed)
    shape: tuple

    def packed_wq(self) -> np.ndarray:
        """Nibble-pack W_q along axis 0: out uint8 (in // 2, out).

        Element ``2i`` goes to the low nibble, ``2i+1`` to the high nibble —
        the layout the Pallas qmatmul kernel and the Rust runtime consume.
        """
        wq = self.w_q
        return (wq[0::2, :] | (wq[1::2, :] << 4)).astype(np.uint8)

    def dequant_draft(self) -> np.ndarray:
        """Materialize the draft weights as float32 (in, out)."""
        q = draft_values(self.w_q)
        n = q.shape[0]
        g = self.scales.astype(np.float64)
        q = q.reshape(n // GROUP_SIZE, GROUP_SIZE, -1) * g[:, None, :]
        return q.reshape(self.w_q.shape).astype(np.float32)

    def reconstruct_fp16_bits(self) -> np.ndarray:
        """Bit-exact FP16 reconstruction (before undoing the tensor scale)."""
        return decode_full(self.w_q, self.w_r)

    def reconstruct_full(self) -> np.ndarray:
        """Full-precision weights as float32, tensor pre-scale undone."""
        bits = self.reconstruct_fp16_bits()
        vals = bits_to_f32(bits)
        return (vals / self.tensor_scale).astype(np.float32)


def f32_to_bits(w: np.ndarray) -> np.ndarray:
    """float array -> FP16 bit patterns (round-to-nearest-even)."""
    return np.asarray(w, dtype=np.float16).view(np.uint16)


def bits_to_f32(bits: np.ndarray) -> np.ndarray:
    return _require_u16(bits).view(np.float16).astype(np.float32)


# FP16 round-to-nearest-even midpoint below 2.0: any f32 at or above it
# rounds UP to FP16 2.0 (exponent 16), outside the remap's domain — so the
# pre-scale must trigger here, not at 2.0 (mirrors rust/src/bsfp/codec.rs).
_FP16_TWO_MIDPOINT = 1.99951171875


def algorithm1_prescale(w: np.ndarray) -> tuple[np.ndarray, float]:
    """Algorithm 1: rescale so max|W| < 2.0 (exponent <= 15)."""
    w = np.asarray(w, dtype=np.float32)
    wmax = float(np.max(np.abs(w))) if w.size else 0.0
    scale = 1.0
    if wmax >= _FP16_TWO_MIDPOINT:
        scale = 1.999 / wmax
        w = w * scale
    return w, scale


def quantize_tensor(w: np.ndarray, group_size: int = GROUP_SIZE) -> QuantizedTensor:
    """Full BSFP quantization of a linear weight (in, out).

    Steps: Algorithm-1 pre-scale -> FP16 cast -> encode (W_q, W_r) ->
    Eq. 4 group scales on the draft magnitudes.
    """
    w = np.asarray(w, dtype=np.float32)
    if w.ndim != 2:
        raise ValueError(f"expected a 2-D weight, got shape {w.shape}")
    if w.shape[0] % group_size != 0:
        raise ValueError(
            f"in-dim {w.shape[0]} not a multiple of group size {group_size}"
        )
    scaled, tscale = algorithm1_prescale(w)
    bits = f32_to_bits(scaled)
    w_q, w_r = encode(bits)
    q = draft_values(w_q)
    true_vals = bits_to_f32(bits).astype(np.float64)
    scales = eq4_scales(true_vals, q, group_size).astype(np.float32)
    return QuantizedTensor(
        w_q=w_q, w_r=w_r, scales=scales, tensor_scale=tscale, shape=w.shape
    )


# ---- Table I baseline quantizers (bit-extraction FP4 variants) -----------

def _extract_quant(bits: np.ndarray, exp_keep: int, man_keep: int) -> np.ndarray:
    """Shared-bit FP4 quantization by extracting top exponent/mantissa bits.

    ``exp_keep`` exponent MSBs (of e3..e0; e4 is always 0 here) and
    ``man_keep`` mantissa MSBs are kept, the rest are zeroed.  This is the
    "Naive" column of Fig. 3 generalized to E1M2/E2M1/E3M0.
    """
    sign, exp, man = split_fields(bits)
    exp_mask = ((0xF << (4 - exp_keep)) & 0xF) if exp_keep < 4 else 0xF
    qexp = (exp & exp_mask).astype(np.int32)
    man_mask = ((0x3FF >> man_keep) ^ 0x3FF) if man_keep else 0
    qman = (man & man_mask).astype(np.float64) / 1024.0
    mag = np.exp2(qexp - FP16_BIAS) * (1.0 + qman)
    # Exponent 0 is subnormal territory; the extraction treats it as 2^-15
    # scale with no implicit 1 -- approximate with the same formula (error is
    # negligible at weight scale and identical across variants).
    return np.where(sign == 1, -mag, mag)


def quantize_variant(w: np.ndarray, variant: str, group_size: int = GROUP_SIZE):
    """Quantize with one of the Table I variants; returns draft f32 weights.

    Variants: ``e1m2``, ``e2m1``, ``e3m0`` (naive, == LSB-cleared exponent),
    ``bsfp`` (E3M0 + remap, the SPEQ draft).
    """
    w = np.asarray(w, dtype=np.float32)
    scaled, tscale = algorithm1_prescale(w)
    bits = f32_to_bits(scaled)
    if variant == "bsfp":
        qt = quantize_tensor(w, group_size)
        return qt.dequant_draft()
    if variant == "e3m0":
        q = _extract_quant(bits, exp_keep=3, man_keep=0)
    elif variant == "e2m1":
        q = _extract_quant(bits, exp_keep=2, man_keep=1)
    elif variant == "e1m2":
        q = _extract_quant(bits, exp_keep=1, man_keep=2)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    true_vals = bits_to_f32(bits).astype(np.float64)
    scales = eq4_scales(true_vals, q, group_size)
    n = q.shape[0]
    out = q.reshape(n // group_size, group_size, -1) * scales[:, None, :]
    return (out.reshape(w.shape) / tscale).astype(np.float32)


def exponent_histogram(w: np.ndarray) -> np.ndarray:
    """Histogram of FP16 exponent values [0, 31] — the Fig. 2(c) analysis."""
    bits = f32_to_bits(np.asarray(w, dtype=np.float32))
    _, exp, _ = split_fields(bits)
    return np.bincount(exp.ravel().astype(np.int64), minlength=32)
