"""Synthetic byte-level corpus with three task families.

Substitute for the paper's GSM8K / HumanEval / MT-bench workloads (see
DESIGN.md §2): the tiny target models are trained on a deterministic mixture
of three structured text families with distinct token-entropy profiles —

* ``math`` — few-shot grade-school arithmetic word problems (GSM8K analog),
* ``code`` — small function definitions with doctests (HumanEval analog),
* ``chat`` — multi-turn templated dialogue (MT-bench analog).

Tokens are raw bytes (vocab = 256), so no tokenizer artifacts are needed on
the Rust side.  Everything is seeded and reproducible.
"""

from __future__ import annotations

import numpy as np

VOCAB = 256
TASKS = ("math", "code", "chat")

# Vocabulary pools are deliberately small: the tiny target models must
# reach the low-entropy, high-confidence regime of the paper's 7B+ models
# (GSM8K/HumanEval answers are near-deterministic for a strong model), or
# the draft/target accept rate — the quantity under study — is dominated by
# corpus noise rather than quantization noise. See DESIGN.md §2.
_NAMES = ["ada", "bob", "carol", "dave", "erin", "frank"]
_OBJECTS = ["apples", "pens", "books", "coins"]
_VERBS = ["buys", "finds", "wins", "gets"]
_FUNCS = ["add", "sub", "mul", "double", "square", "inc", "dec", "neg"]
_GREET = ["hello", "hi there", "good morning"]
_TOPICS = ["weather", "music", "books", "travel"]
_REPLIES = ["that sounds great", "i agree with you", "tell me more about it"]


def _math_sample(rng: np.random.Generator) -> str:
    name = _NAMES[rng.integers(len(_NAMES))]
    obj = _OBJECTS[rng.integers(len(_OBJECTS))]
    verb = _VERBS[rng.integers(len(_VERBS))]
    a = int(rng.integers(2, 30))
    b = int(rng.integers(2, 15))
    op = rng.integers(3)
    if op == 0:
        q = f"{name} has {a} {obj} and {verb} {b} more. how many {obj} now?"
        ans, work = a + b, f"{a}+{b}={a + b}"
    elif op == 1:
        hi, lo = max(a, b), min(a, b)
        q = f"{name} has {hi} {obj} and gives away {lo}. how many {obj} left?"
        ans, work = hi - lo, f"{hi}-{lo}={hi - lo}"
    else:
        a2, b2 = int(rng.integers(2, 10)), int(rng.integers(2, 10))
        q = f"{name} {verb} {a2} bags of {b2} {obj}. how many {obj} total?"
        ans, work = a2 * b2, f"{a2}*{b2}={a2 * b2}"
    return f"Q: {q}\nA: {work}. the answer is {ans}.\n"


def _code_sample(rng: np.random.Generator) -> str:
    f = _FUNCS[rng.integers(len(_FUNCS))]
    a = int(rng.integers(1, 10))
    x = int(rng.integers(1, 10))
    body = {
        "add": (f"x + {a}", x + a),
        "sub": (f"x - {a}", x - a),
        "mul": (f"x * {a}", x * a),
        "double": ("x + x", 2 * x),
        "square": ("x * x", x * x),
        "inc": ("x + 1", x + 1),
        "dec": ("x - 1", x - 1),
        "neg": ("0 - x", -x),
    }[f]
    return (
        f"def {f}_{a}(x):\n"
        f"    return {body[0]}\n"
        f"assert {f}_{a}({x}) == {body[1]}\n"
    )


def _chat_sample(rng: np.random.Generator) -> str:
    g = _GREET[rng.integers(len(_GREET))]
    t = _TOPICS[rng.integers(len(_TOPICS))]
    r1 = _REPLIES[rng.integers(len(_REPLIES))]
    r2 = _REPLIES[rng.integers(len(_REPLIES))]
    return (
        f"USER: {g}, can we talk about {t}?\n"
        f"BOT: {r1}. {t} is a fine topic.\n"
        f"USER: what do you think about {t} today?\n"
        f"BOT: {r2}.\n"
    )


_SAMPLERS = {"math": _math_sample, "code": _code_sample, "chat": _chat_sample}


def sample(task: str, rng: np.random.Generator) -> str:
    return _SAMPLERS[task](rng)


def make_stream(n_bytes: int, seed: int, mix=(1.0, 1.0, 1.0)) -> np.ndarray:
    """Deterministic training stream: uint8 array of length >= n_bytes."""
    rng = np.random.default_rng(seed)
    probs = np.asarray(mix, dtype=np.float64)
    probs /= probs.sum()
    chunks: list[bytes] = []
    total = 0
    while total < n_bytes:
        task = TASKS[rng.choice(3, p=probs)]
        piece = sample(task, rng).encode()
        chunks.append(piece)
        total += len(piece)
    return np.frombuffer(b"".join(chunks), dtype=np.uint8)[:n_bytes].copy()


def make_prompts(task: str, n: int, seed: int, prompt_len: int):
    """Task prompts for generation benchmarks (few-shot context + problem).

    Mirrors the paper's benchmarks: each prompt ends with a *complete*
    problem and an answer stem (GSM8K question + "A: ", HumanEval signature
    + body start, MT-bench user turn + "BOT: "), so generation is the
    model answering — the mostly-deterministic regime in which draft/target
    alignment (the accept rate) is meaningful.  Returns uint8 token lists,
    each exactly ``prompt_len`` long (left-truncated).
    """
    rng = np.random.default_rng(seed)
    prompts = []
    for _ in range(n):
        ctx = "".join(sample(task, rng) for _ in range(6))
        if task == "math":
            # Full question, cut right before the worked answer.
            q = sample("math", rng)
            stem = q[: q.index("\nA: ") + len("\nA: ")]
        elif task == "code":
            # Signature + body start; the name determines the body.
            c = sample("code", rng)
            stem = c[: c.index("return ") + len("return ")]
        else:
            # Complete user turn; the bot reply follows.
            c = sample("chat", rng)
            stem = c[: c.index("BOT: ") + len("BOT: ")]
        text = (ctx + stem).encode()
        text = text[-prompt_len:]
        if len(text) < prompt_len:
            text = b" " * (prompt_len - len(text)) + text
        prompts.append(list(text))
    return prompts


def heldout(n_bytes: int, seed: int) -> np.ndarray:
    """Held-out evaluation stream (wikitext2-perplexity analog)."""
    return make_stream(n_bytes, seed=seed ^ 0x5EED)
