"""L2: decoder-only transformer in pure JAX — target model and BSFP draft.

The same architecture serves as (a) the full-precision target, (b) the BSFP
4-bit draft (identical graph, linear layers routed through the Pallas
``qmatmul`` kernel over packed ``W_q`` + Eq. 4 scales), and (c) the training
forward.  This mirrors the paper's parameter sharing: the draft *is* the
target's weight bits.

Graphs exported to HLO (see ``aot.py``):

* ``prefill(params, tokens[P], length)        -> (logits[P,V], kv)``
* ``decode_full(params, token, pos, kv)       -> (logits[V], kv')``
* ``decode_draft(qparams, token, pos, kv)     -> (logits[V], kv')``

KV cache layout: ``f32[L, 2, C, H, Dh]`` (axis 1: 0 = keys, 1 = values).
The draft and full graphs share one cache (paper §III-C: zero KV overhead);
verification overwrites the drafted positions with full-precision KV.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import full_matmul as k_full
from .kernels import qmatmul as k_quant
from . import bsfp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of one tiny target model (a paper-LLM analog)."""

    name: str
    paper_analog: str
    n_layers: int
    d_model: int
    d_ff: int
    n_heads: int
    vocab: int = 256
    cache_len: int = 512
    prefill_len: int = 256
    seed: int = 0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        return sum(int(np.prod(s)) for _, s in param_shapes(self))


# The five paper models, scaled to CPU-trainable analogs (DESIGN.md §2).
# Ordering mirrors the paper's Table II rows.
MODEL_ZOO = [
    ModelConfig("vicuna-7b-tiny", "Vicuna-7b", 2, 128, 256, 4, seed=11),
    ModelConfig("llama2-7b-tiny", "Llama2-7b", 3, 128, 384, 4, seed=22),
    ModelConfig("llama3.1-8b-tiny", "Llama3.1-8b", 4, 128, 384, 4, seed=33),
    ModelConfig("llama3.2-3b-tiny", "Llama3.2-3b", 2, 128, 384, 4, seed=44),
    ModelConfig("llama2-13b-tiny", "Llama2-13b", 4, 256, 512, 8, seed=55),
]


def zoo_by_name(name: str) -> ModelConfig:
    for cfg in MODEL_ZOO:
        if cfg.name == name:
            return cfg
    raise KeyError(name)


# Linear weights quantized by BSFP (per layer + head); everything else
# (embedding, norms) stays FP16, as in the paper (linear tensors only).
_LAYER_LINEARS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def param_shapes(cfg: ModelConfig):
    """Deterministic (name, shape) list — the manifest/flattening order."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    shapes: list[tuple[str, tuple]] = [("embed", (v, d))]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        shapes += [
            (p + "attn_norm", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "mlp_norm", (d,)),
            (p + "w_gate", (d, f)),
            (p + "w_up", (d, f)),
            (p + "w_down", (f, d)),
        ]
    shapes += [("final_norm", (d,)), ("lm_head", (d, v))]
    return shapes


def linear_names(cfg: ModelConfig) -> list[str]:
    names = []
    for l in range(cfg.n_layers):
        names += [f"layer{l}.{w}" for w in _LAYER_LINEARS]
    names.append("lm_head")
    return names


def init_params(cfg: ModelConfig) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(cfg.seed)
    params = {}
    for name, shape in param_shapes(cfg):
        if name.endswith("norm"):
            params[name] = np.ones(shape, dtype=np.float32)
        else:
            fan_in = shape[0]
            std = 0.5 / np.sqrt(fan_in)
            params[name] = (rng.standard_normal(shape) * std).astype(np.float32)
    return params


# ---- building blocks ------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope(x: jnp.ndarray, pos: jnp.ndarray, head_dim: int) -> jnp.ndarray:
    """Rotary embedding; x: (T, H, Dh), pos: (T,) int32."""
    half = head_dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (np.log(10000.0) / half))
    angles = pos[:, None].astype(jnp.float32) * freqs[None, :]  # (T, half)
    cos = jnp.cos(angles)[:, None, :]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


LinearFn = Callable[[jnp.ndarray, str], jnp.ndarray]


def _block(x, l: int, linear: LinearFn, params, cfg: ModelConfig, kv, pos, t):
    """One transformer block over t tokens at positions ``pos``.

    x: (T, D); kv: full cache; pos: (T,) positions being written.
    Attention reads the cache after writing, so prefill (T = P) and
    single-token decode (T = 1) share this code path.
    """
    h_count, hd, c = cfg.n_heads, cfg.head_dim, cfg.cache_len
    h = rmsnorm(x, params[f"layer{l}.attn_norm"])
    q = linear(h, f"layer{l}.wq").reshape(t, h_count, hd)
    k = linear(h, f"layer{l}.wk").reshape(t, h_count, hd)
    v = linear(h, f"layer{l}.wv").reshape(t, h_count, hd)
    q = rope(q, pos, hd)
    k = rope(k, pos, hd)
    kv = jax.lax.dynamic_update_slice(kv, k[None, None], (l, 0, pos[0], 0, 0))
    kv = jax.lax.dynamic_update_slice(kv, v[None, None], (l, 1, pos[0], 0, 0))
    keys, vals = kv[l, 0], kv[l, 1]  # (C, H, Dh)
    scores = jnp.einsum("thd,chd->htc", q, keys) / np.sqrt(hd)
    cache_pos = jnp.arange(c, dtype=jnp.int32)
    mask = cache_pos[None, :] <= pos[:, None]  # (T, C) causal over cache
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("htc,chd->thd", attn, vals).reshape(t, cfg.d_model)
    x = x + linear(ctx, f"layer{l}.wo")
    h = rmsnorm(x, params[f"layer{l}.mlp_norm"])
    gate = jax.nn.silu(linear(h, f"layer{l}.w_gate"))
    up = linear(h, f"layer{l}.w_up")
    x = x + linear(gate * up, f"layer{l}.w_down")
    return x, kv


def _forward(tokens, pos, kv, params, linear: LinearFn, cfg: ModelConfig):
    t = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)  # (T, D)
    for l in range(cfg.n_layers):
        x, kv = _block(x, l, linear, params, cfg, kv, pos, t)
    x = rmsnorm(x, params["final_norm"])
    logits = linear(x, "lm_head")
    return logits, kv


# ---- linear-op routings ---------------------------------------------------

def full_linear(params, cfg: ModelConfig, *, use_pallas: bool) -> LinearFn:
    """Full-precision linears — Pallas full-mode GEMM in exported graphs."""
    lin = set(linear_names(cfg))

    def linear(x, name):
        if use_pallas and name in lin:
            b = x.shape[0]
            bm = min(k_full.BLOCK_M, b)
            if b % bm == 0:
                return k_full.matmul(x, params[name])
        return x @ params[name]

    return linear


def draft_linear(qparams, params, cfg: ModelConfig) -> LinearFn:
    """Draft linears — Pallas quantize-mode GEMM over packed W_q."""
    lin = set(linear_names(cfg))

    def linear(x, name):
        if name in lin:
            return k_quant.qmatmul(
                x, qparams[name + ".wq"], qparams[name + ".scales"]
            )
        return x @ params[name]

    return linear


# ---- exported graph builders ---------------------------------------------
#
# PJRT returns multi-output graphs as one tuple buffer, which the Rust side
# cannot split without a full host round-trip.  All request-path graphs
# therefore return a SINGLE flat f32 "state" vector:
#
#     state = [ S_SLOTS * V logits slots | KV cache (flattened) ]
#
# Rust threads the state buffer output -> input and copies only the logits
# prefix to the host each step.  The verify graph fills all S_SLOTS logits
# rows (the paper's single parallel verification pass); prefill and the two
# decode graphs fill slot 0 only.

# Max draft length 20 (the paper ablates L up to 20; default L = 16) + 1
# bonus token from verification.
S_SLOTS = 21


def kv_shape(cfg: ModelConfig):
    return (cfg.n_layers, 2, cfg.cache_len, cfg.n_heads, cfg.head_dim)


def state_len(cfg: ModelConfig) -> int:
    return S_SLOTS * cfg.vocab + int(np.prod(kv_shape(cfg)))


def _pack_state(slots: jnp.ndarray, kv: jnp.ndarray, cfg: ModelConfig):
    return jnp.concatenate([slots.reshape(-1), kv.reshape(-1)])


def _unpack_kv(state: jnp.ndarray, cfg: ModelConfig):
    return state[S_SLOTS * cfg.vocab :].reshape(kv_shape(cfg))


def make_prefill(cfg: ModelConfig, *, use_pallas: bool = True):
    """Prefill graph: prompt -> state (slot 0 = logits at position len-1)."""

    def prefill(params: dict, tokens, length):
        kv = jnp.zeros(kv_shape(cfg), dtype=jnp.float32)
        pos = jnp.arange(cfg.prefill_len, dtype=jnp.int32)
        linear = full_linear(params, cfg, use_pallas=use_pallas)
        # Zero padded tail tokens; their KV rows are written but never
        # attended to (decode masks by true cache position).
        tokens = jnp.where(pos < length, tokens, 0)
        logits, kv = _forward(tokens, pos, kv, params, linear, cfg)
        last = jax.lax.dynamic_slice(logits, (length - 1, 0), (1, cfg.vocab))
        slots = jnp.zeros((S_SLOTS, cfg.vocab), dtype=jnp.float32)
        slots = jax.lax.dynamic_update_slice(slots, last, (0, 0))
        return _pack_state(slots, kv, cfg)

    return prefill


def make_eval(cfg: ModelConfig, *, use_pallas: bool = True):
    """Eval graph: full per-position logits (P, V) — the perplexity harness."""

    def evaluate(params: dict, tokens, length):
        kv = jnp.zeros(kv_shape(cfg), dtype=jnp.float32)
        pos = jnp.arange(cfg.prefill_len, dtype=jnp.int32)
        linear = full_linear(params, cfg, use_pallas=use_pallas)
        tokens = jnp.where(pos < length, tokens, 0)
        logits, _ = _forward(tokens, pos, kv, params, linear, cfg)
        return logits

    return evaluate


def _decode_step(linear, params, cfg, token, pos, state):
    kv = _unpack_kv(state, cfg)
    tokens = jnp.reshape(token, (1,)).astype(jnp.int32)
    posv = jnp.reshape(pos, (1,)).astype(jnp.int32)
    logits, kv = _forward(tokens, posv, kv, params, linear, cfg)
    slots = jnp.zeros((S_SLOTS, cfg.vocab), dtype=jnp.float32)
    slots = slots.at[0].set(logits[0])
    return _pack_state(slots, kv, cfg)


def make_decode(cfg: ModelConfig, *, use_pallas: bool = True):
    def decode(params: dict, token, pos, state):
        linear = full_linear(params, cfg, use_pallas=use_pallas)
        return _decode_step(linear, params, cfg, token, pos, state)

    return decode


def make_decode_draft(cfg: ModelConfig):
    def decode_draft(params: dict, qparams: dict, token, pos, state):
        linear = draft_linear(qparams, params, cfg)
        return _decode_step(linear, params, cfg, token, pos, state)

    return decode_draft


def make_verify(cfg: ModelConfig, *, use_pallas: bool = True):
    """Verification graph: score S_SLOTS tokens in ONE parallel pass.

    Recomputes full-precision KV for every drafted position (overwriting the
    draft's quantized-pass KV — the shared-cache scheme of §III-C) and fills
    every logits slot.  Padded tail tokens write KV rows beyond the current
    position, which are never attended to before being overwritten.
    """

    def verify(params: dict, tokens, pos0, state):
        kv = _unpack_kv(state, cfg)
        linear = full_linear(params, cfg, use_pallas=use_pallas)
        tokens = jnp.reshape(tokens, (S_SLOTS,)).astype(jnp.int32)
        pos = pos0 + jnp.arange(S_SLOTS, dtype=jnp.int32)
        logits, kv = _forward(tokens, pos, kv, params, linear, cfg)
        return _pack_state(logits, kv, cfg)

    return verify


# ---- training forward (batched, no cache, plain jnp) ----------------------

def train_logits(params: dict, tokens: jnp.ndarray, cfg: ModelConfig):
    """Batched training forward; tokens (B, S) -> logits (B, S, V)."""

    def one(seq):
        s = seq.shape[0]
        cfg_local = dataclasses.replace(cfg, cache_len=s)
        kv = jnp.zeros(kv_shape(cfg_local), dtype=jnp.float32)
        pos = jnp.arange(s, dtype=jnp.int32)
        linear = full_linear(params, cfg_local, use_pallas=False)
        logits, _ = _forward(seq, pos, kv, params, linear, cfg_local)
        return logits

    return jax.vmap(one)(tokens)


def quantize_params(params: dict, cfg: ModelConfig):
    """BSFP-quantize every linear weight; returns the draft qparams dict.

    Each linear ``name`` contributes ``name.wq`` (nibble-packed uint8) and
    ``name.scales`` (f32).  Also returns per-tensor manifest metadata.
    """
    qparams: dict[str, np.ndarray] = {}
    meta: dict[str, dict] = {}
    for name in linear_names(cfg):
        w = np.asarray(params[name], dtype=np.float32)
        qt = bsfp.quantize_tensor(w)
        # Lossless invariant (the paper's bit-sharing property).
        rec = qt.reconstruct_fp16_bits()
        orig_bits = bsfp.f32_to_bits(bsfp.algorithm1_prescale(w)[0])
        assert np.array_equal(rec, orig_bits), f"lossless violation in {name}"
        qparams[name + ".wq"] = qt.packed_wq()
        qparams[name + ".scales"] = qt.scales.astype(np.float32)
        meta[name] = {"tensor_scale": qt.tensor_scale, "shape": list(w.shape)}
    return qparams, meta
