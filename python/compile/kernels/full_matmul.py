"""Pallas full-precision GEMM kernel — the verification-pass hot path.

Full mode of the paper's reconfigurable PE array (Fig. 6, right): weights are
consumed at full precision.  Tiles are sized for VMEM-style double buffering:
the grid walks (M tiles, K tiles) and accumulates into the output tile so the
weight tensor streams through exactly once per M tile (weight-stationary
within a tile, matching the accelerator's W-buffer reuse).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_K = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(1)
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(k > 0)
    def _acc():
        o_ref[...] = o_ref[...] + acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(x, w, *, interpret: bool = True):
    """Full-precision GEMM ``x @ w`` with (M, K)-tiled accumulation.

    Args:
      x: (B, K) float32.
      w: (K, N) float32.
    Returns (B, N) float32.
    """
    b, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = min(BLOCK_M, b)
    bk = min(BLOCK_K, k)
    assert b % bm == 0 and k % bk == 0, (x.shape, bm, bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(b // bm, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(x, w)
