"""Pallas BSFP encode kernel: FP16 bit patterns -> (W_q, W_r).

The quantization itself happens once, offline — but expressing the encoder as
a kernel (a) documents the paper's Fig. 3 remap as dataflow, and (b) gives the
test suite a third independent implementation to cross-check (numpy codec,
jnp oracle, Pallas kernel must all agree bit-for-bit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _remap(exp: jnp.ndarray):
    """Fig. 3 remap in arithmetic form (kernels cannot capture LUT arrays).

    naive code = E >> 1.  Codes 3'b000 / 3'b010 are stolen for E = 9 / 11, so
    low-range values whose naive code is even round up to the next odd code
    (flag set); E = 9 and E = 11 take the stolen codes (flag set); everything
    else keeps its naive code (flag clear).
    """
    naive = exp >> 1
    low_even = (exp < 8) & ((naive & 1) == 0)
    critical = (exp == 9) | (exp == 11)
    code = jnp.where(low_even, naive + 1, jnp.where(critical, exp - 9, naive))
    flag = (low_even | critical).astype(jnp.int32)
    return code, flag


def _encode_kernel(bits_ref, wq_ref, wr_ref):
    bits = bits_ref[...].astype(jnp.int32)  # uint16 widened for bit ops
    sign = (bits >> 15) & 1
    exp = (bits >> 10) & 0x1F
    man = bits & 0x3FF
    code, flag = _remap(exp)
    e0 = exp & 1
    wq_ref[...] = ((sign << 3) | code).astype(jnp.uint8)
    wr_ref[...] = ((flag << 11) | (e0 << 10) | man).astype(jnp.uint16)


@functools.partial(jax.jit, static_argnames=("interpret",))
def encode(bits, *, interpret: bool = True):
    """Encode FP16 bit patterns (uint16, any 2-D shape) into (W_q, W_r)."""
    rows, cols = bits.shape
    br = min(128, rows)
    assert rows % br == 0, bits.shape
    return pl.pallas_call(
        _encode_kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), jnp.uint8),
            jax.ShapeDtypeStruct((rows, cols), jnp.uint16),
        ],
        interpret=interpret,
    )(bits)
