"""Pallas draft-GEMM kernel: activations @ BSFP-packed 4-bit weights.

This is the paper's quantize-mode hot path (Fig. 6, left) re-expressed for a
TPU-style memory hierarchy instead of the ASIC PE array:

* the weight stream into the kernel is the *packed* 4-bit ``W_q`` (two codes
  per byte) plus the per-128-group Eq. 4 scales — 4.25 bits/element instead
  of 16, which is exactly the bandwidth reduction the reconfigurable PE
  array exploits in quantize mode;
* the Fig. 5(a) draft decoder (code -> quantized exponent, a pure LUT) runs
  in-register on the VMEM-resident tile before the MXU matmul;
* the grid walks K in 128-wide groups (one scale row per grid step) and
  accumulates into the output tile, i.e. the HBM->VMEM schedule replaces the
  paper's threadblock/PE-tile schedule (see DESIGN.md §Hardware-Adaptation).

Kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls; numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import FP16_BIAS, GROUP_SIZE


def _code_to_qexp(code: jnp.ndarray) -> jnp.ndarray:
    """Fig. 5(a) LUT [9, 2, 11, 6, 8, 10, 12, 14] in arithmetic form.

    Pallas kernels cannot close over constant arrays, so the decoder's
    NOR-gate structure is expressed directly: the stolen codes 3'b000 and
    3'b010 (both with c0 = c2 = 0) decode to 9 and 11 (= code + 9); every
    other code decodes to 2*code, exactly the "append a zero" datapath.
    """
    code = code.astype(jnp.int32)
    stolen = (code < 4) & ((code & 1) == 0)
    return jnp.where(stolen, code + 9, 2 * code)


def _decode_nibbles(codes: jnp.ndarray) -> jnp.ndarray:
    """Fig. 5(a) decode of a nibble plane: 4-bit codes -> signed draft values."""
    sign = (codes >> 3) & 1
    qexp = _code_to_qexp(codes & 0x7)
    mag = jnp.exp2(qexp.astype(jnp.float32) - FP16_BIAS)
    return jnp.where(sign == 1, -mag, mag)


def _qmatmul_kernel(x_ref, wq_ref, s_ref, o_ref):
    # Perf (§Perf log, 3.0x in interpret mode): decode the low/high nibble
    # planes separately and pair them with the even/odd activation lanes —
    # y = x_even @ W_lo + x_odd @ W_hi — instead of interleaving the planes
    # back into a (GROUP_SIZE, N) tile (stack + reshape dominated the step).
    k = pl.program_id(0)
    packed = wq_ref[...]
    s = s_ref[...]
    w_lo = _decode_nibbles(packed & 0xF) * s         # group rows 0, 2, 4, ...
    w_hi = _decode_nibbles((packed >> 4) & 0xF) * s  # group rows 1, 3, 5, ...
    x = x_ref[...]
    acc = jnp.dot(x[:, 0::2], w_lo, preferred_element_type=jnp.float32) + jnp.dot(
        x[:, 1::2], w_hi, preferred_element_type=jnp.float32
    )

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(k > 0)
    def _acc():
        o_ref[...] = o_ref[...] + acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def qmatmul(x, wq_packed, scales, *, interpret: bool = True):
    """Draft GEMM ``x @ dequant(wq_packed, scales)``.

    Args:
      x:          (B, K) float32 activations.
      wq_packed:  (K // 2, N) uint8 nibble-packed W_q codes.
      scales:     (K // GROUP_SIZE, N) float32 Eq. 4 group scales.
    Returns (B, N) float32.
    """
    b, k = x.shape
    kp, n = wq_packed.shape
    assert kp * 2 == k, (x.shape, wq_packed.shape)
    assert k % GROUP_SIZE == 0, f"K={k} must be a multiple of {GROUP_SIZE}"
    groups = k // GROUP_SIZE
    return pl.pallas_call(
        _qmatmul_kernel,
        grid=(groups,),
        in_specs=[
            pl.BlockSpec((b, GROUP_SIZE), lambda i: (0, i)),
            pl.BlockSpec((GROUP_SIZE // 2, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(x, wq_packed, scales)
