"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
is pytest-verified (with hypothesis shape/dtype sweeps) against the matching
function here, and the L2 graphs can be built against either implementation.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Fig. 5(a) draft decoder table: 3-bit remapped code -> quantized exponent.
CODE_TO_QEXP = jnp.asarray([9, 2, 11, 6, 8, 10, 12, 14], dtype=jnp.int32)
FP16_BIAS = 15
GROUP_SIZE = 128


def unpack_codes(wq_packed: jnp.ndarray) -> jnp.ndarray:
    """Unpack nibble-packed W_q codes: (K//2, N) uint8 -> (K, N) uint8.

    Element 2i sits in the low nibble, 2i+1 in the high nibble.
    """
    lo = wq_packed & 0xF
    hi = (wq_packed >> 4) & 0xF
    kp, n = wq_packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * kp, n)


def dequant_draft(wq_packed: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Reference BSFP draft dequantization: packed codes + Eq.4 scales -> f32.

    ``wq_packed``: (K//2, N) uint8; ``scales``: (K//GROUP_SIZE, N) f32.
    Returns (K, N) float32 draft weights.
    """
    codes = unpack_codes(wq_packed)
    sign = (codes >> 3) & 1
    qexp = CODE_TO_QEXP[(codes & 0x7).astype(jnp.int32)]
    mag = jnp.exp2(qexp.astype(jnp.float32) - FP16_BIAS)
    w = jnp.where(sign == 1, -mag, mag)
    k, n = w.shape
    g = k // GROUP_SIZE
    w = w.reshape(g, GROUP_SIZE, n) * scales.reshape(g, 1, n)
    return w.reshape(k, n)


def qmatmul(x: jnp.ndarray, wq_packed: jnp.ndarray, scales: jnp.ndarray):
    """Reference draft GEMM: x (B, K) f32 @ BSFP-packed weight -> (B, N)."""
    return x @ dequant_draft(wq_packed, scales)


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference full-precision GEMM."""
    return x @ w


def quantize_bits(bits: jnp.ndarray):
    """jnp mirror of bsfp.encode on uint16 FP16 bit patterns.

    Returns (w_q uint8, w_r uint16).  Used as the oracle for the Pallas
    quantize kernel.
    """
    remap_code = jnp.asarray(
        [1, 1, 1, 1, 3, 3, 3, 3, 4, 0, 5, 2, 6, 6, 7, 7], dtype=jnp.uint16
    )
    remap_flag = jnp.asarray(
        [1, 1, 0, 0, 1, 1, 0, 0, 0, 1, 0, 1, 0, 0, 0, 0], dtype=jnp.uint16
    )
    bits = bits.astype(jnp.uint16)
    sign = bits >> 15
    exp = (bits >> 10) & 0x1F
    man = bits & 0x3FF
    code = remap_code[exp.astype(jnp.int32)]
    flag = remap_flag[exp.astype(jnp.int32)]
    e0 = exp & 1
    w_q = ((sign << 3) | code).astype(jnp.uint8)
    w_r = ((flag << 11) | (e0 << 10) | man).astype(jnp.uint16)
    return w_q, w_r


def np_goldens(rng: np.random.Generator, k: int = 256, n: int = 8):
    """Random FP16-representable weights for golden-vector emission."""
    return rng.standard_normal((k, n)).astype(np.float16).astype(np.float32)
