"""AOT pipeline: train -> quantize -> lower to HLO text -> artifacts/.

Runs once at ``make artifacts``; Python never appears on the request path.
Per model it emits

    artifacts/<name>/weights.bin        FP16 bit patterns, param order
    artifacts/<name>/{prefill,decode_full,decode_draft}.hlo.txt
    artifacts/<name>/train_meta.json

plus shared files

    artifacts/manifest.json             configs, param tables, graph arg order
    artifacts/goldens.bin               exhaustive BSFP encode vectors
    artifacts/goldens.json              Eq.4-scale / qmatmul cross-layer vectors
    artifacts/tasks/{math,code,chat}.json
    artifacts/heldout.bin               held-out stream for perplexity (Table I)

Interchange format is HLO **text**: jax >= 0.5 emits protos with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).  Graphs are lowered
with ``return_tuple=False`` so outputs arrive as separate PJRT buffers and
the Rust engine can thread the KV buffer between steps without host copies.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import bsfp, corpus, train
from .model import (
    MODEL_ZOO,
    ModelConfig,
    kv_shape,
    linear_names,
    make_decode,
    make_decode_draft,
    make_prefill,
    param_shapes,
    quantize_params,
)

GOLDEN_QMATMUL_K = 256
GOLDEN_QMATMUL_N = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def cfg_digest(cfg: ModelConfig) -> str:
    # Include the corpus generator source: retrain when the data changes.
    corpus_src = (pathlib.Path(__file__).parent / "corpus.py").read_bytes()
    blob = json.dumps(
        {
            "corpus": hashlib.sha256(corpus_src).hexdigest(),
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "d_ff": cfg.d_ff,
            "n_heads": cfg.n_heads,
            "vocab": cfg.vocab,
            "seed": cfg.seed,
            "steps": train.STEPS,
            "batch": train.BATCH,
            "seq": train.SEQ,
        },
        sort_keys=True,
    )
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


# ---- weights serialization -------------------------------------------------

def save_weights(path: pathlib.Path, params: dict, cfg: ModelConfig):
    """Concatenate FP16 bit patterns in param_shapes order."""
    chunks = []
    for name, shape in param_shapes(cfg):
        w = np.asarray(params[name], dtype=np.float32)
        assert tuple(w.shape) == tuple(shape), (name, w.shape, shape)
        chunks.append(w.astype(np.float16).view(np.uint16).ravel())
    blob = np.concatenate(chunks)
    path.write_bytes(blob.tobytes())


def load_weights(path: pathlib.Path, cfg: ModelConfig) -> dict:
    raw = np.frombuffer(path.read_bytes(), dtype=np.uint16)
    params, off = {}, 0
    for name, shape in param_shapes(cfg):
        n = int(np.prod(shape))
        params[name] = raw[off : off + n].view(np.float16).astype(np.float32).reshape(shape)
        off += n
    assert off == raw.size
    return params


def param_table(cfg: ModelConfig):
    table, off = [], 0
    for name, shape in param_shapes(cfg):
        n = int(np.prod(shape)) * 2
        table.append(
            {"name": name, "shape": list(shape), "dtype": "f16", "offset_bytes": off, "size_bytes": n}
        )
        off += n
    return table


# ---- graph export ----------------------------------------------------------

def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def export_graphs(cfg: ModelConfig, out_dir: pathlib.Path, log=print):
    from .model import S_SLOTS, make_eval, make_verify, state_len

    names = [n for n, _ in param_shapes(cfg)]
    shapes = dict(param_shapes(cfg))
    lin = set(linear_names(cfg))
    slen = state_len(cfg)

    def emit(fname, fn, extra_args):
        args = [_sds(shapes[n]) for n in names] + extra_args
        (out_dir / fname).write_text(to_hlo_text(jax.jit(fn).lower(*args)))
        log(f"  [{cfg.name}] {fname}")

    # prefill(params..., tokens, length) -> state
    def prefill_flat(*args):
        params = dict(zip(names, args[: len(names)]))
        tokens, length = args[len(names) :]
        return make_prefill(cfg)(params, tokens, length)

    emit(
        "prefill.hlo.txt",
        prefill_flat,
        [_sds((cfg.prefill_len,), jnp.int32), _sds((), jnp.int32)],
    )

    # eval(params..., tokens, length) -> logits (P, V)
    def eval_flat(*args):
        params = dict(zip(names, args[: len(names)]))
        tokens, length = args[len(names) :]
        return make_eval(cfg)(params, tokens, length)

    emit(
        "eval.hlo.txt",
        eval_flat,
        [_sds((cfg.prefill_len,), jnp.int32), _sds((), jnp.int32)],
    )

    # decode_full(params..., token, pos, state) -> state
    def decode_flat(*args):
        params = dict(zip(names, args[: len(names)]))
        token, pos, state = args[len(names) :]
        return make_decode(cfg)(params, token, pos, state)

    emit(
        "decode_full.hlo.txt",
        decode_flat,
        [_sds((), jnp.int32), _sds((), jnp.int32), _sds((slen,))],
    )

    # verify(params..., tokens[S_SLOTS], pos0, state) -> state
    def verify_flat(*args):
        params = dict(zip(names, args[: len(names)]))
        tokens, pos0, state = args[len(names) :]
        return make_verify(cfg)(params, tokens, pos0, state)

    emit(
        "verify.hlo.txt",
        verify_flat,
        [_sds((S_SLOTS,), jnp.int32), _sds((), jnp.int32), _sds((slen,))],
    )

    # extract(state) -> logits slots (S_SLOTS, V).  The PJRT build cannot
    # copy a raw prefix of a device buffer to the host, so this tiny graph
    # slices the logits slots out of the threaded state on-device; only
    # S_SLOTS * V floats ever cross the host boundary per step.
    def extract_fn(state):
        return state[: S_SLOTS * cfg.vocab].reshape(S_SLOTS, cfg.vocab)

    (out_dir / "extract.hlo.txt").write_text(
        to_hlo_text(jax.jit(extract_fn).lower(_sds((slen,))))
    )
    log(f"  [{cfg.name}] extract.hlo.txt")

    # decode_draft(mixed args: quantized linears as (wq, scales))
    draft_order = []  # manifest arg list
    for n in names:
        if n in lin:
            draft_order += [n + ".wq", n + ".scales"]
        else:
            draft_order.append(n)

    def draft_flat(*args):
        params, qparams, i = {}, {}, 0
        for n in names:
            if n in lin:
                qparams[n + ".wq"] = args[i]
                qparams[n + ".scales"] = args[i + 1]
                i += 2
            else:
                params[n] = args[i]
                i += 1
        token, pos, state = args[i:]
        return make_decode_draft(cfg)(params, qparams, token, pos, state)

    draft_args = []
    for n in names:
        if n in lin:
            k, out = shapes[n]
            draft_args.append(_sds((k // 2, out), jnp.uint8))
            draft_args.append(_sds((k // bsfp.GROUP_SIZE, out), jnp.float32))
        else:
            draft_args.append(_sds(shapes[n]))
    draft_args += [_sds((), jnp.int32), _sds((), jnp.int32), _sds((slen,))]
    (out_dir / "decode_draft.hlo.txt").write_text(
        to_hlo_text(jax.jit(draft_flat).lower(*draft_args))
    )
    log(f"  [{cfg.name}] decode_draft.hlo.txt")
    return draft_order


# ---- goldens ---------------------------------------------------------------

def emit_goldens(out_dir: pathlib.Path):
    """Exhaustive encode vectors + Eq.4/qmatmul cross-layer checks.

    goldens.bin layout: for all 32768 valid FP16 bit patterns (exp <= 15),
    ordered by bits = s<<15 | e<<10 | m ascending within s-major order:
        [32768 x u8  W_q][32768 x u16 W_r (LE)]
    """
    pats = []
    for s in range(2):
        for e in range(16):
            for m in range(1024):
                pats.append((s << 15) | (e << 10) | m)
    bits = np.asarray(pats, dtype=np.uint16)
    w_q, w_r = bsfp.encode(bits)
    assert np.array_equal(bsfp.decode_full(w_q, w_r), bits)
    (out_dir / "goldens.bin").write_bytes(
        w_q.astype(np.uint8).tobytes() + w_r.astype("<u2").tobytes()
    )

    rng = np.random.default_rng(7)
    w = (rng.standard_normal((GOLDEN_QMATMUL_K, GOLDEN_QMATMUL_N)) * 0.07).astype(
        np.float32
    )
    qt = bsfp.quantize_tensor(w)
    x = rng.standard_normal((1, GOLDEN_QMATMUL_K)).astype(np.float32)
    y = (x @ qt.dequant_draft()).astype(np.float32)
    golden = {
        "qmatmul": {
            "w_f16_bits": bsfp.f32_to_bits(w).ravel().tolist(),
            "k": GOLDEN_QMATMUL_K,
            "n": GOLDEN_QMATMUL_N,
            "x": x.ravel().tolist(),
            "y": y.ravel().tolist(),
            "scales": qt.scales.ravel().tolist(),
            "wq_packed": qt.packed_wq().ravel().tolist(),
        },
        "eq4": {
            "w_bits": bsfp.f32_to_bits(w[:128, 0]).tolist(),
            "scale": float(qt.scales[0, 0]),
        },
    }
    (out_dir / "goldens.json").write_text(json.dumps(golden))


def emit_tasks(out_dir: pathlib.Path, prompt_len: int, n_prompts: int):
    tdir = out_dir / "tasks"
    tdir.mkdir(exist_ok=True)
    files = {}
    for i, task in enumerate(corpus.TASKS):
        prompts = corpus.make_prompts(task, n_prompts, seed=1000 + i, prompt_len=prompt_len)
        paper_name = {"math": "GSM8K", "code": "Humaneval", "chat": "MT-bench"}[task]
        (tdir / f"{task}.json").write_text(
            json.dumps({"task": task, "paper_analog": paper_name, "prompt_len": prompt_len, "prompts": prompts})
        )
        files[task] = f"tasks/{task}.json"
    return files


# ---- main ------------------------------------------------------------------

def build_model(cfg: ModelConfig, out_root: pathlib.Path, force: bool, log=print):
    mdir = out_root / cfg.name
    mdir.mkdir(parents=True, exist_ok=True)
    wpath = mdir / "weights.bin"
    meta_path = mdir / "train_meta.json"
    digest = cfg_digest(cfg)

    if wpath.exists() and meta_path.exists() and not force:
        meta = json.loads(meta_path.read_text())
        if meta.get("digest") == digest:
            log(f"  [{cfg.name}] cached weights (digest {digest})")
            params = load_weights(wpath, cfg)
        else:
            params = None
    else:
        params = None

    if params is None:
        log(f"  [{cfg.name}] training ({cfg.param_count():,} params)...")
        params, losses = train.train_model(cfg, log=log)
        save_weights(wpath, params, cfg)
        meta_path.write_text(
            json.dumps(
                {
                    "digest": digest,
                    "loss_first": losses[0],
                    "loss_last": losses[-1],
                    "loss_curve": losses[:: max(1, len(losses) // 50)],
                }
            )
        )
        params = load_weights(wpath, cfg)  # reload: canonical FP16 values

    # Quantize (validates the lossless invariant) and export graphs.
    from .model import S_SLOTS, state_len

    _, qmeta = quantize_params(params, cfg)
    draft_order = export_graphs(cfg, mdir, log=log)
    meta = json.loads(meta_path.read_text())
    return {
        "state": {"slots": S_SLOTS, "state_len": state_len(cfg)},
        "config": {
            "name": cfg.name,
            "paper_analog": cfg.paper_analog,
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "d_ff": cfg.d_ff,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "vocab": cfg.vocab,
            "cache_len": cfg.cache_len,
            "prefill_len": cfg.prefill_len,
            "param_count": cfg.param_count(),
        },
        "params": param_table(cfg),
        "linears": linear_names(cfg),
        "quant_meta": qmeta,
        "kv_shape": list(kv_shape(cfg)),
        "graphs": {
            "prefill": {
                "file": f"{cfg.name}/prefill.hlo.txt",
                "args": [n for n, _ in param_shapes(cfg)] + ["tokens", "length"],
                "outputs": ["state"],
            },
            "eval": {
                "file": f"{cfg.name}/eval.hlo.txt",
                "args": [n for n, _ in param_shapes(cfg)] + ["tokens", "length"],
                "outputs": ["logits"],
            },
            "decode_full": {
                "file": f"{cfg.name}/decode_full.hlo.txt",
                "args": [n for n, _ in param_shapes(cfg)] + ["token", "pos", "state"],
                "outputs": ["state"],
            },
            "verify": {
                "file": f"{cfg.name}/verify.hlo.txt",
                "args": [n for n, _ in param_shapes(cfg)] + ["tokens", "pos0", "state"],
                "outputs": ["state"],
            },
            "decode_draft": {
                "file": f"{cfg.name}/decode_draft.hlo.txt",
                "args": draft_order + ["token", "pos", "state"],
                "outputs": ["state"],
            },
            "extract": {
                "file": f"{cfg.name}/extract.hlo.txt",
                "args": ["state"],
                "outputs": ["logits_slots"],
            },
        },
        "train": {"loss_first": meta["loss_first"], "loss_last": meta["loss_last"]},
        "weights": f"{cfg.name}/weights.bin",
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="", help="comma-separated subset of model names")
    ap.add_argument("--force", action="store_true", help="retrain even if cached")
    ap.add_argument("--heldout-bytes", type=int, default=1 << 18)
    ap.add_argument("--n-prompts", type=int, default=12)
    args = ap.parse_args()

    out_root = pathlib.Path(args.out_dir).resolve()
    out_root.mkdir(parents=True, exist_ok=True)
    wanted = [s for s in args.models.split(",") if s]
    zoo = [c for c in MODEL_ZOO if not wanted or c.name in wanted]

    print(f"AOT: building {len(zoo)} models into {out_root}")
    models = {}
    for cfg in zoo:
        models[cfg.name] = build_model(cfg, out_root, args.force)

    emit_goldens(out_root)
    print("  goldens.bin / goldens.json")
    prompt_len = 128
    task_files = emit_tasks(out_root, prompt_len=prompt_len, n_prompts=args.n_prompts)
    print("  tasks/*.json")
    heldout = corpus.heldout(args.heldout_bytes, seed=99)
    (out_root / "heldout.bin").write_bytes(heldout.tobytes())
    print("  heldout.bin")

    manifest = {
        "version": 1,
        "group_size": bsfp.GROUP_SIZE,
        "models": models,
        "tasks": task_files,
        "prompt_len": prompt_len,
        "heldout": "heldout.bin",
        "goldens_bin": "goldens.bin",
        "goldens_json": "goldens.json",
    }
    (out_root / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print("  manifest.json")
    print("AOT done.")


if __name__ == "__main__":
    sys.exit(main())
