//! PE-array cycle model (Fig. 6 modes) — the Table IV compute substrate.
//! Run: cargo bench --bench bench_pe_array

use speq::accel::{AccelConfig, ArrayMode, PeArray};
use speq::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("bench_pe_array");
    let pe = PeArray::new(&AccelConfig::default());

    b.bench("gemm_cycles_full_4kx4k", || {
        black_box(pe.gemm_cycles(1, 4096, 4096, ArrayMode::Full));
    });
    b.bench("gemm_cycles_quant_4kx4k", || {
        black_box(pe.gemm_cycles(1, 4096, 4096, ArrayMode::Quant));
    });
    b.bench("gemm_activity_verify17", || {
        black_box(pe.gemm_activity(17, 4096, 4096, ArrayMode::Full));
    });

    let cfg = AccelConfig::default();
    b.metric("full_mode_peak", pe.peak_macs_per_s(ArrayMode::Full) / 1e12, "TMAC/s");
    b.metric("quant_mode_peak", pe.peak_macs_per_s(ArrayMode::Quant) / 1e12, "TMAC/s");
    b.metric("dram_bytes_per_cycle", cfg.dram_bytes_per_cycle(), "B/cyc");
}
