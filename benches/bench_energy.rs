//! Energy model evaluation speed + Fig. 8 metrics.
//! Run: cargo bench --bench bench_energy

use speq::accel::{paper_dims, power_report, Accel, ArrayMode, BaselineKind, DesignPoint};
use speq::specdec::{IterRecord, SpecTrace};
use speq::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("bench_energy");
    let accel = Accel::default();
    let dims = paper_dims("Llama2-7b").unwrap();

    b.bench("decode_step_energy_full", || {
        black_box(accel.decode_step_cost(dims, 1024, ArrayMode::Full).energy);
    });
    b.bench("decode_step_energy_quant", || {
        black_box(accel.decode_step_cost(dims, 1024, ArrayMode::Quant).energy);
    });

    let q = power_report(&accel.cfg, &accel.energy, true);
    let f = power_report(&accel.cfg, &accel.energy, false);
    b.metric("power_quantize_mode", q.total_mw, "mW (paper: 508)");
    b.metric("power_full_mode", f.total_mw, "mW (paper: 559)");

    let trace = SpecTrace {
        iterations: vec![IterRecord { drafted: 16, accepted: 14, early_exit: false }; 16],
        produced: 240,
        prompt_len: 128,
    };
    let tc = accel.run_trace(dims, &trace, 1024);
    b.metric("speq_energy_gain", tc.energy_efficiency_gain(), "x vs FP16 (paper: 1.74)");
    let fp16 = DesignPoint::get(BaselineKind::Fp16).token_cost(&accel, dims, 1024);
    let o8 = DesignPoint::get(BaselineKind::Olive8).token_cost(&accel, dims, 1024);
    b.metric(
        "olive8_energy_gain",
        fp16.energy.total_pj() / o8.energy.total_pj(),
        "x vs FP16",
    );
}
