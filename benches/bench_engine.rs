//! Engine hot path over the execution backend (builtin native model; uses
//! trained artifacts automatically when present).
//! Run: cargo bench --bench bench_engine

use speq::model::SamplingParams;
use speq::runtime::{load_backend, Backend, ModelSource};
use speq::specdec::{Engine, SpecConfig};
use speq::util::bench::{black_box, Bench};

fn main() {
    let source = ModelSource::auto();
    let backend = load_backend(&source, "vicuna-7b-tiny").expect("backend");
    let model = backend.as_ref();
    let engine = Engine::new(model);
    let mut b = Bench::new(format!("bench_engine[{}]", model.backend_name()));
    let prompt: &[u8] = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";

    // Single-step costs (the request-path atoms).
    let plen = prompt.len();
    let mut toks: Vec<i32> = prompt.iter().map(|&x| x as i32).collect();
    toks.resize(model.prefill_len(), b' ' as i32);
    b.bench("prefill_256", || {
        black_box(model.prefill(&toks, plen).expect("prefill").logits.len());
    });
    // Steps thread the state through an Option so each iteration measures
    // exactly one step (re-decoding position `plen` overwrites one KV row).
    let mut state = Some(model.prefill(&toks, plen).expect("prefill").state);
    b.bench("decode_full_step", || {
        let out = model.decode_full(65, plen, state.take().unwrap()).expect("step");
        black_box(out.logits.len());
        state = Some(out.state);
    });
    let mut state = Some(model.prefill(&toks, plen).expect("prefill").state);
    b.bench("decode_draft_step", || {
        let out = model.decode_draft(65, plen, state.take().unwrap()).expect("step");
        black_box(out.logits.len());
        state = Some(out.state);
    });
    let vtokens: Vec<i32> = (0..model.slots() as i32).collect();
    let mut state = Some(model.prefill(&toks, plen).expect("prefill").state);
    b.bench("verify_pass_full_slots", || {
        let out = model.verify(&vtokens, plen, state.take().unwrap()).expect("verify");
        black_box(out.logits.len());
        state = Some(out.state);
    });

    // End-to-end generation (64 tokens).
    let cfg = SpecConfig { gen_len: 64, ..Default::default() };
    let s = b.bench("generate_spec_64tok", || {
        black_box(engine.generate_spec(prompt, &cfg).expect("spec").tokens.len());
    });
    b.metric("spec_tokens_per_s", 64.0 / (s.mean_ns * 1e-9), "tok/s (CPU)");
    let s = b.bench("generate_ar_64tok", || {
        black_box(
            engine.generate_ar(prompt, 64, SamplingParams::greedy()).expect("ar").tokens.len(),
        );
    });
    b.metric("ar_tokens_per_s", 64.0 / (s.mean_ns * 1e-9), "tok/s (CPU)");
}
