//! Engine hot path over the execution backend (builtin native model; uses
//! trained artifacts automatically when present).
//!
//! Run: cargo bench --bench bench_engine
//! Quick CI regression guard: cargo bench --bench bench_engine -- --smoke

use std::collections::BTreeMap;

use speq::model::SamplingParams;
use speq::runtime::{
    load_backend, load_backend_with, Backend, ModelSource, NativeConfig, SeqSlot, SimdLevel,
};
use speq::specdec::{AdaptiveConfig, BatchEngine, Engine, SpecConfig};
use speq::util::bench::{black_box, smoke_requested, Bench};

fn main() {
    let smoke = smoke_requested();
    let source = ModelSource::auto();
    let backend = load_backend(&source, "vicuna-7b-tiny").expect("backend");
    let model = backend.as_ref();
    let engine = Engine::new(model);
    let mut b = Bench::auto(format!("bench_engine[{}]", model.backend_name()));
    let prompt: &[u8] = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";

    // Single-step costs (the request-path atoms).
    let plen = prompt.len();
    let mut toks: Vec<i32> = prompt.iter().map(|&x| x as i32).collect();
    toks.resize(model.prefill_len(), b' ' as i32);
    b.bench("prefill_256", || {
        black_box(model.prefill(&toks, plen).expect("prefill").logits.len());
    });
    // Steps thread the state through an Option so each iteration measures
    // exactly one step (re-decoding position `plen` overwrites one KV row).
    let mut state = Some(model.prefill(&toks, plen).expect("prefill").state);
    b.bench("decode_full_step", || {
        let out = model.decode_full(65, plen, state.take().unwrap()).expect("step");
        black_box(out.logits.len());
        state = Some(out.state);
    });
    let mut state = Some(model.prefill(&toks, plen).expect("prefill").state);
    b.bench("decode_draft_step", || {
        let out = model.decode_draft(65, plen, state.take().unwrap()).expect("step");
        black_box(out.logits.len());
        state = Some(out.state);
    });
    let vtokens: Vec<i32> = (0..model.slots() as i32).collect();
    let mut state = Some(model.prefill(&toks, plen).expect("prefill").state);
    b.bench("verify_pass_full_slots", || {
        let out = model.verify(&vtokens, plen, state.take().unwrap()).expect("verify");
        black_box(out.logits.len());
        state = Some(out.state);
    });

    // Weight-traffic accounting: the paper's quarter-to-all claim as a
    // measured, regression-checked number.  Drain whatever the timing
    // loops above accumulated, then meter a clean run of each pass.
    model.drain_traffic();
    let reps = 16usize;
    let mut state = Some(model.prefill(&toks, plen).expect("prefill").state);
    model.drain_traffic();
    for i in 0..reps {
        let out = model.decode_draft(65, plen + i, state.take().unwrap()).expect("draft");
        state = Some(out.state);
    }
    let draft_traffic = model.drain_traffic();
    let mut state = Some(model.prefill(&toks, plen).expect("prefill").state);
    model.drain_traffic();
    for i in 0..reps {
        let out = model.decode_full(65, plen + i, state.take().unwrap()).expect("full");
        state = Some(out.state);
    }
    let full_traffic = model.drain_traffic();
    let draft_bpt = draft_traffic.draft_bytes_per_token();
    let full_bpt = full_traffic.full_bytes_per_token();
    if full_bpt > 0.0 {
        let ratio = draft_bpt / full_bpt;
        b.metric("bytes_per_token_draft", draft_bpt, "B/tok");
        b.metric("bytes_per_token_full", full_bpt, "B/tok");
        b.metric("draft_traffic_ratio", ratio, "x");
        b.metrics_json(&[
            ("bytes_per_token_draft", draft_bpt),
            ("bytes_per_token_full", full_bpt),
            ("draft_traffic_ratio", ratio),
        ]);
        // CI regression guard: the draft pass must stream at most 0.35x
        // the full pass's weight bytes (the quarter claim plus scale/norm
        // overhead).  A violated bound fails the bench target.
        assert!(
            ratio <= 0.35,
            "draft/full weight-traffic ratio {ratio:.4} exceeds the 0.35 bound"
        );
    }

    // Batched decode: the continuous-batching lever.  Each step streams
    // every weight once for the whole batch, so tokens/sec should scale
    // strongly super-linearly vs sequential GEMVs on the memory-bound
    // interpreter.
    let mut tok_per_s = Vec::new();
    for &bsz in &[1usize, 4, 8] {
        let slots: Vec<SeqSlot> = (0..bsz).map(|_| model.alloc_slot()).collect();
        let prompts: Vec<Vec<i32>> = vec![toks.clone(); bsz];
        let lengths: Vec<usize> = vec![plen; bsz];
        model.prefill_batch(&slots, &prompts, &lengths).expect("prefill_batch");
        let tokens: Vec<i32> = vec![65; bsz];
        let pos: Vec<usize> = vec![plen; bsz];
        let s = b.bench(format!("batched_decode_b{bsz}"), || {
            black_box(model.decode_full_batch(&slots, &tokens, &pos).expect("decode").len());
        });
        let tps = bsz as f64 / (s.mean_ns * 1e-9);
        b.metric(format!("batched_decode_b{bsz}_tok_per_s"), tps, "tok/s (CPU)");
        tok_per_s.push((bsz, tps));
        for &slot in &slots {
            model.free_slot(slot);
        }
    }
    if let (Some(&(_, t1)), Some(&(_, t8))) = (tok_per_s.first(), tok_per_s.last()) {
        b.metric("batched_decode_b8_vs_b1_speedup", t8 / t1, "x");
    }

    // Thread-scaling sweep: T in {1, 2, 4, 8} at batch 1/4/8.  The
    // column-sharded kernels are bit-deterministic for every T (pinned by
    // prop_threads.rs), so threads are purely a wall-clock lever — this
    // sweep is what turns the quarter-traffic draft into measured
    // tokens/sec.  Each cell emits a BENCH_JSON line with `threads` and
    // `tokens_per_sec` for the perf trajectory (BENCH_*.json in CI).
    let sweep: &[usize] = &[1, 2, 4, 8];
    let sweep_batches: &[usize] = &[1, 4, 8];
    let mut tps: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    for &t in sweep {
        let backend_t =
            load_backend_with(&source, "vicuna-7b-tiny", &NativeConfig::with_threads(t))
                .expect("backend");
        let model_t = backend_t.as_ref();
        for &bsz in sweep_batches {
            let slots: Vec<SeqSlot> = (0..bsz).map(|_| model_t.alloc_slot()).collect();
            let prompts: Vec<Vec<i32>> = vec![toks.clone(); bsz];
            let lengths: Vec<usize> = vec![plen; bsz];
            model_t.prefill_batch(&slots, &prompts, &lengths).expect("prefill_batch");
            let tokens: Vec<i32> = vec![65; bsz];
            let pos: Vec<usize> = vec![plen; bsz];
            let s = b.bench(format!("decode_b{bsz}_t{t}"), || {
                black_box(
                    model_t.decode_full_batch(&slots, &tokens, &pos).expect("decode").len(),
                );
            });
            let v = bsz as f64 / (s.mean_ns * 1e-9);
            b.metric(format!("decode_b{bsz}_t{t}_tok_per_s"), v, "tok/s (CPU)");
            b.metrics_json(&[
                ("threads", t as f64),
                ("batch", bsz as f64),
                ("tokens_per_sec", v),
            ]);
            tps.insert((t, bsz), v);
            for &slot in &slots {
                model_t.free_slot(slot);
            }
        }
    }
    for &bsz in sweep_batches {
        let t1 = tps[&(1, bsz)];
        for &t in &sweep[1..] {
            let speedup = tps[&(t, bsz)] / t1;
            b.metric(format!("thread_speedup_b{bsz}_t{t}"), speedup, "x vs T=1");
            b.metric(
                format!("parallel_efficiency_b{bsz}_t{t}"),
                speedup / t as f64,
                "(1.0 = linear)",
            );
        }
    }
    // CI regression guard: batched decode must actually scale with
    // threads.  The full >= 1.7x bound at T=4 needs >= 4 real cores; on
    // narrower machines the physical ceiling is the core count, so the
    // bound degrades gracefully (and 1-core machines only check that
    // threading is not a slowdown cliff).
    let t4_speedup = tps[&(4, 8)] / tps[&(1, 8)];
    b.metric("thread_gate_t4_vs_t1_b8", t4_speedup, "x");
    b.metrics_json(&[
        ("threads", 4.0),
        ("batch", 8.0),
        ("tokens_per_sec", tps[&(4, 8)]),
        ("speedup_t4_vs_t1", t4_speedup),
    ]);
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let required = if cores >= 4 {
        1.7
    } else if cores >= 2 {
        1.3
    } else {
        0.5
    };
    assert!(
        t4_speedup >= required,
        "T=4 batched decode speedup {t4_speedup:.3}x below the {required}x bound \
         ({cores} cores available)"
    );

    // End-to-end generation.
    let gen = if smoke { 16 } else { 64 };
    let cfg = SpecConfig { gen_len: gen, ..Default::default() };
    let s = b.bench(format!("generate_spec_{gen}tok"), || {
        black_box(engine.generate_spec(prompt, &cfg).expect("spec").tokens.len());
    });
    let spec_tps = gen as f64 / (s.mean_ns * 1e-9);
    b.metric("spec_tokens_per_s", spec_tps, "tok/s (CPU)");

    // Tracing overhead: the identical generation with the recorder armed,
    // measured back-to-back against the disarmed run above.  A disarmed
    // probe is one relaxed atomic load; an armed event is a clock read
    // plus an uncontended ring push.  CI gate: armed recording may cost
    // at most 3% of spec decode throughput.
    speq::trace::arm();
    let s = b.bench(format!("generate_spec_{gen}tok_traced"), || {
        speq::trace::clear();
        black_box(engine.generate_spec(prompt, &cfg).expect("traced spec").tokens.len());
    });
    speq::trace::disarm();
    speq::trace::clear();
    let traced_tps = gen as f64 / (s.mean_ns * 1e-9);
    let trace_overhead_pct = 100.0 * (spec_tps / traced_tps - 1.0);
    b.metric("traced_spec_tokens_per_s", traced_tps, "tok/s (CPU)");
    b.metric("trace_overhead_pct", trace_overhead_pct, "% vs disarmed");
    b.metrics_json(&[
        ("spec_tokens_per_sec", spec_tps),
        ("traced_spec_tokens_per_sec", traced_tps),
        ("trace_overhead_pct", trace_overhead_pct),
    ]);
    assert!(
        trace_overhead_pct <= 3.0,
        "armed tracing costs {trace_overhead_pct:.2}% on the spec decode path (bound: 3%)"
    );

    let s = b.bench(format!("generate_ar_{gen}tok"), || {
        black_box(
            engine.generate_ar(prompt, gen, SamplingParams::greedy()).expect("ar").tokens.len(),
        );
    });
    b.metric("ar_tokens_per_s", gen as f64 / (s.mean_ns * 1e-9), "tok/s (CPU)");

    // Same generation with the per-sequence adaptive draft-length
    // controller steering the budget.  Greedy adaptation is lossless
    // (token stream identical to static), so the delta against
    // spec_tokens_per_s is pure controller overhead plus whatever its
    // budget choices win or lose on this prompt.
    let mut acfg = cfg;
    acfg.adaptive = AdaptiveConfig::enabled();
    let s = b.bench(format!("generate_spec_{gen}tok_adaptive"), || {
        black_box(engine.generate_spec(prompt, &acfg).expect("adaptive spec").tokens.len());
    });
    let adaptive_tps = gen as f64 / (s.mean_ns * 1e-9);
    b.metric("adaptive_spec_tokens_per_s", adaptive_tps, "tok/s (CPU)");
    b.metrics_json(&[
        ("spec_tokens_per_sec", spec_tps),
        ("adaptive_spec_tokens_per_sec", adaptive_tps),
    ]);

    // SIMD dispatch end-to-end: the same speculative generation with the
    // kernels forced to the scalar tier, against the default (best
    // detected) run above.  Token streams are bitwise identical across
    // tiers (prop_simd.rs pins that), so this is purely the wall-clock
    // win of the vector decode/axpy paths; no gate here — the kernel-level
    // 1.5x decode bound lives in bench_kernels.
    let best = SimdLevel::detect();
    if best != SimdLevel::Scalar {
        let scalar_backend = load_backend_with(
            &source,
            "vicuna-7b-tiny",
            &NativeConfig::default().with_simd(SimdLevel::Scalar),
        )
        .expect("backend");
        let scalar_engine = Engine::new(scalar_backend.as_ref());
        let s = b.bench(format!("generate_spec_{gen}tok_scalar_simd"), || {
            black_box(scalar_engine.generate_spec(prompt, &cfg).expect("spec").tokens.len());
        });
        let scalar_tps = gen as f64 / (s.mean_ns * 1e-9);
        b.metric("spec_tokens_per_s_scalar_simd", scalar_tps, "tok/s (CPU)");
        b.metric(
            format!("simd_e2e_speedup_{}", best.name()),
            spec_tps / scalar_tps,
            "x vs scalar",
        );
        b.metrics_json(&[
            ("simd_lanes", best.lanes() as f64),
            ("spec_tokens_per_sec_best_simd", spec_tps),
            ("spec_tokens_per_sec_scalar_simd", scalar_tps),
            ("simd_e2e_speedup", spec_tps / scalar_tps),
        ]);
    }

    // Batched end-to-end speculative serving throughput at batch 8.
    let batch_engine = BatchEngine::new(model);
    let requests: Vec<(Vec<u8>, SpecConfig)> = (0..8)
        .map(|i| {
            let mut p = prompt.to_vec();
            p.push(b'0' + i as u8);
            (p, SpecConfig { gen_len: gen, ..Default::default() })
        })
        .collect();
    let s = b.bench(format!("batch8_generate_spec_{gen}tok"), || {
        black_box(batch_engine.run_spec(&requests).expect("batched spec").len());
    });
    b.metric(
        "batch8_spec_tokens_per_s",
        (8 * gen) as f64 / (s.mean_ns * 1e-9),
        "tok/s (CPU)",
    );
}
