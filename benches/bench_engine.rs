//! Engine hot path over PJRT (needs artifacts; skips gracefully).
//! Run: cargo bench --bench bench_engine

use speq::model::{Manifest, ModelRuntime, SamplingParams};
use speq::runtime::Runtime;
use speq::specdec::{Engine, SpecConfig};
use speq::util::bench::{black_box, Bench};

fn main() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(manifest) = Manifest::load(&root) else {
        eprintln!("bench_engine: no artifacts (run `make artifacts`), skipping");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let model = ModelRuntime::load(&rt, &manifest, "vicuna-7b-tiny").expect("model");
    let engine = Engine::new(&model);
    let mut b = Bench::new("bench_engine");
    let prompt: &[u8] = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";

    // Single-step costs (the request-path atoms).
    let plen = prompt.len();
    let mut toks: Vec<i32> = prompt.iter().map(|&x| x as i32).collect();
    toks.resize(model.prefill_len(), b' ' as i32);
    let pre = model.prefill(&toks, plen).expect("prefill");
    b.bench("prefill_256", || {
        black_box(model.prefill(&toks, plen).expect("prefill"));
    });
    b.bench("decode_full_step", || {
        black_box(model.decode_full(65, plen, &pre.state).expect("step"));
    });
    b.bench("decode_draft_step", || {
        black_box(model.decode_draft(65, plen, &pre.state).expect("step"));
    });
    let vtokens: Vec<i32> = (0..model.slots() as i32).collect();
    b.bench("verify_pass_full_slots", || {
        black_box(model.verify(&vtokens, plen, &pre.state).expect("verify"));
    });

    // End-to-end generation (64 tokens).
    let cfg = SpecConfig { gen_len: 64, ..Default::default() };
    let s = b.bench("generate_spec_64tok", || {
        black_box(engine.generate_spec(prompt, &cfg).expect("spec"));
    });
    b.metric("spec_tokens_per_s", 64.0 / (s.mean_ns * 1e-9), "tok/s (CPU)");
    let s = b.bench("generate_ar_64tok", || {
        black_box(engine.generate_ar(prompt, 64, SamplingParams::greedy()).expect("ar"));
    });
    b.metric("ar_tokens_per_s", 64.0 / (s.mean_ns * 1e-9), "tok/s (CPU)");
}
