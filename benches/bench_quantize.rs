//! BSFP codec throughput (supports Table I / the artifact pipeline).
//! Run: cargo bench --bench bench_quantize

use speq::bsfp::{encode_tensor, quantize_tensor, GROUP_SIZE};
use speq::quant::{quantize_fp4, quantize_int, Fp4Variant, IntMethod};
use speq::util::bench::{black_box, Bench};
use speq::util::rng::Rng;

fn main() {
    let mut b = Bench::new("bench_quantize");
    let k = GROUP_SIZE * 32; // 4096
    let n = 256;
    let w = Rng::seed_from_u64(1).normal_vec(k * n, 0.1);

    b.bench("encode_1M_elems", || {
        black_box(encode_tensor(black_box(&w)));
    });
    let s = b.bench("bsfp_quantize_1M_elems", || {
        black_box(quantize_tensor(black_box(&w), k, n));
    });
    let elems_per_s = (k * n) as f64 / (s.mean_ns * 1e-9);
    b.metric("bsfp_quantize_throughput", elems_per_s / 1e6, "Melem/s");

    let qt = quantize_tensor(&w, k, n);
    b.bench("dequant_draft_1M_elems", || {
        black_box(qt.dequant_draft());
    });
    b.bench("reconstruct_full_1M_elems", || {
        black_box(qt.reconstruct_fp16_bits());
    });
    b.bench("pack_wq_1M_elems", || {
        black_box(qt.packed_wq());
    });
    b.bench("fp4_e3m0_1M_elems", || {
        black_box(quantize_fp4(black_box(&w), k, n, Fp4Variant::E3M0));
    });
    b.bench("olive4_1M_elems", || {
        black_box(quantize_int(black_box(&w), k, n, IntMethod::olive(4)));
    });
}
