//! Micro-benchmarks for the serving front end's hot paths: HTTP request
//! parsing, SSE event assembly, and the streaming-safe byte escaper.
//! These run per-request / per-chunk on every connection thread, so their
//! cost bounds the front end's overhead on top of generation.
//!
//! Run: cargo bench --bench bench_http [-- --smoke]

use std::io::Cursor;

use speq::net::api;
use speq::net::http;
use speq::util::bench::{black_box, Bench};
use speq::util::json;

fn main() {
    let mut b = Bench::auto("net_http");

    let post_body = r#"{"prompt":"Q: 1+1?\nA: ","gen_len":64,"seed":0,"gamma":0.6}"#;
    let post = format!(
        "POST /v1/generate HTTP/1.1\r\nhost: bench\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: keep-alive\r\n\r\n{}",
        post_body.len(),
        post_body
    )
    .into_bytes();
    b.bench("parse_request_post_json", || {
        let r = http::read_request(&mut Cursor::new(post.clone()), 1 << 20, || false)
            .unwrap()
            .unwrap();
        black_box(r.body.len());
    });

    let body = r#"{"prompt":"Q: ada has 3 apples and finds 4 more. how many?\nA: ","gen_len":64,"mode":"spec","temperature":0,"seed":0,"max_draft":16,"gamma":0.6}"#;
    b.bench("parse_generate_request_schema", || {
        let g = speq::net::GenerateRequest::from_json(body).unwrap();
        black_box(g.gen_len);
    });

    // A representative accepted-chunk payload: 17 byte tokens (max_draft
    // 16 + bonus), mixed printable/non-printable.
    let chunk: Vec<u8> = (0..17u8).map(|i| i.wrapping_mul(37).wrapping_add(9)).collect();
    b.bench("sse_chunk_event_17_tokens", || {
        let ev = api::sse_event("chunk", &api::chunk_event_data(&chunk));
        black_box(ev.len());
    });

    let mixed: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
    let escape_stats = b.bench("escape_bytes_4k_mixed", || {
        black_box(json::escape_bytes(&mixed).len());
    });
    let mb_per_s = mixed.len() as f64 / (escape_stats.mean_ns / 1e9) / 1e6;
    b.metric("escape_bytes_throughput", mb_per_s, "MB/s");

    let mut out = Vec::with_capacity(8192);
    b.bench("write_chunked_sse_response", || {
        out.clear();
        http::write_chunked_head(&mut out, 200, "text/event-stream", true).unwrap();
        for _ in 0..4 {
            http::write_chunk(&mut out, &api::sse_event("chunk", &api::chunk_event_data(&chunk)))
                .unwrap();
        }
        http::finish_chunked(&mut out).unwrap();
        black_box(out.len());
    });

    b.metrics_json(&[("escape_mb_per_s", mb_per_s)]);
}
