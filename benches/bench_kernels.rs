//! Kernel-level throughput bench: plane decode and GEMM rates per SIMD
//! dispatch tier, on one thread (so the numbers isolate the vector win
//! from the thread-scaling lever `bench_engine` already sweeps).
//!
//! Run: cargo bench --bench bench_kernels
//! Quick CI regression guard: cargo bench --bench bench_kernels -- --smoke
//!
//! Per tier it reports the full-plane and draft-prefix decode rates (GB/s
//! of weight-plane bytes consumed) and the three GEMM kernels' GFLOP/s at
//! batch 1/4/8, each as a `BENCH_JSON` line (collected into
//! `BENCH_kernels_*.json` by CI; `benches/baselines/` keeps a reference
//! snapshot).  The regression gate: on any host with a vector tier, the
//! best tier's full-plane decode must be >= 1.5x scalar.

use speq::bsfp::simd::{decode_draft_row_pair, draft_lut};
use speq::bsfp::{quantize_tensor, SimdLevel, GROUP_SIZE};
use speq::runtime::kernels::{gemm_dense, gemm_draft_prefix, gemm_full_planes, SCRATCH_ROWS};
use speq::runtime::WorkerPool;
use speq::util::bench::{black_box, Bench};
use speq::util::rng::Rng;

fn main() {
    let (k, n) = (512usize, 512usize);
    assert_eq!(k % GROUP_SIZE, 0);
    let w = Rng::seed_from_u64(2024).uniform_vec(k * n, 0.3);
    let qt = quantize_tensor(&w, k, n);
    let planes = qt.planes();
    let prefix = qt.packed_wq();
    let decoded = planes.decode_full_f32();
    let lut = draft_lut();
    let pool = WorkerPool::new(1);

    let full_plane_bytes = planes.full_bytes() as f64; // 2 B/weight
    let draft_plane_bytes = prefix.len() as f64; // 0.5 B/weight

    // (tier, full-plane decode GB/s) per tier, for the end-of-run gate.
    let mut full_decode_rate: Vec<(SimdLevel, f64)> = Vec::new();

    for level in SimdLevel::available() {
        let mut b = Bench::auto(format!("bench_kernels[{}]", level.name()));
        let mut json: Vec<(&str, f64)> = vec![
            ("k", k as f64),
            ("n", n as f64),
            ("lanes", level.lanes() as f64),
        ];

        // Raw decoders: every row pair of the tensor, one shard.
        let mut lo = vec![0.0f32; n];
        let mut hi = vec![0.0f32; n];
        let s = b.bench(format!("decode_full_{k}x{n}"), || {
            for p in 0..k / 2 {
                planes.decode_row_pair_full_cols_with(level, p, 0, n, &mut lo, &mut hi);
            }
            black_box(lo[0]);
        });
        let gbps = full_plane_bytes / (s.mean_ns * 1e-9) / 1e9;
        b.metric("decode_full_gbps", gbps, "GB/s (plane bytes)");
        json.push(("full_decode_gbps", gbps));
        full_decode_rate.push((level, gbps));

        let mut pre = vec![0.0f32; n];
        let s = b.bench(format!("decode_draft_{k}x{n}"), || {
            let mut cur_group = usize::MAX;
            for p in 0..k / 2 {
                let g = 2 * p / GROUP_SIZE;
                if g != cur_group {
                    cur_group = g;
                    for (pv, &sv) in pre.iter_mut().zip(&qt.scales[g * n..(g + 1) * n]) {
                        *pv = sv / qt.tensor_scale;
                    }
                }
                let prow = &prefix[p * n..(p + 1) * n];
                decode_draft_row_pair(level, prow, &pre, &lut, &mut lo, &mut hi);
            }
            black_box(lo[0]);
        });
        let gbps = draft_plane_bytes / (s.mean_ns * 1e-9) / 1e9;
        b.metric("decode_draft_gbps", gbps, "GB/s (plane bytes)");
        json.push(("draft_decode_gbps", gbps));

        // The three GEMM kernels at batch 1/4/8 (2*k*n flops per row).
        for bsz in [1usize, 4, 8] {
            let xs = Rng::seed_from_u64(7 + bsz as u64).normal_vec(bsz * k, 1.0);
            let mut ys = vec![0.0f32; bsz * n];
            let mut scratch = vec![0.0f32; SCRATCH_ROWS * n];
            let flops = (2 * bsz * k * n) as f64;

            let s = b.bench(format!("gemm_dense_b{bsz}"), || {
                gemm_dense(&pool, level, &xs, bsz, &decoded, k, n, &mut ys);
                black_box(ys[0]);
            });
            let dense_gflops = flops / (s.mean_ns * 1e-9) / 1e9;
            b.metric(format!("gemm_dense_b{bsz}_gflops"), dense_gflops, "GFLOP/s");

            let s = b.bench(format!("gemm_full_planes_b{bsz}"), || {
                gemm_full_planes(&pool, level, &xs, bsz, &planes, &mut scratch, &mut ys);
                black_box(ys[0]);
            });
            let full_gflops = flops / (s.mean_ns * 1e-9) / 1e9;
            b.metric(format!("gemm_full_planes_b{bsz}_gflops"), full_gflops, "GFLOP/s");

            let s = b.bench(format!("gemm_draft_prefix_b{bsz}"), || {
                gemm_draft_prefix(
                    &pool,
                    level,
                    &xs,
                    bsz,
                    &prefix,
                    &qt.scales,
                    qt.tensor_scale,
                    k,
                    n,
                    &mut scratch,
                    &mut ys,
                );
                black_box(ys[0]);
            });
            let draft_gflops = flops / (s.mean_ns * 1e-9) / 1e9;
            b.metric(format!("gemm_draft_prefix_b{bsz}_gflops"), draft_gflops, "GFLOP/s");

            if bsz == 1 {
                json.push(("gemm_dense_b1_gflops", dense_gflops));
                json.push(("gemm_full_planes_b1_gflops", full_gflops));
                json.push(("gemm_draft_prefix_b1_gflops", draft_gflops));
            } else if bsz == 8 {
                json.push(("gemm_dense_b8_gflops", dense_gflops));
                json.push(("gemm_full_planes_b8_gflops", full_gflops));
                json.push(("gemm_draft_prefix_b8_gflops", draft_gflops));
            }
        }
        b.metrics_json(&json);
    }

    // Regression gate: the vector win on the hot full-plane decoder.  Only
    // meaningful where a vector tier exists (scalar-only hosts pass
    // trivially — there is nothing to gate).
    let scalar_rate = full_decode_rate[0].1;
    let (best, best_rate) = *full_decode_rate.last().expect("scalar always present");
    let summary = Bench::auto("bench_kernels[summary]");
    summary.metrics_json(&[
        ("scalar_full_decode_gbps", scalar_rate),
        ("best_full_decode_gbps", best_rate),
        ("best_vs_scalar_speedup", best_rate / scalar_rate),
    ]);
    if best != SimdLevel::Scalar {
        let speedup = best_rate / scalar_rate;
        println!(
            "bench_kernels: {} full-plane decode {speedup:.2}x scalar ({best_rate:.2} vs {scalar_rate:.2} GB/s)",
            best.name()
        );
        assert!(
            speedup >= 1.5,
            "{} full-plane decode speedup {speedup:.3}x below the 1.5x bound",
            best.name()
        );
    } else {
        println!("bench_kernels: no vector tier on this host; speedup gate skipped");
    }
}
