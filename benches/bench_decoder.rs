//! Fig. 5 decoder datapath throughput (the 3.5%-area unit).
//! Run: cargo bench --bench bench_decoder

use speq::bsfp::{decode_draft_gate, decode_full_bits, decode_full_gate, encode_bits, BsfpCode};
use speq::util::bench::{black_box, Bench};
use speq::util::rng::Rng;

fn main() {
    let mut b = Bench::new("bench_decoder");
    let mut rng = Rng::seed_from_u64(2);
    let codes: Vec<BsfpCode> = (0..65536)
        .map(|_| {
            let bits = (rng.next_u32() as u16) & !(0x4000); // clear e4: exp <= 15
            encode_bits(bits)
        })
        .collect();

    let s = b.bench("draft_decode_64k", || {
        let mut acc = 0u32;
        for c in &codes {
            acc = acc.wrapping_add(decode_draft_gate(c.w_q & 7) as u32);
        }
        black_box(acc);
    });
    b.metric("draft_decode_rate", 65536.0 / (s.mean_ns * 1e-9) / 1e9, "Gdecodes/s");

    b.bench("full_decode_gate_64k", || {
        let mut acc = 0u32;
        for c in &codes {
            let flag = ((c.w_r >> 11) & 1) as u8;
            let e0 = ((c.w_r >> 10) & 1) as u8;
            acc = acc.wrapping_add(decode_full_gate(c.w_q & 7, flag, e0) as u32);
        }
        black_box(acc);
    });
    b.bench("full_decode_lut_64k", || {
        let mut acc = 0u32;
        for &c in &codes {
            acc = acc.wrapping_add(decode_full_bits(c) as u32);
        }
        black_box(acc);
    });
}
