//! End-to-end accelerator speedup evaluation (Table III / Fig. 7 engine).
//! Replays measured traces if present, otherwise synthetic ones.
//! Run: cargo bench --bench bench_accel_speedup

use speq::accel::{paper_dims, speedup_vs_fp16, Accel, BaselineKind, PAPER_MODELS};
use speq::specdec::{IterRecord, SpecTrace};
use speq::util::bench::{black_box, Bench};
use speq::workload::load_trace;

fn synthetic_trace() -> SpecTrace {
    SpecTrace {
        iterations: vec![IterRecord { drafted: 16, accepted: 14, early_exit: false }; 16],
        produced: 240,
        prompt_len: 128,
    }
}

fn main() {
    let mut b = Bench::new("bench_accel_speedup");
    let accel = Accel::default();
    let results = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/results");

    for dims in PAPER_MODELS.iter() {
        let tiny = format!("{}-tiny", dims.name.to_lowercase());
        let trace = load_trace(&results, &tiny, "chat", 16, 0.6)
            .map(|r| r.trace)
            .unwrap_or_else(synthetic_trace);
        b.bench(format!("run_trace_{}", dims.name), || {
            black_box(accel.run_trace(dims, &trace, 1024));
        });
        let tc = accel.run_trace(dims, &trace, 1024);
        b.metric(format!("{}_speedup", dims.name), tc.speedup(), "x vs FP16");
    }

    let dims = paper_dims("Llama2-7b").unwrap();
    let trace = synthetic_trace();
    b.bench("olive8_speedup_eval", || {
        black_box(speedup_vs_fp16(BaselineKind::Olive8, &accel, dims, 1024, None));
    });
    b.bench("speq_speedup_eval", || {
        black_box(speedup_vs_fp16(BaselineKind::Speq, &accel, dims, 1024, Some(&trace)));
    });
}
