//! Fig. 9 ablation grid evaluation cost (per grid point, analytic part).
//! Run: cargo bench --bench bench_ablation

use speq::accel::{paper_dims, Accel};
use speq::specdec::{expected_accept_length, theoretical_speedup, IterRecord, SpecTrace};
use speq::util::bench::{black_box, Bench};

fn trace_for(l: u32, accept: u32) -> SpecTrace {
    SpecTrace {
        iterations: vec![IterRecord { drafted: l, accepted: accept.min(l), early_exit: false }; 8],
        produced: 8 * (accept.min(l) as usize + 1),
        prompt_len: 128,
    }
}

fn main() {
    let mut b = Bench::new("bench_ablation");
    let accel = Accel::default();
    let dims = paper_dims("Llama3.1-8b").unwrap();

    b.bench("grid_point_sim", || {
        for l in [4u32, 8, 12, 16, 20] {
            let t = trace_for(l, l - 1);
            black_box(accel.run_trace(dims, &t, 1024));
        }
    });
    b.bench("eq1_eq2_grid_25pts", || {
        for l in [4usize, 8, 12, 16, 20] {
            for r in [0.5, 0.7, 0.9, 0.95, 0.99] {
                black_box(expected_accept_length(r, l));
                black_box(theoretical_speedup(r, l, 0.31, 1.0));
            }
        }
    });

    // The ablation's analytic shape: the best L shrinks as r drops.
    for r in [0.8, 0.95] {
        let best = [4usize, 8, 12, 16, 20]
            .into_iter()
            .max_by(|&a, &bb| {
                theoretical_speedup(r, a, 0.31, 1.0)
                    .partial_cmp(&theoretical_speedup(r, bb, 0.31, 1.0))
                    .unwrap()
            })
            .unwrap();
        b.metric(format!("best_L_at_r_{r}"), best as f64, "draft len");
    }
}
