//! Paged-KV capacity + prefix-sharing bench: how many concurrent
//! sequences fit a fixed KV page budget on a shared-prefix workload,
//! with the prefix cache on vs off (the dense-equivalent baseline), and
//! what prefix reuse does to prefill latency.
//!
//! The capacity gate is count-based, not timing-based: admission stops
//! when the allocator's `pages_in_use` would exceed the budget, so the
//! >= 2x concurrency bound is deterministic on every machine.
//!
//! Run: cargo bench --bench bench_kv
//! Quick CI regression guard: cargo bench --bench bench_kv -- --smoke

use speq::runtime::{Backend, NativeBackend, SeqSlot, PAGE_TOKENS};
use speq::util::bench::{black_box, smoke_requested, Bench};

/// Fixed KV memory budget, in pages (64 pages = 1024 token positions).
const PAGE_BUDGET: u64 = 64;

/// 64-byte shared system prefix = four full KV pages of common prompt.
const SHARED_PREFIX: &[u8] = b"SYSTEM: you are a helpful concise assistant for short answers.\n\n";

fn prompt_for(i: usize) -> Vec<u8> {
    let mut p = SHARED_PREFIX.to_vec();
    p.extend_from_slice(format!("USER {i:03}: hi\nBOT: ").as_bytes());
    p
}

fn padded(backend: &NativeBackend, prompt: &[u8]) -> (Vec<i32>, usize) {
    let mut toks: Vec<i32> = prompt.iter().map(|&c| c as i32).collect();
    let plen = toks.len().min(backend.prefill_len());
    toks.resize(backend.prefill_len(), b' ' as i32);
    (toks, plen)
}

/// Admit shared-prefix sequences until the next one would push the
/// allocator past `PAGE_BUDGET` pages; returns (admitted, slots, plen).
fn admit_to_budget(backend: &NativeBackend) -> (usize, Vec<SeqSlot>, usize) {
    let mut slots = Vec::new();
    let mut plen = 0;
    loop {
        let prompt = prompt_for(slots.len());
        let (toks, len) = padded(backend, &prompt);
        plen = len;
        let slot = backend.alloc_slot();
        backend.prefill_batch(&[slot], &[toks], &[len]).expect("prefill");
        if backend.kv_stats().pages_in_use > PAGE_BUDGET {
            backend.free_slot(slot); // over budget: this one doesn't fit
            return (slots.len(), slots, plen);
        }
        slots.push(slot);
        if slots.len() >= 512 {
            return (slots.len(), slots, plen); // safety stop
        }
    }
}

fn main() {
    let _smoke = smoke_requested();
    let mut b = Bench::auto("bench_kv".to_string());

    assert_eq!(SHARED_PREFIX.len(), 4 * PAGE_TOKENS, "prefix must fill whole pages");

    // ---- capacity at a fixed page budget: dense baseline ----
    let dense = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
    dense.set_prefix_cache(false);
    let (dense_seqs, dense_slots, _) = admit_to_budget(&dense);
    let dense_stats = dense.kv_stats();

    // ---- capacity at the same budget: prefix sharing on ----
    let shared = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
    let (shared_seqs, shared_slots, plen) = admit_to_budget(&shared);
    let shared_stats = shared.kv_stats();

    let ratio = shared_seqs as f64 / dense_seqs.max(1) as f64;
    b.metric("kv_budget_pages", PAGE_BUDGET as f64, "pages");
    b.metric("dense_seqs_at_budget", dense_seqs as f64, "seqs");
    b.metric("shared_seqs_at_budget", shared_seqs as f64, "seqs");
    b.metric("shared_vs_dense_concurrency", ratio, "x");
    b.metric("shared_pages_in_use", shared_stats.pages_in_use as f64, "pages");
    b.metric("shared_pages_shared", shared_stats.pages_shared as f64, "pages");
    b.metric(
        "prefix_hit_tokens",
        shared_stats.prefix_hit_tokens as f64,
        "tok",
    );
    b.metrics_json(&[
        ("kv_budget_pages", PAGE_BUDGET as f64),
        ("dense_seqs_at_budget", dense_seqs as f64),
        ("shared_seqs_at_budget", shared_seqs as f64),
        ("shared_vs_dense_concurrency", ratio),
        ("prefix_hit_tokens", shared_stats.prefix_hit_tokens as f64),
        ("cow_copies", shared_stats.cow_copies as f64),
    ]);

    // The tentpole's capacity claim, checked deterministically: at a
    // fixed page budget, prefix sharing must fit at least 2x the
    // concurrent sequences of the dense-equivalent baseline.
    assert!(
        ratio >= 2.0,
        "shared-prefix concurrency {shared_seqs} vs dense {dense_seqs} \
         ({ratio:.2}x) is below the 2x capacity bound at {PAGE_BUDGET} pages"
    );
    assert!(
        dense_stats.prefix_hit_tokens == 0,
        "dense baseline must not touch the prefix cache"
    );

    // Every admitted sequence is actually decodable under the budget:
    // one lockstep decode step across the whole shared fleet (tail-page
    // copy-on-write happens here, bounded by one page per sequence).
    let tokens: Vec<i32> = vec![65; shared_slots.len()];
    let pos: Vec<usize> = vec![plen; shared_slots.len()];
    let rows = shared
        .decode_full_batch(&shared_slots, &tokens, &pos)
        .expect("fleet decode");
    black_box(rows.len());

    // ---- prefill latency: cache-cold vs cache-hot ----
    let hot_prompt = prompt_for(0); // inserted during admission above
    let (hot_toks, hot_len) = padded(&shared, &hot_prompt);
    let cold = b.bench("prefill_cold_dense", || {
        black_box(dense.prefill(&hot_toks, hot_len).expect("prefill").logits.len());
    });
    let hot = b.bench("prefill_hot_prefix_cache", || {
        black_box(shared.prefill(&hot_toks, hot_len).expect("prefill").logits.len());
    });
    let speedup = cold.mean_ns / hot.mean_ns;
    b.metric("prefill_prefix_reuse_speedup", speedup, "x vs cold");
    b.metrics_json(&[
        ("prefill_cold_ns", cold.mean_ns),
        ("prefill_hot_ns", hot.mean_ns),
        ("prefill_prefix_reuse_speedup", speedup),
    ]);

    // Cleanup: every page must come home.
    for s in shared_slots {
        shared.free_slot(s);
    }
    for s in dense_slots {
        dense.free_slot(s);
    }
    shared.prefix_tree().clear(shared.kv_allocator());
    assert_eq!(shared.kv_stats().pages_in_use, 0, "leaked pages (shared)");
    assert_eq!(dense.kv_stats().pages_in_use, 0, "leaked pages (dense)");
}
