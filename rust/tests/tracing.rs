//! Integration: structured engine tracing — recording must never change
//! output bits, the Chrome trace export must be strict JSON with balanced
//! spans and a lossless speculation histogram, the coordinator must emit
//! a complete request lifecycle whose phase attribution sums to the
//! measured latency, and `/debug/trace` must serve it all over HTTP.
//!
//! Every test takes `trace::test_guard()` — arming is process-global, so
//! tests that record (or assert disarmed behavior) serialize.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use speq::coordinator::{Mode, Priority, Server, ServerConfig, SubmitParams};
use speq::model::SamplingParams;
use speq::net::loadgen::PROMPTS;
use speq::net::{GenerateRequest, NetConfig, NetServer};
use speq::runtime::{load_backend_with, ModelSource, NativeConfig};
use speq::specdec::{Engine, SpecConfig};
use speq::trace;
use speq::util::json::{self, Value};

const MODEL: &str = "vicuna-7b-tiny";
const PROMPT: &[u8] = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";

fn spec_tokens(threads: usize, gen_len: usize) -> Vec<u8> {
    let native = NativeConfig::with_threads(threads);
    let backend = load_backend_with(&ModelSource::Builtin, MODEL, &native).expect("backend");
    let engine = Engine::new(backend.as_ref());
    let cfg = SpecConfig { gen_len, ..Default::default() };
    engine.generate_spec(PROMPT, &cfg).expect("generation").tokens
}

/// Recording is pure observation: token streams are bit-identical armed
/// vs disarmed, at every worker-pool width.
#[test]
fn token_streams_bit_identical_armed_vs_disarmed() {
    let _g = trace::test_guard();
    for threads in [1usize, 4] {
        let disarmed = spec_tokens(threads, 48);
        trace::arm();
        let armed = spec_tokens(threads, 48);
        trace::disarm();
        trace::clear();
        assert_eq!(
            armed, disarmed,
            "tracing changed output bits at {threads} thread(s)"
        );
        assert!(!disarmed.is_empty(), "generation produced no tokens");
    }
}

/// Walk exported events: per-tid `B`/`E` spans must balance LIFO (strict
/// — the test cleared the rings, so no truncation excuse applies) and
/// timestamps must be non-decreasing per thread.
fn assert_spans_balanced(events: &[Value]) {
    let mut stacks: std::collections::BTreeMap<u64, Vec<&str>> = Default::default();
    let mut last_ts: std::collections::BTreeMap<u64, f64> = Default::default();
    for ev in events {
        let ph = ev.get("ph").and_then(Value::as_str).expect("ph");
        let tid = ev.get("tid").and_then(Value::as_f64).expect("tid") as u64;
        let name = ev.get("name").and_then(Value::as_str).expect("name");
        let ts = ev.get("ts").and_then(Value::as_f64).expect("ts");
        let prev = last_ts.entry(tid).or_insert(0.0);
        assert!(ts >= *prev, "timestamps regressed on tid {tid}: {ts} < {prev}");
        *prev = ts;
        match ph {
            "B" => stacks.entry(tid).or_default().push(name),
            "E" => {
                let top = stacks.get_mut(&tid).and_then(Vec::pop);
                assert_eq!(top, Some(name), "E {name:?} without matching B on tid {tid}");
            }
            _ => {}
        }
    }
    for (tid, stack) in stacks {
        assert!(stack.is_empty(), "unclosed spans on tid {tid}: {stack:?}");
    }
}

/// The export is strict JSON, spans balance, and the `spec`/`iter`
/// instants rebuild the engine's own `SpecTrace` exactly.
#[test]
fn exported_trace_is_strict_json_and_round_trips_the_spec_histogram() {
    let _g = trace::test_guard();
    trace::arm();
    let backend = load_backend_with(&ModelSource::Builtin, MODEL, &NativeConfig::default())
        .expect("backend");
    let engine = Engine::new(backend.as_ref());
    let cfg = SpecConfig { gen_len: 48, ..Default::default() };
    let out = engine.generate_spec(PROMPT, &cfg).expect("generation");
    trace::disarm();

    let text = trace::export_json(usize::MAX);
    let doc = json::parse(&text).expect("export must be strict JSON");
    let events = doc.get("traceEvents").and_then(Value::as_arr).expect("traceEvents");
    assert!(!events.is_empty(), "armed generation recorded nothing");
    assert_spans_balanced(events);
    for cat in ["engine", "spec"] {
        assert!(
            events.iter().any(|e| e.get("cat").and_then(Value::as_str) == Some(cat)),
            "no {cat:?} events in the export"
        );
    }

    let rebuilt = speq::report::spec_trace_from_chrome_json(&text).expect("rebuild");
    assert_eq!(rebuilt.iterations, out.trace.iterations, "spec histogram must survive export");
    assert_eq!(rebuilt.produced, out.trace.produced);
}

/// The coordinator emits the full request lifecycle (`b` → `n admit` →
/// `e outcome=done`) and the per-phase attribution on the response sums
/// to the measured latency (the ±5% acceptance gate; by construction it
/// is exact up to float rounding).
#[test]
fn coordinator_emits_request_lifecycle_and_phase_sum_matches_latency() {
    let _g = trace::test_guard();
    trace::arm();
    let server = Server::start(ServerConfig {
        source: ModelSource::Builtin,
        model: MODEL.into(),
        workers: 1,
        max_batch: 4,
        ..ServerConfig::default()
    })
    .expect("coordinator");
    let (id, stream) = server
        .submit(
            PROMPT,
            SubmitParams {
                gen_len: 32,
                mode: Mode::Speculative,
                priority: Priority::Interactive,
                sampling: SamplingParams::greedy(),
                ..Default::default()
            },
        )
        .expect("submit");
    let body = stream.wait().expect("completion");
    server.shutdown();
    trace::disarm();

    let phase_sum = body.phases.total_s();
    assert!(body.latency_s > 0.0);
    assert!(
        (phase_sum - body.latency_s).abs() <= 0.05 * body.latency_s,
        "phase buckets sum to {phase_sum:.6}s but latency is {:.6}s",
        body.latency_s
    );

    let events = trace::snapshot_events(usize::MAX);
    let req: Vec<_> = events.iter().filter(|e| e.cat == "req" && e.id == id).collect();
    let phases: Vec<u8> = req.iter().map(|e| e.ph).collect();
    assert_eq!(phases, vec![b'b', b'n', b'e'], "lifecycle for request {id}: {req:?}");
    assert_eq!(req[1].name, "admit");
    assert!(
        req[2].args.contains(&("outcome", trace::ArgVal::Str("done"))),
        "terminal event must carry the outcome: {:?}",
        req[2].args
    );
    assert!(
        req[2].args.iter().any(|&(k, _)| k == "queue_wait_ms"),
        "done event must carry the phase attribution: {:?}",
        req[2].args
    );
    // One scheduler step per engine loop iteration.
    assert!(
        events.iter().any(|e| e.cat == "sched" && e.name == "step" && e.ph == b'X'),
        "no scheduler step events recorded"
    );
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(format!("GET {path} HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n").as_bytes())
        .expect("send");
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    let text = String::from_utf8_lossy(&out).into_owned();
    let status = text.split_whitespace().nth(1).and_then(|v| v.parse().ok()).unwrap_or(0);
    (status, text)
}

fn body_of(text: &str) -> &str {
    &text[text.find("\r\n\r\n").expect("header/body split") + 4..]
}

/// `GET /debug/trace` serves the live ring as Perfetto-loadable JSON;
/// `?last=N` bounds the window; non-GET methods are rejected.
#[test]
fn debug_trace_endpoint_serves_the_recording() {
    let _g = trace::test_guard();
    trace::arm();
    let mut server = NetServer::bind(NetConfig {
        addr: "127.0.0.1:0".to_string(),
        server: ServerConfig {
            source: ModelSource::Builtin,
            model: MODEL.into(),
            workers: 1,
            max_batch: 4,
            queue_capacity: 8,
            ..ServerConfig::default()
        },
        ..NetConfig::default()
    })
    .expect("bind");
    let req = GenerateRequest {
        prompt: PROMPTS[0].as_bytes().to_vec(),
        gen_len: 16,
        ..GenerateRequest::default()
    };
    let post = format!(
        "POST /v1/generate HTTP/1.1\r\nhost: t\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{}",
        req.to_json().len(),
        req.to_json()
    );
    let mut s = TcpStream::connect(server.addr()).expect("connect");
    s.write_all(post.as_bytes()).expect("send");
    let mut resp = Vec::new();
    let _ = s.read_to_end(&mut resp);
    assert!(String::from_utf8_lossy(&resp).starts_with("HTTP/1.1 200"), "generate failed");

    let (status, text) = http_get(server.addr(), "/debug/trace");
    assert_eq!(status, 200, "{text}");
    let doc = json::parse(body_of(&text)).expect("trace body must be strict JSON");
    let events = doc.get("traceEvents").and_then(Value::as_arr).expect("traceEvents");
    assert!(!events.is_empty(), "served trace is empty after a completed request");
    assert!(
        events.iter().any(|e| e.get("cat").and_then(Value::as_str) == Some("req")),
        "no request lifecycle events in the served trace"
    );

    let (status, text) = http_get(server.addr(), "/debug/trace?last=3");
    assert_eq!(status, 200);
    let doc = json::parse(body_of(&text)).expect("bounded trace JSON");
    assert!(doc.get("traceEvents").and_then(Value::as_arr).expect("arr").len() <= 3);

    // Wrong method on the route.
    let mut s = TcpStream::connect(server.addr()).expect("connect");
    s.write_all(b"POST /debug/trace HTTP/1.1\r\nhost: t\r\ncontent-length: 0\r\nconnection: close\r\n\r\n")
        .expect("send");
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    let text = String::from_utf8_lossy(&out);
    assert!(text.starts_with("HTTP/1.1 405"), "expected 405, got: {text}");

    server.shutdown(Duration::from_secs(30));
    trace::disarm();
}
