//! Adaptive draft-length controller: convergence properties of the EWMA
//! estimator + Eq. 2 argmax, and end-to-end losslessness/determinism of
//! adaptive generation (static-path bit-identity is pinned by the golden
//! suite, which runs with the controller disabled).

use speq::model::SamplingParams;
use speq::runtime::NativeBackend;
use speq::specdec::{
    theoretical_speedup, AdaptiveConfig, AdaptiveController, BatchEngine, CostRatios, Engine,
    SpecConfig,
};
use speq::util::rng::Rng;

/// Brute-force argmax of the Eq. 2 speedup model over L ∈ [1, max].
fn theory_argmax(r: f64, max: usize, ratios: &CostRatios) -> (usize, f64) {
    let mut best = (1, f64::NEG_INFINITY);
    for l in 1..=max {
        let s = theoretical_speedup(r, l, ratios.td, ratios.tv);
        if s > best.1 {
            best = (l, s);
        }
    }
    best
}

/// Drive a controller with Bernoulli(r) accept streams (geometric
/// acceptance, as verification produces) at its own chosen budgets for
/// `iters` verify outcomes; returns the budget chosen at each iteration.
fn drive(
    c: &mut AdaptiveController,
    r: f64,
    iters: usize,
    ratios: &CostRatios,
    rng: &mut Rng,
) -> Vec<usize> {
    let mut budgets = Vec::with_capacity(iters);
    for _ in 0..iters {
        let drafted = c.pick_budget(16, ratios).max(1);
        let mut accepted = 0;
        for _ in 0..drafted {
            if rng.gen_f64() < r {
                accepted += 1;
            } else {
                break;
            }
        }
        c.observe(drafted, accepted);
        budgets.push(c.pick_budget(16, ratios));
    }
    budgets
}

/// Mean budget over the final `n` entries (smooths EWMA wobble).
fn tail_mean(budgets: &[usize], n: usize) -> f64 {
    let tail = &budgets[budgets.len().saturating_sub(n)..];
    tail.iter().sum::<usize>() as f64 / tail.len() as f64
}

#[test]
fn controller_converges_to_the_theory_argmax() {
    // Property: for a stationary accept rate, the controller's typical
    // late-run budget must be near-optimal under the true rate — within
    // 10% of the brute-force optimum (the EWMA estimate wobbles around r,
    // so the instantaneous argmax visits neighboring L values; the tail
    // mean is the controller's operating point).
    let ratios = CostRatios::default();
    for (i, &r) in [0.3f64, 0.6, 0.8, 0.95].iter().enumerate() {
        let cfg = AdaptiveConfig { enabled: true, alpha: 0.05, ..Default::default() };
        let mut c = AdaptiveController::new(cfg);
        let mut rng = Rng::seed_from_u64(0xADA0 + i as u64);
        let budgets = drive(&mut c, r, 800, &ratios, &mut rng);
        let typical = tail_mean(&budgets, 200).round().max(1.0) as usize;
        let (opt_l, opt_s) = theory_argmax(r, 16, &ratios);
        let got_s = theoretical_speedup(r, typical, ratios.td, ratios.tv);
        assert!(
            got_s >= 0.9 * opt_s,
            "r={r}: operating at L={typical} (S={got_s:.3}) vs optimum L={opt_l} (S={opt_s:.3})"
        );
        assert!(
            (c.accept_rate() - r).abs() < 0.2,
            "r={r}: EWMA estimate {:.3} drifted",
            c.accept_rate()
        );
    }
}

#[test]
fn controller_tracks_a_mid_run_shift() {
    // An easy stretch followed by a hard one: the typical budget must
    // climb, then collapse back to a short chain.
    let ratios = CostRatios::default();
    let cfg = AdaptiveConfig { enabled: true, alpha: 0.05, ..Default::default() };
    let mut c = AdaptiveController::new(cfg);
    let mut rng = Rng::seed_from_u64(0x5417);
    let high = tail_mean(&drive(&mut c, 0.95, 500, &ratios, &mut rng), 100);
    assert!(high >= 4.0, "high-accept phase should open long chains, got {high:.2}");
    let low = tail_mean(&drive(&mut c, 0.05, 150, &ratios, &mut rng), 50);
    assert!(low <= 2.0, "low-accept phase should collapse the budget, got {low:.2}");
    assert!(high > low);
}

#[test]
fn greedy_adaptation_is_lossless() {
    // Greedy speculative decoding is exactly lossless, with or without the
    // controller: adaptation changes *when* verify passes happen, never
    // which tokens survive them.
    let model = NativeBackend::builtin("vicuna-7b-tiny").unwrap();
    let engine = Engine::new(&model);
    let prompt: &[u8] = b"def add_two(x):\n    return ";
    let gen_len = 96;
    let ar = engine.generate_ar(prompt, gen_len, SamplingParams::greedy()).unwrap();
    let stat = engine
        .generate_spec(prompt, &SpecConfig { gen_len, ..Default::default() })
        .unwrap();
    let acfg = SpecConfig { gen_len, adaptive: AdaptiveConfig::enabled(), ..Default::default() };
    let adap = engine.generate_spec(prompt, &acfg).unwrap();
    assert_eq!(stat.tokens, ar.tokens, "static spec must match AR (greedy lossless)");
    assert_eq!(adap.tokens, ar.tokens, "adaptive spec must match AR (greedy lossless)");
    assert_eq!(adap.trace.produced, adap.tokens.len());
}

#[test]
fn adaptive_generation_is_deterministic() {
    // The controller is a pure function of observed outcomes: two
    // identical adaptive runs must agree token-for-token and
    // iteration-for-iteration (budget sequence included, via `drafted`).
    let model = NativeBackend::builtin("llama3.2-3b-tiny").unwrap();
    let engine = Engine::new(&model);
    let prompt: &[u8] = b"Q: bob has 9 coins and spends 2. how many coins left?\nA: ";
    let cfg =
        SpecConfig { gen_len: 64, adaptive: AdaptiveConfig::enabled(), ..Default::default() };
    let a = engine.generate_spec(prompt, &cfg).unwrap();
    let b = engine.generate_spec(prompt, &cfg).unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.trace.iterations, b.trace.iterations);
    assert_eq!(a.trace.produced, b.trace.produced);
}

#[test]
fn batched_adaptive_matches_static_tokens() {
    // The batched state machine with per-session controllers must still be
    // lossless under greedy sampling — mixed static/adaptive batches
    // produce the same byte streams as all-static ones.
    let model = NativeBackend::builtin("vicuna-7b-tiny").unwrap();
    let be = BatchEngine::new(&model);
    let prompts: [&[u8]; 3] = [
        b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ",
        b"def add_two(x):\n    return ",
        b"USER: hello, can we talk about music?\nBOT: ",
    ];
    let mk = |adaptive: bool| -> Vec<(Vec<u8>, SpecConfig)> {
        prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let ad = if adaptive && i % 2 == 0 {
                    AdaptiveConfig::enabled()
                } else {
                    AdaptiveConfig::default()
                };
                (p.to_vec(), SpecConfig { gen_len: 48, adaptive: ad, ..Default::default() })
            })
            .collect()
    };
    let stat = be.run_spec(&mk(false)).unwrap();
    let adap = be.run_spec(&mk(true)).unwrap();
    assert_eq!(stat.len(), adap.len());
    for (i, (s, a)) in stat.iter().zip(&adap).enumerate() {
        assert_eq!(s.tokens, a.tokens, "request {i}: adaptive batch diverged");
    }
}
