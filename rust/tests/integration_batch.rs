//! Property: continuous batching is a throughput optimization, never a
//! semantic change.  `BatchEngine` over N concurrent greedy requests must
//! produce bit-identical tokens to N sequential `Engine::generate_spec`
//! runs — including mixed prompt lengths, per-sequence early exit, and
//! mid-batch completion (unequal `gen_len`s retire sessions while others
//! keep running).

use speq::model::SamplingParams;
use speq::runtime::{Backend, NativeBackend};
use speq::specdec::{ArSession, BatchEngine, Engine, GenSession, SpecConfig, SpecSession};

/// Mixed prompt lengths: short, mid, and longer-than-prefill-window.
fn prompts() -> Vec<Vec<u8>> {
    let huge = vec![b'q'; 400];
    vec![
        b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ".to_vec(),
        b"def inc(x): ".to_vec(),
        b"USER: hi\nBOT: ".to_vec(),
        huge,
        b"Q: 2 + 2 = ".to_vec(),
    ]
}

/// Unequal lengths force mid-batch completion (gen_len 1 retires after the
/// very first step).
const GEN_LENS: [usize; 5] = [40, 9, 23, 64, 1];

#[test]
fn batched_greedy_spec_is_bit_identical_to_sequential() {
    let model = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
    let engine = Engine::new(&model);
    let batch = BatchEngine::new(&model);

    let requests: Vec<(Vec<u8>, SpecConfig)> = prompts()
        .into_iter()
        .zip(GEN_LENS)
        .map(|(p, g)| (p, SpecConfig { gen_len: g, ..Default::default() }))
        .collect();

    let sequential: Vec<Vec<u8>> = requests
        .iter()
        .map(|(p, cfg)| engine.generate_spec(p, cfg).expect("sequential").tokens)
        .collect();

    let batched = batch.run_spec(&requests).expect("batched");
    assert_eq!(batched.len(), sequential.len());
    for (i, (b, s)) in batched.iter().zip(&sequential).enumerate() {
        assert_eq!(b.tokens, *s, "sequence {i} diverged under batching");
        assert_eq!(b.tokens.len(), GEN_LENS[i], "sequence {i} wrong length");
    }
    assert_eq!(model.arena().in_use(), 0, "all KV slots must be released");
}

#[test]
fn batched_spec_traces_match_sequential() {
    // Not just the tokens: per-iteration draft/accept counts must match,
    // i.e. the state machine walks the exact same path as the loop.
    let model = NativeBackend::builtin("llama3.2-3b-tiny").expect("builtin");
    let engine = Engine::new(&model);
    let batch = BatchEngine::new(&model);
    let requests: Vec<(Vec<u8>, SpecConfig)> = prompts()
        .into_iter()
        .zip(GEN_LENS)
        .map(|(p, g)| (p, SpecConfig { gen_len: g, max_draft: 6, ..Default::default() }))
        .collect();
    let batched = batch.run_spec(&requests).expect("batched");
    for (i, (p, cfg)) in requests.iter().enumerate() {
        let seq = engine.generate_spec(p, cfg).expect("sequential");
        assert_eq!(batched[i].trace.iterations, seq.trace.iterations, "trace {i} diverged");
        assert_eq!(batched[i].trace.produced, seq.trace.produced);
    }
}

#[test]
fn batched_sampling_mode_matches_sequential() {
    // Temperature sampling: each session owns its seeded RNG, so batching
    // must not perturb the sampled stream either.
    let model = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
    let engine = Engine::new(&model);
    let batch = BatchEngine::new(&model);
    let requests: Vec<(Vec<u8>, SpecConfig)> = prompts()
        .into_iter()
        .zip(GEN_LENS)
        .enumerate()
        .map(|(i, (p, g))| {
            (
                p,
                SpecConfig {
                    gen_len: g,
                    sampling: SamplingParams { temperature: 0.8, seed: 100 + i as u64 },
                    ..Default::default()
                },
            )
        })
        .collect();
    let batched = batch.run_spec(&requests).expect("batched");
    for (i, (p, cfg)) in requests.iter().enumerate() {
        let seq = engine.generate_spec(p, cfg).expect("sequential");
        assert_eq!(batched[i].tokens, seq.tokens, "sampled sequence {i} diverged");
    }
}

#[test]
fn batched_ar_matches_sequential_and_mixed_batches_work() {
    // A mixed batch: speculative and autoregressive sessions in lockstep.
    let model = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
    let engine = Engine::new(&model);
    let batch = BatchEngine::new(&model);
    let prompt: &[u8] = b"Q: eve has 4 figs and buys 2. how many figs now?\nA: ";

    let spec_cfg = SpecConfig { gen_len: 32, ..Default::default() };
    let sessions = vec![
        GenSession::Spec(SpecSession::new(&model, prompt, spec_cfg).expect("spec session")),
        GenSession::Ar(
            ArSession::new(&model, prompt, 32, SamplingParams::greedy()).expect("ar session"),
        ),
    ];
    let results = batch.run(sessions).expect("mixed batch");

    let seq_spec = engine.generate_spec(prompt, &spec_cfg).expect("seq spec");
    let seq_ar = engine.generate_ar(prompt, 32, SamplingParams::greedy()).expect("seq ar");
    assert_eq!(results[0].tokens, seq_spec.tokens, "spec diverged in mixed batch");
    assert_eq!(results[1].tokens, seq_ar.tokens, "ar diverged in mixed batch");
    // Greedy losslessness carries over to the batched path.
    assert_eq!(results[0].tokens, results[1].tokens);
    assert_eq!(model.arena().in_use(), 0);
}
