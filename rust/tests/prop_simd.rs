//! SIMD-dispatch property suite: every dispatch tier must be bitwise
//! identical to the scalar reference — for the raw plane decoders, for
//! all three GEMM kernels, across thread counts, and end-to-end through
//! the native backend.
//!
//! Why this holds: SIMD is confined to element-wise, order-free work (the
//! plane decoders and the per-element `y += a·x` update, separate
//! multiply + add, never FMA), while every output element keeps the
//! serial ascending-index accumulation order.  Per-lane IEEE multiply and
//! add round exactly like their scalar counterparts, so a vector tier can
//! only move the *same* operations onto wider registers — never change a
//! single f32 result.  The widths below deliberately straddle the vector
//! lane counts (1, lane-1, lane, lane+1, odd primes) so both the vector
//! body and the scalar tail of every path are exercised.

use speq::bsfp::simd::{
    decode_draft_row_pair, decode_draft_row_pair_scalar, decode_full_row_pair,
    decode_full_row_pair_scalar, draft_lut,
};
use speq::bsfp::{quantize_tensor, PlanePair, SimdLevel, GROUP_SIZE};
use speq::runtime::kernels::{gemm_dense, gemm_draft_prefix, gemm_full_planes, SCRATCH_ROWS};
use speq::model::SamplingParams;
use speq::runtime::{NativeBackend, WorkerPool};
use speq::specdec::{Engine, SpecConfig};
use speq::util::rng::Rng;

/// Widths straddling every tier's lane count (AVX2 = 8, SSE/NEON = 4),
/// plus odd primes that leave ragged scalar tails.
const WIDTHS: [usize; 12] = [1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 37];

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: idx {i} ({g:?} vs {w:?})");
    }
}

#[test]
fn dispatch_vocabulary_is_sane() {
    let avail = SimdLevel::available();
    assert_eq!(avail[0], SimdLevel::Scalar, "scalar must always be available");
    assert_eq!(*avail.last().unwrap(), SimdLevel::detect(), "detect() is the best tier");
    for level in &avail {
        assert!(level.is_available());
        assert_eq!(SimdLevel::parse(level.name()), Some(*level), "name/parse roundtrip");
        assert_eq!(level.resolve(), *level, "available levels resolve to themselves");
    }
    assert_eq!(SimdLevel::parse("auto"), Some(SimdLevel::detect()));
    assert_eq!(SimdLevel::parse(""), Some(SimdLevel::detect()));
    assert_eq!(SimdLevel::parse("AVX2"), Some(SimdLevel::Avx2), "parse is case-insensitive");
    assert_eq!(SimdLevel::parse("bogus"), None);
}

/// Raw full-plane decoder: every tier == scalar, bitwise, over widths
/// that straddle the lane counts and over *all* 4-bit codes (the dense
/// sweep covers all 256 prefix bytes x assorted residual bits).
#[test]
fn full_decoder_matches_scalar_bitwise() {
    let mut rng = Rng::seed_from_u64(0xf00d);
    for &n in &WIDTHS {
        for round in 0..8u64 {
            let prow: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            let rrow: Vec<u8> = (0..3 * n).map(|_| rng.next_u32() as u8).collect();
            let mut lo_s = vec![0.0f32; n];
            let mut hi_s = vec![0.0f32; n];
            decode_full_row_pair_scalar(&prow, &rrow, &mut lo_s, &mut hi_s);
            for level in SimdLevel::available() {
                let mut lo = vec![f32::NAN; n];
                let mut hi = vec![f32::NAN; n];
                decode_full_row_pair(level, &prow, &rrow, &mut lo, &mut hi);
                let what = format!("full n={n} round={round} {}", level.name());
                assert_bits_eq(&lo, &lo_s, &what);
                assert_bits_eq(&hi, &hi_s, &what);
            }
        }
    }
    // Dense sweep: all 256 prefix bytes x a stride of residual patterns
    // (covers every code/flag/e0 mux arm, subnormal and zero mantissas).
    let n = 256;
    for seed in 0..4u64 {
        let prow: Vec<u8> = (0..n).map(|j| j as u8).collect();
        let rrow: Vec<u8> = (0..3 * n).map(|j| (j as u64 * (2 * seed + 7) + seed) as u8).collect();
        let mut lo_s = vec![0.0f32; n];
        let mut hi_s = vec![0.0f32; n];
        decode_full_row_pair_scalar(&prow, &rrow, &mut lo_s, &mut hi_s);
        for level in SimdLevel::available() {
            let mut lo = vec![f32::NAN; n];
            let mut hi = vec![f32::NAN; n];
            decode_full_row_pair(level, &prow, &rrow, &mut lo, &mut hi);
            let what = format!("full dense seed={seed} {}", level.name());
            assert_bits_eq(&lo, &lo_s, &what);
            assert_bits_eq(&hi, &hi_s, &what);
        }
    }
}

/// Raw draft decoder: every tier == scalar, bitwise, including hoisted
/// factors of exactly 0.0, negative, tiny (denormal-adjacent), and the
/// outlier `tensor_scale` regime (factor > 1).
#[test]
fn draft_decoder_matches_scalar_bitwise() {
    let lut = draft_lut();
    let mut rng = Rng::seed_from_u64(0xbeef);
    let factors = [1.0f32, 0.0, -0.37, 1e-20, 3.5e4, 0.73 / 0.9995];
    for &n in &WIDTHS {
        for (fi, &f) in factors.iter().enumerate() {
            let prow: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            let mut pre: Vec<f32> = (0..n).map(|_| rng.gen_f32() * 0.5).collect();
            pre[0] = f; // pin the edge factor somewhere in every width
            let mut lo_s = vec![0.0f32; n];
            let mut hi_s = vec![0.0f32; n];
            decode_draft_row_pair_scalar(&prow, &pre, &lut, &mut lo_s, &mut hi_s);
            for level in SimdLevel::available() {
                let mut lo = vec![f32::NAN; n];
                let mut hi = vec![f32::NAN; n];
                decode_draft_row_pair(level, &prow, &pre, &lut, &mut lo, &mut hi);
                let what = format!("draft n={n} factor#{fi} {}", level.name());
                assert_bits_eq(&lo, &lo_s, &what);
                assert_bits_eq(&hi, &hi_s, &what);
            }
        }
    }
    // Dense byte sweep: all 256 nibble-pair bytes at once.
    let n = 256;
    let prow: Vec<u8> = (0..n).map(|j| j as u8).collect();
    let pre: Vec<f32> = (0..n).map(|j| (j as f32 - 77.0) * 0.013).collect();
    let mut lo_s = vec![0.0f32; n];
    let mut hi_s = vec![0.0f32; n];
    decode_draft_row_pair_scalar(&prow, &pre, &lut, &mut lo_s, &mut hi_s);
    for level in SimdLevel::available() {
        let mut lo = vec![f32::NAN; n];
        let mut hi = vec![f32::NAN; n];
        decode_draft_row_pair(level, &prow, &pre, &lut, &mut lo, &mut hi);
        let what = format!("draft dense {}", level.name());
        assert_bits_eq(&lo, &lo_s, &what);
        assert_bits_eq(&hi, &hi_s, &what);
    }
}

fn batch(b: usize, k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut out = Vec::with_capacity(b * k);
    for _ in 0..b {
        out.extend(rng.normal_vec(k, 1.0));
    }
    out
}

/// All three GEMM kernels: every (tier, thread count, batch) combination
/// produces the scalar/serial bits, over awkward column counts.
#[test]
fn gemm_kernels_match_scalar_across_tiers_and_threads() {
    let k = 2 * GROUP_SIZE; // two scale groups
    for &n in &[1usize, 5, 8, 17, 37] {
        let w = Rng::seed_from_u64(100 + n as u64).uniform_vec(k * n, 0.3);
        let qt = quantize_tensor(&w, k, n);
        let planes = qt.planes();
        let prefix = qt.packed_wq();
        for b in [1usize, 3] {
            let xs = batch(b, k, 200 + n as u64);
            let serial = WorkerPool::new(1);
            let mut dense_ref = vec![f32::NAN; b * n];
            gemm_dense(&serial, SimdLevel::Scalar, &xs, b, &w, k, n, &mut dense_ref);
            let mut full_ref = vec![f32::NAN; b * n];
            let mut scratch = vec![0.0f32; SCRATCH_ROWS * n];
            gemm_full_planes(&serial, SimdLevel::Scalar, &xs, b, &planes, &mut scratch, &mut full_ref);
            let mut draft_ref = vec![f32::NAN; b * n];
            gemm_draft_prefix(
                &serial,
                SimdLevel::Scalar,
                &xs,
                b,
                &prefix,
                &qt.scales,
                qt.tensor_scale,
                k,
                n,
                &mut scratch,
                &mut draft_ref,
            );
            for level in SimdLevel::available() {
                for t in [1usize, 2, 4] {
                    let pool = WorkerPool::new(t);
                    let what = format!("n={n} b={b} T={t} {}", level.name());
                    let mut ys = vec![f32::NAN; b * n];
                    gemm_dense(&pool, level, &xs, b, &w, k, n, &mut ys);
                    assert_bits_eq(&ys, &dense_ref, &format!("dense {what}"));
                    let mut ys = vec![f32::NAN; b * n];
                    gemm_full_planes(&pool, level, &xs, b, &planes, &mut scratch, &mut ys);
                    assert_bits_eq(&ys, &full_ref, &format!("full {what}"));
                    let mut ys = vec![f32::NAN; b * n];
                    gemm_draft_prefix(
                        &pool,
                        level,
                        &xs,
                        b,
                        &prefix,
                        &qt.scales,
                        qt.tensor_scale,
                        k,
                        n,
                        &mut scratch,
                        &mut ys,
                    );
                    assert_bits_eq(&ys, &draft_ref, &format!("draft {what}"));
                }
            }
        }
    }
}

/// The outlier regime (Algorithm-1 pre-scale active, `tensor_scale < 1`)
/// through the draft kernel, bitwise across tiers.
#[test]
fn outlier_tensor_scale_is_tier_invariant() {
    let (k, n) = (GROUP_SIZE, 13usize);
    let mut w = Rng::seed_from_u64(55).uniform_vec(k * n, 0.2);
    w[3] = 2.75; // forces the pre-scale
    let qt = quantize_tensor(&w, k, n);
    assert!(qt.tensor_scale < 1.0, "outlier must trigger Algorithm 1");
    let xs = batch(2, k, 56);
    let pool = WorkerPool::new(2);
    let prefix = qt.packed_wq();
    let mut scratch = vec![0.0f32; SCRATCH_ROWS * n];
    let mut reference = vec![f32::NAN; 2 * n];
    gemm_draft_prefix(
        &pool,
        SimdLevel::Scalar,
        &xs,
        2,
        &prefix,
        &qt.scales,
        qt.tensor_scale,
        k,
        n,
        &mut scratch,
        &mut reference,
    );
    for level in SimdLevel::available() {
        let mut ys = vec![f32::NAN; 2 * n];
        gemm_draft_prefix(
            &pool,
            level,
            &xs,
            2,
            &prefix,
            &qt.scales,
            qt.tensor_scale,
            k,
            n,
            &mut scratch,
            &mut ys,
        );
        assert_bits_eq(&ys, &reference, &format!("outlier draft {}", level.name()));
    }
}

/// Non-finite weights take the dense fallback path (they are outside the
/// quantizable FP16 domain); the dense kernel must stay tier-invariant
/// even with inf/NaN in the stream — vector multiply/add follows the same
/// IEEE propagation rules as scalar, in the same order.
#[test]
fn non_finite_dense_fallback_is_tier_invariant() {
    let (k, n) = (32usize, 17usize);
    let mut w = Rng::seed_from_u64(77).uniform_vec(k * n, 0.4);
    w[5] = f32::INFINITY;
    w[n + 2] = f32::NEG_INFINITY;
    w[2 * n + 9] = f32::NAN;
    assert!(!speq::bsfp::fp16_exact_in_domain(&w), "must be outside the BSFP domain");
    // Strictly nonzero activations: keeps inf columns at inf (0 * inf
    // would make NaNs where the reference has them too, but nonzero is
    // the clearer pin).
    let xs: Vec<f32> = (0..2 * k).map(|i| 0.25 + (i as f32) * 0.01).collect();
    let pool = WorkerPool::new(2);
    let mut reference = vec![f32::NAN; 2 * n];
    gemm_dense(&pool, SimdLevel::Scalar, &xs, 2, &w, k, n, &mut reference);
    assert!(reference.iter().any(|v| !v.is_finite()), "non-finiteness must propagate");
    for level in SimdLevel::available() {
        let mut ys = vec![0.0f32; 2 * n];
        gemm_dense(&pool, level, &xs, 2, &w, k, n, &mut ys);
        assert_bits_eq(&ys, &reference, &format!("non-finite dense {}", level.name()));
    }
}

/// A degenerate-width pool (more threads than columns) leaves some shards
/// empty; every tier must still produce the serial bits.
#[test]
fn more_threads_than_columns_is_tier_invariant() {
    let (k, n) = (GROUP_SIZE, 3usize);
    let w = Rng::seed_from_u64(91).uniform_vec(k * n, 0.3);
    let qt = quantize_tensor(&w, k, n);
    let planes = qt.planes();
    let xs = batch(1, k, 92);
    let serial = WorkerPool::new(1);
    let wide = WorkerPool::new(8);
    let mut scratch = vec![0.0f32; SCRATCH_ROWS * n];
    let mut reference = vec![f32::NAN; n];
    gemm_full_planes(&serial, SimdLevel::Scalar, &xs, 1, &planes, &mut scratch, &mut reference);
    for level in SimdLevel::available() {
        let mut ys = vec![f32::NAN; n];
        gemm_full_planes(&wide, level, &xs, 1, &planes, &mut scratch, &mut ys);
        assert_bits_eq(&ys, &reference, &format!("narrow-n full {}", level.name()));
    }
}

/// End-to-end: generated token streams are byte-identical for every
/// dispatch tier (speculative and autoregressive, through the full
/// backend: attention, norms, sampling — everything).
#[test]
fn generated_tokens_are_tier_invariant() {
    const PROMPT: &[u8] = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";
    let cfg = SpecConfig { max_draft: 8, gen_len: 24, ..Default::default() };
    let run = |level: SimdLevel| {
        let mut b = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin model");
        b.set_simd(level);
        b.set_threads(2);
        assert_eq!(b.simd_level(), level);
        let engine = Engine::new(&b);
        let spec = engine.generate_spec(PROMPT, &cfg).expect("spec").tokens;
        let ar = engine
            .generate_ar(PROMPT, cfg.gen_len, SamplingParams::greedy())
            .expect("ar")
            .tokens;
        (spec, ar)
    };
    let (spec_ref, ar_ref) = run(SimdLevel::Scalar);
    assert_eq!(spec_ref, ar_ref, "greedy spec != AR at scalar");
    for level in SimdLevel::available() {
        let (spec, ar) = run(level);
        assert_eq!(spec, spec_ref, "spec tokens diverged at {}", level.name());
        assert_eq!(ar, ar_ref, "AR tokens diverged at {}", level.name());
    }
}
