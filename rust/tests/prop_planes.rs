//! Exhaustive properties of the exponent remap and the bit-plane split.
//!
//! Pushes **all 65,536 FP16 bit patterns** — subnormals, infinities and
//! NaNs included — through the remap → plane-split pack → decode pipeline:
//!
//! * every in-domain pattern (`exp <= 15`) round-trips bit-exactly through
//!   `try_encode_bits` → plane pack → plane unpack → full decode;
//! * every out-of-domain pattern (`exp > 15`, which covers inf/NaN) is
//!   rejected by `try_encode_bits` — the weight store routes such tensors
//!   to its dense fallback, keeping full-pass exactness total;
//! * the Eq. 4 scales satisfy the per-group MSE error bound over the
//!   entire in-domain value population.

use speq::bsfp::{
    decode_full_bits, draft_value, f16_bits_to_f32, quantize_tensor, split_fields,
    try_encode_bits, unpack_residuals, PlanePair, GROUP_SIZE,
};

/// All 32,768 in-domain FP16 bit patterns (sign x 16 exponents x 1024
/// mantissas), ordered by bits ascending — 256 Eq. 4 groups of 128.
fn domain_bits() -> Vec<u16> {
    let mut out = Vec::with_capacity(32768);
    for s in 0..2u16 {
        for e in 0..16u16 {
            for m in 0..1024u16 {
                out.push((s << 15) | (e << 10) | m);
            }
        }
    }
    out
}

#[test]
fn all_65536_patterns_encode_or_are_rejected() {
    let mut encoded = 0usize;
    let mut rejected = 0usize;
    for bits in 0..=u16::MAX {
        let exp = split_fields(bits).exp;
        match try_encode_bits(bits) {
            Some(c) => {
                assert!(exp <= 15, "bits {bits:#06x}: encoded an out-of-domain exponent");
                // Lossless reconstruction through the Fig. 5(b) decoder.
                assert_eq!(decode_full_bits(c), bits, "bits {bits:#06x}");
                // The packed fields stay in their bit budgets.
                assert!(c.w_q <= 0xf, "bits {bits:#06x}: W_q overflows 4 bits");
                assert!(c.w_r <= 0xfff, "bits {bits:#06x}: W_r overflows 12 bits");
                encoded += 1;
            }
            None => {
                assert!(exp > 15, "bits {bits:#06x}: rejected an in-domain exponent");
                rejected += 1;
            }
        }
    }
    assert_eq!(encoded, 32768);
    assert_eq!(rejected, 32768);
}

#[test]
fn plane_split_is_lossless_over_the_entire_domain() {
    // One tensor holding every in-domain pattern exactly once: (32768, 1).
    let bits = domain_bits();
    let w: Vec<f32> = bits.iter().map(|&b| f16_bits_to_f32(b)).collect();
    let k = w.len();
    let qt = quantize_tensor(&w, k, 1);
    assert_eq!(qt.tensor_scale, 1.0, "the domain maxes at 1.9990234 < 2.0");
    let planes = PlanePair::from_quantized(&qt);

    // Plane packing is invertible: codes and residuals survive the nibble
    // and 12-bit packings.
    assert_eq!(planes.codes(), qt.w_q);
    assert_eq!(unpack_residuals(&planes.residual, k, 1), qt.w_r);

    // Full decode through the planes reproduces every FP16 pattern
    // bit-exactly (subnormals and signed zeros included).
    let decoded = planes.decode_full_f32();
    for (i, (&d, &orig)) in decoded.iter().zip(&w).enumerate() {
        assert_eq!(
            d.to_bits(),
            orig.to_bits(),
            "bits {:#06x} (idx {i}) did not survive the plane round-trip",
            bits[i]
        );
    }
}

#[test]
fn eq4_error_bound_holds_per_group() {
    // Over the full domain tensor: for every 128-element group, the Eq. 4
    // scale must (a) be a local MSE minimum (perturbing it either way
    // cannot help) and (b) beat the trivial scale-zero predictor, i.e.
    // group draft MSE <= group signal energy.
    let bits = domain_bits();
    let w: Vec<f32> = bits.iter().map(|&b| f16_bits_to_f32(b)).collect();
    let k = w.len();
    let qt = quantize_tensor(&w, k, 1);
    let q: Vec<f64> = qt.w_q.iter().map(|&c| draft_value(c) as f64).collect();
    let groups = k / GROUP_SIZE;
    assert_eq!(qt.scales.len(), groups);
    for g in 0..groups {
        let lo = g * GROUP_SIZE;
        let hi = lo + GROUP_SIZE;
        let mse = |s: f64| -> f64 {
            (lo..hi).map(|i| (q[i] * s - w[i] as f64).powi(2)).sum::<f64>()
                / GROUP_SIZE as f64
        };
        let s = qt.scales[g] as f64;
        let at = mse(s);
        assert!(at <= mse(s * 1.01) + 1e-18, "group {g}: scale not optimal (up)");
        assert!(at <= mse(s * 0.99) + 1e-18, "group {g}: scale not optimal (down)");
        let signal =
            (lo..hi).map(|i| (w[i] as f64).powi(2)).sum::<f64>() / GROUP_SIZE as f64;
        assert!(
            at <= signal + 1e-18,
            "group {g}: draft error {at} exceeds signal energy {signal}"
        );
    }
}

#[test]
fn draft_plane_view_matches_the_codec_dequant_over_the_domain() {
    // The prefix-plane draft view (what the quarter-traffic kernel
    // streams) must equal the codec's dequantization bitwise, group
    // scales applied.
    let bits = domain_bits();
    let w: Vec<f32> = bits.iter().map(|&b| f16_bits_to_f32(b)).collect();
    let k = w.len();
    let qt = quantize_tensor(&w, k, 1);
    let planes = PlanePair::from_quantized(&qt);
    let expect = qt.dequant_draft();
    let codes = planes.codes();
    for (i, &code) in codes.iter().enumerate() {
        let got = draft_value(code) * qt.scales[i / GROUP_SIZE];
        assert_eq!(got.to_bits(), expect[i].to_bits(), "idx {i}");
    }
}
