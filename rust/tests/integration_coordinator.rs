//! Integration: the serving coordinator end-to-end (continuous-batching
//! scheduler + queue + sessions + metrics + streaming) over the builtin
//! native backend — no artifacts.

use speq::coordinator::{
    Mode, ModelSource, Priority, ResponseEvent, Server, ServerConfig, SubmitParams,
};

fn server(workers: usize) -> Server {
    let cfg = ServerConfig {
        source: ModelSource::Builtin,
        model: "vicuna-7b-tiny".into(),
        workers,
        queue_capacity: 32,
        ..ServerConfig::default()
    };
    Server::start(cfg).expect("server start")
}

#[test]
fn serves_a_single_request() {
    let server = server(1);
    let body = server.generate(b"Q: ada has 2 pens and buys 3 more. how many pens now?\nA: ", 48).expect("generate");
    assert_eq!(body.tokens.len(), 48);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.tokens, 48);
    assert!(snap.latency_p50_ms > 0.0);
    assert!(snap.batch_occupancy_mean >= 1.0, "scheduler should record batch steps");
    server.shutdown();
}

#[test]
fn serves_concurrent_requests_in_one_batch() {
    // One scheduler thread, many concurrent requests: continuous batching
    // must interleave them rather than serving one at a time.
    let server = server(1);
    let prompts: Vec<&[u8]> = vec![
        b"Q: bob has 5 coins and wins 2 more. how many coins now?\nA: ",
        b"def inc_1(x):\n    return ",
        b"USER: hello, can we talk about music?\nBOT: ",
        b"Q: carol has 9 cards and gives away 4. how many cards left?\nA: ",
    ];
    let mut streams = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let (_, stream) = server
            .submit(
                p,
                SubmitParams {
                    gen_len: 32,
                    priority: if i % 2 == 0 { Priority::Interactive } else { Priority::Batch },
                    ..Default::default()
                },
            )
            .expect("submit");
        streams.push(stream);
    }
    for stream in streams {
        let body = stream.wait().expect("generation ok");
        assert_eq!(body.tokens.len(), 32);
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 4);
    assert_eq!(snap.failed, 0);
    server.shutdown();
}

#[test]
fn speculative_and_autoregressive_modes_agree() {
    let server = server(1);
    let prompt: &[u8] = b"Q: ken has 8 books and sells 3. how many books left?\nA: ";
    let (_, spec_stream) = server
        .submit(prompt, SubmitParams { gen_len: 40, ..Default::default() })
        .unwrap();
    let (_, ar_stream) = server
        .submit(
            prompt,
            SubmitParams { gen_len: 40, mode: Mode::Autoregressive, ..Default::default() },
        )
        .unwrap();
    let spec = spec_stream.wait().unwrap();
    let ar = ar_stream.wait().unwrap();
    assert_eq!(spec.tokens, ar.tokens, "serving path lost losslessness");
    // The speculative mode should have used drafts and accepted some.
    assert!(spec.trace.draft_steps() > 0);
    assert_eq!(ar.trace.draft_steps(), 0);
    server.shutdown();
}

#[test]
fn responses_stream_incremental_chunks() {
    let server = server(1);
    let (id, stream) = server
        .submit(
            b"Q: dana has 6 pears and eats 1. how many pears left?\nA: ",
            SubmitParams { gen_len: 48, ..Default::default() },
        )
        .unwrap();
    let mut streamed = Vec::new();
    let mut chunks = 0;
    let body = loop {
        let resp = stream.recv().expect("event");
        assert_eq!(resp.id, id);
        match resp.event {
            ResponseEvent::Chunk(c) => {
                assert!(!c.is_empty());
                chunks += 1;
                streamed.extend(c);
            }
            ResponseEvent::Done(result) => break result.expect("generation ok"),
            ResponseEvent::Cancelled(kind) => panic!("unexpected cancellation: {kind}"),
        }
    };
    assert!(chunks >= 2, "expected incremental chunks, got {chunks}");
    assert_eq!(streamed, body.tokens, "chunks must concatenate to the final body");
    server.shutdown();
}

#[test]
fn invalid_request_is_failed_and_counted() {
    let server = server(1);
    // max_draft exceeds the model's logits slots: admission must fail the
    // request (and count it) without wedging the scheduler.
    let (_, stream) = server
        .submit(b"Q: ", SubmitParams { gen_len: 8, max_draft: 99, ..Default::default() })
        .unwrap();
    let err = stream.wait().unwrap_err();
    assert!(format!("{err}").contains("max_draft"), "{err}");
    // The server still works afterwards.
    let body = server.generate(b"Q: 1 + 1 = ", 16).expect("generate");
    assert_eq!(body.tokens.len(), 16);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.failed, 1);
    assert_eq!(snap.completed, 1);
    server.shutdown();
}

#[test]
fn empty_prompt_is_failed_per_request_not_per_batch() {
    let server = server(1);
    // An invalid prompt must fail at admission (its own request only) —
    // never inside a batched engine step where it would take down every
    // co-batched request.
    let (_, good) = server
        .submit(b"Q: 3 + 4 = ", SubmitParams { gen_len: 16, ..Default::default() })
        .unwrap();
    let (_, bad) = server.submit(b"", SubmitParams { gen_len: 16, ..Default::default() }).unwrap();
    let err = bad.wait().unwrap_err();
    assert!(format!("{err}").contains("empty prompt"), "{err}");
    let body = good.wait().expect("co-submitted request must survive");
    assert_eq!(body.tokens.len(), 16);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.failed, 1);
    assert_eq!(snap.completed, 1);
    server.shutdown();
}

#[test]
fn same_session_turns_are_serialized_not_co_batched() {
    // Two turns of one conversation submitted back-to-back (no client-side
    // wait) must see each other's history exactly as if submitted serially:
    // the scheduler defers turn 2 until turn 1 retires.
    let turn1: &[u8] = b"USER: tell me about pears\nBOT: ";
    let turn2: &[u8] = b"\nUSER: and apples?\nBOT: ";
    let sid = 11u64;

    // Reference: strictly serial submission.
    let serial = server(1);
    let (_, s1) = serial
        .submit(turn1, SubmitParams { gen_len: 24, session: Some(sid), ..Default::default() })
        .unwrap();
    s1.wait().unwrap();
    let (_, s2) = serial
        .submit(turn2, SubmitParams { gen_len: 24, session: Some(sid), ..Default::default() })
        .unwrap();
    let expected = s2.wait().unwrap().tokens;
    serial.shutdown();

    // Concurrent submission of both turns.
    let concurrent = server(1);
    let (_, c1) = concurrent
        .submit(turn1, SubmitParams { gen_len: 24, session: Some(sid), ..Default::default() })
        .unwrap();
    let (_, c2) = concurrent
        .submit(turn2, SubmitParams { gen_len: 24, session: Some(sid), ..Default::default() })
        .unwrap();
    c1.wait().unwrap();
    let got = c2.wait().unwrap().tokens;
    assert_eq!(got, expected, "turn 2 saw different session history under co-submission");
    concurrent.shutdown();
}

#[test]
fn sessions_carry_context_between_turns() {
    let server = server(1);
    let sid = 7u64;
    let (_, s1) = server
        .submit(
            b"USER: hello, can we talk about books?\nBOT: ",
            SubmitParams { gen_len: 24, session: Some(sid), ..Default::default() },
        )
        .unwrap();
    s1.wait().unwrap();
    assert_eq!(server.sessions().len(), 1);
    let (_, s2) = server
        .submit(
            b"\nUSER: what do you think about books today?\nBOT: ",
            SubmitParams { gen_len: 24, session: Some(sid), ..Default::default() },
        )
        .unwrap();
    let out2 = s2.wait().unwrap();
    assert_eq!(out2.tokens.len(), 24);
    server.shutdown();
}

#[test]
fn deadline_expired_request_is_cancelled_between_steps() {
    let server = server(1);
    // A deadline far shorter than a 200-token generation: the scheduler
    // must retire the sequence between engine steps, free its slot, emit
    // the terminal Cancelled event, and count it.
    let (_, stream) = server
        .submit(
            b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ",
            SubmitParams {
                gen_len: 200,
                deadline: Some(std::time::Instant::now() + std::time::Duration::from_millis(20)),
                ..Default::default()
            },
        )
        .unwrap();
    let err = stream.wait().unwrap_err();
    assert!(format!("{err}").contains("deadline"), "{err}");
    // The slot is free again: a fresh request completes normally.
    let body = server.generate(b"Q: 1 + 1 = ", 16).expect("generate after cancel");
    assert_eq!(body.tokens.len(), 16);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.failed, 0);
    server.shutdown();
}

#[test]
fn client_cancel_token_retires_an_in_flight_request() {
    let server = server(1);
    let (_, stream) = server
        .submit(
            b"USER: hello, can we talk about music?\nBOT: ",
            SubmitParams { gen_len: 200, ..Default::default() },
        )
        .unwrap();
    let cancel = stream.cancel_token();
    // Let it get admitted and produce at least one step, then cancel.
    std::thread::sleep(std::time::Duration::from_millis(30));
    cancel.cancel();
    let err = stream.wait().unwrap_err();
    assert!(format!("{err}").contains("cancelled"), "{err}");
    assert!(server.metrics().snapshot().cancelled >= 1);
    // Scheduler is healthy afterwards.
    let body = server.generate(b"Q: 2 + 2 = ", 8).expect("generate after cancel");
    assert_eq!(body.tokens.len(), 8);
    server.shutdown();
}

#[test]
fn drain_completes_in_flight_work_and_rejects_new_submissions() {
    let server = server(1);
    let (_, stream) = server
        .submit(
            b"Q: dana has 6 pears and eats 1. how many pears left?\nA: ",
            SubmitParams { gen_len: 48, ..Default::default() },
        )
        .unwrap();
    assert!(
        server.drain(std::time::Duration::from_secs(60)),
        "drain must finish the in-flight request"
    );
    assert_eq!(server.pending_requests(), 0);
    // Drained servers accept no new work ...
    let err = server
        .submit(b"Q: too late\nA: ", SubmitParams::default())
        .unwrap_err();
    assert!(format!("{err}").contains("closed"), "{err}");
    // ... but the drained request completed in full.
    let body = stream.wait().expect("in-flight request survives drain");
    assert_eq!(body.tokens.len(), 48);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.rejected, 1);
    server.shutdown();
}

#[test]
fn unknown_builtin_model_fails_fast() {
    let cfg = ServerConfig {
        source: ModelSource::Builtin,
        model: "gpt-5".into(),
        workers: 1,
        queue_capacity: 4,
        ..ServerConfig::default()
    };
    let err = Server::start(cfg).unwrap_err();
    assert!(format!("{err}").contains("builtin zoo"), "{err}");
}

#[test]
fn missing_artifacts_source_fails_fast() {
    let cfg = ServerConfig {
        source: ModelSource::Artifacts("/nonexistent/artifacts".into()),
        model: "vicuna-7b-tiny".into(),
        workers: 1,
        queue_capacity: 4,
        ..ServerConfig::default()
    };
    let err = Server::start(cfg).unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
}
