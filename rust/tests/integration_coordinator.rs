//! Integration: the serving coordinator end-to-end (worker pool + queue +
//! sessions + metrics) over the builtin native backend — no artifacts.

use speq::coordinator::{Mode, ModelSource, Priority, Server, ServerConfig};
use speq::model::SamplingParams;

fn server(workers: usize) -> Server {
    let cfg = ServerConfig {
        source: ModelSource::Builtin,
        model: "vicuna-7b-tiny".into(),
        workers,
        queue_capacity: 32,
        session_history: 96,
    };
    Server::start(cfg).expect("server start")
}

#[test]
fn serves_a_single_request() {
    let server = server(1);
    let body = server.generate(b"Q: ada has 2 pens and buys 3 more. how many pens now?\nA: ", 48).expect("generate");
    assert_eq!(body.tokens.len(), 48);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.tokens, 48);
    assert!(snap.latency_p50_ms > 0.0);
    server.shutdown();
}

#[test]
fn serves_concurrent_requests_across_workers() {
    let server = server(2);
    let prompts: Vec<&[u8]> = vec![
        b"Q: bob has 5 coins and wins 2 more. how many coins now?\nA: ",
        b"def inc_1(x):\n    return ",
        b"USER: hello, can we talk about music?\nBOT: ",
        b"Q: carol has 9 cards and gives away 4. how many cards left?\nA: ",
    ];
    let mut rxs = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let (_, rx) = server
            .submit(
                p,
                32,
                Mode::Speculative,
                if i % 2 == 0 { Priority::Interactive } else { Priority::Batch },
                SamplingParams::greedy(),
                None,
                16,
                0.6,
            )
            .expect("submit");
        rxs.push(rx);
    }
    let mut workers_seen = std::collections::HashSet::new();
    for rx in rxs {
        let resp = rx.recv().expect("response");
        let body = resp.result.expect("generation ok");
        assert_eq!(body.tokens.len(), 32);
        workers_seen.insert(body.worker);
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 4);
    // With 2 workers and 4 requests, both workers should usually see work;
    // don't hard-require it (scheduling is load-dependent), just record.
    eprintln!("workers used: {workers_seen:?}");
    server.shutdown();
}

#[test]
fn speculative_and_autoregressive_modes_agree() {
    let server = server(1);
    let prompt: &[u8] = b"Q: ken has 8 books and sells 3. how many books left?\nA: ";
    let (_, rx_spec) = server
        .submit(prompt, 40, Mode::Speculative, Priority::Interactive,
                SamplingParams::greedy(), None, 16, 0.6)
        .unwrap();
    let (_, rx_ar) = server
        .submit(prompt, 40, Mode::Autoregressive, Priority::Interactive,
                SamplingParams::greedy(), None, 16, 0.6)
        .unwrap();
    let spec = rx_spec.recv().unwrap().result.unwrap();
    let ar = rx_ar.recv().unwrap().result.unwrap();
    assert_eq!(spec.tokens, ar.tokens, "serving path lost losslessness");
    // The speculative mode should have used drafts and accepted some.
    assert!(spec.trace.draft_steps() > 0);
    assert_eq!(ar.trace.draft_steps(), 0);
    server.shutdown();
}

#[test]
fn sessions_carry_context_between_turns() {
    let server = server(1);
    let sid = 7u64;
    let (_, rx1) = server
        .submit(b"USER: hello, can we talk about books?\nBOT: ", 24,
                Mode::Speculative, Priority::Interactive,
                SamplingParams::greedy(), Some(sid), 16, 0.6)
        .unwrap();
    rx1.recv().unwrap().result.unwrap();
    assert_eq!(server.sessions().len(), 1);
    let (_, rx2) = server
        .submit(b"\nUSER: what do you think about books today?\nBOT: ", 24,
                Mode::Speculative, Priority::Interactive,
                SamplingParams::greedy(), Some(sid), 16, 0.6)
        .unwrap();
    let out2 = rx2.recv().unwrap().result.unwrap();
    assert_eq!(out2.tokens.len(), 24);
    server.shutdown();
}

#[test]
fn unknown_builtin_model_fails_fast() {
    let cfg = ServerConfig {
        source: ModelSource::Builtin,
        model: "gpt-5".into(),
        workers: 1,
        queue_capacity: 4,
        session_history: 16,
    };
    let err = Server::start(cfg).unwrap_err();
    assert!(format!("{err}").contains("builtin zoo"), "{err}");
}

#[test]
fn missing_artifacts_source_fails_fast() {
    let cfg = ServerConfig {
        source: ModelSource::Artifacts("/nonexistent/artifacts".into()),
        model: "vicuna-7b-tiny".into(),
        workers: 1,
        queue_capacity: 4,
        session_history: 16,
    };
    let err = Server::start(cfg).unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
}
