//! Property-based invariants (randomized, seeded; see util::prop).

use speq::bsfp::{
    decode_full_bits, encode_bits, pack_nibbles, quantize_tensor, unpack_nibbles,
    GROUP_SIZE,
};
use speq::model::{ModelConfig, SamplingParams};
use speq::quant::{quantize_fp4, quantize_int, Fp4Variant, IntMethod};
use speq::runtime::{InitStyle, NativeBackend};
use speq::specdec::{expected_accept_length, Engine, IterRecord, SpecConfig, SpecTrace};
use speq::util::json;
use speq::util::prop::check;
use speq::util::rng::Rng;

#[test]
fn prop_bsfp_roundtrip_random_tensors() {
    check(50, "bsfp_roundtrip", |rng| {
        let k = GROUP_SIZE * rng.gen_between(1, 4);
        let n = rng.gen_between(1, 12);
        let amp = [0.02f32, 0.2, 1.5, 3.5][rng.gen_range(4)];
        let w = rng.normal_vec(k * n, amp);
        let qt = quantize_tensor(&w, k, n);
        // Lossless: reconstruct_full == FP16(w * tensor_scale) / tensor_scale.
        let rec = qt.reconstruct_full();
        for (i, (&r, &orig)) in rec.iter().zip(&w).enumerate() {
            let expect = speq::bsfp::f16_bits_to_f32(speq::bsfp::f32_to_f16_bits(
                orig * qt.tensor_scale,
            )) / qt.tensor_scale;
            assert!(
                (r - expect).abs() <= expect.abs() * 1e-6 + 1e-9,
                "idx {i}: {r} vs {expect}"
            );
        }
    });
}

#[test]
fn prop_bsfp_quantize_pack_decode_error_bound() {
    // The full satellite pipeline: quantize -> nibble-pack -> unpack ->
    // draft-decode.  Packing must be transparent, and the decoded draft's
    // per-group error must respect the E3M0+Eq.4 bound: each draft value is
    // a power of two within a factor of sqrt(2)-ish of its weight, so the
    // group MSE stays below the group signal energy.
    check(40, "bsfp_pipeline_error_bound", |rng| {
        let k = GROUP_SIZE * rng.gen_between(1, 4);
        let n = rng.gen_between(1, 10);
        let amp = [0.02f32, 0.15, 1.2, 3.0][rng.gen_range(4)];
        let w = rng.normal_vec(k * n, amp);
        let qt = quantize_tensor(&w, k, n);
        // Pack/unpack transparency over the real codes.
        assert_eq!(unpack_nibbles(&qt.packed_wq(), k, n), qt.w_q);
        // Decoded draft error bound vs the (pre-scaled) FP16 values.
        let draft = qt.dequant_draft();
        let full: Vec<f32> = qt
            .reconstruct_fp16_bits()
            .iter()
            .map(|&b| speq::bsfp::f16_bits_to_f32(b))
            .collect();
        let (mut err2, mut sig2) = (0.0f64, 0.0f64);
        for (d, t) in draft.iter().zip(&full) {
            err2 += ((d - t) as f64).powi(2);
            sig2 += (*t as f64).powi(2);
        }
        assert!(
            err2 <= sig2 * 0.5 + 1e-12,
            "draft error energy {err2} exceeds half the signal energy {sig2}"
        );
        // And the lossless path is still exact under the pre-scale.
        for (i, (&r, &orig)) in qt.reconstruct_full().iter().zip(&w).enumerate() {
            let expect = speq::bsfp::f16_bits_to_f32(speq::bsfp::f32_to_f16_bits(
                orig * qt.tensor_scale,
            )) / qt.tensor_scale;
            assert!((r - expect).abs() <= expect.abs() * 1e-6 + 1e-9, "idx {i}");
        }
    });
}

#[test]
fn prop_native_greedy_spec_is_lossless() {
    // Greedy speculative decoding must be token-identical to the
    // autoregressive baseline on the NativeBackend for random models
    // (confident and diffuse), random prompts, and random (L, gamma).
    check(6, "native_greedy_lossless", |rng| {
        let cfg = ModelConfig {
            name: "prop-tiny".into(),
            paper_analog: "none".into(),
            n_layers: 1 + rng.gen_range(2),
            d_model: 128,
            d_ff: 128,
            n_heads: 4,
            head_dim: 32,
            vocab: 64,
            cache_len: 160,
            prefill_len: 64,
            param_count: 0,
        };
        let style = if rng.gen_bool(0.5) { InitStyle::Confident } else { InitStyle::Random };
        let slots = 9;
        let model =
            NativeBackend::synthetic(cfg, slots, rng.next_u64(), style).expect("synthetic");
        let engine = Engine::new(&model);
        let prompt: Vec<u8> =
            (0..rng.gen_between(4, 48)).map(|_| rng.gen_range(64) as u8).collect();
        let gen_len = rng.gen_between(1, 40);
        let cfg = SpecConfig {
            max_draft: rng.gen_between(1, slots), // in [1, slots-1]
            gamma: [0.0f32, 0.5, 0.9][rng.gen_range(3)],
            sampling: SamplingParams::greedy(),
            gen_len,
            ..Default::default()
        };
        let ar = engine.generate_ar(&prompt, gen_len, SamplingParams::greedy()).expect("ar");
        let spec = engine.generate_spec(&prompt, &cfg).expect("spec");
        assert_eq!(
            ar.tokens, spec.tokens,
            "lossless violation (style {style:?}, L {}, gamma {})",
            cfg.max_draft, cfg.gamma
        );
        assert_eq!(spec.trace.produced, spec.tokens.len());
    });
}

#[test]
fn prop_quantize_deterministic() {
    check(20, "quantize_deterministic", |rng| {
        let w = rng.normal_vec(GROUP_SIZE * 2 * 4, 0.1);
        let a = quantize_tensor(&w, GROUP_SIZE * 2, 4);
        let b = quantize_tensor(&w, GROUP_SIZE * 2, 4);
        assert_eq!(a.w_q, b.w_q);
        assert_eq!(a.w_r, b.w_r);
        assert_eq!(a.scales, b.scales);
    });
}

#[test]
fn prop_scales_positive_and_bounded() {
    // Eq. 4 scales must be positive and within the dequant bracket: the
    // draft magnitudes sit within a factor of ~4 of the true values, so
    // the MSE-optimal scale stays in a modest range.
    check(40, "scales_bounded", |rng| {
        let w = rng.normal_vec(GROUP_SIZE * 3, 0.3);
        let qt = quantize_tensor(&w, GROUP_SIZE, 3);
        for &s in &qt.scales {
            assert!(s > 0.0 && s < 8.0, "scale out of range: {s}");
        }
    });
}

#[test]
fn prop_pack_roundtrip() {
    check(40, "pack_roundtrip", |rng| {
        let k = 2 * rng.gen_between(1, 64);
        let n = rng.gen_between(1, 16);
        let w: Vec<u8> = (0..k * n).map(|_| (rng.gen_range(16)) as u8).collect();
        assert_eq!(unpack_nibbles(&pack_nibbles(&w, k, n), k, n), w);
    });
}

#[test]
fn prop_encode_decode_is_identity_under_prescale() {
    check(30, "encode_identity", |rng| {
        // Any f32 value scaled into (|v| < 2) range round-trips bit-exactly.
        for _ in 0..256 {
            let v = (rng.gen_f32() - 0.5) * 3.9;
            let bits = speq::bsfp::f32_to_f16_bits(v);
            let exp = (bits >> 10) & 0x1f;
            if exp > 15 {
                continue;
            }
            assert_eq!(decode_full_bits(encode_bits(bits)), bits);
        }
    });
}

#[test]
fn prop_fp4_variants_never_flip_sign() {
    check(20, "fp4_sign", |rng| {
        let w = rng.normal_vec(GROUP_SIZE * 2 * 2, 0.2);
        for variant in [Fp4Variant::E1M2, Fp4Variant::E2M1, Fp4Variant::E3M0] {
            let q = quantize_fp4(&w, GROUP_SIZE * 2, 2, variant);
            for (&orig, &qv) in w.iter().zip(&q) {
                assert!(
                    orig == 0.0 || qv == 0.0 || orig.signum() == qv.signum(),
                    "{variant:?} flipped sign: {orig} -> {qv}"
                );
            }
        }
    });
}

#[test]
fn prop_int_quant_bounded_by_range() {
    check(20, "int_bounded", |rng| {
        let w = rng.normal_vec(GROUP_SIZE * 2, 0.2);
        for m in [IntMethod::olive(4), IntMethod::olive(8), IntMethod::tender(4)] {
            let q = quantize_int(&w, GROUP_SIZE * 2, 1, m);
            let wmax = w.iter().fold(0f32, |a, &b| a.max(b.abs()));
            for &qv in &q {
                assert!(qv.abs() <= wmax * 4.5, "{} exceeded range: {qv}", m.name());
            }
        }
    });
}

#[test]
fn prop_trace_statistics_consistent() {
    check(40, "trace_stats", |rng| {
        let iters: Vec<IterRecord> = (0..rng.gen_between(1, 40))
            .map(|_| {
                let drafted = rng.gen_between(1, 17) as u32;
                IterRecord {
                    drafted,
                    accepted: rng.gen_range(drafted as usize + 1) as u32,
                    early_exit: rng.gen_bool(0.3),
                }
            })
            .collect();
        let produced =
            iters.iter().map(|i| i.accepted as usize + 1).sum::<usize>();
        let t = SpecTrace { iterations: iters, produced, prompt_len: 64 };
        assert!(t.accept_rate() >= 0.0 && t.accept_rate() <= 1.0);
        assert!(t.mean_accept_len() >= 1.0);
        assert!(t.mean_accept_len() <= 17.0 + 1e-9);
        assert!(t.mean_draft_len() >= 1.0 && t.mean_draft_len() <= 16.0);
        // produced tokens == sum(accepted + bonus).
        assert_eq!(t.produced, produced);
    });
}

#[test]
fn prop_eq1_bounds_hold() {
    check(40, "eq1_bounds", |rng| {
        let r = rng.gen_f64();
        let l = rng.gen_between(1, 21);
        let la = expected_accept_length(r, l);
        assert!(la >= 1.0 - 1e-12, "La < 1: {la}");
        assert!(la <= l as f64 + 1.0 + 1e-12, "La > L+1: {la}");
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    check(60, "json_roundtrip", |rng| {
        // Build a random JSON value, write, parse, compare.
        fn gen(rng: &mut Rng, depth: usize) -> json::Value {
            match if depth > 2 { rng.gen_range(4) } else { rng.gen_range(6) } {
                0 => json::Value::Null,
                1 => json::Value::Bool(rng.gen_bool(0.5)),
                2 => json::Value::Num((rng.gen_f64() * 2e6).round() / 64.0),
                3 => {
                    let n = rng.gen_range(12);
                    json::Value::Str(
                        (0..n).map(|_| "ab\"\\\nξ☃e "
                            .chars().nth(rng.gen_range(9)).unwrap()).collect(),
                    )
                }
                4 => json::Value::Arr((0..rng.gen_range(5)).map(|_| gen(rng, depth + 1)).collect()),
                _ => json::Value::Obj(
                    (0..rng.gen_range(5))
                        .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 0);
        let text = json::write(&v);
        let back = json::parse(&text).expect("reparse");
        assert_eq!(back, v, "roundtrip failed for {text}");
    });
}

#[test]
fn prop_accel_cycles_monotone_in_work() {
    use speq::accel::{Accel, ArrayMode};
    check(25, "accel_monotone", |rng| {
        let a = Accel::default();
        let k = 128 * rng.gen_between(1, 32);
        let n = 128 * rng.gen_between(1, 32);
        let c1 = a.gemm_cost(1, k, n, ArrayMode::Full, 2.0);
        let c2 = a.gemm_cost(1, 2 * k, n, ArrayMode::Full, 2.0);
        assert!(c2.cycles >= c1.cycles);
        assert!(c2.energy.total_pj() >= c1.energy.total_pj());
        let q = a.gemm_cost(1, k, n, ArrayMode::Quant, 0.625);
        assert!(q.cycles <= c1.cycles, "quant mode slower than full");
    });
}
