//! Integration: the speculative decoding engine over real artifacts.

use speq::model::{Manifest, ModelRuntime, SamplingParams};
use speq::runtime::Runtime;
use speq::specdec::{Engine, SpecConfig};

fn load_model(name: &str) -> Option<ModelRuntime> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let m = match Manifest::load(&root) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping integration test (no artifacts): {e}");
            return None;
        }
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    Some(ModelRuntime::load(&rt, &m, name).expect("model load"))
}

const PROMPT: &[u8] = b"Q: bob has 12 coins and wins 7 more. how many coins now?\nA: ";

#[test]
fn greedy_spec_decode_is_lossless() {
    // The paper's core claim: speculative output == the full model's output,
    // token for token.
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let engine = Engine::new(&model);
    let gen_len = 96;
    let ar = engine.generate_ar(PROMPT, gen_len, SamplingParams::greedy()).expect("ar");
    let cfg = SpecConfig { gen_len, ..Default::default() };
    let spec = engine.generate_spec(PROMPT, &cfg).expect("spec");
    assert_eq!(
        ar.tokens,
        spec.tokens,
        "lossless violation:\n ar={:?}\n spec={:?}",
        String::from_utf8_lossy(&ar.tokens),
        String::from_utf8_lossy(&spec.tokens)
    );
}

#[test]
fn accept_rate_is_high_for_bsfp_draft() {
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let engine = Engine::new(&model);
    let cfg = SpecConfig { gen_len: 128, ..Default::default() };
    let res = engine.generate_spec(PROMPT, &cfg).expect("spec");
    let r = res.trace.accept_rate();
    // Paper reports ~0.97 on real models; the tiny analogs should clear a
    // loose bar (the in-distribution prompt keeps entropy moderate).
    assert!(r > 0.6, "accept rate too low: {r}");
    assert!(res.trace.mean_accept_len() > 2.0, "mean accept {}", res.trace.mean_accept_len());
}

#[test]
fn spec_decode_reduces_full_model_passes() {
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let engine = Engine::new(&model);
    let cfg = SpecConfig { gen_len: 128, ..Default::default() };
    let res = engine.generate_spec(PROMPT, &cfg).expect("spec");
    // Verification passes should be far fewer than tokens produced — that
    // is the whole point of speculative decoding.
    assert!(
        (res.trace.verify_passes() as usize) * 2 < res.trace.produced,
        "verify passes {} vs produced {}",
        res.trace.verify_passes(),
        res.trace.produced
    );
}

#[test]
fn tight_gamma_causes_early_exits() {
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let engine = Engine::new(&model);
    let strict = SpecConfig { gen_len: 64, gamma: 0.99, ..Default::default() };
    let res = engine.generate_spec(PROMPT, &strict).expect("spec");
    let loose = SpecConfig { gen_len: 64, gamma: 0.0, ..Default::default() };
    let res_loose = engine.generate_spec(PROMPT, &loose).expect("spec");
    assert!(
        res.trace.mean_draft_len() <= res_loose.trace.mean_draft_len(),
        "strict gamma should shorten drafts: {} vs {}",
        res.trace.mean_draft_len(),
        res_loose.trace.mean_draft_len()
    );
    // gamma = 0 must never early-exit.
    assert_eq!(res_loose.trace.early_exit_rate(), 0.0);
}

#[test]
fn sampling_mode_generates_plausible_text() {
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let engine = Engine::new(&model);
    let cfg = SpecConfig {
        gen_len: 64,
        sampling: SamplingParams { temperature: 0.8, seed: 42 },
        ..Default::default()
    };
    let res = engine.generate_spec(PROMPT, &cfg).expect("spec");
    assert_eq!(res.tokens.len(), 64);
    let printable =
        res.tokens.iter().filter(|&&b| (32..127).contains(&b) || b == b'\n').count();
    assert!(printable > 48, "sampled text implausible: {:?}", res.tokens);
}

#[test]
fn lossless_across_models_and_prompts() {
    // Spot-check a second model and a code-style prompt.
    let Some(model) = load_model("llama3.2-3b-tiny") else { return };
    let engine = Engine::new(&model);
    let prompt: &[u8] = b"def add_3(x):\n    return ";
    let ar = engine.generate_ar(prompt, 64, SamplingParams::greedy()).expect("ar");
    let cfg = SpecConfig { gen_len: 64, ..Default::default() };
    let spec = engine.generate_spec(prompt, &cfg).expect("spec");
    assert_eq!(ar.tokens, spec.tokens);
}
