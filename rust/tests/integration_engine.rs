//! Integration: the speculative decoding engine over the native backend.
//!
//! Runs entirely on the builtin synthetic zoo — no artifacts, no PJRT —
//! and asserts the paper's core properties end to end: the full
//! draft → verify → accept loop executes, and greedy speculative decoding
//! is **bit-identical** to the autoregressive baseline.

use speq::model::SamplingParams;
use speq::runtime::{Backend, NativeBackend};
use speq::specdec::{Engine, SpecConfig};

fn load_model(name: &str) -> NativeBackend {
    NativeBackend::builtin(name).expect("builtin model")
}

const PROMPT: &[u8] = b"Q: bob has 12 coins and wins 7 more. how many coins now?\nA: ";

#[test]
fn greedy_spec_decode_is_lossless() {
    // The paper's core claim: speculative output == the full model's output,
    // token for token.
    let model = load_model("vicuna-7b-tiny");
    let engine = Engine::new(&model);
    let gen_len = 96;
    let ar = engine.generate_ar(PROMPT, gen_len, SamplingParams::greedy()).expect("ar");
    let cfg = SpecConfig { gen_len, ..Default::default() };
    let spec = engine.generate_spec(PROMPT, &cfg).expect("spec");
    assert_eq!(
        ar.tokens,
        spec.tokens,
        "lossless violation:\n ar={:?}\n spec={:?}",
        String::from_utf8_lossy(&ar.tokens),
        String::from_utf8_lossy(&spec.tokens)
    );
}

#[test]
fn draft_verify_accept_loop_is_exercised() {
    let model = load_model("vicuna-7b-tiny");
    let engine = Engine::new(&model);
    let cfg = SpecConfig { gen_len: 96, ..Default::default() };
    let res = engine.generate_spec(PROMPT, &cfg).expect("spec");
    assert_eq!(res.tokens.len(), 96);
    assert_eq!(res.trace.produced, res.tokens.len());
    assert!(res.trace.draft_steps() > 0, "no draft steps ran");
    assert!(res.trace.verify_passes() > 0, "no verification passes ran");
    let accepted: u64 = res.trace.iterations.iter().map(|i| i.accepted as u64).sum();
    assert!(accepted > 0, "verification never accepted a draft token");
    for it in &res.trace.iterations {
        assert!(it.accepted <= it.drafted, "accepted > drafted");
    }
}

#[test]
fn accept_rate_is_high_for_bsfp_draft() {
    let model = load_model("vicuna-7b-tiny");
    let engine = Engine::new(&model);
    let cfg = SpecConfig { gen_len: 128, ..Default::default() };
    let res = engine.generate_spec(PROMPT, &cfg).expect("spec");
    let r = res.trace.accept_rate();
    // Paper reports ~0.97 on real models; the confident builtin analogs
    // should clear a loose bar.
    assert!(r > 0.5, "accept rate too low: {r}");
    assert!(res.trace.mean_accept_len() > 2.0, "mean accept {}", res.trace.mean_accept_len());
}

#[test]
fn spec_decode_reduces_full_model_passes() {
    let model = load_model("vicuna-7b-tiny");
    let engine = Engine::new(&model);
    let cfg = SpecConfig { gen_len: 128, ..Default::default() };
    let res = engine.generate_spec(PROMPT, &cfg).expect("spec");
    // Verification passes should be far fewer than tokens produced — that
    // is the whole point of speculative decoding.
    assert!(
        (res.trace.verify_passes() as usize) * 2 < res.trace.produced,
        "verify passes {} vs produced {}",
        res.trace.verify_passes(),
        res.trace.produced
    );
}

#[test]
fn tight_gamma_causes_early_exits() {
    let model = load_model("vicuna-7b-tiny");
    let engine = Engine::new(&model);
    let strict = SpecConfig { gen_len: 64, gamma: 0.9999, ..Default::default() };
    let res = engine.generate_spec(PROMPT, &strict).expect("spec");
    let loose = SpecConfig { gen_len: 64, gamma: 0.0, ..Default::default() };
    let res_loose = engine.generate_spec(PROMPT, &loose).expect("spec");
    assert!(
        res.trace.mean_draft_len() <= res_loose.trace.mean_draft_len(),
        "strict gamma should shorten drafts: {} vs {}",
        res.trace.mean_draft_len(),
        res_loose.trace.mean_draft_len()
    );
    // gamma = 0 must never early-exit.
    assert_eq!(res_loose.trace.early_exit_rate(), 0.0);
}

#[test]
fn gamma_zero_drafts_run_to_full_length() {
    let model = load_model("vicuna-7b-tiny");
    let engine = Engine::new(&model);
    let cfg = SpecConfig { gen_len: 80, gamma: 0.0, max_draft: 8, ..Default::default() };
    let res = engine.generate_spec(PROMPT, &cfg).expect("spec");
    // gamma = 0 disables §III-C: no iteration may early-exit, and the
    // first iteration (budget not yet clamped by gen_len) drafts exactly
    // max_draft tokens.
    assert!(!res.trace.iterations.is_empty());
    for it in &res.trace.iterations {
        assert!(!it.early_exit, "gamma=0 must never early-exit");
        assert!(it.drafted >= 1);
    }
    assert_eq!(res.trace.iterations[0].drafted, 8);
}

#[test]
fn sampling_mode_produces_requested_length() {
    let model = load_model("vicuna-7b-tiny");
    let engine = Engine::new(&model);
    let cfg = SpecConfig {
        gen_len: 64,
        sampling: SamplingParams { temperature: 0.8, seed: 42 },
        ..Default::default()
    };
    let res = engine.generate_spec(PROMPT, &cfg).expect("spec");
    assert_eq!(res.tokens.len(), 64);
    assert_eq!(res.trace.produced, 64);
    assert!(res.tokens.iter().all(|&t| (t as usize) < model.vocab()));
    // Same seed -> same output (the engine is deterministic end to end).
    let again = engine.generate_spec(PROMPT, &cfg).expect("spec");
    assert_eq!(res.tokens, again.tokens);
}

#[test]
fn lossless_across_models_and_prompts() {
    // Spot-check a second model and a code-style prompt.
    let model = load_model("llama3.2-3b-tiny");
    let engine = Engine::new(&model);
    let prompt: &[u8] = b"def add_3(x):\n    return ";
    let ar = engine.generate_ar(prompt, 64, SamplingParams::greedy()).expect("ar");
    let cfg = SpecConfig { gen_len: 64, ..Default::default() };
    let spec = engine.generate_spec(prompt, &cfg).expect("spec");
    assert_eq!(ar.tokens, spec.tokens);
}

#[test]
fn lossless_on_a_deep_model() {
    // 4-layer config: deeper stacks accumulate more numerical state; the
    // bit-identity must still hold.
    let model = load_model("llama3.1-8b-tiny");
    let engine = Engine::new(&model);
    let ar = engine.generate_ar(PROMPT, 48, SamplingParams::greedy()).expect("ar");
    let cfg = SpecConfig { gen_len: 48, ..Default::default() };
    let spec = engine.generate_spec(PROMPT, &cfg).expect("spec");
    assert_eq!(ar.tokens, spec.tokens);
}
