//! Integration: PJRT runtime + compiled artifacts.
//!
//! These tests need `make artifacts` to have run; they skip (with a message)
//! when the artifacts directory is absent so `cargo test` stays green on a
//! fresh checkout.

use speq::model::{argmax, Manifest, ModelRuntime};
use speq::runtime::Runtime;

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&root) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping integration test (no artifacts): {e}");
            None
        }
    }
}

fn load_model(name: &str) -> Option<ModelRuntime> {
    let m = manifest()?;
    let rt = Runtime::cpu().expect("PJRT CPU client");
    Some(ModelRuntime::load(&rt, &m, name).expect("model load"))
}

/// A short, in-distribution prompt (math task style).
fn test_prompt(len: usize) -> Vec<i32> {
    let text = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";
    let mut toks: Vec<i32> = text.iter().map(|&b| b as i32).collect();
    toks.truncate(len);
    while toks.len() < len {
        toks.push(b' ' as i32);
    }
    toks
}

#[test]
fn prefill_produces_finite_logits() {
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let prompt = test_prompt(model.prefill_len());
    let out = model.prefill(&prompt, 63).expect("prefill");
    assert_eq!(out.logits.len(), model.vocab());
    assert!(out.logits.iter().all(|v| v.is_finite()), "non-finite logits");
}

#[test]
fn eval_graph_returns_full_position_logits() {
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let p = model.prefill_len();
    let prompt = test_prompt(p);
    let logits = model.eval_logits(&prompt, 63).expect("eval");
    assert_eq!(logits.len(), p * model.vocab());
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn decode_full_continues_the_prompt_plausibly() {
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let plen = 63usize;
    let prompt = test_prompt(model.prefill_len());
    let out = model.prefill(&prompt, plen).expect("prefill");
    let mut tok = argmax(&out.logits) as i32;
    let mut state = out.state;
    let mut generated = Vec::new();
    for i in 0..16 {
        let step = model.decode_full(tok, plen + i, &state).expect("decode");
        state = step.state;
        tok = argmax(&step.logits) as i32;
        assert!((tok as usize) < model.vocab());
        generated.push(tok as u8);
    }
    // The model was trained to near-zero loss on this grammar: continuations
    // should be printable ASCII, not random bytes.
    let printable =
        generated.iter().filter(|&&b| (32..127).contains(&b) || b == b'\n').count();
    assert!(printable >= 12, "implausible continuation: {generated:?}");
}

#[test]
fn draft_graph_tracks_full_graph() {
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let plen = 63usize;
    let prompt = test_prompt(model.prefill_len());
    let out_full = model.prefill(&prompt, plen).expect("prefill");
    let out_draft = model.prefill(&prompt, plen).expect("prefill");
    let tok0 = argmax(&out_full.logits) as i32;

    // Run 24 greedy steps with the full graph and the draft graph from the
    // same starting state; the BSFP draft should agree on most tokens
    // (paper: accept rate ~0.97). Draft re-syncs to full on divergence,
    // as verification does.
    let (mut agree, mut total) = (0, 0);
    let (mut state_full, mut state_draft) = (out_full.state, out_draft.state);
    let (mut tok_full, mut tok_draft) = (tok0, tok0);
    for i in 0..24 {
        let sf = model.decode_full(tok_full, plen + i, &state_full).expect("full");
        let sd = model.decode_draft(tok_draft, plen + i, &state_draft).expect("draft");
        state_full = sf.state;
        state_draft = sd.state;
        tok_full = argmax(&sf.logits) as i32;
        tok_draft = argmax(&sd.logits) as i32;
        if tok_full == tok_draft {
            agree += 1;
        } else {
            tok_draft = tok_full;
        }
        total += 1;
    }
    assert!(agree * 2 >= total, "draft agreed only {agree}/{total} steps");
}

#[test]
fn verify_graph_matches_sequential_full_decode() {
    // The single-pass verification must produce the same greedy tokens as
    // running the full decode graph sequentially over the same tokens.
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let plen = 63usize;
    let s = model.slots();
    let prompt = test_prompt(model.prefill_len());
    let pre = model.prefill(&prompt, plen).expect("prefill");
    let tok0 = argmax(&pre.logits) as i32;

    // Sequential: decode s tokens one by one.
    let mut seq_tokens = vec![tok0];
    let mut state = model.prefill(&prompt, plen).expect("prefill").state;
    let mut tok = tok0;
    let mut seq_logits = Vec::new();
    for i in 0..s {
        let step = model.decode_full(tok, plen + i, &state).expect("decode");
        state = step.state;
        tok = argmax(&step.logits) as i32;
        seq_logits.push(step.logits);
        if i + 1 < s {
            seq_tokens.push(tok);
        }
    }

    // Parallel: verify the same s tokens in one pass.
    let ver = model.verify(&seq_tokens, plen, &pre.state).expect("verify");
    let v = model.vocab();
    for i in 0..s {
        let row = &ver.logits[i * v..(i + 1) * v];
        let a = argmax(row);
        let b = argmax(&seq_logits[i]);
        assert_eq!(a, b, "verify row {i} argmax diverges from sequential decode");
    }
}

#[test]
fn identity_transform_reproduces_baseline_logits() {
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let prompt = test_prompt(model.prefill_len());
    let base = model.eval_logits(&prompt, 48).expect("eval");
    let bufs =
        model.build_transformed_params(|_, w, _, _| Ok(w.to_vec())).expect("transform");
    let again = model.eval_logits_with(&bufs, &prompt, 48).expect("eval_with");
    assert_eq!(base, again, "identity transform changed logits");
}

#[test]
fn bsfp_transform_matches_draft_graph() {
    // Dequantized-BSFP weights through the *full* graph must match the
    // packed-W_q draft graph (same math, two routes).
    let Some(model) = load_model("vicuna-7b-tiny") else { return };
    let plen = 63usize;
    let prompt = test_prompt(model.prefill_len());
    let pre = model.prefill(&prompt, plen).expect("prefill");
    let tok0 = argmax(&pre.logits) as i32;

    let bufs = model
        .build_transformed_params(|_, w, k, n| {
            let qt = speq::bsfp::quantize_tensor(w, k, n);
            // dequant_draft applies qt.scales (scaled domain); undo the
            // Algorithm-1 tensor scale to reach the original domain.
            let mut out = qt.dequant_draft();
            for o in out.iter_mut() {
                *o /= qt.tensor_scale;
            }
            Ok(out)
        })
        .expect("bsfp transform");

    let mut state_a = model.prefill(&prompt, plen).expect("prefill").state;
    let mut state_b = pre.state;
    let (mut tok_a, mut tok_b) = (tok0, tok0);
    for i in 0..8 {
        let sa = model.decode_full_with(&bufs, tok_a, plen + i, &state_a).expect("a");
        let sb = model.decode_draft(tok_b, plen + i, &state_b).expect("b");
        state_a = sa.state;
        state_b = sb.state;
        tok_a = argmax(&sa.logits) as i32;
        tok_b = argmax(&sb.logits) as i32;
        assert_eq!(tok_a, tok_b, "step {i}: dequant route diverged from draft graph");
    }
}
