//! Integration: the execution-backend contract on the native interpreter.
//!
//! These tests need no artifacts and no PJRT: they run the builtin
//! synthetic zoo on `NativeBackend` and check the graph-level invariants
//! the speculative engine relies on (verify == sequential decode, draft ==
//! dequantized-weights route, transform hooks).  When an artifacts
//! directory is present, an extra test loads the trained weights through
//! the same backend.

use speq::model::{argmax, Manifest};
use speq::runtime::{Backend, InitStyle, NativeBackend};

fn backend(name: &str) -> NativeBackend {
    NativeBackend::builtin(name).expect("builtin model")
}

/// A short, in-distribution prompt (math task style), padded to `len`.
fn test_prompt(len: usize) -> Vec<i32> {
    let text = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";
    let mut toks: Vec<i32> = text.iter().map(|&b| b as i32).collect();
    toks.truncate(len);
    while toks.len() < len {
        toks.push(b' ' as i32);
    }
    toks
}

#[test]
fn prefill_produces_finite_logits() {
    let model = backend("vicuna-7b-tiny");
    let prompt = test_prompt(model.prefill_len());
    let out = model.prefill(&prompt, 63).expect("prefill");
    assert_eq!(out.logits.len(), model.vocab());
    assert!(out.logits.iter().all(|v| v.is_finite()), "non-finite logits");
}

#[test]
fn eval_returns_full_position_logits() {
    let model = backend("vicuna-7b-tiny");
    let p = model.prefill_len();
    let prompt = test_prompt(p);
    let logits = model.eval_logits(&prompt, 63).expect("eval");
    assert_eq!(logits.len(), p * model.vocab());
    assert!(logits.iter().all(|v| v.is_finite()));
    // Row 0 of eval must match prefill at length 1 (same math, two entries).
    let pre = model.prefill(&prompt, 1).expect("prefill");
    assert_eq!(&logits[..model.vocab()], &pre.logits[..], "eval row 0 != prefill(len=1)");
}

#[test]
fn decode_full_is_deterministic_and_in_vocab() {
    let model = backend("vicuna-7b-tiny");
    let plen = 63usize;
    let prompt = test_prompt(model.prefill_len());
    let run = || {
        let out = model.prefill(&prompt, plen).expect("prefill");
        let mut tok = argmax(&out.logits) as i32;
        let mut state = out.state;
        let mut generated = Vec::new();
        for i in 0..16 {
            let step = model.decode_full(tok, plen + i, state).expect("decode");
            state = step.state;
            tok = argmax(&step.logits) as i32;
            assert!((tok as usize) < model.vocab());
            generated.push(tok as u8);
        }
        generated
    };
    assert_eq!(run(), run(), "decode must be deterministic");
}

#[test]
fn draft_pass_tracks_full_pass() {
    let model = backend("vicuna-7b-tiny");
    let plen = 63usize;
    let prompt = test_prompt(model.prefill_len());
    let out_full = model.prefill(&prompt, plen).expect("prefill");
    let out_draft = model.prefill(&prompt, plen).expect("prefill");
    let tok0 = argmax(&out_full.logits) as i32;

    // Run 24 greedy steps with the full pass and the draft pass from the
    // same starting state; the BSFP draft should agree on most tokens
    // (paper: accept rate ~0.97). Draft re-syncs to full on divergence,
    // as verification does.
    let (mut agree, mut total) = (0, 0);
    let (mut state_full, mut state_draft) = (out_full.state, out_draft.state);
    let (mut tok_full, mut tok_draft) = (tok0, tok0);
    for i in 0..24 {
        let sf = model.decode_full(tok_full, plen + i, state_full).expect("full");
        let sd = model.decode_draft(tok_draft, plen + i, state_draft).expect("draft");
        state_full = sf.state;
        state_draft = sd.state;
        tok_full = argmax(&sf.logits) as i32;
        tok_draft = argmax(&sd.logits) as i32;
        if tok_full == tok_draft {
            agree += 1;
        } else {
            tok_draft = tok_full;
        }
        total += 1;
    }
    assert!(agree * 2 >= total, "draft agreed only {agree}/{total} steps");
}

#[test]
fn verify_matches_sequential_full_decode_bitwise() {
    // The single-pass verification must produce the same logits as running
    // the full decode sequentially over the same tokens — on the native
    // backend this is exact (identical code path), which is what makes
    // greedy speculative decoding lossless.
    let model = backend("vicuna-7b-tiny");
    let plen = 63usize;
    let s = model.slots();
    let prompt = test_prompt(model.prefill_len());
    let pre = model.prefill(&prompt, plen).expect("prefill");
    let tok0 = argmax(&pre.logits) as i32;

    // Sequential: decode s tokens one by one.
    let mut seq_tokens = vec![tok0];
    let mut state = model.prefill(&prompt, plen).expect("prefill").state;
    let mut tok = tok0;
    let mut seq_logits = Vec::new();
    for i in 0..s {
        let step = model.decode_full(tok, plen + i, state).expect("decode");
        state = step.state;
        tok = argmax(&step.logits) as i32;
        seq_logits.push(step.logits);
        if i + 1 < s {
            seq_tokens.push(tok);
        }
    }

    // Parallel: verify the same s tokens in one pass.
    let ver = model.verify(&seq_tokens, plen, pre.state).expect("verify");
    let v = model.vocab();
    for i in 0..s {
        let row = &ver.logits[i * v..(i + 1) * v];
        assert_eq!(row, &seq_logits[i][..], "verify row {i} diverges from sequential decode");
    }
}

#[test]
fn identity_transform_reproduces_baseline_logits() {
    let model = backend("vicuna-7b-tiny");
    let prompt = test_prompt(model.prefill_len());
    let base = model.eval_logits(&prompt, 48).expect("eval");
    let variant = model
        .with_transformed_weights(&mut |_, w, _, _| Ok(w.to_vec()))
        .expect("transform");
    let again = variant.eval_logits(&prompt, 48).expect("eval_with");
    assert_eq!(base, again, "identity transform changed logits");
}

#[test]
fn bsfp_transform_matches_draft_pass() {
    // Dequantized-BSFP weights through the *full* pass must match the
    // draft pass (same math, two routes).
    let model = backend("vicuna-7b-tiny");
    let plen = 63usize;
    let prompt = test_prompt(model.prefill_len());
    let pre = model.prefill(&prompt, plen).expect("prefill");
    let tok0 = argmax(&pre.logits) as i32;

    let variant = model
        .with_transformed_weights(&mut |_, w, k, n| {
            let qt = speq::bsfp::quantize_tensor(w, k, n);
            // dequant_draft applies qt.scales (scaled domain); undo the
            // Algorithm-1 tensor scale to reach the original domain.
            let mut out = qt.dequant_draft();
            for o in out.iter_mut() {
                *o /= qt.tensor_scale;
            }
            Ok(out)
        })
        .expect("bsfp transform");

    let mut state_a = variant.prefill(&prompt, plen).expect("prefill").state;
    let mut state_b = pre.state;
    let (mut tok_a, mut tok_b) = (tok0, tok0);
    for i in 0..8 {
        let sa = variant.decode_full(tok_a, plen + i, state_a).expect("a");
        let sb = model.decode_draft(tok_b, plen + i, state_b).expect("b");
        state_a = sa.state;
        state_b = sb.state;
        tok_a = argmax(&sa.logits) as i32;
        tok_b = argmax(&sb.logits) as i32;
        assert_eq!(tok_a, tok_b, "step {i}: dequant route diverged from draft pass");
    }
}

#[test]
fn random_init_backend_still_honors_the_contract() {
    // Even a diffuse (untrained-style) model keeps the structural
    // invariants: finite logits, verify == sequential.
    let mut cfg = speq::runtime::builtin_config("vicuna-7b-tiny").unwrap();
    cfg.name = "random-tiny".into();
    let model = NativeBackend::synthetic(cfg, 9, 123, InitStyle::Random).expect("synthetic");
    let prompt = test_prompt(model.prefill_len());
    let pre = model.prefill(&prompt, 32).expect("prefill");
    assert!(pre.logits.iter().all(|v| v.is_finite()));
    let vtokens: Vec<i32> = (0..9).collect();
    let ver = model.verify(&vtokens, 32, pre.state).expect("verify");
    let mut state = model.prefill(&prompt, 32).expect("prefill").state;
    let v = model.vocab();
    for (i, &t) in vtokens.iter().enumerate() {
        let step = model.decode_full(t, 32 + i, state).expect("decode");
        state = step.state;
        assert_eq!(&ver.logits[i * v..(i + 1) * v], &step.logits[..], "row {i}");
    }
}

#[test]
fn trained_artifacts_load_on_the_native_backend() {
    // Artifact-gated: when trained weights exist, the native backend runs
    // them without any HLO or XLA library.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let m = match Manifest::load(&root) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping artifacts test (no artifacts): {e}");
            return;
        }
    };
    let model = NativeBackend::from_manifest(&m, "vicuna-7b-tiny").expect("load");
    let prompt = test_prompt(model.prefill_len());
    let out = model.prefill(&prompt, 63).expect("prefill");
    assert_eq!(out.logits.len(), model.vocab());
    assert!(out.logits.iter().all(|v| v.is_finite()));
}
