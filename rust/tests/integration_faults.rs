//! Integration: fault injection, blast-radius isolation, and graceful
//! degradation over the builtin native backend — no artifacts.
//!
//! Every test holds `faults::test_guard()` for its whole body: the fault
//! plan and its counters are process-global, so tests in this binary must
//! serialize and start from a clean (disarmed) registry.

use std::time::Duration;

use speq::coordinator::{ResponseEvent, Server, ServerConfig, SubmitParams};
use speq::faults::{self, FailureKind, FaultAction, FaultPlan, FaultSite};
use speq::model::SamplingParams;
use speq::runtime::{load_backend_with, Backend, ModelSource, NativeConfig};
use speq::specdec::{
    AdaptiveConfig, ArSession, BatchEngine, Engine, GenSession, SpecConfig, SpecSession,
};

fn backend() -> Box<dyn Backend> {
    load_backend_with(&ModelSource::Builtin, "vicuna-7b-tiny", &NativeConfig::default())
        .expect("builtin backend")
}

fn server(workers: usize) -> Server {
    let cfg = ServerConfig {
        source: ModelSource::Builtin,
        model: "vicuna-7b-tiny".into(),
        workers,
        queue_capacity: 32,
        ..ServerConfig::default()
    };
    Server::start(cfg).expect("server start")
}

fn spec_session(backend: &dyn Backend, prompt: &[u8], gen_len: usize) -> GenSession {
    GenSession::Spec(
        SpecSession::new(
            backend,
            prompt,
            SpecConfig {
                max_draft: 16,
                gamma: 0.6,
                sampling: SamplingParams::greedy(),
                gen_len,
                adaptive: AdaptiveConfig::default(),
            },
        )
        .expect("spec session"),
    )
}

fn ar_session(backend: &dyn Backend, prompt: &[u8], gen_len: usize) -> GenSession {
    GenSession::Ar(
        ArSession::new(backend, prompt, gen_len, SamplingParams::greedy()).expect("ar session"),
    )
}

/// Outcome of driving a batch to quiescence with `step_report`:
/// per-session `Ok(tokens)` or the `(kind, detail)` that quarantined it.
type BatchOutcome = Vec<Result<Vec<u8>, (FailureKind, String)>>;

/// Step the batch like the scheduler does — failed sessions are released
/// and excluded from later steps; everyone else runs to completion.
fn run_batch(backend: &dyn Backend, mut sessions: Vec<GenSession>, max_steps: usize) -> BatchOutcome {
    let engine = BatchEngine::new(backend);
    let mut failure: Vec<Option<(FailureKind, String)>> = vec![None; sessions.len()];
    for _ in 0..max_steps {
        let mut live_map = Vec::new();
        let mut refs: Vec<&mut GenSession> = Vec::new();
        for (i, s) in sessions.iter_mut().enumerate() {
            if failure[i].is_none() && !s.is_done() {
                live_map.push(i);
                refs.push(s);
            }
        }
        if refs.is_empty() {
            break;
        }
        let report = engine.step_report(&mut refs);
        for f in report.failures {
            let gi = live_map[f.session];
            failure[gi] = Some((f.kind, f.detail));
        }
        for (i, s) in sessions.iter_mut().enumerate() {
            if failure[i].is_some() {
                s.release(backend);
            }
        }
    }
    sessions
        .into_iter()
        .zip(failure)
        .map(|(s, f)| match f {
            Some(fk) => Err(fk),
            None => {
                assert!(s.is_done(), "session neither failed nor finished in the step budget");
                Ok(s.into_result().tokens)
            }
        })
        .collect()
}

const PROMPTS: [&[u8]; 4] = [
    b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ",
    b"def add_two(x):\n    return ",
    b"USER: hello, can we talk about music?\nBOT: ",
    b"Q: bob has 9 coins and spends 2. how many coins left?\nA: ",
];

/// The acceptance scenario: a seeded plan injects a step failure into a
/// 4-sequence batch; exactly the sessions in the failing op get typed
/// errors, and the others complete bit-identically to a fault-free run.
#[test]
fn step_failure_quarantines_only_the_faulted_op_sessions() {
    let _g = faults::test_guard();

    // Fault-free reference run: 2 speculative + 2 autoregressive.
    let clean = {
        let b = backend();
        let sessions = vec![
            spec_session(b.as_ref(), PROMPTS[0], 24),
            spec_session(b.as_ref(), PROMPTS[1], 24),
            ar_session(b.as_ref(), PROMPTS[2], 24),
            ar_session(b.as_ref(), PROMPTS[3], 24),
        ];
        run_batch(b.as_ref(), sessions, 256)
    };
    for r in &clean {
        assert!(r.is_ok(), "fault-free run must not fail: {r:?}");
    }

    // Same batch with the first draft op failing: the draft op carries
    // exactly the two speculative sessions.
    faults::install(FaultPlan::seeded(3).on_nth(FaultSite::StepDraft, 1, FaultAction::Error));
    let b = backend();
    let sessions = vec![
        spec_session(b.as_ref(), PROMPTS[0], 24),
        spec_session(b.as_ref(), PROMPTS[1], 24),
        ar_session(b.as_ref(), PROMPTS[2], 24),
        ar_session(b.as_ref(), PROMPTS[3], 24),
    ];
    let faulted = run_batch(b.as_ref(), sessions, 256);

    for i in [0usize, 1] {
        match &faulted[i] {
            Err((kind, detail)) => {
                assert_eq!(*kind, FailureKind::StepError, "session {i}");
                assert!(detail.contains("injected fault at step.draft"), "{detail}");
            }
            Ok(_) => panic!("spec session {i} was in the failing draft op and must fail"),
        }
    }
    for i in [2usize, 3] {
        let survivor = faulted[i].as_ref().expect("AR session was not in the failing op");
        assert_eq!(
            survivor,
            clean[i].as_ref().unwrap(),
            "survivor {i} must stream bit-identical tokens to the fault-free run"
        );
    }
    assert!(faults::injected_total() >= 1);
}

/// An injected worker-shard panic surfaces as a typed `WorkerPanic` on the
/// sessions in the panicking op, and the backend (worker pool included)
/// keeps serving afterwards.
#[test]
fn worker_panic_is_contained_and_backend_survives() {
    let _g = faults::test_guard();
    faults::install(FaultPlan::seeded(5).on_nth(FaultSite::WorkerShard, 1, FaultAction::Panic));

    let b = backend();
    // The first batched decode through the backend is the spec session's
    // draft sub-step, so the panic lands there; the AR session's decode
    // burst comes later in the step and must survive.
    let sessions = vec![
        spec_session(b.as_ref(), PROMPTS[0], 16),
        ar_session(b.as_ref(), PROMPTS[2], 16),
    ];
    let out = run_batch(b.as_ref(), sessions, 256);
    let (kind, detail) = out[0].as_ref().expect_err("spec session must be quarantined");
    assert_eq!(*kind, FailureKind::WorkerPanic);
    assert!(detail.contains("panic in engine step"), "{detail}");
    let ar_tokens = out[1].as_ref().expect("AR session must survive the contained panic");
    assert_eq!(ar_tokens.len(), 16);

    // Pool plumbing survived: a fresh session on the same backend runs
    // clean end to end (the plan's single shot is spent).
    let again = run_batch(b.as_ref(), vec![spec_session(b.as_ref(), PROMPTS[1], 16)], 256);
    assert_eq!(again[0].as_ref().unwrap().len(), 16);
}

/// KV page exhaustion mid-decode fails only the page-hungry sequence with
/// a typed `PageExhausted`, frees every page it retained, and leaves the
/// allocator + prefix tree consistent (full eviction drains to zero).
#[test]
fn page_exhaustion_mid_decode_fails_alone_and_frees_pages() {
    let _g = faults::test_guard();
    let b = backend();
    let engine = BatchEngine::new(b.as_ref());

    // One long speculative generation (must allocate pages beyond its
    // prompt) and one short AR generation that fits its prefill slack.
    let mut spec = spec_session(b.as_ref(), PROMPTS[0], 64);
    let mut ar = ar_session(b.as_ref(), PROMPTS[3], 8);

    // Step once so both prefills land, then clamp the budget to exactly
    // the pages now in use: the next allocation anyone needs must fail.
    {
        let mut refs: Vec<&mut GenSession> = vec![&mut spec, &mut ar];
        let report = engine.step_report(&mut refs);
        assert!(report.failures.is_empty(), "no faults armed yet: {:?}", report.failures);
    }
    let in_use = b.kv_stats().pages_in_use;
    assert!(in_use > 0);
    b.set_kv_page_budget(Some(in_use));

    let mut spec_failure = None;
    for _ in 0..64 {
        if spec_failure.is_some() || spec.is_done() {
            break;
        }
        let mut refs: Vec<&mut GenSession> = Vec::new();
        let mut map = Vec::new();
        if !spec.is_done() {
            map.push("spec");
            refs.push(&mut spec);
        }
        if !ar.is_done() {
            map.push("ar");
            refs.push(&mut ar);
        }
        if refs.is_empty() {
            break;
        }
        let report = engine.step_report(&mut refs);
        for f in report.failures {
            assert_eq!(map[f.session], "spec", "only the growing sequence may exhaust");
            assert_eq!(f.kind, FailureKind::PageExhausted);
            assert!(f.detail.contains("kv page budget exhausted"), "{}", f.detail);
            spec_failure = Some(f);
        }
    }
    let spec_failure = spec_failure.expect("64-token generation must outgrow a zero-slack budget");
    assert_eq!(spec_failure.kind, FailureKind::PageExhausted);
    assert!(ar.is_done(), "the short AR sequence must finish untouched");

    // Quarantine-release the failed sequence: its pages must come back.
    let held_before_release = b.kv_stats().pages_in_use;
    spec.release(b.as_ref());
    assert!(
        b.kv_stats().pages_in_use < held_before_release,
        "releasing the quarantined sequence must free its pages"
    );

    // Recovery: with the budget lifted, a fresh identical generation runs
    // to completion on the same backend.
    b.set_kv_page_budget(None);
    let redo = run_batch(b.as_ref(), vec![spec_session(b.as_ref(), PROMPTS[0], 64)], 256);
    assert_eq!(redo[0].as_ref().unwrap().len(), 64);

    // Leak check: all that remains is the prefix cache, and evicting it
    // drains the allocator to zero — refcounts were consistent throughout.
    b.relieve_kv_pressure(usize::MAX);
    assert_eq!(b.kv_stats().pages_in_use, 0, "pages leaked past release + full eviction");
}

/// Chaos property: under a randomized plan mixing step errors, panics,
/// and page exhaustion, every surviving request's token stream is bitwise
/// identical to the fault-free reference, and the server drains cleanly.
#[test]
fn chaos_survivors_stream_bit_identical_tokens() {
    let _g = faults::test_guard();

    // Fault-free reference streams from the offline engine (the serving
    // determinism contract: HTTP/scheduler transport never changes bits).
    let expected: Vec<Vec<u8>> = {
        let b = backend();
        let engine = Engine::new(b.as_ref());
        PROMPTS
            .iter()
            .map(|p| {
                engine
                    .generate_spec(
                        p,
                        &SpecConfig {
                            max_draft: 16,
                            gamma: 0.6,
                            sampling: SamplingParams::greedy(),
                            gen_len: 32,
                            adaptive: AdaptiveConfig::default(),
                        },
                    )
                    .expect("reference generation")
                    .tokens
            })
            .collect()
    };

    for seed in [11u64, 29, 47] {
        faults::install(
            FaultPlan::seeded(seed)
                .with_prob(FaultSite::StepDraft, 0.05, FaultAction::Error)
                .with_prob(FaultSite::StepVerify, 0.04, FaultAction::Panic)
                .with_prob(FaultSite::StepDecode, 0.04, FaultAction::Error)
                .with_prob(FaultSite::PageAlloc, 0.02, FaultAction::Exhaust),
        );
        let server = server(1);
        let mut streams = Vec::new();
        for p in PROMPTS.iter() {
            let (_, stream) = server
                .submit(p, SubmitParams { gen_len: 32, ..Default::default() })
                .expect("submit");
            streams.push(stream);
        }
        let mut survivors = 0;
        let mut failed = 0;
        for (i, stream) in streams.into_iter().enumerate() {
            let mut tokens = Vec::new();
            loop {
                match stream.recv().expect("terminal event").event {
                    ResponseEvent::Chunk(c) => tokens.extend(c),
                    ResponseEvent::Done(Ok(body)) => {
                        assert_eq!(tokens, body.tokens, "chunks must reassemble the body");
                        assert_eq!(
                            tokens, expected[i],
                            "survivor {i} diverged from the fault-free stream (seed {seed})"
                        );
                        survivors += 1;
                        break;
                    }
                    ResponseEvent::Done(Err(e)) => {
                        assert!(!e.to_string().is_empty());
                        failed += 1;
                        break;
                    }
                    ResponseEvent::Cancelled(k) => panic!("nothing cancels here: {k}"),
                }
            }
        }
        assert!(
            server.drain(Duration::from_secs(120)),
            "server must drain after the storm (seed {seed})"
        );
        let snap = server.metrics().snapshot();
        assert_eq!(snap.submitted, 4);
        assert_eq!(snap.completed, survivors as u64);
        assert_eq!(snap.failed, failed as u64);
        assert_eq!(
            snap.submitted,
            snap.completed + snap.failed + snap.cancelled + snap.rejected,
            "terminal accounting must balance (seed {seed})"
        );
        server.shutdown();
        faults::clear();
    }
}

/// Regression (admit/cancel race): a request cancelled *while being
/// admitted* must be retired with `Cancelled` before entering the batch —
/// it must never stream a token.  The `sched.admit` stall widens the
/// window deterministically.
#[test]
fn cancel_during_admission_never_streams_tokens() {
    let _g = faults::test_guard();
    let server = server(1);

    // Warm up so the scheduler is loaded and idle (model cold-start must
    // not eat the stall window).
    server.generate(PROMPTS[1], 8).expect("warmup");

    faults::install(FaultPlan::seeded(0).on_nth(FaultSite::SchedAdmit, 1, FaultAction::Stall(250)));
    let (_, stream) = server
        .submit(PROMPTS[0], SubmitParams { gen_len: 16, ..Default::default() })
        .expect("submit");
    let cancel = stream.cancel_token();
    // Land the cancel inside the admission stall: after the entry check,
    // before the session enters the active batch.
    std::thread::sleep(Duration::from_millis(60));
    cancel.cancel();

    let mut saw_chunk = false;
    loop {
        match stream.recv().expect("terminal event").event {
            ResponseEvent::Chunk(_) => saw_chunk = true,
            ResponseEvent::Cancelled(_) => break,
            ResponseEvent::Done(r) => {
                panic!("expected cancellation, got Done ({:?} tokens)", r.map(|b| b.tokens.len()))
            }
        }
    }
    assert!(!saw_chunk, "a cancelled admission must never stream tokens");
    let snap = server.metrics().snapshot();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.completed, 1, "only the warmup completed");
    server.shutdown();
}

/// Drain settles under a cancel storm and the terminal accounting
/// balances: every submitted request reaches exactly one terminal event.
#[test]
fn drain_settles_under_cancel_storm() {
    let _g = faults::test_guard();
    let server = server(2);
    let mut streams = Vec::new();
    for i in 0..8 {
        let (_, stream) = server
            .submit(PROMPTS[i % PROMPTS.len()], SubmitParams { gen_len: 24, ..Default::default() })
            .expect("submit");
        if i % 2 == 1 {
            stream.cancel_token().cancel();
        }
        streams.push(stream);
    }
    assert!(server.drain(Duration::from_secs(120)), "drain must settle");
    for stream in streams {
        let mut terminals = 0;
        loop {
            match stream.recv() {
                Ok(r) => match r.event {
                    ResponseEvent::Chunk(_) => {}
                    ResponseEvent::Done(_) | ResponseEvent::Cancelled(_) => terminals += 1,
                },
                Err(_) => break, // channel closed after the terminal event
            }
        }
        assert_eq!(terminals, 1, "exactly one terminal event per request");
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.submitted, 8);
    assert_eq!(
        snap.submitted,
        snap.completed + snap.failed + snap.cancelled + snap.rejected,
        "terminal accounting must balance after drain"
    );
    assert!(snap.cancelled >= 1, "the storm must cancel something");
    assert!(snap.completed >= 1, "unstormed requests must complete");
    server.shutdown();
}

/// The step watchdog converts an injected stall into a typed
/// `step_timeout` failure and the server keeps serving afterwards.
#[test]
fn watchdog_fails_a_stuck_step_and_recovers() {
    let _g = faults::test_guard();
    faults::install(FaultPlan::seeded(0).on_nth(FaultSite::StepVerify, 1, FaultAction::Stall(800)));
    let cfg = ServerConfig {
        source: ModelSource::Builtin,
        model: "vicuna-7b-tiny".into(),
        workers: 1,
        queue_capacity: 32,
        // Wide enough that honest debug-build steps never trip it; the
        // 800ms injected stall overshoots it by 4x.
        step_deadline: Duration::from_millis(200),
        ..ServerConfig::default()
    };
    let server = Server::start(cfg).expect("server start");
    let err = server
        .generate(PROMPTS[0], 24)
        .expect_err("the stalled step must fail the batch via the watchdog");
    assert!(err.to_string().contains("step_timeout"), "{err:#}");

    // The scheduler survived the verdict: the next request completes.
    let body = server.generate(PROMPTS[1], 12).expect("post-timeout request");
    assert_eq!(body.tokens.len(), 12);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.failed, 1);
    assert_eq!(snap.completed, 1);
    assert!(snap.faults_recovered >= 1, "containment must count as recovery");
    server.shutdown();
}
