//! Cross-layer golden tests: the Rust BSFP codec must agree bit-for-bit
//! with the Python reference that produced the artifacts.

use speq::bsfp::{encode_bits, eq4_scales, f16_bits_to_f32, f32_to_f16_bits, quantize_tensor};
use speq::model::Manifest;
use speq::util::json;

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&root) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping goldens test (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn exhaustive_encode_matches_python_goldens() {
    // goldens.bin: for all 32768 valid patterns (exp <= 15), ordered by
    // bits ascending: [32768 x u8 W_q][32768 x u16 W_r (LE)].
    let Some(m) = manifest() else { return };
    let raw = std::fs::read(m.path(&m.goldens_bin)).expect("goldens.bin");
    assert_eq!(raw.len(), 32768 + 2 * 32768);
    let (wq_bytes, wr_bytes) = raw.split_at(32768);
    let mut idx = 0usize;
    for s in 0..2u16 {
        for e in 0..16u16 {
            for man in 0..1024u16 {
                let bits = (s << 15) | (e << 10) | man;
                let c = encode_bits(bits);
                let golden_wq = wq_bytes[idx];
                let golden_wr =
                    u16::from_le_bytes([wr_bytes[2 * idx], wr_bytes[2 * idx + 1]]);
                assert_eq!(c.w_q, golden_wq, "W_q mismatch at bits {bits:#06x}");
                assert_eq!(c.w_r, golden_wr, "W_r mismatch at bits {bits:#06x}");
                idx += 1;
            }
        }
    }
    assert_eq!(idx, 32768);
}

#[test]
fn qmatmul_golden_vector_matches() {
    // goldens.json carries an end-to-end qmatmul vector: FP16 weight bits,
    // the Python-computed packed W_q + Eq.4 scales, and the expected y.
    let Some(m) = manifest() else { return };
    let text = std::fs::read_to_string(m.path(&m.goldens_json)).expect("goldens.json");
    let v = json::parse(&text).expect("parse goldens.json");
    let q = v.get("qmatmul").expect("qmatmul golden");
    let k = q.get("k").unwrap().as_usize().unwrap();
    let n = q.get("n").unwrap().as_usize().unwrap();
    let w_bits: Vec<u16> = q
        .get("w_f16_bits").unwrap().as_arr().unwrap()
        .iter().map(|x| x.as_f64().unwrap() as u16).collect();
    let x: Vec<f32> = q
        .get("x").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_f64().unwrap() as f32).collect();
    let y_expect: Vec<f32> = q
        .get("y").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_f64().unwrap() as f32).collect();
    let scales_expect: Vec<f32> = q
        .get("scales").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_f64().unwrap() as f32).collect();
    let wq_expect: Vec<u8> = q
        .get("wq_packed").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_f64().unwrap() as u8).collect();

    let w: Vec<f32> = w_bits.iter().map(|&b| f16_bits_to_f32(b)).collect();
    let qt = quantize_tensor(&w, k, n);
    assert_eq!(qt.packed_wq(), wq_expect, "packed W_q differs from python");
    for (i, (&a, &b)) in qt.scales.iter().zip(&scales_expect).enumerate() {
        assert!((a - b).abs() <= b.abs() * 1e-5 + 1e-7, "scale {i}: {a} vs {b}");
    }
    // y = x @ dequant_draft
    let d = qt.dequant_draft();
    let mut y = vec![0f32; n];
    for i in 0..k {
        for j in 0..n {
            y[j] += x[i] * d[i * n + j];
        }
    }
    for (j, (&a, &b)) in y.iter().zip(&y_expect).enumerate() {
        assert!((a - b).abs() <= b.abs() * 1e-4 + 1e-4, "y[{j}]: {a} vs {b}");
    }
}

#[test]
fn eq4_golden_scale_matches() {
    let Some(m) = manifest() else { return };
    let text = std::fs::read_to_string(m.path(&m.goldens_json)).expect("goldens.json");
    let v = json::parse(&text).expect("parse");
    let g = v.get("eq4").expect("eq4 golden");
    let bits: Vec<u16> = g
        .get("w_bits").unwrap().as_arr().unwrap()
        .iter().map(|x| x.as_f64().unwrap() as u16).collect();
    let expect = g.get("scale").unwrap().as_f64().unwrap() as f32;
    let w: Vec<f32> = bits.iter().map(|&b| f16_bits_to_f32(b)).collect();
    let q: Vec<f32> = w
        .iter()
        .map(|&v| {
            let c = encode_bits(f32_to_f16_bits(v));
            speq::bsfp::decode_draft_exp(c.w_q);
            let (s, qe) = speq::bsfp::decode_draft_exp(c.w_q);
            let mag = ((qe as i32 - 15) as f32).exp2();
            if s == 1 { -mag } else { mag }
        })
        .collect();
    let scales = eq4_scales(&w, &q, 128, 1);
    assert!((scales[0] - expect).abs() <= expect.abs() * 1e-5 + 1e-7,
            "{} vs {}", scales[0], expect);
}

#[test]
fn weights_bin_exponents_satisfy_premise() {
    // Every trained model's linear weights must use only exponents [0, 15]
    // (the Fig. 2(c) premise BSFP relies on).  Loads through the native
    // backend: no XLA library required.
    use speq::runtime::Backend;
    let Some(m) = manifest() else { return };
    for name in m.model_names() {
        let model = speq::runtime::NativeBackend::from_manifest(&m, &name).unwrap();
        for lin in model.linears().to_vec() {
            let hist =
                speq::bsfp::exponent_histogram(model.weights().f32(&lin).iter().copied());
            let high: u64 = hist[16..].iter().sum();
            assert_eq!(high, 0, "{name}/{lin} has exponents >= 16");
        }
    }
}
