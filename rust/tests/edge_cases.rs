//! Edge cases and failure injection: malformed artifacts, boundary
//! generation lengths, capacity errors, queue stress.

use speq::coordinator::{Priority, RequestQueue};
use speq::model::{Manifest, ModelConfig, SamplingParams};
use speq::runtime::{Backend, InitStyle, NativeBackend};
use speq::specdec::{Engine, SpecConfig};

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_root().join("manifest.json").exists()
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let err = Manifest::load("/nonexistent/path").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("speq_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json !!").unwrap();
    assert!(Manifest::load(&dir).is_err());
    // Structurally valid JSON but missing fields:
    std::fs::write(dir.join("manifest.json"), r#"{"version": 1}"#).unwrap();
    assert!(Manifest::load(&dir).is_err());
}

#[test]
fn truncated_weights_bin_is_rejected() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load(artifacts_root()).unwrap();
    let entry = m.model("vicuna-7b-tiny").unwrap();
    let dir = std::env::temp_dir().join("speq_truncated_weights");
    std::fs::create_dir_all(&dir).unwrap();
    let full = std::fs::read(m.path(&entry.weights)).unwrap();
    let trunc_path = dir.join("weights.bin");
    std::fs::write(&trunc_path, &full[..full.len() / 2]).unwrap();
    let err = speq::model::load_weights(&trunc_path, entry).unwrap_err();
    assert!(format!("{err:#}").contains("truncated"), "{err:#}");
}

#[test]
fn unknown_model_name_is_a_clear_error() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load(artifacts_root()).unwrap();
    let err = m.model("gpt-5").unwrap_err();
    assert!(format!("{err}").contains("not in manifest"));
}

#[test]
fn engine_boundary_generation_lengths() {
    let model = NativeBackend::builtin("vicuna-7b-tiny").unwrap();
    let engine = Engine::new(&model);
    // gen_len 1: exactly one token, no draft iterations needed.
    let r = engine
        .generate_spec(b"Q: ", &SpecConfig { gen_len: 1, ..Default::default() })
        .unwrap();
    assert_eq!(r.tokens.len(), 1);
    assert_eq!(r.trace.produced, 1);
    // Oversized prompt: uses the trailing window, still works.
    let huge = vec![b'a'; 10_000];
    let r = engine
        .generate_spec(&huge, &SpecConfig { gen_len: 4, ..Default::default() })
        .unwrap();
    assert_eq!(r.tokens.len(), 4);
    // Requesting more than KV capacity: clamped, not crashed.
    let r = engine
        .generate_spec(b"Q: ", &SpecConfig { gen_len: 100_000, ..Default::default() })
        .unwrap();
    assert!(r.tokens.len() <= model.cache_len());
    assert_eq!(r.trace.produced, r.tokens.len());
    // max_draft beyond graph slots is rejected.
    let err = engine
        .generate_spec(b"Q: ", &SpecConfig { max_draft: 99, ..Default::default() })
        .unwrap_err();
    assert!(format!("{err}").contains("slots"));
}

#[test]
fn zero_gen_len_produces_no_tokens() {
    // Regression: `generate_ar` used to emit one token and report
    // `produced: gen_len`, disagreeing with `tokens.len()`.
    let model = NativeBackend::builtin("vicuna-7b-tiny").unwrap();
    let engine = Engine::new(&model);
    let ar = engine.generate_ar(b"Q: ", 0, SamplingParams::greedy()).unwrap();
    assert!(ar.tokens.is_empty());
    assert_eq!(ar.trace.produced, 0);
    let spec = engine
        .generate_spec(b"Q: ", &SpecConfig { gen_len: 0, ..Default::default() })
        .unwrap();
    assert!(spec.tokens.is_empty());
    assert_eq!(spec.trace.produced, 0);
}

#[test]
fn undersized_kv_cache_is_a_proper_error() {
    // Regression: `Engine::capacity` used to underflow (usize wrap) when
    // cache_len < prompt_len + slots + 1; it must be a clean error now.
    let cfg = ModelConfig {
        name: "cramped".into(),
        paper_analog: "none".into(),
        n_layers: 1,
        d_model: 128,
        d_ff: 128,
        n_heads: 4,
        head_dim: 32,
        vocab: 64,
        cache_len: 40, // < prefill(32) + slots(9) + 1
        prefill_len: 32,
        param_count: 0,
    };
    let model = NativeBackend::synthetic(cfg, 9, 5, InitStyle::Random).unwrap();
    let engine = Engine::new(&model);
    let prompt = vec![b' '; 32];
    let err = engine
        .generate_spec(&prompt, &SpecConfig { gen_len: 8, max_draft: 4, ..Default::default() })
        .unwrap_err();
    assert!(format!("{err}").contains("KV cache too small"), "{err}");
    let err = engine.generate_ar(&prompt, 8, SamplingParams::greedy()).unwrap_err();
    assert!(format!("{err}").contains("KV cache too small"), "{err}");
}

#[test]
fn engine_ar_spec_agree_at_tiny_lengths() {
    let model = NativeBackend::builtin("llama3.2-3b-tiny").unwrap();
    let engine = Engine::new(&model);
    for gen_len in [1usize, 2, 3, 17, 18] {
        let ar = engine
            .generate_ar(b"def add_2(x):\n    return ", gen_len, SamplingParams::greedy())
            .unwrap();
        let spec = engine
            .generate_spec(
                b"def add_2(x):\n    return ",
                &SpecConfig { gen_len, ..Default::default() },
            )
            .unwrap();
        assert_eq!(ar.tokens, spec.tokens, "mismatch at gen_len {gen_len}");
    }
}

#[test]
fn queue_stress_many_producers() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{mpsc, Arc};
    let q = Arc::new(RequestQueue::new(1024));
    let popped = Arc::new(AtomicUsize::new(0));
    let n_producers = 8;
    let per = 100;

    let mut consumers = Vec::new();
    for _ in 0..4 {
        let q = q.clone();
        let popped = popped.clone();
        consumers.push(std::thread::spawn(move || {
            while q.pop().is_some() {
                popped.fetch_add(1, Ordering::Relaxed);
            }
        }));
    }
    let mut producers = Vec::new();
    for p in 0..n_producers {
        let q = q.clone();
        producers.push(std::thread::spawn(move || {
            for i in 0..per {
                let (tx, _rx) = mpsc::channel();
                // _rx dropped: responses would be discarded; fine for stress.
                let req = speq::coordinator::Request {
                    id: (p * per + i) as u64,
                    prompt: vec![b'x'],
                    gen_len: 1,
                    max_draft: 16,
                    gamma: 0.6,
                    adaptive: false,
                    sampling: SamplingParams::greedy(),
                    mode: speq::coordinator::Mode::Speculative,
                    priority: if i % 2 == 0 { Priority::Interactive } else { Priority::Batch },
                    session: None,
                    deadline: None,
                    cancel: speq::coordinator::CancelToken::new(),
                    submitted: std::time::Instant::now(),
                    respond_to: tx,
                };
                while q.submit(req_clone_hack(&req)).is_err() {
                    std::thread::yield_now();
                }
                drop(req);
            }
        }));
    }
    for h in producers {
        h.join().unwrap();
    }
    // Drain, then close.
    while !q.is_empty() {
        std::thread::yield_now();
    }
    q.close();
    for h in consumers {
        h.join().unwrap();
    }
    assert_eq!(popped.load(Ordering::Relaxed), n_producers * per);
}

// Request isn't Clone (contains a Sender we want unique); rebuild instead.
fn req_clone_hack(r: &speq::coordinator::Request) -> speq::coordinator::Request {
    let (tx, _rx) = std::sync::mpsc::channel();
    speq::coordinator::Request {
        id: r.id,
        prompt: r.prompt.clone(),
        gen_len: r.gen_len,
        max_draft: r.max_draft,
        gamma: r.gamma,
        adaptive: r.adaptive,
        sampling: r.sampling,
        mode: r.mode,
        priority: r.priority,
        session: r.session,
        deadline: r.deadline,
        cancel: r.cancel.clone(),
        submitted: r.submitted,
        respond_to: tx,
    }
}
