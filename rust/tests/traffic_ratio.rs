//! Weight-traffic regression tests: the quarter-to-all claim as a number.
//!
//! For every builtin zoo model, meters the bytes the native backend's
//! kernels stream per decoded token and asserts the draft pass stays at or
//! below 0.35x the full pass (the 4-of-16-bit prefix plane plus Eq. 4
//! scales, norms and the embedding row — comfortably under the bound, but
//! any regression to dense draft weights trips it immediately).

use speq::runtime::{Backend, NativeBackend};

const PROMPT_LEN: usize = 16;
const STEPS: usize = 4;

/// Meter `STEPS` draft steps and `STEPS` full steps on `model`; returns
/// `(draft bytes/token, full bytes/token, verify bytes/row)`.
fn meter(model: &str) -> (f64, f64, f64) {
    let b = NativeBackend::builtin(model).expect("builtin model");
    let mut toks = vec![b'a' as i32; b.prefill_len()];
    for (i, t) in toks.iter_mut().enumerate().take(PROMPT_LEN) {
        *t = b'a' as i32 + (i % 16) as i32;
    }

    let pre = b.prefill(&toks, PROMPT_LEN).expect("prefill");
    b.drain_traffic();
    let mut state = Some(pre.state);
    for i in 0..STEPS {
        let out = b
            .decode_draft(1, PROMPT_LEN + i, state.take().unwrap())
            .expect("draft step");
        state = Some(out.state);
    }
    let draft = b.drain_traffic();

    for i in 0..STEPS {
        let out = b
            .decode_full(1, PROMPT_LEN + STEPS + i, state.take().unwrap())
            .expect("full step");
        state = Some(out.state);
    }
    let full = b.drain_traffic();

    let vtokens: Vec<i32> = (0..b.slots() as i32).collect();
    let _ = b
        .verify(&vtokens, PROMPT_LEN + 2 * STEPS, state.take().unwrap())
        .expect("verify pass");
    let verify = b.drain_traffic();

    assert_eq!(draft.draft_tokens, STEPS as u64, "{model}: draft tokens");
    assert_eq!(full.full_tokens, STEPS as u64, "{model}: full tokens");
    assert_eq!(verify.verify_rows, b.slots() as u64, "{model}: verify rows");
    assert!(draft.draft_bytes > 0 && full.full_bytes > 0, "{model}: empty counters");
    (
        draft.draft_bytes_per_token(),
        full.full_bytes_per_token(),
        verify.verify_bytes_per_row(),
    )
}

#[test]
fn draft_traffic_is_at_most_035x_full_on_every_zoo_model() {
    for model in speq::runtime::builtin_model_names() {
        let (draft_bpt, full_bpt, verify_bpr) = meter(model);
        let ratio = draft_bpt / full_bpt;
        assert!(
            ratio <= 0.35,
            "{model}: draft streams {draft_bpt:.0} B/tok vs full {full_bpt:.0} B/tok \
             (ratio {ratio:.4} > 0.35)"
        );
        // The packed full pass streams the FP16 footprint, so a verify row
        // costs the same weights as a full decode step.
        assert_eq!(verify_bpr, full_bpt, "{model}: verify row != full step traffic");
    }
}

#[test]
fn packed_full_pass_streams_the_fp16_footprint() {
    // On a zoo model every linear is packed: the full pass must stream
    // exactly 2 bytes per linear weight plus the f32 norms + embedding
    // row — i.e. strictly less than the dense f32 interpreter streamed.
    let b = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
    let linear_elems: usize = b
        .linears()
        .to_vec()
        .iter()
        .map(|name| b.weights().f32(name).len())
        .sum();
    let d = b.config().d_model;
    let non_linear = (d + (2 * b.config().n_layers + 1) * d) * 4;
    let toks = vec![b'a' as i32; b.prefill_len()];
    let pre = b.prefill(&toks, 4).expect("prefill");
    b.drain_traffic();
    let _ = b.decode_full(1, 4, pre.state).expect("full step");
    let t = b.drain_traffic();
    assert_eq!(
        t.full_bytes as usize,
        linear_elems * 2 + non_linear,
        "full pass must stream prefix+residual planes (2 B/weight)"
    );
    assert!((t.full_bytes as usize) < linear_elems * 4, "must undercut dense f32");
}

#[test]
fn every_zoo_linear_is_packed() {
    // The quarter-traffic claim only holds if the whole zoo actually hits
    // the packed path — a silent fallback to split/dense would still pass
    // generation tests while quadrupling draft traffic.
    for model in speq::runtime::builtin_model_names() {
        let b = NativeBackend::builtin(model).expect("builtin");
        for name in b.linears().to_vec() {
            assert_eq!(b.store_kind(&name), "packed", "{model}/{name}");
        }
    }
}
