//! Integration: the HTTP/SSE serving front end over real TCP sockets —
//! bit-identity of streamed tokens vs offline generation, malformed-request
//! handling, admission-control backpressure (429), deadline cancellation,
//! and graceful shutdown.  Everything runs on an ephemeral localhost port
//! with the builtin native backend — no artifacts, no external deps.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use speq::coordinator::ServerConfig;
use speq::net::loadgen::{self, stream_once, Terminal, PROMPTS};
use speq::net::{GenerateRequest, LoadConfig, LoadMode, NetConfig, NetServer};
use speq::runtime::{load_backend_with, ModelSource, NativeConfig};
use speq::specdec::{Engine, SpecConfig};

const MODEL: &str = "vicuna-7b-tiny";

fn net_server(workers: usize, max_batch: usize, queue: usize) -> NetServer {
    let cfg = NetConfig {
        addr: "127.0.0.1:0".to_string(),
        server: ServerConfig {
            source: ModelSource::Builtin,
            model: MODEL.into(),
            workers,
            queue_capacity: queue,
            max_batch,
            ..ServerConfig::default()
        },
        ..NetConfig::default()
    };
    NetServer::bind(cfg).expect("bind net server")
}

/// Offline reference: the same generation through `Engine::generate_spec`.
fn offline_tokens(prompt: &[u8], gen_len: usize) -> Vec<u8> {
    let backend =
        load_backend_with(&ModelSource::Builtin, MODEL, &NativeConfig::default()).expect("backend");
    let engine = Engine::new(backend.as_ref());
    let cfg = SpecConfig { gen_len, ..Default::default() };
    engine.generate_spec(prompt, &cfg).expect("offline generation").tokens
}

/// Send raw bytes, return `(status, full response text)`.
fn raw_request(addr: std::net::SocketAddr, raw: &[u8]) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(raw).expect("send");
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out); // server closes (connection: close)
    let text = String::from_utf8_lossy(&out).into_owned();
    let status = text
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    (status, text)
}

fn post(path: &str, body: &str) -> Vec<u8> {
    format!(
        "POST {path} HTTP/1.1\r\nhost: t\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

#[test]
fn streamed_tokens_bit_identical_to_offline_for_concurrent_clients() {
    // ≥8 concurrent clients against one scheduler: continuous batching
    // co-batches them, and every streamed byte sequence must still be
    // bit-identical to the offline engine for the same prompt/seed.
    let server = net_server(1, 8, 32);
    let addr = server.addr().to_string();
    let gen_len = 48;

    let mut handles = Vec::new();
    for i in 0..8 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let req = GenerateRequest {
                prompt: PROMPTS[i % PROMPTS.len()].as_bytes().to_vec(),
                gen_len,
                ..GenerateRequest::default()
            };
            let out = stream_once(&addr, &req, Duration::from_secs(120)).expect("stream");
            (i, out)
        }));
    }
    for h in handles {
        let (i, out) = h.join().expect("client thread");
        assert_eq!(out.status, 200, "client {i}");
        assert_eq!(out.terminal, Terminal::Done, "client {i}");
        assert!(out.ttft_s.is_some(), "client {i} never saw a chunk event");
        let expected = offline_tokens(PROMPTS[i % PROMPTS.len()].as_bytes(), gen_len);
        assert_eq!(
            out.tokens, expected,
            "client {i}: streamed bytes differ from offline generation"
        );
        let done = out.done_data.expect("done event data");
        assert!(done.contains("\"accept_rate\""), "done stats missing: {done}");
        assert!(done.contains("\"draft_traffic_ratio\""), "traffic stats missing: {done}");
    }
    let snap = server.snapshot();
    assert_eq!(snap.completed, 8);
    assert_eq!(snap.failed, 0);
}

#[test]
fn generate_route_returns_full_body_bit_identical_to_offline() {
    let server = net_server(1, 4, 8);
    let req = GenerateRequest {
        prompt: PROMPTS[0].as_bytes().to_vec(),
        gen_len: 24,
        ..GenerateRequest::default()
    };
    let (status, text) = raw_request(server.addr(), &post("/v1/generate", &req.to_json()));
    assert_eq!(status, 200, "{text}");
    let body_start = text.find("\r\n\r\n").expect("header/body split") + 4;
    let v = speq::util::json::parse(&text[body_start..]).expect("JSON body");
    let tokens: Vec<u8> = v
        .get("tokens")
        .expect("tokens array")
        .as_arr()
        .unwrap()
        .iter()
        .map(|n| n.as_usize().unwrap() as u8)
        .collect();
    assert_eq!(tokens, offline_tokens(PROMPTS[0].as_bytes(), 24));
    assert!(v.get("accept_rate").is_some());
    assert!(v.get("ttft_ms").is_some(), "generate path must observe TTFT");
    assert!(v.get("draft_traffic_ratio").is_some());
}

#[test]
fn malformed_requests_get_4xx() {
    let mut server = net_server(1, 2, 8);
    let addr = server.addr();

    // Bad JSON body.
    let (status, _) = raw_request(addr, &post("/v1/generate", "{not json"));
    assert_eq!(status, 400);
    // Missing prompt.
    let (status, _) = raw_request(addr, &post("/v1/generate", "{\"gen_len\":4}"));
    assert_eq!(status, 400);
    // Unknown route.
    let (status, _) = raw_request(addr, &post("/v1/unknown", "{}"));
    assert_eq!(status, 404);
    // Known route, wrong method.
    let (status, _) =
        raw_request(addr, b"GET /v1/generate HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n");
    assert_eq!(status, 405);
    // Declared body above the configured cap.
    let huge = NetConfig::default().max_body_bytes + 1;
    let (status, _) = raw_request(
        addr,
        format!(
            "POST /v1/generate HTTP/1.1\r\nhost: t\r\ncontent-length: {huge}\r\nconnection: close\r\n\r\n"
        )
        .as_bytes(),
    );
    assert_eq!(status, 413);
    // Unsupported HTTP version.
    let (status, _) = raw_request(addr, b"GET /healthz HTTP/3\r\n\r\n");
    assert_eq!(status, 400);

    // The server is still healthy afterwards.
    let (status, text) =
        raw_request(addr, b"GET /healthz HTTP/1.1\r\nconnection: close\r\n\r\n");
    assert_eq!(status, 200);
    assert!(text.contains("\"status\":\"ok\""));
    assert!(server.shutdown(Duration::from_secs(30)));
}

#[test]
fn queue_overflow_returns_429_with_retry_after() {
    // One scheduler, batch of 1, queue of 1: a burst of 12 concurrent
    // long generations must overflow admission and draw 429s.
    let server = net_server(1, 1, 1);
    let addr = server.addr().to_string();

    let mut handles = Vec::new();
    for i in 0..12 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let req = GenerateRequest {
                prompt: PROMPTS[i % PROMPTS.len()].as_bytes().to_vec(),
                gen_len: 96,
                ..GenerateRequest::default()
            };
            stream_once(&addr, &req, Duration::from_secs(120)).expect("stream")
        }));
    }
    let outcomes: Vec<_> = handles.into_iter().map(|h| h.join().expect("client")).collect();
    let rejected: Vec<_> =
        outcomes.iter().filter(|o| o.terminal == Terminal::Rejected).collect();
    let completed = outcomes.iter().filter(|o| o.terminal == Terminal::Done).count();
    assert!(
        !rejected.is_empty(),
        "expected admission-control 429s from a 12-request burst into a 1-deep queue"
    );
    assert!(completed >= 1, "some requests must still complete");
    for r in &rejected {
        assert_eq!(r.status, 429);
        assert_eq!(r.retry_after_s, Some(1), "429 must carry Retry-After");
    }
    // No request may be silently dropped: every outcome is terminal.
    assert_eq!(
        outcomes.len(),
        completed + rejected.len()
            + outcomes.iter().filter(|o| o.terminal == Terminal::Cancelled).count(),
        "unexpected error/drop outcomes: {outcomes:?}"
    );

    // The throttle shows up on /metrics.
    let (status, page) =
        raw_request(server.addr(), b"GET /metrics HTTP/1.1\r\nconnection: close\r\n\r\n");
    assert_eq!(status, 200);
    let throttled = metric_value(&page, "speq_http_throttled_total");
    assert!(throttled >= 1.0, "throttle counter missing from:\n{page}");
}

#[test]
fn deadline_expired_request_is_cancelled_and_frees_its_slot() {
    let server = net_server(1, 2, 8);
    let addr = server.addr().to_string();

    // A long generation with a deadline far shorter than its runtime.
    let req = GenerateRequest {
        prompt: PROMPTS[0].as_bytes().to_vec(),
        gen_len: 240,
        deadline_ms: Some(30),
        ..GenerateRequest::default()
    };
    let out = stream_once(&addr, &req, Duration::from_secs(120)).expect("stream");
    assert_eq!(out.status, 200, "SSE starts before the deadline fires");
    assert_eq!(
        out.terminal,
        Terminal::Cancelled,
        "expected a terminal cancelled event, got {:?} ({:?})",
        out.terminal,
        out.error_body
    );

    // The cancelled sequence must have freed its batch slot: a normal
    // request right after completes with bit-exact output.
    let follow = GenerateRequest {
        prompt: PROMPTS[1].as_bytes().to_vec(),
        gen_len: 24,
        ..GenerateRequest::default()
    };
    let out2 = stream_once(&addr, &follow, Duration::from_secs(120)).expect("stream");
    assert_eq!(out2.terminal, Terminal::Done);
    assert_eq!(out2.tokens, offline_tokens(PROMPTS[1].as_bytes(), 24));

    let snap = server.snapshot();
    assert!(snap.cancelled >= 1, "requests_cancelled not counted: {snap:?}");
    let (_, page) =
        raw_request(server.addr(), b"GET /metrics HTTP/1.1\r\nconnection: close\r\n\r\n");
    assert!(
        metric_value(&page, "speq_requests_cancelled_total") >= 1.0,
        "cancellation missing from /metrics:\n{page}"
    );
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    let mut server = net_server(1, 4, 8);
    let addr = server.addr().to_string();

    let client = std::thread::spawn(move || {
        let req = GenerateRequest {
            prompt: PROMPTS[2].as_bytes().to_vec(),
            gen_len: 64,
            ..GenerateRequest::default()
        };
        stream_once(&addr, &req, Duration::from_secs(120)).expect("stream")
    });
    // Let the request reach the scheduler, then shut down underneath it.
    std::thread::sleep(Duration::from_millis(50));
    let drained = server.shutdown(Duration::from_secs(60));
    let out = client.join().expect("client thread");
    assert!(drained, "drain must complete within the timeout");
    assert_eq!(
        out.terminal,
        Terminal::Done,
        "in-flight request must finish during graceful shutdown ({:?})",
        out.error_body
    );
    assert_eq!(out.tokens, offline_tokens(PROMPTS[2].as_bytes(), 64));
    assert_eq!(server.snapshot().completed, 1);
}

#[test]
fn metrics_expose_latency_histograms() {
    let server = net_server(1, 4, 8);
    let addr = server.addr().to_string();
    let req = GenerateRequest {
        prompt: PROMPTS[3].as_bytes().to_vec(),
        gen_len: 32,
        ..GenerateRequest::default()
    };
    let out = stream_once(&addr, &req, Duration::from_secs(120)).expect("stream");
    assert_eq!(out.terminal, Terminal::Done);

    let (status, page) =
        raw_request(server.addr(), b"GET /metrics HTTP/1.1\r\nconnection: close\r\n\r\n");
    assert_eq!(status, 200);
    for series in ["speq_ttft_seconds", "speq_inter_token_seconds", "speq_request_duration_seconds"]
    {
        assert!(
            page.contains(&format!("# TYPE {series} histogram")),
            "{series} histogram missing from /metrics:\n{page}"
        );
        assert!(page.contains(&format!("{series}_bucket{{le=\"+Inf\"}}")));
    }
    assert!(metric_value(&page, "speq_ttft_seconds_count") >= 1.0);
    assert!(
        metric_value(&page, "speq_inter_token_seconds_count") >= 1.0,
        "a 32-token stream must observe inter-token gaps"
    );
    assert!(metric_value(&page, "speq_requests_completed_total") >= 1.0);
    assert!(metric_value(&page, "speq_tokens_generated_total") >= 32.0);
}

#[test]
fn loadgen_closed_loop_smoke_over_real_sockets() {
    let server = net_server(2, 4, 32);
    let cfg = LoadConfig {
        addr: server.addr().to_string(),
        mode: LoadMode::Closed { users: 4 },
        requests: 8,
        gen_len: 24,
        ..LoadConfig::default()
    };
    let report = loadgen::run(&cfg).expect("loadgen run");
    assert_eq!(report.completed, 8, "all smoke requests must complete: {report:?}");
    assert_eq!(report.failed, 0);
    assert!(report.goodput_rps > 0.0);
    assert!(report.tokens >= 8 * 24);
    assert!(report.ttft_ms.p50 > 0.0);
    assert!(report.total_ms.p99 >= report.total_ms.p50);
    let line = report.bench_json();
    assert!(line.starts_with("BENCH_JSON {"), "{line}");
}

#[test]
fn loadgen_open_loop_poisson_arrivals_complete() {
    let server = net_server(2, 4, 32);
    let cfg = LoadConfig {
        addr: server.addr().to_string(),
        mode: LoadMode::Open { rate_rps: 40.0 },
        requests: 6,
        gen_len: 16,
        ..LoadConfig::default()
    };
    let report = loadgen::run(&cfg).expect("loadgen run");
    assert_eq!(report.completed + report.rejected, 6, "{report:?}");
    assert!(report.completed >= 1);
    assert_eq!(report.failed, 0);
}

/// Extract the value of an un-labelled metric line (`name value`).
fn metric_value(page: &str, name: &str) -> f64 {
    page.lines()
        .find(|l| l.starts_with(name) && l.as_bytes().get(name.len()) == Some(&b' '))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(-1.0)
}
