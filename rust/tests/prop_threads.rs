//! Thread-invariance property suite: the parallel runtime must be
//! bitwise-deterministic for every worker-pool width.
//!
//! For every builtin zoo model, speculative + autoregressive decoding at
//! batch 1 and batch 4 is generated under `T in {1, 2, 4, 8}` kernel
//! threads and asserted byte-identical across all widths — and, when the
//! self-recording golden snapshots exist (`rust/tests/goldens/*.golden`,
//! written by `golden_tokens.rs`), identical to the recorded streams too.
//! A separate test pins raw *logits* bits (prefill / full decode / draft
//! decode / verify) across widths, so a divergence is caught even where
//! greedy argmax would mask it.
//!
//! Why this holds: the kernels shard the output-column dimension into
//! contiguous per-shard ranges and every output element keeps its exact
//! ascending-index accumulation order, so the thread count can only move
//! work between cores, never change a single f32 operation.

use std::path::PathBuf;

use speq::model::SamplingParams;
use speq::runtime::{Backend, NativeBackend};
use speq::specdec::{ArSession, BatchEngine, Engine, GenSession, SpecConfig};

const GEN_LEN: usize = 28;
const MAX_DRAFT: usize = 8;
const BASE_PROMPT: &[u8] = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";
const THREADS: [usize; 4] = [1, 2, 4, 8];

fn backend(model: &str, threads: usize) -> NativeBackend {
    let mut b = NativeBackend::builtin(model).expect("builtin model");
    b.set_threads(threads);
    b
}

fn spec_cfg() -> SpecConfig {
    SpecConfig { max_draft: MAX_DRAFT, gen_len: GEN_LEN, ..Default::default() }
}

/// The batch-4 prompts `golden_tokens.rs` pins (sequence 0 == batch-1).
fn batch_prompts() -> Vec<Vec<u8>> {
    (0..4usize)
        .map(|i| {
            let mut p = BASE_PROMPT.to_vec();
            if i > 0 {
                p.push(b'0' + i as u8);
            }
            p
        })
        .collect()
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// The `tokens=` hex of one stream in a recorded golden snapshot, if the
/// snapshot exists (they are self-recorded by `golden_tokens.rs`; absent
/// on a fresh checkout, in which case cross-thread equality still pins
/// the invariance).
fn golden_tokens_hex(model: &str, key: &str) -> Option<String> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/goldens")
        .join(format!("{model}.golden"));
    let text = std::fs::read_to_string(path).ok()?;
    let prefix = format!("{key} tokens=");
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(&prefix) {
            return Some(rest.split_whitespace().next().unwrap_or(rest).to_string());
        }
    }
    None
}

struct Streams {
    spec1: Vec<u8>,
    ar1: Vec<u8>,
    spec4: Vec<Vec<u8>>,
    ar4: Vec<Vec<u8>>,
}

/// Generate every pinned stream for one model at one pool width.
fn streams(model: &str, threads: usize) -> Streams {
    let backend = backend(model, threads);
    let engine = Engine::new(&backend);
    let spec1 = engine.generate_spec(BASE_PROMPT, &spec_cfg()).expect("spec b1").tokens;
    let ar1 = engine
        .generate_ar(BASE_PROMPT, GEN_LEN, SamplingParams::greedy())
        .expect("ar b1")
        .tokens;
    assert_eq!(spec1, ar1, "{model} T={threads}: greedy spec != AR");

    let batch = BatchEngine::new(&backend);
    let requests: Vec<(Vec<u8>, SpecConfig)> =
        batch_prompts().into_iter().map(|p| (p, spec_cfg())).collect();
    let spec4: Vec<Vec<u8>> =
        batch.run_spec(&requests).expect("spec b4").into_iter().map(|r| r.tokens).collect();
    let ar_sessions: Vec<GenSession> = batch_prompts()
        .iter()
        .map(|p| {
            ArSession::new(&backend, p, GEN_LEN, SamplingParams::greedy())
                .map(GenSession::Ar)
                .expect("ar session")
        })
        .collect();
    let ar4: Vec<Vec<u8>> =
        batch.run(ar_sessions).expect("ar b4").into_iter().map(|r| r.tokens).collect();
    assert_eq!(backend.arena().in_use(), 0, "{model} T={threads}: leaked KV slots");
    Streams { spec1, ar1, spec4, ar4 }
}

fn check_model(model: &str) {
    let base = streams(model, THREADS[0]);
    // Against the recorded goldens, when present.
    if let Some(want) = golden_tokens_hex(model, "spec_b1") {
        assert_eq!(hex(&base.spec1), want, "{model}: spec_b1 diverged from recorded golden");
    }
    if let Some(want) = golden_tokens_hex(model, "ar_b1") {
        assert_eq!(hex(&base.ar1), want, "{model}: ar_b1 diverged from recorded golden");
    }
    for i in 0..4 {
        if let Some(want) = golden_tokens_hex(model, &format!("spec_b4[{i}]")) {
            assert_eq!(hex(&base.spec4[i]), want, "{model}: spec_b4[{i}] diverged from golden");
        }
        if let Some(want) = golden_tokens_hex(model, &format!("ar_b4[{i}]")) {
            assert_eq!(hex(&base.ar4[i]), want, "{model}: ar_b4[{i}] diverged from golden");
        }
    }
    // Across every pool width: byte-identical streams.
    for &t in &THREADS[1..] {
        let s = streams(model, t);
        assert_eq!(s.spec1, base.spec1, "{model}: spec_b1 diverged at T={t}");
        assert_eq!(s.ar1, base.ar1, "{model}: ar_b1 diverged at T={t}");
        assert_eq!(s.spec4, base.spec4, "{model}: spec_b4 diverged at T={t}");
        assert_eq!(s.ar4, base.ar4, "{model}: ar_b4 diverged at T={t}");
    }
}

#[test]
fn threads_vicuna_7b_tiny() {
    check_model("vicuna-7b-tiny");
}

#[test]
fn threads_llama2_7b_tiny() {
    check_model("llama2-7b-tiny");
}

#[test]
fn threads_llama3_1_8b_tiny() {
    check_model("llama3.1-8b-tiny");
}

#[test]
fn threads_llama3_2_3b_tiny() {
    check_model("llama3.2-3b-tiny");
}

#[test]
fn threads_llama2_13b_tiny() {
    check_model("llama2-13b-tiny");
}

/// Raw logits bits (not just greedy tokens) across pool widths, over the
/// four request-path operations and a batch-4 decode.
fn logits_bits(model: &str, threads: usize) -> Vec<u32> {
    let b = backend(model, threads);
    let mut toks: Vec<i32> = BASE_PROMPT.iter().map(|&c| c as i32).collect();
    let plen = toks.len().min(b.prefill_len());
    toks.resize(b.prefill_len(), b' ' as i32);
    let mut bits = Vec::new();
    let pre = b.prefill(&toks, plen).expect("prefill");
    bits.extend(pre.logits.iter().map(|v| v.to_bits()));
    let full = b.decode_full(65, plen, pre.state).expect("full");
    bits.extend(full.logits.iter().map(|v| v.to_bits()));
    let draft = b.decode_draft(66, plen + 1, full.state).expect("draft");
    bits.extend(draft.logits.iter().map(|v| v.to_bits()));
    let vtokens: Vec<i32> = (0..b.slots() as i32).collect();
    let ver = b.verify(&vtokens, plen + 2, draft.state).expect("verify");
    bits.extend(ver.logits.iter().map(|v| v.to_bits()));

    // Batch-4 decode through the slot arena.
    let prompts = batch_prompts();
    let padded: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| {
            let mut t: Vec<i32> = p.iter().map(|&c| c as i32).collect();
            t.resize(b.prefill_len(), b' ' as i32);
            t
        })
        .collect();
    let lengths: Vec<usize> = prompts.iter().map(|p| p.len().min(b.prefill_len())).collect();
    let slots: Vec<_> = (0..4).map(|_| b.alloc_slot()).collect();
    for row in b.prefill_batch(&slots, &padded, &lengths).expect("prefill_batch") {
        bits.extend(row.iter().map(|v| v.to_bits()));
    }
    for row in b
        .decode_full_batch(&slots, &[65, 66, 67, 68], &lengths)
        .expect("decode_full_batch")
    {
        bits.extend(row.iter().map(|v| v.to_bits()));
    }
    for &s in &slots {
        b.free_slot(s);
    }
    bits
}

#[test]
fn logits_bit_identical_across_thread_counts() {
    for model in ["vicuna-7b-tiny", "llama2-13b-tiny"] {
        let reference = logits_bits(model, THREADS[0]);
        for &t in &THREADS[1..] {
            assert_eq!(
                logits_bits(model, t),
                reference,
                "{model}: logits bits diverged at T={t}"
            );
        }
    }
}
