//! Paged-KV + prefix-sharing property suite.
//!
//! Three layers of guarantees:
//!
//! * **Memory safety** — the [`PageAllocator`]'s generation-stamped page
//!   ids turn double frees, stale-page-table use-after-free, and refcount
//!   underflow into typed errors instead of silent corruption, and the
//!   [`PrefixTree`]'s retain/release discipline never leaks or
//!   double-frees a page.
//! * **Copy-on-write** — overwriting a drafted/decoded position that
//!   lands in a tree-shared page copies exactly that page, leaving the
//!   cached prefix bits untouched.
//! * **Bit identity** — decoding a batch of sequences that share a
//!   prompt prefix through the prefix cache is bitwise identical to
//!   decoding them as fully independent sequences, across kernel thread
//!   counts T in {1, 4} and every available SIMD tier, including under
//!   concurrent batch steps from multiple threads.

use speq::runtime::{
    Backend, NativeBackend, PageAllocator, PrefixTree, SimdLevel, PAGE_TOKENS,
};
use speq::specdec::{BatchEngine, Engine, SpecConfig};

// ---------------------------------------------------------------------------
// allocator + tree memory-safety audits
// ---------------------------------------------------------------------------

#[test]
fn double_free_is_a_typed_error_not_corruption() {
    let alloc = PageAllocator::new(64);
    let id = alloc.alloc();
    alloc.release(id).expect("first release");
    let err = alloc.release(id).expect_err("second release must fail");
    assert!(format!("{err}").contains("stale page id"), "{err}");
    assert_eq!(alloc.stats().pages_in_use, 0);
}

#[test]
fn stale_page_table_reads_are_rejected() {
    // A sequence that kept page ids across a free (use-after-free through
    // an old page table) must get an error, even after the slot is
    // recycled to a new owner.
    let alloc = PageAllocator::new(64);
    let old = alloc.alloc();
    alloc.release(old).unwrap();
    let new = alloc.alloc(); // recycles the same slab slot, new generation
    assert_eq!(old.index(), new.index(), "free list must recycle the slot");
    for err in [
        alloc.page_ptr(old).expect_err("stale page_ptr"),
        alloc.retain(old).expect_err("stale retain"),
        alloc.make_unique(old).map(|_| ()).expect_err("stale make_unique"),
    ] {
        assert!(format!("{err}").contains("stale page id"), "{err}");
    }
    // The new owner is untouched by the rejected accesses.
    assert_eq!(alloc.refcount(new).unwrap(), 1);
    alloc.release(new).unwrap();
}

#[test]
fn refcount_underflow_is_impossible() {
    let alloc = PageAllocator::new(64);
    let id = alloc.alloc();
    alloc.retain(id).unwrap();
    alloc.release(id).unwrap();
    alloc.release(id).unwrap(); // hits zero: page freed, generation bumped
    let err = alloc.release(id).expect_err("release below zero must fail");
    assert!(format!("{err}").contains("stale page id"), "{err}");
    assert_eq!(alloc.stats().pages_in_use, 0);
}

#[test]
fn tree_clear_returns_every_retained_page() {
    let alloc = PageAllocator::new(8);
    let tree = PrefixTree::new(1024);
    let tokens: Vec<i32> = (0..3 * PAGE_TOKENS as i32).collect();
    let pages: Vec<_> = (0..3).map(|_| alloc.alloc()).collect();
    tree.insert(&alloc, &tokens, &pages).unwrap();
    // The tree holds its own references; drop the caller's.
    for p in pages {
        alloc.release(p).unwrap();
    }
    assert_eq!(alloc.stats().pages_in_use, 3);
    tree.clear(&alloc);
    assert_eq!(alloc.stats().pages_in_use, 0, "clear leaked pages");
    assert_eq!(tree.pages_held(), 0);
}

#[test]
fn lookup_references_are_real_retains() {
    let alloc = PageAllocator::new(8);
    let tree = PrefixTree::new(1024);
    let tokens: Vec<i32> = (0..2 * PAGE_TOKENS as i32).collect();
    let pages: Vec<_> = (0..2).map(|_| alloc.alloc()).collect();
    tree.insert(&alloc, &tokens, &pages).unwrap();
    let (hit, reused) = tree.lookup(&alloc, &tokens, tokens.len());
    assert_eq!(reused, 2 * PAGE_TOKENS);
    // Caller now co-owns the pages: clearing the tree must NOT free them.
    tree.clear(&alloc);
    for &p in &hit {
        assert!(alloc.refcount(p).unwrap() >= 2, "lookup must retain for the caller");
    }
    for p in hit.into_iter().chain(pages) {
        alloc.release(p).unwrap();
    }
    assert_eq!(alloc.stats().pages_in_use, 0);
}

// ---------------------------------------------------------------------------
// copy-on-write through the backend
// ---------------------------------------------------------------------------

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn overwriting_a_drafted_position_cows_exactly_one_page() {
    let b = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
    let prompt: Vec<u8> = b"SYSTEM: shared preamble here.\nQ: 2 + 2 = ".to_vec();
    let mut toks: Vec<i32> = prompt.iter().map(|&c| c as i32).collect();
    let plen = toks.len();
    toks.resize(b.prefill_len(), b' ' as i32);

    let pre = b.prefill(&toks, plen).expect("prefill");
    let s0 = b.kv_stats();
    // The prompt's tail page is pinned by the prefix tree; the first
    // decode writes into position `plen`, which lives in that page.
    let step = b.decode_full(65, plen, pre.state).expect("decode");
    let s1 = b.kv_stats();
    assert_eq!(s1.cow_copies, s0.cow_copies + 1, "exactly one page must be copied");
    assert_eq!(s1.pages_in_use, s0.pages_in_use + 1, "the copy is one new page");
    // The page is now private: the next write in the same page must not
    // copy again.
    let step2 = b.decode_full(66, plen + 1, step.state).expect("decode 2");
    assert_eq!(b.kv_stats().cow_copies, s1.cow_copies, "private pages never re-COW");

    // The cached prefix kept its original bits: replaying the prompt and
    // the same two decodes reproduces the logits bitwise.
    let pre_b = b.prefill(&toks, plen).expect("prefill replay");
    assert!(b.kv_stats().prefix_hit_tokens > 0, "replay should hit the cache");
    let r1 = b.decode_full(65, plen, pre_b.state).expect("decode replay");
    let r2 = b.decode_full(66, plen + 1, r1.state).expect("decode replay 2");
    assert_eq!(bits(&step2.logits), bits(&r2.logits), "COW corrupted the shared prefix");
}

// ---------------------------------------------------------------------------
// shared-prefix == independent, across threads and SIMD tiers
// ---------------------------------------------------------------------------

const SHARED_PREFIX: &[u8] = b"SYSTEM: you are a terse assistant. answer briefly.\n";

fn prefixed_prompts() -> Vec<Vec<u8>> {
    [
        &b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: "[..],
        b"Q: bob has 9 coins and spends 2. how many coins left?\nA: ",
        b"USER: hello, can we talk about music?\nBOT: ",
        b"def add_two(x):\n    return ",
    ]
    .iter()
    .map(|suffix| {
        let mut p = SHARED_PREFIX.to_vec();
        p.extend_from_slice(suffix);
        p
    })
    .collect()
}

fn spec_cfg() -> SpecConfig {
    SpecConfig { max_draft: 8, gen_len: 24, ..Default::default() }
}

/// Generated token streams for the shared-prefix workload: batched run
/// plus a sequential re-run of prompt 0 (which by then fully hits the
/// cache on a caching backend).
fn workload_streams(backend: &NativeBackend) -> Vec<Vec<u8>> {
    let batch = BatchEngine::new(backend);
    let requests: Vec<(Vec<u8>, SpecConfig)> =
        prefixed_prompts().into_iter().map(|p| (p, spec_cfg())).collect();
    let mut streams: Vec<Vec<u8>> =
        batch.run_spec(&requests).expect("batched spec").into_iter().map(|r| r.tokens).collect();
    let engine = Engine::new(backend);
    streams.push(engine.generate_spec(&prefixed_prompts()[0], &spec_cfg()).expect("rerun").tokens);
    streams
}

#[test]
fn shared_prefix_decoding_is_bit_identical_to_independent() {
    let mut reference: Option<Vec<Vec<u8>>> = None;
    for threads in [1usize, 4] {
        for level in SimdLevel::available() {
            // Caching backend: sequences share prompt pages copy-on-write.
            let mut cached = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
            cached.set_threads(threads);
            cached.set_simd(level);
            // Independent backend: prefix cache disabled, every sequence
            // owns all of its pages (the dense-equivalent layout).
            let mut dense = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
            dense.set_threads(threads);
            dense.set_simd(level);
            dense.set_prefix_cache(false);

            let got_cached = workload_streams(&cached);
            let got_dense = workload_streams(&dense);
            let what = format!("T={threads} simd={}", level.name());
            assert_eq!(got_cached, got_dense, "{what}: sharing changed the tokens");
            let stats = cached.kv_stats();
            assert!(stats.prefix_hit_tokens > 0, "{what}: workload never hit the cache");
            assert!(stats.cow_copies > 0, "{what}: decode never had to COW");
            assert_eq!(dense.kv_stats().prefix_hit_tokens, 0, "{what}: dense backend cached");
            match &reference {
                None => reference = Some(got_cached),
                Some(want) => {
                    assert_eq!(&got_cached, want, "{what}: diverged from T=1 scalar")
                }
            }
        }
    }
}

#[test]
fn concurrent_batch_steps_over_shared_pages_stay_bitwise_correct() {
    // Two sequences sharing every prompt page, decoded simultaneously
    // from two OS threads through the slot arena: the workspace lock
    // serializes page access, COW keeps their writes private, and both
    // must reproduce the single-threaded reference bitwise.
    let prompt: Vec<u8> = {
        let mut p = SHARED_PREFIX.to_vec();
        p.extend_from_slice(b"Q: carol has 7 cards and gives away 3. how many left?\nA: ");
        p
    };
    let b = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
    let mut toks: Vec<i32> = prompt.iter().map(|&c| c as i32).collect();
    let plen = toks.len();
    toks.resize(b.prefill_len(), b' ' as i32);
    let steps: Vec<i32> = (0..8).map(|k| 65 + k).collect();

    // Single-sequence reference on an independent backend.
    let reference: Vec<Vec<u32>> = {
        let dense = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
        dense.set_prefix_cache(false);
        let mut state = dense.prefill(&toks, plen).expect("prefill").state;
        let mut rows = Vec::new();
        for (k, &t) in steps.iter().enumerate() {
            let out = dense.decode_full(t, plen + k, state).expect("decode");
            rows.push(bits(&out.logits));
            state = out.state;
        }
        rows
    };

    // Two slots over the caching backend; the second prefill reuses the
    // first's pages through the tree.
    let slots = [b.alloc_slot(), b.alloc_slot()];
    b.prefill_batch(&slots[..1], &[toks.clone()], &[plen]).expect("prefill a");
    b.prefill_batch(&slots[1..], &[toks.clone()], &[plen]).expect("prefill b");
    assert!(b.kv_stats().prefix_hit_tokens > 0, "second prefill must hit the cache");
    assert!(b.kv_stats().pages_shared > 0, "the two sequences must share pages");

    let cow_before = b.kv_stats().cow_copies;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|w| {
                let b = &b;
                let slot = slots[w];
                let steps = &steps;
                scope.spawn(move || -> Vec<Vec<u32>> {
                    let mut rows = Vec::new();
                    for (k, &t) in steps.iter().enumerate() {
                        let out = b
                            .decode_full_batch(&[slot], &[t], &[plen + k])
                            .expect("concurrent decode");
                        rows.push(out[0].iter().map(|v| v.to_bits()).collect());
                    }
                    rows
                })
            })
            .collect();
        for h in handles {
            let rows = h.join().expect("worker");
            assert_eq!(rows, reference, "concurrent shared-page decode diverged");
        }
    });
    assert!(b.kv_stats().cow_copies > cow_before, "shared tail pages must COW");
    for s in slots {
        b.free_slot(s);
    }
    assert_eq!(b.arena().in_use(), 0, "leaked KV slots");
}
