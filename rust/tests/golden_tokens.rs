//! Golden token-stream snapshot tests: pin draft/full/verify token
//! sequences and accept-length traces for every builtin zoo model, in both
//! engine modes (speculative + autoregressive) and both batch sizes (1 and
//! 4), so any kernel rewrite that changes output bits fails loudly.
//!
//! Snapshot lifecycle:
//! * **First run** (no `rust/tests/goldens/<model>.golden` yet): the test
//!   records the snapshot and passes, printing where it wrote it.  CI runs
//!   the debug suite first, so the release suite of the same workspace
//!   compares against the debug-recorded snapshots — a cross-profile
//!   bit-identity check on every push.
//! * **Subsequent runs**: the regenerated stream must match the file
//!   byte-for-byte.  `SPEQ_UPDATE_GOLDENS=1 cargo test --test
//!   golden_tokens` re-records after an *intentional* output change.
//!
//! Independent of the files, every run asserts the structural identities:
//! greedy speculative output == the autoregressive baseline, and batch-4
//! output == batch-1 output per sequence.

use std::fmt::Write as _;
use std::fs;
use std::path::PathBuf;

use speq::model::SamplingParams;
use speq::runtime::{Backend, NativeBackend};
use speq::specdec::{ArSession, BatchEngine, Engine, GenResult, GenSession, SpecConfig};

const GEN_LEN: usize = 28;
const MAX_DRAFT: usize = 8;
const BASE_PROMPT: &[u8] = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";

fn goldens_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/goldens")
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// `drafted:accepted:early_exit` per draft-verify iteration.
fn trace_str(r: &GenResult) -> String {
    r.trace
        .iterations
        .iter()
        .map(|i| format!("{}:{}:{}", i.drafted, i.accepted, i.early_exit as u8))
        .collect::<Vec<_>>()
        .join(",")
}

fn spec_cfg() -> SpecConfig {
    SpecConfig { max_draft: MAX_DRAFT, gen_len: GEN_LEN, ..Default::default() }
}

/// Batch prompts: sequence 0 is the batch-1 prompt (so batch-vs-single
/// identity is directly visible in the snapshot); the rest diverge.
fn batch_prompts() -> Vec<Vec<u8>> {
    (0..4usize)
        .map(|i| {
            let mut p = BASE_PROMPT.to_vec();
            if i > 0 {
                p.push(b'0' + i as u8);
            }
            p
        })
        .collect()
}

/// Generate every pinned stream for one model and render the snapshot.
fn render(model: &str) -> String {
    let backend = NativeBackend::builtin(model).expect("builtin model");
    let engine = Engine::new(&backend);
    let spec1 = engine.generate_spec(BASE_PROMPT, &spec_cfg()).expect("spec b1");
    let ar1 =
        engine.generate_ar(BASE_PROMPT, GEN_LEN, SamplingParams::greedy()).expect("ar b1");
    assert_eq!(spec1.tokens.len(), GEN_LEN, "{model}: clamped spec generation");
    // The paper's lossless claim: greedy speculative decoding must be
    // bit-identical to the autoregressive baseline.
    assert_eq!(spec1.tokens, ar1.tokens, "{model}: greedy spec != AR");

    let batch = BatchEngine::new(&backend);
    let requests: Vec<(Vec<u8>, SpecConfig)> =
        batch_prompts().into_iter().map(|p| (p, spec_cfg())).collect();
    let spec4 = batch.run_spec(&requests).expect("spec b4");
    assert_eq!(spec4[0].tokens, spec1.tokens, "{model}: spec batch-4 seq 0 != batch-1");

    let ar_sessions: Vec<GenSession> = batch_prompts()
        .iter()
        .map(|p| {
            ArSession::new(&backend, p, GEN_LEN, SamplingParams::greedy())
                .map(GenSession::Ar)
                .expect("ar session")
        })
        .collect();
    let ar4 = batch.run(ar_sessions).expect("ar b4");
    assert_eq!(ar4[0].tokens, ar1.tokens, "{model}: AR batch-4 seq 0 != batch-1");
    for (i, (s, a)) in spec4.iter().zip(&ar4).enumerate() {
        assert_eq!(s.tokens, a.tokens, "{model}: batched greedy spec != AR for seq {i}");
    }
    assert_eq!(backend.arena().in_use(), 0, "{model}: leaked KV slots");

    let mut out = String::new();
    writeln!(out, "# golden token streams for {model} (recorded by golden_tokens.rs)").unwrap();
    writeln!(out, "# regenerate: SPEQ_UPDATE_GOLDENS=1 cargo test --test golden_tokens").unwrap();
    writeln!(out, "spec_b1 tokens={} trace={}", hex(&spec1.tokens), trace_str(&spec1)).unwrap();
    writeln!(out, "ar_b1 tokens={}", hex(&ar1.tokens)).unwrap();
    for (i, r) in spec4.iter().enumerate() {
        writeln!(out, "spec_b4[{i}] tokens={} trace={}", hex(&r.tokens), trace_str(r)).unwrap();
    }
    for (i, r) in ar4.iter().enumerate() {
        writeln!(out, "ar_b4[{i}] tokens={}", hex(&r.tokens)).unwrap();
    }
    out
}

fn check(model: &str) {
    let rendered = render(model);
    let dir = goldens_dir();
    let path = dir.join(format!("{model}.golden"));
    // Re-record only on an affirmative value: `SPEQ_UPDATE_GOLDENS=0` (or
    // empty) must still compare, not silently overwrite the snapshots.
    let update = std::env::var("SPEQ_UPDATE_GOLDENS")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if path.exists() && !update {
        let want = fs::read_to_string(&path).expect("read golden snapshot");
        assert_eq!(
            rendered, want,
            "{model}: token streams diverged from {} — a kernel change altered \
             output bits; if intentional, re-record with SPEQ_UPDATE_GOLDENS=1",
            path.display()
        );
    } else {
        fs::create_dir_all(&dir).expect("create goldens dir");
        fs::write(&path, &rendered).expect("write golden snapshot");
        eprintln!("recorded golden snapshot at {}", path.display());
    }
}

#[test]
fn golden_vicuna_7b_tiny() {
    check("vicuna-7b-tiny");
}

#[test]
fn golden_llama2_7b_tiny() {
    check("llama2-7b-tiny");
}

#[test]
fn golden_llama3_1_8b_tiny() {
    check("llama3.1-8b-tiny");
}

#[test]
fn golden_llama3_2_3b_tiny() {
    check("llama3.2-3b-tiny");
}

#[test]
fn golden_llama2_13b_tiny() {
    check("llama2-13b-tiny");
}
