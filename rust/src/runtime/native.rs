//! [`NativeBackend`]: a pure-Rust interpreter for the tiny SPEQ transformer.
//!
//! Executes the same architecture as the AOT-compiled HLO graphs
//! (`python/compile/model.py`) directly from [`HostWeights`]: RMSNorm +
//! RoPE attention + SiLU-gated MLP, KV cache in host memory.
//!
//! **Bit-plane packed weight store.**  Every quantizable linear's
//! kernel-facing copy lives once, in BSFP-packed form ([`LinearStore`]):
//! a nibble-packed *prefix plane* (the 4-bit `W_q` codes) plus a
//! 12-bit-packed *residual plane* (the `W_r` remainders) with the Eq. 4
//! group scales alongside.  The cache-blocked kernels in
//! [`super::kernels`] decode on the fly: the draft pass streams only the
//! prefix plane + scales (a quarter of the full pass's weight bytes —
//! the paper's headline), while the full and verify passes stream prefix
//! + residual (exactly the FP16 footprint) and reconstruct the original
//! bits losslessly.  Tensors the planes cannot reproduce exactly
//! (Algorithm-1 outliers, transformed non-FP16 values, non-finite
//! values) fall back to the dense f32 tensor for the full pass, so
//! full-pass exactness holds unconditionally.  A [`TrafficCounters`]
//! instance counts the weight bytes each pass streams, surfaced through
//! [`Backend::traffic`].
//!
//! Residency: vs the retired layout (dense f32 full + dense f32 draft +
//! u16 bits ≈ 10 B/weight), a packed linear now holds planes + scales
//! (≈ 2.5 B/weight) plus the f32 expansion (4 B/weight) kept only for
//! the cold [`Backend::weights`] analysis/transform API — the redundant
//! u16 bit copy is dropped at load (the planes are those bits).
//!
//! **Parallel runtime.**  Every GEMM runs on a backend-owned persistent
//! [`WorkerPool`] ([`super::pool`]), sharded over contiguous
//! output-column ranges; attention is parallelized over `(sequence,
//! head)` pairs.  The pool width comes from [`NativeConfig`] (the
//! `--threads` CLI knob / `SPEQ_THREADS` env var, 0 = auto-detect) and is
//! *purely* a wall-clock knob: every output element keeps its exact
//! ascending-index accumulation order, so results are bitwise identical
//! for every thread count (pinned by `prop_threads.rs` and the goldens).
//!
//! **Flat workspace.**  `step_batch` runs entirely out of a reusable
//! [`Workspace`] of flat `B x n` activation matrices (ping-pong residual
//! stream, attention scores/context, MLP gate/up, logits, kernel decode
//! scratch).  Buffers grow monotonically to the largest batch seen
//! (warm-up); after that a step performs no workspace allocation inside
//! the interpreter — `step_batch` debug-asserts it.  The attention
//! `scores` scratch is sized to the live max position of the batch
//! (rounded up to page granularity), not to the full `cache_len`.
//!
//! **Paged KV cache + prefix sharing.**  A sequence's KV rows live in
//! fixed-size pages ([`super::paging`]: [`PAGE_TOKENS`] positions x all
//! layers/heads each) referenced through a per-sequence page table
//! ([`NativeState`]), so a sequence only occupies memory for positions
//! it has written — max concurrency is bounded by *live* tokens, not by
//! worst-case context length.  Prompt prefixes are interned in a radix
//! tree ([`super::prefix`]): prefill looks the prompt up first and
//! reuses every cached whole-page prefix by reference (refcounted,
//! copy-on-write on first write — including `verify` overwriting drafted
//! positions), running the forward pass over only the novel suffix.
//! Reuse is bit-exact because cached pages were written by a
//! deterministic prefill of the same tokens at the same absolute
//! positions.  All page-*data* access (gather in attention, the
//! per-position KV write, COW clones) happens while the workspace lock
//! is held, which serializes `step_batch` bodies; page *metadata* is
//! guarded by the allocator's own lock.
//!
//! Determinism contract: `decode_full` and each row of `verify` run the
//! exact same code path over the exact same f32 operations, which makes
//! greedy speculative decoding *bit-identical* to the autoregressive
//! baseline — the property `integration_engine.rs` asserts.  The kernel
//! accumulation order is identical across the dense and packed paths, so
//! the packed store is also bit-identical to the retired dual dense
//! full/draft weight maps (pinned by `rust/tests/goldens/`).
//!
//! Weights come from three sources:
//! * [`NativeBackend::from_manifest`] — trained `weights.bin` artifacts
//!   (no HLO or XLA library needed);
//! * [`NativeBackend::builtin`] — the built-in synthetic zoo mirroring the
//!   five paper-analog configs, constructed so next-token predictions are
//!   confident (a stand-in for the trained near-zero-loss checkpoints);
//! * [`NativeBackend::synthetic`] — custom configs for tests.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::backend::{
    Backend, BackendState, PassKind, SeqSlot, SlotArena, StepOutput, TrafficCounters,
    TrafficSnapshot, VerifyOutput,
};
use super::kernels::{
    axpy, dot, gemm_dense, gemm_draft_prefix, gemm_full_planes, SCRATCH_ROWS,
};
use super::paging::{KvStats, PageAllocator, PageId, PagePtr, PAGE_TOKENS};
use super::pool::{SharedSlice, WorkerPool};
use super::prefix::PrefixTree;
use crate::bsfp::simd::{decode_draft_row_pair, draft_lut};
use crate::bsfp::{
    f16_bits_to_f32, f32_to_f16_bits, fp16_exact_in_domain, quantize_tensor, PlanePair,
    SimdLevel, GROUP_SIZE,
};
use crate::model::{load_weights, HostWeights, Manifest, ModelConfig};
use crate::util::rng::Rng;

/// Logits slots in the state (max draft length 20 + 1 bonus), mirroring
/// `python/compile/model.py::S_SLOTS`.
pub const S_SLOTS: usize = 21;

/// Max pages the prefix tree pins (LRU leaf eviction past this).  At the
/// builtin-zoo geometry one page is `n_layers * 2 * 16 * d_model` f32s,
/// so 1024 pages bound the cache to a few hundred MB worst case while
/// covering far more distinct prompts than the serving queue admits.
const PREFIX_CACHE_PAGES: usize = 1024;

/// The built-in synthetic zoo: the five paper-analog configurations of
/// `python/compile/model.py::MODEL_ZOO` (name, paper analog, layers,
/// d_model, d_ff, heads, seed).
const BUILTIN_ZOO: [(&str, &str, usize, usize, usize, usize, u64); 5] = [
    ("vicuna-7b-tiny", "Vicuna-7b", 2, 128, 256, 4, 11),
    ("llama2-7b-tiny", "Llama2-7b", 3, 128, 384, 4, 22),
    ("llama3.1-8b-tiny", "Llama3.1-8b", 4, 128, 384, 4, 33),
    ("llama3.2-3b-tiny", "Llama3.2-3b", 2, 128, 384, 4, 44),
    ("llama2-13b-tiny", "Llama2-13b", 4, 256, 512, 8, 55),
];

/// How synthetic weights are initialized.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitStyle {
    /// Random init plus a byte-successor head structure that makes
    /// next-token predictions confident — the analog of the trained
    /// near-zero-loss checkpoints (high draft accept rate).
    Confident,
    /// Plain random init: diffuse, low-confidence predictions (exercises
    /// the rejection/correction paths).
    Random,
}

/// Runtime knobs of the native backend (everything *outside* the model:
/// results are bit-identical for every setting).
#[derive(Debug, Clone)]
pub struct NativeConfig {
    /// Worker-pool width for the parallel kernels: the calling thread plus
    /// `threads - 1` persistent workers.  `0` = auto-detect
    /// (`std::thread::available_parallelism`).  Purely a wall-clock knob —
    /// the column-sharded kernels keep every output element's accumulation
    /// order thread-count invariant.
    pub threads: usize,
    /// SIMD dispatch tier for the plane decoders and kernel updates
    /// (`SPEQ_SIMD` env var / `--simd` CLI knob; defaults to the best
    /// tier this host supports).  Also purely a wall-clock knob: every
    /// tier produces bitwise identical results (`bsfp::simd`).
    pub simd: SimdLevel,
}

impl Default for NativeConfig {
    /// `SPEQ_THREADS` when set (`0` = auto-detect), else 1 (serial);
    /// `SPEQ_SIMD` when set, else the best detected tier.
    fn default() -> Self {
        let threads = std::env::var("SPEQ_THREADS")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(1);
        Self { threads, simd: SimdLevel::from_env() }
    }
}

impl NativeConfig {
    /// A config with an explicit pool width (`0` = auto-detect); the SIMD
    /// tier still comes from `SPEQ_SIMD` / detection.
    pub fn with_threads(threads: usize) -> Self {
        Self { threads, ..Self::default() }
    }

    /// Builder-style SIMD tier override (clamped to this host's support
    /// at backend construction).
    pub fn with_simd(mut self, simd: SimdLevel) -> Self {
        self.simd = simd;
        self
    }

    /// The pool width this config resolves to (`0` -> core count).
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }
}

/// Host-memory request state: a page table into the backend-owned
/// [`PageAllocator`].  `table[pos / PAGE_TOKENS]` is the page holding
/// position `pos`'s KV rows; the table grows as the sequence advances
/// and only ever covers written positions.  Entries may be shared with
/// the prefix tree or other sequences (refcounted) — the backend makes a
/// page private (copy-on-write) before writing into it.  Dropping the
/// state releases every reference.
pub struct NativeState {
    alloc: Arc<PageAllocator>,
    table: Vec<PageId>,
}

impl Drop for NativeState {
    fn drop(&mut self) {
        for &p in &self.table {
            // A failed release means the id went stale through allocator
            // misuse; dropping is not the place to surface it.
            let _ = self.alloc.release(p);
        }
    }
}

/// Reusable flat activation buffers for `step_batch` — all row-major
/// `B x n` matrices plus the kernels' block-decode scratch.  Buffers grow
/// monotonically to the largest batch seen and are reused verbatim after
/// that (`growths` counts the growth events; the steady state adds zero
/// heap allocation per step).
struct Workspace {
    /// Batch rows the buffers are currently sized for.
    cap_b: usize,
    /// Residual stream, `B x d`.
    x: Vec<f32>,
    /// RMSNorm output (attention + MLP + final), `B x d`.
    h: Vec<f32>,
    /// Attention projections, `B x d` each.
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention context (head-concatenated), `B x d`.
    ctx: Vec<f32>,
    /// Linear output staging (wo / w_down), `B x d`.
    o: Vec<f32>,
    /// MLP intermediates, `B x d_ff` each.
    gate: Vec<f32>,
    up: Vec<f32>,
    /// Per-(sequence, head) attention scores, `B x n_heads x score_cols`
    /// where `score_cols` is the live max position rounded up to page
    /// granularity — not the full `cache_len` (monotonic growth).
    scores: Vec<f32>,
    /// Per-sequence page-pointer tables the attention gather reads
    /// through, `B x ceil(cache_len / PAGE_TOKENS)`; refilled each step.
    page_ptrs: Vec<PagePtr>,
    /// Output logits, `B x vocab`.
    logits: Vec<f32>,
    /// Kernel decode tiles plus the draft kernel's hoisted-factor row,
    /// `SCRATCH_ROWS x max(d, d_ff, vocab)`.
    scratch: Vec<f32>,
    /// Buffer growth events since construction (warm-up counter).
    growths: u64,
}

impl Workspace {
    fn new() -> Self {
        Self {
            cap_b: 0,
            x: Vec::new(),
            h: Vec::new(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            ctx: Vec::new(),
            o: Vec::new(),
            gate: Vec::new(),
            up: Vec::new(),
            scores: Vec::new(),
            page_ptrs: Vec::new(),
            logits: Vec::new(),
            scratch: Vec::new(),
            growths: 0,
        }
    }

    /// Size every buffer for a batch of `b` attending `score_cols`
    /// positions (no-op once both fit).  `score_cols` tracks the live max
    /// position rounded to page granularity, so short sequences never pay
    /// `cache_len`-sized scores traffic; buffers only ever grow.
    fn prepare(&mut self, c: &ModelConfig, b: usize, score_cols: usize) {
        if b <= self.cap_b && self.scores.len() >= b * c.n_heads * score_cols {
            return;
        }
        let b = b.max(self.cap_b);
        let d = c.d_model;
        let n_max = d.max(c.d_ff).max(c.vocab);
        let pages = (c.cache_len + PAGE_TOKENS - 1) / PAGE_TOKENS;
        self.x.resize(b * d, 0.0);
        self.h.resize(b * d, 0.0);
        self.q.resize(b * d, 0.0);
        self.k.resize(b * d, 0.0);
        self.v.resize(b * d, 0.0);
        self.ctx.resize(b * d, 0.0);
        self.o.resize(b * d, 0.0);
        self.gate.resize(b * c.d_ff, 0.0);
        self.up.resize(b * c.d_ff, 0.0);
        let sneed = (b * c.n_heads * score_cols).max(self.scores.len());
        self.scores.resize(sneed, 0.0);
        self.page_ptrs.resize(b * pages, PagePtr::dangling());
        self.logits.resize(b * c.vocab, 0.0);
        self.scratch.resize(SCRATCH_ROWS * n_max, 0.0);
        self.cap_b = b;
        self.growths += 1;
    }
}

impl NativeState {
    /// Total f32 elements the sequence's pages occupy (diagnostics).
    pub fn kv_len(&self) -> usize {
        self.table.len() * self.alloc.page_elems()
    }

    /// Pages currently referenced by this sequence (diagnostics).
    pub fn pages(&self) -> usize {
        self.table.len()
    }
}

/// One quantizable linear in the kernel-facing packed weight store.
enum LinearStore {
    /// In-domain, exactly-FP16 tensor (every trained/synthetic weight):
    /// the bit planes serve BOTH passes — the full decode is lossless and
    /// the Algorithm-1 tensor scale is 1.0 by construction.  The kernels
    /// never touch the dense f32 expansion (it stays only for the cold
    /// `weights()` API) and the u16 bit copy is dropped at load.
    Packed { planes: PlanePair, scales: Vec<f32> },
    /// Fallback for tensors the planes cannot reproduce exactly
    /// (Algorithm-1 outliers with `max|W| >= 2`, transformed weights that
    /// are not FP16 values): the full pass keeps streaming the dense f32
    /// tensor while the draft pass still reads its quarter-traffic prefix
    /// plane (pre-scaled, exactly as the retired `derive_draft` did).
    Split { prefix: Vec<u8>, scales: Vec<f32>, tensor_scale: f32 },
}

/// A pure-Rust executable model (full target + BSFP draft, shared KV).
pub struct NativeBackend {
    config: ModelConfig,
    slots: usize,
    linears: Vec<String>,
    weights: HostWeights,
    /// The bit-plane packed weight store the kernels stream; linears
    /// absent from the map (non-2-D, in-dim not a group multiple, or
    /// non-finite values) run dense for both passes.
    store: BTreeMap<String, LinearStore>,
    /// Weight bytes streamed per pass (the quarter-to-all accounting).
    traffic: TrafficCounters,
    /// RoPE frequencies, one per half head-dim.
    freqs: Vec<f32>,
    /// Precomputed per-layer parameter names (hot path: no formatting).
    layer_names: Vec<LayerNames>,
    /// Per-sequence KV states for the batched serving API.
    arena: SlotArena,
    /// The paged KV store every sequence's page table points into.
    page_alloc: Arc<PageAllocator>,
    /// Radix tree interning prompt prefixes (pages shared by reference).
    prefix: PrefixTree,
    /// Whether prefill consults/feeds the prefix tree (on by default;
    /// benches disable it to measure the dense-equivalent baseline).
    prefix_enabled: AtomicBool,
    /// Persistent worker pool the column-sharded kernels run on.
    pool: WorkerPool,
    /// SIMD dispatch tier the kernels decode with (resolved once at
    /// construction; always a level this host supports).
    simd: SimdLevel,
    /// Reusable flat activation buffers (one in-flight step at a time;
    /// the mutex keeps the backend `Sync` and is uncontended in practice).
    workspace: Mutex<Workspace>,
}

/// Deterministic `(name, shape)` parameter list — mirrors
/// `python/compile/model.py::param_shapes`.
pub fn param_shapes(cfg: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
    let mut shapes = vec![("embed".to_string(), vec![v, d])];
    for l in 0..cfg.n_layers {
        let p = format!("layer{l}.");
        shapes.push((format!("{p}attn_norm"), vec![d]));
        for w in ["wq", "wk", "wv", "wo"] {
            shapes.push((format!("{p}{w}"), vec![d, d]));
        }
        shapes.push((format!("{p}mlp_norm"), vec![d]));
        shapes.push((format!("{p}w_gate"), vec![d, f]));
        shapes.push((format!("{p}w_up"), vec![d, f]));
        shapes.push((format!("{p}w_down"), vec![f, d]));
    }
    shapes.push(("final_norm".to_string(), vec![d]));
    shapes.push(("lm_head".to_string(), vec![d, v]));
    shapes
}

/// The BSFP-quantized linear names — mirrors
/// `python/compile/model.py::linear_names`.
pub fn linear_names(cfg: &ModelConfig) -> Vec<String> {
    let mut names = Vec::new();
    for l in 0..cfg.n_layers {
        for w in ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"] {
            names.push(format!("layer{l}.{w}"));
        }
    }
    names.push("lm_head".to_string());
    names
}

/// Names of the built-in synthetic models.
pub fn builtin_model_names() -> Vec<&'static str> {
    BUILTIN_ZOO.iter().map(|z| z.0).collect()
}

/// Configuration of a built-in model by name.
pub fn builtin_config(name: &str) -> Result<ModelConfig> {
    let z = BUILTIN_ZOO
        .iter()
        .find(|z| z.0 == name)
        .with_context(|| format!("model {name:?} not in builtin zoo (have {:?})", builtin_model_names()))?;
    let mut cfg = ModelConfig {
        name: z.0.to_string(),
        paper_analog: z.1.to_string(),
        n_layers: z.2,
        d_model: z.3,
        d_ff: z.4,
        n_heads: z.5,
        head_dim: z.3 / z.5,
        vocab: 256,
        cache_len: 512,
        prefill_len: 256,
        param_count: 0,
    };
    cfg.param_count =
        param_shapes(&cfg).iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    Ok(cfg)
}

/// Seed of a built-in model (weights are derived deterministically).
fn builtin_seed(name: &str) -> u64 {
    BUILTIN_ZOO.iter().find(|z| z.0 == name).map(|z| z.6).unwrap_or(1)
}

impl NativeBackend {
    /// Build from explicit weights with the env-default runtime config
    /// (`SPEQ_THREADS`, else serial).
    pub fn from_weights(
        config: ModelConfig,
        linears: Vec<String>,
        weights: HostWeights,
        slots: usize,
    ) -> Result<Self> {
        Self::from_weights_with(config, linears, weights, slots, &NativeConfig::default())
    }

    /// Build from explicit weights (the general constructor); the worker
    /// pool is built once at `native`'s resolved width.
    pub fn from_weights_with(
        config: ModelConfig,
        linears: Vec<String>,
        mut weights: HostWeights,
        slots: usize,
        native: &NativeConfig,
    ) -> Result<Self> {
        anyhow::ensure!(config.n_heads > 0 && config.d_model % config.n_heads == 0,
            "d_model {} not divisible by n_heads {}", config.d_model, config.n_heads);
        let head_dim = config.d_model / config.n_heads;
        anyhow::ensure!(head_dim == config.head_dim,
            "head_dim {} inconsistent with d_model/n_heads = {head_dim}", config.head_dim);
        anyhow::ensure!(head_dim % 2 == 0, "RoPE needs an even head_dim, got {head_dim}");
        anyhow::ensure!(slots >= 2, "need at least 2 logits slots (1 draft + bonus)");
        anyhow::ensure!(config.prefill_len >= 1, "prefill_len must be >= 1");
        for (name, shape) in param_shapes(&config) {
            let n: usize = shape.iter().product();
            let have = weights
                .f32s
                .get(&name)
                .with_context(|| format!("weights missing param {name:?}"))?;
            anyhow::ensure!(have.len() == n, "param {name:?}: {} values, expected {n}", have.len());
        }
        let store = build_store(&weights, &linears);
        // The planes ARE the canonical FP16 bits of a packed linear (the
        // full decode reconstructs them losslessly), so drop the redundant
        // u16 bit copies.  The f32 expansion stays resident for the cold
        // `weights()` analysis/transform API — the kernels never stream it
        // for packed tensors.
        for (name, entry) in &store {
            if matches!(entry, LinearStore::Packed { .. }) {
                weights.bits.remove(name);
            }
        }
        let half = head_dim / 2;
        let freqs: Vec<f32> = (0..half)
            .map(|j| (-(j as f32) * (10000.0f32).ln() / half as f32).exp())
            .collect();
        let layer_names = (0..config.n_layers).map(LayerNames::layer).collect();
        // One page = all layers/heads of PAGE_TOKENS positions; the prefix
        // tree may pin at most PREFIX_CACHE_PAGES pages (LRU past that).
        let page_elems = config.n_layers * 2 * PAGE_TOKENS * config.d_model;
        Ok(Self {
            config,
            slots,
            linears,
            weights,
            store,
            traffic: TrafficCounters::new(),
            freqs,
            layer_names,
            arena: SlotArena::new(),
            page_alloc: Arc::new(PageAllocator::new(page_elems)),
            prefix: PrefixTree::new(PREFIX_CACHE_PAGES),
            prefix_enabled: AtomicBool::new(true),
            pool: WorkerPool::new(native.resolved_threads()),
            simd: native.simd.resolve(),
            workspace: Mutex::new(Workspace::new()),
        })
    }

    /// Resize the worker pool (`0` = auto-detect).  Results are
    /// bit-identical for every width — this is purely a wall-clock knob.
    pub fn set_threads(&mut self, threads: usize) {
        let t = NativeConfig::with_threads(threads).resolved_threads();
        if t != self.pool.threads() {
            self.pool = WorkerPool::new(t);
        }
    }

    /// Current worker-pool width (caller thread included).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The SIMD dispatch tier the kernels run at.  Results are
    /// bit-identical for every tier — this is purely a wall-clock knob.
    pub fn simd_level(&self) -> SimdLevel {
        self.simd
    }

    /// Force a SIMD tier (clamped to this host's support); tests and the
    /// scalar-baseline bench comparison use this.
    pub fn set_simd(&mut self, level: SimdLevel) {
        self.simd = level.resolve();
    }

    /// Workspace buffer-growth events so far.  Growth happens only while
    /// warming up to a larger batch (or a deeper attended position); a
    /// steady-state `step_batch` performs no workspace allocation inside
    /// the interpreter (debug-asserted there).
    pub fn workspace_growths(&self) -> u64 {
        self.workspace.lock().unwrap_or_else(|e| e.into_inner()).growths
    }

    /// Enable/disable the prompt prefix cache.  Disabling also clears the
    /// tree (releasing its page references), which makes the backend
    /// behave exactly like the dense per-sequence layout — every prompt
    /// token is recomputed and no page is ever shared.  Results are
    /// bit-identical either way; this is purely a memory/throughput knob.
    pub fn set_prefix_cache(&self, enabled: bool) {
        self.prefix_enabled.store(enabled, Ordering::Relaxed);
        if !enabled {
            self.prefix.clear(&self.page_alloc);
        }
    }

    /// Whether prefill currently consults the prefix tree.
    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix_enabled.load(Ordering::Relaxed)
    }

    /// The backend's page allocator (occupancy probes in tests/benches).
    pub fn kv_allocator(&self) -> &Arc<PageAllocator> {
        &self.page_alloc
    }

    /// The backend's prefix tree (diagnostics).
    pub fn prefix_tree(&self) -> &PrefixTree {
        &self.prefix
    }

    /// Load trained weights from an artifacts manifest (no HLO needed).
    pub fn from_manifest(manifest: &Manifest, name: &str) -> Result<Self> {
        Self::from_manifest_with(manifest, name, &NativeConfig::default())
    }

    /// [`NativeBackend::from_manifest`] with an explicit runtime config.
    pub fn from_manifest_with(
        manifest: &Manifest,
        name: &str,
        native: &NativeConfig,
    ) -> Result<Self> {
        let entry = manifest.model(name)?;
        let weights = load_weights(manifest.path(&entry.weights), entry)
            .with_context(|| format!("loading weights for {name}"))?;
        Self::from_weights_with(
            entry.config.clone(),
            entry.linears.clone(),
            weights,
            entry.state_slots,
            native,
        )
    }

    /// A built-in synthetic model by zoo name (no artifacts required).
    pub fn builtin(name: &str) -> Result<Self> {
        Self::builtin_with(name, &NativeConfig::default())
    }

    /// [`NativeBackend::builtin`] with an explicit runtime config.
    pub fn builtin_with(name: &str, native: &NativeConfig) -> Result<Self> {
        let config = builtin_config(name)?;
        Self::synthetic_with(config, S_SLOTS, builtin_seed(name), InitStyle::Confident, native)
    }

    /// Build a synthetic model for an arbitrary configuration.
    ///
    /// `config.param_count` is recomputed from the shapes.  All non-norm
    /// parameters are rounded to FP16 (the codec's substrate), exactly as
    /// the trained artifacts are.
    pub fn synthetic(
        config: ModelConfig,
        slots: usize,
        seed: u64,
        style: InitStyle,
    ) -> Result<Self> {
        Self::synthetic_with(config, slots, seed, style, &NativeConfig::default())
    }

    /// [`NativeBackend::synthetic`] with an explicit runtime config.
    pub fn synthetic_with(
        mut config: ModelConfig,
        slots: usize,
        seed: u64,
        style: InitStyle,
        native: &NativeConfig,
    ) -> Result<Self> {
        config.param_count =
            param_shapes(&config).iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        let weights = synthetic_weights(&config, seed, style);
        Self::from_weights_with(config.clone(), linear_names(&config), weights, slots, native)
    }

    /// Pages a full-length sequence spans (the page-table stride of the
    /// workspace pointer table).
    fn pages_per_seq(&self) -> usize {
        (self.config.cache_len + PAGE_TOKENS - 1) / PAGE_TOKENS
    }

    /// In-page offset of cache row `(layer, which, pos)`; the row holds
    /// `n_heads * head_dim` contiguous f32s.  Pages are laid out
    /// `[L, 2, PAGE_TOKENS, d_model]`, mirroring the retired flat
    /// `[L, 2, cache_len, d_model]` layout with `cache_len` folded to
    /// page granularity.
    fn page_offset(&self, layer: usize, which: usize, pos: usize) -> usize {
        let c = &self.config;
        ((layer * 2 + which) * PAGE_TOKENS + pos % PAGE_TOKENS) * c.n_heads * c.head_dim
    }

    /// A fresh empty sequence state over this backend's page allocator.
    fn fresh_state(&self) -> NativeState {
        NativeState { alloc: Arc::clone(&self.page_alloc), table: Vec::new() }
    }

    fn take_state(&self, state: BackendState) -> Result<NativeState> {
        match state {
            BackendState::Native(s) => {
                anyhow::ensure!(
                    Arc::ptr_eq(&s.alloc, &self.page_alloc),
                    "state's KV elements live in another backend's page allocator \
                     (state from another model?)"
                );
                Ok(s)
            }
            #[cfg(feature = "pjrt")]
            BackendState::Pjrt(_) => {
                anyhow::bail!("native backend received a PJRT device state")
            }
        }
    }

    /// Make `pos` writable for `st`: extend the page table up to `pos`'s
    /// page (fresh zeroed pages) and take private ownership of that page
    /// (copy-on-write when the prefix tree or another sequence shares
    /// it).  Must run under the workspace lock — COW copies page data.
    fn ensure_writable(&self, st: &mut NativeState, pos: usize) -> Result<()> {
        let pi = pos / PAGE_TOKENS;
        while st.table.len() <= pi {
            // Fallible: a configured page budget (or an injected
            // `page.alloc=exhaust` fault) surfaces here as a typed
            // `PageExhausted` step error that the scheduler can contain
            // per-sequence and answer with the degradation ladder.
            st.table.push(self.page_alloc.try_alloc()?);
        }
        let (id, _copied) = self.page_alloc.make_unique(st.table[pi])?;
        st.table[pi] = id;
        Ok(())
    }

    /// Dense f32 view of a non-linear parameter (embed, norms).
    fn p(&self, name: &str) -> &[f32] {
        self.weights.f32(name)
    }

    /// Batched linear `X (B, k) @ name`, flat row-major in and out, routed
    /// through the bit-plane store and counted against `kind`'s traffic
    /// bucket.  The draft pass streams the prefix plane + Eq. 4 scales;
    /// every other pass streams prefix + residual (packed tensors) or the
    /// dense fallback.  Traffic is counted **once per call**, on the
    /// calling thread, never inside kernel shards — the pool only ever
    /// executes closures that don't touch the counters.
    #[allow(clippy::too_many_arguments)]
    fn mm(
        &self,
        kind: PassKind,
        xs: &[f32],
        b: usize,
        name: &str,
        k: usize,
        n: usize,
        out: &mut [f32],
        scratch: &mut [f32],
    ) {
        match self.store.get(name) {
            Some(LinearStore::Packed { planes, scales }) => {
                if kind == PassKind::Draft {
                    self.traffic
                        .add_bytes(kind, (planes.prefix_bytes() + scales.len() * 4) as u64);
                    gemm_draft_prefix(
                        &self.pool,
                        self.simd,
                        xs,
                        b,
                        &planes.prefix,
                        scales,
                        1.0,
                        k,
                        n,
                        scratch,
                        out,
                    )
                } else {
                    self.traffic.add_bytes(kind, planes.full_bytes() as u64);
                    gemm_full_planes(&self.pool, self.simd, xs, b, planes, scratch, out)
                }
            }
            Some(LinearStore::Split { prefix, scales, tensor_scale }) => {
                if kind == PassKind::Draft {
                    self.traffic
                        .add_bytes(kind, (prefix.len() + scales.len() * 4 + 4) as u64);
                    gemm_draft_prefix(
                        &self.pool,
                        self.simd,
                        xs,
                        b,
                        prefix,
                        scales,
                        *tensor_scale,
                        k,
                        n,
                        scratch,
                        out,
                    )
                } else {
                    self.traffic.add_bytes(kind, (k * n * 4) as u64);
                    gemm_dense(&self.pool, self.simd, xs, b, self.weights.f32(name), k, n, out)
                }
            }
            None => {
                self.traffic.add_bytes(kind, (k * n * 4) as u64);
                gemm_dense(&self.pool, self.simd, xs, b, self.weights.f32(name), k, n, out)
            }
        }
    }

    /// How the store keeps one linear: `"packed"` (planes serve both
    /// passes), `"split"` (dense full + prefix-plane draft), or `"dense"`
    /// (not quantizable; both passes dense).  Diagnostics and tests.
    pub fn store_kind(&self, name: &str) -> &'static str {
        match self.store.get(name) {
            Some(LinearStore::Packed { .. }) => "packed",
            Some(LinearStore::Split { .. }) => "split",
            None => "dense",
        }
    }

    /// Materialize the store's view of one linear exactly as the kernels
    /// stream it (`draft == false`: the full pass; `draft == true`: the
    /// quarter-traffic draft pass).  Diagnostics and the bit-identity
    /// tests — the hot kernels never materialize this.
    pub fn decode_linear(&self, name: &str, draft: bool) -> Vec<f32> {
        let shape = self.weights.shape(name);
        let (k, n) = (shape[0], *shape.get(1).unwrap_or(&1));
        // Stream the nibble-packed prefix plane row-pair-wise through the
        // kernels' shared LUT path — no O(k*n) unpacked-code temporary.
        // Row pairs (2p, 2p+1) share a scale-group row (GROUP_SIZE is
        // even), and the `scale / tensor_scale` factor is hoisted to a
        // once-per-group row, exactly as the draft GEMM kernel does.
        let decode_draft_plane = |prefix: &[u8], scales: &[f32], tensor_scale: f32| -> Vec<f32> {
            let lut = draft_lut();
            let mut out = vec![0.0f32; k * n];
            let mut pre = vec![0.0f32; n];
            let mut cur_group = usize::MAX;
            for p in 0..k / 2 {
                let g = 2 * p / GROUP_SIZE;
                if g != cur_group {
                    cur_group = g;
                    for (pv, &sv) in pre.iter_mut().zip(&scales[g * n..(g + 1) * n]) {
                        *pv = sv / tensor_scale;
                    }
                }
                let prow = &prefix[p * n..(p + 1) * n];
                let (lo, hi) = out[2 * p * n..(2 * p + 2) * n].split_at_mut(n);
                decode_draft_row_pair(self.simd, prow, &pre, &lut, lo, hi);
            }
            out
        };
        match self.store.get(name) {
            Some(LinearStore::Packed { planes, scales }) => {
                if draft {
                    decode_draft_plane(&planes.prefix, scales, 1.0)
                } else {
                    planes.decode_full_f32()
                }
            }
            Some(LinearStore::Split { prefix, scales, tensor_scale }) => {
                if draft {
                    decode_draft_plane(prefix, scales, *tensor_scale)
                } else {
                    self.weights.f32(name).to_vec()
                }
            }
            None => self.weights.f32(name).to_vec(),
        }
    }

    /// One decode step at `pos`: writes this position's KV, attends the
    /// cache up to `pos`, returns the logits row.  Implemented as a
    /// batch of one so single-sequence and batched execution share one
    /// code path (the bit-identity contract of the batched serving API).
    fn step(
        &self,
        kind: PassKind,
        token: i32,
        pos: usize,
        state: &mut NativeState,
    ) -> Result<Vec<f32>> {
        let mut rows = self.step_batch(kind, &[token], &[pos], &mut [state])?;
        Ok(rows.pop().expect("batch of one"))
    }

    /// One decode step for `B` independent sequences in lockstep.
    ///
    /// Every linear streams each weight row exactly once for the whole
    /// batch (`B×K · K×N` instead of `B` GEMVs) — the memory-bandwidth win
    /// continuous batching exists for.  Activations live in the flat
    /// backend-owned [`Workspace`] (no per-layer/per-token allocation
    /// after warm-up; debug-asserted below), linears run column-sharded on
    /// the worker pool, and attention runs parallel over `(sequence,
    /// head)` pairs.  Per-sequence accumulation order is identical to a
    /// serial batch of one on one thread, so results are bit-identical to
    /// sequential execution regardless of batch composition or pool width.
    fn step_batch(
        &self,
        kind: PassKind,
        tokens: &[i32],
        pos: &[usize],
        states: &mut [&mut NativeState],
    ) -> Result<Vec<Vec<f32>>> {
        let c = &self.config;
        let b = tokens.len();
        anyhow::ensure!(
            pos.len() == b && states.len() == b,
            "step_batch: mismatched batch arity ({b} tokens, {} pos, {} states)",
            pos.len(),
            states.len()
        );
        for (&token, &p) in tokens.iter().zip(pos) {
            anyhow::ensure!(
                token >= 0 && (token as usize) < c.vocab,
                "token {token} outside vocab {}",
                c.vocab
            );
            anyhow::ensure!(p < c.cache_len, "position {p} exceeds cache_len {}", c.cache_len);
        }
        // Fault probe: an armed `worker.shard` site panics inside a pool
        // job, exercising the worker pool's real panic plumbing (drain,
        // re-raise on the caller) and the engine's `catch_unwind`
        // containment above.  Single atomic load when no plan is active.
        if crate::faults::enabled()
            && matches!(
                crate::faults::hit(crate::faults::FaultSite::WorkerShard),
                Some(crate::faults::FaultAction::Panic)
            )
        {
            // Job 1 lands on a pool worker when one exists (job 0 runs on
            // the caller); on a serial pool both run on the caller — the
            // panic is raised either way.
            self.pool.run(2, |j| {
                if j == 1 {
                    panic!("injected worker shard panic (fault site worker.shard)");
                }
            });
        }
        let (d, hd, nh) = (c.d_model, c.head_dim, c.n_heads);
        let (ff, v) = (c.d_ff, c.vocab);
        // Attention scratch covers the deepest attended position of this
        // batch, rounded up to page granularity — not the full cache_len.
        let max_pos = pos.iter().copied().max().unwrap_or(0);
        let scols = (max_pos / PAGE_TOKENS + 1) * PAGE_TOKENS;
        let stride = self.pages_per_seq();
        // Traffic: one token (or verify row) per sequence; the embedding
        // row gather per sequence plus each norm vector once per batch
        // (linears are counted inside `mm`).
        self.traffic.add_tokens(kind, b as u64);
        self.traffic
            .add_bytes(kind, ((b * d + (2 * c.n_layers + 1) * d) * 4) as u64);
        let mut guard = self.workspace.lock().unwrap_or_else(|e| e.into_inner());
        let ws = &mut *guard;
        // A workspace already sized for this batch is warm: the entire
        // step below must then run workspace-allocation-free (asserted at
        // the end; page-table growth is the allocator's business).
        let was_warm = ws.cap_b >= b && ws.scores.len() >= b * nh * scols;
        let growths_at_start = ws.growths;
        ws.prepare(c, b, scols);
        // Page bookkeeping, serialized by the workspace lock held above:
        // give every sequence private ownership of the page it is about
        // to write (allocating/COW-cloning as needed), then snapshot the
        // batch's page-pointer tables for the gather below.  Pointers
        // stay valid for the whole step — slabs never move and pages
        // referenced by live tables are never recycled.
        for (i, st) in states.iter_mut().enumerate() {
            self.ensure_writable(st, pos[i])?;
        }
        for (i, st) in states.iter().enumerate() {
            for (j, &pid) in st.table.iter().enumerate().take(pos[i] / PAGE_TOKENS + 1) {
                ws.page_ptrs[i * stride + j] = self.page_alloc.page_ptr(pid)?;
            }
        }
        let embed = self.p("embed");
        for (bi, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            ws.x[bi * d..(bi + 1) * d].copy_from_slice(&embed[t * d..(t + 1) * d]);
        }
        for l in 0..c.n_layers {
            let names = &self.layer_names[l];
            // ---- attention ----
            rmsnorm_rows(&ws.x[..b * d], b, d, self.p(&names.attn_norm), &mut ws.h[..b * d]);
            self.mm(kind, &ws.h[..b * d], b, &names.wq, d, d, &mut ws.q[..b * d], &mut ws.scratch);
            self.mm(kind, &ws.h[..b * d], b, &names.wk, d, d, &mut ws.k[..b * d], &mut ws.scratch);
            self.mm(kind, &ws.h[..b * d], b, &names.wv, d, d, &mut ws.v[..b * d], &mut ws.scratch);
            for i in 0..b {
                rope_in_place(&mut ws.q[i * d..(i + 1) * d], nh, hd, pos[i], &self.freqs);
                rope_in_place(&mut ws.k[i * d..(i + 1) * d], nh, hd, pos[i], &self.freqs);
                // This position's page is exclusively ours
                // (`ensure_writable` above), so the mutable row cannot
                // alias another sequence's data or the prefix tree's.
                let page = ws.page_ptrs[i * stride + pos[i] / PAGE_TOKENS];
                let krow = unsafe { page.row_mut(self.page_offset(l, 0, pos[i]), d) };
                krow.copy_from_slice(&ws.k[i * d..(i + 1) * d]);
                let vrow = unsafe { page.row_mut(self.page_offset(l, 1, pos[i]), d) };
                vrow.copy_from_slice(&ws.v[i * d..(i + 1) * d]);
            }
            ws.ctx[..b * d].fill(0.0);
            {
                // Parallel over (sequence, head) pairs.  Each pair owns a
                // disjoint scores row and context slice; pages are
                // read-only here (all writes happened in the loop above)
                // and the ascending-t gather visits positions in exactly
                // the retired flat-buffer order, so accumulation — and
                // therefore every output bit — is unchanged.
                let scale = 1.0 / (hd as f32).sqrt();
                let qs: &[f32] = &ws.q;
                let scores = SharedSlice::new(&mut ws.scores);
                let ctx = SharedSlice::new(&mut ws.ctx);
                let pptrs: &[PagePtr] = &ws.page_ptrs;
                self.pool.run(b * nh, |pair| {
                    let (i, head) = (pair / nh, pair % nh);
                    let q = &qs[i * d + head * hd..i * d + (head + 1) * hd];
                    // SAFETY: pair (i, head) exclusively owns its scores
                    // row and its head's slice of sequence i's context.
                    let srow = unsafe { scores.slice_mut((i * nh + head) * scols, pos[i] + 1) };
                    let ch = unsafe { ctx.slice_mut(i * d + head * hd, hd) };
                    for (t, s) in srow.iter_mut().enumerate() {
                        let page = pptrs[i * stride + t / PAGE_TOKENS];
                        // SAFETY: position t <= pos[i] was written, so its
                        // page is live; no mutable access is in flight.
                        let kr = unsafe {
                            page.row(self.page_offset(l, 0, t) + head * hd, hd)
                        };
                        *s = dot(q, kr) * scale;
                    }
                    softmax_in_place(srow);
                    for (t, &a) in srow.iter().enumerate() {
                        let page = pptrs[i * stride + t / PAGE_TOKENS];
                        // SAFETY: as above.
                        let vr = unsafe {
                            page.row(self.page_offset(l, 1, t) + head * hd, hd)
                        };
                        axpy(ch, a, vr);
                    }
                });
            }
            self.mm(kind, &ws.ctx[..b * d], b, &names.wo, d, d, &mut ws.o[..b * d], &mut ws.scratch);
            axpy(&mut ws.x[..b * d], 1.0, &ws.o[..b * d]);
            // ---- MLP ----
            rmsnorm_rows(&ws.x[..b * d], b, d, self.p(&names.mlp_norm), &mut ws.h[..b * d]);
            self.mm(
                kind,
                &ws.h[..b * d],
                b,
                &names.w_gate,
                d,
                ff,
                &mut ws.gate[..b * ff],
                &mut ws.scratch,
            );
            self.mm(kind, &ws.h[..b * d], b, &names.w_up, d, ff, &mut ws.up[..b * ff], &mut ws.scratch);
            for (g, &u) in ws.gate[..b * ff].iter_mut().zip(&ws.up[..b * ff]) {
                let s = *g / (1.0 + (-*g).exp());
                *g = s * u;
            }
            self.mm(
                kind,
                &ws.gate[..b * ff],
                b,
                &names.w_down,
                ff,
                d,
                &mut ws.o[..b * d],
                &mut ws.scratch,
            );
            axpy(&mut ws.x[..b * d], 1.0, &ws.o[..b * d]);
        }
        rmsnorm_rows(&ws.x[..b * d], b, d, self.p("final_norm"), &mut ws.h[..b * d]);
        self.mm(kind, &ws.h[..b * d], b, "lm_head", d, v, &mut ws.logits[..b * v], &mut ws.scratch);
        debug_assert!(
            !was_warm || ws.growths == growths_at_start,
            "step_batch allocated workspace buffers after warm-up"
        );
        Ok((0..b).map(|i| ws.logits[i * v..(i + 1) * v].to_vec()).collect())
    }

    /// Move the native states of a slot batch out of the arena, validating
    /// each.  On failure every already-taken state is restored.
    fn take_native_states(&self, slots: &[SeqSlot]) -> Result<Vec<NativeState>> {
        let mut states = Vec::with_capacity(slots.len());
        for (i, &slot) in slots.iter().enumerate() {
            let taken = self.arena.take(slot).and_then(|s| self.take_state(s));
            match taken {
                Ok(s) => states.push(s),
                Err(e) => {
                    self.restore_states(&slots[..i], states);
                    return Err(e);
                }
            }
        }
        Ok(states)
    }

    /// Put a batch of native states back into their slots.
    fn restore_states(&self, slots: &[SeqSlot], states: Vec<NativeState>) {
        for (&slot, s) in slots.iter().zip(states) {
            let _ = self.arena.put(slot, BackendState::Native(s));
        }
    }

    /// Shared body of the batched decode operations.
    fn decode_batch(
        &self,
        kind: PassKind,
        slots: &[SeqSlot],
        tokens: &[i32],
        pos: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            slots.len() == tokens.len() && slots.len() == pos.len(),
            "decode batch: mismatched batch arity"
        );
        if slots.is_empty() {
            return Ok(Vec::new());
        }
        let mut states = self.take_native_states(slots)?;
        let mut refs: Vec<&mut NativeState> = states.iter_mut().collect();
        let result = self.step_batch(kind, tokens, pos, &mut refs);
        drop(refs);
        self.restore_states(slots, states);
        result
    }

    /// Shared body of `prefill` / `prefill_batch`: per-sequence prefix
    /// lookup, position-lockstep forward pass over each sequence's novel
    /// suffix, then prompt registration in the prefix tree.
    ///
    /// The lookup is capped at `len - 1` so the final prompt position —
    /// whose logits the caller needs — is always computed.  Registration
    /// includes the partial tail page, so the sequence's own next write
    /// into that page (first decode or `verify`) copy-on-writes it.
    fn prefill_states(
        &self,
        prompts: &[&[i32]],
        lengths: &[usize],
    ) -> Result<(Vec<NativeState>, Vec<Vec<f32>>)> {
        let b = prompts.len();
        let enabled = self.prefix_enabled.load(Ordering::Relaxed);
        let mut states: Vec<NativeState> = Vec::with_capacity(b);
        let mut reused: Vec<usize> = Vec::with_capacity(b);
        for (toks, &len) in prompts.iter().zip(lengths) {
            let (pages, r) = if enabled {
                self.prefix.lookup(&self.page_alloc, &toks[..len], len - 1)
            } else {
                (Vec::new(), 0)
            };
            self.page_alloc.add_prefix_tokens(r as u64, (len - r) as u64);
            states.push(NativeState { alloc: Arc::clone(&self.page_alloc), table: pages });
            reused.push(r);
        }
        let mut logits: Vec<Vec<f32>> = vec![Vec::new(); b];
        let maxlen = lengths.iter().copied().max().unwrap_or(0);
        // Position-lockstep over the batch: sequences before their first
        // novel position or past their own length drop out, the rest
        // share one weight stream per position.
        for t in 0..maxlen {
            let active: Vec<usize> =
                (0..b).filter(|&i| reused[i] <= t && t < lengths[i]).collect();
            if active.is_empty() {
                continue;
            }
            let toks: Vec<i32> = active.iter().map(|&i| prompts[i][t]).collect();
            let poss: Vec<usize> = vec![t; active.len()];
            let mut refs: Vec<&mut NativeState> = states
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| reused[*i] <= t && t < lengths[*i])
                .map(|(_, s)| s)
                .collect();
            let rows = self.step_batch(PassKind::Prefill, &toks, &poss, &mut refs)?;
            for (&i, row) in active.iter().zip(rows) {
                logits[i] = row;
            }
        }
        if enabled {
            for ((toks, &len), st) in prompts.iter().zip(lengths).zip(&states) {
                let n_pages = (len + PAGE_TOKENS - 1) / PAGE_TOKENS;
                // Registration failure (a racing eviction starved a
                // retain) only loses cache coverage, never correctness.
                let _ = self.prefix.insert(&self.page_alloc, &toks[..len], &st.table[..n_pages]);
            }
        }
        Ok((states, logits))
    }
}

/// Per-layer parameter names, computed once at load time.
struct LayerNames {
    attn_norm: String,
    wq: String,
    wk: String,
    wv: String,
    wo: String,
    mlp_norm: String,
    w_gate: String,
    w_up: String,
    w_down: String,
}

impl LayerNames {
    fn layer(l: usize) -> Self {
        Self {
            attn_norm: format!("layer{l}.attn_norm"),
            wq: format!("layer{l}.wq"),
            wk: format!("layer{l}.wk"),
            wv: format!("layer{l}.wv"),
            wo: format!("layer{l}.wo"),
            mlp_norm: format!("layer{l}.mlp_norm"),
            w_gate: format!("layer{l}.w_gate"),
            w_up: format!("layer{l}.w_up"),
            w_down: format!("layer{l}.w_down"),
        }
    }
}

impl Backend for NativeBackend {
    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn slots(&self) -> usize {
        self.slots
    }

    fn linears(&self) -> &[String] {
        &self.linears
    }

    fn weights(&self) -> &HostWeights {
        &self.weights
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn arena(&self) -> &SlotArena {
        &self.arena
    }

    fn traffic(&self) -> TrafficSnapshot {
        self.traffic.snapshot()
    }

    fn drain_traffic(&self) -> TrafficSnapshot {
        self.traffic.drain()
    }

    fn kv_stats(&self) -> KvStats {
        self.page_alloc.stats()
    }

    fn prefix_cached_tokens(&self, tokens: &[i32]) -> usize {
        if tokens.is_empty() || !self.prefix_enabled.load(Ordering::Relaxed) {
            return 0;
        }
        // Same `len - 1` cap as prefill's lookup: the final position is
        // always computed, so it can never be served from the cache.
        self.prefix.peek(tokens, tokens.len() - 1)
    }

    fn set_kv_page_budget(&self, budget: Option<u64>) {
        self.page_alloc.set_page_budget(budget);
    }

    fn relieve_kv_pressure(&self, n_pages: usize) -> usize {
        // Evicting childless LRU leaves only drops *cached* prefixes —
        // live sequences hold their own page references, so their token
        // streams are unaffected (a later identical prompt just recomputes
        // its prefill, bit-identically).
        self.prefix.evict_lru(&self.page_alloc, n_pages)
    }

    fn prefill_batch(
        &self,
        slots: &[SeqSlot],
        prompts: &[Vec<i32>],
        lengths: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            slots.len() == prompts.len() && slots.len() == lengths.len(),
            "prefill_batch: mismatched batch arity"
        );
        let p = self.config.prefill_len;
        for (toks, &len) in prompts.iter().zip(lengths) {
            anyhow::ensure!(toks.len() == p, "prefill needs exactly {p} (padded) tokens");
            anyhow::ensure!(len >= 1 && len <= p, "prefill length out of range");
        }
        let views: Vec<&[i32]> = prompts.iter().map(|t| t.as_slice()).collect();
        let (states, logits) = self.prefill_states(&views, lengths)?;
        for (&slot, st) in slots.iter().zip(states) {
            self.arena.put(slot, BackendState::Native(st))?;
        }
        Ok(logits)
    }

    fn decode_full_batch(
        &self,
        slots: &[SeqSlot],
        tokens: &[i32],
        pos: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        self.decode_batch(PassKind::Full, slots, tokens, pos)
    }

    fn decode_draft_batch(
        &self,
        slots: &[SeqSlot],
        tokens: &[i32],
        pos: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        self.decode_batch(PassKind::Draft, slots, tokens, pos)
    }

    fn verify_batch(
        &self,
        slots: &[SeqSlot],
        tokens: &[Vec<i32>],
        pos0: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let s = self.slots;
        let v = self.config.vocab;
        anyhow::ensure!(
            slots.len() == tokens.len() && slots.len() == pos0.len(),
            "verify_batch: mismatched batch arity"
        );
        for toks in tokens {
            anyhow::ensure!(toks.len() == s, "verify needs exactly {s} (padded) tokens");
        }
        if slots.is_empty() {
            return Ok(Vec::new());
        }
        let mut states = self.take_native_states(slots)?;
        let b = slots.len();
        let mut out = vec![vec![0.0f32; s * v]; b];
        let mut err = None;
        // Verification rows are sequential per sequence (row i attends row
        // i-1's KV), so the batch advances row-by-row: one shared weight
        // stream scores row i of every sequence.
        for row in 0..s {
            let toks: Vec<i32> = tokens.iter().map(|t| t[row]).collect();
            let poss: Vec<usize> = pos0.iter().map(|&p| p + row).collect();
            let mut refs: Vec<&mut NativeState> = states.iter_mut().collect();
            match self.step_batch(PassKind::Verify, &toks, &poss, &mut refs) {
                Ok(rows) => {
                    for (i, r) in rows.into_iter().enumerate() {
                        out[i][row * v..(row + 1) * v].copy_from_slice(&r);
                    }
                }
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        self.restore_states(slots, states);
        match err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    fn prefill(&self, tokens: &[i32], length: usize) -> Result<StepOutput> {
        let p = self.config.prefill_len;
        anyhow::ensure!(tokens.len() == p, "prefill needs exactly {p} (padded) tokens");
        anyhow::ensure!(length >= 1 && length <= p, "prefill length out of range");
        let (states, logits) = self.prefill_states(&[tokens], &[length])?;
        let state = states.into_iter().next().expect("batch of one");
        let logits = logits.into_iter().next().expect("batch of one");
        Ok(StepOutput { logits, state: BackendState::Native(state) })
    }

    fn decode_full(&self, token: i32, pos: usize, state: BackendState) -> Result<StepOutput> {
        let mut s = self.take_state(state)?;
        let logits = self.step(PassKind::Full, token, pos, &mut s)?;
        Ok(StepOutput { logits, state: BackendState::Native(s) })
    }

    fn decode_draft(&self, token: i32, pos: usize, state: BackendState) -> Result<StepOutput> {
        let mut s = self.take_state(state)?;
        let logits = self.step(PassKind::Draft, token, pos, &mut s)?;
        Ok(StepOutput { logits, state: BackendState::Native(s) })
    }

    fn verify(&self, tokens: &[i32], pos0: usize, state: BackendState) -> Result<VerifyOutput> {
        let s = self.slots;
        anyhow::ensure!(tokens.len() == s, "verify needs exactly {s} (padded) tokens");
        let mut st = self.take_state(state)?;
        let v = self.config.vocab;
        let mut logits = vec![0.0f32; s * v];
        // Each row runs the same full-precision step as `decode_full`, so
        // verification is bit-identical to sequential decoding; rows past
        // the real draft length score padding tokens whose KV rows are
        // never attended before being overwritten.  Overwriting a drafted
        // position whose page is shared with the prefix tree (the prompt's
        // tail page) copy-on-writes just that page inside `step_batch`.
        for (i, &tok) in tokens.iter().enumerate() {
            let row = self.step(PassKind::Verify, tok, pos0 + i, &mut st)?;
            logits[i * v..(i + 1) * v].copy_from_slice(&row);
        }
        Ok(VerifyOutput { logits, state: BackendState::Native(st) })
    }

    fn eval_logits(&self, tokens: &[i32], length: usize) -> Result<Vec<f32>> {
        let p = self.config.prefill_len;
        anyhow::ensure!(tokens.len() == p, "eval needs exactly {p} (padded) tokens");
        anyhow::ensure!(length >= 1 && length <= p, "eval length out of range");
        anyhow::ensure!(p <= self.config.cache_len, "prefill window exceeds cache");
        let v = self.config.vocab;
        // The perplexity harness needs every position's logits, so this
        // path stays cold: a fresh unshared state, no prefix-tree lookup
        // or registration (cached positions would skip their logits row).
        let mut state = self.fresh_state();
        let mut out = vec![0.0f32; p * v];
        for (t, &tok) in tokens.iter().enumerate().take(length) {
            let row = self.step(PassKind::Prefill, tok, t, &mut state)?;
            out[t * v..(t + 1) * v].copy_from_slice(&row);
        }
        Ok(out)
    }

    fn with_transformed_weights(
        &self,
        transform: &mut dyn FnMut(&str, &[f32], usize, usize) -> Result<Vec<f32>>,
    ) -> Result<Box<dyn Backend>> {
        let mut weights = self.weights.clone();
        for name in &self.linears {
            let shape = weights.shape(name).to_vec();
            if shape.len() != 2 {
                continue;
            }
            let (k, n) = (shape[0], shape[1]);
            let new = transform(name, weights.f32(name), k, n)?;
            anyhow::ensure!(
                new.len() == k * n,
                "transform for {name:?} returned {} values, expected {}",
                new.len(),
                k * n
            );
            // Keep the canonical bit view in sync (best effort: transformed
            // values need not be FP16-representable, mirroring the PJRT
            // path which uploads transformed weights as raw f32).
            weights.bits.insert(name.clone(), new.iter().map(|&v| f32_to_f16_bits(v)).collect());
            weights.f32s.insert(name.clone(), new);
        }
        // The transformed clone inherits this backend's pool width and
        // SIMD tier (the perplexity harness compares variants under one
        // runtime config).
        let b = NativeBackend::from_weights_with(
            self.config.clone(),
            self.linears.clone(),
            weights,
            self.slots,
            &NativeConfig::with_threads(self.pool.threads()).with_simd(self.simd),
        )?;
        Ok(Box::new(b))
    }
}

/// Build the bit-plane packed weight store for every quantizable linear —
/// the one shared `quantize_tensor` path (the same codec call the PJRT
/// artifact pipeline and the analyses use; the retired `derive_draft`
/// dense dequant copy is gone).
fn build_store(weights: &HostWeights, linears: &[String]) -> BTreeMap<String, LinearStore> {
    let mut store = BTreeMap::new();
    for name in linears {
        let shape = weights.shape(name);
        if shape.len() != 2 || shape[0] % GROUP_SIZE != 0 {
            // Not quantizable: dense for both passes (matches the retired
            // draft fallback).
            continue;
        }
        let (k, n) = (shape[0], shape[1]);
        let w = weights.f32(name);
        if w.iter().any(|v| !v.is_finite()) {
            // Quantizing non-finite values is undefined; keep the tensor
            // dense for both passes so the full path stays exact.
            continue;
        }
        let qt = quantize_tensor(w, k, n);
        if qt.tensor_scale == 1.0 && fp16_exact_in_domain(w) {
            store.insert(
                name.clone(),
                LinearStore::Packed { planes: qt.planes(), scales: qt.scales },
            );
        } else {
            store.insert(
                name.clone(),
                LinearStore::Split {
                    prefix: qt.packed_wq(),
                    scales: qt.scales,
                    tensor_scale: qt.tensor_scale,
                },
            );
        }
    }
    store
}

/// Deterministic synthetic weights for `cfg` (see [`InitStyle`]).
fn synthetic_weights(cfg: &ModelConfig, seed: u64, style: InitStyle) -> HostWeights {
    let mut rng = Rng::seed_from_u64(seed);
    // Residual-path damping keeps the byte-successor structure dominant
    // over the random mixing layers (deeper stacks need more damping).
    let damp = if cfg.n_layers >= 4 { 0.15f32 } else { 0.25f32 };
    let beta = 2.5f32;
    let mut f32s: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    let mut shapes: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (name, shape) in param_shapes(cfg) {
        let n: usize = shape.iter().product();
        let data = if name.ends_with("norm") {
            vec![1.0f32; n]
        } else {
            let mut std = 0.5 / (shape[0] as f32).sqrt();
            if style == InitStyle::Confident
                && (name.ends_with(".wo") || name.ends_with(".w_down"))
            {
                std *= damp;
            }
            rng.normal_vec(n, std)
        };
        shapes.insert(name.clone(), shape);
        f32s.insert(name, data);
    }
    if style == InitStyle::Confident {
        // Successor head: align lm_head column (t+1) mod V with embed row t,
        // making the model a confident byte-successor predictor — the
        // stand-in for training to near-zero loss.
        let (v, d) = (cfg.vocab, cfg.d_model);
        let embed = f32s["embed"].clone();
        let lm = f32s.get_mut("lm_head").expect("lm_head exists");
        for t in 0..v {
            let row = &embed[t * d..(t + 1) * d];
            let norm = dot(row, row).sqrt().max(1e-6);
            let col = (t + 1) % v;
            for (j, &e) in row.iter().enumerate() {
                lm[j * v + col] += beta * e / norm;
            }
        }
    }
    // Round everything to FP16 — the canonical substrate of the codec.
    let mut bits: BTreeMap<String, Vec<u16>> = BTreeMap::new();
    for (name, data) in f32s.iter_mut() {
        let b: Vec<u16> = data.iter().map(|&x| f32_to_f16_bits(x)).collect();
        *data = b.iter().map(|&x| f16_bits_to_f32(x)).collect();
        bits.insert(name.clone(), b);
    }
    HostWeights { bits, f32s, shapes }
}

// ---- f32 activation helpers (GEMM kernels live in `super::kernels`) --------

/// Row-wise RMSNorm over a flat `(b, d)` batch, written into `out` (no
/// allocation).  Per-row arithmetic is exactly the retired per-`Vec`
/// `rmsnorm`: ascending-index sum of squares, then `v * r * g`.
fn rmsnorm_rows(x: &[f32], b: usize, d: usize, w: &[f32], out: &mut [f32]) {
    debug_assert!(x.len() == b * d && out.len() == b * d && w.len() == d);
    for i in 0..b {
        let xr = &x[i * d..(i + 1) * d];
        let or = &mut out[i * d..(i + 1) * d];
        let ms = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + 1e-5).sqrt();
        for (o, (&v, &g)) in or.iter_mut().zip(xr.iter().zip(w)) {
            *o = v * r * g;
        }
    }
}

fn softmax_in_place(v: &mut [f32]) {
    let m = v.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut z = 0.0f32;
    for s in v.iter_mut() {
        *s = (*s - m).exp();
        z += *s;
    }
    for s in v.iter_mut() {
        *s /= z;
    }
}

/// Rotary embedding over `(n_heads, head_dim)` flattened, matching
/// `python/compile/model.py::rope`.
fn rope_in_place(x: &mut [f32], n_heads: usize, head_dim: usize, pos: usize, freqs: &[f32]) {
    let half = head_dim / 2;
    for head in 0..n_heads {
        let base = head * head_dim;
        for (j, &f) in freqs.iter().enumerate() {
            let (sin, cos) = (pos as f32 * f).sin_cos();
            let a = x[base + j];
            let b = x[base + half + j];
            x[base + j] = a * cos - b * sin;
            x[base + half + j] = a * sin + b * cos;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "unit-tiny".into(),
            paper_analog: "none".into(),
            n_layers: 1,
            d_model: 128,
            d_ff: 128,
            n_heads: 4,
            head_dim: 32,
            vocab: 64,
            cache_len: 96,
            prefill_len: 32,
            param_count: 0,
        }
    }

    #[test]
    fn builtin_zoo_loads_and_prefills() {
        let b = NativeBackend::builtin("vicuna-7b-tiny").expect("builtin");
        assert_eq!(b.vocab(), 256);
        assert_eq!(b.slots(), S_SLOTS);
        let toks = vec![b'a' as i32; b.prefill_len()];
        let out = b.prefill(&toks, 8).expect("prefill");
        assert_eq!(out.logits.len(), 256);
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn unknown_builtin_is_an_error() {
        let err = NativeBackend::builtin("gpt-5").unwrap_err();
        assert!(format!("{err}").contains("builtin zoo"), "{err}");
    }

    #[test]
    fn decode_is_deterministic() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 7, InitStyle::Random).unwrap();
        let toks = vec![3i32; b.prefill_len()];
        let run = || {
            let pre = b.prefill(&toks, 4).unwrap();
            let step = b.decode_full(1, 4, pre.state).unwrap();
            step.logits
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn verify_rows_match_sequential_decode_bitwise() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 9, InitStyle::Confident).unwrap();
        let toks = vec![5i32; b.prefill_len()];
        let plen = 6usize;
        let vtokens: Vec<i32> = (1..=5).collect();

        let pre = b.prefill(&toks, plen).unwrap();
        let ver = b.verify(&vtokens, plen, pre.state).unwrap();

        let mut state = b.prefill(&toks, plen).unwrap().state;
        let v = b.vocab();
        for (i, &tok) in vtokens.iter().enumerate() {
            let step = b.decode_full(tok, plen + i, state).unwrap();
            state = step.state;
            assert_eq!(
                step.logits,
                ver.logits[i * v..(i + 1) * v].to_vec(),
                "verify row {i} diverged from sequential decode"
            );
        }
    }

    #[test]
    fn packed_store_reproduces_full_and_draft_bits() {
        // The tentpole's bit-identity pin: for every quantizable linear,
        // the store's full-pass view must equal the dense f32 weights
        // bitwise (what the retired kernels streamed), and its draft-pass
        // view must equal the retired `derive_draft` dequantization
        // bitwise.
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 3, InitStyle::Confident).unwrap();
        for name in b.linears().to_vec() {
            let shape = b.weights().shape(&name).to_vec();
            if shape.len() != 2 || shape[0] % GROUP_SIZE != 0 {
                assert_eq!(b.store_kind(&name), "dense", "{name}");
                continue;
            }
            // Synthetic weights are FP16-cast and small: always packed.
            assert_eq!(b.store_kind(&name), "packed", "{name}");
            let full = b.decode_linear(&name, false);
            let dense = b.weights().f32(&name);
            assert_eq!(full.len(), dense.len(), "{name}");
            for (i, (d, f)) in dense.iter().zip(&full).enumerate() {
                assert_eq!(d.to_bits(), f.to_bits(), "{name} full idx {i}");
            }
            let qt = quantize_tensor(dense, shape[0], shape[1]);
            let expect: Vec<f32> =
                qt.dequant_draft().iter().map(|&v| v / qt.tensor_scale).collect();
            let draft = b.decode_linear(&name, true);
            for (i, (e, d)) in expect.iter().zip(&draft).enumerate() {
                assert_eq!(e.to_bits(), d.to_bits(), "{name} draft idx {i}");
            }
        }
        assert_eq!(b.store_kind("lm_head"), "packed");
    }

    #[test]
    fn packed_linears_drop_the_redundant_bit_copy() {
        // The planes are the canonical bits: keeping the u16 copy too
        // would re-create the dual-store memory overhead the packed
        // layout exists to remove.
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 3, InitStyle::Confident).unwrap();
        for name in b.linears().to_vec() {
            if b.store_kind(&name) == "packed" {
                assert!(!b.weights().bits.contains_key(&name), "{name} kept its bit copy");
            }
        }
        // Non-linear parameters keep theirs (they are not in the store).
        assert!(b.weights().bits.contains_key("embed"));
        assert!(b.weights().bits.contains_key("final_norm"));
    }

    #[test]
    fn outlier_tensor_splits_and_full_pass_stays_exact() {
        // A weight >= 2.0 forces the Algorithm-1 pre-scale: the planes can
        // no longer reproduce the tensor exactly, so the full pass must
        // keep the dense view while the draft reads the pre-scaled prefix.
        let base = NativeBackend::synthetic(tiny_cfg(), 5, 4, InitStyle::Random).unwrap();
        let mut weights = base.weights.clone();
        weights.f32s.get_mut("layer0.wq").unwrap()[0] = 2.75;
        let b = NativeBackend::from_weights(
            base.config.clone(),
            base.linears.clone(),
            weights,
            5,
        )
        .unwrap();
        assert_eq!(b.store_kind("layer0.wq"), "split");
        let full = b.decode_linear("layer0.wq", false);
        let dense = b.weights().f32("layer0.wq");
        assert_eq!(full[0].to_bits(), 2.75f32.to_bits());
        for (i, (d, f)) in dense.iter().zip(&full).enumerate() {
            assert_eq!(d.to_bits(), f.to_bits(), "full idx {i}");
        }
        // Draft still matches the retired derive_draft semantics.
        let qt = quantize_tensor(dense, 128, 128);
        assert!(qt.tensor_scale < 1.0);
        let expect: Vec<f32> =
            qt.dequant_draft().iter().map(|&v| v / qt.tensor_scale).collect();
        let draft = b.decode_linear("layer0.wq", true);
        for (i, (e, d)) in expect.iter().zip(&draft).enumerate() {
            assert_eq!(e.to_bits(), d.to_bits(), "draft idx {i}");
        }
    }

    #[test]
    fn non_finite_tensor_falls_back_to_dense_for_both_passes() {
        let base = NativeBackend::synthetic(tiny_cfg(), 5, 4, InitStyle::Random).unwrap();
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut weights = base.weights.clone();
            weights.f32s.get_mut("layer0.wo").unwrap()[7] = bad;
            let b = NativeBackend::from_weights(
                base.config.clone(),
                base.linears.clone(),
                weights,
                5,
            )
            .unwrap();
            assert_eq!(b.store_kind("layer0.wo"), "dense");
            // Full-path exactness holds even for non-encodable values.
            let full = b.decode_linear("layer0.wo", false);
            assert_eq!(full[7].to_bits(), bad.to_bits());
            // Other linears are unaffected.
            assert_eq!(b.store_kind("layer0.wq"), "packed");
        }
    }

    #[test]
    fn transformed_weights_keep_the_full_pass_dense_exact() {
        // `with_transformed_weights` produces values that need not be
        // FP16-representable; the rebuilt store must route them to the
        // split fallback so the perplexity harness sees the raw f32s.
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 6, InitStyle::Random).unwrap();
        let t = b
            .with_transformed_weights(&mut |_, w, _, _| {
                Ok(w.iter().map(|&v| v * 1.000001).collect())
            })
            .unwrap();
        // Spot-check through the public weights view: the dense values are
        // the transformed ones, not an FP16 re-rounding.
        let orig = b.weights().f32("layer0.wq");
        let got = t.weights().f32("layer0.wq");
        for (i, (&o, &g)) in orig.iter().zip(got).enumerate().take(16) {
            assert_eq!(g.to_bits(), (o * 1.000001).to_bits(), "idx {i}");
        }
        // And the transformed backend still decodes deterministically.
        let toks = vec![1i32; t.prefill_len()];
        let out = t.prefill(&toks, 4).unwrap();
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn traffic_counters_measure_the_quarter_ratio() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 9, InitStyle::Confident).unwrap();
        let toks = vec![5i32; b.prefill_len()];
        let pre = b.prefill(&toks, 4).unwrap();
        let snap = b.traffic();
        assert_eq!(snap.prefill_tokens, 4);
        assert!(snap.prefill_bytes > 0);
        b.drain_traffic();

        // One draft step, then one full step, from the same state.
        let step = b.decode_draft(1, 4, pre.state).unwrap();
        let draft = b.drain_traffic();
        let _ = b.decode_full(1, 5, step.state).unwrap();
        let full = b.drain_traffic();
        assert_eq!(draft.draft_tokens, 1);
        assert_eq!(full.full_tokens, 1);
        assert!(draft.draft_bytes > 0 && full.full_bytes > 0);
        // Packed linears stream 1/4 of the full plane bytes; scales, norms
        // and the embedding row push the ratio above 0.25 but it must stay
        // well under the regression bound.
        let ratio = draft.draft_bytes as f64 / full.full_bytes as f64;
        assert!(ratio <= 0.35, "draft/full traffic ratio {ratio}");
        // Verify rows stream the same weights as full decode steps.
        let pre = b.prefill(&toks, 4).unwrap();
        b.drain_traffic();
        let vtokens: Vec<i32> = (0..b.slots() as i32).collect();
        let _ = b.verify(&vtokens, 4, pre.state).unwrap();
        let ver = b.drain_traffic();
        assert_eq!(ver.verify_rows, b.slots() as u64);
        assert_eq!(ver.verify_bytes, full.full_bytes * b.slots() as u64);
    }

    #[test]
    fn workspace_reuses_buffers_after_warmup() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 7, InitStyle::Random).unwrap();
        let toks = vec![3i32; b.prefill_len()];
        let pre = b.prefill(&toks, 4).unwrap();
        let grown = b.workspace_growths();
        assert!(grown >= 1, "prefill must warm the workspace up");
        // Steady-state steps reuse the warm buffers: zero further growth.
        let step = b.decode_full(1, 4, pre.state).unwrap();
        assert_eq!(b.workspace_growths(), grown, "decode step grew the workspace");
        let step = b.decode_draft(2, 5, step.state).unwrap();
        assert_eq!(b.workspace_growths(), grown, "draft step grew the workspace");
        let vtokens: Vec<i32> = (0..b.slots() as i32).collect();
        let _ = b.verify(&vtokens, 6, step.state).unwrap();
        assert_eq!(b.workspace_growths(), grown, "verify pass grew the workspace");
    }

    #[test]
    fn workspace_grows_once_for_a_larger_batch() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 7, InitStyle::Confident).unwrap();
        let p = b.prefill_len();
        let prompts1 = vec![vec![5i32; p]];
        let slots1 = vec![b.alloc_slot()];
        b.prefill_batch(&slots1, &prompts1, &[4]).unwrap();
        let g1 = b.workspace_growths();
        // A wider batch grows the buffers exactly once more...
        let prompts4: Vec<Vec<i32>> = (0..4).map(|i| vec![5i32 + i; p]).collect();
        let slots4: Vec<SeqSlot> = (0..4).map(|_| b.alloc_slot()).collect();
        b.prefill_batch(&slots4, &prompts4, &[4, 4, 4, 4]).unwrap();
        let g4 = b.workspace_growths();
        assert_eq!(g4, g1 + 1, "batch-4 warm-up should be one growth event");
        // ...and a subsequent narrower batch reuses them.
        b.decode_full_batch(&slots1, &[1], &[4]).unwrap();
        assert_eq!(b.workspace_growths(), g4);
        for s in slots1.into_iter().chain(slots4) {
            b.free_slot(s);
        }
    }

    #[test]
    fn thread_count_never_changes_output_bits() {
        // The tentpole's end-to-end pin at the backend level: prefill,
        // full/draft decode, and verify logits are bit-identical for any
        // pool width (the zoo-wide engine-level sweep lives in
        // rust/tests/prop_threads.rs).
        let mk = |threads: usize| {
            let mut b =
                NativeBackend::synthetic(tiny_cfg(), 5, 9, InitStyle::Confident).unwrap();
            b.set_threads(threads);
            b
        };
        let base = mk(1);
        let toks = vec![5i32; base.prefill_len()];
        let pre = base.prefill(&toks, 6).unwrap();
        let full = base.decode_full(1, 6, pre.state).unwrap();
        let vtokens: Vec<i32> = (0..base.slots() as i32).collect();
        let ver = base.verify(&vtokens, 7, full.state).unwrap();
        for t in [2usize, 3, 4, 8] {
            let b = mk(t);
            assert_eq!(b.threads(), t);
            let pre_t = b.prefill(&toks, 6).unwrap();
            assert_eq!(
                pre_t.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                pre.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "prefill logits diverged at T={t}"
            );
            let full_t = b.decode_full(1, 6, pre_t.state).unwrap();
            assert_eq!(
                full_t.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                full.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "decode logits diverged at T={t}"
            );
            let ver_t = b.verify(&vtokens, 7, full_t.state).unwrap();
            assert_eq!(
                ver_t.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                ver.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "verify logits diverged at T={t}"
            );
        }
    }

    #[test]
    fn simd_level_never_changes_output_bits() {
        // Backend-level pin of the "SIMD decodes, scalar-order
        // accumulates" contract: prefill, full/draft decode, and verify
        // logits are bit-identical on every dispatch tier this host
        // supports (the kernel-level sweep lives in
        // rust/tests/prop_simd.rs).
        let mk = |level: SimdLevel| {
            let mut b =
                NativeBackend::synthetic(tiny_cfg(), 5, 9, InitStyle::Confident).unwrap();
            b.set_simd(level);
            b.set_threads(2);
            b
        };
        let base = mk(SimdLevel::Scalar);
        assert_eq!(base.simd_level(), SimdLevel::Scalar);
        let toks = vec![5i32; base.prefill_len()];
        let pre = base.prefill(&toks, 6).unwrap();
        let draft = base.decode_draft(1, 6, pre.state).unwrap();
        let vtokens: Vec<i32> = (0..base.slots() as i32).collect();
        let ver = base.verify(&vtokens, 7, draft.state).unwrap();
        for level in SimdLevel::available() {
            let b = mk(level);
            assert_eq!(b.simd_level(), level);
            let pre_l = b.prefill(&toks, 6).unwrap();
            assert_eq!(
                pre_l.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                pre.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "prefill logits diverged at {}",
                level.name()
            );
            let draft_l = b.decode_draft(1, 6, pre_l.state).unwrap();
            assert_eq!(
                draft_l.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                draft.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "draft logits diverged at {}",
                level.name()
            );
            let ver_l = b.verify(&vtokens, 7, draft_l.state).unwrap();
            assert_eq!(
                ver_l.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                ver.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "verify logits diverged at {}",
                level.name()
            );
        }
    }

    #[test]
    fn state_from_another_model_is_rejected() {
        let a = NativeBackend::synthetic(tiny_cfg(), 5, 1, InitStyle::Random).unwrap();
        let mut big = tiny_cfg();
        big.cache_len = 128;
        let c = NativeBackend::synthetic(big, 5, 1, InitStyle::Random).unwrap();
        let toks = vec![0i32; a.prefill_len()];
        let pre = a.prefill(&toks, 2).unwrap();
        let err = c.decode_full(0, 2, pre.state).unwrap_err();
        assert!(format!("{err}").contains("KV elements"), "{err}");
    }

    #[test]
    fn batched_ops_match_single_sequence_bitwise() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 9, InitStyle::Confident).unwrap();
        let p = b.prefill_len();
        let prompts: Vec<Vec<i32>> = vec![vec![5i32; p], vec![7i32; p], vec![11i32; p]];
        let lengths = vec![6usize, 3, 9];
        let slots: Vec<SeqSlot> = (0..3).map(|_| b.alloc_slot()).collect();

        // Batched prefill == per-sequence prefill, bitwise.
        let pre = b.prefill_batch(&slots, &prompts, &lengths).unwrap();
        let mut seq_states = Vec::new();
        for (i, (toks, &len)) in prompts.iter().zip(&lengths).enumerate() {
            let s = b.prefill(toks, len).unwrap();
            assert_eq!(pre[i], s.logits, "prefill logits diverged for seq {i}");
            seq_states.push(s.state);
        }

        // One batched draft step == sequential draft steps, bitwise.
        let toks = [1i32, 2, 3];
        let rows = b.decode_draft_batch(&slots, &toks, &lengths).unwrap();
        let mut next_states = Vec::new();
        for (i, state) in seq_states.into_iter().enumerate() {
            let s = b.decode_draft(toks[i], lengths[i], state).unwrap();
            assert_eq!(rows[i], s.logits, "draft logits diverged for seq {i}");
            next_states.push(s.state);
        }

        // One batched verify pass == sequential verify passes, bitwise.
        let vtokens: Vec<Vec<i32>> =
            vec![vec![1, 2, 3, 4, 5], vec![2, 3, 4, 5, 6], vec![3, 4, 5, 6, 7]];
        let pos0: Vec<usize> = lengths.iter().map(|&l| l + 1).collect();
        let vrows = b.verify_batch(&slots, &vtokens, &pos0).unwrap();
        for (i, state) in next_states.into_iter().enumerate() {
            let v = b.verify(&vtokens[i], pos0[i], state).unwrap();
            assert_eq!(vrows[i], v.logits, "verify logits diverged for seq {i}");
        }
        for &s in &slots {
            b.free_slot(s);
        }
        assert_eq!(b.arena().in_use(), 0);
    }

    #[test]
    fn slot_without_state_is_rejected_and_slots_recycle() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 2, InitStyle::Random).unwrap();
        let slot = b.alloc_slot();
        let err = b.decode_full_batch(&[slot], &[1], &[2]).unwrap_err();
        assert!(format!("{err}").contains("no state"), "{err}");
        b.free_slot(slot);
        let again = b.alloc_slot();
        assert_eq!(slot, again, "freed slot index should be recycled");
        b.free_slot(again);
        // Double-free is a no-op.
        b.free_slot(again);
        assert_eq!(b.arena().in_use(), 0);
    }

    #[test]
    fn out_of_range_token_is_rejected() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 1, InitStyle::Random).unwrap();
        let toks = vec![0i32; b.prefill_len()];
        let pre = b.prefill(&toks, 2).unwrap();
        let err = b.decode_full(64, 2, pre.state).unwrap_err();
        assert!(format!("{err}").contains("vocab"), "{err}");
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn prefix_cache_serves_repeat_prompts_bitwise() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 9, InitStyle::Confident).unwrap();
        let toks: Vec<i32> = (0..32).map(|t| t % 64).collect();
        let first = b.prefill(&toks, 32).unwrap();
        let miss = b.kv_stats();
        assert_eq!(miss.prefix_hit_tokens, 0);
        assert_eq!(miss.prefix_miss_tokens, 32);
        // The repeat prompt reuses the cached full page; the tail page is
        // capped at len-1 (the final position's logits must be computed).
        assert_eq!(b.prefix_cached_tokens(&toks), 16);
        let second = b.prefill(&toks, 32).unwrap();
        let hit = b.kv_stats();
        assert_eq!(hit.prefix_hit_tokens, 16);
        assert_eq!(hit.prefix_miss_tokens, 32 + 16);
        assert_eq!(bits(&first.logits), bits(&second.logits), "reuse changed the logits");
        assert!(hit.pages_shared > 0, "live sequences + tree should share pages");
    }

    #[test]
    fn decode_into_a_shared_tail_page_cows_it() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 9, InitStyle::Confident).unwrap();
        let toks: Vec<i32> = (0..32).map(|t| (t * 3) % 64).collect();
        // 20-token prompt: one full page + a 4-token tail page, both
        // registered in (and pinned by) the prefix tree.
        let pre1 = b.prefill(&toks, 20).unwrap();
        let cow0 = b.kv_stats().cow_copies;
        let step1 = b.decode_full(7, 20, pre1.state).unwrap();
        assert!(
            b.kv_stats().cow_copies > cow0,
            "writing into the tree-shared tail page must copy-on-write"
        );
        // The tree's copy kept the original bits: a fresh sequence over
        // the same prompt + decode reproduces the logits bitwise.
        let pre2 = b.prefill(&toks, 20).unwrap();
        let step2 = b.decode_full(7, 20, pre2.state).unwrap();
        assert_eq!(bits(&step1.logits), bits(&step2.logits));
    }

    #[test]
    fn freed_sequences_return_their_pages() {
        let b = NativeBackend::synthetic(tiny_cfg(), 5, 3, InitStyle::Random).unwrap();
        b.set_prefix_cache(false);
        let toks = vec![1i32; b.prefill_len()];
        let pre = b.prefill(&toks, 32).unwrap();
        assert_eq!(b.kv_stats().pages_in_use, 2, "32 positions = 2 pages");
        drop(pre.state);
        assert_eq!(b.kv_stats().pages_in_use, 0, "dropping the state must free its pages");
        // And through the arena path too.
        let slot = b.alloc_slot();
        b.prefill_batch(&[slot], &[toks.clone()], &[20]).unwrap();
        assert_eq!(b.kv_stats().pages_in_use, 2);
        b.free_slot(slot);
        assert_eq!(b.kv_stats().pages_in_use, 0);
    }

    #[test]
    fn disabling_the_prefix_cache_matches_dense_behavior() {
        let cached = NativeBackend::synthetic(tiny_cfg(), 5, 9, InitStyle::Confident).unwrap();
        let dense = NativeBackend::synthetic(tiny_cfg(), 5, 9, InitStyle::Confident).unwrap();
        dense.set_prefix_cache(false);
        let toks: Vec<i32> = (0..32).map(|t| (t * 5) % 64).collect();
        for _ in 0..2 {
            let a = cached.prefill(&toks, 32).unwrap();
            let d = dense.prefill(&toks, 32).unwrap();
            assert_eq!(bits(&a.logits), bits(&d.logits));
            let a2 = cached.decode_full(3, 32, a.state).unwrap();
            let d2 = dense.decode_full(3, 32, d.state).unwrap();
            assert_eq!(bits(&a2.logits), bits(&d2.logits));
        }
        assert_eq!(dense.kv_stats().prefix_hit_tokens, 0);
        assert!(cached.kv_stats().prefix_hit_tokens > 0);
        assert_eq!(dense.prefix_cached_tokens(&toks), 0);
    }
}
