//! HLO-text loading (the AOT interchange format).

use std::path::Path;

use anyhow::{Context, Result};

/// Parse an HLO text file into an [`xla::XlaComputation`].
///
/// Text is the only interchange format that round-trips between jax >= 0.5
/// and xla_extension 0.5.1 (serialized protos carry 64-bit instruction ids
/// the older runtime rejects; the text parser reassigns them).
pub fn load_hlo_text(path: impl AsRef<Path>) -> Result<xla::XlaComputation> {
    let path = path.as_ref();
    anyhow::ensure!(path.exists(), "HLO file missing: {} (run `make artifacts`)", path.display());
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
    Ok(xla::XlaComputation::from_proto(&proto))
}
