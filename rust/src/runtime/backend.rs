//! The execution-backend abstraction.
//!
//! Everything above this layer (the speculative engine, the serving
//! coordinator, the report harness) is written against [`Backend`]: the five
//! request-path operations (`prefill`, `decode_full`, `decode_draft`,
//! `verify`, `eval_logits`) plus opaque state threading.  Two
//! implementations exist:
//!
//! * [`NativeBackend`] — pure-Rust interpreter over [`HostWeights`]
//!   (always available; the default).
//! * `model::ModelRuntime` — PJRT execution of AOT-compiled HLO (behind
//!   the non-default `pjrt` cargo feature).
//!
//! State is passed *by value*: each step consumes the previous state and
//! returns the next one, which lets the native backend mutate its KV cache
//! in place and the PJRT backend thread device buffers without host copies.

use anyhow::Result;

use crate::model::{HostWeights, Manifest, ModelConfig};

use super::native::NativeBackend;

/// Opaque per-request state (logits slots + KV cache), backend-specific.
pub enum BackendState {
    /// Host-memory KV cache of the native interpreter.
    Native(super::native::NativeState),
    /// Device-resident state buffer of the PJRT backend.
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer),
}

/// Logits for slot 0 (length `vocab`) plus the threaded state.
pub struct StepOutput {
    pub logits: Vec<f32>,
    pub state: BackendState,
}

/// All `slots` logits rows (flattened, `slots * vocab`) plus the state.
pub struct VerifyOutput {
    pub logits: Vec<f32>,
    pub state: BackendState,
}

/// One executable model: full-precision target + BSFP draft, shared KV.
///
/// Implementations must keep the draft/verify contract of the paper: the
/// draft pass runs the same architecture over the BSFP 4-bit view of the
/// *same* weights, both passes share one KV cache, and `verify` overwrites
/// drafted positions with full-precision KV.
pub trait Backend {
    /// Model architecture (dims, vocab, cache/prefill lengths).
    fn config(&self) -> &ModelConfig;

    /// Logits slots per state (max draft length + 1 bonus token).
    fn slots(&self) -> usize;

    /// Names of the BSFP-quantized linear weights.
    fn linears(&self) -> &[String];

    /// Host copies of the weights (analyses: exponent histograms, re-quantization).
    fn weights(&self) -> &HostWeights;

    /// Human-readable backend identifier (`"native"`, `"pjrt"`).
    fn backend_name(&self) -> &'static str;

    /// Run prefill over a padded prompt; slot 0 of the returned logits is
    /// the prediction after position `length - 1`.
    fn prefill(&self, tokens: &[i32], length: usize) -> Result<StepOutput>;

    /// One full-precision decode step (the autoregressive baseline).
    fn decode_full(&self, token: i32, pos: usize, state: BackendState) -> Result<StepOutput>;

    /// One 4-bit BSFP draft decode step (parameter-sharing draft model).
    fn decode_draft(&self, token: i32, pos: usize, state: BackendState) -> Result<StepOutput>;

    /// Score `slots()` tokens in one full-precision verification pass;
    /// `tokens[i]` is scored at position `pos0 + i` and full-precision KV
    /// overwrites the drafted positions (shared cache, §III-C).
    fn verify(&self, tokens: &[i32], pos0: usize, state: BackendState) -> Result<VerifyOutput>;

    /// Per-position logits `(prefill_len, vocab)` for a padded window — the
    /// perplexity harness (rows at positions `>= length` are padding).
    fn eval_logits(&self, tokens: &[i32], length: usize) -> Result<Vec<f32>>;

    /// Clone this model with every 2-D linear weight passed through
    /// `transform(name, w, k, n) -> w'` — the hook the Table I perplexity
    /// harness uses to compare quantization variants.
    fn with_transformed_weights(
        &self,
        transform: &mut dyn FnMut(&str, &[f32], usize, usize) -> Result<Vec<f32>>,
    ) -> Result<Box<dyn Backend>>;

    fn vocab(&self) -> usize {
        self.config().vocab
    }

    fn cache_len(&self) -> usize {
        self.config().cache_len
    }

    fn prefill_len(&self) -> usize {
        self.config().prefill_len
    }
}

/// Where a model's weights come from.
#[derive(Debug, Clone)]
pub enum ModelSource {
    /// The built-in synthetic zoo — no artifacts directory required.
    Builtin,
    /// An artifacts directory (trained weights; compiled HLO graphs when
    /// the `pjrt` feature is active).
    Artifacts(std::path::PathBuf),
}

impl ModelSource {
    /// `Artifacts(root)` when `root` has a manifest, `Builtin` otherwise.
    pub fn at(root: impl Into<std::path::PathBuf>) -> Self {
        let root = root.into();
        if root.join("manifest.json").exists() {
            ModelSource::Artifacts(root)
        } else {
            ModelSource::Builtin
        }
    }

    /// [`ModelSource::at`] the default artifacts root
    /// (`$SPEQ_ARTIFACTS` or `./artifacts`).
    pub fn auto() -> Self {
        Self::at(Manifest::default_root())
    }

    /// The manifest backing this source (`None` for the builtin zoo).
    pub fn manifest(&self) -> Result<Option<Manifest>> {
        match self {
            ModelSource::Builtin => Ok(None),
            ModelSource::Artifacts(root) => Ok(Some(Manifest::load(root)?)),
        }
    }
}

/// Load an execution backend for `model` from `source`.
///
/// With the `pjrt` feature enabled and an artifacts source, the PJRT
/// backend is tried first (unless `SPEQ_BACKEND=native`) and the native
/// interpreter is the fallback; the default build always selects the
/// native backend.
pub fn load_backend(source: &ModelSource, model: &str) -> Result<Box<dyn Backend>> {
    match source {
        ModelSource::Builtin => Ok(Box::new(NativeBackend::builtin(model)?)),
        ModelSource::Artifacts(root) => {
            let manifest = Manifest::load(root)?;
            #[cfg(feature = "pjrt")]
            {
                let force_native =
                    std::env::var("SPEQ_BACKEND").map(|v| v == "native").unwrap_or(false);
                if !force_native {
                    match pjrt_backend(&manifest, model) {
                        Ok(b) => return Ok(b),
                        Err(e) => {
                            eprintln!("pjrt backend unavailable ({e:#}); falling back to native")
                        }
                    }
                }
            }
            Ok(Box::new(NativeBackend::from_manifest(&manifest, model)?))
        }
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(manifest: &Manifest, model: &str) -> Result<Box<dyn Backend>> {
    let rt = super::Runtime::cpu()?;
    Ok(Box::new(crate::model::ModelRuntime::load(&rt, manifest, model)?))
}
