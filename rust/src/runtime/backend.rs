//! The execution-backend abstraction.
//!
//! Everything above this layer (the speculative engine, the serving
//! coordinator, the report harness) is written against [`Backend`]: the five
//! request-path operations (`prefill`, `decode_full`, `decode_draft`,
//! `verify`, `eval_logits`) plus opaque state threading.  Two
//! implementations exist:
//!
//! * [`NativeBackend`] — pure-Rust interpreter over [`HostWeights`]
//!   (always available; the default).
//! * `model::ModelRuntime` — PJRT execution of AOT-compiled HLO (behind
//!   the non-default `pjrt` cargo feature).
//!
//! Two request-state disciplines coexist:
//!
//! * **By-value threading** (the original single-sequence API): each step
//!   consumes the previous [`BackendState`] and returns the next one, which
//!   lets the native backend mutate its KV cache in place and the PJRT
//!   backend thread device buffers without host copies.
//! * **Slot-indexed arena** (the batched serving API): the backend owns a
//!   [`SlotArena`] of per-sequence states indexed by [`SeqSlot`].  Callers
//!   allocate a slot per sequence and drive the batched operations
//!   (`prefill_batch`, `decode_full_batch`, `decode_draft_batch`,
//!   `verify_batch`), which read and write the arena in place.  Default
//!   implementations loop the single-sequence operations — so every
//!   backend (including PJRT) is batch-capable — while [`NativeBackend`]
//!   overrides them to stream each weight through the whole batch once per
//!   step (one `B×K · K×N` matmul instead of `B` GEMVs).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::model::{HostWeights, Manifest, ModelConfig};

use super::native::NativeBackend;

/// Which request-path pass a kernel invocation serves — the key the
/// traffic accounting is bucketed by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassKind {
    /// Prompt-window passes (`prefill`, `eval_logits`): full weights,
    /// one position per token.
    Prefill,
    /// Quantized draft decode: prefix plane + Eq. 4 scales only.
    Draft,
    /// Full-precision decode (the autoregressive baseline path).
    Full,
    /// Verification rows (full weights; one row per scored position).
    Verify,
}

/// Point-in-time weight-traffic totals: bytes the execution kernels
/// streamed from the resident weight store, bucketed per [`PassKind`],
/// plus the token/row counts to normalize them.
///
/// Only *weight* bytes are counted (packed planes, scales, dense
/// fallbacks, norms, embedding rows) — KV-cache and activation traffic is
/// out of scope: the paper's quarter-to-all claim is about the weight
/// stream, which dominates at decode batch sizes.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TrafficSnapshot {
    pub prefill_bytes: u64,
    pub prefill_tokens: u64,
    pub draft_bytes: u64,
    pub draft_tokens: u64,
    pub full_bytes: u64,
    pub full_tokens: u64,
    pub verify_bytes: u64,
    pub verify_rows: u64,
}

impl TrafficSnapshot {
    fn per(bytes: u64, count: u64) -> f64 {
        if count == 0 {
            0.0
        } else {
            bytes as f64 / count as f64
        }
    }

    /// Draft-pass weight bytes per decoded token (0 when none ran).
    pub fn draft_bytes_per_token(&self) -> f64 {
        Self::per(self.draft_bytes, self.draft_tokens)
    }

    /// Full-pass weight bytes per decoded token (0 when none ran).
    pub fn full_bytes_per_token(&self) -> f64 {
        Self::per(self.full_bytes, self.full_tokens)
    }

    /// Verify-pass weight bytes per scored row (0 when none ran).
    pub fn verify_bytes_per_row(&self) -> f64 {
        Self::per(self.verify_bytes, self.verify_rows)
    }

    /// The measured quarter-to-all ratio: draft bytes/token over full
    /// bytes/token (0 until both passes have run).
    pub fn draft_full_ratio(&self) -> f64 {
        let full = self.full_bytes_per_token();
        if full == 0.0 {
            0.0
        } else {
            self.draft_bytes_per_token() / full
        }
    }

    /// Whether any pass recorded traffic.
    pub fn is_empty(&self) -> bool {
        self.prefill_bytes == 0 && self.draft_bytes == 0 && self.full_bytes == 0
            && self.verify_bytes == 0
    }

    /// Accumulate another snapshot (metric sinks merge per-step drains).
    pub fn merge(&mut self, o: &TrafficSnapshot) {
        self.prefill_bytes += o.prefill_bytes;
        self.prefill_tokens += o.prefill_tokens;
        self.draft_bytes += o.draft_bytes;
        self.draft_tokens += o.draft_tokens;
        self.full_bytes += o.full_bytes;
        self.full_tokens += o.full_tokens;
        self.verify_bytes += o.verify_bytes;
        self.verify_rows += o.verify_rows;
    }
}

/// Atomic weight-traffic counters, owned by a backend and incremented by
/// its kernels (`&self` methods throughout, so counting needs interior
/// mutability).
///
/// Concurrency contract for the parallel runtime: the counters are
/// thread-safe (relaxed atomics — totals are exact, only cross-bucket
/// ordering is unspecified), but a kernel invocation is counted **once
/// per call on the calling thread**, never inside pool shards.  A weight
/// row decoded by shard 0 and a row decoded by shard 7 are part of the
/// same single stream of the tensor; per-shard counting would multiply
/// reported traffic by the thread count and break the quarter-to-all
/// ratio's thread invariance.
#[derive(Debug, Default)]
pub struct TrafficCounters {
    prefill_bytes: AtomicU64,
    prefill_tokens: AtomicU64,
    draft_bytes: AtomicU64,
    draft_tokens: AtomicU64,
    full_bytes: AtomicU64,
    full_tokens: AtomicU64,
    verify_bytes: AtomicU64,
    verify_rows: AtomicU64,
}

impl TrafficCounters {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket(&self, kind: PassKind) -> (&AtomicU64, &AtomicU64) {
        match kind {
            PassKind::Prefill => (&self.prefill_bytes, &self.prefill_tokens),
            PassKind::Draft => (&self.draft_bytes, &self.draft_tokens),
            PassKind::Full => (&self.full_bytes, &self.full_tokens),
            PassKind::Verify => (&self.verify_bytes, &self.verify_rows),
        }
    }

    /// Count weight bytes streamed by one kernel invocation.
    pub fn add_bytes(&self, kind: PassKind, bytes: u64) {
        self.bucket(kind).0.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Count tokens (or verify rows) served by one batched step.
    pub fn add_tokens(&self, kind: PassKind, tokens: u64) {
        self.bucket(kind).1.fetch_add(tokens, Ordering::Relaxed);
    }

    /// Cumulative totals since construction or the last [`drain`].
    ///
    /// [`drain`]: TrafficCounters::drain
    pub fn snapshot(&self) -> TrafficSnapshot {
        TrafficSnapshot {
            prefill_bytes: self.prefill_bytes.load(Ordering::Relaxed),
            prefill_tokens: self.prefill_tokens.load(Ordering::Relaxed),
            draft_bytes: self.draft_bytes.load(Ordering::Relaxed),
            draft_tokens: self.draft_tokens.load(Ordering::Relaxed),
            full_bytes: self.full_bytes.load(Ordering::Relaxed),
            full_tokens: self.full_tokens.load(Ordering::Relaxed),
            verify_bytes: self.verify_bytes.load(Ordering::Relaxed),
            verify_rows: self.verify_rows.load(Ordering::Relaxed),
        }
    }

    /// Return the totals and reset every counter to zero — the serving
    /// metrics accumulate per-step deltas through this.
    pub fn drain(&self) -> TrafficSnapshot {
        TrafficSnapshot {
            prefill_bytes: self.prefill_bytes.swap(0, Ordering::Relaxed),
            prefill_tokens: self.prefill_tokens.swap(0, Ordering::Relaxed),
            draft_bytes: self.draft_bytes.swap(0, Ordering::Relaxed),
            draft_tokens: self.draft_tokens.swap(0, Ordering::Relaxed),
            full_bytes: self.full_bytes.swap(0, Ordering::Relaxed),
            full_tokens: self.full_tokens.swap(0, Ordering::Relaxed),
            verify_bytes: self.verify_bytes.swap(0, Ordering::Relaxed),
            verify_rows: self.verify_rows.swap(0, Ordering::Relaxed),
        }
    }
}

/// Opaque per-request state (logits slots + KV cache), backend-specific.
pub enum BackendState {
    /// Host-memory KV cache of the native interpreter.
    Native(super::native::NativeState),
    /// Device-resident state buffer of the PJRT backend.
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer),
}

/// Index of one sequence's KV state in the backend-owned [`SlotArena`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SeqSlot(pub usize);

struct SlotArenaInner {
    /// Per-slot state; `None` for allocated-but-unprefilled slots.
    states: Vec<Option<BackendState>>,
    /// Whether the slot index is currently leased to a sequence.
    allocated: Vec<bool>,
    /// Recycled slot indices.
    free: Vec<usize>,
}

/// Backend-owned arena of per-sequence request states.
///
/// Slots are allocated one per in-flight sequence; the state itself is
/// created by `prefill_batch` and mutated in place by the batched decode /
/// verify operations.  The arena is the backing store for the [`Backend`]
/// batched-op default implementations, so every backend exposes the same
/// allocate/free discipline to the serving layer.
pub struct SlotArena {
    inner: Mutex<SlotArenaInner>,
}

impl SlotArena {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(SlotArenaInner {
                states: Vec::new(),
                allocated: Vec::new(),
                free: Vec::new(),
            }),
        }
    }

    /// Lease a slot (no state yet — `prefill_batch` creates it).
    pub fn alloc(&self) -> SeqSlot {
        let mut g = self.inner.lock().unwrap();
        if let Some(i) = g.free.pop() {
            g.allocated[i] = true;
            SeqSlot(i)
        } else {
            g.states.push(None);
            g.allocated.push(true);
            SeqSlot(g.states.len() - 1)
        }
    }

    /// Return a slot to the arena, dropping its state.  Double-frees and
    /// out-of-range slots are ignored (free is used on error paths).
    pub fn free(&self, slot: SeqSlot) {
        let mut g = self.inner.lock().unwrap();
        if slot.0 < g.allocated.len() && g.allocated[slot.0] {
            g.allocated[slot.0] = false;
            g.states[slot.0] = None;
            g.free.push(slot.0);
        }
    }

    /// Move a slot's state out (the caller must `put` it back).
    pub fn take(&self, slot: SeqSlot) -> Result<BackendState> {
        let mut g = self.inner.lock().unwrap();
        anyhow::ensure!(
            slot.0 < g.allocated.len() && g.allocated[slot.0],
            "slot {} is not allocated",
            slot.0
        );
        g.states[slot.0]
            .take()
            .ok_or_else(|| anyhow::anyhow!("slot {} has no state (prefill first?)", slot.0))
    }

    /// Store a slot's state.
    pub fn put(&self, slot: SeqSlot, state: BackendState) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        anyhow::ensure!(
            slot.0 < g.allocated.len() && g.allocated[slot.0],
            "slot {} is not allocated",
            slot.0
        );
        g.states[slot.0] = Some(state);
        Ok(())
    }

    /// Number of currently leased slots.
    pub fn in_use(&self) -> usize {
        self.inner.lock().unwrap().allocated.iter().filter(|&&a| a).count()
    }
}

impl Default for SlotArena {
    fn default() -> Self {
        Self::new()
    }
}

/// Logits for slot 0 (length `vocab`) plus the threaded state.
pub struct StepOutput {
    pub logits: Vec<f32>,
    pub state: BackendState,
}

/// All `slots` logits rows (flattened, `slots * vocab`) plus the state.
pub struct VerifyOutput {
    pub logits: Vec<f32>,
    pub state: BackendState,
}

/// One executable model: full-precision target + BSFP draft, shared KV.
///
/// Implementations must keep the draft/verify contract of the paper: the
/// draft pass runs the same architecture over the BSFP 4-bit view of the
/// *same* weights, both passes share one KV cache, and `verify` overwrites
/// drafted positions with full-precision KV.
pub trait Backend {
    /// Model architecture (dims, vocab, cache/prefill lengths).
    fn config(&self) -> &ModelConfig;

    /// Logits slots per state (max draft length + 1 bonus token).
    fn slots(&self) -> usize;

    /// Names of the BSFP-quantized linear weights.
    fn linears(&self) -> &[String];

    /// Host copies of the weights (analyses: exponent histograms, re-quantization).
    fn weights(&self) -> &HostWeights;

    /// Human-readable backend identifier (`"native"`, `"pjrt"`).
    fn backend_name(&self) -> &'static str;

    /// Run prefill over a padded prompt; slot 0 of the returned logits is
    /// the prediction after position `length - 1`.
    fn prefill(&self, tokens: &[i32], length: usize) -> Result<StepOutput>;

    /// One full-precision decode step (the autoregressive baseline).
    fn decode_full(&self, token: i32, pos: usize, state: BackendState) -> Result<StepOutput>;

    /// One 4-bit BSFP draft decode step (parameter-sharing draft model).
    fn decode_draft(&self, token: i32, pos: usize, state: BackendState) -> Result<StepOutput>;

    /// Score `slots()` tokens in one full-precision verification pass;
    /// `tokens[i]` is scored at position `pos0 + i` and full-precision KV
    /// overwrites the drafted positions (shared cache, §III-C).
    fn verify(&self, tokens: &[i32], pos0: usize, state: BackendState) -> Result<VerifyOutput>;

    /// Per-position logits `(prefill_len, vocab)` for a padded window — the
    /// perplexity harness (rows at positions `>= length` are padding).
    fn eval_logits(&self, tokens: &[i32], length: usize) -> Result<Vec<f32>>;

    /// Clone this model with every 2-D linear weight passed through
    /// `transform(name, w, k, n) -> w'` — the hook the Table I perplexity
    /// harness uses to compare quantization variants.
    fn with_transformed_weights(
        &self,
        transform: &mut dyn FnMut(&str, &[f32], usize, usize) -> Result<Vec<f32>>,
    ) -> Result<Box<dyn Backend>>;

    // ---- batched serving API (continuous batching) ----------------------
    //
    // The serving scheduler drives many sequences in lockstep through these
    // operations.  Per-sequence results are REQUIRED to be bit-identical to
    // the corresponding single-sequence operation: batching is a throughput
    // optimization, never a semantic change.
    //
    // Error contract: when a batched operation returns `Err`, the states of
    // EVERY slot in the call are unspecified (some sequences may have
    // advanced; the default impls may leave a failed sequence's state
    // consumed).  Callers must treat the whole batch as failed and free the
    // slots — which is exactly what the serving scheduler does.  Callers
    // should therefore validate predictable bad input (token range, prompt
    // shape) per-sequence *before* batching.

    /// The backend-owned per-sequence state arena backing the batched ops.
    fn arena(&self) -> &SlotArena;

    /// Lease a KV slot for a new sequence (state is created by
    /// [`Backend::prefill_batch`]).
    fn alloc_slot(&self) -> SeqSlot {
        self.arena().alloc()
    }

    /// Release a sequence's slot and drop its state.
    fn free_slot(&self, slot: SeqSlot) {
        self.arena().free(slot)
    }

    /// Run prefill for a batch of sequences; `prompts[i]` is padded to
    /// `prefill_len` and masked by `lengths[i]`.  Stores each sequence's
    /// fresh state in its slot and returns each sequence's slot-0 logits.
    fn prefill_batch(
        &self,
        slots: &[SeqSlot],
        prompts: &[Vec<i32>],
        lengths: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            slots.len() == prompts.len() && slots.len() == lengths.len(),
            "prefill_batch: mismatched batch arity"
        );
        let mut out = Vec::with_capacity(slots.len());
        for ((slot, toks), &len) in slots.iter().zip(prompts).zip(lengths) {
            let step = self.prefill(toks, len)?;
            self.arena().put(*slot, step.state)?;
            out.push(step.logits);
        }
        Ok(out)
    }

    /// One full-precision decode step for each sequence in the batch.
    fn decode_full_batch(
        &self,
        slots: &[SeqSlot],
        tokens: &[i32],
        pos: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            slots.len() == tokens.len() && slots.len() == pos.len(),
            "decode_full_batch: mismatched batch arity"
        );
        let mut out = Vec::with_capacity(slots.len());
        for ((&slot, &tok), &p) in slots.iter().zip(tokens).zip(pos) {
            let state = self.arena().take(slot)?;
            let step = self.decode_full(tok, p, state)?;
            self.arena().put(slot, step.state)?;
            out.push(step.logits);
        }
        Ok(out)
    }

    /// One BSFP draft decode step for each sequence in the batch.
    fn decode_draft_batch(
        &self,
        slots: &[SeqSlot],
        tokens: &[i32],
        pos: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            slots.len() == tokens.len() && slots.len() == pos.len(),
            "decode_draft_batch: mismatched batch arity"
        );
        let mut out = Vec::with_capacity(slots.len());
        for ((&slot, &tok), &p) in slots.iter().zip(tokens).zip(pos) {
            let state = self.arena().take(slot)?;
            let step = self.decode_draft(tok, p, state)?;
            self.arena().put(slot, step.state)?;
            out.push(step.logits);
        }
        Ok(out)
    }

    /// One verification pass for each sequence; `tokens[i]` holds exactly
    /// `slots()` (padded) tokens scored from `pos0[i]`.  Returns each
    /// sequence's flattened `slots() * vocab` logits.
    fn verify_batch(
        &self,
        slots: &[SeqSlot],
        tokens: &[Vec<i32>],
        pos0: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            slots.len() == tokens.len() && slots.len() == pos0.len(),
            "verify_batch: mismatched batch arity"
        );
        let mut out = Vec::with_capacity(slots.len());
        for ((&slot, toks), &p0) in slots.iter().zip(tokens).zip(pos0) {
            let state = self.arena().take(slot)?;
            let ver = self.verify(toks, p0, state)?;
            self.arena().put(slot, ver.state)?;
            out.push(ver.logits);
        }
        Ok(out)
    }

    // ---- weight-traffic accounting --------------------------------------
    //
    // Implementations that stream weights through instrumented kernels
    // (the native backend's bit-plane store) report bytes per pass here;
    // the defaults return zeros so backends without accounting (PJRT,
    // where traffic happens device-side) remain conformant.

    /// Cumulative weight-traffic totals since construction or the last
    /// [`Backend::drain_traffic`].
    fn traffic(&self) -> TrafficSnapshot {
        TrafficSnapshot::default()
    }

    /// Return-and-reset the traffic totals (metric sinks accumulate the
    /// per-step deltas; see `coordinator::Metrics::record_traffic`).
    fn drain_traffic(&self) -> TrafficSnapshot {
        TrafficSnapshot::default()
    }

    // ---- KV paging / prefix-sharing surface ------------------------------
    //
    // Backends with a paged KV store (the native backend) report page
    // occupancy, sharing, and prefix-cache hit rates here; the defaults
    // describe a dense, unshared store so other backends stay conformant.

    /// Point-in-time paged-KV occupancy and prefix-cache statistics
    /// (all-zero for backends without a paged store).
    fn kv_stats(&self) -> super::paging::KvStats {
        super::paging::KvStats::default()
    }

    /// How many leading tokens of `tokens` the prefix cache could serve
    /// without recomputation (0 for backends without a prefix cache).
    /// Admission control uses this to budget novel prefill work per round.
    fn prefix_cached_tokens(&self, _tokens: &[i32]) -> usize {
        0
    }

    /// Cap the paged-KV store at `budget` live pages (`None` = unbounded);
    /// allocations past the budget fail with a typed
    /// [`PageExhausted`](super::paging::PageExhausted) step error.  No-op
    /// for backends without a paged store.
    fn set_kv_page_budget(&self, _budget: Option<u64>) {}

    /// Rung 1 of the degradation ladder: release up to `n_pages` of
    /// reclaimable cached KV (prefix-cache LRU leaves), returning how many
    /// pages were actually freed.  `0` for backends without a reclaimable
    /// cache — the scheduler then escalates straight to capping
    /// speculation / shedding admissions.
    fn relieve_kv_pressure(&self, _n_pages: usize) -> usize {
        0
    }

    fn vocab(&self) -> usize {
        self.config().vocab
    }

    fn cache_len(&self) -> usize {
        self.config().cache_len
    }

    fn prefill_len(&self) -> usize {
        self.config().prefill_len
    }
}

/// Where a model's weights come from.
#[derive(Debug, Clone)]
pub enum ModelSource {
    /// The built-in synthetic zoo — no artifacts directory required.
    Builtin,
    /// An artifacts directory (trained weights; compiled HLO graphs when
    /// the `pjrt` feature is active).
    Artifacts(std::path::PathBuf),
}

impl ModelSource {
    /// `Artifacts(root)` when `root` has a manifest, `Builtin` otherwise.
    pub fn at(root: impl Into<std::path::PathBuf>) -> Self {
        let root = root.into();
        if root.join("manifest.json").exists() {
            ModelSource::Artifacts(root)
        } else {
            ModelSource::Builtin
        }
    }

    /// [`ModelSource::at`] the default artifacts root
    /// (`$SPEQ_ARTIFACTS` or `./artifacts`).
    pub fn auto() -> Self {
        Self::at(Manifest::default_root())
    }

    /// The manifest backing this source (`None` for the builtin zoo).
    pub fn manifest(&self) -> Result<Option<Manifest>> {
        match self {
            ModelSource::Builtin => Ok(None),
            ModelSource::Artifacts(root) => Ok(Some(Manifest::load(root)?)),
        }
    }
}

/// Load an execution backend for `model` from `source` with the default
/// native runtime config (`SPEQ_THREADS` or serial).
///
/// With the `pjrt` feature enabled and an artifacts source, the PJRT
/// backend is tried first (unless `SPEQ_BACKEND=native`) and the native
/// interpreter is the fallback; the default build always selects the
/// native backend.
pub fn load_backend(source: &ModelSource, model: &str) -> Result<Box<dyn Backend>> {
    load_backend_with(source, model, &super::native::NativeConfig::default())
}

/// [`load_backend`] with an explicit native runtime config (the
/// `--threads` CLI knob).  The config only affects the native backend's
/// worker-pool width — results are bit-identical for every value.
pub fn load_backend_with(
    source: &ModelSource,
    model: &str,
    native: &super::native::NativeConfig,
) -> Result<Box<dyn Backend>> {
    match source {
        ModelSource::Builtin => Ok(Box::new(NativeBackend::builtin_with(model, native)?)),
        ModelSource::Artifacts(root) => {
            let manifest = Manifest::load(root)?;
            #[cfg(feature = "pjrt")]
            {
                let force_native =
                    std::env::var("SPEQ_BACKEND").map(|v| v == "native").unwrap_or(false);
                if !force_native {
                    match pjrt_backend(&manifest, model) {
                        Ok(b) => return Ok(b),
                        Err(e) => {
                            crate::log_warn!(
                                "speq::runtime::backend",
                                "pjrt backend unavailable ({e:#}); falling back to native"
                            );
                        }
                    }
                }
            }
            Ok(Box::new(NativeBackend::from_manifest_with(&manifest, model, native)?))
        }
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(manifest: &Manifest, model: &str) -> Result<Box<dyn Backend>> {
    let rt = super::Runtime::cpu()?;
    Ok(Box::new(crate::model::ModelRuntime::load(&rt, manifest, model)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_counters_bucket_and_normalize() {
        let c = TrafficCounters::new();
        c.add_bytes(PassKind::Draft, 100);
        c.add_tokens(PassKind::Draft, 4);
        c.add_bytes(PassKind::Full, 400);
        c.add_tokens(PassKind::Full, 4);
        c.add_bytes(PassKind::Verify, 800);
        c.add_tokens(PassKind::Verify, 8);
        let s = c.snapshot();
        assert_eq!(s.draft_bytes, 100);
        assert_eq!(s.full_tokens, 4);
        assert!((s.draft_bytes_per_token() - 25.0).abs() < 1e-12);
        assert!((s.full_bytes_per_token() - 100.0).abs() < 1e-12);
        assert!((s.verify_bytes_per_row() - 100.0).abs() < 1e-12);
        assert!((s.draft_full_ratio() - 0.25).abs() < 1e-12);
        assert!(!s.is_empty());
    }

    #[test]
    fn traffic_drain_resets_and_merge_accumulates() {
        let c = TrafficCounters::new();
        c.add_bytes(PassKind::Prefill, 10);
        c.add_tokens(PassKind::Prefill, 1);
        let first = c.drain();
        assert_eq!(first.prefill_bytes, 10);
        assert!(c.snapshot().is_empty(), "drain must reset");
        c.add_bytes(PassKind::Prefill, 5);
        c.add_tokens(PassKind::Prefill, 1);
        let mut total = first;
        total.merge(&c.drain());
        assert_eq!(total.prefill_bytes, 15);
        assert_eq!(total.prefill_tokens, 2);
    }

    #[test]
    fn empty_snapshot_ratios_are_zero() {
        let s = TrafficSnapshot::default();
        assert!(s.is_empty());
        assert_eq!(s.draft_bytes_per_token(), 0.0);
        assert_eq!(s.full_bytes_per_token(), 0.0);
        assert_eq!(s.draft_full_ratio(), 0.0);
    }
}
