//! Radix tree over token streams for KV prefix sharing.
//!
//! Each node covers one KV page: up to [`PAGE_TOKENS`] consecutive
//! prompt tokens plus the (immutable, refcounted) page holding their KV
//! rows.  Full nodes (exactly `PAGE_TOKENS` tokens) may have children;
//! partial nodes (a prompt's sub-page tail) are terminal.  Prefill
//! consults the tree first: every whole-node match contributes its page
//! to the new sequence's table by reference (refcount bump) instead of
//! recomputing those positions, so prefill of a cached prefix is a tree
//! walk plus a forward pass over only the novel suffix.
//!
//! **Why reuse is bit-exact.**  A node's page was written by a
//! deterministic prefill of exactly those tokens at exactly those
//! absolute positions (RoPE positions always start at 0), and the
//! runtime's kernels are bitwise reproducible across batch composition,
//! thread count, and SIMD tier — so the cached rows are bit-identical to
//! what recomputation would produce (pinned by `kv_paging.rs` /
//! `prop_threads.rs` / `prop_simd.rs`).
//!
//! Tree references pin pages: a sequence that later *writes* into a
//! tree-shared page (its first decode lands in the cached tail page;
//! `verify` overwrites drafted positions) triggers copy-on-write of just
//! that page ([`PageAllocator::make_unique`]).  Capacity is bounded:
//! past `max_pages`, least-recently-used leaves are evicted and their
//! pages released.
//!
//! Lock order: the tree's mutex is acquired *before* the allocator's
//! (tree ops retain/release pages while holding their own lock); no path
//! takes the locks in the opposite order.

use std::sync::Mutex;

use anyhow::Result;

use super::paging::{PageAllocator, PageId, PAGE_TOKENS};

struct Node {
    /// The 1..=PAGE_TOKENS prompt tokens this node's page covers.
    tokens: Vec<i32>,
    /// The KV page; the tree holds one reference.
    page: PageId,
    /// Child node indices (full nodes only; partial nodes are terminal).
    children: Vec<usize>,
    parent: Option<usize>,
    /// LRU clock stamp of the last lookup/insert touching this node.
    last_used: u64,
}

struct TreeInner {
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    roots: Vec<usize>,
    pages_held: usize,
    clock: u64,
}

/// The prefix tree (interior mutability; shared by prefill and the
/// admission path).
pub struct PrefixTree {
    max_pages: usize,
    inner: Mutex<TreeInner>,
}

impl PrefixTree {
    /// A tree pinning at most `max_pages` pages (LRU leaf eviction past
    /// that).
    pub fn new(max_pages: usize) -> Self {
        Self {
            max_pages,
            inner: Mutex::new(TreeInner {
                nodes: Vec::new(),
                free: Vec::new(),
                roots: Vec::new(),
                pages_held: 0,
                clock: 0,
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, TreeInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Pages currently pinned by the tree.
    pub fn pages_held(&self) -> usize {
        self.lock().pages_held
    }

    /// Longest cached prefix of `tokens` reusable under the cap: pages
    /// are retained for the caller (one reference each, in table order)
    /// and the covered token count is returned.  The match never exceeds
    /// `max_tokens` — prefill passes `len - 1` so the final prompt
    /// position (whose logits the caller needs) is always computed.
    pub fn lookup(
        &self,
        alloc: &PageAllocator,
        tokens: &[i32],
        max_tokens: usize,
    ) -> (Vec<PageId>, usize) {
        let mut g = self.lock();
        g.clock += 1;
        let clock = g.clock;
        let mut pages = Vec::new();
        let mut matched = 0usize;
        let mut level: &[usize] = &g.roots;
        let mut found: Vec<usize> = Vec::new(); // node path, for stamping
        loop {
            let rest = &tokens[matched..];
            // Prefer the longest matching child (a full node over a
            // partial sibling sharing its first tokens).
            let mut best: Option<usize> = None;
            for &ni in level {
                let node = g.nodes[ni].as_ref().expect("live child");
                if node.tokens.len() <= rest.len()
                    && matched + node.tokens.len() <= max_tokens
                    && node.tokens[..] == rest[..node.tokens.len()]
                    && best.map_or(true, |b| {
                        g.nodes[b].as_ref().expect("live child").tokens.len() < node.tokens.len()
                    })
                {
                    best = Some(ni);
                }
            }
            let Some(ni) = best else { break };
            let node = g.nodes[ni].as_ref().expect("live child");
            if alloc.retain(node.page).is_err() {
                break; // defensive: tree refs keep pages live
            }
            pages.push(node.page);
            matched += node.tokens.len();
            found.push(ni);
            if node.tokens.len() < PAGE_TOKENS {
                break; // partial nodes are terminal
            }
            // Re-borrow for the next level (split lifetimes via raw walk).
            let children: *const Vec<usize> =
                &g.nodes[ni].as_ref().expect("live child").children;
            // SAFETY: `g` is held for the whole loop; nodes are not
            // mutated during lookup.
            level = unsafe { &*children };
        }
        for ni in found {
            if let Some(n) = g.nodes[ni].as_mut() {
                n.last_used = clock;
            }
        }
        (pages, matched)
    }

    /// Covered-token count [`PrefixTree::lookup`] would return, without
    /// retaining pages or touching LRU stamps (the admission path's
    /// read-only probe).
    pub fn peek(&self, tokens: &[i32], max_tokens: usize) -> usize {
        let g = self.lock();
        let mut matched = 0usize;
        let mut level: &[usize] = &g.roots;
        loop {
            let rest = &tokens[matched..];
            let mut best: Option<usize> = None;
            for &ni in level {
                let node = g.nodes[ni].as_ref().expect("live child");
                if node.tokens.len() <= rest.len()
                    && matched + node.tokens.len() <= max_tokens
                    && node.tokens[..] == rest[..node.tokens.len()]
                    && best.map_or(true, |b| {
                        g.nodes[b].as_ref().expect("live child").tokens.len() < node.tokens.len()
                    })
                {
                    best = Some(ni);
                }
            }
            let Some(ni) = best else { break };
            let node = g.nodes[ni].as_ref().expect("live child");
            matched += node.tokens.len();
            if node.tokens.len() < PAGE_TOKENS {
                break;
            }
            let children: *const Vec<usize> = &node.children;
            // SAFETY: `g` is held; read-only walk.
            level = unsafe { &*children };
        }
        matched
    }

    /// Register a freshly prefilled prompt: `pages` is the sequence's
    /// page table covering `tokens` (`ceil(len / PAGE_TOKENS)` entries).
    /// Nodes already present are reused untouched (their pages may
    /// differ in identity from the caller's but hold identical bits —
    /// prefill is deterministic); new nodes retain the caller's pages.
    /// Past the page cap, least-recently-used leaves are evicted.
    pub fn insert(&self, alloc: &PageAllocator, tokens: &[i32], pages: &[PageId]) -> Result<()> {
        let len = tokens.len();
        anyhow::ensure!(
            pages.len() * PAGE_TOKENS >= len && (len + PAGE_TOKENS - 1) / PAGE_TOKENS <= pages.len(),
            "insert: {} pages cannot cover {len} tokens",
            pages.len()
        );
        let mut g = self.lock();
        g.clock += 1;
        let clock = g.clock;
        let mut parent: Option<usize> = None;
        let n_pages = (len + PAGE_TOKENS - 1) / PAGE_TOKENS;
        for pi in 0..n_pages {
            let lo = pi * PAGE_TOKENS;
            let hi = (lo + PAGE_TOKENS).min(len);
            let seg = &tokens[lo..hi];
            let level: Vec<usize> = match parent {
                Some(p) => g.nodes[p].as_ref().expect("live parent").children.clone(),
                None => g.roots.clone(),
            };
            let existing = level.iter().copied().find(|&ni| {
                g.nodes[ni].as_ref().expect("live child").tokens[..] == seg[..]
            });
            match existing {
                Some(ni) => {
                    let node = g.nodes[ni].as_mut().expect("live child");
                    node.last_used = clock;
                    if node.tokens.len() < PAGE_TOKENS {
                        break; // identical partial tail already cached
                    }
                    parent = Some(ni);
                }
                None => {
                    alloc.retain(pages[pi])?;
                    let node = Node {
                        tokens: seg.to_vec(),
                        page: pages[pi],
                        children: Vec::new(),
                        parent,
                        last_used: clock,
                    };
                    let ni = match g.free.pop() {
                        Some(i) => {
                            g.nodes[i] = Some(node);
                            i
                        }
                        None => {
                            g.nodes.push(Some(node));
                            g.nodes.len() - 1
                        }
                    };
                    match parent {
                        Some(p) => g.nodes[p].as_mut().expect("live parent").children.push(ni),
                        None => g.roots.push(ni),
                    }
                    g.pages_held += 1;
                    if seg.len() < PAGE_TOKENS {
                        break;
                    }
                    parent = Some(ni);
                }
            }
        }
        // Enforce the page cap: evict the least-recently-used leaves
        // (fresh inserts carry the current clock, so cold branches go
        // first).
        while g.pages_held > self.max_pages {
            if !evict_one(&mut g, alloc) {
                break;
            }
        }
        Ok(())
    }

    /// On-demand pressure relief: evict up to `n` least-recently-used
    /// childless leaves and release their pages, returning how many were
    /// evicted.  This is rung 1 of the coordinator's degradation ladder —
    /// under KV page exhaustion, cached prefixes are sacrificed before
    /// speculation is capped or admissions shed.  Evicting only childless
    /// leaves keeps every remaining root-to-leaf path intact, so cache
    /// hits stay bit-exact.
    pub fn evict_lru(&self, alloc: &PageAllocator, n: usize) -> usize {
        let mut g = self.lock();
        let mut evicted = 0;
        while evicted < n {
            if !evict_one(&mut g, alloc) {
                break;
            }
            evicted += 1;
        }
        evicted
    }

    /// Drop every node and release every pinned page (tests; also lets a
    /// backend disable caching retroactively).
    pub fn clear(&self, alloc: &PageAllocator) {
        let mut g = self.lock();
        for node in g.nodes.iter_mut() {
            if let Some(n) = node.take() {
                let _ = alloc.release(n.page);
            }
        }
        g.nodes.clear();
        g.free.clear();
        g.roots.clear();
        g.pages_held = 0;
    }
}

/// Evict the single least-recently-used childless leaf, releasing its
/// page (under the tree lock; documented lock order: tree -> allocator).
/// Returns `false` when no evictable leaf exists.
fn evict_one(g: &mut TreeInner, alloc: &PageAllocator) -> bool {
    let victim = g
        .nodes
        .iter()
        .enumerate()
        .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
        .filter(|(_, n)| n.children.is_empty())
        .min_by_key(|(_, n)| n.last_used)
        .map(|(i, _)| i);
    let Some(vi) = victim else { return false };
    let node = g.nodes[vi].take().expect("victim is live");
    match node.parent {
        Some(p) => {
            let pc = &mut g.nodes[p].as_mut().expect("live parent").children;
            pc.retain(|&c| c != vi);
        }
        None => g.roots.retain(|&c| c != vi),
    }
    g.free.push(vi);
    g.pages_held -= 1;
    let _ = alloc.release(node.page);
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn page(alloc: &PageAllocator) -> PageId {
        alloc.alloc()
    }

    #[test]
    fn evict_lru_releases_leaves_on_demand() {
        let alloc = PageAllocator::new(4);
        let tree = PrefixTree::new(64);
        // Three independent single-page prompts, then a two-page chain.
        for (i, base) in [0i32, 100, 200].into_iter().enumerate() {
            let toks: Vec<i32> = (base..base + 16).collect();
            let p = page(&alloc);
            tree.insert(&alloc, &toks, &[p]).unwrap();
            alloc.release(p).unwrap();
            let _ = i;
        }
        let chain: Vec<i32> = (300..332).collect();
        let cp: Vec<PageId> = (0..2).map(|_| page(&alloc)).collect();
        tree.insert(&alloc, &chain, &cp).unwrap();
        for p in &cp {
            alloc.release(*p).unwrap();
        }
        assert_eq!(tree.pages_held(), 5);
        let before = alloc.stats().pages_in_use;
        // Evict two: the two oldest childless leaves go; interior chain
        // nodes survive until their children are gone.
        assert_eq!(tree.evict_lru(&alloc, 2), 2);
        assert_eq!(tree.pages_held(), 3);
        assert_eq!(alloc.stats().pages_in_use, before - 2, "evicted pages were released");
        // Evicting far more than exists drains the tree and reports the
        // true count.
        assert_eq!(tree.evict_lru(&alloc, 100), 3);
        assert_eq!(tree.pages_held(), 0);
        assert_eq!(alloc.stats().pages_in_use, 0);
        assert_eq!(tree.evict_lru(&alloc, 1), 0, "empty tree has nothing to evict");
    }

    #[test]
    fn lookup_matches_whole_nodes_under_the_cap() {
        let alloc = PageAllocator::new(4);
        let tree = PrefixTree::new(64);
        // 40-token prompt: two full pages + one 8-token partial tail.
        let toks: Vec<i32> = (0..40).collect();
        let pages: Vec<PageId> = (0..3).map(|_| page(&alloc)).collect();
        tree.insert(&alloc, &toks, &pages).unwrap();
        assert_eq!(tree.pages_held(), 3);

        // Same prompt, capped at len-1: the partial tail cannot fit.
        let (hit, r) = tree.lookup(&alloc, &toks, 39);
        assert_eq!(r, 32);
        assert_eq!(hit, pages[..2].to_vec());
        assert_eq!(alloc.refcount(pages[0]).unwrap(), 3, "table + tree + lookup");
        for p in hit {
            alloc.release(p).unwrap();
        }

        // A longer prompt sharing the full pages + partial tail.
        let mut longer = toks.clone();
        longer.extend(40..50);
        let (hit, r) = tree.lookup(&alloc, &longer, longer.len() - 1);
        assert_eq!(r, 40, "partial tail matches when it fits under the cap");
        assert_eq!(hit.len(), 3);
        for p in hit {
            alloc.release(p).unwrap();
        }

        // A diverging prompt matches nothing.
        let mut other = toks.clone();
        other[3] = 999;
        let (hit, r) = tree.lookup(&alloc, &other, other.len());
        assert_eq!((hit.len(), r), (0, 0));
        for p in pages {
            alloc.release(p).unwrap();
        }
    }

    #[test]
    fn peek_matches_lookup_without_retaining() {
        let alloc = PageAllocator::new(4);
        let tree = PrefixTree::new(64);
        let toks: Vec<i32> = (0..32).collect();
        let pages: Vec<PageId> = (0..2).map(|_| page(&alloc)).collect();
        tree.insert(&alloc, &toks, &pages).unwrap();
        assert_eq!(tree.peek(&toks, 31), 16);
        assert_eq!(tree.peek(&toks, 32), 32);
        assert_eq!(alloc.refcount(pages[0]).unwrap(), 2, "peek must not retain");
        for p in pages {
            alloc.release(p).unwrap();
        }
    }

    #[test]
    fn identical_reinsert_adds_nothing() {
        let alloc = PageAllocator::new(4);
        let tree = PrefixTree::new(64);
        let toks: Vec<i32> = (0..20).collect();
        let pages: Vec<PageId> = (0..2).map(|_| page(&alloc)).collect();
        tree.insert(&alloc, &toks, &pages).unwrap();
        let fresh: Vec<PageId> = (0..2).map(|_| page(&alloc)).collect();
        tree.insert(&alloc, &toks, &fresh).unwrap();
        assert_eq!(tree.pages_held(), 2, "identical prompt must not duplicate nodes");
        assert_eq!(alloc.refcount(fresh[0]).unwrap(), 1, "reinsert must not retain");
        for p in pages.into_iter().chain(fresh) {
            alloc.release(p).unwrap();
        }
    }

    #[test]
    fn eviction_releases_lru_leaves() {
        let alloc = PageAllocator::new(4);
        let tree = PrefixTree::new(2);
        let a: Vec<i32> = (0..16).collect();
        let b: Vec<i32> = (100..116).collect();
        let c: Vec<i32> = (200..216).collect();
        let (pa, pb, pc) = (page(&alloc), page(&alloc), page(&alloc));
        tree.insert(&alloc, &a, &[pa]).unwrap();
        tree.insert(&alloc, &b, &[pb]).unwrap();
        // Touch `a` so `b` is the LRU when `c` overflows the cap.
        let (hit, _) = tree.lookup(&alloc, &a, 16);
        for p in hit {
            alloc.release(p).unwrap();
        }
        tree.insert(&alloc, &c, &[pc]).unwrap();
        assert_eq!(tree.pages_held(), 2);
        assert_eq!(tree.peek(&b, 16), 0, "LRU entry evicted");
        assert_eq!(tree.peek(&a, 16), 16);
        assert_eq!(tree.peek(&c, 16), 16);
        assert_eq!(alloc.refcount(pb).unwrap(), 1, "eviction released the tree ref");
        for p in [pa, pb, pc] {
            alloc.release(p).unwrap();
        }
        tree.clear(&alloc);
        assert_eq!(alloc.stats().pages_in_use, 0);
    }
}
