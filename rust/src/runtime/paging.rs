//! Paged KV cache: a refcounted free-list page allocator.
//!
//! The dense per-sequence KV buffer (`n_layers x 2 x cache_len x d_model`
//! f32s, allocated up front at full context length) made worst-case
//! context length the memory ceiling on concurrency.  This module splits
//! the cache into fixed-size **pages** of [`PAGE_TOKENS`] token positions
//! (all layers and heads of those positions), handed out by a free-list
//! [`PageAllocator`] owned by the backend.  Sequences hold per-sequence
//! page tables (`Vec<PageId>`) instead of flat buffers, so a sequence
//! only ever occupies pages for positions it has actually written.
//!
//! **Sharing + copy-on-write.**  Pages are refcounted: the prefix tree
//! ([`super::prefix`]) and any number of sequence tables may reference
//! the same immutable page.  A sequence about to *write* a shared page
//! (first decode into a cached prefix's tail page, `verify` overwriting
//! drafted positions) calls [`PageAllocator::make_unique`], which clones
//! just that page (copy-on-write) — every other reference keeps the
//! original bits.
//!
//! **Safety model.**  [`PageId`]s carry a generation counter: releasing a
//! page to refcount 0 bumps its generation, so any stale id (double
//! free, use-after-free through an old page table) is rejected with an
//! error instead of corrupting another sequence's cache —
//! `rust/tests/kv_paging.rs` audits these paths.  Page *data* lives in
//! boxed slabs whose addresses never move as capacity grows, so the raw
//! row pointers the attention kernels gather through ([`PagePtr`])
//! remain valid across allocator growth; all page-data access is
//! serialized by the backend's workspace lock (see `native.rs`).

use std::sync::Mutex;

use anyhow::Result;

/// Token positions per KV page.  One page holds
/// `n_layers * 2 * PAGE_TOKENS * d_model` f32s — all layers/heads of 16
/// consecutive positions — so page-table indexing is `pos / PAGE_TOKENS`
/// and in-page slotting is `pos % PAGE_TOKENS`.
pub const PAGE_TOKENS: usize = 16;

/// Pages per backing slab chunk (chunks are boxed so page addresses are
/// stable as the pool grows).
const CHUNK_PAGES: usize = 32;

/// A checked handle to one page: slab index plus the generation the
/// handle was issued at.  A page's generation bumps every time it is
/// freed, so handles retained past a free are detected (use-after-free /
/// double-free) instead of silently aliasing a reallocated page.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PageId {
    index: u32,
    gen: u32,
}

impl PageId {
    /// Slab index (diagnostics; identity is `(index, gen)`).
    pub fn index(&self) -> u32 {
        self.index
    }
}

/// A raw pointer to one page's f32 data.  `Send + Sync` so the attention
/// pool's closures can gather through a batch-wide pointer table; safety
/// rests on the backend's discipline (all page-data access runs under
/// the workspace lock, and written pages are exclusively owned — see
/// `native.rs`).
#[derive(Debug, Clone, Copy)]
pub struct PagePtr(*mut f32);

unsafe impl Send for PagePtr {}
unsafe impl Sync for PagePtr {}

impl PagePtr {
    /// A null placeholder for table slots beyond a sequence's length.
    pub fn dangling() -> Self {
        PagePtr(std::ptr::NonNull::dangling().as_ptr())
    }

    /// Read `len` f32s at `offset` into the page.
    ///
    /// # Safety
    /// `offset + len` must lie inside the page and no `&mut` access to
    /// that range may be live (the backend serializes page access).
    pub unsafe fn row(&self, offset: usize, len: usize) -> &[f32] {
        std::slice::from_raw_parts(self.0.add(offset), len)
    }

    /// Mutable view of `len` f32s at `offset` into the page.
    ///
    /// # Safety
    /// As [`PagePtr::row`], plus the range must be exclusively owned by
    /// the caller (refcount-1 pages only; COW guarantees this).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut(&self, offset: usize, len: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.0.add(offset), len)
    }
}

/// Point-in-time KV paging statistics (gauges are current values,
/// counters are cumulative since allocator construction).  Surfaced
/// through [`Backend::kv_stats`] into coordinator metrics and the
/// Prometheus `/metrics` page.
///
/// [`Backend::kv_stats`]: super::backend::Backend::kv_stats
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvStats {
    /// Pages currently allocated (refcount >= 1).
    pub pages_in_use: u64,
    /// Pages currently referenced by more than one holder (shared
    /// prefix pages).
    pub pages_shared: u64,
    /// Slab capacity in pages (grows on demand, never shrinks).
    pub pages_capacity: u64,
    /// Configured page budget (`0` = unbounded); allocations beyond it
    /// fail typed and start the scheduler's degradation ladder.
    pub pages_budget: u64,
    /// High-water mark of `pages_in_use`.
    pub pages_high_water: u64,
    /// Cumulative copy-on-write page clones.
    pub cow_copies: u64,
    /// Cumulative prompt tokens served from the prefix cache (skipped
    /// forward-pass positions).
    pub prefix_hit_tokens: u64,
    /// Cumulative prompt tokens computed by the forward pass.
    pub prefix_miss_tokens: u64,
}

/// Typed KV-page exhaustion error: the allocator's page budget (or an
/// injected `page.alloc=exhaust` fault) refused an allocation.  Its
/// Display prefix (`"kv page budget exhausted"`) is a stable contract:
/// the batch engine classifies a failed step carrying it as
/// [`FailureKind::PageExhausted`] and starts the degradation ladder (the
/// vendored anyhow shim flattens error chains to strings, so there is no
/// downcast).
///
/// [`FailureKind::PageExhausted`]: crate::faults::FailureKind::PageExhausted
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageExhausted {
    /// Pages live at the refused allocation.
    pub in_use: u64,
    /// The configured budget (`u64::MAX` when the refusal was injected).
    pub budget: u64,
}

impl std::fmt::Display for PageExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv page budget exhausted ({} pages in use, budget {})", self.in_use, self.budget)
    }
}

impl std::error::Error for PageExhausted {}

struct PageMeta {
    refcount: u32,
    gen: u32,
}

struct PageInner {
    /// Backing slabs, `CHUNK_PAGES * page_elems` f32s each.  Boxed so
    /// page addresses never move when `chunks` grows.
    chunks: Vec<Box<[f32]>>,
    meta: Vec<PageMeta>,
    free: Vec<u32>,
    /// Maximum live pages [`PageAllocator::try_alloc`] will grant
    /// (`None` = unbounded, the historical behavior).
    budget: Option<u64>,
    in_use: u64,
    high_water: u64,
    cow_copies: u64,
    prefix_hit_tokens: u64,
    prefix_miss_tokens: u64,
}

/// Free-list allocator of fixed-size refcounted KV pages.
pub struct PageAllocator {
    page_elems: usize,
    inner: Mutex<PageInner>,
}

impl PageAllocator {
    /// An allocator of pages holding `page_elems` f32s each (the backend
    /// sizes this as `n_layers * 2 * PAGE_TOKENS * d_model`).
    pub fn new(page_elems: usize) -> Self {
        assert!(page_elems > 0, "page_elems must be positive");
        Self {
            page_elems,
            inner: Mutex::new(PageInner {
                chunks: Vec::new(),
                meta: Vec::new(),
                free: Vec::new(),
                budget: None,
                in_use: 0,
                high_water: 0,
                cow_copies: 0,
                prefix_hit_tokens: 0,
                prefix_miss_tokens: 0,
            }),
        }
    }

    /// f32 elements per page.
    pub fn page_elems(&self) -> usize {
        self.page_elems
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, PageInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Cap live pages at `budget` (`None` removes the cap).  Existing
    /// pages are never reclaimed here — a lowered budget only refuses
    /// *new* [`PageAllocator::try_alloc`] calls until usage drops.
    pub fn set_page_budget(&self, budget: Option<u64>) {
        self.lock().budget = budget;
    }

    /// The configured page budget, if any.
    pub fn page_budget(&self) -> Option<u64> {
        self.lock().budget
    }

    /// Allocate a zeroed page with refcount 1.  Panics if a page budget
    /// is configured and exhausted — budget-aware callers (the native
    /// backend's decode path) use [`PageAllocator::try_alloc`].
    pub fn alloc(&self) -> PageId {
        self.try_alloc().expect("kv page budget exhausted in an infallible alloc path")
    }

    /// Allocate a zeroed page with refcount 1, refusing (typed) when the
    /// page budget is exhausted or a `page.alloc=exhaust` fault fires.
    pub fn try_alloc(&self) -> Result<PageId, PageExhausted> {
        // Probe before taking the page lock (the fault registry has its
        // own lock; keep the order registry-free → page lock acyclic).
        let injected = matches!(
            crate::faults::hit(crate::faults::FaultSite::PageAlloc),
            Some(crate::faults::FaultAction::Exhaust)
        );
        let mut g = self.lock();
        if injected {
            return Err(PageExhausted { in_use: g.in_use, budget: u64::MAX });
        }
        if let Some(budget) = g.budget {
            if g.in_use >= budget {
                return Err(PageExhausted { in_use: g.in_use, budget });
            }
        }
        let index = match g.free.pop() {
            Some(i) => i,
            None => {
                let i = g.meta.len() as u32;
                if (i as usize) % CHUNK_PAGES == 0 {
                    g.chunks.push(vec![0.0f32; CHUNK_PAGES * self.page_elems].into_boxed_slice());
                }
                g.meta.push(PageMeta { refcount: 0, gen: 0 });
                i
            }
        };
        let gen = {
            let m = &mut g.meta[index as usize];
            debug_assert_eq!(m.refcount, 0, "free-list page had live references");
            m.refcount = 1;
            m.gen
        };
        // Recycled pages carry a previous sequence's KV rows; zero them so
        // a fresh page is indistinguishable from the dense layout's
        // zero-initialized buffers.
        let (c, off) = (index as usize / CHUNK_PAGES, (index as usize % CHUNK_PAGES) * self.page_elems);
        g.chunks[c][off..off + self.page_elems].fill(0.0);
        g.in_use += 1;
        g.high_water = g.high_water.max(g.in_use);
        Ok(PageId { index, gen })
    }

    fn check(&self, g: &PageInner, id: PageId, op: &str) -> Result<()> {
        let m = g
            .meta
            .get(id.index as usize)
            .ok_or_else(|| anyhow::anyhow!("{op}: page index {} out of range", id.index))?;
        anyhow::ensure!(
            m.gen == id.gen,
            "{op}: stale page id {} (gen {} != live gen {}): double free or use-after-free \
             through an old page table",
            id.index,
            id.gen,
            m.gen
        );
        anyhow::ensure!(
            m.refcount > 0,
            "{op}: page {} refcount underflow (page already free)",
            id.index
        );
        Ok(())
    }

    /// Add a reference to a live page.
    pub fn retain(&self, id: PageId) -> Result<()> {
        let mut g = self.lock();
        self.check(&g, id, "retain")?;
        g.meta[id.index as usize].refcount += 1;
        Ok(())
    }

    /// Drop a reference; the page returns to the free list (and its
    /// generation bumps, invalidating every outstanding [`PageId`]) when
    /// the count reaches zero.
    pub fn release(&self, id: PageId) -> Result<()> {
        let mut g = self.lock();
        self.check(&g, id, "release")?;
        let m = &mut g.meta[id.index as usize];
        m.refcount -= 1;
        if m.refcount == 0 {
            m.gen = m.gen.wrapping_add(1);
            g.free.push(id.index);
            g.in_use -= 1;
        }
        Ok(())
    }

    /// Current reference count of a live page.
    pub fn refcount(&self, id: PageId) -> Result<u32> {
        let g = self.lock();
        self.check(&g, id, "refcount")?;
        Ok(g.meta[id.index as usize].refcount)
    }

    /// Ensure the caller holds the only reference to this page's data,
    /// cloning it (copy-on-write) when it is shared.  Returns the id to
    /// use in the caller's table and whether a copy happened; the
    /// caller's original reference is consumed on copy.
    pub fn make_unique(&self, id: PageId) -> Result<(PageId, bool)> {
        let mut g = self.lock();
        self.check(&g, id, "make_unique")?;
        if g.meta[id.index as usize].refcount == 1 {
            return Ok((id, false));
        }
        // A COW clone is a net new live page; it honors the budget too
        // (the caller's shared reference stays intact on refusal).
        if let Some(budget) = g.budget {
            if g.in_use >= budget {
                return Err(PageExhausted { in_use: g.in_use, budget }.into());
            }
        }
        // Shared: allocate a private clone and move the caller's ref.
        let new_index = match g.free.pop() {
            Some(i) => i,
            None => {
                let i = g.meta.len() as u32;
                if (i as usize) % CHUNK_PAGES == 0 {
                    g.chunks.push(vec![0.0f32; CHUNK_PAGES * self.page_elems].into_boxed_slice());
                }
                g.meta.push(PageMeta { refcount: 0, gen: 0 });
                i
            }
        };
        let pe = self.page_elems;
        let (sc, so) = (id.index as usize / CHUNK_PAGES, (id.index as usize % CHUNK_PAGES) * pe);
        let (dc, dof) = (new_index as usize / CHUNK_PAGES, (new_index as usize % CHUNK_PAGES) * pe);
        if sc == dc {
            let chunk = &mut g.chunks[sc];
            chunk.copy_within(so..so + pe, dof);
        } else {
            // Disjoint chunks: split-borrow the vector.
            let (lo, hi) = g.chunks.split_at_mut(sc.max(dc));
            let (src, dst) = if sc < dc {
                (&lo[sc][so..so + pe], &mut hi[0][dof..dof + pe])
            } else {
                (&hi[0][so..so + pe], &mut lo[dc][dof..dof + pe])
            };
            dst.copy_from_slice(src);
        }
        let gen = {
            let m = &mut g.meta[new_index as usize];
            m.refcount = 1;
            m.gen
        };
        g.meta[id.index as usize].refcount -= 1;
        g.in_use += 1;
        g.high_water = g.high_water.max(g.in_use);
        g.cow_copies += 1;
        Ok((PageId { index: new_index, gen }, true))
    }

    /// Raw pointer to a live page's data (stable until the page is freed
    /// — slabs never move).  See [`PagePtr`] for the access contract.
    pub fn page_ptr(&self, id: PageId) -> Result<PagePtr> {
        let mut g = self.lock();
        self.check(&g, id, "page_ptr")?;
        let (c, off) = (id.index as usize / CHUNK_PAGES, (id.index as usize % CHUNK_PAGES) * self.page_elems);
        Ok(PagePtr(g.chunks[c][off..].as_mut_ptr()))
    }

    /// Record prompt tokens served from the prefix cache vs computed.
    pub fn add_prefix_tokens(&self, hit: u64, miss: u64) {
        let mut g = self.lock();
        g.prefix_hit_tokens += hit;
        g.prefix_miss_tokens += miss;
    }

    /// Point-in-time statistics (see [`KvStats`]).
    pub fn stats(&self) -> KvStats {
        let g = self.lock();
        KvStats {
            pages_in_use: g.in_use,
            pages_shared: g.meta.iter().filter(|m| m.refcount > 1).count() as u64,
            pages_capacity: g.meta.len() as u64,
            pages_budget: g.budget.unwrap_or(0),
            pages_high_water: g.high_water,
            cow_copies: g.cow_copies,
            prefix_hit_tokens: g.prefix_hit_tokens,
            prefix_miss_tokens: g.prefix_miss_tokens,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_budget_refuses_then_recovers() {
        let a = PageAllocator::new(4);
        a.set_page_budget(Some(2));
        let p = a.try_alloc().unwrap();
        let q = a.try_alloc().unwrap();
        let err = a.try_alloc().unwrap_err();
        assert_eq!(err, PageExhausted { in_use: 2, budget: 2 });
        assert_eq!(a.stats().pages_budget, 2);
        // Freeing a page restores headroom; lifting the budget unbounds.
        a.release(q).unwrap();
        let r = a.try_alloc().unwrap();
        a.set_page_budget(None);
        let s = a.try_alloc().unwrap();
        for id in [p, r, s] {
            a.release(id).unwrap();
        }
        assert_eq!(a.stats().pages_in_use, 0);
    }

    #[test]
    fn injected_exhaustion_fails_one_alloc_typed() {
        let _g = crate::faults::test_guard();
        crate::faults::install(
            crate::faults::FaultPlan::seeded(1).on_nth(
                crate::faults::FaultSite::PageAlloc,
                2,
                crate::faults::FaultAction::Exhaust,
            ),
        );
        let a = PageAllocator::new(4);
        let p = a.try_alloc().unwrap();
        let err = a.try_alloc().unwrap_err();
        assert_eq!(err.budget, u64::MAX, "injected refusal, not a real budget");
        let q = a.try_alloc().unwrap();
        for id in [p, q] {
            a.release(id).unwrap();
        }
    }

    #[test]
    fn alloc_zeroes_and_tracks_occupancy() {
        let a = PageAllocator::new(8);
        let p = a.alloc();
        let ptr = a.page_ptr(p).unwrap();
        unsafe { ptr.row_mut(0, 8) }.copy_from_slice(&[1.0; 8]);
        assert_eq!(a.stats().pages_in_use, 1);
        a.release(p).unwrap();
        assert_eq!(a.stats().pages_in_use, 0);
        // The recycled page must come back zeroed.
        let q = a.alloc();
        assert_eq!(q.index(), p.index());
        let ptr = a.page_ptr(q).unwrap();
        assert!(unsafe { ptr.row(0, 8) }.iter().all(|&v| v == 0.0));
        assert_eq!(a.stats().pages_high_water, 1);
        a.release(q).unwrap();
    }

    #[test]
    fn double_free_is_rejected() {
        let a = PageAllocator::new(4);
        let p = a.alloc();
        a.release(p).unwrap();
        let err = a.release(p).unwrap_err();
        assert!(format!("{err}").contains("stale page id"), "{err}");
    }

    #[test]
    fn stale_id_after_recycle_is_rejected() {
        let a = PageAllocator::new(4);
        let p = a.alloc();
        a.release(p).unwrap();
        let q = a.alloc(); // recycles the same slab index, new generation
        assert_eq!(p.index(), q.index());
        assert!(a.page_ptr(p).is_err(), "stale page_ptr must fail");
        assert!(a.retain(p).is_err(), "stale retain must fail");
        assert!(a.release(p).is_err(), "stale release must fail");
        a.release(q).unwrap();
    }

    #[test]
    fn refcounts_gate_the_free() {
        let a = PageAllocator::new(4);
        let p = a.alloc();
        a.retain(p).unwrap();
        assert_eq!(a.refcount(p).unwrap(), 2);
        a.release(p).unwrap();
        assert_eq!(a.stats().pages_in_use, 1, "still referenced");
        a.release(p).unwrap();
        assert_eq!(a.stats().pages_in_use, 0);
        assert!(a.refcount(p).is_err(), "freed page has no refcount");
    }

    #[test]
    fn make_unique_cows_shared_pages_only() {
        let a = PageAllocator::new(4);
        let p = a.alloc();
        let ptr = a.page_ptr(p).unwrap();
        unsafe { ptr.row_mut(0, 4) }.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        // Sole owner: no copy.
        let (same, copied) = a.make_unique(p).unwrap();
        assert_eq!(same, p);
        assert!(!copied);
        // Shared: the caller gets a private clone, the original survives.
        a.retain(p).unwrap();
        let (q, copied) = a.make_unique(p).unwrap();
        assert!(copied);
        assert_ne!(q.index(), p.index());
        assert_eq!(a.refcount(p).unwrap(), 1);
        assert_eq!(a.refcount(q).unwrap(), 1);
        let qp = a.page_ptr(q).unwrap();
        assert_eq!(unsafe { qp.row(0, 4) }, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.stats().cow_copies, 1);
        // The clone is independent of the original.
        unsafe { qp.row_mut(0, 1) }[0] = 9.0;
        let pp = a.page_ptr(p).unwrap();
        assert_eq!(unsafe { pp.row(0, 1) }[0], 1.0);
        a.release(p).unwrap();
        a.release(q).unwrap();
    }

    #[test]
    fn pointers_survive_slab_growth() {
        let a = PageAllocator::new(2);
        let first = a.alloc();
        let ptr = a.page_ptr(first).unwrap();
        unsafe { ptr.row_mut(0, 2) }.copy_from_slice(&[7.0, 8.0]);
        // Force several chunk allocations.
        let many: Vec<PageId> = (0..CHUNK_PAGES * 3).map(|_| a.alloc()).collect();
        assert_eq!(unsafe { ptr.row(0, 2) }, &[7.0, 8.0], "page data moved");
        for p in many {
            a.release(p).unwrap();
        }
        a.release(first).unwrap();
    }
}
