//! Std-only persistent worker pool for the deterministic parallel runtime.
//!
//! [`WorkerPool`] owns `threads - 1` long-lived worker threads (the caller
//! is always shard 0, so a pool of one thread spawns nothing and runs every
//! job inline).  [`WorkerPool::run`] executes a borrowed closure over job
//! indices `0..jobs` and returns only after every job has finished, which
//! is what makes handing workers a non-`'static` closure sound.
//!
//! **Determinism contract.**  The pool assigns job `j` statically to
//! participant `j % threads` — there is no work stealing and no
//! load-dependent repartitioning.  Parallel kernels shard the
//! *output-column* dimension into contiguous ranges (see [`col_range`]),
//! so every output element is computed by exactly one shard, in exactly
//! the same ascending-index accumulation order as the serial kernel.
//! Results are therefore bitwise identical for every thread count; which
//! OS thread happens to execute a shard can never change output bits.
//!
//! The pool is intentionally tiny: a published epoch counter, a static
//! round-robin job split, and a spin-then-sleep wait on each side.  Workers
//! spin briefly (kernel launches arrive in bursts — several per decode
//! step) before parking on a condvar; the caller busy-yields for the
//! stragglers since it just finished the same-sized shard itself.

use std::cell::UnsafeCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A borrowed task: the closure `run` is currently executing, type-erased.
/// The `'static` lifetime is a lie told only between publish and the final
/// `remaining` decrement — `run` does not return while any worker can still
/// dereference it.
type TaskRef = &'static (dyn Fn(usize) + Sync);

/// Iterations of the workers' spin phase before parking on the condvar.
const SPIN_ITERS: u32 = 4096;

struct Shared {
    /// Bumped once per published job batch; workers wait for a change.
    epoch: AtomicU64,
    /// The current task and its job count.  Written by `run` strictly
    /// before the epoch bump (Release) and read by workers strictly after
    /// observing it (Acquire), while `remaining` proves all workers idle.
    task: UnsafeCell<Option<(TaskRef, usize)>>,
    /// Workers that have not finished the current epoch yet.
    remaining: AtomicUsize,
    /// Set when any worker's shard panicked (the caller re-panics).
    panicked: AtomicBool,
    shutdown: AtomicBool,
    /// Sleep lock + condvar for the workers' slow-path wait.
    sleep: Mutex<()>,
    cv: Condvar,
}

// SAFETY: the `UnsafeCell` is only written by `run` while every worker is
// provably idle (`remaining == 0` from the previous epoch, observed via the
// caller's wait), and only read by workers after an Acquire load of the
// epoch that was bumped with Release after the write.
unsafe impl Sync for Shared {}

/// Persistent worker pool; see the module docs for the determinism
/// contract.  Dropping the pool joins every worker.
pub struct WorkerPool {
    threads: usize,
    shared: Arc<Shared>,
    /// Serializes concurrent `run` calls (the pool runs one job batch at a
    /// time; kernels never nest pool calls).
    job_lock: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// A pool executing jobs across `threads` participants: the calling
    /// thread plus `threads - 1` spawned workers.  `threads == 1` (or 0,
    /// normalized up) spawns nothing and makes [`WorkerPool::run`] a plain
    /// serial loop.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            epoch: AtomicU64::new(0),
            task: UnsafeCell::new(None),
            remaining: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            sleep: Mutex::new(()),
            cv: Condvar::new(),
        });
        let mut handles = Vec::new();
        for w in 1..threads {
            let shared = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("speq-pool-{w}"))
                    .spawn(move || worker_main(w, threads, shared))
                    .expect("spawn pool worker"),
            );
        }
        Self { threads, shared, job_lock: Mutex::new(()), handles }
    }

    /// Number of participants (caller + workers) a job batch is split over.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(j)` for every `j in 0..jobs`, returning when all are
    /// done.  Job `j` runs on participant `j % threads()`; the caller is
    /// participant 0 and does its share in place.  Panics from any shard
    /// propagate to the caller after the batch drains (the pool stays
    /// usable).  Must not be called from inside a running job.
    pub fn run(&self, jobs: usize, f: impl Fn(usize) + Sync) {
        if self.threads <= 1 || jobs <= 1 {
            for j in 0..jobs {
                f(j);
            }
            return;
        }
        let _serialize = self.job_lock.lock().unwrap_or_else(|e| e.into_inner());
        // SAFETY: see `TaskRef` — the borrow is dead before `run` returns.
        let task: TaskRef = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), TaskRef>(&f)
        };
        unsafe {
            *self.shared.task.get() = Some((task, jobs));
        }
        self.shared.panicked.store(false, Ordering::Relaxed);
        self.shared.remaining.store(self.threads - 1, Ordering::Release);
        self.shared.epoch.fetch_add(1, Ordering::Release);
        {
            // Lock-then-notify pairs with the workers' epoch re-check under
            // the same lock, so a worker can never sleep through a publish.
            let _g = self.shared.sleep.lock().unwrap_or_else(|e| e.into_inner());
            self.shared.cv.notify_all();
        }

        // The caller is participant 0.
        let caller = catch_unwind(AssertUnwindSafe(|| {
            let mut j = 0;
            while j < jobs {
                f(j);
                j += self.threads;
            }
        }));

        // The workers still borrow `f`: drain them before unwinding.  The
        // wait is short — the caller just finished an equal share — so a
        // yielding spin beats a condvar round-trip.
        let mut spins = 0u32;
        while self.shared.remaining.load(Ordering::Acquire) != 0 {
            spins = spins.wrapping_add(1);
            if spins % 64 == 0 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        unsafe {
            *self.shared.task.get() = None;
        }
        if let Err(p) = caller {
            resume_unwind(p);
        }
        if self.shared.panicked.load(Ordering::Acquire) {
            panic!("worker thread panicked in parallel kernel shard");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _g = self.shared.sleep.lock().unwrap_or_else(|e| e.into_inner());
            self.shared.cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(index: usize, threads: usize, shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        // Spin-then-sleep wait for a new epoch (or shutdown).
        let mut iters = 0u32;
        let epoch = loop {
            let e = shared.epoch.load(Ordering::Acquire);
            if e != seen || shared.shutdown.load(Ordering::Acquire) {
                break e;
            }
            iters += 1;
            if iters < SPIN_ITERS {
                if iters % 32 == 0 {
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
            } else {
                let mut g = shared.sleep.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    let e = shared.epoch.load(Ordering::Acquire);
                    if e != seen || shared.shutdown.load(Ordering::Acquire) {
                        break;
                    }
                    g = shared.cv.wait(g).unwrap_or_else(|e| e.into_inner());
                }
                break shared.epoch.load(Ordering::Acquire);
            }
        };
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        seen = epoch;
        // SAFETY: the publisher wrote the task before the Release epoch
        // bump we just Acquired, and will not overwrite it until we
        // decrement `remaining` below.
        let (task, jobs) = unsafe { (*shared.task.get()).expect("pool epoch without a task") };
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut j = index;
            while j < jobs {
                task(j);
                j += threads;
            }
        }));
        if result.is_err() {
            shared.panicked.store(true, Ordering::Release);
        }
        shared.remaining.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Columns `[j0, j1)` of shard `s` when `n` output columns are split into
/// `t` contiguous, near-equal ranges (the first `n % t` shards get one
/// extra column).  The split depends only on `(n, s, t)`, never on load —
/// part of the determinism contract.
pub fn col_range(n: usize, s: usize, t: usize) -> (usize, usize) {
    debug_assert!(s < t);
    let base = n / t;
    let rem = n % t;
    let j0 = s * base + s.min(rem);
    let j1 = j0 + base + usize::from(s < rem);
    (j0, j1)
}

/// A shared mutable f32 view for pool shards that write provably disjoint
/// index ranges (kernel output columns, per-shard scratch tiles, per-head
/// attention rows).  The *caller* of [`SharedSlice::slice_mut`] is
/// responsible for disjointness; the type only carries the pointer across
/// the closure boundary.
pub struct SharedSlice<'a> {
    ptr: *mut f32,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [f32]>,
}

// SAFETY: f32 has no drop/aliasing semantics of its own; soundness rests
// entirely on the disjoint-range contract of `slice_mut` callers.
unsafe impl Send for SharedSlice<'_> {}
unsafe impl Sync for SharedSlice<'_> {}

impl<'a> SharedSlice<'a> {
    pub fn new(slice: &'a mut [f32]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    /// Mutable view of `start..start + len`.
    ///
    /// # Safety
    /// No two concurrently live views may overlap, and the underlying
    /// slice must not be accessed through any other path while views are
    /// live.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [f32] {
        debug_assert!(start + len <= self.len, "SharedSlice range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        let hits = AtomicUsize::new(0);
        pool.run(7, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn every_job_runs_exactly_once() {
        for threads in [2usize, 3, 4, 8] {
            let pool = WorkerPool::new(threads);
            for jobs in [0usize, 1, 2, 5, 16, 33] {
                let counts: Vec<AtomicUsize> =
                    (0..jobs).map(|_| AtomicUsize::new(0)).collect();
                pool.run(jobs, |j| {
                    counts[j].fetch_add(1, Ordering::Relaxed);
                });
                for (j, c) in counts.iter().enumerate() {
                    assert_eq!(
                        c.load(Ordering::Relaxed),
                        1,
                        "job {j} ran a wrong number of times (T={threads}, jobs={jobs})"
                    );
                }
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_many_batches() {
        let pool = WorkerPool::new(4);
        let total = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(9, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 200 * 9);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            // Job 1 runs on the worker (1 % 2 == 1); job 0 on the caller.
            pool.run(2, |j| {
                if j == 1 {
                    panic!("shard boom");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must reach the caller");
        // The pool keeps working afterwards.
        let hits = AtomicUsize::new(0);
        pool.run(4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn col_range_partitions_exactly() {
        for n in [0usize, 1, 5, 16, 127, 256] {
            for t in [1usize, 2, 3, 4, 8] {
                let mut covered = 0;
                let mut prev_end = 0;
                for s in 0..t {
                    let (j0, j1) = col_range(n, s, t);
                    assert_eq!(j0, prev_end, "ranges must be contiguous");
                    assert!(j1 >= j0);
                    covered += j1 - j0;
                    prev_end = j1;
                }
                assert_eq!(prev_end, n);
                assert_eq!(covered, n);
            }
        }
    }

    #[test]
    fn shared_slice_disjoint_writes() {
        let mut buf = vec![0.0f32; 64];
        let pool = WorkerPool::new(4);
        {
            let view = SharedSlice::new(&mut buf);
            pool.run(4, |s| {
                let (j0, j1) = col_range(64, s, 4);
                // SAFETY: col_range partitions 0..64 disjointly.
                let part = unsafe { view.slice_mut(j0, j1 - j0) };
                for (off, v) in part.iter_mut().enumerate() {
                    *v = (j0 + off) as f32;
                }
            });
        }
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
    }
}
