//! Executable wrapper: buffer-first execution with host read-back helpers.

use anyhow::{Context, Result};

/// A compiled graph plus its provenance, executed over PJRT buffers.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

/// A tensor copied back to the host.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl Executable {
    pub(crate) fn new(exe: xla::PjRtLoadedExecutable, name: String) -> Self {
        Self { exe, name }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute over device buffers; returns one buffer per graph output.
    ///
    /// Graphs are lowered with `return_tuple=False`, so PJRT hands back the
    /// outputs individually — this is what lets the engine thread the KV
    /// buffer between steps with zero host copies.
    pub fn run(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self
            .exe
            .execute_b(args)
            .with_context(|| format!("executing {}", self.name))?;
        anyhow::ensure!(!out.is_empty(), "{}: no output device", self.name);
        Ok(out.swap_remove(0))
    }

    /// Copy an f32 output buffer back to the host.
    ///
    /// Goes through a literal: this PJRT build (xla_extension 0.5.1 CPU)
    /// does not implement raw host copies.
    pub fn to_host_f32(buf: &xla::PjRtBuffer) -> Result<HostTensor> {
        let shape = buf.on_device_shape()?;
        let dims: Vec<usize> = match &shape {
            xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
            _ => anyhow::bail!("expected array output, got {shape:?}"),
        };
        let literal = buf.to_literal_sync()?;
        let data = literal.to_vec::<f32>()?;
        anyhow::ensure!(
            data.len() == dims.iter().product::<usize>(),
            "element count mismatch: {} vs dims {dims:?}",
            data.len()
        );
        Ok(HostTensor { data, dims })
    }
}

impl HostTensor {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.dims.len(), 2, "row() needs a 2-D tensor");
        let n = self.dims[1];
        &self.data[i * n..(i + 1) * n]
    }
}
