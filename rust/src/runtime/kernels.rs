//! Cache-blocked, column-sharded GEMV/GEMM kernels over the bit-plane
//! weight store.
//!
//! Three kernels share one contract: `X (B, k) @ W (k, n)` with `W`
//! row-major, activations and outputs as *flat* row-major batches
//! (`xs[b * k + i]`, `ys[b * n + j]` — no per-row heap allocation), the
//! weight-row loop outermost inside each shard (each row's bytes are
//! streamed from memory exactly once per shard for the whole batch), and
//! per-output accumulation in ascending-`i` order.
//!
//! **Parallelism and determinism.**  Every kernel splits the *output
//! column* dimension into contiguous per-shard ranges
//! ([`pool::col_range`]) executed on a [`WorkerPool`].  A shard owns its
//! columns outright: it decodes only those columns of each weight block
//! into a private scratch tile, initializes them on the first accumulation
//! block (`y = 0.0 + a·x`, folding the old separate zeroing pass into the
//! first weight row), and accumulates in the exact ascending-`i` order of
//! the serial loop.  Because each output element is produced by exactly
//! one shard with an unchanged accumulation order, kernel outputs are
//! **bitwise identical for every thread count** — the property
//! `prop_threads.rs` and the golden harness pin.  Traffic accounting
//! stays with the caller (one count per kernel call, never per shard —
//! see [`super::TrafficCounters`]).
//!
//! **SIMD dispatch: SIMD decodes, scalar-order accumulates.**  Each kernel
//! takes a [`SimdLevel`] (detected once at backend init, forced via
//! `SPEQ_SIMD` / `--simd`).  Vector code is confined to the element-wise,
//! order-free parts — the plane decoders (`bsfp::simd`) and the
//! per-element `y[j] += a · x[j]` update ([`axpy_simd`], separate
//! multiply + add, never a fused FMA) — while every output element keeps
//! the serial ascending-`i` accumulation order.  Per-lane IEEE multiply
//! and add round exactly like their scalar counterparts, so **every
//! dispatch tier produces bitwise identical outputs** (pinned by
//! `rust/tests/prop_simd.rs` and the goldens).  [`dot`] is deliberately
//! *not* vectorized: a horizontal reduction changes the summation order
//! and would break the bitwise contract.
//!
//! * [`gemm_dense`] — plain f32 weights (non-quantizable linears, the
//!   Algorithm-1 outlier fallback, transformed-weight variants).
//! * [`gemm_full_planes`] — decodes prefix + residual planes on the fly
//!   ([`PlanePair::decode_row_pair_full_cols`]), one [`BLOCK_ROWS`]-row
//!   block at a time into a scratch tile that stays cache-resident while
//!   every batch row consumes it; prefetches the next block's plane bytes
//!   during accumulation.
//! * [`gemm_draft_prefix`] — decodes *only* the nibble-packed prefix plane
//!   (plus Eq. 4 group scales), streaming a quarter of the full pass's
//!   weight bytes per token.  The per-column `scale / tensor_scale` factor
//!   is hoisted to a once-per-scale-group row (an exact factorization —
//!   every draft LUT entry is a power of two — so the decoded bits are
//!   unchanged; see [`bsfp::simd::decode_draft_row_pair_scalar`]).
//!
//! [`pool::col_range`]: super::pool::col_range
//! [`bsfp::simd::decode_draft_row_pair_scalar`]: crate::bsfp::simd::decode_draft_row_pair_scalar

use super::pool::{col_range, SharedSlice, WorkerPool};
use crate::bsfp::simd::{decode_draft_row_pair, draft_lut, SimdLevel};
use crate::bsfp::{PlanePair, GROUP_SIZE};

/// Weight rows decoded per block.  Must be even (the planes pack row
/// pairs) and divide [`GROUP_SIZE`] (so a block never straddles a scale
/// group); 16 rows of up to 512 f32 columns keep the scratch tile well
/// inside L1.
pub const BLOCK_ROWS: usize = 16;

/// Scratch rows the blocked kernels need: the [`BLOCK_ROWS`] decode tile
/// plus one extra row holding the draft kernel's hoisted
/// `scale / tensor_scale` factors (recomputed only when the block enters a
/// new scale group).  Callers size `scratch` as `SCRATCH_ROWS * n`.
pub const SCRATCH_ROWS: usize = BLOCK_ROWS + 1;

// Load-bearing invariant: `gemm_draft_prefix` reads one scale-group row
// per block and the plane decoders walk row pairs — retuning BLOCK_ROWS
// to a value violating either silently corrupts draft scales.
const _: () = assert!(BLOCK_ROWS % 2 == 0 && GROUP_SIZE % BLOCK_ROWS == 0);

/// Scalar dot product.  Deliberately not SIMD-dispatched: vectorizing a
/// reduction reorders the partial sums, which would break the bitwise
/// thread/SIMD invariance contract for the attention scores built on it.
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// `y += a * x` (scalar reference; also the attention/residual update,
/// which is not on the dispatched-kernel path).
pub(crate) fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// `y = 0.0 + a * x` (scalar reference): the first accumulation block,
/// which doubles as the output zeroing.  The explicit `0.0 +` keeps the
/// result bitwise identical to "fill with zero, then `+=`" — for
/// `a * x = -0.0` the sum is `+0.0`, exactly what the old separate-zeroing
/// code produced — and IEEE forbids folding `0.0 + z` to `z`, so the
/// optimizer cannot change it.
pub(crate) fn axpy_init(y: &mut [f32], a: f32, x: &[f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = 0.0 + a * xi;
    }
}

/// SIMD-dispatched `y += a * x`.  Per-lane multiply + add (never FMA)
/// rounds exactly like the scalar loop, so all tiers are bitwise equal.
#[inline]
pub(crate) fn axpy_simd(level: SimdLevel, y: &mut [f32], a: f32, x: &[f32]) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: available levels only (enforced at config resolve time).
        SimdLevel::Avx2 => unsafe { x86::axpy_avx2(y, a, x) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::axpy_sse41(y, a, x) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::axpy_neon(y, a, x) },
        _ => axpy(y, a, x),
    }
}

/// SIMD-dispatched `y = 0.0 + a * x` (see [`axpy_init`]).
#[inline]
pub(crate) fn axpy_init_simd(level: SimdLevel, y: &mut [f32], a: f32, x: &[f32]) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: available levels only (enforced at config resolve time).
        SimdLevel::Avx2 => unsafe { x86::axpy_init_avx2(y, a, x) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::axpy_init_sse41(y, a, x) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::axpy_init_neon(y, a, x) },
        _ => axpy_init(y, a, x),
    }
}

/// Software-prefetch a byte range into L1 (x86_64 only; a no-op
/// elsewhere).  Used to pull the *next* weight block's plane bytes in
/// while the current block's accumulation runs.
#[inline]
fn prefetch_bytes(data: &[u8]) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: `_mm_prefetch` is a hint over baseline SSE (always present
    // on x86_64) and cannot fault even on a bad address; all addresses
    // here are in-bounds anyway.
    unsafe {
        use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        let mut p = data.as_ptr();
        let end = p.add(data.len());
        while p < end {
            _mm_prefetch::<_MM_HINT_T0>(p as *const i8);
            p = p.add(64);
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = data;
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let prod = _mm256_mul_ps(av, _mm256_loadu_ps(x.as_ptr().add(i)));
            let sum = _mm256_add_ps(_mm256_loadu_ps(y.as_ptr().add(i)), prod);
            _mm256_storeu_ps(y.as_mut_ptr().add(i), sum);
            i += 8;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_init_avx2(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let av = _mm256_set1_ps(a);
        let zero = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let prod = _mm256_mul_ps(av, _mm256_loadu_ps(x.as_ptr().add(i)));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(zero, prod));
            i += 8;
        }
        while i < n {
            y[i] = 0.0 + a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn axpy_sse41(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let av = _mm_set1_ps(a);
        let mut i = 0;
        while i + 4 <= n {
            let prod = _mm_mul_ps(av, _mm_loadu_ps(x.as_ptr().add(i)));
            let sum = _mm_add_ps(_mm_loadu_ps(y.as_ptr().add(i)), prod);
            _mm_storeu_ps(y.as_mut_ptr().add(i), sum);
            i += 4;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn axpy_init_sse41(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let av = _mm_set1_ps(a);
        let zero = _mm_setzero_ps();
        let mut i = 0;
        while i + 4 <= n {
            let prod = _mm_mul_ps(av, _mm_loadu_ps(x.as_ptr().add(i)));
            _mm_storeu_ps(y.as_mut_ptr().add(i), _mm_add_ps(zero, prod));
            i += 4;
        }
        while i < n {
            y[i] = 0.0 + a * x[i];
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_neon(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let av = vdupq_n_f32(a);
        let mut i = 0;
        while i + 4 <= n {
            let prod = vmulq_f32(av, vld1q_f32(x.as_ptr().add(i)));
            let sum = vaddq_f32(vld1q_f32(y.as_ptr().add(i)), prod);
            vst1q_f32(y.as_mut_ptr().add(i), sum);
            i += 4;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_init_neon(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let av = vdupq_n_f32(a);
        let zero = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= n {
            let prod = vmulq_f32(av, vld1q_f32(x.as_ptr().add(i)));
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(zero, prod));
            i += 4;
        }
        while i < n {
            y[i] = 0.0 + a * x[i];
            i += 1;
        }
    }
}

/// `X (B, k) @ w (k, n)` with `w` row-major f32, into `ys (B, n)`.
///
/// Inside each column shard the weight-row loop is outermost, so each
/// row's bytes are streamed from memory exactly once per shard for the
/// whole batch — the continuous-batching bandwidth win.  Each output
/// element accumulates in the same `i`-ascending order as a serial batch
/// of one, so results are bit-identical for every batch size, thread
/// count, and SIMD tier.
pub fn gemm_dense(
    pool: &WorkerPool,
    level: SimdLevel,
    xs: &[f32],
    b: usize,
    w: &[f32],
    k: usize,
    n: usize,
    ys: &mut [f32],
) {
    debug_assert_eq!(xs.len(), b * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(ys.len(), b * n);
    let t = pool.threads();
    let y = SharedSlice::new(ys);
    pool.run(t, |s| {
        let (j0, j1) = col_range(n, s, t);
        if j0 == j1 {
            return;
        }
        let width = j1 - j0;
        if k == 0 {
            // No accumulation block will initialize the outputs.
            for bi in 0..b {
                // SAFETY: shard `s` exclusively owns columns j0..j1 of
                // every batch row (col_range partitions 0..n disjointly).
                unsafe { y.slice_mut(bi * n + j0, width) }.fill(0.0);
            }
            return;
        }
        for i in 0..k {
            let row = &w[i * n + j0..i * n + j1];
            for bi in 0..b {
                let x = xs[bi * k + i];
                // SAFETY: as above — disjoint column ranges per shard.
                let yrow = unsafe { y.slice_mut(bi * n + j0, width) };
                if i == 0 {
                    // First row initializes (zeroing folded into the
                    // first accumulation — same bits as fill(0.0) + `+=`).
                    axpy_init_simd(level, yrow, x, row);
                } else {
                    axpy_simd(level, yrow, x, row);
                }
            }
        }
    });
}

/// `X (B, k) @ decode_full(planes)` — the full/verify pass kernel, into
/// `ys (B, n)`.
///
/// Streams prefix + residual (2 bytes per weight, the FP16 footprint) and
/// reconstructs each shard's columns of a [`BLOCK_ROWS`]-row block into a
/// private region of `scratch` (length >= [`SCRATCH_ROWS`]` * n`) via the
/// Fig. 5(b) decoder (SIMD-dispatched) before accumulating.  Row order
/// inside a block is ascending, so results are bitwise equal to
/// [`gemm_dense`] over the decoded values.
pub fn gemm_full_planes(
    pool: &WorkerPool,
    level: SimdLevel,
    xs: &[f32],
    b: usize,
    planes: &PlanePair,
    scratch: &mut [f32],
    ys: &mut [f32],
) {
    let (k, n) = (planes.k, planes.n);
    debug_assert_eq!(xs.len(), b * k);
    debug_assert_eq!(ys.len(), b * n);
    debug_assert!(scratch.len() >= BLOCK_ROWS * n);
    debug_assert_eq!(k % 2, 0);
    let t = pool.threads();
    let y = SharedSlice::new(ys);
    let tiles = SharedSlice::new(&mut scratch[..BLOCK_ROWS * n]);
    pool.run(t, |s| {
        let (j0, j1) = col_range(n, s, t);
        if j0 == j1 {
            return;
        }
        let width = j1 - j0;
        // SAFETY: per-shard regions are disjoint — shard widths sum to n,
        // so `BLOCK_ROWS * j0` offsets never overlap; same for the output
        // columns.
        let tile = unsafe { tiles.slice_mut(BLOCK_ROWS * j0, BLOCK_ROWS * width) };
        if k == 0 {
            for bi in 0..b {
                unsafe { y.slice_mut(bi * n + j0, width) }.fill(0.0);
            }
            return;
        }
        let mut i0 = 0;
        while i0 < k {
            let rows = BLOCK_ROWS.min(k - i0);
            debug_assert_eq!(rows % 2, 0, "plane row pairs require an even block");
            for r in 0..rows / 2 {
                let (lo, hi) = tile[2 * r * width..(2 * r + 2) * width].split_at_mut(width);
                planes.decode_row_pair_full_cols_with(level, i0 / 2 + r, j0, j1, lo, hi);
            }
            // Pull the next block's plane bytes toward L1 while the
            // accumulation below runs on the current tile.
            if i0 + rows < k {
                let nrows = BLOCK_ROWS.min(k - i0 - rows) / 2;
                let np = (i0 + rows) / 2;
                for r in 0..nrows {
                    prefetch_bytes(&planes.prefix[(np + r) * n + j0..(np + r) * n + j1]);
                    prefetch_bytes(
                        &planes.residual[3 * ((np + r) * n + j0)..3 * ((np + r) * n + j1)],
                    );
                }
            }
            for r in 0..rows {
                let trow = &tile[r * width..(r + 1) * width];
                for bi in 0..b {
                    let x = xs[bi * k + i0 + r];
                    let yrow = unsafe { y.slice_mut(bi * n + j0, width) };
                    if i0 + r == 0 {
                        axpy_init_simd(level, yrow, x, trow);
                    } else {
                        axpy_simd(level, yrow, x, trow);
                    }
                }
            }
            i0 += rows;
        }
    });
}

/// `X (B, k) @ draft(prefix, scales)` — the quarter-traffic draft kernel,
/// into `ys (B, n)`.
///
/// Streams only the nibble-packed prefix plane plus the Eq. 4 group
/// scales.  Each decoded value is
/// `draft_value(W_q) * (scale / tensor_scale)` with the parenthesized
/// factor hoisted to a once-per-scale-group row kept in the extra
/// [`SCRATCH_ROWS`] scratch row (`~GROUP_SIZE/2×` fewer divides than the
/// old per-element divide).  The factorization is bitwise exact —
/// `draft_value` is always a power of two, and all intermediates stay
/// normal — so outputs remain bit-identical to the retired `derive_draft`
/// dequantization (`dequant_draft` multiplied code value by scale, then
/// divided by the Algorithm-1 tensor scale).  `tensor_scale` is 1.0 for
/// in-domain tensors (division by 1.0 is an IEEE identity).
#[allow(clippy::too_many_arguments)]
pub fn gemm_draft_prefix(
    pool: &WorkerPool,
    level: SimdLevel,
    xs: &[f32],
    b: usize,
    prefix: &[u8],
    scales: &[f32],
    tensor_scale: f32,
    k: usize,
    n: usize,
    scratch: &mut [f32],
    ys: &mut [f32],
) {
    debug_assert_eq!(xs.len(), b * k);
    debug_assert_eq!(ys.len(), b * n);
    debug_assert!(scratch.len() >= SCRATCH_ROWS * n);
    debug_assert_eq!(prefix.len(), k / 2 * n);
    debug_assert_eq!(scales.len(), k / GROUP_SIZE * n);
    debug_assert_eq!(k % GROUP_SIZE, 0);
    let lut = draft_lut();
    let t = pool.threads();
    let y = SharedSlice::new(ys);
    let tiles = SharedSlice::new(&mut scratch[..SCRATCH_ROWS * n]);
    pool.run(t, |s| {
        let (j0, j1) = col_range(n, s, t);
        if j0 == j1 {
            return;
        }
        let width = j1 - j0;
        // SAFETY: disjoint per-shard regions, as in `gemm_full_planes`;
        // the hoisted-factor row lives past the BLOCK_ROWS tiles at
        // `BLOCK_ROWS * n + j0`, likewise partitioned by column.
        let tile = unsafe { tiles.slice_mut(BLOCK_ROWS * j0, BLOCK_ROWS * width) };
        let pre = unsafe { tiles.slice_mut(BLOCK_ROWS * n + j0, width) };
        if k == 0 {
            for bi in 0..b {
                unsafe { y.slice_mut(bi * n + j0, width) }.fill(0.0);
            }
            return;
        }
        let mut cur_group = usize::MAX;
        let mut i0 = 0;
        while i0 < k {
            let rows = BLOCK_ROWS.min(k - i0);
            debug_assert_eq!(rows % 2, 0);
            // BLOCK_ROWS divides GROUP_SIZE, so the whole block shares one
            // scale-group row; the hoisted factor is recomputed only when
            // the block enters a new group.
            let g = i0 / GROUP_SIZE;
            if g != cur_group {
                cur_group = g;
                let srow = &scales[g * n + j0..g * n + j1];
                for (p, &sv) in pre.iter_mut().zip(srow) {
                    *p = sv / tensor_scale;
                }
            }
            for r in 0..rows / 2 {
                let prow = &prefix[(i0 / 2 + r) * n + j0..(i0 / 2 + r) * n + j1];
                let (lo, hi) = tile[2 * r * width..(2 * r + 2) * width].split_at_mut(width);
                decode_draft_row_pair(level, prow, pre, &lut, lo, hi);
            }
            // Prefetch the next block's prefix bytes during accumulation.
            if i0 + rows < k {
                let nrows = BLOCK_ROWS.min(k - i0 - rows) / 2;
                let np = (i0 + rows) / 2;
                for r in 0..nrows {
                    prefetch_bytes(&prefix[(np + r) * n + j0..(np + r) * n + j1]);
                }
            }
            for r in 0..rows {
                let trow = &tile[r * width..(r + 1) * width];
                for bi in 0..b {
                    let x = xs[bi * k + i0 + r];
                    let yrow = unsafe { y.slice_mut(bi * n + j0, width) };
                    if i0 + r == 0 {
                        axpy_init_simd(level, yrow, x, trow);
                    } else {
                        axpy_simd(level, yrow, x, trow);
                    }
                }
            }
            i0 += rows;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsfp::quantize_tensor;
    use crate::util::rng::Rng;

    fn batch(b: usize, k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut out = Vec::with_capacity(b * k);
        for _ in 0..b {
            out.extend(rng.normal_vec(k, 1.0));
        }
        out
    }

    fn run_dense(
        pool: &WorkerPool,
        level: SimdLevel,
        xs: &[f32],
        b: usize,
        w: &[f32],
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let mut ys = vec![f32::NAN; b * n];
        gemm_dense(pool, level, xs, b, w, k, n, &mut ys);
        ys
    }

    fn run_full(
        pool: &WorkerPool,
        level: SimdLevel,
        xs: &[f32],
        b: usize,
        planes: &PlanePair,
    ) -> Vec<f32> {
        let mut ys = vec![f32::NAN; b * planes.n];
        let mut scratch = vec![0.0f32; SCRATCH_ROWS * planes.n];
        gemm_full_planes(pool, level, xs, b, planes, &mut scratch, &mut ys);
        ys
    }

    #[allow(clippy::too_many_arguments)]
    fn run_draft(
        pool: &WorkerPool,
        level: SimdLevel,
        xs: &[f32],
        b: usize,
        prefix: &[u8],
        scales: &[f32],
        ts: f32,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let mut ys = vec![f32::NAN; b * n];
        let mut scratch = vec![0.0f32; SCRATCH_ROWS * n];
        gemm_draft_prefix(pool, level, xs, b, prefix, scales, ts, k, n, &mut scratch, &mut ys);
        ys
    }

    #[test]
    fn full_plane_kernel_matches_dense_bitwise() {
        let pool = WorkerPool::new(1);
        let (k, n) = (256, 24);
        let w = Rng::seed_from_u64(3).uniform_vec(k * n, 0.4);
        let qt = quantize_tensor(&w, k, n);
        let planes = qt.planes();
        // Dense reference over the *decoded* values: same accumulation
        // order, so bits must match exactly — on every dispatch tier.
        let decoded = planes.decode_full_f32();
        let xs = batch(3, k, 11);
        let dense = run_dense(&pool, SimdLevel::Scalar, &xs, 3, &decoded, k, n);
        for level in SimdLevel::available() {
            let packed = run_full(&pool, level, &xs, 3, &planes);
            for (i, (d, p)) in dense.iter().zip(&packed).enumerate() {
                assert_eq!(d.to_bits(), p.to_bits(), "{} flat idx {i}", level.name());
            }
        }
    }

    #[test]
    fn draft_prefix_kernel_matches_retired_dequant_bitwise() {
        let pool = WorkerPool::new(1);
        let (k, n) = (256, 16);
        let w = Rng::seed_from_u64(5).uniform_vec(k * n, 0.3);
        let qt = quantize_tensor(&w, k, n);
        // The retired derive_draft materialization: dequant then undo the
        // Algorithm-1 pre-scale.
        let mut old = qt.dequant_draft();
        for v in &mut old {
            *v /= qt.tensor_scale;
        }
        let xs = batch(2, k, 13);
        let dense = run_dense(&pool, SimdLevel::Scalar, &xs, 2, &old, k, n);
        for level in SimdLevel::available() {
            let packed = run_draft(
                &pool,
                level,
                &xs,
                2,
                &qt.packed_wq(),
                &qt.scales,
                qt.tensor_scale,
                k,
                n,
            );
            for (i, (d, p)) in dense.iter().zip(&packed).enumerate() {
                assert_eq!(d.to_bits(), p.to_bits(), "{} flat idx {i}", level.name());
            }
        }
    }

    #[test]
    fn draft_kernel_handles_outlier_tensor_scale() {
        let pool = WorkerPool::new(1);
        let (k, n) = (128, 4);
        let mut w = Rng::seed_from_u64(8).uniform_vec(k * n, 0.2);
        w[10] = 2.75; // force the Algorithm-1 pre-scale
        let qt = quantize_tensor(&w, k, n);
        assert!(qt.tensor_scale < 1.0);
        let mut old = qt.dequant_draft();
        for v in &mut old {
            *v /= qt.tensor_scale;
        }
        let xs = batch(1, k, 17);
        let dense = run_dense(&pool, SimdLevel::Scalar, &xs, 1, &old, k, n);
        for level in SimdLevel::available() {
            let packed = run_draft(
                &pool,
                level,
                &xs,
                1,
                &qt.packed_wq(),
                &qt.scales,
                qt.tensor_scale,
                k,
                n,
            );
            assert_eq!(
                dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                packed.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{}",
                level.name()
            );
        }
    }

    #[test]
    fn kernels_are_batch_size_invariant() {
        let pool = WorkerPool::new(1);
        let (k, n) = (128, 8);
        let w = Rng::seed_from_u64(21).uniform_vec(k * n, 0.3);
        let qt = quantize_tensor(&w, k, n);
        let planes = qt.planes();
        let xs = batch(4, k, 23);
        for level in SimdLevel::available() {
            let full_b4 = run_full(&pool, level, &xs, 4, &planes);
            for i in 0..4 {
                let solo = run_full(&pool, level, &xs[i * k..(i + 1) * k], 1, &planes);
                assert_eq!(
                    solo,
                    full_b4[i * n..(i + 1) * n],
                    "{}: full kernel diverged for seq {i}",
                    level.name()
                );
            }
        }
    }

    #[test]
    fn zero_k_dense_still_zeroes_output() {
        // With the zeroing folded into the first accumulation block, an
        // empty in-dimension must still initialize the outputs.
        let pool = WorkerPool::new(2);
        let out = run_dense(&pool, SimdLevel::Scalar, &[], 2, &[], 0, 5);
        assert_eq!(out, vec![0.0f32; 10]);
    }

    #[test]
    fn kernels_are_thread_count_invariant_bitwise() {
        // The tentpole's pin: for any thread count, every kernel's output
        // bits equal the serial (T=1) bits — including odd column counts
        // that leave some shards wider than others or empty.
        let (k, b) = (128usize, 3usize);
        let best = SimdLevel::detect();
        for n in [1usize, 7, 24, 33] {
            let w = Rng::seed_from_u64(41).uniform_vec(k * n, 0.35);
            let qt = quantize_tensor(&w, k, n);
            let planes = qt.planes();
            let xs = batch(b, k, 43);
            let serial = WorkerPool::new(1);
            let dense1 = run_dense(&serial, best, &xs, b, &w, k, n);
            let full1 = run_full(&serial, best, &xs, b, &planes);
            let draft1 = run_draft(
                &serial,
                best,
                &xs,
                b,
                &qt.packed_wq(),
                &qt.scales,
                qt.tensor_scale,
                k,
                n,
            );
            for t in [2usize, 3, 4, 8] {
                let pool = WorkerPool::new(t);
                let dense_t = run_dense(&pool, best, &xs, b, &w, k, n);
                let full_t = run_full(&pool, best, &xs, b, &planes);
                let draft_t = run_draft(
                    &pool,
                    best,
                    &xs,
                    b,
                    &qt.packed_wq(),
                    &qt.scales,
                    qt.tensor_scale,
                    k,
                    n,
                );
                for (i, (a, c)) in dense1.iter().zip(&dense_t).enumerate() {
                    assert_eq!(a.to_bits(), c.to_bits(), "dense T={t} n={n} idx {i}");
                }
                for (i, (a, c)) in full1.iter().zip(&full_t).enumerate() {
                    assert_eq!(a.to_bits(), c.to_bits(), "full T={t} n={n} idx {i}");
                }
                for (i, (a, c)) in draft1.iter().zip(&draft_t).enumerate() {
                    assert_eq!(a.to_bits(), c.to_bits(), "draft T={t} n={n} idx {i}");
                }
            }
        }
    }
}
