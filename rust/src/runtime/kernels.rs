//! Cache-blocked, column-sharded GEMV/GEMM kernels over the bit-plane
//! weight store.
//!
//! Three kernels share one contract: `X (B, k) @ W (k, n)` with `W`
//! row-major, activations and outputs as *flat* row-major batches
//! (`xs[b * k + i]`, `ys[b * n + j]` — no per-row heap allocation), the
//! weight-row loop outermost inside each shard (each row's bytes are
//! streamed from memory exactly once per shard for the whole batch), and
//! per-output accumulation in ascending-`i` order.
//!
//! **Parallelism and determinism.**  Every kernel splits the *output
//! column* dimension into contiguous per-shard ranges
//! ([`pool::col_range`]) executed on a [`WorkerPool`].  A shard owns its
//! columns outright: it zeroes them, decodes only those columns of each
//! weight block into a private scratch tile, and accumulates in the exact
//! ascending-`i` order of the serial loop.  Because each output element is
//! produced by exactly one shard with an unchanged accumulation order,
//! kernel outputs are **bitwise identical for every thread count** — the
//! property `prop_threads.rs` and the golden harness pin.  Traffic
//! accounting stays with the caller (one count per kernel call, never per
//! shard — see [`super::TrafficCounters`]).
//!
//! * [`gemm_dense`] — plain f32 weights (non-quantizable linears, the
//!   Algorithm-1 outlier fallback, transformed-weight variants).
//! * [`gemm_full_planes`] — decodes prefix + residual planes on the fly
//!   ([`PlanePair::decode_row_pair_full_cols`]), one [`BLOCK_ROWS`]-row
//!   block at a time into a scratch tile that stays cache-resident while
//!   every batch row consumes it.
//! * [`gemm_draft_prefix`] — decodes *only* the nibble-packed prefix plane
//!   (plus Eq. 4 group scales), streaming a quarter of the full pass's
//!   weight bytes per token.
//!
//! [`pool::col_range`]: super::pool::col_range

use super::pool::{col_range, SharedSlice, WorkerPool};
use crate::bsfp::{draft_value, PlanePair, GROUP_SIZE};

/// Weight rows decoded per block.  Must be even (the planes pack row
/// pairs) and divide [`GROUP_SIZE`] (so a block never straddles a scale
/// group); 16 rows of up to 512 f32 columns keep the scratch tile well
/// inside L1.
pub const BLOCK_ROWS: usize = 16;

// Load-bearing invariant: `gemm_draft_prefix` reads one scale-group row
// per block and the plane decoders walk row pairs — retuning BLOCK_ROWS
// to a value violating either silently corrupts draft scales.
const _: () = assert!(BLOCK_ROWS % 2 == 0 && GROUP_SIZE % BLOCK_ROWS == 0);

pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// `y += a * x`.
pub(crate) fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// The 16-entry draft dequantization LUT (`draft_value` per 4-bit code).
pub(crate) fn draft_lut() -> [f32; 16] {
    std::array::from_fn(|c| draft_value(c as u8))
}

/// Decode one nibble-packed prefix row (rows `2p` / `2p+1` at the same
/// columns) into `lo`/`hi` through the draft LUT:
/// `draft_value(W_q) * scale / tensor_scale` — bitwise the exact sequence
/// the retired `derive_draft` dequantization used.  Shared by the draft
/// GEMM kernel and the cold `decode_linear` diagnostics path (which
/// previously materialized the whole unpacked-code matrix instead).
#[inline]
pub(crate) fn decode_draft_row_pair(
    prow: &[u8],
    srow: &[f32],
    lut: &[f32; 16],
    tensor_scale: f32,
    lo: &mut [f32],
    hi: &mut [f32],
) {
    debug_assert!(prow.len() == srow.len() && prow.len() == lo.len() && prow.len() == hi.len());
    for (jj, &byte) in prow.iter().enumerate() {
        lo[jj] = lut[(byte & 0xf) as usize] * srow[jj] / tensor_scale;
        hi[jj] = lut[(byte >> 4) as usize] * srow[jj] / tensor_scale;
    }
}

/// `X (B, k) @ w (k, n)` with `w` row-major f32, into `ys (B, n)`.
///
/// Inside each column shard the weight-row loop is outermost, so each
/// row's bytes are streamed from memory exactly once per shard for the
/// whole batch — the continuous-batching bandwidth win.  Each output
/// element accumulates in the same `i`-ascending order as a serial batch
/// of one, so results are bit-identical for every batch size and thread
/// count.
pub fn gemm_dense(
    pool: &WorkerPool,
    xs: &[f32],
    b: usize,
    w: &[f32],
    k: usize,
    n: usize,
    ys: &mut [f32],
) {
    debug_assert_eq!(xs.len(), b * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(ys.len(), b * n);
    let t = pool.threads();
    let y = SharedSlice::new(ys);
    pool.run(t, |s| {
        let (j0, j1) = col_range(n, s, t);
        if j0 == j1 {
            return;
        }
        let width = j1 - j0;
        for bi in 0..b {
            // SAFETY: shard `s` exclusively owns columns j0..j1 of every
            // batch row (col_range partitions 0..n disjointly).
            unsafe { y.slice_mut(bi * n + j0, width) }.fill(0.0);
        }
        for i in 0..k {
            let row = &w[i * n + j0..i * n + j1];
            for bi in 0..b {
                let x = xs[bi * k + i];
                let yrow = unsafe { y.slice_mut(bi * n + j0, width) };
                axpy(yrow, x, row);
            }
        }
    });
}

/// `X (B, k) @ decode_full(planes)` — the full/verify pass kernel, into
/// `ys (B, n)`.
///
/// Streams prefix + residual (2 bytes per weight, the FP16 footprint) and
/// reconstructs each shard's columns of a [`BLOCK_ROWS`]-row block into a
/// private region of `scratch` (length >= `BLOCK_ROWS * n`) via the
/// Fig. 5(b) decoder before accumulating.  Row order inside a block is
/// ascending, so results are bitwise equal to [`gemm_dense`] over the
/// decoded values.
pub fn gemm_full_planes(
    pool: &WorkerPool,
    xs: &[f32],
    b: usize,
    planes: &PlanePair,
    scratch: &mut [f32],
    ys: &mut [f32],
) {
    let (k, n) = (planes.k, planes.n);
    debug_assert_eq!(xs.len(), b * k);
    debug_assert_eq!(ys.len(), b * n);
    debug_assert!(scratch.len() >= BLOCK_ROWS * n);
    debug_assert_eq!(k % 2, 0);
    let t = pool.threads();
    let y = SharedSlice::new(ys);
    let tiles = SharedSlice::new(&mut scratch[..BLOCK_ROWS * n]);
    pool.run(t, |s| {
        let (j0, j1) = col_range(n, s, t);
        if j0 == j1 {
            return;
        }
        let width = j1 - j0;
        // SAFETY: per-shard regions are disjoint — shard widths sum to n,
        // so `BLOCK_ROWS * j0` offsets never overlap; same for the output
        // columns.
        let tile = unsafe { tiles.slice_mut(BLOCK_ROWS * j0, BLOCK_ROWS * width) };
        for bi in 0..b {
            unsafe { y.slice_mut(bi * n + j0, width) }.fill(0.0);
        }
        let mut i0 = 0;
        while i0 < k {
            let rows = BLOCK_ROWS.min(k - i0);
            debug_assert_eq!(rows % 2, 0, "plane row pairs require an even block");
            for r in 0..rows / 2 {
                let (lo, hi) = tile[2 * r * width..(2 * r + 2) * width].split_at_mut(width);
                planes.decode_row_pair_full_cols(i0 / 2 + r, j0, j1, lo, hi);
            }
            for r in 0..rows {
                let trow = &tile[r * width..(r + 1) * width];
                for bi in 0..b {
                    let x = xs[bi * k + i0 + r];
                    let yrow = unsafe { y.slice_mut(bi * n + j0, width) };
                    axpy(yrow, x, trow);
                }
            }
            i0 += rows;
        }
    });
}

/// `X (B, k) @ draft(prefix, scales)` — the quarter-traffic draft kernel,
/// into `ys (B, n)`.
///
/// Streams only the nibble-packed prefix plane plus the Eq. 4 group
/// scales.  Each decoded value is computed as
/// `draft_value(W_q) * scale / tensor_scale` — bitwise the exact sequence
/// the retired `derive_draft` dequantization used (`dequant_draft`
/// multiplied code value by scale, then divided by the Algorithm-1
/// tensor scale), so kernel outputs are bit-identical to the old
/// materialized draft weights.  `tensor_scale` is 1.0 for in-domain
/// tensors (division by 1.0 is an IEEE identity).
#[allow(clippy::too_many_arguments)]
pub fn gemm_draft_prefix(
    pool: &WorkerPool,
    xs: &[f32],
    b: usize,
    prefix: &[u8],
    scales: &[f32],
    tensor_scale: f32,
    k: usize,
    n: usize,
    scratch: &mut [f32],
    ys: &mut [f32],
) {
    debug_assert_eq!(xs.len(), b * k);
    debug_assert_eq!(ys.len(), b * n);
    debug_assert!(scratch.len() >= BLOCK_ROWS * n);
    debug_assert_eq!(prefix.len(), k / 2 * n);
    debug_assert_eq!(scales.len(), k / GROUP_SIZE * n);
    debug_assert_eq!(k % GROUP_SIZE, 0);
    let lut = draft_lut();
    let t = pool.threads();
    let y = SharedSlice::new(ys);
    let tiles = SharedSlice::new(&mut scratch[..BLOCK_ROWS * n]);
    pool.run(t, |s| {
        let (j0, j1) = col_range(n, s, t);
        if j0 == j1 {
            return;
        }
        let width = j1 - j0;
        // SAFETY: disjoint per-shard regions, as in `gemm_full_planes`.
        let tile = unsafe { tiles.slice_mut(BLOCK_ROWS * j0, BLOCK_ROWS * width) };
        for bi in 0..b {
            unsafe { y.slice_mut(bi * n + j0, width) }.fill(0.0);
        }
        let mut i0 = 0;
        while i0 < k {
            let rows = BLOCK_ROWS.min(k - i0);
            debug_assert_eq!(rows % 2, 0);
            // BLOCK_ROWS divides GROUP_SIZE, so the whole block shares one
            // scale-group row.
            let srow = &scales[(i0 / GROUP_SIZE) * n + j0..(i0 / GROUP_SIZE) * n + j1];
            for r in 0..rows / 2 {
                let prow = &prefix[(i0 / 2 + r) * n + j0..(i0 / 2 + r) * n + j1];
                let (lo, hi) = tile[2 * r * width..(2 * r + 2) * width].split_at_mut(width);
                decode_draft_row_pair(prow, srow, &lut, tensor_scale, lo, hi);
            }
            for r in 0..rows {
                let trow = &tile[r * width..(r + 1) * width];
                for bi in 0..b {
                    let x = xs[bi * k + i0 + r];
                    let yrow = unsafe { y.slice_mut(bi * n + j0, width) };
                    axpy(yrow, x, trow);
                }
            }
            i0 += rows;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsfp::quantize_tensor;
    use crate::util::rng::Rng;

    fn batch(b: usize, k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut out = Vec::with_capacity(b * k);
        for _ in 0..b {
            out.extend(rng.normal_vec(k, 1.0));
        }
        out
    }

    fn run_dense(pool: &WorkerPool, xs: &[f32], b: usize, w: &[f32], k: usize, n: usize) -> Vec<f32> {
        let mut ys = vec![f32::NAN; b * n];
        gemm_dense(pool, xs, b, w, k, n, &mut ys);
        ys
    }

    fn run_full(pool: &WorkerPool, xs: &[f32], b: usize, planes: &PlanePair) -> Vec<f32> {
        let mut ys = vec![f32::NAN; b * planes.n];
        let mut scratch = vec![0.0f32; BLOCK_ROWS * planes.n];
        gemm_full_planes(pool, xs, b, planes, &mut scratch, &mut ys);
        ys
    }

    #[allow(clippy::too_many_arguments)]
    fn run_draft(
        pool: &WorkerPool,
        xs: &[f32],
        b: usize,
        prefix: &[u8],
        scales: &[f32],
        ts: f32,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let mut ys = vec![f32::NAN; b * n];
        let mut scratch = vec![0.0f32; BLOCK_ROWS * n];
        gemm_draft_prefix(pool, xs, b, prefix, scales, ts, k, n, &mut scratch, &mut ys);
        ys
    }

    #[test]
    fn full_plane_kernel_matches_dense_bitwise() {
        let pool = WorkerPool::new(1);
        let (k, n) = (256, 24);
        let w = Rng::seed_from_u64(3).uniform_vec(k * n, 0.4);
        let qt = quantize_tensor(&w, k, n);
        let planes = qt.planes();
        // Dense reference over the *decoded* values: same accumulation
        // order, so bits must match exactly.
        let decoded = planes.decode_full_f32();
        let xs = batch(3, k, 11);
        let dense = run_dense(&pool, &xs, 3, &decoded, k, n);
        let packed = run_full(&pool, &xs, 3, &planes);
        for (i, (d, p)) in dense.iter().zip(&packed).enumerate() {
            assert_eq!(d.to_bits(), p.to_bits(), "flat idx {i}");
        }
    }

    #[test]
    fn draft_prefix_kernel_matches_retired_dequant_bitwise() {
        let pool = WorkerPool::new(1);
        let (k, n) = (256, 16);
        let w = Rng::seed_from_u64(5).uniform_vec(k * n, 0.3);
        let qt = quantize_tensor(&w, k, n);
        // The retired derive_draft materialization: dequant then undo the
        // Algorithm-1 pre-scale.
        let mut old = qt.dequant_draft();
        for v in &mut old {
            *v /= qt.tensor_scale;
        }
        let xs = batch(2, k, 13);
        let dense = run_dense(&pool, &xs, 2, &old, k, n);
        let packed =
            run_draft(&pool, &xs, 2, &qt.packed_wq(), &qt.scales, qt.tensor_scale, k, n);
        for (i, (d, p)) in dense.iter().zip(&packed).enumerate() {
            assert_eq!(d.to_bits(), p.to_bits(), "flat idx {i}");
        }
    }

    #[test]
    fn draft_kernel_handles_outlier_tensor_scale() {
        let pool = WorkerPool::new(1);
        let (k, n) = (128, 4);
        let mut w = Rng::seed_from_u64(8).uniform_vec(k * n, 0.2);
        w[10] = 2.75; // force the Algorithm-1 pre-scale
        let qt = quantize_tensor(&w, k, n);
        assert!(qt.tensor_scale < 1.0);
        let mut old = qt.dequant_draft();
        for v in &mut old {
            *v /= qt.tensor_scale;
        }
        let xs = batch(1, k, 17);
        let dense = run_dense(&pool, &xs, 1, &old, k, n);
        let packed =
            run_draft(&pool, &xs, 1, &qt.packed_wq(), &qt.scales, qt.tensor_scale, k, n);
        assert_eq!(
            dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            packed.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn kernels_are_batch_size_invariant() {
        let pool = WorkerPool::new(1);
        let (k, n) = (128, 8);
        let w = Rng::seed_from_u64(21).uniform_vec(k * n, 0.3);
        let qt = quantize_tensor(&w, k, n);
        let planes = qt.planes();
        let xs = batch(4, k, 23);
        let full_b4 = run_full(&pool, &xs, 4, &planes);
        for i in 0..4 {
            let solo = run_full(&pool, &xs[i * k..(i + 1) * k], 1, &planes);
            assert_eq!(
                solo,
                full_b4[i * n..(i + 1) * n],
                "full kernel diverged for seq {i}"
            );
        }
    }

    #[test]
    fn kernels_are_thread_count_invariant_bitwise() {
        // The tentpole's pin: for any thread count, every kernel's output
        // bits equal the serial (T=1) bits — including odd column counts
        // that leave some shards wider than others or empty.
        let (k, b) = (128usize, 3usize);
        for n in [1usize, 7, 24, 33] {
            let w = Rng::seed_from_u64(41).uniform_vec(k * n, 0.35);
            let qt = quantize_tensor(&w, k, n);
            let planes = qt.planes();
            let xs = batch(b, k, 43);
            let serial = WorkerPool::new(1);
            let dense1 = run_dense(&serial, &xs, b, &w, k, n);
            let full1 = run_full(&serial, &xs, b, &planes);
            let draft1 =
                run_draft(&serial, &xs, b, &qt.packed_wq(), &qt.scales, qt.tensor_scale, k, n);
            for t in [2usize, 3, 4, 8] {
                let pool = WorkerPool::new(t);
                let dense_t = run_dense(&pool, &xs, b, &w, k, n);
                let full_t = run_full(&pool, &xs, b, &planes);
                let draft_t = run_draft(
                    &pool,
                    &xs,
                    b,
                    &qt.packed_wq(),
                    &qt.scales,
                    qt.tensor_scale,
                    k,
                    n,
                );
                for (i, (a, c)) in dense1.iter().zip(&dense_t).enumerate() {
                    assert_eq!(a.to_bits(), c.to_bits(), "dense T={t} n={n} idx {i}");
                }
                for (i, (a, c)) in full1.iter().zip(&full_t).enumerate() {
                    assert_eq!(a.to_bits(), c.to_bits(), "full T={t} n={n} idx {i}");
                }
                for (i, (a, c)) in draft1.iter().zip(&draft_t).enumerate() {
                    assert_eq!(a.to_bits(), c.to_bits(), "draft T={t} n={n} idx {i}");
                }
            }
        }
    }
}
