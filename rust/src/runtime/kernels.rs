//! Cache-blocked GEMV/GEMM kernels over the bit-plane weight store.
//!
//! Three kernels share one contract: `X (B, k) @ W (k, n)` with `W`
//! row-major, the weight-row loop outermost (each row is streamed from
//! memory exactly once for the whole batch), and per-output accumulation
//! in ascending-`i` order.  Because the accumulation order is identical
//! across all three, a kernel swap can never change output bits as long
//! as the decoded weight values are bitwise equal — the property the
//! golden-test harness and `prop_planes.rs` pin.
//!
//! * [`gemm_dense`] — plain f32 weights (non-quantizable linears, the
//!   Algorithm-1 outlier fallback, transformed-weight variants).
//! * [`gemm_full_planes`] — decodes prefix + residual planes on the fly
//!   ([`PlanePair::decode_row_pair_full`]), one [`BLOCK_ROWS`]-row block
//!   at a time into a scratch tile that stays cache-resident while every
//!   batch row consumes it.
//! * [`gemm_draft_prefix`] — decodes *only* the nibble-packed prefix plane
//!   (plus Eq. 4 group scales), streaming a quarter of the full pass's
//!   weight bytes per token.

use crate::bsfp::{draft_value, PlanePair, GROUP_SIZE};

/// Weight rows decoded per block.  Must be even (the planes pack row
/// pairs) and divide [`GROUP_SIZE`] (so a block never straddles a scale
/// group); 16 rows of up to 512 f32 columns keep the scratch tile well
/// inside L1.
pub const BLOCK_ROWS: usize = 16;

// Load-bearing invariant: `gemm_draft_prefix` reads one scale-group row
// per block and the plane decoders walk row pairs — retuning BLOCK_ROWS
// to a value violating either silently corrupts draft scales.
const _: () = assert!(BLOCK_ROWS % 2 == 0 && GROUP_SIZE % BLOCK_ROWS == 0);

pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// `y += a * x`.
pub(crate) fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// `X (B, k) @ w (k, n)` with `w` row-major f32.
///
/// The weight-row loop is outermost so each row of `w` is streamed from
/// memory exactly once for the whole batch — the continuous-batching
/// bandwidth win.  Each output row accumulates in the same `i`-ascending
/// order as a batch of one, so per-sequence results are bit-identical for
/// every batch size.
pub fn gemm_dense(xs: &[Vec<f32>], w: &[f32], k: usize, n: usize) -> Vec<Vec<f32>> {
    debug_assert!(xs.iter().all(|x| x.len() == k));
    debug_assert_eq!(w.len(), k * n);
    let mut ys: Vec<Vec<f32>> = xs.iter().map(|_| vec![0.0f32; n]).collect();
    for i in 0..k {
        let row = &w[i * n..(i + 1) * n];
        for (y, x) in ys.iter_mut().zip(xs) {
            axpy(y, x[i], row);
        }
    }
    ys
}

/// `X (B, k) @ decode_full(planes)` — the full/verify pass kernel.
///
/// Streams prefix + residual (2 bytes per weight, the FP16 footprint) and
/// reconstructs each block of [`BLOCK_ROWS`] rows into a scratch tile via
/// the Fig. 5(b) decoder before accumulating.  Row order inside a block is
/// ascending, so results are bitwise equal to [`gemm_dense`] over the
/// decoded values.
pub fn gemm_full_planes(xs: &[Vec<f32>], planes: &PlanePair) -> Vec<Vec<f32>> {
    let (k, n) = (planes.k, planes.n);
    debug_assert!(xs.iter().all(|x| x.len() == k));
    debug_assert_eq!(k % 2, 0);
    let mut ys: Vec<Vec<f32>> = xs.iter().map(|_| vec![0.0f32; n]).collect();
    let mut scratch = vec![0.0f32; BLOCK_ROWS * n];
    let mut i0 = 0;
    while i0 < k {
        let rows = BLOCK_ROWS.min(k - i0);
        debug_assert_eq!(rows % 2, 0, "plane row pairs require an even block");
        for r in 0..rows / 2 {
            let (lo, hi) = scratch[2 * r * n..(2 * r + 2) * n].split_at_mut(n);
            planes.decode_row_pair_full(i0 / 2 + r, lo, hi);
        }
        for r in 0..rows {
            let row = &scratch[r * n..(r + 1) * n];
            for (y, x) in ys.iter_mut().zip(xs) {
                axpy(y, x[i0 + r], row);
            }
        }
        i0 += rows;
    }
    ys
}

/// `X (B, k) @ draft(prefix, scales)` — the quarter-traffic draft kernel.
///
/// Streams only the nibble-packed prefix plane plus the Eq. 4 group
/// scales.  Each decoded value is computed as
/// `draft_value(W_q) * scale / tensor_scale` — bitwise the exact sequence
/// the retired `derive_draft` dequantization used (`dequant_draft`
/// multiplied code value by scale, then divided by the Algorithm-1
/// tensor scale), so kernel outputs are bit-identical to the old
/// materialized draft weights.  `tensor_scale` is 1.0 for in-domain
/// tensors (division by 1.0 is an IEEE identity).
pub fn gemm_draft_prefix(
    xs: &[Vec<f32>],
    prefix: &[u8],
    scales: &[f32],
    tensor_scale: f32,
    k: usize,
    n: usize,
) -> Vec<Vec<f32>> {
    debug_assert!(xs.iter().all(|x| x.len() == k));
    debug_assert_eq!(prefix.len(), k / 2 * n);
    debug_assert_eq!(scales.len(), k / GROUP_SIZE * n);
    debug_assert_eq!(k % GROUP_SIZE, 0);
    let lut: [f32; 16] = std::array::from_fn(|c| draft_value(c as u8));
    let mut ys: Vec<Vec<f32>> = xs.iter().map(|_| vec![0.0f32; n]).collect();
    let mut scratch = vec![0.0f32; BLOCK_ROWS * n];
    let mut i0 = 0;
    while i0 < k {
        let rows = BLOCK_ROWS.min(k - i0);
        debug_assert_eq!(rows % 2, 0);
        // BLOCK_ROWS divides GROUP_SIZE, so the whole block shares one
        // scale-group row.
        let srow = &scales[(i0 / GROUP_SIZE) * n..(i0 / GROUP_SIZE + 1) * n];
        for r in 0..rows / 2 {
            let prow = &prefix[(i0 / 2 + r) * n..(i0 / 2 + r + 1) * n];
            let (lo, hi) = scratch[2 * r * n..(2 * r + 2) * n].split_at_mut(n);
            for j in 0..n {
                let byte = prow[j];
                lo[j] = lut[(byte & 0xf) as usize] * srow[j] / tensor_scale;
                hi[j] = lut[(byte >> 4) as usize] * srow[j] / tensor_scale;
            }
        }
        for r in 0..rows {
            let row = &scratch[r * n..(r + 1) * n];
            for (y, x) in ys.iter_mut().zip(xs) {
                axpy(y, x[i0 + r], row);
            }
        }
        i0 += rows;
    }
    ys
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsfp::quantize_tensor;
    use crate::util::rng::Rng;

    fn batch(b: usize, k: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..b).map(|_| rng.normal_vec(k, 1.0)).collect()
    }

    #[test]
    fn full_plane_kernel_matches_dense_bitwise() {
        let (k, n) = (256, 24);
        let w = Rng::seed_from_u64(3).uniform_vec(k * n, 0.4);
        let qt = quantize_tensor(&w, k, n);
        let planes = qt.planes();
        // Dense reference over the *decoded* values: same accumulation
        // order, so bits must match exactly.
        let decoded = planes.decode_full_f32();
        let xs = batch(3, k, 11);
        let dense = gemm_dense(&xs, &decoded, k, n);
        let packed = gemm_full_planes(&xs, &planes);
        for (b, (dr, pr)) in dense.iter().zip(&packed).enumerate() {
            for (j, (d, p)) in dr.iter().zip(pr).enumerate() {
                assert_eq!(d.to_bits(), p.to_bits(), "batch {b} col {j}");
            }
        }
    }

    #[test]
    fn draft_prefix_kernel_matches_retired_dequant_bitwise() {
        let (k, n) = (256, 16);
        let w = Rng::seed_from_u64(5).uniform_vec(k * n, 0.3);
        let qt = quantize_tensor(&w, k, n);
        // The retired derive_draft materialization: dequant then undo the
        // Algorithm-1 pre-scale.
        let mut old = qt.dequant_draft();
        for v in &mut old {
            *v /= qt.tensor_scale;
        }
        let xs = batch(2, k, 13);
        let dense = gemm_dense(&xs, &old, k, n);
        let packed =
            gemm_draft_prefix(&xs, &qt.packed_wq(), &qt.scales, qt.tensor_scale, k, n);
        for (b, (dr, pr)) in dense.iter().zip(&packed).enumerate() {
            for (j, (d, p)) in dr.iter().zip(pr).enumerate() {
                assert_eq!(d.to_bits(), p.to_bits(), "batch {b} col {j}");
            }
        }
    }

    #[test]
    fn draft_kernel_handles_outlier_tensor_scale() {
        let (k, n) = (128, 4);
        let mut w = Rng::seed_from_u64(8).uniform_vec(k * n, 0.2);
        w[10] = 2.75; // force the Algorithm-1 pre-scale
        let qt = quantize_tensor(&w, k, n);
        assert!(qt.tensor_scale < 1.0);
        let mut old = qt.dequant_draft();
        for v in &mut old {
            *v /= qt.tensor_scale;
        }
        let xs = batch(1, k, 17);
        let dense = gemm_dense(&xs, &old, k, n);
        let packed =
            gemm_draft_prefix(&xs, &qt.packed_wq(), &qt.scales, qt.tensor_scale, k, n);
        assert_eq!(dense[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   packed[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn kernels_are_batch_size_invariant() {
        let (k, n) = (128, 8);
        let w = Rng::seed_from_u64(21).uniform_vec(k * n, 0.3);
        let qt = quantize_tensor(&w, k, n);
        let planes = qt.planes();
        let xs = batch(4, k, 23);
        let full_b4 = gemm_full_planes(&xs, &planes);
        for (i, x) in xs.iter().enumerate() {
            let solo = gemm_full_planes(std::slice::from_ref(x), &planes);
            assert_eq!(solo[0], full_b4[i], "full kernel diverged for seq {i}");
        }
    }
}
