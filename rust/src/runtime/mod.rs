//! Execution backends: the [`Backend`] trait, the always-available
//! pure-Rust [`NativeBackend`], and (behind the non-default `pjrt`
//! feature) the PJRT runtime that executes AOT-compiled HLO graphs.
//!
//! * [`backend`] — the trait every layer above this one is written
//!   against: five request-path operations plus opaque state threading,
//!   the batched serving API over the [`SeqSlot`]-indexed [`SlotArena`],
//!   and the [`ModelSource`]/[`load_backend`] factory.
//! * [`native`] — host-memory interpreter for the tiny SPEQ transformer;
//!   every quantizable linear lives once in a bit-plane packed store
//!   (prefix plane = 4-bit `W_q`, residual plane = 12-bit `W_r`, Eq. 4
//!   scales alongside), so the whole stack builds, tests, and serves
//!   without PJRT or artifacts.  Batched operations stream each weight
//!   once per step for the whole batch.
//! * [`kernels`] — the cache-blocked GEMV/GEMM kernels that decode the
//!   planes on the fly: the draft kernel streams only the prefix plane
//!   (quarter traffic), the full/verify kernel streams both planes, and
//!   all kernels share one accumulation order (bit-identity across paths).
//!   Kernels take flat strided batches, shard the output-column dimension
//!   across the worker pool, and run their decoders/updates through
//!   runtime-dispatched SIMD tiers ([`SimdLevel`]: AVX2/SSE4.1 on x86_64,
//!   NEON on aarch64, scalar reference everywhere; `SPEQ_SIMD` /
//!   `--simd` force a tier).  SIMD is element-wise only — accumulation
//!   order never changes, so every tier is bitwise identical.
//! * [`pool`] — the std-only persistent [`WorkerPool`] behind the
//!   parallel kernels: static job assignment, contiguous column shards,
//!   and a determinism contract that makes results bitwise identical for
//!   every thread count ([`NativeConfig`] / `--threads` / `SPEQ_THREADS`
//!   select the width).
//! * [`paging`] — the paged KV store: fixed [`PAGE_TOKENS`]-position
//!   pages handed out by the refcounted free-list [`PageAllocator`]
//!   (generation-stamped [`PageId`]s reject double frees and stale page
//!   tables; `make_unique` gives copy-on-write), plus the [`KvStats`]
//!   occupancy/sharing snapshot surfaced through `Backend::kv_stats`.
//! * [`prefix`] — the radix tree over token streams ([`PrefixTree`]):
//!   each node owns one immutable KV page, so sequences sharing a prompt
//!   prefix map the same pages copy-on-write and prefill of a cached
//!   prefix is a tree lookup plus a forward pass over only the novel
//!   suffix.  LRU leaf eviction bounds resident pages.
//! * `exec`/`hlo` (`pjrt` feature) — the `xla` crate wrapper: HLO text
//!   loading, compilation, buffer-to-buffer execution.  The interchange is
//!   HLO **text** (xla_extension 0.5.1 rejects jax >= 0.5's 64-bit-id
//!   serialized protos; the text parser reassigns ids).

pub mod backend;
pub mod kernels;
pub mod native;
pub mod paging;
pub mod pool;
pub mod prefix;

pub use backend::{
    load_backend, load_backend_with, Backend, BackendState, ModelSource, PassKind, SeqSlot,
    SlotArena, StepOutput, TrafficCounters, TrafficSnapshot, VerifyOutput,
};
pub use native::{
    builtin_config, builtin_model_names, InitStyle, NativeBackend, NativeConfig, S_SLOTS,
};
pub use crate::bsfp::SimdLevel;
pub use paging::{KvStats, PageAllocator, PageExhausted, PageId, PAGE_TOKENS};
pub use pool::WorkerPool;
pub use prefix::PrefixTree;

#[cfg(feature = "pjrt")]
mod exec;
#[cfg(feature = "pjrt")]
mod hlo;

#[cfg(feature = "pjrt")]
pub use exec::{Executable, HostTensor};
#[cfg(feature = "pjrt")]
pub use hlo::load_hlo_text;

#[cfg(feature = "pjrt")]
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::sync::Arc;

#[cfg(feature = "pjrt")]
use anyhow::{Context, Result};

/// Shared PJRT client handle (cheaply cloneable).
#[cfg(feature = "pjrt")]
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT runtime.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load an HLO text file and compile it to an [`Executable`].
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let comp = load_hlo_text(path)?;
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable::new(exe, path.display().to_string()))
    }

    /// Upload an f32 tensor to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a u8 tensor (packed W_q) to the device.
    pub fn upload_u8(&self, data: &[u8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 scalar.
    pub fn upload_i32_scalar(&self, v: i32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }

    /// Upload an i32 vector.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}
