//! PJRT runtime: load AOT-compiled HLO text, compile, execute.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin).  All graphs are produced
//! once at build time by `python/compile/aot.py`; this module is the only
//! boundary between the Rust request path and the compiled computations.
//!
//! Design notes:
//! * Interchange is HLO **text** — xla_extension 0.5.1 rejects jax >= 0.5's
//!   64-bit-id serialized protos; the text parser reassigns ids.
//! * Everything stays in [`xla::PjRtBuffer`]s: weights are uploaded once,
//!   the KV cache is threaded output->input between steps without touching
//!   the host, and only tokens/positions/logits cross the host boundary.

mod exec;
mod hlo;

pub use exec::{Executable, HostTensor};
pub use hlo::load_hlo_text;

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

/// Shared PJRT client handle (cheaply cloneable).
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

impl Runtime {
    /// Create a CPU PJRT runtime.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load an HLO text file and compile it to an [`Executable`].
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let comp = load_hlo_text(path)?;
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable::new(exe, path.display().to_string()))
    }

    /// Upload an f32 tensor to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a u8 tensor (packed W_q) to the device.
    pub fn upload_u8(&self, data: &[u8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 scalar.
    pub fn upload_i32_scalar(&self, v: i32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }

    /// Upload an i32 vector.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}
