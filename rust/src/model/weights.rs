//! Weight loading: `weights.bin` (FP16 bit patterns, param order) -> host.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::manifest::ModelEntry;
use crate::bsfp::f16_bits_to_f32;

/// Host-resident weights for one model: FP16 bit patterns (canonical) plus
/// f32 expansions (what the f32 HLO graphs consume).
#[derive(Debug, Clone)]
pub struct HostWeights {
    /// param name -> FP16 bit patterns (row-major, manifest shape)
    pub bits: BTreeMap<String, Vec<u16>>,
    /// param name -> f32 values
    pub f32s: BTreeMap<String, Vec<f32>>,
    /// param name -> shape
    pub shapes: BTreeMap<String, Vec<usize>>,
}

/// Load and expand a model's `weights.bin`.
pub fn load_weights(path: impl AsRef<Path>, entry: &ModelEntry) -> Result<HostWeights> {
    let path = path.as_ref();
    let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut bits = BTreeMap::new();
    let mut f32s = BTreeMap::new();
    let mut shapes = BTreeMap::new();
    for p in &entry.params {
        anyhow::ensure!(p.dtype == "f16", "unsupported dtype {} for {}", p.dtype, p.name);
        let end = p.offset_bytes + p.size_bytes;
        anyhow::ensure!(end <= raw.len(), "weights.bin truncated at {}", p.name);
        let slice = &raw[p.offset_bytes..end];
        let b: Vec<u16> =
            slice.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect();
        let f: Vec<f32> = b.iter().map(|&x| f16_bits_to_f32(x)).collect();
        let n: usize = p.shape.iter().product();
        anyhow::ensure!(b.len() == n, "size mismatch for {}", p.name);
        bits.insert(p.name.clone(), b);
        f32s.insert(p.name.clone(), f);
        shapes.insert(p.name.clone(), p.shape.clone());
    }
    Ok(HostWeights { bits, f32s, shapes })
}

impl HostWeights {
    pub fn shape(&self, name: &str) -> &[usize] {
        &self.shapes[name]
    }

    pub fn f32(&self, name: &str) -> &[f32] {
        &self.f32s[name]
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.f32s.values().map(|v| v.len()).sum()
    }
}
