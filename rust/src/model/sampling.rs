//! Logits post-processing: softmax, greedy argmax, temperature sampling.
//!
//! Sampling runs host-side (L3) on the logits returned by the compiled
//! graphs, matching the accelerator's SFU placement in Fig. 4.

use crate::util::rng::Rng;

/// Sampling configuration for a generation request.
#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    /// 0.0 = greedy.
    pub temperature: f32,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 0.0, seed: 0 }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self::default()
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// Index of the maximum logit (ties -> lowest index, matching jnp.argmax).
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Numerically-stable softmax.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let exps: Vec<f32> = logits.iter().map(|&v| (v - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

/// Maximum softmax probability, allocation-free.
///
/// Bitwise identical to `softmax(logits).iter().fold(0.0, max)`: the max
/// logit's exponent is exactly `exp(0) = 1.0`, IEEE division by a positive
/// `z` is monotone (so the max exponent maps to the max probability), and
/// `z` is summed in the same index order as `softmax` — hence the result
/// is exactly `1.0 / z`, bit-for-bit the value the allocating path yields.
/// Used by the §III-C gamma early-exit check in greedy drafting, where the
/// full distribution is never read.
pub fn softmax_top(logits: &[f32]) -> f32 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let z: f32 = logits.iter().map(|&v| (v - m).exp()).sum();
    1.0 / z
}

/// Numerically-stable log-softmax.
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let z: f32 = logits.iter().map(|&v| (v - m).exp()).sum();
    let lz = z.ln() + m;
    logits.iter().map(|&v| v - lz).collect()
}

/// Sample a token; returns `(token, probs)` where `probs` is the (possibly
/// temperature-scaled) distribution used — the speculative-sampling
/// acceptance rule needs it.
pub fn sample_from_logits(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Rng,
) -> (usize, Vec<f32>) {
    if params.is_greedy() {
        let probs = softmax(logits);
        (argmax(logits), probs)
    } else {
        let scaled: Vec<f32> = logits.iter().map(|&v| v / params.temperature).collect();
        let probs = softmax(&scaled);
        let u: f32 = rng.gen_f32();
        let mut acc = 0.0;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if u <= acc {
                return (i, probs);
            }
        }
        (probs.len() - 1, probs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max_and_first_tie() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[2.0, 2.0]), 0);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let p = softmax(&[1000.0, 1000.0, 999.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[0] > p[2]);
    }

    #[test]
    fn softmax_top_is_bitwise_the_softmax_max() {
        // Regression for the greedy draft path: the allocation-free top
        // probability must equal the allocating softmax's max exactly
        // (bit-for-bit), or greedy early-exit decisions would drift.
        let cases: [&[f32]; 4] = [
            &[0.3, -1.2, 2.0, 0.0],
            &[1000.0, 1000.0, 999.0],
            &[-5.0; 7],
            &[0.0],
        ];
        for logits in cases {
            let via_vec = softmax(logits).iter().fold(0.0f32, |m, &p| m.max(p));
            assert_eq!(softmax_top(logits).to_bits(), via_vec.to_bits());
        }
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let logits = [0.3f32, -1.2, 2.0, 0.0];
        let p = softmax(&logits);
        let lp = log_softmax(&logits);
        for (a, b) in p.iter().zip(&lp) {
            assert!((a.ln() - b).abs() < 1e-5);
        }
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::seed_from_u64(0);
        let logits = [0.0f32, 5.0, 1.0];
        let (tok, _) = sample_from_logits(&logits, &SamplingParams::greedy(), &mut rng);
        assert_eq!(tok, 1);
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut rng = Rng::seed_from_u64(1);
        let logits = [1.0f32, 1.0, 1.0];
        let params = SamplingParams { temperature: 1.0, seed: 1 };
        let mut seen = [false; 3];
        for _ in 0..200 {
            let (t, _) = sample_from_logits(&logits, &params, &mut rng);
            seen[t] = true;
        }
        assert!(seen.iter().all(|&s| s), "all tokens should be sampled");
    }
}
