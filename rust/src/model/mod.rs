//! Model substrate: manifests, weights, and logits post-processing.
//!
//! The artifacts manifest and `weights.bin` loader are backend-independent
//! (the native backend executes straight from [`HostWeights`]).  With the
//! `pjrt` feature, `ModelRuntime` additionally bridges the artifacts
//! directory to compiled HLO execution: it owns the compiled graphs and
//! the device-resident weight buffers (full FP16-derived params uploaded
//! once; BSFP draft params derived by the Rust codec from the same bits),
//! and implements [`crate::runtime::Backend`] over device state.

#[cfg(feature = "pjrt")]
mod exec;
mod manifest;
mod sampling;
mod weights;

#[cfg(feature = "pjrt")]
pub use exec::ModelRuntime;
pub use manifest::{GraphEntry, Manifest, ModelConfig, ModelEntry, ParamInfo};
pub use sampling::{argmax, log_softmax, sample_from_logits, softmax, softmax_top, SamplingParams};
pub use weights::{load_weights, HostWeights};
