//! Model substrate: manifests, weights, the executable model, and sampling.
//!
//! [`ModelRuntime`] is the bridge between the artifacts directory and the
//! speculative-decoding engine: it owns the three compiled graphs (prefill,
//! full decode, draft decode), the device-resident weight buffers (full
//! FP16-derived params uploaded once; BSFP draft params derived by the Rust
//! codec from the same bits and uploaded once), and exposes step functions
//! that thread the KV cache buffer between calls.

mod exec;
mod manifest;
mod sampling;
mod weights;

pub use exec::{ModelRuntime, StepOutput};
pub use manifest::{GraphEntry, Manifest, ModelConfig, ModelEntry, ParamInfo};
pub use sampling::{argmax, log_softmax, sample_from_logits, softmax, SamplingParams};
pub use weights::{load_weights, HostWeights};
