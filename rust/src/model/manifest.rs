//! `artifacts/manifest.json` schema (written by `python/compile/aot.py`),
//! parsed with the in-tree JSON module.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub group_size: usize,
    pub models: BTreeMap<String, ModelEntry>,
    /// task name -> relative path of the prompt file
    pub tasks: BTreeMap<String, String>,
    pub prompt_len: usize,
    pub heldout: String,
    pub goldens_bin: String,
    pub goldens_json: String,
    /// Root directory the relative paths resolve against.
    pub root: PathBuf,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub config: ModelConfig,
    pub params: Vec<ParamInfo>,
    pub linears: Vec<String>,
    pub kv_shape: Vec<usize>,
    pub graphs: BTreeMap<String, GraphEntry>,
    pub train: TrainInfo,
    pub weights: String,
    /// Logits slots in the state vector (max draft length + 1 bonus).
    pub state_slots: usize,
    /// Total f32 length of the state vector: `slots * vocab + kv_elements`.
    pub state_len: usize,
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub paper_analog: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub cache_len: usize,
    pub prefill_len: usize,
    pub param_count: usize,
}

#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

#[derive(Debug, Clone)]
pub struct GraphEntry {
    pub file: String,
    pub args: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct TrainInfo {
    pub loss_first: f64,
    pub loss_last: f64,
}

fn str_field(v: &Value, key: &str) -> Result<String> {
    Ok(v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing string field {key:?}"))?
        .to_string())
}

fn usize_field(v: &Value, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Value::as_usize)
        .ok_or_else(|| anyhow::anyhow!("missing numeric field {key:?}"))
}

fn usize_vec(v: &Value) -> Result<Vec<usize>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected array"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow::anyhow!("expected number")))
        .collect()
}

fn str_vec(v: &Value) -> Result<Vec<String>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected array"))?
        .iter()
        .map(|x| {
            x.as_str().map(str::to_string).ok_or_else(|| anyhow::anyhow!("expected string"))
        })
        .collect()
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        anyhow::ensure!(
            path.exists(),
            "{} not found — run `make artifacts` first",
            path.display()
        );
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let mut models = BTreeMap::new();
        for (name, entry) in
            v.get("models").and_then(Value::as_obj).context("manifest missing models")?
        {
            models.insert(name.clone(), parse_model(entry).context(name.clone())?);
        }
        let mut tasks = BTreeMap::new();
        for (name, path) in
            v.get("tasks").and_then(Value::as_obj).context("manifest missing tasks")?
        {
            tasks.insert(
                name.clone(),
                path.as_str().context("task path must be a string")?.to_string(),
            );
        }
        Ok(Self {
            version: usize_field(&v, "version")? as u32,
            group_size: usize_field(&v, "group_size")?,
            models,
            tasks,
            prompt_len: usize_field(&v, "prompt_len")?,
            heldout: str_field(&v, "heldout")?,
            goldens_bin: str_field(&v, "goldens_bin")?,
            goldens_json: str_field(&v, "goldens_json")?,
            root,
        })
    }

    /// Default artifacts root: `$SPEQ_ARTIFACTS` or `./artifacts`.
    pub fn default_root() -> PathBuf {
        std::env::var_os("SPEQ_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "model {name:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }
}

fn parse_model(v: &Value) -> Result<ModelEntry> {
    let c = v.get("config").context("model missing config")?;
    let config = ModelConfig {
        name: str_field(c, "name")?,
        paper_analog: str_field(c, "paper_analog")?,
        n_layers: usize_field(c, "n_layers")?,
        d_model: usize_field(c, "d_model")?,
        d_ff: usize_field(c, "d_ff")?,
        n_heads: usize_field(c, "n_heads")?,
        head_dim: usize_field(c, "head_dim")?,
        vocab: usize_field(c, "vocab")?,
        cache_len: usize_field(c, "cache_len")?,
        prefill_len: usize_field(c, "prefill_len")?,
        param_count: usize_field(c, "param_count")?,
    };
    let mut params = Vec::new();
    for p in v.get("params").and_then(Value::as_arr).context("model missing params")? {
        params.push(ParamInfo {
            name: str_field(p, "name")?,
            shape: usize_vec(p.get("shape").context("param missing shape")?)?,
            dtype: str_field(p, "dtype")?,
            offset_bytes: usize_field(p, "offset_bytes")?,
            size_bytes: usize_field(p, "size_bytes")?,
        });
    }
    let linears = str_vec(v.get("linears").context("model missing linears")?)?;
    let kv_shape = usize_vec(v.get("kv_shape").context("model missing kv_shape")?)?;
    let mut graphs = BTreeMap::new();
    for (name, g) in v.get("graphs").and_then(Value::as_obj).context("missing graphs")? {
        graphs.insert(
            name.clone(),
            GraphEntry {
                file: str_field(g, "file")?,
                args: str_vec(g.get("args").context("graph missing args")?)?,
                outputs: str_vec(g.get("outputs").context("graph missing outputs")?)?,
            },
        );
    }
    let t = v.get("train").context("model missing train")?;
    let train = TrainInfo {
        loss_first: t.get("loss_first").and_then(Value::as_f64).unwrap_or(f64::NAN),
        loss_last: t.get("loss_last").and_then(Value::as_f64).unwrap_or(f64::NAN),
    };
    let state = v.get("state").context("model missing state")?;
    Ok(ModelEntry {
        config,
        params,
        linears,
        kv_shape,
        graphs,
        train,
        weights: str_field(v, "weights")?,
        state_slots: usize_field(state, "slots")?,
        state_len: usize_field(state, "state_len")?,
    })
}

impl ModelEntry {
    pub fn graph(&self, name: &str) -> Result<&GraphEntry> {
        self.graphs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("graph {name:?} missing from manifest entry"))
    }

    pub fn kv_elements(&self) -> usize {
        self.kv_shape.iter().product()
    }

    pub fn param(&self, name: &str) -> Result<&ParamInfo> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow::anyhow!("param {name:?} not in manifest"))
    }

    pub fn is_linear(&self, name: &str) -> bool {
        self.linears.iter().any(|l| l == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let doc = r#"{
          "version": 1, "group_size": 128, "prompt_len": 128,
          "heldout": "heldout.bin", "goldens_bin": "g.bin", "goldens_json": "g.json",
          "tasks": {"math": "tasks/math.json"},
          "models": {"m": {
            "config": {"name":"m","paper_analog":"X","n_layers":2,"d_model":128,
                       "d_ff":256,"n_heads":4,"head_dim":32,"vocab":256,
                       "cache_len":512,"prefill_len":256,"param_count":1000},
            "params": [{"name":"embed","shape":[256,128],"dtype":"f16",
                        "offset_bytes":0,"size_bytes":65536}],
            "linears": ["lm_head"],
            "kv_shape": [2,2,512,4,32],
            "graphs": {"prefill":{"file":"m/prefill.hlo.txt",
                                   "args":["embed","tokens","length"],
                                   "outputs":["logits","kv"]}},
            "train": {"loss_first": 5.5, "loss_last": 0.4},
            "weights": "m/weights.bin",
            "state": {"slots": 17, "state_len": 266496}
          }}
        }"#;
        let dir = std::env::temp_dir().join("speq_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.group_size, 128);
        let e = m.model("m").unwrap();
        assert_eq!(e.config.d_model, 128);
        assert_eq!(e.params[0].size_bytes, 65536);
        assert!(e.is_linear("lm_head"));
        assert!(!e.is_linear("embed"));
        assert_eq!(e.kv_elements(), 2 * 2 * 512 * 4 * 32);
        assert_eq!(e.graph("prefill").unwrap().outputs, vec!["logits", "kv"]);
        assert_eq!(e.state_slots, 17);
        assert!(m.model("nope").is_err());
    }
}
