//! [`ModelRuntime`]: one model's compiled graphs + device-resident weights.
//!
//! Weights are uploaded once: full-precision params as f32 buffers, and the
//! BSFP draft params (nibble-packed `W_q` + Eq. 4 scales) derived from the
//! *same* FP16 bits by the Rust codec — the paper's parameter sharing made
//! literal.
//!
//! All request-path graphs return one flat f32 **state** vector
//! `[S_SLOTS * V logits slots | KV]` (see `python/compile/model.py`): the
//! state buffer is threaded output -> input entirely on-device, and each
//! step copies only the logits prefix to the host.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::manifest::{Manifest, ModelEntry};
use super::weights::{load_weights, HostWeights};
use crate::bsfp::{quantize_tensor, GROUP_SIZE};
use crate::runtime::{Executable, Runtime};

/// Logits (slot 0, length V) + the threaded state buffer.
pub struct StepOutput {
    pub logits: Vec<f32>,
    pub state: xla::PjRtBuffer,
}

/// All `S_SLOTS` logits rows (flattened, S*V) + the threaded state buffer.
pub struct VerifyOutput {
    pub logits: Vec<f32>,
    pub state: xla::PjRtBuffer,
}

/// A loaded, executable model (full target + BSFP draft).
pub struct ModelRuntime {
    pub entry: ModelEntry,
    rt: Runtime,
    prefill_exe: Executable,
    eval_exe: Executable,
    decode_full_exe: Executable,
    decode_draft_exe: Executable,
    verify_exe: Executable,
    /// Tiny on-device slicer: state -> logits slots (the PJRT build has no
    /// raw prefix reads, so extraction happens device-side).
    extract_exe: Executable,
    /// Full-precision params, manifest `params` order.
    full_bufs: Vec<xla::PjRtBuffer>,
    /// Draft args, manifest `decode_draft.args` order (minus token/pos/state).
    draft_bufs: Vec<xla::PjRtBuffer>,
    /// Host copies for analyses (exponent histograms, re-quantization).
    pub weights: HostWeights,
}

impl ModelRuntime {
    /// Load a model by name from the manifest, compiling all five graphs.
    pub fn load(rt: &Runtime, manifest: &Manifest, name: &str) -> Result<Self> {
        let entry = manifest.model(name)?.clone();
        let weights = load_weights(manifest.path(&entry.weights), &entry)
            .with_context(|| format!("loading weights for {name}"))?;

        let prefill_exe = rt.load(manifest.path(&entry.graph("prefill")?.file))?;
        let eval_exe = rt.load(manifest.path(&entry.graph("eval")?.file))?;
        let decode_full_exe = rt.load(manifest.path(&entry.graph("decode_full")?.file))?;
        let decode_draft_exe = rt.load(manifest.path(&entry.graph("decode_draft")?.file))?;
        let verify_exe = rt.load(manifest.path(&entry.graph("verify")?.file))?;
        let extract_exe = rt.load(manifest.path(&entry.graph("extract")?.file))?;

        let full_bufs = upload_full_params(rt, &entry, &weights, None)?;
        let draft_bufs = upload_draft_params(rt, &entry, &weights)?;

        Ok(Self {
            entry,
            rt: rt.clone(),
            prefill_exe,
            eval_exe,
            decode_full_exe,
            decode_draft_exe,
            verify_exe,
            extract_exe,
            full_bufs,
            draft_bufs,
            weights,
        })
    }

    pub fn vocab(&self) -> usize {
        self.entry.config.vocab
    }

    pub fn cache_len(&self) -> usize {
        self.entry.config.cache_len
    }

    pub fn prefill_len(&self) -> usize {
        self.entry.config.prefill_len
    }

    /// Number of logits slots in the state vector (max draft length + 1).
    pub fn slots(&self) -> usize {
        self.entry.state_slots
    }

    /// Total f32 length of the state vector.
    pub fn state_len(&self) -> usize {
        self.entry.state_len
    }

    fn read_logits(&self, state: &xla::PjRtBuffer, rows: usize) -> Result<Vec<f32>> {
        let mut out = self.extract_exe.run(&[state])?;
        anyhow::ensure!(out.len() == 1, "extract: expected 1 output");
        let t = Executable::to_host_f32(&out.pop().unwrap())?;
        Ok(t.data[..rows * self.vocab()].to_vec())
    }

    /// Run the prefill graph over a (padded) prompt.
    ///
    /// Slot 0 of the returned logits is the prediction after position
    /// `length - 1`.
    pub fn prefill(&self, tokens: &[i32], length: usize) -> Result<StepOutput> {
        self.prefill_with(&self.full_bufs, tokens, length)
    }

    /// Prefill with substituted parameter buffers.
    pub fn prefill_with(
        &self,
        param_bufs: &[xla::PjRtBuffer],
        tokens: &[i32],
        length: usize,
    ) -> Result<StepOutput> {
        let p = self.entry.config.prefill_len;
        anyhow::ensure!(tokens.len() == p, "prefill needs exactly {p} (padded) tokens");
        anyhow::ensure!(length >= 1 && length <= p, "prefill length out of range");
        let tok_buf = self.rt.upload_i32(tokens, &[p])?;
        let len_buf = self.rt.upload_i32_scalar(length as i32)?;
        let mut args: Vec<&xla::PjRtBuffer> = param_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let mut out = self.prefill_exe.run(&args)?;
        anyhow::ensure!(out.len() == 1, "prefill: expected 1 output, got {}", out.len());
        let state = out.pop().unwrap();
        let logits = self.read_logits(&state, 1)?;
        Ok(StepOutput { logits, state })
    }

    /// Per-position logits `(P, V)` for a padded window — the perplexity
    /// harness (Table I).
    pub fn eval_logits(&self, tokens: &[i32], length: usize) -> Result<Vec<f32>> {
        self.eval_logits_with(&self.full_bufs, tokens, length)
    }

    /// Eval with substituted parameter buffers (quantization variants).
    pub fn eval_logits_with(
        &self,
        param_bufs: &[xla::PjRtBuffer],
        tokens: &[i32],
        length: usize,
    ) -> Result<Vec<f32>> {
        let p = self.entry.config.prefill_len;
        anyhow::ensure!(tokens.len() == p, "eval needs exactly {p} (padded) tokens");
        let tok_buf = self.rt.upload_i32(tokens, &[p])?;
        let len_buf = self.rt.upload_i32_scalar(length as i32)?;
        let mut args: Vec<&xla::PjRtBuffer> = param_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let mut out = self.eval_exe.run(&args)?;
        anyhow::ensure!(out.len() == 1, "eval: expected 1 output");
        let t = Executable::to_host_f32(&out.pop().unwrap())?;
        Ok(t.data)
    }

    /// One full-precision decode step (autoregressive baseline).
    pub fn decode_full(
        &self,
        token: i32,
        pos: usize,
        state: &xla::PjRtBuffer,
    ) -> Result<StepOutput> {
        self.decode_with(&self.decode_full_exe, &self.full_bufs, token, pos, state)
    }

    /// One 4-bit BSFP draft decode step.
    pub fn decode_draft(
        &self,
        token: i32,
        pos: usize,
        state: &xla::PjRtBuffer,
    ) -> Result<StepOutput> {
        self.decode_with(&self.decode_draft_exe, &self.draft_bufs, token, pos, state)
    }

    /// One decode step with substituted full-precision params.
    pub fn decode_full_with(
        &self,
        param_bufs: &[xla::PjRtBuffer],
        token: i32,
        pos: usize,
        state: &xla::PjRtBuffer,
    ) -> Result<StepOutput> {
        self.decode_with(&self.decode_full_exe, param_bufs, token, pos, state)
    }

    fn decode_with(
        &self,
        exe: &Executable,
        param_bufs: &[xla::PjRtBuffer],
        token: i32,
        pos: usize,
        state: &xla::PjRtBuffer,
    ) -> Result<StepOutput> {
        let tok_buf = self.rt.upload_i32_scalar(token)?;
        let pos_buf = self.rt.upload_i32_scalar(pos as i32)?;
        let mut args: Vec<&xla::PjRtBuffer> = param_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(state);
        let mut out = exe.run(&args)?;
        anyhow::ensure!(out.len() == 1, "decode: expected 1 output, got {}", out.len());
        let state = out.pop().unwrap();
        let logits = self.read_logits(&state, 1)?;
        Ok(StepOutput { logits, state })
    }

    /// Verify up to `slots()` tokens in one parallel full-precision pass.
    ///
    /// `tokens[i]` is scored at position `pos0 + i`; the returned logits hold
    /// all `S_SLOTS` rows (rows beyond the real draft count are padding).
    /// Full-precision KV overwrites the drafted positions (shared cache).
    pub fn verify(
        &self,
        tokens: &[i32],
        pos0: usize,
        state: &xla::PjRtBuffer,
    ) -> Result<VerifyOutput> {
        let s = self.slots();
        anyhow::ensure!(tokens.len() == s, "verify needs exactly {s} (padded) tokens");
        let tok_buf = self.rt.upload_i32(tokens, &[s])?;
        let pos_buf = self.rt.upload_i32_scalar(pos0 as i32)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.full_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(state);
        let mut out = self.verify_exe.run(&args)?;
        anyhow::ensure!(out.len() == 1, "verify: expected 1 output, got {}", out.len());
        let state = out.pop().unwrap();
        let logits = self.read_logits(&state, s)?;
        Ok(VerifyOutput { logits, state })
    }

    /// Build full-precision parameter buffers with each linear weight passed
    /// through `transform(name, w, k, n) -> w'` — the hook the Table I
    /// perplexity harness uses to compare quantization variants.
    pub fn build_transformed_params(
        &self,
        mut transform: impl FnMut(&str, &[f32], usize, usize) -> Result<Vec<f32>>,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut host: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        for p in &self.entry.params {
            let w = self.weights.f32(&p.name);
            if self.entry.is_linear(&p.name) && p.shape.len() == 2 {
                host.insert(p.name.clone(), transform(&p.name, w, p.shape[0], p.shape[1])?);
            } else {
                host.insert(p.name.clone(), w.to_vec());
            }
        }
        upload_full_params(&self.rt, &self.entry, &self.weights, Some(&host))
    }

    /// Expose the resident full-param buffers (for harness reuse).
    pub fn full_param_buffers(&self) -> &[xla::PjRtBuffer] {
        &self.full_bufs
    }
}

fn upload_full_params(
    rt: &Runtime,
    entry: &ModelEntry,
    weights: &HostWeights,
    overrides: Option<&BTreeMap<String, Vec<f32>>>,
) -> Result<Vec<xla::PjRtBuffer>> {
    let mut bufs = Vec::with_capacity(entry.params.len());
    for p in &entry.params {
        let data: &[f32] = match overrides.and_then(|o| o.get(&p.name)) {
            Some(v) => v,
            None => weights.f32(&p.name),
        };
        bufs.push(rt.upload_f32(data, &p.shape)?);
    }
    Ok(bufs)
}

/// Derive the BSFP draft params from the FP16 bits and upload them in the
/// draft graph's argument order: per manifest `params`, linears contribute
/// `(wq_packed u8 (k/2, n), scales f32 (k/128, n))`, everything else its f32
/// tensor.
fn upload_draft_params(
    rt: &Runtime,
    entry: &ModelEntry,
    weights: &HostWeights,
) -> Result<Vec<xla::PjRtBuffer>> {
    let mut bufs = Vec::new();
    for p in &entry.params {
        if entry.is_linear(&p.name) && p.shape.len() == 2 {
            let (k, n) = (p.shape[0], p.shape[1]);
            let qt = quantize_tensor(weights.f32(&p.name), k, n);
            // Fold the Algorithm-1 pre-scale into the group scales so the
            // draft graph produces original-domain values.
            let scales: Vec<f32> = qt.scales.iter().map(|&s| s / qt.tensor_scale).collect();
            bufs.push(rt.upload_u8(&qt.packed_wq(), &[k / 2, n])?);
            bufs.push(rt.upload_f32(&scales, &[k / GROUP_SIZE, n])?);
        } else {
            bufs.push(rt.upload_f32(weights.f32(&p.name), &p.shape)?);
        }
    }
    Ok(bufs)
}
