//! [`ModelRuntime`]: the PJRT execution backend (`pjrt` feature).
//!
//! One model's compiled graphs + device-resident weights.  Weights are
//! uploaded once: full-precision params as f32 buffers, and the BSFP draft
//! params (nibble-packed `W_q` + Eq. 4 scales) derived from the *same*
//! FP16 bits by the Rust codec — the paper's parameter sharing made
//! literal.
//!
//! All request-path graphs return one flat f32 **state** vector
//! `[S_SLOTS * V logits slots | KV]` (see `python/compile/model.py`): the
//! state buffer is threaded output -> input entirely on-device, and each
//! step copies only the logits prefix to the host.  The state travels
//! through [`BackendState::Pjrt`] to satisfy the [`Backend`] contract.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::manifest::{Manifest, ModelEntry};
use super::weights::{load_weights, HostWeights};
use crate::bsfp::{f32_to_f16_bits, quantize_tensor, GROUP_SIZE};
use crate::model::ModelConfig;
use crate::runtime::{
    Backend, BackendState, Executable, Runtime, SlotArena, StepOutput, VerifyOutput,
};

/// The six compiled graphs of one model.
struct Graphs {
    prefill: Executable,
    eval: Executable,
    decode_full: Executable,
    decode_draft: Executable,
    verify: Executable,
    /// Tiny on-device slicer: state -> logits slots (the PJRT build has no
    /// raw prefix reads, so extraction happens device-side).
    extract: Executable,
}

/// A loaded, executable model (full target + BSFP draft) over PJRT.
///
/// Graphs and parameter buffers are `Arc`-shared so
/// [`Backend::with_transformed_weights`] variants reuse the compiled
/// executables and the resident draft params.
pub struct ModelRuntime {
    pub entry: ModelEntry,
    rt: Runtime,
    exes: Arc<Graphs>,
    /// Full-precision params, manifest `params` order.
    full_bufs: Arc<Vec<xla::PjRtBuffer>>,
    /// Draft args, manifest `decode_draft.args` order (minus token/pos/state).
    draft_bufs: Arc<Vec<xla::PjRtBuffer>>,
    /// Host copies for analyses (exponent histograms, re-quantization).
    pub weights: HostWeights,
    /// Per-sequence device states for the batched serving API (the default
    /// batched ops loop the single-sequence graphs through this arena).
    arena: SlotArena,
}

impl ModelRuntime {
    /// Load a model by name from the manifest, compiling all graphs.
    pub fn load(rt: &Runtime, manifest: &Manifest, name: &str) -> Result<Self> {
        let entry = manifest.model(name)?.clone();
        let weights = load_weights(manifest.path(&entry.weights), &entry)
            .with_context(|| format!("loading weights for {name}"))?;

        let exes = Arc::new(Graphs {
            prefill: rt.load(manifest.path(&entry.graph("prefill")?.file))?,
            eval: rt.load(manifest.path(&entry.graph("eval")?.file))?,
            decode_full: rt.load(manifest.path(&entry.graph("decode_full")?.file))?,
            decode_draft: rt.load(manifest.path(&entry.graph("decode_draft")?.file))?,
            verify: rt.load(manifest.path(&entry.graph("verify")?.file))?,
            extract: rt.load(manifest.path(&entry.graph("extract")?.file))?,
        });

        let full_bufs = Arc::new(upload_full_params(rt, &entry, &weights, None)?);
        let draft_bufs = Arc::new(upload_draft_params(rt, &entry, &weights)?);

        Ok(Self {
            entry,
            rt: rt.clone(),
            exes,
            full_bufs,
            draft_bufs,
            weights,
            arena: SlotArena::new(),
        })
    }

    /// Total f32 length of the state vector.
    pub fn state_len(&self) -> usize {
        self.entry.state_len
    }

    fn read_logits(&self, state: &xla::PjRtBuffer, rows: usize) -> Result<Vec<f32>> {
        let mut out = self.exes.extract.run(&[state])?;
        anyhow::ensure!(out.len() == 1, "extract: expected 1 output");
        let t = Executable::to_host_f32(&out.pop().unwrap())?;
        Ok(t.data[..rows * self.vocab()].to_vec())
    }

    fn take_state(&self, state: BackendState) -> Result<xla::PjRtBuffer> {
        match state {
            BackendState::Pjrt(buf) => Ok(buf),
            BackendState::Native(_) => {
                anyhow::bail!("pjrt backend received a native host state")
            }
        }
    }

    fn decode_with(
        &self,
        exe: &Executable,
        param_bufs: &[xla::PjRtBuffer],
        token: i32,
        pos: usize,
        state: &xla::PjRtBuffer,
    ) -> Result<(Vec<f32>, xla::PjRtBuffer)> {
        let tok_buf = self.rt.upload_i32_scalar(token)?;
        let pos_buf = self.rt.upload_i32_scalar(pos as i32)?;
        let mut args: Vec<&xla::PjRtBuffer> = param_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(state);
        let mut out = exe.run(&args)?;
        anyhow::ensure!(out.len() == 1, "decode: expected 1 output, got {}", out.len());
        let state = out.pop().unwrap();
        let logits = self.read_logits(&state, 1)?;
        Ok((logits, state))
    }
}

impl Backend for ModelRuntime {
    fn config(&self) -> &ModelConfig {
        &self.entry.config
    }

    fn slots(&self) -> usize {
        self.entry.state_slots
    }

    fn linears(&self) -> &[String] {
        &self.entry.linears
    }

    fn weights(&self) -> &HostWeights {
        &self.weights
    }

    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn arena(&self) -> &SlotArena {
        &self.arena
    }

    fn prefill(&self, tokens: &[i32], length: usize) -> Result<StepOutput> {
        let p = self.entry.config.prefill_len;
        anyhow::ensure!(tokens.len() == p, "prefill needs exactly {p} (padded) tokens");
        anyhow::ensure!(length >= 1 && length <= p, "prefill length out of range");
        let tok_buf = self.rt.upload_i32(tokens, &[p])?;
        let len_buf = self.rt.upload_i32_scalar(length as i32)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.full_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let mut out = self.exes.prefill.run(&args)?;
        anyhow::ensure!(out.len() == 1, "prefill: expected 1 output, got {}", out.len());
        let state = out.pop().unwrap();
        let logits = self.read_logits(&state, 1)?;
        Ok(StepOutput { logits, state: BackendState::Pjrt(state) })
    }

    fn decode_full(&self, token: i32, pos: usize, state: BackendState) -> Result<StepOutput> {
        let buf = self.take_state(state)?;
        let (logits, state) =
            self.decode_with(&self.exes.decode_full, &self.full_bufs, token, pos, &buf)?;
        Ok(StepOutput { logits, state: BackendState::Pjrt(state) })
    }

    fn decode_draft(&self, token: i32, pos: usize, state: BackendState) -> Result<StepOutput> {
        let buf = self.take_state(state)?;
        let (logits, state) =
            self.decode_with(&self.exes.decode_draft, &self.draft_bufs, token, pos, &buf)?;
        Ok(StepOutput { logits, state: BackendState::Pjrt(state) })
    }

    fn verify(&self, tokens: &[i32], pos0: usize, state: BackendState) -> Result<VerifyOutput> {
        let s = self.slots();
        anyhow::ensure!(tokens.len() == s, "verify needs exactly {s} (padded) tokens");
        let buf = self.take_state(state)?;
        let tok_buf = self.rt.upload_i32(tokens, &[s])?;
        let pos_buf = self.rt.upload_i32_scalar(pos0 as i32)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.full_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&buf);
        let mut out = self.exes.verify.run(&args)?;
        anyhow::ensure!(out.len() == 1, "verify: expected 1 output, got {}", out.len());
        let state = out.pop().unwrap();
        let logits = self.read_logits(&state, s)?;
        Ok(VerifyOutput { logits, state: BackendState::Pjrt(state) })
    }

    fn eval_logits(&self, tokens: &[i32], length: usize) -> Result<Vec<f32>> {
        let p = self.entry.config.prefill_len;
        anyhow::ensure!(tokens.len() == p, "eval needs exactly {p} (padded) tokens");
        let tok_buf = self.rt.upload_i32(tokens, &[p])?;
        let len_buf = self.rt.upload_i32_scalar(length as i32)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.full_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let mut out = self.exes.eval.run(&args)?;
        anyhow::ensure!(out.len() == 1, "eval: expected 1 output");
        let t = Executable::to_host_f32(&out.pop().unwrap())?;
        Ok(t.data)
    }

    fn with_transformed_weights(
        &self,
        transform: &mut dyn FnMut(&str, &[f32], usize, usize) -> Result<Vec<f32>>,
    ) -> Result<Box<dyn Backend>> {
        let mut host: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        let mut weights = self.weights.clone();
        for p in &self.entry.params {
            let w = self.weights.f32(&p.name);
            if self.entry.is_linear(&p.name) && p.shape.len() == 2 {
                let new = transform(&p.name, w, p.shape[0], p.shape[1])?;
                anyhow::ensure!(
                    new.len() == w.len(),
                    "transform for {:?} returned {} values, expected {}",
                    p.name,
                    new.len(),
                    w.len()
                );
                weights
                    .bits
                    .insert(p.name.clone(), new.iter().map(|&v| f32_to_f16_bits(v)).collect());
                weights.f32s.insert(p.name.clone(), new.clone());
                host.insert(p.name.clone(), new);
            }
        }
        let full_bufs = upload_full_params(&self.rt, &self.entry, &self.weights, Some(&host))?;
        // Re-derive the draft from the transformed weights so the variant's
        // draft pass shares the same bits as its full pass (matching the
        // native backend's semantics).
        let draft_bufs = upload_draft_params(&self.rt, &self.entry, &weights)?;
        Ok(Box::new(Self {
            entry: self.entry.clone(),
            rt: self.rt.clone(),
            exes: Arc::clone(&self.exes),
            full_bufs: Arc::new(full_bufs),
            draft_bufs: Arc::new(draft_bufs),
            weights,
            arena: SlotArena::new(),
        }))
    }
}

fn upload_full_params(
    rt: &Runtime,
    entry: &ModelEntry,
    weights: &HostWeights,
    overrides: Option<&BTreeMap<String, Vec<f32>>>,
) -> Result<Vec<xla::PjRtBuffer>> {
    let mut bufs = Vec::with_capacity(entry.params.len());
    for p in &entry.params {
        let data: &[f32] = match overrides.and_then(|o| o.get(&p.name)) {
            Some(v) => v,
            None => weights.f32(&p.name),
        };
        bufs.push(rt.upload_f32(data, &p.shape)?);
    }
    Ok(bufs)
}

/// Derive the BSFP draft params from the FP16 bits and upload them in the
/// draft graph's argument order: per manifest `params`, linears contribute
/// `(wq_packed u8 (k/2, n), scales f32 (k/128, n))`, everything else its f32
/// tensor.
fn upload_draft_params(
    rt: &Runtime,
    entry: &ModelEntry,
    weights: &HostWeights,
) -> Result<Vec<xla::PjRtBuffer>> {
    let mut bufs = Vec::new();
    for p in &entry.params {
        if entry.is_linear(&p.name) && p.shape.len() == 2 {
            let (k, n) = (p.shape[0], p.shape[1]);
            let qt = quantize_tensor(weights.f32(&p.name), k, n);
            // Fold the Algorithm-1 pre-scale into the group scales so the
            // draft graph produces original-domain values.
            let scales: Vec<f32> = qt.scales.iter().map(|&s| s / qt.tensor_scale).collect();
            bufs.push(rt.upload_u8(&qt.packed_wq(), &[k / 2, n])?);
            bufs.push(rt.upload_f32(&scales, &[k / GROUP_SIZE, n])?);
        } else {
            bufs.push(rt.upload_f32(weights.f32(&p.name), &p.shape)?);
        }
    }
    Ok(bufs)
}
