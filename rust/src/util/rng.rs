//! Deterministic RNG (SplitMix64 core) — no external crates.
//!
//! SplitMix64 passes BigCrush for the output sizes used here and is fully
//! reproducible across platforms, which the experiment harness requires.

/// Seedable deterministic generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    /// Next 64 uniformly-random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f32 in [0, 1).
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n) (n > 0). Uses rejection-free multiply-shift;
    /// bias is < 2^-32 for the n used here.
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn gen_between(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.gen_range(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli(p).
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fill a vec with scaled uniform values in [-amp/2, amp/2).
    pub fn uniform_vec(&mut self, n: usize, amp: f32) -> Vec<f32> {
        (0..n).map(|_| (self.gen_f32() - 0.5) * amp).collect()
    }

    /// Fill a vec with normal(0, std) values.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.gen_normal() * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Rng::seed_from_u64(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from_u64(8);
        let n = 100_000;
        let vals: Vec<f64> = (0..n).map(|_| rng.gen_normal() as f64).collect();
        let mean = vals.iter().sum::<f64>() / n as f64;
        let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Rng::seed_from_u64(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
