//! Tiny property-testing driver.
//!
//! `check(cases, name, |rng| ...)` runs the closure `cases` times with
//! independent seeded RNGs; on panic it reports the failing seed so the case
//! can be replayed with `check_one(seed, ...)`.

use super::rng::Rng;

/// Run `f` for `cases` random cases; each case gets a deterministic seed.
/// Panics (with the seed) on the first failing case.
pub fn check(cases: u64, name: &str, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0x5eed_0000_0000_0000 ^ hash_name(name).wrapping_add(case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seed_from_u64(seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single case by seed.
pub fn check_one(seed: u64, f: impl FnOnce(&mut Rng)) {
    let mut rng = Rng::seed_from_u64(seed);
    f(&mut rng);
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a.
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(25, "trivial", |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check(10, "fails", |rng| {
            let v = rng.gen_range(4);
            assert!(v < 2, "v={v}");
        });
    }
}
