//! Flag-style CLI argument parsing (`--key value`, `--flag`).

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, flags, and positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator (first element must already exclude argv[0]).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("report --exp table1 --models m1,m2 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("report"));
        assert_eq!(a.get("exp"), Some("table1"));
        assert_eq!(a.get("models"), Some("m1,m2"));
        assert!(a.has("verbose"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn equals_form_and_numbers() {
        let a = parse("serve --port=8080 --gamma 0.6");
        assert_eq!(a.get_usize("port", 0), 8080);
        assert!((a.get_f64("gamma", 0.0) - 0.6).abs() < 1e-9);
        assert_eq!(a.get_usize("absent", 7), 7);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("run --fast");
        assert!(a.has("fast"));
    }
}
