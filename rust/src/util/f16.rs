//! IEEE 754 binary16 ("half") conversions.
//!
//! Exact `f16 -> f32` widening and round-to-nearest-even `f32 -> f16`
//! narrowing, matching numpy's behaviour bit-for-bit (cross-checked by the
//! exhaustive round-trip test below and by the Python-emitted goldens).
//!
//! The vectorized plane decoders (`bsfp::simd`) widen halves with a
//! branch-free magnitude-shift construction instead of this function's
//! renormalization loop; the two are exhaustively pinned bitwise-equal
//! over the BSFP domain (`exp <= 15`, subnormals included) by the simd
//! module's tests.

/// Widen an FP16 bit pattern to f32 (exact).
pub fn f16_to_f32(bits: u16) -> f32 {
    let sign = ((bits >> 15) & 1) as u32;
    let exp = ((bits >> 10) & 0x1f) as u32;
    let man = (bits & 0x3ff) as u32;
    let out = if exp == 0 {
        if man == 0 {
            sign << 31 // +/- 0
        } else {
            // Subnormal: renormalize.
            let mut e = 127 - 15 + 1;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            (sign << 31) | ((e as u32) << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        // Inf / NaN.
        (sign << 31) | (0xff << 23) | (man << 13)
    } else {
        (sign << 31) | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(out)
}

/// Narrow an f32 to an FP16 bit pattern (round-to-nearest-even).
pub fn f32_to_f16(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 31) & 1) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x7f_ffff;

    if exp == 0xff {
        // Inf / NaN: preserve a quiet NaN payload bit.
        let m = if man != 0 { 0x200 | ((man >> 13) as u16 & 0x3ff) | 1 } else { 0 };
        return (sign << 15) | (0x1f << 10) | if man != 0 && m & 0x3ff == 0 { 1 } else { m & 0x3ff };
    }

    // Unbiased exponent.
    let e = exp - 127;
    if e >= 16 {
        // Overflow -> infinity.
        return (sign << 15) | (0x1f << 10);
    }
    if e >= -14 {
        // Normal range for f16.
        let half_exp = (e + 15) as u16;
        let mut half_man = (man >> 13) as u16;
        // Round to nearest even on the 13 truncated bits.
        let round_bits = man & 0x1fff;
        if round_bits > 0x1000 || (round_bits == 0x1000 && half_man & 1 == 1) {
            half_man += 1;
        }
        let mut out = ((half_exp as u32) << 10) + half_man as u32; // carry may bump exp
        if out >= 0x7c00 {
            out = 0x7c00; // rounded up to infinity
        }
        return (sign << 15) | out as u16;
    }
    if e >= -25 {
        // Subnormal f16.
        let shift = (-14 - e) as u32; // 1..=11
        let full = 0x80_0000 | man; // implicit 1
        let total_shift = 13 + shift;
        let half_man = (full >> total_shift) as u16;
        let round_mask = 1u32 << (total_shift - 1);
        let rem = full & ((1 << total_shift) - 1);
        let mut out = half_man;
        if rem > round_mask || (rem == round_mask && half_man & 1 == 1) {
            out += 1;
        }
        return (sign << 15) | out;
    }
    // Underflow -> signed zero.
    sign << 15
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3c00);
        assert_eq!(f32_to_f16(-2.0), 0xc000);
        assert_eq!(f32_to_f16(65504.0), 0x7bff); // max finite f16
        assert_eq!(f32_to_f16(65536.0), 0x7c00); // overflow -> inf
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0x3555), 0.33325195); // ~1/3
        assert_eq!(f16_to_f32(0x0001), 5.9604645e-8); // smallest subnormal
    }

    #[test]
    fn roundtrip_all_finite_f16_patterns() {
        for bits in 0..=u16::MAX {
            let exp = (bits >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/nan handled separately
            }
            let f = f16_to_f32(bits);
            assert_eq!(f32_to_f16(f), bits, "bits {bits:#06x} -> {f} -> mismatch");
        }
    }

    #[test]
    fn nan_maps_to_nan() {
        let nan16 = f32_to_f16(f32::NAN);
        assert_eq!(nan16 & 0x7c00, 0x7c00);
        assert_ne!(nan16 & 0x3ff, 0);
        assert!(f16_to_f32(nan16).is_nan());
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and the next f16
        // (1.0 + 2^-10); RNE keeps the even mantissa (1.0).
        assert_eq!(f32_to_f16(1.0 + 2f32.powi(-11)), 0x3c00);
        // 1.0 + 3*2^-11 is halfway between odd and even; rounds up to even.
        assert_eq!(f32_to_f16(1.0 + 3.0 * 2f32.powi(-11)), 0x3c02);
        // Just above halfway rounds up.
        assert_eq!(f32_to_f16(1.0 + 2f32.powi(-11) + 2f32.powi(-20)), 0x3c01);
    }

    #[test]
    fn subnormal_rounding() {
        let tiny = f16_to_f32(0x0001);
        assert_eq!(f32_to_f16(tiny * 0.49), 0x0000);
        assert_eq!(f32_to_f16(tiny * 0.51), 0x0001);
        assert_eq!(f32_to_f16(tiny * 1.5), 0x0002); // halfway -> even
    }
}
