//! In-tree substrates for an offline build.
//!
//! The build environment has no crate registry (see `third_party/` for the
//! vendored `anyhow` shim and the `xla` stub), so the usual ecosystem
//! crates are replaced by small, fully-tested implementations:
//!
//! * [`f16`] — IEEE 754 binary16 <-> f32 conversion (round-to-nearest-even),
//!   the substrate under all BSFP bit manipulation.
//! * [`json`] — a strict, minimal JSON parser/writer for `manifest.json`,
//!   task files, goldens and report output.
//! * [`rng`] — deterministic SplitMix64-based RNG (uniform, range, normal).
//! * [`cli`] — flag-style argument parsing for the `speq` binary.
//! * [`bench`] — a micro-benchmark harness (used by `benches/*.rs`, which
//!   run with `harness = false`).
//! * [`prop`] — a tiny property-testing driver (randomized invariant checks
//!   with seed reporting on failure).
//! * [`log`] — leveled stderr diagnostics (`SPEQ_LOG`, timestamps, target
//!   prefixes) behind the crate-root `log_warn!`-family macros.

pub mod bench;
pub mod cli;
pub mod f16;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
