//! Minimal strict JSON parser and writer.
//!
//! Sufficient for the machine-generated artifact files (`manifest.json`,
//! `tasks/*.json`, `goldens.json`) and for report output.  Supports the full
//! JSON grammar including string escapes and `\uXXXX` (with surrogate
//! pairs); numbers are parsed as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` that errors with the key name — manifest loading helper.
    pub fn req(&self, key: &str) -> Result<&Value, ParseError> {
        self.get(key).ok_or_else(|| ParseError::new(format!("missing key {key:?}"), 0))
    }
}

/// Parse failure with byte offset.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl ParseError {
    fn new(msg: impl Into<String>, offset: usize) -> Self {
        Self { msg: msg.into(), offset }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(ParseError::new("trailing content", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(ParseError::new(format!("expected {:?}", c as char), self.i))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(ParseError::new(format!("unexpected {:?}", c as char), self.i)),
            None => Err(ParseError::new("unexpected end of input", self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(ParseError::new(format!("expected {word}"), self.i))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(ParseError::new("expected ',' or '}'", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(ParseError::new("expected ',' or ']'", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(ParseError::new("unterminated string", self.i)),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xd800..0xdc00).contains(&hi) {
                                // Surrogate pair.
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xdc00..0xe000).contains(&lo) {
                                    return Err(ParseError::new("bad low surrogate", self.i));
                                }
                                let cp =
                                    0x10000 + (((hi - 0xd800) as u32) << 10) + (lo - 0xdc00) as u32;
                                char::from_u32(cp)
                                    .ok_or_else(|| ParseError::new("bad codepoint", self.i))?
                            } else {
                                char::from_u32(hi as u32)
                                    .ok_or_else(|| ParseError::new("bad codepoint", self.i))?
                            };
                            out.push(c);
                            continue; // hex4 advanced past the digits
                        }
                        _ => return Err(ParseError::new("bad escape", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| ParseError::new("invalid UTF-8", start))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(ParseError::new("short \\u escape", self.i));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| ParseError::new("bad hex", self.i))?;
        let v = u16::from_str_radix(s, 16).map_err(|_| ParseError::new("bad hex", self.i))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| ParseError::new(format!("bad number {s:?}"), start))
    }
}

/// Serialize a [`Value`] to compact JSON text.
pub fn write(v: &Value) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_str(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(item, out);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_into(val, out);
            }
            out.push('}');
        }
    }
}

/// Streaming-safe escaper for byte-level token payloads: renders raw bytes
/// as a quoted JSON string that is also safe to embed in a single SSE
/// `data:` line.
///
/// The serving stack's tokens are *bytes*, and a streamed chunk can split a
/// multi-byte UTF-8 sequence at any boundary — so the bytes cannot be
/// interpreted as UTF-8 text.  Instead each byte maps to the codepoint of
/// the same value (Latin-1 style): printable ASCII passes through verbatim,
/// everything else (control chars, `"`/`\`, DEL, and all bytes ≥ 0x80)
/// becomes a `\u00XX` escape.  Properties:
///
/// * lossless: [`bytes_from_escaped`] inverts it exactly for every byte
///   value (asserted exhaustively in tests);
/// * the output is valid JSON parseable by [`parse`];
/// * the output contains no raw control characters, so it can never break
///   SSE's line-based `data:` framing.
pub fn escape_bytes(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() + 2);
    out.push('"');
    for &b in bytes {
        match b {
            b'"' => out.push_str("\\\""),
            b'\\' => out.push_str("\\\\"),
            0x20..=0x7e => out.push(b as char),
            _ => {
                let _ = write!(out, "\\u{:04x}", b as u32);
            }
        }
    }
    out.push('"');
    out
}

/// Invert [`escape_bytes`]: map a parsed JSON string back to raw bytes.
/// Returns `None` if the string contains a codepoint above U+00FF (i.e. it
/// was not produced by the byte escaper).
pub fn bytes_from_escaped(s: &str) -> Option<Vec<u8>> {
    let mut out = Vec::with_capacity(s.len());
    for c in s.chars() {
        let cp = c as u32;
        if cp > 0xff {
            return None;
        }
        out.push(cp as u8);
    }
    Some(out)
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, {"b": "x"}, null], "c": {"d": false}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        // Surrogate pair (U+1F600).
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "1 2", "{'a':1}"] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn roundtrip_write_parse() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"he\"llo","t":true},"z":null}"#;
        let v = parse(src).unwrap();
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn escape_bytes_roundtrips_every_single_byte_token() {
        // Property over ALL single-byte tokens: escape → parse (the strict
        // JSON parser) → invert must reproduce the byte, and the escaped
        // form must be SSE-line-safe (no raw control chars).
        for b in 0..=255u8 {
            let escaped = escape_bytes(&[b]);
            assert!(
                escaped.chars().all(|c| (' '..='~').contains(&c)),
                "byte {b:#04x} escaped to a non-printable form: {escaped:?}"
            );
            let parsed = parse(&escaped).unwrap_or_else(|e| panic!("byte {b:#04x}: {e}"));
            let s = parsed.as_str().expect("escaped byte parses to a string");
            assert_eq!(
                bytes_from_escaped(s).as_deref(),
                Some(&[b][..]),
                "byte {b:#04x} did not roundtrip (escaped: {escaped:?})"
            );
        }
    }

    #[test]
    fn escape_bytes_roundtrips_random_byte_streams() {
        use crate::util::prop;
        prop::check(64, "escape_bytes_roundtrip", |rng| {
            let n = rng.gen_range(64) + 1;
            let bytes: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            let escaped = escape_bytes(&bytes);
            let parsed = parse(&escaped).expect("valid JSON");
            let back = bytes_from_escaped(parsed.as_str().unwrap()).expect("latin-1 range");
            assert_eq!(back, bytes);
            // SSE framing safety: a data line may not contain raw CR/LF.
            assert!(!escaped.contains('\n') && !escaped.contains('\r'));
        });
    }

    #[test]
    fn bytes_from_escaped_rejects_wide_codepoints() {
        assert_eq!(bytes_from_escaped("ok"), Some(b"ok".to_vec()));
        assert_eq!(bytes_from_escaped("😀"), None);
    }

    #[test]
    fn parses_real_manifest_like_doc() {
        let doc = r#"{"version":1,"models":{"m":{"params":[{"name":"embed","shape":[256,128],"offset_bytes":0}]}}}"#;
        let v = parse(doc).unwrap();
        let p = &v.get("models").unwrap().get("m").unwrap().get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("shape").unwrap().as_arr().unwrap()[1].as_usize(), Some(128));
    }
}
