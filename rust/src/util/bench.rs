//! Micro-benchmark harness (criterion replacement for the offline build).
//!
//! Each `benches/*.rs` target (built with `harness = false`) constructs a
//! [`Bench`], registers closures, and prints a stable, parseable report:
//!
//! ```text
//! bench_quantize/encode_1M        1.234 ms/iter  (n=420, p50=1.2ms p95=1.4ms)
//! ```

use std::time::{Duration, Instant};

/// Target minimum sampling time per benchmark.
const TARGET: Duration = Duration::from_millis(400);
const WARMUP: Duration = Duration::from_millis(100);
/// Smoke mode (`-- --smoke`, used in CI): just enough sampling to catch
/// gross regressions and prove the bench target still runs.
const SMOKE_TARGET: Duration = Duration::from_millis(40);
const SMOKE_WARMUP: Duration = Duration::from_millis(5);
const MAX_ITERS: u64 = 1_000_000;

/// Whether the process was invoked with a `--smoke` argument
/// (`cargo bench --bench bench_engine -- --smoke`).
pub fn smoke_requested() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// One benchmark group (named per paper table/figure).
pub struct Bench {
    group: String,
    results: Vec<(String, Stats)>,
    target: Duration,
    warmup: Duration,
}

/// Timing statistics over collected samples.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl Bench {
    pub fn new(group: impl Into<String>) -> Self {
        Self::with_durations(group, TARGET, WARMUP)
    }

    /// A group honoring [`smoke_requested`]: full sampling normally, a
    /// fast low-confidence pass under `-- --smoke` (CI regression guard).
    pub fn auto(group: impl Into<String>) -> Self {
        if smoke_requested() {
            Self::with_durations(group, SMOKE_TARGET, SMOKE_WARMUP)
        } else {
            Self::new(group)
        }
    }

    /// A group with explicit sampling durations.
    pub fn with_durations(
        group: impl Into<String>,
        target: Duration,
        warmup: Duration,
    ) -> Self {
        let group = group.into();
        eprintln!("== bench group {group} ==");
        Self { group, results: Vec::new(), target, warmup }
    }

    /// Time `f`, adaptively choosing iteration count.
    pub fn bench(&mut self, name: impl Into<String>, mut f: impl FnMut()) -> Stats {
        let name = name.into();
        // Warmup.
        let warm_start = Instant::now();
        while warm_start.elapsed() < self.warmup {
            f();
        }
        // Estimate per-iter cost.
        let t0 = Instant::now();
        f();
        let est = t0.elapsed().max(Duration::from_nanos(50));
        let chunk = ((self.target.as_nanos() / 20 / est.as_nanos()).max(1) as u64).min(MAX_ITERS);

        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.target && total_iters < MAX_ITERS {
            let t = Instant::now();
            for _ in 0..chunk {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / chunk as f64);
            total_iters += chunk;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let stats = Stats {
            iters: total_iters,
            mean_ns: mean,
            p50_ns: samples[samples.len() / 2],
            p95_ns: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
        };
        println!(
            "{}/{name:<40} {:>12}/iter  (n={}, p50={}, p95={})",
            self.group,
            fmt_ns(stats.mean_ns),
            stats.iters,
            fmt_ns(stats.p50_ns),
            fmt_ns(stats.p95_ns),
        );
        self.results.push((name, stats.clone()));
        stats
    }

    /// Report a derived metric (throughput, cycles, joules) alongside timings.
    pub fn metric(&mut self, name: impl Into<String>, value: f64, unit: &str) {
        println!("{}/{:<40} {value:>14.4} {unit}", self.group, name.into());
    }

    /// Emit a machine-readable `BENCH_JSON {...}` line (one JSON object per
    /// call) for CI and the report harness to consume — e.g. the
    /// `bytes_per_token_{draft,full}` traffic numbers the quarter-to-all
    /// regression check reads, and the `threads`/`batch`/`tokens_per_sec`
    /// cells of the engine bench's thread-scaling sweep (collected into
    /// `BENCH_*.json` artifacts by CI so the perf trajectory accumulates
    /// across commits).  Non-finite values are serialized as 0.
    pub fn metrics_json(&self, fields: &[(&str, f64)]) {
        let body: Vec<String> = fields
            .iter()
            .map(|(key, value)| {
                let v = if value.is_finite() { *value } else { 0.0 };
                format!("\"{key}\":{v}")
            })
            .collect();
        println!("BENCH_JSON {{\"group\":\"{}\",{}}}", self.group, body.join(","));
    }

    pub fn results(&self) -> &[(String, Stats)] {
        &self.results
    }
}

/// Nearest-rank percentile (`p` in `[0, 1]`); sorts `values` in place and
/// returns `0.0` when empty.  The single definition shared by the serving
/// metrics snapshot and the loadgen report, so every report agrees on the
/// rank convention (`round((n-1)·p)`).
pub fn percentile(values: &mut [f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((values.len() as f64 - 1.0) * p).round() as usize;
    values[idx.min(values.len() - 1)]
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// A guard against the optimizer eliding benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert_eq!(fmt_ns(12_500.0), "12.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&mut v, 0.0), 1.0);
        assert_eq!(percentile(&mut v, 1.0), 100.0);
        assert!((percentile(&mut v, 0.5) - 51.0).abs() <= 1.0);
        assert!((percentile(&mut v, 0.95) - 95.0).abs() <= 1.0);
        assert_eq!(percentile(&mut [], 0.5), 0.0);
        let mut unsorted = vec![3.0, 1.0, 2.0];
        assert_eq!(percentile(&mut unsorted, 1.0), 3.0);
    }
}
