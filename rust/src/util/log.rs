//! Minimal leveled stderr diagnostics for the serving stack.
//!
//! The crate's scattered `eprintln!` warnings (pjrt fallback, SIMD tier
//! resolution, workload skips) route through one sink so serving logs are
//! grep-able: every line carries an epoch timestamp, a level, and a
//! target prefix —
//!
//! ```text
//! [1754640000.123 WARN speq::bsfp::simd] SIMD level Avx2 unavailable ...
//! ```
//!
//! The threshold comes from `SPEQ_LOG={error,warn,info,debug}` (default
//! `warn`), read once on first use; [`set_level`] overrides it for tests.
//! Disabled levels cost one relaxed atomic load at the macro call site.
//!
//! Use via the crate-root macros: `log_error!`, `log_warn!`, `log_info!`,
//! `log_debug!`, each taking a target followed by `format!` arguments.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered: a message is emitted when its level is at or
/// below the configured threshold.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

/// Unset sentinel: resolved from `SPEQ_LOG` on first probe.
const UNSET: usize = usize::MAX;

static LEVEL: AtomicUsize = AtomicUsize::new(UNSET);

fn from_env() -> usize {
    match std::env::var("SPEQ_LOG").ok().as_deref() {
        Some("error") => Level::Error as usize,
        Some("warn") => Level::Warn as usize,
        Some("info") => Level::Info as usize,
        Some("debug") => Level::Debug as usize,
        // Unknown values fall back to the default rather than erroring:
        // logging must never take the process down.
        _ => Level::Warn as usize,
    }
}

/// Current threshold (lazily resolved from the environment).
pub fn threshold() -> usize {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != UNSET {
        return l;
    }
    let resolved = from_env();
    LEVEL.store(resolved, Ordering::Relaxed);
    resolved
}

/// Override the threshold (tests; wins over `SPEQ_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Is `level` currently emitted?
#[inline]
pub fn enabled(level: Level) -> bool {
    (level as usize) <= threshold()
}

/// Format one log line (separated from [`emit`] so tests can assert on
/// the exact shape without capturing stderr).
pub fn format_line(level: Level, target: &str, msg: std::fmt::Arguments<'_>) -> String {
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    format!(
        "[{}.{:03} {} {}] {}",
        now.as_secs(),
        now.subsec_millis(),
        level.name(),
        target,
        msg
    )
}

/// Write one line to stderr.  Called by the macros after their level
/// check; callable directly for pre-formatted messages.
pub fn emit(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    eprintln!("{}", format_line(level, target, msg));
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Error) {
            $crate::util::log::emit($crate::util::log::Level::Error, $target, format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            $crate::util::log::emit($crate::util::log::Level::Warn, $target, format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            $crate::util::log::emit($crate::util::log::Level::Info, $target, format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            $crate::util::log::emit($crate::util::log::Level::Debug, $target, format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_shape_has_timestamp_level_and_target() {
        let line = format_line(Level::Warn, "speq::test", format_args!("x = {}", 7));
        // "[<secs>.<millis> WARN speq::test] x = 7"
        assert!(line.starts_with('['), "{line}");
        assert!(line.contains(" WARN speq::test] x = 7"), "{line}");
        let ts = line[1..].split(' ').next().unwrap();
        let (secs, millis) = ts.split_once('.').expect("secs.millis");
        assert!(secs.chars().all(|c| c.is_ascii_digit()));
        assert_eq!(millis.len(), 3);
    }

    #[test]
    fn threshold_gates_levels_and_macros_expand() {
        // One test fn: the threshold is process-global, so splitting the
        // set_level assertions across parallel test fns would race.
        set_level(Level::Info);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Error);
        assert!(!enabled(Level::Warn));
        // Macro smoke: the expansions type-check and run (stderr only).
        crate::log_error!("speq::test", "e {}", 1);
        crate::log_warn!("speq::test", "w");
        crate::log_info!("speq::test", "i");
        crate::log_debug!("speq::test", "d");
        set_level(Level::Warn);
    }
}
