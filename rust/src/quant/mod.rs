//! Baseline quantizers.
//!
//! Two families:
//!
//! * **FP4 bit-extraction variants** (Table I): `E1M2`, `E2M1`, naive `E3M0`
//!   — the same shared-bit extraction as BSFP but without the remap, used to
//!   reproduce the perplexity ordering of Table I.
//! * **INT quantizers** (accelerator baselines): symmetric per-group INT4/8,
//!   an Olive-style outlier-victim-pair variant and a Tender-style
//!   decomposed variant.  These are *lossy* (the paper reports ppl 44.2 /
//!   36.5 for 4-bit Olive / Tender on Llama2-7b) and exist so Figs. 7–8 can
//!   compare against their accelerator cost models with matching accuracy
//!   caveats.

mod fp4;
mod int;

pub use fp4::{quantize_fp4, Fp4Variant};
pub use int::{quantize_int, IntMethod};

/// Apply a named weight transform; the generic hook used by the perplexity
/// harness (Table I) — every variant maps `(k, n)` f32 weights to the f32
/// weights the draft model would actually use.
pub fn transform_weights(
    method: &str,
    w: &[f32],
    k: usize,
    n: usize,
) -> Result<Vec<f32>, String> {
    match method {
        "fp16" => Ok(w.to_vec()),
        "bsfp" => Ok(crate::bsfp::quantize_tensor(w, k, n).dequant_draft()),
        "e3m0" | "naive" => Ok(quantize_fp4(w, k, n, Fp4Variant::E3M0)),
        "e2m1" => Ok(quantize_fp4(w, k, n, Fp4Variant::E2M1)),
        "e1m2" => Ok(quantize_fp4(w, k, n, Fp4Variant::E1M2)),
        "int4" | "olive4" => Ok(quantize_int(w, k, n, IntMethod::olive(4))),
        "int8" | "olive8" => Ok(quantize_int(w, k, n, IntMethod::olive(8))),
        "tender4" => Ok(quantize_int(w, k, n, IntMethod::tender(4))),
        "tender8" => Ok(quantize_int(w, k, n, IntMethod::tender(8))),
        other => Err(format!("unknown quantization method {other:?}")),
    }
}

/// All method names accepted by [`transform_weights`], for CLI help/report.
pub const METHODS: &[&str] =
    &["fp16", "bsfp", "e3m0", "e2m1", "e1m2", "olive4", "olive8", "tender4", "tender8"];
