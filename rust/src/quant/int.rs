//! INT quantizer analogs of the Olive (ISCA'23) and Tender (ISCA'24)
//! accelerator baselines.
//!
//! These reproduce the *numerics class* of each design so the perplexity
//! comparison ("severe performance degradation" at 4-bit, paper §V-A) and
//! the accelerator cost models share one definition:
//!
//! * **Olive**: symmetric per-group INT with outlier–victim pairs — each
//!   outlier (|w| beyond the clip range) steals its neighbour's slot to gain
//!   extended range; the victim is pruned to zero.
//! * **Tender**: per-channel decomposition — channels are split into
//!   magnitude clusters, each cluster quantized with its own power-of-two
//!   scale so runtime requantization is shift-only.

use crate::bsfp::GROUP_SIZE;

/// Which INT baseline, with bit width.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IntMethod {
    pub bits: u32,
    pub style: IntStyle,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntStyle {
    /// Olive-style outlier-victim-pair quantization.
    OutlierVictim,
    /// Tender-style per-channel power-of-two cluster decomposition.
    Decomposed,
}

impl IntMethod {
    pub fn olive(bits: u32) -> Self {
        Self { bits, style: IntStyle::OutlierVictim }
    }

    pub fn tender(bits: u32) -> Self {
        Self { bits, style: IntStyle::Decomposed }
    }

    pub fn name(&self) -> String {
        match self.style {
            IntStyle::OutlierVictim => format!("Olive-{}b", self.bits),
            IntStyle::Decomposed => format!("Tender-{}b", self.bits),
        }
    }
}

fn quant_sym(v: f32, scale: f32, qmax: i32) -> f32 {
    if scale <= 0.0 {
        return 0.0;
    }
    let q = (v / scale).round().clamp(-(qmax as f32), qmax as f32);
    q * scale
}

/// Olive-style: per-group symmetric INT, clip range set by a percentile so
/// most values quantize finely; outliers beyond the clip steal their
/// neighbour's slot (victim -> 0) and are kept at 4x extended range.
fn quantize_olive(w: &[f32], k: usize, n: usize, bits: u32) -> Vec<f32> {
    let qmax = (1i32 << (bits - 1)) - 1;
    let mut out = vec![0.0f32; k * n];
    let groups = k / GROUP_SIZE;
    for g in 0..groups {
        for j in 0..n {
            // Collect the group column.
            let mut mags: Vec<f32> = (0..GROUP_SIZE)
                .map(|i| w[(g * GROUP_SIZE + i) * n + j].abs())
                .collect();
            mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
            // Clip at the 99th percentile: inliers get fine resolution.
            let clip = mags[(GROUP_SIZE * 99 / 100).min(GROUP_SIZE - 1)].max(1e-12);
            let scale = clip / qmax as f32;
            for i in 0..GROUP_SIZE {
                let idx = (g * GROUP_SIZE + i) * n + j;
                let v = w[idx];
                if v.abs() > clip {
                    // Outlier: extended range at coarse resolution, and the
                    // victim (next element in the pair) is zeroed.
                    out[idx] = quant_sym(v, scale * 4.0, qmax);
                    let victim = idx ^ if i % 2 == 0 { n } else { 0 };
                    if victim != idx && victim < out.len() && i % 2 == 0 && i + 1 < GROUP_SIZE {
                        out[(g * GROUP_SIZE + i + 1) * n + j] = 0.0;
                    }
                } else if out[idx] == 0.0 {
                    out[idx] = quant_sym(v, scale, qmax);
                }
            }
        }
    }
    out
}

/// Tender-style: split each group column into two magnitude clusters, each
/// with a power-of-two scale (shift-only requantization).
fn quantize_tender(w: &[f32], k: usize, n: usize, bits: u32) -> Vec<f32> {
    let qmax = (1i32 << (bits - 1)) - 1;
    let mut out = vec![0.0f32; k * n];
    let groups = k / GROUP_SIZE;
    for g in 0..groups {
        for j in 0..n {
            let col: Vec<f32> =
                (0..GROUP_SIZE).map(|i| w[(g * GROUP_SIZE + i) * n + j]).collect();
            let maxab = col.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-12);
            // Power-of-two base scale.
            let base = (maxab / qmax as f32).log2().ceil().exp2();
            // Small-magnitude cluster gets a 1/16 (shift-by-4) finer scale.
            let fine = base / 16.0;
            let thresh = fine * qmax as f32;
            for i in 0..GROUP_SIZE {
                let idx = (g * GROUP_SIZE + i) * n + j;
                let v = w[idx];
                let s = if v.abs() <= thresh { fine } else { base };
                out[idx] = quant_sym(v, s, qmax);
            }
        }
    }
    out
}

/// Quantize a `(k, n)` row-major weight with an INT baseline.
pub fn quantize_int(w: &[f32], k: usize, n: usize, method: IntMethod) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    assert_eq!(k % GROUP_SIZE, 0);
    match method.style {
        IntStyle::OutlierVictim => quantize_olive(w, k, n, method.bits),
        IntStyle::Decomposed => quantize_tender(w, k, n, method.bits),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn weights(k: usize, n: usize, seed: u64) -> Vec<f32> {
        Rng::seed_from_u64(seed).uniform_vec(k * n, 0.2)
    }

    fn mse(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>()
            / a.len() as f64
    }

    #[test]
    fn int8_much_better_than_int4() {
        let w = weights(256, 8, 5);
        for mk in [IntMethod::olive, IntMethod::tender] {
            let q4 = quantize_int(&w, 256, 8, mk(4));
            let q8 = quantize_int(&w, 256, 8, mk(8));
            assert!(mse(&q8, &w) < mse(&q4, &w) / 4.0);
        }
    }

    #[test]
    fn bsfp_preserves_dynamic_range_better_than_int4() {
        // The paper's accuracy argument vs 4-bit INT accelerators: a
        // floating-point draft bounds *relative* error across the whole
        // dynamic range, while INT4 zeroes/coarsens small weights (uniform
        // step).  Median relative error is the range-sensitivity proxy; the
        // end-task comparison (perplexity) is the Table I harness.
        let w = Rng::seed_from_u64(6).normal_vec(512 * 8, 0.07);
        let bsfp = crate::bsfp::quantize_tensor(&w, 512, 8).dequant_draft();
        let p90_rel = |q: &[f32]| -> f64 {
            let mut rel: Vec<f64> = w
                .iter()
                .zip(q)
                .filter(|(&wv, _)| wv.abs() > 1e-6)
                .map(|(&wv, &qv)| ((qv - wv).abs() / wv.abs()) as f64)
                .collect();
            rel.sort_by(|a, b| a.partial_cmp(b).unwrap());
            rel[rel.len() * 9 / 10]
        };
        let bsfp_p90 = p90_rel(&bsfp);
        for m in [IntMethod::olive(4), IntMethod::tender(4)] {
            let q = quantize_int(&w, 512, 8, m);
            let int_p90 = p90_rel(&q);
            assert!(
                bsfp_p90 < int_p90,
                "{}: p90 rel err {int_p90:.4} vs BSFP {bsfp_p90:.4}",
                m.name()
            );
        }
    }

    #[test]
    fn olive_handles_outliers_better_than_plain_clip() {
        let mut w = weights(128, 1, 7);
        w[13] = 1.5; // big outlier vs ~0.1 spread
        let q = quantize_int(&w, 128, 1, IntMethod::olive(4));
        // The outlier survives with extended range (not clipped to ~0.1).
        assert!(q[13].abs() > 0.3, "outlier was clipped: {}", q[13]);
    }
}
