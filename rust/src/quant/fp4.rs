//! FP4 bit-extraction quantizers (the Table I baselines).
//!
//! Same shared-bit philosophy as BSFP — quantized values are *extracted*
//! from the FP16 bit pattern — but without the remap: `ExMy` keeps the top
//! `x` exponent bits (of e3..e0; e4 is 0 post Algorithm-1) and the top `y`
//! mantissa bits, zeroing the rest.  Naive E3M0 therefore rounds neighbour
//! exponents to the same value, which is exactly the failure mode the remap
//! fixes (Fig. 3 / Table I).

use crate::bsfp::{
    algorithm1_prescale, eq4_scales, f16_bits_to_f32, f32_to_f16_bits, split_fields,
    FP16_BIAS, GROUP_SIZE,
};

/// The three FP4 layouts evaluated in Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fp4Variant {
    /// 1 exponent bit, 2 mantissa bits.
    E1M2,
    /// 2 exponent bits, 1 mantissa bit.
    E2M1,
    /// 3 exponent bits, 0 mantissa bits (the naive BSFP precursor).
    E3M0,
}

impl Fp4Variant {
    fn keep(self) -> (u32, u32) {
        match self {
            Fp4Variant::E1M2 => (1, 2),
            Fp4Variant::E2M1 => (2, 1),
            Fp4Variant::E3M0 => (3, 0),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Fp4Variant::E1M2 => "E1M2",
            Fp4Variant::E2M1 => "E2M1",
            Fp4Variant::E3M0 => "E3M0",
        }
    }
}

/// Unscaled extraction quantization of one FP16 bit pattern.
fn extract_quant(bits: u16, exp_keep: u32, man_keep: u32) -> f32 {
    let f = split_fields(bits);
    let exp_mask: u8 = if exp_keep >= 4 { 0xf } else { (0xfu8 << (4 - exp_keep)) & 0xf };
    let qexp = (f.exp & exp_mask) as i32;
    let man_mask: u16 = if man_keep == 0 { 0 } else { (0x3ff >> man_keep) ^ 0x3ff };
    let qman = (f.man & man_mask) as f32 / 1024.0;
    let mag = ((qexp - FP16_BIAS) as f32).exp2() * (1.0 + qman);
    if f.sign == 1 {
        -mag
    } else {
        mag
    }
}

/// Quantize a `(k, n)` row-major weight with an FP4 variant + Eq. 4 group
/// scales; returns the f32 draft weights the variant would produce.
pub fn quantize_fp4(w: &[f32], k: usize, n: usize, variant: Fp4Variant) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    let (scaled, tscale) = algorithm1_prescale(w);
    let (ek, mk) = variant.keep();
    let fp16: Vec<f32> =
        scaled.iter().map(|&v| f16_bits_to_f32(f32_to_f16_bits(v))).collect();
    let q: Vec<f32> =
        scaled.iter().map(|&v| extract_quant(f32_to_f16_bits(v), ek, mk)).collect();
    let scales = eq4_scales(&fp16, &q, k, n);
    let mut out = vec![0.0f32; k * n];
    for i in 0..k {
        let g = i / GROUP_SIZE;
        for j in 0..n {
            out[i * n + j] = q[i * n + j] * scales[g * n + j] / tscale;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn weights(k: usize, n: usize, seed: u64) -> Vec<f32> {
        // Normal weights: the bell-shaped, wide-exponent-range distribution
        // of trained LLM weights, where the Table I ordering materializes.
        Rng::seed_from_u64(seed).normal_vec(k * n, 0.07)
    }

    /// MSE over the top-decile-magnitude weights — the error component that
    /// drives perplexity (large weights dominate logit perturbations), and
    /// the metric under which the Table I ordering is reproducible at the
    /// weight level.  (Plain MSE does *not* order E1M2 vs E2M1 reliably;
    /// the end-task check is the Table I perplexity harness.)
    fn top_decile_mse(q: &[f32], w: &[f32]) -> f64 {
        let mut mags: Vec<f32> = w.iter().map(|v| v.abs()).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let thr = mags[mags.len() * 9 / 10];
        let mut acc = 0.0f64;
        let mut count = 0usize;
        for (&qv, &wv) in q.iter().zip(w) {
            if wv.abs() > thr {
                acc += ((qv - wv) as f64).powi(2);
                count += 1;
            }
        }
        acc / count.max(1) as f64
    }

    #[test]
    fn error_ordering_matches_table1() {
        // Paper Table I: +Remap < E3M0 < E2M1 < E1M2 in perplexity; the
        // top-magnitude weight error reproduces the same ordering.
        let w = weights(512, 16, 9);
        let bsfp = crate::bsfp::quantize_tensor(&w, 512, 16).dequant_draft();
        let e3 = quantize_fp4(&w, 512, 16, Fp4Variant::E3M0);
        let e2 = quantize_fp4(&w, 512, 16, Fp4Variant::E2M1);
        let e1 = quantize_fp4(&w, 512, 16, Fp4Variant::E1M2);
        let (m_bsfp, m3, m2, m1) = (
            top_decile_mse(&bsfp, &w),
            top_decile_mse(&e3, &w),
            top_decile_mse(&e2, &w),
            top_decile_mse(&e1, &w),
        );
        assert!(m_bsfp < m3, "remap must beat naive E3M0: {m_bsfp} vs {m3}");
        assert!(m3 < m2, "E3M0 must beat E2M1: {m3} vs {m2}");
        assert!(m2 < m1, "E2M1 must beat E1M2: {m2} vs {m1}");
    }

    #[test]
    fn e3m0_clears_exponent_lsb() {
        // extract_quant with (3, 0) equals 2^((E & !1) - 15).
        let v = 0.11f32;
        let bits = f32_to_f16_bits(v);
        let f = split_fields(bits);
        let q = extract_quant(bits, 3, 0);
        assert_eq!(q, (((f.exp & 0xe) as i32 - FP16_BIAS) as f32).exp2());
    }
}
