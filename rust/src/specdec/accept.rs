//! Acceptance rules for verification.
//!
//! * Greedy: accept the longest prefix of draft tokens matching the
//!   target's argmax chain, then take the target's own next token as the
//!   bonus — output is *identical* to pure autoregressive greedy decoding
//!   (the lossless property, tested in `integration_engine.rs`).
//! * Sampling: Leviathan et al. speculative sampling — accept draft token x
//!   with probability `min(1, p(x)/q(x))`, resample the residual
//!   `norm(max(0, p - q))` at the first rejection.  Preserves the target
//!   distribution exactly.

use crate::model::argmax;
use crate::util::rng::Rng;

/// Result of verifying a drafted chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AcceptOutcome {
    /// Number of draft tokens accepted (prefix length).
    pub accepted: usize,
    /// The bonus/correction token emitted by the target after the accepted
    /// prefix.
    pub next_token: usize,
}

/// Greedy acceptance.
///
/// `draft_tokens` are the k drafted tokens; `verify_logits` holds at least
/// `k + 1` rows of `vocab` logits, where row `i` is the target's prediction
/// after consuming the carry token and drafts `1..=i`.
pub fn greedy_accept(
    draft_tokens: &[usize],
    verify_logits: &[f32],
    vocab: usize,
) -> AcceptOutcome {
    debug_assert!(verify_logits.len() >= (draft_tokens.len() + 1) * vocab);
    let mut accepted = 0;
    for (i, &d) in draft_tokens.iter().enumerate() {
        let row = &verify_logits[i * vocab..(i + 1) * vocab];
        if argmax(row) == d {
            accepted += 1;
        } else {
            break;
        }
    }
    let row = &verify_logits[accepted * vocab..(accepted + 1) * vocab];
    AcceptOutcome { accepted, next_token: argmax(row) }
}

/// Leviathan speculative sampling acceptance.
///
/// `draft_probs[i]` is the draft's (temperature-scaled) distribution used to
/// sample `draft_tokens[i]`; `target_probs_rows` holds `k + 1` rows of the
/// target's distribution at the same positions.
pub fn speculative_sample_accept(
    draft_tokens: &[usize],
    draft_probs: &[Vec<f32>],
    target_probs_rows: &[Vec<f32>],
    rng: &mut Rng,
) -> AcceptOutcome {
    debug_assert_eq!(draft_tokens.len(), draft_probs.len());
    debug_assert!(target_probs_rows.len() >= draft_tokens.len() + 1);
    for (i, &d) in draft_tokens.iter().enumerate() {
        let p = target_probs_rows[i][d];
        let q = draft_probs[i][d].max(1e-30);
        if (rng.gen_f64() as f32) < (p / q).min(1.0) {
            continue; // accepted
        }
        // Rejected: resample from the residual distribution.
        let residual: Vec<f32> = target_probs_rows[i]
            .iter()
            .zip(&draft_probs[i])
            .map(|(&pv, &qv)| (pv - qv).max(0.0))
            .collect();
        let z: f32 = residual.iter().sum();
        let next = if z <= 1e-12 {
            argmax(&target_probs_rows[i])
        } else {
            let u = rng.gen_f32() * z;
            let mut acc = 0.0;
            let mut pick = residual.len() - 1;
            for (t, &rv) in residual.iter().enumerate() {
                acc += rv;
                if u <= acc {
                    pick = t;
                    break;
                }
            }
            pick
        };
        return AcceptOutcome { accepted: i, next_token: next };
    }
    // All drafts accepted: sample the bonus from the last target row.
    let last = &target_probs_rows[draft_tokens.len()];
    let u: f32 = rng.gen_f32();
    let mut acc = 0.0;
    let mut pick = last.len() - 1;
    for (t, &pv) in last.iter().enumerate() {
        acc += pv;
        if u <= acc {
            pick = t;
            break;
        }
    }
    AcceptOutcome { accepted: draft_tokens.len(), next_token: pick }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_hot_logits(vocab: usize, hot: usize) -> Vec<f32> {
        let mut v = vec![0.0; vocab];
        v[hot] = 10.0;
        v
    }

    #[test]
    fn greedy_accepts_matching_prefix() {
        let vocab = 8;
        // Target chain: 3, 5, 1; drafts: 3, 5, 2 -> accept 2, bonus = 1.
        let mut logits = Vec::new();
        logits.extend(one_hot_logits(vocab, 3));
        logits.extend(one_hot_logits(vocab, 5));
        logits.extend(one_hot_logits(vocab, 1));
        logits.extend(one_hot_logits(vocab, 7)); // unused row
        let out = greedy_accept(&[3, 5, 2], &logits, vocab);
        assert_eq!(out, AcceptOutcome { accepted: 2, next_token: 1 });
    }

    #[test]
    fn greedy_rejects_all_when_first_mismatches() {
        let vocab = 4;
        let mut logits = one_hot_logits(vocab, 0);
        logits.extend(one_hot_logits(vocab, 2));
        let out = greedy_accept(&[3], &logits, vocab);
        assert_eq!(out, AcceptOutcome { accepted: 0, next_token: 0 });
    }

    #[test]
    fn greedy_full_accept_takes_bonus() {
        let vocab = 4;
        let mut logits = one_hot_logits(vocab, 1);
        logits.extend(one_hot_logits(vocab, 2));
        let out = greedy_accept(&[1], &logits, vocab);
        assert_eq!(out, AcceptOutcome { accepted: 1, next_token: 2 });
    }

    #[test]
    fn spec_sampling_accepts_when_distributions_match() {
        // p == q => always accept, bonus sampled from target.
        let mut rng = Rng::seed_from_u64(3);
        let probs = vec![0.25f32; 4];
        let out = speculative_sample_accept(
            &[2, 1],
            &[probs.clone(), probs.clone()],
            &[probs.clone(), probs.clone(), probs.clone()],
            &mut rng,
        );
        assert_eq!(out.accepted, 2);
        assert!(out.next_token < 4);
    }

    #[test]
    fn spec_sampling_rejects_impossible_tokens() {
        // Target gives probability 0 to the draft token -> always reject,
        // resample from residual = target.
        let mut rng = Rng::seed_from_u64(4);
        let q = vec![1.0f32, 0.0, 0.0, 0.0];
        let p = vec![0.0f32, 0.5, 0.5, 0.0];
        let out = speculative_sample_accept(&[0], &[q], &[p.clone(), p], &mut rng);
        assert_eq!(out.accepted, 0);
        assert!(out.next_token == 1 || out.next_token == 2);
    }

    #[test]
    fn spec_sampling_preserves_target_distribution() {
        // Chi-square-ish check: with one draft token, the emitted token's
        // marginal must match the target p regardless of the draft q.
        let q = vec![0.7f32, 0.1, 0.1, 0.1];
        let p = vec![0.1f32, 0.4, 0.4, 0.1];
        let mut counts = [0usize; 4];
        let n = 40_000;
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..n {
            // Draw a draft token from q.
            let u = rng.gen_f32();
            let mut acc = 0.0;
            let mut d = 3;
            for (t, &qv) in q.iter().enumerate() {
                acc += qv;
                if u <= acc {
                    d = t;
                    break;
                }
            }
            let out = speculative_sample_accept(&[d], &[q.clone()], &[p.clone(), p.clone()], &mut rng);
            // The emitted token is the accepted draft or the resample; with
            // a single position both cases emit exactly one token with
            // marginal p.
            let tok = if out.accepted == 1 { d } else { out.next_token };
            counts[tok] += 1;
        }
        for t in 0..4 {
            let emp = counts[t] as f64 / n as f64;
            assert!(
                (emp - p[t] as f64).abs() < 0.02,
                "token {t}: {emp} vs {}",
                p[t]
            );
        }
    }
}
