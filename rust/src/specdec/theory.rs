//! The paper's analytic model (Eq. 1 and Eq. 2, after [Leviathan et al.]).

/// Eq. 1: expected accept length `L_a = (1 - r^(L+1)) / (1 - r)` for draft
/// length `L` and per-token accept rate `r`.
pub fn expected_accept_length(r: f64, draft_len: usize) -> f64 {
    assert!((0.0..=1.0).contains(&r), "accept rate out of range: {r}");
    if (1.0 - r).abs() < 1e-12 {
        return draft_len as f64 + 1.0;
    }
    (1.0 - r.powi(draft_len as i32 + 1)) / (1.0 - r)
}

/// Eq. 2: speedup over autoregressive decoding,
/// `L_a * T_ar / (L * T_d + T_v)`.
///
/// `td_ratio` is `T_d / T_ar` (draft step cost relative to an
/// autoregressive step) and `tv_ratio` is `T_v / T_ar` (one parallel
/// verification pass relative to an autoregressive step).
pub fn theoretical_speedup(r: f64, draft_len: usize, td_ratio: f64, tv_ratio: f64) -> f64 {
    let la = expected_accept_length(r, draft_len);
    la / (draft_len as f64 * td_ratio + tv_ratio)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_length_limits() {
        // r = 0: only the bonus token survives each pass.
        assert!((expected_accept_length(0.0, 16) - 1.0).abs() < 1e-12);
        // r = 1: every draft accepted, plus the bonus.
        assert!((expected_accept_length(1.0, 16) - 17.0).abs() < 1e-12);
        // Monotone in r.
        let mut prev = 0.0;
        for i in 0..=10 {
            let la = expected_accept_length(i as f64 / 10.0, 8);
            assert!(la >= prev);
            prev = la;
        }
    }

    #[test]
    fn geometric_series_identity() {
        // L_a = sum_{i=0..L} r^i.
        let (r, l): (f64, usize) = (0.9, 6);
        let direct: f64 = (0..=l).map(|i| r.powi(i as i32)).sum();
        assert!((expected_accept_length(r, l) - direct).abs() < 1e-12);
    }

    #[test]
    fn paper_scale_speedup() {
        // Paper's operating point: r ~ 0.976, L = 16, quantize-mode draft
        // ~3.2x cheaper than an AR step, verify ~ one AR step (parallel,
        // weight-bound). The model should land near the reported ~2.1x.
        let s = theoretical_speedup(0.976, 16, 1.0 / 3.2, 1.0);
        assert!(s > 1.8 && s < 2.6, "speedup {s}");
    }

    #[test]
    fn speedup_degrades_with_slow_draft() {
        let fast = theoretical_speedup(0.95, 8, 0.2, 1.0);
        let slow = theoretical_speedup(0.95, 8, 0.9, 1.0);
        assert!(fast > slow);
    }
}
