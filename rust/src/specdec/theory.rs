//! The paper's analytic model (Eq. 1 and Eq. 2, after [Leviathan et al.]).
//!
//! Both entry points are **total**: a live accept-rate estimator can hand
//! them `0/0` (NaN), ε-out-of-range values from floating-point accumulation,
//! or zero cost ratios from an empty traffic counter, and they must never
//! panic a serving thread.  Inputs are sanitized — NaN accept rates read as
//! 0 (pessimistic: no draft evidence), finite rates clamp into `[0, 1]`,
//! and non-positive/non-finite cost ratios floor at [`MIN_COST_RATIO`] — so
//! the result is always finite and non-negative.

/// Smallest cost ratio the model will use.  A measured `T_d/T_ar` or
/// `T_v/T_ar` at or below zero (or NaN/inf) means the counters were empty
/// or nonsense; flooring instead of panicking keeps Eq. 2 total while
/// making degenerate inputs yield an (obviously huge but finite) speedup
/// rather than a division by zero.
pub const MIN_COST_RATIO: f64 = 1e-6;

/// Clamp an accept-rate estimate into `[0, 1]`; NaN reads as 0.
fn sanitize_rate(r: f64) -> f64 {
    if r.is_nan() {
        return 0.0;
    }
    r.clamp(0.0, 1.0)
}

/// Floor a cost ratio at [`MIN_COST_RATIO`]; NaN/inf/non-positive read as
/// the floor.  (`f64::clamp` propagates NaN, so the finite check is
/// explicit.)
fn sanitize_ratio(v: f64) -> f64 {
    if v.is_finite() && v > MIN_COST_RATIO {
        v
    } else {
        MIN_COST_RATIO
    }
}

/// Eq. 1: expected accept length `L_a = (1 - r^(L+1)) / (1 - r)` for draft
/// length `L` and per-token accept rate `r`.
///
/// Total over all inputs: `r` is sanitized per the module docs, and
/// `draft_len == 0` is meaningful (speculation disabled — only the bonus
/// token survives, `L_a = 1`).
pub fn expected_accept_length(r: f64, draft_len: usize) -> f64 {
    let r = sanitize_rate(r);
    if (1.0 - r).abs() < 1e-12 {
        return draft_len as f64 + 1.0;
    }
    (1.0 - r.powi(draft_len as i32 + 1)) / (1.0 - r)
}

/// Eq. 2: speedup over autoregressive decoding,
/// `L_a * T_ar / (L * T_d + T_v)`.
///
/// `td_ratio` is `T_d / T_ar` (draft step cost relative to an
/// autoregressive step) and `tv_ratio` is `T_v / T_ar` (one parallel
/// verification pass relative to an autoregressive step).
///
/// Total over all inputs: ratios are floored at [`MIN_COST_RATIO`], `r` is
/// sanitized, and `draft_len == 0` degenerates to `1 / tv_ratio` (pure
/// verify-driven decoding).
pub fn theoretical_speedup(r: f64, draft_len: usize, td_ratio: f64, tv_ratio: f64) -> f64 {
    let la = expected_accept_length(r, draft_len);
    la / (draft_len as f64 * sanitize_ratio(td_ratio) + sanitize_ratio(tv_ratio))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_length_limits() {
        // r = 0: only the bonus token survives each pass.
        assert!((expected_accept_length(0.0, 16) - 1.0).abs() < 1e-12);
        // r = 1: every draft accepted, plus the bonus.
        assert!((expected_accept_length(1.0, 16) - 17.0).abs() < 1e-12);
        // Monotone in r.
        let mut prev = 0.0;
        for i in 0..=10 {
            let la = expected_accept_length(i as f64 / 10.0, 8);
            assert!(la >= prev);
            prev = la;
        }
    }

    #[test]
    fn geometric_series_identity() {
        // L_a = sum_{i=0..L} r^i.
        let (r, l): (f64, usize) = (0.9, 6);
        let direct: f64 = (0..=l).map(|i| r.powi(i as i32)).sum();
        assert!((expected_accept_length(r, l) - direct).abs() < 1e-12);
    }

    #[test]
    fn paper_scale_speedup() {
        // Paper's operating point: r ~ 0.976, L = 16, quantize-mode draft
        // ~3.2x cheaper than an AR step, verify ~ one AR step (parallel,
        // weight-bound). The model should land near the reported ~2.1x.
        let s = theoretical_speedup(0.976, 16, 1.0 / 3.2, 1.0);
        assert!(s > 1.8 && s < 2.6, "speedup {s}");
    }

    #[test]
    fn speedup_degrades_with_slow_draft() {
        let fast = theoretical_speedup(0.95, 8, 0.2, 1.0);
        let slow = theoretical_speedup(0.95, 8, 0.9, 1.0);
        assert!(fast > slow);
    }

    #[test]
    fn total_over_nan_and_out_of_range_rates() {
        // NaN (a 0/0 estimator cold start) reads as r = 0.
        let nan = expected_accept_length(f64::NAN, 16);
        assert!(nan.is_finite());
        assert!((nan - 1.0).abs() < 1e-12);
        // ε-out-of-range values clamp rather than panic.
        assert!((expected_accept_length(1.0 + 1e-9, 8) - 9.0).abs() < 1e-12);
        assert!((expected_accept_length(-1e-9, 8) - 1.0).abs() < 1e-12);
        assert!((expected_accept_length(f64::INFINITY, 8) - 9.0).abs() < 1e-12);
        let s = theoretical_speedup(f64::NAN, 16, 0.27, 1.0);
        assert!(s.is_finite() && s >= 0.0, "speedup {s}");
    }

    #[test]
    fn total_over_degenerate_cost_ratios() {
        // Empty traffic counters produce 0/0 = NaN or 0.0 ratios; the
        // model floors them and stays finite.
        for &(td, tv) in &[
            (0.0, 0.0),
            (f64::NAN, 1.0),
            (0.27, f64::NAN),
            (-1.0, 1.0),
            (f64::INFINITY, f64::INFINITY),
        ] {
            let s = theoretical_speedup(0.8, 8, td, tv);
            assert!(s.is_finite() && s >= 0.0, "td={td} tv={tv} -> {s}");
        }
    }

    #[test]
    fn zero_draft_len_means_speculation_disabled() {
        // L = 0 is the batch policy's "disable" setting: one verify pass
        // scoring only the carry token yields exactly the bonus token.
        assert!((expected_accept_length(0.9, 0) - 1.0).abs() < 1e-12);
        let s = theoretical_speedup(0.9, 0, 0.27, 1.0);
        assert!((s - 1.0).abs() < 1e-12, "L=0 speedup should be 1/tv, got {s}");
    }
}
