//! Speculative decoding engine — the paper's decoding loop (§II-B, §III-C).
//!
//! The draft model is the BSFP 4-bit view of the target's own weights; the
//! target verifies up to `max_draft` tokens in one parallel pass.  Both
//! passes share a single KV cache (state buffer), with verification
//! overwriting the draft's quantized-pass KV — zero memory overhead.
//!
//! * [`engine`] — the single-sequence generate loop: draft (with §III-C
//!   early exit), verify, accept; plus the plain autoregressive baseline.
//! * [`batch`] — the same loop decomposed into resumable per-request state
//!   machines ([`SpecSession`] / [`ArSession`]) stepped in lockstep by
//!   [`BatchEngine`] over the backend's batched ops — the continuous
//!   batching substrate of the serving scheduler.
//! * [`accept`] — acceptance rules: greedy longest-prefix and Leviathan
//!   speculative sampling (lossless for temperature > 0).
//! * [`trace`] — per-iteration records consumed by the accelerator
//!   simulator and the report harness.
//! * [`theory`] — the paper's Eq. 1 (expected accept length) and Eq. 2
//!   (speedup), validated against simulation in experiment E10.  Total
//!   over NaN/out-of-range inputs so live estimators can call it.
//! * [`adaptive`] — per-sequence adaptive draft-length controller: an EWMA
//!   accept-rate estimate driven by verify outcomes, with the §III-C
//!   censoring correction (an early-exited or rejected chain yields
//!   `accepted` success trials plus at most one failure — the untested
//!   tail is censored, not counted), maximizing Eq. 2 over the draft
//!   budget each iteration; plus the coordinator's batch-occupancy policy.
//!
//! Adaptation is opt-in (`SpecConfig::adaptive.enabled`); with it off the
//! decode path is bit-identical to the static engine (pinned by goldens).

mod accept;
mod adaptive;
mod batch;
mod engine;
mod theory;
mod trace;

pub use accept::{greedy_accept, speculative_sample_accept, AcceptOutcome};
pub use adaptive::{
    AdaptiveConfig, AdaptiveController, BatchSpecPolicy, CostRatios, FALLBACK_TD_RATIO,
    FALLBACK_TV_RATIO,
};
pub use batch::{
    ArSession, BatchEngine, GenSession, PhaseSeconds, SpecSession, StepFailure, StepReport,
};
pub use engine::{Engine, GenResult, SpecConfig};
pub use theory::{expected_accept_length, theoretical_speedup, MIN_COST_RATIO};
pub use trace::{IterRecord, SpecTrace};
