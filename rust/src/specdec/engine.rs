//! The SPEQ generation engine: draft -> verify -> accept, with early exit.
//!
//! The engine is generic over the execution backend: it drives any
//! [`Backend`] (native interpreter or PJRT) through the five request-path
//! operations and threads the opaque state between them.

use std::time::{Duration, Instant};

use anyhow::Result;

use super::accept::{greedy_accept, speculative_sample_accept};
use super::adaptive::{AdaptiveConfig, AdaptiveController, CostRatios};
use super::trace::{IterRecord, SpecTrace};
use crate::model::{argmax, sample_from_logits, softmax, softmax_top, SamplingParams};
use crate::runtime::Backend;
use crate::util::rng::Rng;

/// Speculative decoding hyperparameters (paper defaults: L = 16, γ = 0.6).
#[derive(Debug, Clone, Copy)]
pub struct SpecConfig {
    /// Maximum draft length L per iteration (must be < model slots).
    /// With adaptation enabled this is the controller's ceiling.
    pub max_draft: usize,
    /// §III-C early-exit threshold γ: stop drafting when the draft's top
    /// probability falls below γ.
    pub gamma: f32,
    pub sampling: SamplingParams,
    /// Tokens to generate.
    pub gen_len: usize,
    /// Per-sequence adaptive draft-length control (off by default; the
    /// static path is bit-identical to the pre-controller engine).
    pub adaptive: AdaptiveConfig,
}

impl Default for SpecConfig {
    fn default() -> Self {
        Self {
            max_draft: 16,
            gamma: 0.6,
            sampling: SamplingParams::greedy(),
            gen_len: 256,
            adaptive: AdaptiveConfig::default(),
        }
    }
}

/// One finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub tokens: Vec<u8>,
    pub trace: SpecTrace,
    pub wall: Duration,
}

/// Clip `prompt` to the backend's prefill window and right-pad; returns the
/// padded token ids plus the real (masked) length.  Shared by the
/// single-sequence [`Engine`] and the batched session state machines.
pub(crate) fn pad_prompt(backend: &dyn Backend, prompt: &[u8]) -> (Vec<i32>, usize) {
    let p = backend.prefill_len();
    let len = prompt.len().min(p);
    let mut toks: Vec<i32> = prompt[prompt.len() - len..].iter().map(|&b| b as i32).collect();
    while toks.len() < p {
        toks.push(b' ' as i32);
    }
    // Left-pad semantics are handled by the caller (prompts are already
    // fixed length); here we right-pad and mask by `len`.
    (toks, len)
}

/// Maximum generable tokens given the KV cache capacity.
///
/// Errors when the cache cannot even hold one verification window past
/// the prompt (`cache_len < prompt_len + slots + 1`) instead of
/// underflowing.
pub(crate) fn capacity(backend: &dyn Backend, prompt_len: usize) -> Result<usize> {
    let need = prompt_len + backend.slots() + 1;
    backend.cache_len().checked_sub(need).ok_or_else(|| {
        anyhow::anyhow!(
            "KV cache too small: cache_len {} < prompt {} + slots {} + 1",
            backend.cache_len(),
            prompt_len,
            backend.slots()
        )
    })
}

/// The engine borrows a loaded backend; it owns no state between calls.
pub struct Engine<'m> {
    backend: &'m dyn Backend,
}

impl<'m> Engine<'m> {
    pub fn new(backend: &'m dyn Backend) -> Self {
        Self { backend }
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend
    }

    fn pad_prompt(&self, prompt: &[u8]) -> (Vec<i32>, usize) {
        pad_prompt(self.backend, prompt)
    }

    fn capacity(&self, prompt_len: usize) -> Result<usize> {
        capacity(self.backend, prompt_len)
    }

    /// Plain autoregressive decoding with the full-precision pass — the
    /// lossless baseline (and the FP16 reference for speedup measurements).
    pub fn generate_ar(
        &self,
        prompt: &[u8],
        gen_len: usize,
        sampling: SamplingParams,
    ) -> Result<GenResult> {
        let t0 = Instant::now();
        let (toks, plen) = self.pad_prompt(prompt);
        let gen_len = gen_len.min(self.capacity(plen)?);
        let mut trace = SpecTrace { iterations: vec![], produced: 0, prompt_len: plen };
        if gen_len == 0 {
            return Ok(GenResult { tokens: vec![], trace, wall: t0.elapsed() });
        }
        let mut rng = Rng::seed_from_u64(sampling.seed);
        let pre = {
            let _span = crate::trace::span("engine", "prefill", &[("n", 1.0)]);
            self.backend.prefill(&toks, plen)?
        };
        let mut state = pre.state;
        let (mut tok, _) = sample_from_logits(&pre.logits, &sampling, &mut rng);
        let mut out = vec![tok as u8];
        let mut pos = plen;
        while out.len() < gen_len {
            let step = {
                let _span = crate::trace::span("engine", "ar_decode", &[("n", 1.0)]);
                self.backend.decode_full(tok as i32, pos, state)?
            };
            state = step.state;
            let (t, _) = sample_from_logits(&step.logits, &sampling, &mut rng);
            tok = t;
            out.push(tok as u8);
            pos += 1;
        }
        // Report what was actually emitted (capacity may clamp `gen_len`).
        trace.produced = out.len();
        Ok(GenResult { tokens: out, trace, wall: t0.elapsed() })
    }

    /// SPEQ speculative decoding: BSFP draft + parallel verification.
    pub fn generate_spec(&self, prompt: &[u8], cfg: &SpecConfig) -> Result<GenResult> {
        let t0 = Instant::now();
        let slots = self.backend.slots();
        anyhow::ensure!(
            cfg.max_draft + 1 <= slots,
            "max_draft {} exceeds graph slots {} - 1",
            cfg.max_draft,
            slots
        );
        anyhow::ensure!(cfg.max_draft >= 1, "max_draft must be >= 1");
        let (toks, plen) = self.pad_prompt(prompt);
        let gen_len = cfg.gen_len.min(self.capacity(plen)?);
        let vocab = self.backend.vocab();
        let mut trace = SpecTrace { iterations: vec![], produced: 0, prompt_len: plen };
        if gen_len == 0 {
            return Ok(GenResult { tokens: vec![], trace, wall: t0.elapsed() });
        }
        let mut rng = Rng::seed_from_u64(cfg.sampling.seed);
        // Cost ratios for the controller come from whatever traffic the
        // backend has already metered (fallback constants when none);
        // sampled once so the budget picker stays a pure function of the
        // verify outcomes.
        let ratios = CostRatios::from_traffic(&self.backend.traffic(), slots);
        let mut ctrl =
            if cfg.adaptive.enabled { Some(AdaptiveController::new(cfg.adaptive)) } else { None };

        let pre = {
            let _span = crate::trace::span("engine", "prefill", &[("n", 1.0)]);
            self.backend.prefill(&toks, plen)?
        };
        let mut state = pre.state;
        // The carry token: sampled from the target's prefill logits, not yet
        // fed through the model.
        let (mut carry, _) = sample_from_logits(&pre.logits, &cfg.sampling, &mut rng);
        let mut out = vec![carry as u8];
        let mut pos0 = plen; // carry token's position

        while out.len() < gen_len {
            // ---- draft phase (quantized pass, shared KV) ----
            let ceiling = match &ctrl {
                Some(c) => c.pick_budget(cfg.max_draft, &ratios),
                None => cfg.max_draft,
            };
            let budget = ceiling.min(gen_len - out.len());
            let mut drafts: Vec<usize> = Vec::with_capacity(budget);
            let mut draft_probs: Vec<Vec<f32>> = Vec::with_capacity(budget);
            let mut early_exit = false;
            let mut tok = carry;
            let draft_span = crate::trace::span("engine", "draft", &[("n", 1.0)]);
            for i in 0..budget {
                let step = self.backend.decode_draft(tok as i32, pos0 + i, state)?;
                state = step.state;
                let (d, top) = if cfg.sampling.is_greedy() {
                    // Greedy never reads the distribution (greedy_accept
                    // re-derives argmax from the verify logits), so skip
                    // the full-vocab softmax Vec: `softmax_top` is bitwise
                    // the same max probability, allocation-free.
                    (argmax(&step.logits), softmax_top(&step.logits))
                } else {
                    let probs = softmax(
                        &step
                            .logits
                            .iter()
                            .map(|&v| v / cfg.sampling.temperature)
                            .collect::<Vec<_>>(),
                    );
                    let (d, _) = sample_from_logits(&step.logits, &cfg.sampling, &mut rng);
                    let top = probs.iter().fold(0.0f32, |m, &p| m.max(p));
                    draft_probs.push(probs);
                    (d, top)
                };
                drafts.push(d);
                tok = d;
                // §III-C: if the draft is not confident, verification will
                // likely reject — stop drafting.
                if top < cfg.gamma && i + 1 < budget {
                    early_exit = true;
                    break;
                }
            }
            drop(draft_span);

            // ---- verification (one parallel full-precision pass) ----
            let mut vtokens: Vec<i32> = Vec::with_capacity(slots);
            vtokens.push(carry as i32);
            vtokens.extend(drafts.iter().map(|&d| d as i32));
            while vtokens.len() < slots {
                vtokens.push(0);
            }
            let ver = {
                let _span = crate::trace::span("engine", "verify", &[("n", 1.0)]);
                self.backend.verify(&vtokens, pos0, state)?
            };
            state = ver.state;

            let outcome = if cfg.sampling.is_greedy() {
                greedy_accept(&drafts, &ver.logits, vocab)
            } else {
                let rows: Vec<Vec<f32>> = (0..=drafts.len())
                    .map(|i| {
                        softmax(
                            &ver.logits[i * vocab..(i + 1) * vocab]
                                .iter()
                                .map(|&v| v / cfg.sampling.temperature)
                                .collect::<Vec<_>>(),
                        )
                    })
                    .collect();
                speculative_sample_accept(&drafts, &draft_probs, &rows, &mut rng)
            };

            trace.iterations.push(IterRecord {
                drafted: drafts.len() as u32,
                accepted: outcome.accepted as u32,
                early_exit,
            });
            crate::trace::instant(
                "spec",
                "iter",
                &[
                    ("drafted", drafts.len() as f64),
                    ("accepted", outcome.accepted as f64),
                    ("early_exit", if early_exit { 1.0 } else { 0.0 }),
                ],
            );
            if let Some(c) = &mut ctrl {
                c.observe(drafts.len(), outcome.accepted);
            }

            // Emit accepted drafts + the bonus/correction token.
            for &d in &drafts[..outcome.accepted] {
                out.push(d as u8);
            }
            out.push(outcome.next_token as u8);
            pos0 += outcome.accepted + 1;
            carry = outcome.next_token;
        }

        out.truncate(gen_len);
        trace.produced = out.len();
        Ok(GenResult { tokens: out, trace, wall: t0.elapsed() })
    }
}
