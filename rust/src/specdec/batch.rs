//! Step-driven speculative decoding for continuous batching.
//!
//! [`Engine::generate_spec`] runs one request to completion inside one call,
//! which forces worker-per-request serving.  This module decomposes that
//! monolithic loop into a resumable per-request state machine
//! ([`SpecSession`]: prefill → draft → verify → … → done, plus the
//! autoregressive [`ArSession`] baseline) and a [`BatchEngine`] that steps a
//! set of sessions in lockstep over the backend's batched operations.  The
//! serving scheduler admits new sessions between steps (continuous
//! batching) and drains incremental token chunks after every step.
//!
//! Determinism contract: a session performs exactly the same backend
//! operations, in the same order, with the same per-request RNG as the
//! monolithic loop — and the backend's batched ops are bit-identical per
//! sequence to the single-sequence ops — so batched greedy decoding is
//! bit-identical to N sequential `generate_spec` runs regardless of batch
//! composition, per-sequence early exit, unequal accept lengths, or
//! mid-batch completion (asserted by `rust/tests/integration_batch.rs`).
//!
//! [`Engine::generate_spec`]: super::Engine::generate_spec

use std::time::{Duration, Instant};

use anyhow::Result;

use super::accept::{greedy_accept, speculative_sample_accept};
use super::adaptive::{AdaptiveController, CostRatios};
use super::engine::{capacity, pad_prompt};
use super::trace::{IterRecord, SpecTrace};
use super::{GenResult, SpecConfig};
use crate::model::{argmax, sample_from_logits, softmax, softmax_top, SamplingParams};
use crate::runtime::{Backend, SeqSlot};
use crate::util::rng::Rng;

/// Decode steps an autoregressive session takes per engine step, so AR
/// baselines keep pace with speculative sessions in a mixed batch (a spec
/// iteration emits several tokens per step).
const AR_BURST: usize = 8;

/// Wall seconds a session has spent inside batched engine ops, by phase.
///
/// [`BatchEngine::step_report`] times every batched op and charges each
/// participating session the op's **full** wall duration — the session
/// was blocked on the op either way, so the sum over phases (plus queue
/// wait and out-of-op stall, computed by the scheduler at completion) is
/// exactly the request's latency.  AR sessions charge their
/// full-precision decode burst to `verify_s` (the same pass kind as
/// verification; they never draft).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseSeconds {
    pub prefill_s: f64,
    pub draft_s: f64,
    pub verify_s: f64,
}

impl PhaseSeconds {
    /// Total attributed in-op time.
    pub fn total(&self) -> f64 {
        self.prefill_s + self.draft_s + self.verify_s
    }
}

/// Where a speculative session is in its draft → verify cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SpecPhase {
    /// Waiting for its prefill pass.
    Prefill,
    /// Drafting with the quantized pass (one token per engine sub-step).
    Draft,
    /// Draft chain complete; waiting for the verification pass.
    Verify,
    /// Generation finished.
    Done,
}

/// Resumable per-request speculative decoding state machine.
///
/// Mirrors `Engine::generate_spec` exactly, but yields control to the
/// [`BatchEngine`] at every backend operation so many sessions can share
/// each weight stream.
pub struct SpecSession {
    cfg: SpecConfig,
    slot: SeqSlot,
    slot_released: bool,
    rng: Rng,
    phase: SpecPhase,
    prompt_tokens: Vec<i32>,
    prompt_len: usize,
    /// Requested length clamped to the KV-cache capacity.
    gen_len: usize,
    out: Vec<u8>,
    /// Streaming watermark: `out[..emitted]` has been handed to the caller.
    emitted: usize,
    trace: SpecTrace,
    /// Position of the carry token (first unverified position).
    pos0: usize,
    /// Token sampled from the target but not yet fed through the model.
    carry: usize,
    drafts: Vec<usize>,
    draft_probs: Vec<Vec<f32>>,
    budget: usize,
    early_exit: bool,
    /// Next token to feed the draft pass.
    draft_tok: usize,
    /// Adaptive draft-length controller (`None` = static `max_draft`).
    adaptive: Option<AdaptiveController>,
    /// Draft/verify cost ratios for the controller, sampled once at
    /// session creation (the scheduler drains traffic every step, so the
    /// live counter is not a usable per-step source).
    ratios: CostRatios,
    started: Instant,
    wall: Duration,
    /// Batched-op time attribution (charged by the engine each step).
    phases: PhaseSeconds,
}

impl SpecSession {
    /// Create a session and lease its KV slot.  Validates the config the
    /// same way `generate_spec` does.
    pub fn new(backend: &dyn Backend, prompt: &[u8], cfg: SpecConfig) -> Result<Self> {
        let slots = backend.slots();
        anyhow::ensure!(
            cfg.max_draft + 1 <= slots,
            "max_draft {} exceeds graph slots {} - 1",
            cfg.max_draft,
            slots
        );
        anyhow::ensure!(cfg.max_draft >= 1, "max_draft must be >= 1");
        let (prompt_tokens, prompt_len) = pad_prompt(backend, prompt);
        let gen_len = cfg.gen_len.min(capacity(backend, prompt_len)?);
        let rng = Rng::seed_from_u64(cfg.sampling.seed);
        let mut s = Self {
            cfg,
            slot: backend.alloc_slot(),
            slot_released: false,
            rng,
            phase: SpecPhase::Prefill,
            prompt_tokens,
            prompt_len,
            gen_len,
            out: Vec::new(),
            emitted: 0,
            trace: SpecTrace { iterations: vec![], produced: 0, prompt_len },
            pos0: 0,
            carry: 0,
            drafts: Vec::new(),
            draft_probs: Vec::new(),
            budget: 0,
            early_exit: false,
            draft_tok: 0,
            adaptive: if cfg.adaptive.enabled {
                Some(AdaptiveController::new(cfg.adaptive))
            } else {
                None
            },
            ratios: CostRatios::from_traffic(&backend.traffic(), slots),
            started: Instant::now(),
            wall: Duration::ZERO,
            phases: PhaseSeconds::default(),
        };
        if s.gen_len == 0 {
            s.finish();
        }
        Ok(s)
    }

    fn finish(&mut self) {
        self.out.truncate(self.gen_len);
        self.trace.produced = self.out.len();
        self.wall = self.started.elapsed();
        self.phase = SpecPhase::Done;
    }

    /// Start the next draft → verify iteration (or finish).
    fn begin_iteration(&mut self) {
        if self.out.len() >= self.gen_len {
            self.finish();
            return;
        }
        let ceiling = match &self.adaptive {
            Some(c) => c.pick_budget(self.cfg.max_draft, &self.ratios),
            None => self.cfg.max_draft,
        };
        self.budget = ceiling.min(self.gen_len - self.out.len());
        self.drafts.clear();
        self.draft_probs.clear();
        self.early_exit = false;
        self.draft_tok = self.carry;
        // A zero budget (batch policy: speculation disabled) skips the
        // draft phase entirely — `on_draft` is the only Draft → Verify
        // transition, so entering Draft with nothing to draft would hang
        // the batch loop.  The verify pass then scores only the carry
        // token: autoregression expressed through the verify graph.
        self.phase =
            if self.budget == 0 { SpecPhase::Verify } else { SpecPhase::Draft };
    }

    fn on_prefill(&mut self, logits: &[f32]) {
        let (carry, _) = sample_from_logits(logits, &self.cfg.sampling, &mut self.rng);
        self.carry = carry;
        self.out.push(carry as u8);
        self.pos0 = self.prompt_len;
        self.begin_iteration();
    }

    /// The draft step this session wants next: `(token, position)`.
    fn draft_input(&self) -> (i32, usize) {
        (self.draft_tok as i32, self.pos0 + self.drafts.len())
    }

    fn on_draft(&mut self, logits: &[f32]) {
        let (d, top) = if self.cfg.sampling.is_greedy() {
            // Greedy acceptance never reads the draft distribution
            // (`greedy_accept` re-derives argmax from the verify logits),
            // so don't allocate or retain a full-vocab softmax Vec per
            // draft token: `softmax_top` yields bitwise the same max
            // probability for the γ check, allocation-free.
            (argmax(logits), softmax_top(logits))
        } else {
            let probs = softmax(
                &logits
                    .iter()
                    .map(|&v| v / self.cfg.sampling.temperature)
                    .collect::<Vec<_>>(),
            );
            let (d, _) = sample_from_logits(logits, &self.cfg.sampling, &mut self.rng);
            let top = probs.iter().fold(0.0f32, |m, &p| m.max(p));
            self.draft_probs.push(probs);
            (d, top)
        };
        self.drafts.push(d);
        self.draft_tok = d;
        if self.drafts.len() == self.budget {
            // Budget exhausted: a full-length draft is not an early exit.
            self.phase = SpecPhase::Verify;
        } else if top < self.cfg.gamma {
            // §III-C: if the draft is not confident, verification will
            // likely reject — stop drafting.
            self.early_exit = true;
            self.phase = SpecPhase::Verify;
        }
    }

    /// The verification window: carry + drafts, zero-padded to `slots`.
    fn verify_tokens(&self, slots: usize) -> Vec<i32> {
        let mut vtokens: Vec<i32> = Vec::with_capacity(slots);
        vtokens.push(self.carry as i32);
        vtokens.extend(self.drafts.iter().map(|&d| d as i32));
        while vtokens.len() < slots {
            vtokens.push(0);
        }
        vtokens
    }

    fn on_verify(&mut self, ver_logits: &[f32], vocab: usize) {
        let outcome = if self.cfg.sampling.is_greedy() {
            greedy_accept(&self.drafts, ver_logits, vocab)
        } else {
            let rows: Vec<Vec<f32>> = (0..=self.drafts.len())
                .map(|i| {
                    softmax(
                        &ver_logits[i * vocab..(i + 1) * vocab]
                            .iter()
                            .map(|&v| v / self.cfg.sampling.temperature)
                            .collect::<Vec<_>>(),
                    )
                })
                .collect();
            speculative_sample_accept(&self.drafts, &self.draft_probs, &rows, &mut self.rng)
        };
        self.trace.iterations.push(IterRecord {
            drafted: self.drafts.len() as u32,
            accepted: outcome.accepted as u32,
            early_exit: self.early_exit,
        });
        crate::trace::instant(
            "spec",
            "iter",
            &[
                ("drafted", self.drafts.len() as f64),
                ("accepted", outcome.accepted as f64),
                ("early_exit", if self.early_exit { 1.0 } else { 0.0 }),
            ],
        );
        if let Some(c) = &mut self.adaptive {
            c.observe(self.drafts.len(), outcome.accepted);
        }
        // Emit accepted drafts + the bonus/correction token.
        for &d in &self.drafts[..outcome.accepted] {
            self.out.push(d as u8);
        }
        self.out.push(outcome.next_token as u8);
        self.pos0 += outcome.accepted + 1;
        self.carry = outcome.next_token;
        self.begin_iteration();
    }
}

/// Resumable per-request autoregressive state machine (the lossless
/// full-precision baseline, batched).
pub struct ArSession {
    sampling: SamplingParams,
    slot: SeqSlot,
    slot_released: bool,
    rng: Rng,
    done: bool,
    prefilled: bool,
    prompt_tokens: Vec<i32>,
    prompt_len: usize,
    gen_len: usize,
    out: Vec<u8>,
    emitted: usize,
    trace: SpecTrace,
    pos: usize,
    tok: usize,
    started: Instant,
    wall: Duration,
    /// Batched-op time attribution (charged by the engine each step).
    phases: PhaseSeconds,
}

impl ArSession {
    pub fn new(
        backend: &dyn Backend,
        prompt: &[u8],
        gen_len: usize,
        sampling: SamplingParams,
    ) -> Result<Self> {
        let (prompt_tokens, prompt_len) = pad_prompt(backend, prompt);
        let gen_len = gen_len.min(capacity(backend, prompt_len)?);
        let mut s = Self {
            sampling,
            slot: backend.alloc_slot(),
            slot_released: false,
            rng: Rng::seed_from_u64(sampling.seed),
            done: false,
            prefilled: false,
            prompt_tokens,
            prompt_len,
            gen_len,
            out: Vec::new(),
            emitted: 0,
            trace: SpecTrace { iterations: vec![], produced: 0, prompt_len },
            pos: 0,
            tok: 0,
            started: Instant::now(),
            wall: Duration::ZERO,
            phases: PhaseSeconds::default(),
        };
        if s.gen_len == 0 {
            s.finish();
        }
        Ok(s)
    }

    fn finish(&mut self) {
        self.trace.produced = self.out.len();
        self.wall = self.started.elapsed();
        self.done = true;
    }

    fn on_prefill(&mut self, logits: &[f32]) {
        let (tok, _) = sample_from_logits(logits, &self.sampling, &mut self.rng);
        self.tok = tok;
        self.out.push(tok as u8);
        self.pos = self.prompt_len;
        self.prefilled = true;
        if self.out.len() >= self.gen_len {
            self.finish();
        }
    }

    fn on_decode(&mut self, logits: &[f32]) {
        let (tok, _) = sample_from_logits(logits, &self.sampling, &mut self.rng);
        self.tok = tok;
        self.out.push(tok as u8);
        self.pos += 1;
        if self.out.len() >= self.gen_len {
            self.finish();
        }
    }
}

/// One in-flight generation of either mode, as scheduled by the
/// [`BatchEngine`].
pub enum GenSession {
    Spec(SpecSession),
    Ar(ArSession),
}

impl GenSession {
    pub fn slot(&self) -> SeqSlot {
        match self {
            GenSession::Spec(s) => s.slot,
            GenSession::Ar(s) => s.slot,
        }
    }

    pub fn is_done(&self) -> bool {
        match self {
            GenSession::Spec(s) => s.phase == SpecPhase::Done,
            GenSession::Ar(s) => s.done,
        }
    }

    /// Tokens produced since the last call (for streaming responses).
    /// Never returns bytes past the clamped generation length.
    pub fn take_new_tokens(&mut self) -> Vec<u8> {
        let (out, emitted, gen_len) = match self {
            GenSession::Spec(s) => (&s.out, &mut s.emitted, s.gen_len),
            GenSession::Ar(s) => (&s.out, &mut s.emitted, s.gen_len),
        };
        let hi = out.len().min(gen_len);
        let chunk = out[*emitted..hi].to_vec();
        *emitted = hi;
        chunk
    }

    /// Apply the batch-level speculation policy's draft cap for upcoming
    /// iterations.  Only adaptive speculative sessions respond; static and
    /// AR sessions are untouched (their decode path must stay bit-identical
    /// to the policy-free engine).
    pub fn apply_spec_policy(&mut self, cap: usize) {
        if let GenSession::Spec(s) = self {
            if let Some(c) = &mut s.adaptive {
                c.set_policy_cap(cap);
            }
        }
    }

    /// Per-phase batched-op time charged to this session so far (see
    /// [`PhaseSeconds`]).
    pub fn phase_seconds(&self) -> PhaseSeconds {
        match self {
            GenSession::Spec(s) => s.phases,
            GenSession::Ar(s) => s.phases,
        }
    }

    fn phases_mut(&mut self) -> &mut PhaseSeconds {
        match self {
            GenSession::Spec(s) => &mut s.phases,
            GenSession::Ar(s) => &mut s.phases,
        }
    }

    /// Live controller state for metrics: `(current draft budget,
    /// accept-rate estimate)`.  `None` for AR and non-adaptive sessions.
    pub fn adaptive_state(&self) -> Option<(usize, f64)> {
        match self {
            GenSession::Spec(s) => {
                s.adaptive.as_ref().map(|c| (s.budget, c.accept_rate()))
            }
            GenSession::Ar(_) => None,
        }
    }

    /// Release the session's KV slot (idempotent; called by the engine on
    /// completion and by the scheduler on error paths).
    pub fn release(&mut self, backend: &dyn Backend) {
        let (slot, released) = match self {
            GenSession::Spec(s) => (s.slot, &mut s.slot_released),
            GenSession::Ar(s) => (s.slot, &mut s.slot_released),
        };
        if !*released {
            backend.free_slot(slot);
            *released = true;
        }
    }

    /// The finished generation.  Call only when [`GenSession::is_done`].
    pub fn into_result(self) -> GenResult {
        match self {
            GenSession::Spec(s) => GenResult { tokens: s.out, trace: s.trace, wall: s.wall },
            GenSession::Ar(s) => GenResult { tokens: s.out, trace: s.trace, wall: s.wall },
        }
    }
}

/// Steps a set of [`GenSession`]s in lockstep over a backend's batched
/// operations.  One [`BatchEngine::step`] advances every active session by
/// one draft → verify iteration (speculative) or up to [`AR_BURST`] decode
/// steps (autoregressive); the caller admits/retires sessions between
/// steps.
pub struct BatchEngine<'m> {
    backend: &'m dyn Backend,
}

/// Per-session outcome of one engine step ([`BatchEngine::step_report`]):
/// the sessions touched by a failing batched op, with a typed
/// [`FailureKind`] and detail message each.  An empty report is a fully
/// successful step.  Reported sessions are *poisoned* — their KV slot
/// state is unspecified (the failing op may have partially written it) —
/// so the caller must retire them (release the slot, answer the request)
/// and must not step them again; every other session was untouched by the
/// failure and continues bit-identically.
///
/// [`FailureKind`]: crate::faults::FailureKind
#[derive(Debug, Default)]
pub struct StepReport {
    pub failures: Vec<StepFailure>,
}

/// One poisoned session from a failed batched op.
#[derive(Debug)]
pub struct StepFailure {
    /// Index into the `sessions` slice passed to the step.
    pub session: usize,
    pub kind: crate::faults::FailureKind,
    pub detail: String,
}

/// Run one batched op behind a fault probe and a panic trap.  Returns the
/// op's rows, or the typed failure shared by every session in the op.
/// Panics (a kernel worker shard, an injected `panic` action) are caught
/// here so one poisoned op cannot take down the scheduler thread; the
/// backend's error contract already guarantees arena consistency on both
/// unwind (taken states drop, releasing their pages) and `Err`.
fn run_op<T>(
    site: crate::faults::FaultSite,
    op: impl FnOnce() -> Result<Vec<T>>,
) -> std::result::Result<Vec<T>, (crate::faults::FailureKind, String)> {
    use crate::faults::{FailureKind, FaultAction};
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let mut injected = None;
    if crate::faults::enabled() {
        injected = crate::faults::hit(site);
        if let Some(FaultAction::Stall(ms)) = injected {
            // An armed stall delays the op (watchdog fodder) but does not
            // fail it.
            std::thread::sleep(std::time::Duration::from_millis(ms));
            injected = None;
        }
    }
    let result = catch_unwind(AssertUnwindSafe(|| {
        match injected {
            Some(FaultAction::Panic) => panic!("injected fault at {}", site.name()),
            Some(FaultAction::Error) => {
                anyhow::bail!("injected fault at {} (step error)", site.name())
            }
            _ => {}
        }
        op()
    }));
    match result {
        Ok(Ok(rows)) => Ok(rows),
        Ok(Err(e)) => {
            // The vendored anyhow shim flattens source chains to strings
            // at `?`-conversion (no downcast), so a typed `PageExhausted`
            // is recognized by its stable Display prefix anywhere in the
            // chain.
            let exhausted = e.chain().any(|c| c.starts_with("kv page budget exhausted"));
            let kind =
                if exhausted { FailureKind::PageExhausted } else { FailureKind::StepError };
            Err((kind, format!("{e:#}")))
        }
        Err(panic) => {
            let msg = if let Some(s) = panic.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = panic.downcast_ref::<String>() {
                s.clone()
            } else {
                "opaque panic payload".to_string()
            };
            Err((FailureKind::WorkerPanic, format!("panic in engine step: {msg}")))
        }
    }
}

impl<'m> BatchEngine<'m> {
    pub fn new(backend: &'m dyn Backend) -> Self {
        Self { backend }
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend
    }

    /// Advance every non-done session by one engine step, aborting the
    /// whole step on the first failed batched op (the historical
    /// contract; offline drivers and tests).  The serving scheduler uses
    /// [`BatchEngine::step_report`] instead, which contains a failure to
    /// the sessions the failing op touched.
    pub fn step(&self, sessions: &mut [&mut GenSession]) -> Result<()> {
        let report = self.step_report(sessions);
        match report.failures.into_iter().next() {
            None => Ok(()),
            Some(f) => Err(anyhow::anyhow!(
                "engine step failed for session {} ({}): {}",
                f.session,
                f.kind,
                f.detail
            )),
        }
    }

    /// Advance every non-done session by one engine step, with blast-radius
    /// isolation.
    ///
    /// Phases inside a step: (1) batched prefill for newly admitted
    /// sessions, (2) batched draft decode repeated until every speculative
    /// session has closed its chain (per-sequence early exit drops
    /// finished drafters out of later sub-steps), (3) one batched
    /// verification pass, (4) a burst of batched full-precision decodes
    /// for autoregressive sessions.  Completed sessions release their KV
    /// slots.
    ///
    /// A failing (or panicking) batched op poisons exactly the sessions it
    /// was operating on — each phase's index set gives the attribution —
    /// and they are reported in the returned [`StepReport`] and excluded
    /// from the rest of the step; every other session continues through
    /// its remaining phases bit-identically to a failure-free step.
    pub fn step_report(&self, sessions: &mut [&mut GenSession]) -> StepReport {
        let backend = self.backend;
        let slots_per_state = backend.slots();
        let vocab = backend.vocab();
        let mut report = StepReport::default();
        // Sessions poisoned by a failed op this step: excluded from every
        // later phase (their KV slot state is unspecified).
        let mut poisoned = vec![false; sessions.len()];
        let poison = |report: &mut StepReport,
                          poisoned: &mut Vec<bool>,
                          members: &[usize],
                          kind: crate::faults::FailureKind,
                          detail: &str| {
            for &i in members {
                poisoned[i] = true;
                report.failures.push(StepFailure {
                    session: i,
                    kind,
                    detail: detail.to_string(),
                });
            }
        };

        // ---- phase 1: prefill newly admitted sessions ----
        let idx: Vec<usize> = (0..sessions.len())
            .filter(|&i| match &*sessions[i] {
                GenSession::Spec(s) => s.phase == SpecPhase::Prefill,
                GenSession::Ar(s) => !s.done && !s.prefilled,
            })
            .collect();
        if !idx.is_empty() {
            let slots: Vec<SeqSlot> = idx.iter().map(|&i| sessions[i].slot()).collect();
            let prompts: Vec<Vec<i32>> = idx
                .iter()
                .map(|&i| match &*sessions[i] {
                    GenSession::Spec(s) => s.prompt_tokens.clone(),
                    GenSession::Ar(s) => s.prompt_tokens.clone(),
                })
                .collect();
            let lengths: Vec<usize> = idx
                .iter()
                .map(|&i| match &*sessions[i] {
                    GenSession::Spec(s) => s.prompt_len,
                    GenSession::Ar(s) => s.prompt_len,
                })
                .collect();
            let span = crate::trace::span("engine", "prefill", &[("n", idx.len() as f64)]);
            let t0 = Instant::now();
            let res = run_op(crate::faults::FaultSite::StepPrefill, || {
                backend.prefill_batch(&slots, &prompts, &lengths)
            });
            drop(span);
            let dt = t0.elapsed().as_secs_f64();
            for &i in &idx {
                sessions[i].phases_mut().prefill_s += dt;
            }
            match res {
                Ok(logits) => {
                    for (&i, row) in idx.iter().zip(&logits) {
                        match &mut *sessions[i] {
                            GenSession::Spec(s) => s.on_prefill(row),
                            GenSession::Ar(s) => s.on_prefill(row),
                        }
                    }
                }
                Err((kind, detail)) => poison(&mut report, &mut poisoned, &idx, kind, &detail),
            }
        }

        // ---- phase 2: draft sub-steps until every chain is closed ----
        loop {
            let drafting: Vec<usize> = (0..sessions.len())
                .filter(|&i| {
                    !poisoned[i]
                        && matches!(&*sessions[i], GenSession::Spec(s) if s.phase == SpecPhase::Draft)
                })
                .collect();
            if drafting.is_empty() {
                break;
            }
            let slots: Vec<SeqSlot> = drafting.iter().map(|&i| sessions[i].slot()).collect();
            let mut tokens = Vec::with_capacity(drafting.len());
            let mut pos = Vec::with_capacity(drafting.len());
            for &i in &drafting {
                if let GenSession::Spec(s) = &*sessions[i] {
                    let (t, p) = s.draft_input();
                    tokens.push(t);
                    pos.push(p);
                }
            }
            let span = crate::trace::span("engine", "draft", &[("n", drafting.len() as f64)]);
            let t0 = Instant::now();
            let res = run_op(crate::faults::FaultSite::StepDraft, || {
                backend.decode_draft_batch(&slots, &tokens, &pos)
            });
            drop(span);
            let dt = t0.elapsed().as_secs_f64();
            for &i in &drafting {
                sessions[i].phases_mut().draft_s += dt;
            }
            match res {
                Ok(rows) => {
                    for (&i, row) in drafting.iter().zip(&rows) {
                        if let GenSession::Spec(s) = &mut *sessions[i] {
                            s.on_draft(row);
                        }
                    }
                }
                Err((kind, detail)) => {
                    // Every drafter was in the failing op; nothing is left
                    // to keep sub-stepping.
                    poison(&mut report, &mut poisoned, &drafting, kind, &detail);
                    break;
                }
            }
        }

        // ---- phase 3: one batched verification pass ----
        let verifying: Vec<usize> = (0..sessions.len())
            .filter(|&i| {
                !poisoned[i]
                    && matches!(&*sessions[i], GenSession::Spec(s) if s.phase == SpecPhase::Verify)
            })
            .collect();
        if !verifying.is_empty() {
            let slots: Vec<SeqSlot> = verifying.iter().map(|&i| sessions[i].slot()).collect();
            let mut tokens = Vec::with_capacity(verifying.len());
            let mut pos0 = Vec::with_capacity(verifying.len());
            for &i in &verifying {
                if let GenSession::Spec(s) = &*sessions[i] {
                    tokens.push(s.verify_tokens(slots_per_state));
                    pos0.push(s.pos0);
                }
            }
            let span = crate::trace::span("engine", "verify", &[("n", verifying.len() as f64)]);
            let t0 = Instant::now();
            let res = run_op(crate::faults::FaultSite::StepVerify, || {
                backend.verify_batch(&slots, &tokens, &pos0)
            });
            drop(span);
            let dt = t0.elapsed().as_secs_f64();
            for &i in &verifying {
                sessions[i].phases_mut().verify_s += dt;
            }
            match res {
                Ok(rows) => {
                    for (&i, row) in verifying.iter().zip(&rows) {
                        if let GenSession::Spec(s) = &mut *sessions[i] {
                            s.on_verify(row, vocab);
                        }
                    }
                }
                Err((kind, detail)) => {
                    poison(&mut report, &mut poisoned, &verifying, kind, &detail)
                }
            }
        }

        // ---- phase 4: autoregressive decode burst ----
        for _ in 0..AR_BURST {
            let decoding: Vec<usize> = (0..sessions.len())
                .filter(|&i| {
                    !poisoned[i]
                        && matches!(&*sessions[i], GenSession::Ar(s) if !s.done && s.prefilled)
                })
                .collect();
            if decoding.is_empty() {
                break;
            }
            let slots: Vec<SeqSlot> = decoding.iter().map(|&i| sessions[i].slot()).collect();
            let mut tokens = Vec::with_capacity(decoding.len());
            let mut pos = Vec::with_capacity(decoding.len());
            for &i in &decoding {
                if let GenSession::Ar(s) = &*sessions[i] {
                    tokens.push(s.tok as i32);
                    pos.push(s.pos);
                }
            }
            let span = crate::trace::span("engine", "ar_decode", &[("n", decoding.len() as f64)]);
            let t0 = Instant::now();
            let res = run_op(crate::faults::FaultSite::StepDecode, || {
                backend.decode_full_batch(&slots, &tokens, &pos)
            });
            drop(span);
            // AR full-precision decode charges the verify bucket (same
            // pass kind; AR sessions never draft).
            let dt = t0.elapsed().as_secs_f64();
            for &i in &decoding {
                sessions[i].phases_mut().verify_s += dt;
            }
            match res {
                Ok(rows) => {
                    for (&i, row) in decoding.iter().zip(&rows) {
                        if let GenSession::Ar(s) = &mut *sessions[i] {
                            s.on_decode(row);
                        }
                    }
                }
                Err((kind, detail)) => {
                    poison(&mut report, &mut poisoned, &decoding, kind, &detail);
                    break;
                }
            }
        }

        // ---- retire: release slots of completed sessions ----
        // Poisoned sessions keep their slots here; the caller releases
        // them when it retires the failed requests (the release is
        // idempotent either way).
        for (i, s) in sessions.iter_mut().enumerate() {
            if !poisoned[i] && s.is_done() {
                s.release(backend);
            }
        }
        report
    }

    /// Convenience driver: run a set of sessions to completion and return
    /// their results in order (tests, benches, offline batch jobs).
    pub fn run(&self, mut sessions: Vec<GenSession>) -> Result<Vec<GenResult>> {
        loop {
            let mut refs: Vec<&mut GenSession> = sessions.iter_mut().collect();
            if refs.iter().all(|s| s.is_done()) {
                break;
            }
            self.step(&mut refs)?;
        }
        Ok(sessions.into_iter().map(|s| s.into_result()).collect())
    }

    /// Convenience: batched speculative decoding of many prompts.
    pub fn run_spec(&self, requests: &[(Vec<u8>, SpecConfig)]) -> Result<Vec<GenResult>> {
        let sessions = requests
            .iter()
            .map(|(prompt, cfg)| {
                SpecSession::new(self.backend, prompt, *cfg).map(GenSession::Spec)
            })
            .collect::<Result<Vec<_>>>()?;
        self.run(sessions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::runtime::{InitStyle, NativeBackend};
    use crate::specdec::Engine;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "batch-tiny".into(),
            paper_analog: "none".into(),
            n_layers: 1,
            d_model: 64,
            d_ff: 96,
            n_heads: 2,
            head_dim: 32,
            // Full byte vocab: test prompts are ASCII strings.
            vocab: 256,
            cache_len: 128,
            prefill_len: 32,
            param_count: 0,
        }
    }

    #[test]
    fn single_session_matches_generate_spec() {
        let model = NativeBackend::synthetic(tiny_cfg(), 6, 13, InitStyle::Confident).unwrap();
        let engine = Engine::new(&model);
        let cfg = SpecConfig { gen_len: 24, max_draft: 4, ..Default::default() };
        let seq = engine.generate_spec(b"hello there", &cfg).unwrap();
        let batch = BatchEngine::new(&model);
        let results = batch.run_spec(&[(b"hello there".to_vec(), cfg)]).unwrap();
        assert_eq!(results[0].tokens, seq.tokens);
        assert_eq!(results[0].trace.iterations, seq.trace.iterations);
        assert_eq!(model.arena().in_use(), 0, "slots must be released");
    }

    #[test]
    fn zero_length_session_is_immediately_done() {
        let model = NativeBackend::synthetic(tiny_cfg(), 6, 13, InitStyle::Random).unwrap();
        let cfg = SpecConfig { gen_len: 0, max_draft: 4, ..Default::default() };
        let s = SpecSession::new(&model, b"x", cfg).unwrap();
        let mut g = GenSession::Spec(s);
        assert!(g.is_done());
        assert!(g.take_new_tokens().is_empty());
        g.release(&model);
        assert_eq!(model.arena().in_use(), 0);
        assert!(g.into_result().tokens.is_empty());
    }

    #[test]
    fn step_report_charges_phase_time_to_participants() {
        let model = NativeBackend::synthetic(tiny_cfg(), 6, 13, InitStyle::Confident).unwrap();
        let cfg = SpecConfig { gen_len: 16, max_draft: 4, ..Default::default() };
        let engine = BatchEngine::new(&model);
        let mut sessions =
            vec![GenSession::Spec(SpecSession::new(&model, b"phase time", cfg).unwrap())];
        while !sessions[0].is_done() {
            let mut refs: Vec<&mut GenSession> = sessions.iter_mut().collect();
            engine.step(&mut refs).unwrap();
        }
        let p = sessions[0].phase_seconds();
        assert!(
            p.prefill_s > 0.0 && p.draft_s > 0.0 && p.verify_s > 0.0,
            "every phase ran at least once: {p:?}"
        );
        assert!(p.total() < 60.0, "attribution must be wall time, not a counter: {p:?}");
        sessions.pop().unwrap().release(&model);
    }

    #[test]
    fn streaming_chunks_concatenate_to_the_full_output() {
        let model = NativeBackend::synthetic(tiny_cfg(), 6, 13, InitStyle::Confident).unwrap();
        let cfg = SpecConfig { gen_len: 20, max_draft: 4, ..Default::default() };
        let engine = BatchEngine::new(&model);
        let mut sessions =
            vec![GenSession::Spec(SpecSession::new(&model, b"stream me", cfg).unwrap())];
        let mut streamed = Vec::new();
        let mut chunks = 0;
        while !sessions[0].is_done() {
            {
                let mut refs: Vec<&mut GenSession> = sessions.iter_mut().collect();
                engine.step(&mut refs).unwrap();
            }
            let c = sessions[0].take_new_tokens();
            if !c.is_empty() {
                chunks += 1;
            }
            streamed.extend(c);
        }
        assert!(chunks >= 2, "expected incremental chunks, got {chunks}");
        let result = sessions.pop().unwrap().into_result();
        assert_eq!(streamed, result.tokens);
        assert_eq!(result.tokens.len(), 20);
    }
}
