//! Decode traces: what the engine did, for the accelerator simulator and
//! the evaluation harness.

/// One draft-verify iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IterRecord {
    /// Draft tokens proposed this iteration (0 when early exit fired
    /// immediately; the verify pass then scores only the carry token).
    pub drafted: u32,
    /// Draft tokens accepted by verification (<= drafted).
    pub accepted: u32,
    /// Whether §III-C early exit stopped the draft before `max_draft`.
    pub early_exit: bool,
}

/// Full trace of one generation request.
#[derive(Debug, Clone, Default)]
pub struct SpecTrace {
    pub iterations: Vec<IterRecord>,
    /// Tokens produced (accepted drafts + bonus tokens).
    pub produced: usize,
    /// Prompt length consumed at prefill.
    pub prompt_len: usize,
}

impl SpecTrace {
    /// Total draft-model forward steps (each costs T_d).
    pub fn draft_steps(&self) -> u64 {
        self.iterations.iter().map(|i| i.drafted as u64).sum()
    }

    /// Total verification passes (each costs T_v).
    pub fn verify_passes(&self) -> u64 {
        self.iterations.len() as u64
    }

    /// Mean accepted draft tokens per verify pass.
    pub fn mean_accept_len(&self) -> f64 {
        if self.iterations.is_empty() {
            return 0.0;
        }
        // +1: each verify also yields the bonus token, matching Eq. 1's
        // average accept length L_a convention.
        self.iterations.iter().map(|i| i.accepted as f64 + 1.0).sum::<f64>()
            / self.iterations.len() as f64
    }

    /// Empirical per-token accept rate r: accepted / drafted.
    ///
    /// With zero drafted tokens there is no evidence either way, so this
    /// returns the documented neutral value `0.0` ("no drafts accepted")
    /// rather than the optimistic `1.0` it used to claim — a cold-start
    /// controller reading `1.0` here would jump straight to `max_draft`.
    /// Controllers wanting an informative prior must supply their own
    /// (see `adaptive::AdaptiveConfig::prior`); API consumers see `0.0`
    /// for autoregressive sessions, which honestly reports that nothing
    /// was speculated.
    pub fn accept_rate(&self) -> f64 {
        let drafted: u64 = self.draft_steps();
        if drafted == 0 {
            return 0.0;
        }
        let accepted: u64 = self.iterations.iter().map(|i| i.accepted as u64).sum();
        accepted as f64 / drafted as f64
    }

    /// Mean drafted length per iteration (the paper's L-bar in Table II).
    pub fn mean_draft_len(&self) -> f64 {
        if self.iterations.is_empty() {
            return 0.0;
        }
        self.draft_steps() as f64 / self.iterations.len() as f64
    }

    /// Fraction of iterations ended by early exit.
    pub fn early_exit_rate(&self) -> f64 {
        if self.iterations.is_empty() {
            return 0.0;
        }
        self.iterations.iter().filter(|i| i.early_exit).count() as f64
            / self.iterations.len() as f64
    }

    /// Merge another trace into this one (aggregate statistics).
    pub fn merge(&mut self, other: &SpecTrace) {
        self.iterations.extend_from_slice(&other.iterations);
        self.produced += other.produced;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> SpecTrace {
        SpecTrace {
            iterations: vec![
                IterRecord { drafted: 4, accepted: 4, early_exit: false },
                IterRecord { drafted: 2, accepted: 1, early_exit: true },
                IterRecord { drafted: 3, accepted: 0, early_exit: false },
            ],
            produced: 8,
            prompt_len: 64,
        }
    }

    #[test]
    fn aggregates() {
        let t = trace();
        assert_eq!(t.draft_steps(), 9);
        assert_eq!(t.verify_passes(), 3);
        assert!((t.accept_rate() - 5.0 / 9.0).abs() < 1e-12);
        assert!((t.mean_accept_len() - (5.0 + 3.0) / 3.0).abs() < 1e-12);
        assert!((t.early_exit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_reports_neutral_accept_rate() {
        // No drafted tokens (AR session, or a spec session before its
        // first verify) is zero evidence, not a perfect accept rate.
        let t = SpecTrace::default();
        assert_eq!(t.accept_rate(), 0.0);
        // Iterations that drafted nothing (early exit before the first
        // draft token) likewise carry no accept-rate evidence.
        let t = SpecTrace {
            iterations: vec![IterRecord { drafted: 0, accepted: 0, early_exit: true }],
            produced: 1,
            prompt_len: 4,
        };
        assert_eq!(t.accept_rate(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = trace();
        let b = trace();
        a.merge(&b);
        assert_eq!(a.iterations.len(), 6);
        assert_eq!(a.produced, 16);
    }
}
