//! Adaptive draft-length control: pick the next draft budget by maximizing
//! the paper's Eq. 2 speedup model at a live accept-rate estimate.
//!
//! The static `max_draft: 16` default is only optimal at the paper's
//! operating point (r ≈ 0.976); the spec-decoding literature (survey
//! 2401.07851, "Decoding Speculative Decoding" 2402.01528) shows the
//! optimum moves with the workload's accept rate and with batch occupancy.
//! This module supplies the three pieces:
//!
//! * [`AdaptiveController`] — a per-sequence EWMA accept-rate estimator fed
//!   from verify outcomes, with the §III-C **censoring correction**: an
//!   early-exited draft chain is a *censored* observation, not a
//!   full-length sample.  Per verify pass we observe `accepted` Bernoulli
//!   successes plus **exactly one failure iff `accepted < drafted`** (the
//!   first rejected token); tokens after the first rejection were never
//!   tested, and the un-drafted tail of an early-exited chain was never
//!   proposed — neither contributes a trial.  Counting the truncated chain
//!   as if it were full-length would bias r̂ upward exactly when γ fires
//!   most (low-confidence stretches).
//! * [`CostRatios`] — measured `T_d/T_ar` and `T_v/T_ar` from the
//!   deterministic weight-traffic counters (the native backend is
//!   memory-bound, so bytes-streamed is the cost model), with the paper's
//!   constants as a fallback before any traffic has been metered.
//! * [`BatchSpecPolicy`] — the coordinator-level occupancy policy: at high
//!   batch occupancy the verification pass amortizes weight traffic across
//!   sequences and long drafts waste work, so the policy caps (and at full
//!   occupancy disables) speculation for adaptive sessions.
//!
//! Determinism contract: the controller is a pure function of the observed
//! `(drafted, accepted)` stream and its config — no wall clock, no
//! randomness — so a replayed request sequence reproduces the exact budget
//! sequence bit-for-bit.

use crate::runtime::TrafficSnapshot;

use super::theory::theoretical_speedup;

/// Paper §IV draft/full weight-traffic ratio (the "quarter" in
/// quarter-to-all), used before any traffic has been metered.
pub const FALLBACK_TD_RATIO: f64 = 0.27;
/// One parallel verification pass streams the full weights once ≈ one AR
/// step (both are memory-bound full-precision passes).
pub const FALLBACK_TV_RATIO: f64 = 1.0;

/// Per-sequence adaptive draft-length knobs, embedded in `SpecConfig`.
///
/// Defaults to disabled: with `enabled: false` sessions take the static
/// `max_draft` path and are bit-identical to the pre-controller engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveConfig {
    /// Off by default; the static path is untouched when disabled.
    pub enabled: bool,
    /// Smallest draft budget the controller may pick (the batch policy may
    /// still force 0 = speculation disabled).
    pub min_draft: usize,
    /// EWMA step per observed accept/reject trial.  Small enough to
    /// average over many verify passes, large enough to track a mid-run
    /// accept-rate shift within a few dozen iterations.
    pub alpha: f64,
    /// Cold-start accept-rate estimate.  Neutral 0.5 — deliberately not
    /// `SpecTrace::accept_rate()`'s empty-trace value (0.0, "no
    /// evidence"), and not the optimistic 1.0 that would open at
    /// `max_draft`.
    pub prior: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self { enabled: false, min_draft: 1, alpha: 0.05, prior: 0.5 }
    }
}

impl AdaptiveConfig {
    /// Enabled with default estimator knobs.
    pub fn enabled() -> Self {
        Self { enabled: true, ..Self::default() }
    }
}

/// Measured draft/verify cost ratios relative to one AR step, in units of
/// weight bytes streamed (the memory-bound cost model the paper argues
/// from, and deterministic across runs unlike wall-clock timing).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostRatios {
    /// `T_d / T_ar`: one draft step over one full-precision decode step.
    pub td: f64,
    /// `T_v / T_ar`: one parallel verification pass over one decode step.
    pub tv: f64,
}

impl Default for CostRatios {
    fn default() -> Self {
        Self { td: FALLBACK_TD_RATIO, tv: FALLBACK_TV_RATIO }
    }
}

impl CostRatios {
    /// Derive ratios from a traffic snapshot.  The verification pass
    /// always scores all `slots` rows regardless of how many drafts the
    /// chain produced (the graph shape is fixed), so `tv` is
    /// `verify_bytes_per_row × slots / full_bytes_per_token`.  Falls back
    /// to the paper constants for any pass type the snapshot has not
    /// metered yet — `theoretical_speedup` sanitizes its inputs, but a
    /// half-empty counter would silently skew the argmax.
    pub fn from_traffic(t: &TrafficSnapshot, slots: usize) -> Self {
        let full = t.full_bytes_per_token();
        if !(full.is_finite() && full > 0.0) {
            return Self::default();
        }
        let draft = t.draft_bytes_per_token();
        let verify = t.verify_bytes_per_row();
        let td = if draft.is_finite() && draft > 0.0 {
            draft / full
        } else {
            FALLBACK_TD_RATIO
        };
        let tv = if verify.is_finite() && verify > 0.0 {
            verify * slots as f64 / full
        } else {
            FALLBACK_TV_RATIO
        };
        Self { td, tv }
    }
}

/// Per-sequence EWMA accept-rate estimator + Eq. 2 budget picker.
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    cfg: AdaptiveConfig,
    /// EWMA accept-rate estimate r̂ ∈ [0, 1].
    rate: f64,
    /// Uncensored Bernoulli trials observed so far.
    trials: u64,
    /// Batch-policy ceiling on the next budget (`usize::MAX` = no cap,
    /// 0 = speculation disabled this iteration).
    policy_cap: usize,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        let rate = if cfg.prior.is_nan() { 0.5 } else { cfg.prior.clamp(0.0, 1.0) };
        Self { cfg, rate, trials: 0, policy_cap: usize::MAX }
    }

    /// Fold one verify outcome into the estimate, with the censoring
    /// correction (module docs): `accepted` successes, plus one failure
    /// only when a draft was actually rejected.  A chain where every
    /// drafted token was accepted — whether it ran to budget or γ-exited
    /// early — ends in censoring, not failure: the tokens that would have
    /// followed were never tested.
    pub fn observe(&mut self, drafted: usize, accepted: usize) {
        let a = self.cfg.alpha;
        for _ in 0..accepted.min(drafted) {
            self.rate = (1.0 - a) * self.rate + a;
            self.trials += 1;
        }
        if accepted < drafted {
            self.rate *= 1.0 - a;
            self.trials += 1;
        }
    }

    /// Current accept-rate estimate r̂.
    pub fn accept_rate(&self) -> f64 {
        self.rate
    }

    /// Uncensored trials folded in so far (0 = still on the prior).
    pub fn trials(&self) -> u64 {
        self.trials
    }

    /// Apply the batch-level policy ceiling for the next iteration.
    pub fn set_policy_cap(&mut self, cap: usize) {
        self.policy_cap = cap;
    }

    /// Pick the next draft budget: argmax of `theoretical_speedup` over
    /// L ∈ [min_draft, min(max_draft, policy_cap)], ties to the smallest L
    /// (less speculative work for equal predicted speedup).  A policy cap
    /// of 0 disables speculation outright (budget 0 = verify-only
    /// iteration producing exactly the bonus token).
    pub fn pick_budget(&self, max_draft: usize, ratios: &CostRatios) -> usize {
        let cap = max_draft.min(self.policy_cap);
        if cap == 0 {
            return 0;
        }
        let lo = self.cfg.min_draft.clamp(1, cap);
        let mut best_l = lo;
        let mut best_s = f64::NEG_INFINITY;
        for l in lo..=cap {
            let s = theoretical_speedup(self.rate, l, ratios.td, ratios.tv);
            if s > best_s {
                best_s = s;
                best_l = l;
            }
        }
        best_l
    }
}

/// Batch-level speculation policy, evaluated by the coordinator scheduler
/// each engine step from the live occupancy `active / max_batch`.
///
/// Below `high_occupancy` the batch is draft-bound and long chains pay off;
/// above it the shared verification pass already amortizes the full-weight
/// stream across many sequences, so drafts are capped at `high_cap`; at
/// full occupancy speculation is disabled (cap 0) — every sequence decodes
/// through verify-only iterations until the batch drains.  The policy only
/// constrains sessions running the adaptive controller; static sessions
/// keep their configured `max_draft` bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchSpecPolicy {
    /// Occupancy fraction at which drafts are capped.
    pub high_occupancy: f64,
    /// Draft cap applied in the high-occupancy band.
    pub high_cap: usize,
}

impl Default for BatchSpecPolicy {
    fn default() -> Self {
        Self { high_occupancy: 0.75, high_cap: 4 }
    }
}

impl BatchSpecPolicy {
    /// Draft-budget ceiling for the coming engine step.
    pub fn draft_cap(&self, active: usize, max_batch: usize) -> usize {
        if max_batch == 0 {
            return usize::MAX;
        }
        let occ = active as f64 / max_batch as f64;
        if occ >= 1.0 {
            0
        } else if occ >= self.high_occupancy {
            self.high_cap
        } else {
            usize::MAX
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_start_opens_conservatively() {
        // On the neutral prior the Eq. 2 argmax sits at a short chain, not
        // max_draft — the regression the `SpecTrace::accept_rate() == 1.0`
        // bug would have caused.
        let c = AdaptiveController::new(AdaptiveConfig::enabled());
        let budget = c.pick_budget(16, &CostRatios::default());
        assert!(
            (1..=4).contains(&budget),
            "cold-start budget {budget} should be short, not max_draft"
        );
    }

    #[test]
    fn observe_applies_censoring_correction() {
        let cfg = AdaptiveConfig { enabled: true, alpha: 0.5, ..Default::default() };
        // Full acceptance of a truncated (early-exited) chain: successes
        // only, no failure trial.
        let mut c = AdaptiveController::new(cfg);
        c.observe(2, 2);
        assert_eq!(c.trials(), 2);
        assert!(c.accept_rate() > 0.8);
        // A rejection contributes exactly one failure regardless of how
        // many drafts followed it (they were never tested).
        let mut c = AdaptiveController::new(cfg);
        c.observe(8, 0);
        assert_eq!(c.trials(), 1);
        // A fully censored iteration (nothing drafted) is no evidence.
        let mut c = AdaptiveController::new(cfg);
        c.observe(0, 0);
        assert_eq!(c.trials(), 0);
        assert_eq!(c.accept_rate(), cfg.prior);
    }

    #[test]
    fn budget_tracks_accept_rate() {
        let cfg = AdaptiveConfig { enabled: true, alpha: 0.2, ..Default::default() };
        let ratios = CostRatios::default();
        let mut c = AdaptiveController::new(cfg);
        // Sustained rejections: the argmax collapses to L = 1.
        for _ in 0..64 {
            c.observe(4, 0);
        }
        assert_eq!(c.pick_budget(16, &ratios), 1);
        // Sustained full acceptance: the argmax climbs to max_draft.
        for _ in 0..256 {
            c.observe(4, 4);
        }
        assert!(c.accept_rate() > 0.99);
        assert_eq!(c.pick_budget(16, &ratios), 16);
    }

    #[test]
    fn policy_cap_bounds_and_disables() {
        let mut c = AdaptiveController::new(AdaptiveConfig::enabled());
        for _ in 0..256 {
            c.observe(4, 4);
        }
        let ratios = CostRatios::default();
        assert_eq!(c.pick_budget(16, &ratios), 16);
        c.set_policy_cap(4);
        assert_eq!(c.pick_budget(16, &ratios), 4);
        c.set_policy_cap(0);
        assert_eq!(c.pick_budget(16, &ratios), 0);
        c.set_policy_cap(usize::MAX);
        assert_eq!(c.pick_budget(16, &ratios), 16);
    }

    #[test]
    fn occupancy_policy_bands() {
        let p = BatchSpecPolicy::default();
        assert_eq!(p.draft_cap(1, 8), usize::MAX);
        assert_eq!(p.draft_cap(5, 8), usize::MAX);
        assert_eq!(p.draft_cap(6, 8), p.high_cap); // 0.75 boundary
        assert_eq!(p.draft_cap(7, 8), p.high_cap);
        assert_eq!(p.draft_cap(8, 8), 0);
        assert_eq!(p.draft_cap(9, 8), 0);
        assert_eq!(p.draft_cap(3, 0), usize::MAX);
    }

    #[test]
    fn cost_ratios_fall_back_on_empty_traffic() {
        let r = CostRatios::from_traffic(&TrafficSnapshot::default(), 17);
        assert_eq!(r, CostRatios::default());
    }

    #[test]
    fn controller_is_deterministic() {
        let cfg = AdaptiveConfig::enabled();
        let ratios = CostRatios::default();
        let run = || {
            let mut c = AdaptiveController::new(cfg);
            let mut budgets = Vec::new();
            for i in 0..100usize {
                let drafted = 1 + i % 5;
                let accepted = drafted * (i % 3) / 2;
                c.observe(drafted, accepted.min(drafted));
                budgets.push(c.pick_budget(16, &ratios));
            }
            (budgets, c.accept_rate().to_bits())
        };
        assert_eq!(run(), run());
    }
}
