//! Runtime-dispatched SIMD implementations of the Fig. 5 plane decoders.
//!
//! The hot loops of the plane-streaming GEMM kernels are the element-wise
//! decoders: nibble-unpack + LUT for the draft prefix plane (Fig. 5(a))
//! and the branch-free bit reconstruction + FP16→f32 widening for the
//! full prefix+residual view (Fig. 5(b)).  Both are order-free per
//! element, so they vectorize without touching the kernels' determinism
//! contract (accumulation order stays scalar and ascending — see
//! `runtime::kernels`).
//!
//! Dispatch tiers ([`SimdLevel`], best detected at backend init, forced
//! via `SPEQ_SIMD` / `--simd`):
//!
//! * `scalar` — the reference implementation, always available; every
//!   other tier must reproduce its output **bitwise** (pinned by the
//!   exhaustive tests below and `rust/tests/prop_simd.rs`).
//! * `sse4.1` (x86_64) — 4 columns per iteration; 16-byte `pshufb` tables
//!   for the remap LUTs.
//! * `avx2` (x86_64) — 8 columns per iteration; `vpermd` for the 8-entry
//!   exponent/MUX tables, `pshufb` for residual byte extraction.
//! * `neon` (aarch64) — 4 columns per iteration via `tbl` lookups.
//!
//! **Why the SIMD bits match scalar exactly.**  The draft LUT values are
//! exact powers of two, so `draft_value(w_q)`'s f32 bits are constructed
//! directly as `sign << 31 | (qexp + 112) << 23` — identical to the
//! scalar `exp2` path — and the single multiply by the precomputed
//! `scale / tensor_scale` row is the same one IEEE operation in both
//! paths.  The full decode reconstructs the same FP16 bit pattern the
//! scalar [`decode_full_bits`] produces (remap tables become in-register
//! shuffles), then widens with a branch-free half→float: normals shift
//! mantissa/rebias exponent exactly as `util::f16::f16_to_f32`; f16
//! subnormals take an exact float subtraction (`(2^-14·(1 + m/1024)) -
//! 2^-14 = m·2^-24`, exact by Sterbenz' lemma), yielding the same
//! normalized f32 the scalar renormalization loop produces.  Inf/NaN
//! cannot occur: the reconstructed exponent is `ehigh << 1 | e0 <= 15`
//! for *every* input bit pattern.

use super::fp16::f16_bits_to_f32;
use super::remap::{decode_full_bits, draft_value, BsfpCode};

/// One instruction-set tier of the plane decoders.
///
/// All variants exist on every architecture (so configs and tests can
/// name them portably); a variant that is foreign to the compilation
/// target simply reports `is_available() == false` and dispatches to
/// scalar.  Callers must only pass available levels to the decode entry
/// points (enforced by [`SimdLevel::resolve`] at config time and
/// debug-asserted in dispatch).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// Reference implementation; always available.
    Scalar,
    /// x86_64 SSE4.1 (4 f32 lanes).
    Sse41,
    /// x86_64 AVX2 (8 f32 lanes).
    Avx2,
    /// aarch64 NEON (4 f32 lanes).
    Neon,
}

impl SimdLevel {
    /// The tiers usable on this host, ascending (always starts with
    /// [`SimdLevel::Scalar`]; the last entry is what [`detect`] returns).
    ///
    /// [`detect`]: SimdLevel::detect
    pub fn available() -> Vec<SimdLevel> {
        let mut out = vec![SimdLevel::Scalar];
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("sse4.1") {
                out.push(SimdLevel::Sse41);
            }
            if std::arch::is_x86_feature_detected!("avx2") {
                out.push(SimdLevel::Avx2);
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            // NEON is a mandatory part of AArch64.
            out.push(SimdLevel::Neon);
        }
        out
    }

    /// The best tier supported by this host (CPUID-style feature
    /// detection, done once — callers cache the result at backend init).
    pub fn detect() -> SimdLevel {
        *Self::available().last().expect("scalar is always available")
    }

    /// Whether this tier can execute on this host.
    pub fn is_available(self) -> bool {
        Self::available().contains(&self)
    }

    /// Stable lowercase name (the `SPEQ_SIMD` / `--simd` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse41 => "sse4.1",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// f32 lanes per decode iteration (1 for scalar).
    pub fn lanes(self) -> usize {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Sse41 | SimdLevel::Neon => 4,
            SimdLevel::Avx2 => 8,
        }
    }

    /// Parse a `SPEQ_SIMD` / `--simd` value.  `"auto"` resolves to
    /// [`SimdLevel::detect`]; unknown strings are `None`.  The returned
    /// level is *not* clamped to this host — call [`SimdLevel::resolve`].
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Some(Self::detect()),
            "scalar" => Some(SimdLevel::Scalar),
            "sse4.1" | "sse41" => Some(SimdLevel::Sse41),
            "avx2" => Some(SimdLevel::Avx2),
            "neon" => Some(SimdLevel::Neon),
            _ => None,
        }
    }

    /// This level if the host supports it, else the best detected tier
    /// (with a warning — a forced-but-unsupported path must degrade, not
    /// crash on an illegal instruction).
    pub fn resolve(self) -> SimdLevel {
        if self.is_available() {
            self
        } else {
            let best = Self::detect();
            crate::log_warn!(
                "speq::bsfp::simd",
                "SIMD level {:?} unavailable on this host; using {:?}",
                self.name(),
                best.name()
            );
            best
        }
    }

    /// The level `SPEQ_SIMD` selects: unset or `auto` detects the best
    /// tier, anything else parses and resolves (unknown values warn and
    /// fall back to detection).
    pub fn from_env() -> SimdLevel {
        match std::env::var("SPEQ_SIMD") {
            Ok(v) => match Self::parse(&v) {
                Some(level) => level.resolve(),
                None => {
                    let best = Self::detect();
                    crate::log_warn!(
                        "speq::bsfp::simd",
                        "unknown SPEQ_SIMD={v:?} (auto|scalar|sse4.1|avx2|neon); using {:?}",
                        best.name()
                    );
                    best
                }
            },
            Err(_) => Self::detect(),
        }
    }
}

/// The 16-entry Fig. 5(a) LUT: `draft_value` per 4-bit code.  Every entry
/// is an exact power of two (`±2^(Q(E)-15)`), which is what makes the
/// hoisted `scale / tensor_scale` factorization bitwise-exact.
pub fn draft_lut() -> [f32; 16] {
    std::array::from_fn(|c| draft_value(c as u8))
}

/// Scalar reference: decode one nibble-packed prefix row pair through the
/// draft LUT and a precomputed per-column factor `pre[j] =
/// scale[j] / tensor_scale` (hoisted out of the row loop — see
/// `runtime::kernels`; the factorization is bitwise-exact because every
/// LUT entry is a power of two and all intermediates stay normal).
pub fn decode_draft_row_pair_scalar(
    prow: &[u8],
    pre: &[f32],
    lut: &[f32; 16],
    lo: &mut [f32],
    hi: &mut [f32],
) {
    debug_assert!(prow.len() == pre.len() && prow.len() == lo.len() && prow.len() == hi.len());
    for (jj, &byte) in prow.iter().enumerate() {
        lo[jj] = lut[(byte & 0xf) as usize] * pre[jj];
        hi[jj] = lut[(byte >> 4) as usize] * pre[jj];
    }
}

/// Scalar reference: decode one prefix+residual row pair (columns of rows
/// `2p` / `2p+1`) to f32 via the Fig. 5(b) reconstruction.  `rrow` holds
/// the 3 packed residual bytes per column (`3 * prow.len()` bytes).
pub fn decode_full_row_pair_scalar(prow: &[u8], rrow: &[u8], lo: &mut [f32], hi: &mut [f32]) {
    debug_assert_eq!(rrow.len(), 3 * prow.len());
    debug_assert!(prow.len() == lo.len() && prow.len() == hi.len());
    for (jj, &byte) in prow.iter().enumerate() {
        let base = 3 * jj;
        let (b0, b1, b2) = (rrow[base] as u16, rrow[base + 1] as u16, rrow[base + 2] as u16);
        let c0 = BsfpCode { w_q: byte & 0xf, w_r: b0 | ((b1 & 0xf) << 8) };
        let c1 = BsfpCode { w_q: byte >> 4, w_r: (b1 >> 4) | (b2 << 4) };
        lo[jj] = f16_bits_to_f32(decode_full_bits(c0));
        hi[jj] = f16_bits_to_f32(decode_full_bits(c1));
    }
}

/// Dispatched draft decode: `lo[j] = lut[prow[j] & 0xf] * pre[j]`,
/// `hi[j] = lut[prow[j] >> 4] * pre[j]`.  Bitwise identical to
/// [`decode_draft_row_pair_scalar`] on every tier.
pub fn decode_draft_row_pair(
    level: SimdLevel,
    prow: &[u8],
    pre: &[f32],
    lut: &[f32; 16],
    lo: &mut [f32],
    hi: &mut [f32],
) {
    debug_assert!(level.is_available(), "dispatching unavailable SIMD level {:?}", level);
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the level is available (asserted above; enforced by
        // `resolve()` at config time), so the target features exist.
        SimdLevel::Avx2 => unsafe { x86::decode_draft_row_pair_avx2(prow, pre, lut, lo, hi) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::decode_draft_row_pair_sse41(prow, pre, lut, lo, hi) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::decode_draft_row_pair_neon(prow, pre, lut, lo, hi) },
        _ => decode_draft_row_pair_scalar(prow, pre, lut, lo, hi),
    }
}

/// Dispatched full (prefix + residual) row-pair decode.  Bitwise
/// identical to [`decode_full_row_pair_scalar`] on every tier.
pub fn decode_full_row_pair(
    level: SimdLevel,
    prow: &[u8],
    rrow: &[u8],
    lo: &mut [f32],
    hi: &mut [f32],
) {
    debug_assert!(level.is_available(), "dispatching unavailable SIMD level {:?}", level);
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `decode_draft_row_pair`.
        SimdLevel::Avx2 => unsafe { x86::decode_full_row_pair_avx2(prow, rrow, lo, hi) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::decode_full_row_pair_sse41(prow, rrow, lo, hi) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::decode_full_row_pair_neon(prow, rrow, lo, hi) },
        _ => decode_full_row_pair_scalar(prow, rrow, lo, hi),
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{decode_draft_row_pair_scalar, decode_full_row_pair_scalar};
    use core::arch::x86_64::*;

    /// `CODE_TO_QEXP + 112`: the f32 biased exponent of `2^(Q(E) - 15)`,
    /// one entry per 3-bit code (indexed by `vpermd`, which reads only the
    /// low 3 index bits).
    const QEXP_BIASED: [i32; 8] = [121, 114, 123, 118, 120, 122, 124, 126];
    /// `FLAG_MUX_EHIGH` replicated to 8 entries for `vpermd` (keyed by
    /// `code & 3`; the table repeats so plain `code` indexes it too).
    const MUX_EHIGH: [i32; 8] = [4, 0, 5, 2, 4, 0, 5, 2];
    /// Byte-shuffle editions of the same tables for `pshufb` (index =
    /// the full 4-bit `w_q`; the sign bit is ignored by replication).
    const QEXP_BIASED_B: [u8; 16] =
        [121, 114, 123, 118, 120, 122, 124, 126, 121, 114, 123, 118, 120, 122, 124, 126];
    const MUX_EHIGH_B: [u8; 16] = [4, 0, 5, 2, 4, 0, 5, 2, 4, 0, 5, 2, 4, 0, 5, 2];

    // Residual byte extraction for 8 columns (24 packed bytes).  Two
    // overlapping 16-byte loads A = bytes[0..16], B = bytes[8..24] form
    // the 256-bit vector [A | B]; `vpshufb` indexes within each 128-bit
    // half, so lane j (columns 0..3 from A, 4..7 from B) picks its two
    // residual bytes: column c reads bytes (3c, 3c+1) for r0 and
    // (3c+1, 3c+2) for r1 (B-relative offsets subtract 8).  0x80 zeroes
    // the upper lane bytes.
    const R0_SHUF: [i8; 32] = [
        0, 1, -128, -128, 3, 4, -128, -128, 6, 7, -128, -128, 9, 10, -128, -128, //
        4, 5, -128, -128, 7, 8, -128, -128, 10, 11, -128, -128, 13, 14, -128, -128,
    ];
    const R1_SHUF: [i8; 32] = [
        1, 2, -128, -128, 4, 5, -128, -128, 7, 8, -128, -128, 10, 11, -128, -128, //
        5, 6, -128, -128, 8, 9, -128, -128, 11, 12, -128, -128, 14, 15, -128, -128,
    ];
    // SSE edition: 4 columns (12 packed bytes, loaded as 8 + 4 in-bounds).
    const R0_SHUF128: [i8; 16] =
        [0, 1, -128, -128, 3, 4, -128, -128, 6, 7, -128, -128, 9, 10, -128, -128];
    const R1_SHUF128: [i8; 16] =
        [1, 2, -128, -128, 4, 5, -128, -128, 7, 8, -128, -128, 10, 11, -128, -128];

    /// Draft f32 bits for 8 lanes of 4-bit `w_q`:
    /// `(w_q & 8) << 28 | QEXP_BIASED[w_q & 7] << 23`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn draft_bits_avx2(wq: __m256i) -> __m256 {
        let tab = _mm256_loadu_si256(QEXP_BIASED.as_ptr() as *const __m256i);
        let expf = _mm256_slli_epi32::<23>(_mm256_permutevar8x32_epi32(tab, wq));
        let sign = _mm256_slli_epi32::<28>(_mm256_and_si256(wq, _mm256_set1_epi32(8)));
        _mm256_castsi256_ps(_mm256_or_si256(expf, sign))
    }

    /// Branch-free FP16 → f32 widening of 8 lanes holding 16-bit half
    /// patterns with exponent <= 15 (no inf/NaN lane can occur — the
    /// Fig. 5(b) reconstruction bounds the exponent).  Matches
    /// `util::f16::f16_to_f32` bitwise, including subnormals and ±0.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn half_to_f32_avx2(h: __m256i) -> __m256 {
        let magnitude = _mm256_slli_epi32::<13>(_mm256_and_si256(h, _mm256_set1_epi32(0x7fff)));
        let exp16 = _mm256_and_si256(magnitude, _mm256_set1_epi32(0x7c00 << 13));
        // Normal: rebias the exponent by (127 - 15).
        let norm = _mm256_add_epi32(magnitude, _mm256_set1_epi32((127 - 15) << 23));
        // Subnormal (exp16 == 0): treat the mantissa as the fraction of
        // 2^-14 and subtract the implicit leading 2^-14 — an exact float
        // subtraction yielding the normalized m * 2^-24.
        let magic = _mm256_castsi256_ps(_mm256_set1_epi32(113 << 23));
        let sub = _mm256_sub_ps(
            _mm256_castsi256_ps(_mm256_add_epi32(norm, _mm256_set1_epi32(1 << 23))),
            magic,
        );
        let is_sub = _mm256_cmpeq_epi32(exp16, _mm256_setzero_si256());
        let val = _mm256_blendv_epi8(norm, _mm256_castps_si256(sub), is_sub);
        let sign = _mm256_slli_epi32::<16>(_mm256_and_si256(h, _mm256_set1_epi32(0x8000)));
        _mm256_castsi256_ps(_mm256_or_si256(val, sign))
    }

    /// Fig. 5(b) reconstruction for 8 lanes: `(w_q, w_r)` → FP16 bits →
    /// f32.  `REMAP`'s inverse tables run as in-register shuffles.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn full_values_avx2(wq: __m256i, wr: __m256i) -> __m256 {
        let one = _mm256_set1_epi32(1);
        let sign = _mm256_slli_epi32::<12>(_mm256_and_si256(wq, _mm256_set1_epi32(8)));
        let code = _mm256_and_si256(wq, _mm256_set1_epi32(7));
        let flag = _mm256_and_si256(_mm256_srli_epi32::<11>(wr), one);
        let e0 = _mm256_and_si256(_mm256_srli_epi32::<10>(wr), one);
        let man = _mm256_and_si256(wr, _mm256_set1_epi32(0x3ff));
        let mux_tab = _mm256_loadu_si256(MUX_EHIGH.as_ptr() as *const __m256i);
        let mux = _mm256_permutevar8x32_epi32(mux_tab, code);
        let flagged = _mm256_cmpeq_epi32(flag, one);
        let ehigh = _mm256_blendv_epi8(code, mux, flagged);
        let exp = _mm256_or_si256(_mm256_slli_epi32::<1>(ehigh), e0);
        let f16 = _mm256_or_si256(sign, _mm256_or_si256(_mm256_slli_epi32::<10>(exp), man));
        half_to_f32_avx2(f16)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn decode_draft_row_pair_avx2(
        prow: &[u8],
        pre: &[f32],
        lut: &[f32; 16],
        lo: &mut [f32],
        hi: &mut [f32],
    ) {
        let w = prow.len();
        let nib = _mm256_set1_epi32(0xf);
        let mut j = 0;
        while j + 8 <= w {
            let bytes =
                _mm256_cvtepu8_epi32(_mm_loadl_epi64(prow.as_ptr().add(j) as *const __m128i));
            let pre_v = _mm256_loadu_ps(pre.as_ptr().add(j));
            let wq_lo = _mm256_and_si256(bytes, nib);
            let wq_hi = _mm256_and_si256(_mm256_srli_epi32::<4>(bytes), nib);
            let lo_v = _mm256_mul_ps(draft_bits_avx2(wq_lo), pre_v);
            let hi_v = _mm256_mul_ps(draft_bits_avx2(wq_hi), pre_v);
            _mm256_storeu_ps(lo.as_mut_ptr().add(j), lo_v);
            _mm256_storeu_ps(hi.as_mut_ptr().add(j), hi_v);
            j += 8;
        }
        decode_draft_row_pair_scalar(&prow[j..], &pre[j..], lut, &mut lo[j..], &mut hi[j..]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn decode_full_row_pair_avx2(
        prow: &[u8],
        rrow: &[u8],
        lo: &mut [f32],
        hi: &mut [f32],
    ) {
        let w = prow.len();
        let nib = _mm256_set1_epi32(0xf);
        let r0_shuf = _mm256_loadu_si256(R0_SHUF.as_ptr() as *const __m256i);
        let r1_shuf = _mm256_loadu_si256(R1_SHUF.as_ptr() as *const __m256i);
        let mask12 = _mm256_set1_epi32(0xfff);
        let mut j = 0;
        while j + 8 <= w {
            let bytes =
                _mm256_cvtepu8_epi32(_mm_loadl_epi64(prow.as_ptr().add(j) as *const __m128i));
            let wq_lo = _mm256_and_si256(bytes, nib);
            let wq_hi = _mm256_and_si256(_mm256_srli_epi32::<4>(bytes), nib);
            // 24 residual bytes for these 8 columns, via two overlapping
            // in-bounds 16-byte loads (3*j + 24 <= 3*w holds when
            // j + 8 <= w).
            let a = _mm_loadu_si128(rrow.as_ptr().add(3 * j) as *const __m128i);
            let bvec = _mm_loadu_si128(rrow.as_ptr().add(3 * j + 8) as *const __m128i);
            let v = _mm256_set_m128i(bvec, a);
            let r0 = _mm256_and_si256(_mm256_shuffle_epi8(v, r0_shuf), mask12);
            let r1 =
                _mm256_and_si256(_mm256_srli_epi32::<4>(_mm256_shuffle_epi8(v, r1_shuf)), mask12);
            _mm256_storeu_ps(lo.as_mut_ptr().add(j), full_values_avx2(wq_lo, r0));
            _mm256_storeu_ps(hi.as_mut_ptr().add(j), full_values_avx2(wq_hi, r1));
            j += 8;
        }
        decode_full_row_pair_scalar(&prow[j..], &rrow[3 * j..], &mut lo[j..], &mut hi[j..]);
    }

    /// Draft f32 bits for 4 lanes (SSE edition of [`draft_bits_avx2`]):
    /// `pshufb` on a byte table, then mask to the low byte of each lane.
    #[inline]
    #[target_feature(enable = "sse4.1")]
    unsafe fn draft_bits_sse41(wq: __m128i) -> __m128 {
        let tab = _mm_loadu_si128(QEXP_BIASED_B.as_ptr() as *const __m128i);
        // Index bytes 1..3 of each lane are zero and would read table[0];
        // the 0xff mask keeps only the intended low byte.
        let qexp = _mm_and_si128(_mm_shuffle_epi8(tab, wq), _mm_set1_epi32(0xff));
        let expf = _mm_slli_epi32::<23>(qexp);
        let sign = _mm_slli_epi32::<28>(_mm_and_si128(wq, _mm_set1_epi32(8)));
        _mm_castsi128_ps(_mm_or_si128(expf, sign))
    }

    /// SSE edition of [`half_to_f32_avx2`] (same algorithm, 4 lanes).
    #[inline]
    #[target_feature(enable = "sse4.1")]
    unsafe fn half_to_f32_sse41(h: __m128i) -> __m128 {
        let magnitude = _mm_slli_epi32::<13>(_mm_and_si128(h, _mm_set1_epi32(0x7fff)));
        let exp16 = _mm_and_si128(magnitude, _mm_set1_epi32(0x7c00 << 13));
        let norm = _mm_add_epi32(magnitude, _mm_set1_epi32((127 - 15) << 23));
        let magic = _mm_castsi128_ps(_mm_set1_epi32(113 << 23));
        let sub =
            _mm_sub_ps(_mm_castsi128_ps(_mm_add_epi32(norm, _mm_set1_epi32(1 << 23))), magic);
        let is_sub = _mm_cmpeq_epi32(exp16, _mm_setzero_si128());
        let val = _mm_blendv_epi8(norm, _mm_castps_si128(sub), is_sub);
        let sign = _mm_slli_epi32::<16>(_mm_and_si128(h, _mm_set1_epi32(0x8000)));
        _mm_castsi128_ps(_mm_or_si128(val, sign))
    }

    /// SSE edition of [`full_values_avx2`] (4 lanes).
    #[inline]
    #[target_feature(enable = "sse4.1")]
    unsafe fn full_values_sse41(wq: __m128i, wr: __m128i) -> __m128 {
        let one = _mm_set1_epi32(1);
        let sign = _mm_slli_epi32::<12>(_mm_and_si128(wq, _mm_set1_epi32(8)));
        let code = _mm_and_si128(wq, _mm_set1_epi32(7));
        let flag = _mm_and_si128(_mm_srli_epi32::<11>(wr), one);
        let e0 = _mm_and_si128(_mm_srli_epi32::<10>(wr), one);
        let man = _mm_and_si128(wr, _mm_set1_epi32(0x3ff));
        let mux_tab = _mm_loadu_si128(MUX_EHIGH_B.as_ptr() as *const __m128i);
        let mux = _mm_and_si128(_mm_shuffle_epi8(mux_tab, code), _mm_set1_epi32(0xff));
        let flagged = _mm_cmpeq_epi32(flag, one);
        let ehigh = _mm_blendv_epi8(code, mux, flagged);
        let exp = _mm_or_si128(_mm_slli_epi32::<1>(ehigh), e0);
        let f16 = _mm_or_si128(sign, _mm_or_si128(_mm_slli_epi32::<10>(exp), man));
        half_to_f32_sse41(f16)
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn decode_draft_row_pair_sse41(
        prow: &[u8],
        pre: &[f32],
        lut: &[f32; 16],
        lo: &mut [f32],
        hi: &mut [f32],
    ) {
        let w = prow.len();
        let nib = _mm_set1_epi32(0xf);
        let mut j = 0;
        while j + 4 <= w {
            let four = (prow.as_ptr().add(j) as *const i32).read_unaligned();
            let bytes = _mm_cvtepu8_epi32(_mm_cvtsi32_si128(four));
            let pre_v = _mm_loadu_ps(pre.as_ptr().add(j));
            let wq_lo = _mm_and_si128(bytes, nib);
            let wq_hi = _mm_and_si128(_mm_srli_epi32::<4>(bytes), nib);
            _mm_storeu_ps(lo.as_mut_ptr().add(j), _mm_mul_ps(draft_bits_sse41(wq_lo), pre_v));
            _mm_storeu_ps(hi.as_mut_ptr().add(j), _mm_mul_ps(draft_bits_sse41(wq_hi), pre_v));
            j += 4;
        }
        decode_draft_row_pair_scalar(&prow[j..], &pre[j..], lut, &mut lo[j..], &mut hi[j..]);
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn decode_full_row_pair_sse41(
        prow: &[u8],
        rrow: &[u8],
        lo: &mut [f32],
        hi: &mut [f32],
    ) {
        let w = prow.len();
        let nib = _mm_set1_epi32(0xf);
        let r0_shuf = _mm_loadu_si128(R0_SHUF128.as_ptr() as *const __m128i);
        let r1_shuf = _mm_loadu_si128(R1_SHUF128.as_ptr() as *const __m128i);
        let mask12 = _mm_set1_epi32(0xfff);
        let mut j = 0;
        while j + 4 <= w {
            let four = (prow.as_ptr().add(j) as *const i32).read_unaligned();
            let bytes = _mm_cvtepu8_epi32(_mm_cvtsi32_si128(four));
            let wq_lo = _mm_and_si128(bytes, nib);
            let wq_hi = _mm_and_si128(_mm_srli_epi32::<4>(bytes), nib);
            // 12 residual bytes for these 4 columns: an 8-byte load plus a
            // 4-byte insert (both in-bounds; 3*j + 12 <= 3*w).
            let head = _mm_loadl_epi64(rrow.as_ptr().add(3 * j) as *const __m128i);
            let tail = (rrow.as_ptr().add(3 * j + 8) as *const i32).read_unaligned();
            let v = _mm_insert_epi32::<2>(head, tail);
            let r0 = _mm_and_si128(_mm_shuffle_epi8(v, r0_shuf), mask12);
            let r1 = _mm_and_si128(_mm_srli_epi32::<4>(_mm_shuffle_epi8(v, r1_shuf)), mask12);
            _mm_storeu_ps(lo.as_mut_ptr().add(j), full_values_sse41(wq_lo, r0));
            _mm_storeu_ps(hi.as_mut_ptr().add(j), full_values_sse41(wq_hi, r1));
            j += 4;
        }
        decode_full_row_pair_scalar(&prow[j..], &rrow[3 * j..], &mut lo[j..], &mut hi[j..]);
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{decode_draft_row_pair_scalar, decode_full_row_pair_scalar};
    use core::arch::aarch64::*;

    /// `CODE_TO_QEXP + 112` indexed by the full 4-bit `w_q` via `tbl`.
    const QEXP_BIASED_B: [u8; 16] =
        [121, 114, 123, 118, 120, 122, 124, 126, 121, 114, 123, 118, 120, 122, 124, 126];
    const MUX_EHIGH_B: [u8; 16] = [4, 0, 5, 2, 4, 0, 5, 2, 4, 0, 5, 2, 4, 0, 5, 2];
    // Residual extraction for 4 columns from vcombine(bytes[0..8],
    // bytes[4..12]): global byte g maps to index g (g < 8) or g + 4
    // (g >= 8); 0xff indexes read as zero.
    const R0_TBL: [u8; 16] = [0, 1, 255, 255, 3, 4, 255, 255, 6, 7, 255, 255, 13, 14, 255, 255];
    const R1_TBL: [u8; 16] = [1, 2, 255, 255, 4, 5, 255, 255, 7, 12, 255, 255, 14, 15, 255, 255];

    /// Load 4 prefix bytes into the low byte of four u32 lanes.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn load4_u32(p: *const u8) -> uint32x4_t {
        let lanes =
            [*p as u32, *p.add(1) as u32, *p.add(2) as u32, *p.add(3) as u32];
        vld1q_u32(lanes.as_ptr())
    }

    /// `tbl` lookup keyed by the low byte of each u32 lane, masked back to
    /// one byte (index bytes 1..3 are zero and would read `table[0]`).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn tbl_u32(table: &[u8; 16], idx: uint32x4_t) -> uint32x4_t {
        let t = vld1q_u8(table.as_ptr());
        let looked = vqtbl1q_u8(t, vreinterpretq_u8_u32(idx));
        vandq_u32(vreinterpretq_u32_u8(looked), vdupq_n_u32(0xff))
    }

    /// Draft f32 bits for 4 lanes of 4-bit `w_q`.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn draft_bits_neon(wq: uint32x4_t) -> float32x4_t {
        let expf = vshlq_n_u32::<23>(tbl_u32(&QEXP_BIASED_B, wq));
        let sign = vshlq_n_u32::<28>(vandq_u32(wq, vdupq_n_u32(8)));
        vreinterpretq_f32_u32(vorrq_u32(expf, sign))
    }

    /// NEON edition of the branch-free FP16 → f32 widening (exponent <=
    /// 15 guaranteed by the Fig. 5(b) reconstruction).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn half_to_f32_neon(h: uint32x4_t) -> float32x4_t {
        let magnitude = vshlq_n_u32::<13>(vandq_u32(h, vdupq_n_u32(0x7fff)));
        let exp16 = vandq_u32(magnitude, vdupq_n_u32(0x7c00 << 13));
        let norm = vaddq_u32(magnitude, vdupq_n_u32((127 - 15) << 23));
        let magic = vreinterpretq_f32_u32(vdupq_n_u32(113 << 23));
        let sub = vsubq_f32(
            vreinterpretq_f32_u32(vaddq_u32(norm, vdupq_n_u32(1 << 23))),
            magic,
        );
        let is_sub = vceqq_u32(exp16, vdupq_n_u32(0));
        let val = vbslq_u32(is_sub, vreinterpretq_u32_f32(sub), norm);
        let sign = vshlq_n_u32::<16>(vandq_u32(h, vdupq_n_u32(0x8000)));
        vreinterpretq_f32_u32(vorrq_u32(val, sign))
    }

    /// Fig. 5(b) reconstruction for 4 lanes.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn full_values_neon(wq: uint32x4_t, wr: uint32x4_t) -> float32x4_t {
        let one = vdupq_n_u32(1);
        let sign = vshlq_n_u32::<12>(vandq_u32(wq, vdupq_n_u32(8)));
        let code = vandq_u32(wq, vdupq_n_u32(7));
        let flag = vandq_u32(vshrq_n_u32::<11>(wr), one);
        let e0 = vandq_u32(vshrq_n_u32::<10>(wr), one);
        let man = vandq_u32(wr, vdupq_n_u32(0x3ff));
        let mux = tbl_u32(&MUX_EHIGH_B, code);
        let flagged = vceqq_u32(flag, one);
        let ehigh = vbslq_u32(flagged, mux, code);
        let exp = vorrq_u32(vshlq_n_u32::<1>(ehigh), e0);
        let f16 = vorrq_u32(sign, vorrq_u32(vshlq_n_u32::<10>(exp), man));
        half_to_f32_neon(f16)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn decode_draft_row_pair_neon(
        prow: &[u8],
        pre: &[f32],
        lut: &[f32; 16],
        lo: &mut [f32],
        hi: &mut [f32],
    ) {
        let w = prow.len();
        let nib = vdupq_n_u32(0xf);
        let mut j = 0;
        while j + 4 <= w {
            let bytes = load4_u32(prow.as_ptr().add(j));
            let pre_v = vld1q_f32(pre.as_ptr().add(j));
            let wq_lo = vandq_u32(bytes, nib);
            let wq_hi = vandq_u32(vshrq_n_u32::<4>(bytes), nib);
            vst1q_f32(lo.as_mut_ptr().add(j), vmulq_f32(draft_bits_neon(wq_lo), pre_v));
            vst1q_f32(hi.as_mut_ptr().add(j), vmulq_f32(draft_bits_neon(wq_hi), pre_v));
            j += 4;
        }
        decode_draft_row_pair_scalar(&prow[j..], &pre[j..], lut, &mut lo[j..], &mut hi[j..]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn decode_full_row_pair_neon(
        prow: &[u8],
        rrow: &[u8],
        lo: &mut [f32],
        hi: &mut [f32],
    ) {
        let w = prow.len();
        let nib = vdupq_n_u32(0xf);
        let mask12 = vdupq_n_u32(0xfff);
        let r0_tbl = vld1q_u8(R0_TBL.as_ptr());
        let r1_tbl = vld1q_u8(R1_TBL.as_ptr());
        let mut j = 0;
        while j + 4 <= w {
            let bytes = load4_u32(prow.as_ptr().add(j));
            let wq_lo = vandq_u32(bytes, nib);
            let wq_hi = vandq_u32(vshrq_n_u32::<4>(bytes), nib);
            // 12 residual bytes via two overlapping in-bounds 8-byte
            // loads (3*j + 12 <= 3*w).
            let head = vld1_u8(rrow.as_ptr().add(3 * j));
            let tail = vld1_u8(rrow.as_ptr().add(3 * j + 4));
            let v = vcombine_u8(head, tail);
            let r0 = vandq_u32(vreinterpretq_u32_u8(vqtbl1q_u8(v, r0_tbl)), mask12);
            let r1 = vandq_u32(
                vshrq_n_u32::<4>(vreinterpretq_u32_u8(vqtbl1q_u8(v, r1_tbl))),
                mask12,
            );
            vst1q_f32(lo.as_mut_ptr().add(j), full_values_neon(wq_lo, r0));
            vst1q_f32(hi.as_mut_ptr().add(j), full_values_neon(wq_hi, r1));
            j += 4;
        }
        decode_full_row_pair_scalar(&prow[j..], &rrow[3 * j..], &mut lo[j..], &mut hi[j..]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsfp::planes::pack_residuals;

    #[test]
    fn parse_vocabulary() {
        assert_eq!(SimdLevel::parse("scalar"), Some(SimdLevel::Scalar));
        assert_eq!(SimdLevel::parse("SSE4.1"), Some(SimdLevel::Sse41));
        assert_eq!(SimdLevel::parse("sse41"), Some(SimdLevel::Sse41));
        assert_eq!(SimdLevel::parse("avx2"), Some(SimdLevel::Avx2));
        assert_eq!(SimdLevel::parse("neon"), Some(SimdLevel::Neon));
        assert_eq!(SimdLevel::parse("auto"), Some(SimdLevel::detect()));
        assert_eq!(SimdLevel::parse("bogus"), None);
    }

    #[test]
    fn available_is_scalar_first_and_detect_last() {
        let avail = SimdLevel::available();
        assert_eq!(avail[0], SimdLevel::Scalar);
        assert_eq!(*avail.last().unwrap(), SimdLevel::detect());
        for level in avail {
            assert!(level.is_available());
            assert_eq!(level.resolve(), level);
            assert!(level.lanes() >= 1);
        }
    }

    #[test]
    fn scalar_full_decode_matches_remap_reference() {
        // The scalar row-pair decoder against the element-wise remap
        // primitives, over every (w_q, w_r) combination.
        let mut lo = [0.0f32; 1];
        let mut hi = [0.0f32; 1];
        for wq in 0..16u8 {
            for wr in 0..4096u16 {
                let prow = [wq | (wq << 4)];
                let rrow = pack_residuals(&[wr, wr], 2, 1);
                decode_full_row_pair_scalar(&prow, &rrow, &mut lo, &mut hi);
                let want =
                    f16_bits_to_f32(decode_full_bits(BsfpCode { w_q: wq, w_r: wr }));
                assert_eq!(lo[0].to_bits(), want.to_bits(), "wq={wq} wr={wr}");
                assert_eq!(hi[0].to_bits(), want.to_bits(), "wq={wq} wr={wr}");
            }
        }
    }

    #[test]
    fn simd_full_decode_matches_scalar_exhaustively() {
        // Every (w_q, w_r) bit pattern — including ones no encoder emits —
        // through every available tier, at a width that exercises both the
        // vector body and the scalar tail (19 = 2*8 + 3 = 4*4 + 3).
        let width = 19usize;
        let levels = SimdLevel::available();
        let mut cursor = 0u64;
        let mut next = || {
            // Deterministic LCG over (w_q, w_r) pattern space.
            cursor = cursor.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((cursor >> 20) & 0xffff) as u16
        };
        for round in 0..64 {
            let mut w_q = vec![0u8; 2 * width];
            let mut w_r = vec![0u16; 2 * width];
            for j in 0..2 * width {
                let bits = next();
                w_q[j] = (bits & 0xf) as u8;
                w_r[j] = (bits >> 4) & 0xfff;
            }
            // Row-pair layout: rows 0 and 1 of a (2, width) matrix.
            let mut prow = vec![0u8; width];
            for j in 0..width {
                prow[j] = w_q[j] | (w_q[width + j] << 4);
            }
            let rrow = pack_residuals(&w_r, 2, width);
            let mut slo = vec![0.0f32; width];
            let mut shi = vec![0.0f32; width];
            decode_full_row_pair_scalar(&prow, &rrow, &mut slo, &mut shi);
            for &level in &levels {
                let mut vlo = vec![f32::NAN; width];
                let mut vhi = vec![f32::NAN; width];
                decode_full_row_pair(level, &prow, &rrow, &mut vlo, &mut vhi);
                for j in 0..width {
                    assert_eq!(
                        vlo[j].to_bits(),
                        slo[j].to_bits(),
                        "{} lo round={round} col={j} wq={} wr={}",
                        level.name(),
                        w_q[j],
                        w_r[j]
                    );
                    assert_eq!(
                        vhi[j].to_bits(),
                        shi[j].to_bits(),
                        "{} hi round={round} col={j}",
                        level.name()
                    );
                }
            }
        }
        // And the dense sweep: all 16 x 4096 patterns at width 1 (pure
        // scalar tail) and width 8/4 (pure vector body).
        let lut = draft_lut();
        let _ = lut;
        for wq in 0..16u8 {
            for wr in (0..4096u16).step_by(7) {
                let width = 8usize;
                let prow = vec![wq | (wq << 4); width];
                let w_r = vec![wr; 2 * width];
                let rrow = pack_residuals(&w_r, 2, width);
                let mut slo = vec![0.0f32; width];
                let mut shi = vec![0.0f32; width];
                decode_full_row_pair_scalar(&prow, &rrow, &mut slo, &mut shi);
                for &level in &levels {
                    let mut vlo = vec![f32::NAN; width];
                    let mut vhi = vec![f32::NAN; width];
                    decode_full_row_pair(level, &prow, &rrow, &mut vlo, &mut vhi);
                    for j in 0..width {
                        assert_eq!(
                            vlo[j].to_bits(),
                            slo[j].to_bits(),
                            "{} wq={wq} wr={wr} col={j}",
                            level.name()
                        );
                        assert_eq!(vhi[j].to_bits(), shi[j].to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn simd_draft_decode_matches_scalar_exhaustively() {
        let lut = draft_lut();
        let levels = SimdLevel::available();
        // All 256 packed prefix bytes, awkward widths around every lane
        // count, and pre factors spanning sign/zero/subnormal-adjacent.
        let pres = [1.0f32, 0.5, -0.25, 0.0, 1.0e-20, 3.141592e4, -7.5e-3];
        for width in [1usize, 3, 4, 5, 7, 8, 9, 16, 17, 31] {
            let mut prow = vec![0u8; width];
            let mut pre = vec![0.0f32; width];
            for j in 0..width {
                prow[j] = ((j * 37 + width * 11) % 256) as u8;
                pre[j] = pres[j % pres.len()] * (1.0 + j as f32 * 0.125);
            }
            let mut slo = vec![0.0f32; width];
            let mut shi = vec![0.0f32; width];
            decode_draft_row_pair_scalar(&prow, &pre, &lut, &mut slo, &mut shi);
            for &level in &levels {
                let mut vlo = vec![f32::NAN; width];
                let mut vhi = vec![f32::NAN; width];
                decode_draft_row_pair(level, &prow, &pre, &lut, &mut vlo, &mut vhi);
                for j in 0..width {
                    assert_eq!(
                        vlo[j].to_bits(),
                        slo[j].to_bits(),
                        "{} width={width} col={j} byte={}",
                        level.name(),
                        prow[j]
                    );
                    assert_eq!(vhi[j].to_bits(), shi[j].to_bits());
                }
            }
        }
        // Dense byte sweep: every packed byte value in the vector body.
        for base in (0..256usize).step_by(8) {
            let prow: Vec<u8> = (0..8).map(|j| ((base + j) % 256) as u8).collect();
            let pre = vec![0.173828125f32; 8];
            let mut slo = vec![0.0f32; 8];
            let mut shi = vec![0.0f32; 8];
            decode_draft_row_pair_scalar(&prow, &pre, &lut, &mut slo, &mut shi);
            for &level in &levels {
                let mut vlo = vec![f32::NAN; 8];
                let mut vhi = vec![f32::NAN; 8];
                decode_draft_row_pair(level, &prow, &pre, &lut, &mut vlo, &mut vhi);
                assert_eq!(
                    vlo.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    slo.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{} base={base}",
                    level.name()
                );
                assert_eq!(
                    vhi.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    shi.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
            }
        }
    }

    #[test]
    fn draft_lut_entries_are_exact_powers_of_two() {
        // The hoisted `scale / tensor_scale` factorization is bitwise
        // exact only because multiplying by a LUT entry is an exact
        // power-of-two scaling; pin that property.
        for (c, &v) in draft_lut().iter().enumerate() {
            let bits = v.to_bits();
            assert_eq!(bits & 0x007f_ffff, 0, "code {c}: mantissa not zero");
            let (sign, qexp) = super::super::remap::decode_draft_exp(c as u8);
            assert_eq!(bits >> 31, sign as u32, "code {c}");
            assert_eq!((bits >> 23) & 0xff, (qexp as u32) + 112, "code {c}");
        }
    }
}
