//! Nibble packing for `W_q` streams.
//!
//! Layout matches `QuantizedTensor.packed_wq` on the Python side and the
//! Pallas `qmatmul` kernel's expectation: element `2i` in the low nibble,
//! `2i+1` in the high nibble, packed along the *in* dimension (axis 0) of a
//! column-major-by-row (in, out) weight.

/// Pack a (k, n) row-major `W_q` matrix (4 significant bits per entry) into
/// a (k/2, n) row-major byte matrix. `k` must be even.
pub fn pack_nibbles(w_q: &[u8], k: usize, n: usize) -> Vec<u8> {
    assert_eq!(w_q.len(), k * n, "w_q length mismatch");
    assert_eq!(k % 2, 0, "in-dim must be even to nibble-pack");
    let mut out = vec![0u8; k / 2 * n];
    for kp in 0..k / 2 {
        let lo_row = &w_q[(2 * kp) * n..(2 * kp + 1) * n];
        let hi_row = &w_q[(2 * kp + 1) * n..(2 * kp + 2) * n];
        let dst = &mut out[kp * n..(kp + 1) * n];
        for j in 0..n {
            dst[j] = (lo_row[j] & 0xf) | ((hi_row[j] & 0xf) << 4);
        }
    }
    out
}

/// Inverse of [`pack_nibbles`].
pub fn unpack_nibbles(packed: &[u8], k: usize, n: usize) -> Vec<u8> {
    assert_eq!(packed.len(), k / 2 * n, "packed length mismatch");
    let mut out = vec![0u8; k * n];
    for kp in 0..k / 2 {
        let src = &packed[kp * n..(kp + 1) * n];
        for j in 0..n {
            out[(2 * kp) * n + j] = src[j] & 0xf;
            out[(2 * kp + 1) * n + j] = (src[j] >> 4) & 0xf;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let k = 6;
        let n = 3;
        let w: Vec<u8> = (0..k * n).map(|i| (i % 16) as u8).collect();
        let packed = pack_nibbles(&w, k, n);
        assert_eq!(packed.len(), k / 2 * n);
        assert_eq!(unpack_nibbles(&packed, k, n), w);
    }

    #[test]
    fn layout_matches_python_convention() {
        // w[0][0]=0xA (low nibble), w[1][0]=0x5 (high nibble) -> 0x5A.
        let w = vec![0xA, 0x5];
        let packed = pack_nibbles(&w, 2, 1);
        assert_eq!(packed, vec![0x5A]);
    }
}
