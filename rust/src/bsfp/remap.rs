//! Fig. 3 exponent remapping tables and the scalar encode/decode primitives.
//!
//! These scalar functions are the semantic ground truth; the vectorized
//! decode paths in [`super::simd`] re-express [`decode_draft_exp`] /
//! [`decode_full_bits`] as in-register table shuffles over the same
//! constants and are tested bitwise-equal against them.

use super::fp16::{join_fields, split_fields, Fp16Fields};

/// FP16 exponent bias.
pub const FP16_BIAS: i32 = 15;
/// Quantization group size (paper §III-B: fine-grained groups of 128).
pub const GROUP_SIZE: usize = 128;

/// Remapped E3M0 code per original exponent `E ∈ [0, 15]` (Fig. 3).
///
/// Codes 3'b000 / 3'b010 are stolen for the critical exponents 9 / 11; the
/// low-magnitude pairs {0,1} and {4,5} round up into codes 001 / 011.
pub const REMAP_CODE: [u8; 16] = [1, 1, 1, 1, 3, 3, 3, 3, 4, 0, 5, 2, 6, 6, 7, 7];

/// Remap flag per original exponent: set when the stored bits differ from
/// the original (the wasted-bit correction signal).
pub const REMAP_FLAG: [u8; 16] = [1, 1, 0, 0, 1, 1, 0, 0, 0, 1, 0, 1, 0, 0, 0, 0];

/// Fig. 5(a) draft decoder LUT: 3-bit code -> quantized exponent value.
pub const CODE_TO_QEXP: [u8; 8] = [9, 2, 11, 6, 8, 10, 12, 14];

/// Fig. 5(b) full decoder MUX: for flagged values, keyed by `(c1, c0)`,
/// the top exponent bits `E[4:1]` (then `E = mux << 1 | e0`).
pub const FLAG_MUX_EHIGH: [u8; 4] = [4, 0, 5, 2];

/// One encoded weight: `(W_q, W_r)` as raw small integers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BsfpCode {
    /// 4 significant bits: `[sign | c2 c1 c0]`.
    pub w_q: u8,
    /// 12 significant bits: `[flag | e0 | m9..m0]`.
    pub w_r: u16,
}

/// Encode one FP16 bit pattern. Panics in debug builds if `exp > 15`
/// (callers must apply the Algorithm-1 pre-scale first).
#[inline]
pub fn encode_bits(bits: u16) -> BsfpCode {
    let Fp16Fields { sign, exp, man } = split_fields(bits);
    debug_assert!(exp <= 15, "exponent {exp} > 15: Algorithm-1 pre-scale missing");
    let exp = (exp & 0xf) as usize;
    let code = REMAP_CODE[exp];
    let flag = REMAP_FLAG[exp] as u16;
    let e0 = (exp as u16) & 1;
    BsfpCode { w_q: (sign << 3) | code, w_r: (flag << 11) | (e0 << 10) | man }
}

/// Total-function variant of [`encode_bits`]: `None` when the exponent is
/// outside BSFP's domain (`exp > 15` — values `>= 2.0`, infinities, NaNs),
/// which callers must handle by the Algorithm-1 pre-scale or a dense
/// fallback.  The bit-plane weight store uses this to classify tensors.
#[inline]
pub fn try_encode_bits(bits: u16) -> Option<BsfpCode> {
    if split_fields(bits).exp > 15 {
        return None;
    }
    Some(encode_bits(bits))
}

/// Fig. 5(b): losslessly reconstruct the original FP16 bit pattern.
#[inline]
pub fn decode_full_bits(c: BsfpCode) -> u16 {
    let sign = (c.w_q >> 3) & 1;
    let code = c.w_q & 0x7;
    let flag = (c.w_r >> 11) & 1;
    let e0 = ((c.w_r >> 10) & 1) as u8;
    let man = c.w_r & 0x3ff;
    let ehigh = if flag == 1 { FLAG_MUX_EHIGH[(code & 0x3) as usize] } else { code };
    let exp = (ehigh << 1) | e0;
    join_fields(Fp16Fields { sign, exp, man })
}

/// Fig. 5(a): draft decode — `(sign, quantized exponent value)`.
#[inline]
pub fn decode_draft_exp(w_q: u8) -> (u8, u8) {
    ((w_q >> 3) & 1, CODE_TO_QEXP[(w_q & 0x7) as usize])
}

/// Unscaled draft value `(-1)^s · 2^(Q(E) - 15)`.
#[inline]
pub fn draft_value(w_q: u8) -> f32 {
    let (sign, qexp) = decode_draft_exp(w_q);
    let mag = (qexp as i32 - FP16_BIAS) as f32;
    let v = mag.exp2();
    if sign == 1 {
        -v
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsfp::fp16::{f16_bits_to_f32, f32_to_f16_bits};

    /// Fig. 3's literal rows: original exponent -> (stored 5-bit field, value).
    #[test]
    fn fig3_remap_rows() {
        // (E, expected quantized value, expected flag)
        let rows = [
            (0u8, 2u8, 1u8),
            (1, 2, 1),
            (2, 2, 0),
            (3, 2, 0),
            (4, 6, 1),
            (5, 6, 1),
            (6, 6, 0),
            (7, 6, 0),
            (8, 8, 0),
            (9, 9, 1),
            (10, 10, 0),
            (11, 11, 1),
            (12, 12, 0),
            (13, 12, 0),
            (14, 14, 0),
            (15, 14, 0),
        ];
        for (e, qval, flag) in rows {
            let code = REMAP_CODE[e as usize];
            assert_eq!(CODE_TO_QEXP[code as usize], qval, "E={e}");
            assert_eq!(REMAP_FLAG[e as usize], flag, "E={e}");
        }
    }

    #[test]
    fn lossless_roundtrip_all_valid_patterns() {
        // Every FP16 pattern with exponent <= 15 (sign x 16 exps x 1024 mans).
        for s in 0..2u16 {
            for e in 0..16u16 {
                for m in 0..1024u16 {
                    let bits = (s << 15) | (e << 10) | m;
                    assert_eq!(decode_full_bits(encode_bits(bits)), bits);
                }
            }
        }
    }

    #[test]
    fn stolen_codes_decode_to_critical_exponents() {
        // Codes 3'b000 and 3'b010 are the remapped 9 and 11.
        assert_eq!(decode_draft_exp(0b0000).1, 9);
        assert_eq!(decode_draft_exp(0b0010).1, 11);
        // Sign bit passes through.
        assert_eq!(decode_draft_exp(0b1000), (1, 9));
    }

    #[test]
    fn draft_value_sign_and_scale() {
        // code 4 => qexp 8 => 2^-7
        assert_eq!(draft_value(0b0100), (2.0f32).powi(-7));
        assert_eq!(draft_value(0b1100), -(2.0f32).powi(-7));
    }

    #[test]
    fn draft_exponent_matches_quantized_fp16_value() {
        // For an in-range weight, the draft magnitude is 2^(Q(E)-15) where
        // Q(E) follows the remap table.
        let w = 0.037_f32; // exp ~ 10
        let bits = f32_to_f16_bits(w);
        let e = super::super::fp16::split_fields(bits).exp;
        let c = encode_bits(bits);
        let (_, qexp) = decode_draft_exp(c.w_q);
        assert_eq!(qexp, CODE_TO_QEXP[REMAP_CODE[e as usize] as usize]);
        // And reconstruction is exact.
        assert_eq!(f16_bits_to_f32(decode_full_bits(c)), f16_bits_to_f32(bits));
    }
}
