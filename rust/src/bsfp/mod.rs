//! Bit-Sharing Floating Point (BSFP) — the paper's core algorithm.
//!
//! Mirrors `python/compile/bsfp.py` bit-for-bit (cross-checked against the
//! exhaustive golden vectors in `artifacts/goldens.bin`).
//!
//! Layout per FP16 weight `s eeeee mmmmmmmmmm` (exponent confined to
//! `[0, 15]` after the Algorithm-1 pre-scale — the paper's Fig. 2(c)
//! observation that the top exponent bit of trained LLM weights is wasted):
//!
//! ```text
//!   W_q (4 bits)  = [sign | c2 c1 c0]      remapped E3M0 code (Fig. 3)
//!   W_r (12 bits) = [flag | e0 | m9..m0]   remainder; flag sits where the
//!                                          wasted e4 bit was
//! ```
//!
//! `W_q ∥ W_r` is exactly 16 bits (zero storage overhead) and reconstructs
//! the original FP16 value losslessly through the Fig. 5(b) decoder.  `W_q`
//! alone, with per-128-group Eq. 4 scales, is the 4-bit draft model.
//!
//! [`PlanePair`] materializes that split as the resident layout of the native
//! backend's packed weight store: a nibble-packed *prefix plane* (`W_q`,
//! the quarter-traffic draft stream) and a 12-bit-packed *residual plane*
//! (`W_r`, additionally streamed by the full/verify pass), decoded on the
//! fly by the `runtime::kernels` GEMM kernels.

mod bf16;
mod codec;
mod decoder;
mod fp16;
mod pack;
mod planes;
mod remap;
pub mod simd;

pub use bf16::{bf16_to_f32, bf16_to_speq_fp16, convert_bf16_tensor, f32_to_bf16, speq_fp16_to_bf16};
pub use codec::{
    algorithm1_prescale, encode_tensor, eq4_scales, fp16_exact_in_domain, quantize_tensor,
    QuantizedTensor,
};
pub use decoder::{decode_draft_gate, decode_full_gate, DecoderUnit};
pub use fp16::{
    exponent_histogram, f16_bits_to_f32, f32_to_f16_bits, split_fields, Fp16Fields,
};
pub use pack::{pack_nibbles, unpack_nibbles};
pub use planes::{pack_residuals, unpack_residuals, PlanePair};
pub use remap::{
    decode_draft_exp, decode_full_bits, draft_value, encode_bits, try_encode_bits, BsfpCode,
    CODE_TO_QEXP, FP16_BIAS, GROUP_SIZE, REMAP_CODE, REMAP_FLAG,
};
pub use simd::SimdLevel;
