//! Gate-level emulation of the Fig. 5 BSFP decoders.
//!
//! These mirror the hardware datapath (NOR gate + bit rewiring for the draft
//! decoder, MUX-based reconstruction for the full decoder) rather than the
//! LUTs in [`super::remap`].  Tests prove both formulations equivalent — the
//! same argument the paper makes for the decoder's 3.5% area cost being the
//! only overhead of remapping.  The [`DecoderUnit`] also counts gate-level
//! activity so the accelerator energy model (Table IV) can charge it.

use super::remap::{decode_draft_exp, decode_full_bits, BsfpCode};

/// Fig. 5(a): draft decoder as the paper's gate structure.
///
/// Input: 3-bit code.  `NOR(bit0, bit2)` detects the stolen codes 3'b000 and
/// 3'b010; if set, the output is wired `[1, 0, c1, 1]` (i.e. 9 or 11 with
/// `c1` selecting), otherwise the code is shifted left ("a zero is appended").
#[inline]
pub fn decode_draft_gate(code: u8) -> u8 {
    let b0 = code & 1;
    let b1 = (code >> 1) & 1;
    let b2 = (code >> 2) & 1;
    let nor = ((b0 | b2) ^ 1) & 1;
    if nor == 1 {
        // [bit3, bit2, bit1, bit0] = [1, 0, c1, 1]
        (1 << 3) | (b1 << 1) | 1
    } else {
        code << 1
    }
}

/// Fig. 5(b): full decoder as the paper's MUX structure.
///
/// Inputs: 3-bit code + 2-bit `W_r` exponent part `[flag, e0]`.  If `flag`
/// is 0 the parts concatenate directly; otherwise a 2-in/3-out MUX keyed on
/// `(c1, c0)` produces `E[3:1]` (with `E[4] = 0` always), concatenated with
/// `e0`.
#[inline]
pub fn decode_full_gate(code: u8, flag: u8, e0: u8) -> u8 {
    if flag & 1 == 0 {
        (code << 1) | (e0 & 1)
    } else {
        let mux = match code & 0x3 {
            0b00 => 0b100, // stolen 000: E = 9  -> E[3:1] = 100
            0b01 => 0b000, // rounded {0,1}:     E[3:1] = 000
            0b10 => 0b101, // stolen 010: E = 11 -> E[3:1] = 101
            _ => 0b010,    // rounded {4,5}:     E[3:1] = 010
        };
        (mux << 1) | (e0 & 1)
    }
}

/// A decoder unit instance with activity counters for the energy model.
#[derive(Debug, Default, Clone)]
pub struct DecoderUnit {
    pub draft_decodes: u64,
    pub full_decodes: u64,
    /// How many decodes hit the flagged (lookup) path.
    pub flagged: u64,
}

impl DecoderUnit {
    pub fn new() -> Self {
        Self::default()
    }

    /// Decode a draft weight (quantize mode), counting activity.
    pub fn draft(&mut self, w_q: u8) -> (u8, u8) {
        self.draft_decodes += 1;
        let sign = (w_q >> 3) & 1;
        let qexp = decode_draft_gate(w_q & 0x7);
        (sign, qexp)
    }

    /// Decode a full weight (full mode), counting activity.
    pub fn full(&mut self, c: BsfpCode) -> u16 {
        self.full_decodes += 1;
        if (c.w_r >> 11) & 1 == 1 {
            self.flagged += 1;
        }
        decode_full_bits(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsfp::remap::{encode_bits, CODE_TO_QEXP};

    #[test]
    fn gate_draft_decoder_equals_lut() {
        for code in 0..8u8 {
            assert_eq!(decode_draft_gate(code), CODE_TO_QEXP[code as usize], "code {code}");
        }
        for w_q in 0..16u8 {
            let mut unit = DecoderUnit::new();
            assert_eq!(unit.draft(w_q), decode_draft_exp(w_q));
        }
    }

    #[test]
    fn gate_full_decoder_equals_lut_for_all_valid_patterns() {
        for s in 0..2u16 {
            for e in 0..16u16 {
                for m in 0..1024u16 {
                    let bits = (s << 15) | (e << 10) | m;
                    let c = encode_bits(bits);
                    let code = c.w_q & 0x7;
                    let flag = ((c.w_r >> 11) & 1) as u8;
                    let e0 = ((c.w_r >> 10) & 1) as u8;
                    let exp = decode_full_gate(code, flag, e0);
                    assert_eq!(exp as u16, e, "bits {bits:#06x}");
                }
            }
        }
    }

    #[test]
    fn decoder_unit_counts_activity() {
        let mut unit = DecoderUnit::new();
        let c = encode_bits(0x0000); // E=0 -> flagged
        unit.full(c);
        unit.draft(c.w_q);
        assert_eq!(unit.full_decodes, 1);
        assert_eq!(unit.draft_decodes, 1);
        assert_eq!(unit.flagged, 1);
    }
}
