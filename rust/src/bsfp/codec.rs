//! Tensor-level BSFP quantization: Algorithm 1 + encode + Eq. 4 scales.

use super::fp16::{f16_bits_to_f32, f32_to_f16_bits, split_fields};
use super::pack::pack_nibbles;
use super::planes::PlanePair;
use super::remap::{decode_full_bits, draft_value, encode_bits, BsfpCode, GROUP_SIZE};

/// Whether every value is exactly FP16-representable with exponent in
/// BSFP's domain (`exp <= 15`, i.e. `|v| < 2.0`) — the condition under
/// which the bit-plane store reproduces the tensor losslessly for the
/// full pass with no Algorithm-1 pre-scale and no dense copy.
pub fn fp16_exact_in_domain(w: &[f32]) -> bool {
    w.iter().all(|&v| {
        let bits = f32_to_f16_bits(v);
        split_fields(bits).exp <= 15 && f16_bits_to_f32(bits).to_bits() == v.to_bits()
    })
}

/// A BSFP-quantized linear weight of shape `(k, n)` (in, out), row-major.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    /// 4-bit codes, one byte each (unpacked), row-major `(k, n)`.
    pub w_q: Vec<u8>,
    /// 12-bit remainders, row-major `(k, n)`.
    pub w_r: Vec<u16>,
    /// Eq. 4 group scales, row-major `(k / GROUP_SIZE, n)`.
    pub scales: Vec<f32>,
    /// Algorithm-1 per-tensor pre-scale (1.0 when `max|W|` stays below
    /// the FP16 rounding midpoint `1.99951171875`).
    pub tensor_scale: f32,
    pub k: usize,
    pub n: usize,
}

/// Algorithm 1: rescale the tensor so that `max|W| < 2.0` (exponent <= 15).
/// Returns `(scaled values, scale)`; multiply model *outputs* by `1/scale`
/// (or fold into the next op) to undo — a per-tensor post-scaling with
/// negligible overhead, as in the paper.
///
/// The threshold is the FP16 round-to-nearest-even midpoint below 2.0
/// (`1.99951171875`): any f32 at or above it rounds *up* to FP16 `2.0`
/// (exponent 16), which the remapped encoding cannot represent — so those
/// tensors must be pre-scaled too, not just `max|W| > 2.0`.
pub fn algorithm1_prescale(w: &[f32]) -> (Vec<f32>, f32) {
    /// Midpoint between the largest FP16 value below 2.0 (`1.9990234375`)
    /// and 2.0; RNE resolves the tie toward 2.0's even mantissa.
    const FP16_TWO_MIDPOINT: f32 = 1.999_511_718_75;
    let wmax = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if wmax >= FP16_TWO_MIDPOINT {
        let scale = 1.999 / wmax;
        (w.iter().map(|&v| v * scale).collect(), scale)
    } else {
        (w.to_vec(), 1.0)
    }
}

/// Eq. 4: per-group MSE-optimal scale `s = Σ w·Q(w) / Σ Q(w)²`, groups of
/// `GROUP_SIZE` along the in-dimension (axis 0) of a row-major `(k, n)`
/// matrix. Returns `(k / GROUP_SIZE, n)` scales.
pub fn eq4_scales(w: &[f32], q: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    assert_eq!(q.len(), k * n);
    assert_eq!(k % GROUP_SIZE, 0, "in-dim {k} not a multiple of {GROUP_SIZE}");
    let groups = k / GROUP_SIZE;
    let mut scales = vec![1.0f32; groups * n];
    // Row-major accumulation (perf: the naive per-(group, col) loop strides
    // by n on every step; walking rows keeps both inputs sequential and
    // auto-vectorizes — 3.4x faster on the 1M-element bench, see
    // EXPERIMENTS.md §Perf).
    let mut num = vec![0.0f64; n];
    let mut den = vec![0.0f64; n];
    for g in 0..groups {
        num.iter_mut().for_each(|v| *v = 0.0);
        den.iter_mut().for_each(|v| *v = 0.0);
        let base = g * GROUP_SIZE * n;
        for i in 0..GROUP_SIZE {
            let row = base + i * n;
            let wr = &w[row..row + n];
            let qr = &q[row..row + n];
            for j in 0..n {
                num[j] += wr[j] as f64 * qr[j] as f64;
                den[j] += qr[j] as f64 * qr[j] as f64;
            }
        }
        let out = &mut scales[g * n..(g + 1) * n];
        for j in 0..n {
            out[j] = if den[j] > 0.0 { (num[j] / den[j].max(1e-30)) as f32 } else { 1.0 };
        }
    }
    scales
}

/// Encode a (k, n) f32 tensor to `(W_q, W_r)` without scales (bit path only).
pub fn encode_tensor(w: &[f32]) -> (Vec<u8>, Vec<u16>) {
    let mut w_q = Vec::with_capacity(w.len());
    let mut w_r = Vec::with_capacity(w.len());
    for &v in w {
        let c = encode_bits(f32_to_f16_bits(v));
        w_q.push(c.w_q);
        w_r.push(c.w_r);
    }
    (w_q, w_r)
}

/// Full BSFP quantization: Algorithm-1 pre-scale, FP16 cast, encode, Eq. 4.
pub fn quantize_tensor(w: &[f32], k: usize, n: usize) -> QuantizedTensor {
    assert_eq!(w.len(), k * n, "shape mismatch");
    let (scaled, tensor_scale) = algorithm1_prescale(w);
    // Perf (§Perf log): convert to FP16 bits ONCE; the canonical values,
    // the codes, and the draft magnitudes all derive from those bits
    // (the naive path re-ran f32->f16 three times per element).
    let bits: Vec<u16> = scaled.iter().map(|&v| f32_to_f16_bits(v)).collect();
    let fp16_vals: Vec<f32> = bits.iter().map(|&b| f16_bits_to_f32(b)).collect();
    let mut w_q = Vec::with_capacity(bits.len());
    let mut w_r = Vec::with_capacity(bits.len());
    for &b in &bits {
        let c = encode_bits(b);
        w_q.push(c.w_q);
        w_r.push(c.w_r);
    }
    // 16-entry LUT instead of a per-element exp2.
    let lut: [f32; 16] = std::array::from_fn(|c| draft_value(c as u8));
    let q: Vec<f32> = w_q.iter().map(|&c| lut[(c & 0xf) as usize]).collect();
    let scales = eq4_scales(&fp16_vals, &q, k, n);
    QuantizedTensor { w_q, w_r, scales, tensor_scale, k, n }
}

impl QuantizedTensor {
    /// Nibble-packed `W_q` for the draft HLO graph: `(k/2, n)` bytes.
    pub fn packed_wq(&self) -> Vec<u8> {
        pack_nibbles(&self.w_q, self.k, self.n)
    }

    /// Split into the bit-plane pair the packed weight store keeps
    /// resident (prefix = packed `W_q`, residual = packed `W_r`).
    pub fn planes(&self) -> PlanePair {
        PlanePair::from_quantized(self)
    }

    /// Materialize the draft weights (scales applied) as f32, row-major.
    pub fn dequant_draft(&self) -> Vec<f32> {
        // Perf: LUT the 16 possible draft values once, then walk rows
        // sequentially against the group's scale row (see §Perf).
        let lut: [f32; 16] = std::array::from_fn(|c| draft_value(c as u8));
        let mut out = vec![0.0f32; self.k * self.n];
        for i in 0..self.k {
            let g = i / GROUP_SIZE;
            let row = i * self.n;
            let srow = &self.scales[g * self.n..(g + 1) * self.n];
            let qrow = &self.w_q[row..row + self.n];
            let orow = &mut out[row..row + self.n];
            for j in 0..self.n {
                orow[j] = lut[(qrow[j] & 0xf) as usize] * srow[j];
            }
        }
        out
    }

    /// Bit-exact FP16 reconstruction (pre-scale still applied).
    pub fn reconstruct_fp16_bits(&self) -> Vec<u16> {
        self.w_q
            .iter()
            .zip(&self.w_r)
            .map(|(&w_q, &w_r)| decode_full_bits(BsfpCode { w_q, w_r }))
            .collect()
    }

    /// Full-precision weights as f32 with the Algorithm-1 scale undone.
    pub fn reconstruct_full(&self) -> Vec<f32> {
        self.reconstruct_fp16_bits()
            .into_iter()
            .map(|b| f16_bits_to_f32(b) / self.tensor_scale)
            .collect()
    }

    /// Mean squared error of the draft weights vs the FP16 originals.
    pub fn draft_mse(&self) -> f64 {
        let full = self.reconstruct_fp16_bits();
        let draft = self.dequant_draft();
        let mut acc = 0.0f64;
        for (d, b) in draft.iter().zip(full) {
            let t = f16_bits_to_f32(b);
            acc += ((d - t) as f64).powi(2);
        }
        acc / self.w_q.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_weights(k: usize, n: usize, seed: u64, amp: f32) -> Vec<f32> {
        Rng::seed_from_u64(seed).uniform_vec(k * n, amp)
    }

    #[test]
    fn lossless_reconstruction() {
        let w = rand_weights(256, 8, 1, 0.2);
        let qt = quantize_tensor(&w, 256, 8);
        let rec = qt.reconstruct_fp16_bits();
        for (i, &v) in w.iter().enumerate() {
            assert_eq!(rec[i], f32_to_f16_bits(v), "idx {i}");
        }
    }

    #[test]
    fn algorithm1_kicks_in_for_outliers() {
        // The Llama2-13B case from the paper: a lone 2.4062 in down_proj.
        let mut w = rand_weights(128, 4, 2, 0.1);
        w[17] = 2.4062;
        let qt = quantize_tensor(&w, 128, 4);
        assert!(qt.tensor_scale < 1.0);
        // Reconstruction with the scale undone matches the FP16-quantized
        // scaled values back in original range (within FP16 resolution).
        let rec = qt.reconstruct_full();
        for (r, &orig) in rec.iter().zip(&w) {
            assert!((r - orig).abs() <= orig.abs() * 1e-2 + 2e-3, "{r} vs {orig}");
        }
    }

    #[test]
    fn near_two_values_are_prescaled_not_rounded_out_of_domain() {
        // 1.9996 < 2.0 but rounds UP to FP16 2.0 (exponent 16): Algorithm 1
        // must kick in or encode_bits would be handed an invalid exponent.
        let mut w = rand_weights(128, 2, 7, 0.1);
        w[3] = 1.9996;
        let qt = quantize_tensor(&w, 128, 2);
        assert!(qt.tensor_scale < 1.0, "midpoint window must trigger the pre-scale");
        // And values safely below the midpoint do not.
        let mut w2 = rand_weights(128, 2, 8, 0.1);
        w2[3] = 1.9990234375; // largest FP16 below 2.0, exactly
        let qt2 = quantize_tensor(&w2, 128, 2);
        assert_eq!(qt2.tensor_scale, 1.0);
    }

    #[test]
    fn eq4_scale_minimizes_group_mse() {
        // Perturbing the Eq.4 scale in either direction cannot reduce MSE.
        let w = rand_weights(128, 1, 3, 0.15);
        let qt = quantize_tensor(&w, 128, 1);
        let q: Vec<f32> = qt.w_q.iter().map(|&c| draft_value(c)).collect();
        let mse = |s: f32| -> f64 {
            w.iter()
                .zip(&q)
                .map(|(&wv, &qv)| {
                    let t = f16_bits_to_f32(f32_to_f16_bits(wv));
                    ((qv * s - t) as f64).powi(2)
                })
                .sum()
        };
        let s0 = qt.scales[0];
        assert!(mse(s0) <= mse(s0 * 1.02) + 1e-12);
        assert!(mse(s0) <= mse(s0 * 0.98) + 1e-12);
    }

    #[test]
    fn fp16_exactness_classifier() {
        // FP16-representable in-domain values pass (incl. a subnormal).
        let tiny = f16_bits_to_f32(0x0001);
        assert!(fp16_exact_in_domain(&[0.5, -0.25, 1.9990234, 0.0, -0.0, tiny]));
        // Out-of-domain magnitude (exp >= 16).
        assert!(!fp16_exact_in_domain(&[0.5, 2.5]));
        // Not exactly representable in FP16.
        assert!(!fp16_exact_in_domain(&[0.1]));
        // Non-finite values.
        assert!(!fp16_exact_in_domain(&[f32::INFINITY]));
        assert!(!fp16_exact_in_domain(&[f32::NAN]));
    }

    #[test]
    fn draft_mse_much_smaller_than_signal() {
        let w = rand_weights(256, 16, 4, 0.1);
        let qt = quantize_tensor(&w, 256, 16);
        let sig: f64 =
            w.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / w.len() as f64;
        // Remapped E3M0 with Eq.4 scales: quantization noise well below signal.
        assert!(qt.draft_mse() < sig * 0.5, "mse {} sig {}", qt.draft_mse(), sig);
    }
}
