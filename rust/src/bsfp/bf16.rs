//! BF16 model support (paper §IV-A).
//!
//! "For models represented in the BF16 format, we first round the exponent
//! values that are smaller than 112 up to 112.  Subsequently, a similar
//! remapping process is applied to the exponent component. ... Furthermore,
//! we pad the mantissa component with three zeros.  This results in weights
//! being represented in the same format as FP16 (S1E5M10)."
//!
//! BF16 is S1E8M7 with bias 127.  Weight-decayed LLM weights satisfy
//! |w| < 2, i.e. biased exponent <= 127+0 = 127; exponents below 112
//! (values < 2^-15, denormal territory for FP16) are rounded up (clamped in
//! magnitude) — a no-op for any weight that matters.  After the shift by
//! 112 the exponent fits 5 bits with the same wasted-top-bit property, and
//! the whole FP16 BSFP pipeline applies unchanged.

use super::fp16::join_fields;
use super::fp16::Fp16Fields;

/// BF16 bit pattern -> the S1E5M10 word the SPEQ datapath consumes.
///
/// Exponents `< 112` round up to 112 (the paper's clamp); exponents
/// `> 127` (|w| >= 2) must have been removed by the Algorithm-1 pre-scale
/// and panic in debug builds.
#[inline]
pub fn bf16_to_speq_fp16(bits: u16) -> u16 {
    let sign = ((bits >> 15) & 1) as u8;
    let exp8 = ((bits >> 7) & 0xff) as i32;
    let man7 = bits & 0x7f;
    debug_assert!(exp8 <= 127, "BF16 exponent {exp8} > 127: Algorithm-1 pre-scale missing");
    let (exp5, man) = if exp8 == 0 && man7 == 0 {
        (0u8, 0u16) // preserve signed zero
    } else if exp8 <= 112 {
        // "Round up to 112": value becomes 2^-15 * (1 + m/128).  FP16's
        // exponent field 0 is subnormal (no implicit 1), so the implicit
        // bit folds into the mantissa: 2^-14 * (0.5 + m/256) with
        // mantissa 512 + 4m — exact for every m.
        (0u8, 512 + 4 * (man7 as u16))
    } else {
        (((exp8 - 112) as u8) & 0x1f, (man7 as u16) << 3) // pad 3 zero bits
    };
    join_fields(Fp16Fields { sign, exp: exp5, man })
}

/// Inverse for the exact (non-clamped) range: S1E5M10 word -> BF16 bits.
#[inline]
pub fn speq_fp16_to_bf16(bits: u16) -> u16 {
    let sign = (bits >> 15) & 1;
    let exp5 = ((bits >> 10) & 0x1f) as i32;
    let man10 = bits & 0x3ff;
    if exp5 == 0 {
        if man10 == 0 {
            return sign << 15; // signed zero
        }
        // Subnormal encoding of the exp-112 band: man10 = 512 + 4*m.
        debug_assert!(man10 >= 512 && (man10 - 512) % 4 == 0, "not a converted BF16 subnormal");
        return (sign << 15) | (112u16 << 7) | ((man10 - 512) / 4);
    }
    debug_assert_eq!(man10 & 0x7, 0, "mantissa tail bits lost in BF16 round-trip");
    let exp8 = (exp5 + 112) as u16;
    (sign << 15) | (exp8 << 7) | (man10 >> 3)
}

/// Convert a BF16 tensor (raw bits) to the FP16-format bits BSFP consumes.
pub fn convert_bf16_tensor(bits: &[u16]) -> Vec<u16> {
    bits.iter().map(|&b| bf16_to_speq_fp16(b)).collect()
}

/// f32 -> BF16 bits (round-to-nearest-even), for building test tensors.
pub fn f32_to_bf16(v: f32) -> u16 {
    let b = v.to_bits();
    let lsb = (b >> 16) & 1;
    let rounded = b.wrapping_add(0x7fff + lsb);
    (rounded >> 16) as u16
}

/// BF16 bits -> f32 (exact).
pub fn bf16_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsfp::fp16::f16_bits_to_f32;
    use crate::bsfp::remap::{decode_full_bits, encode_bits};

    #[test]
    fn normal_range_converts_exactly() {
        // BF16 values with exponent in [112, 127] convert to FP16 exactly
        // (7 mantissa bits always fit in 10).
        for v in [1.0f32, -0.5, 0.0625, 1.5, -1.9921875, 3.0517578125e-5] {
            let bf = f32_to_bf16(v);
            let fp = bf16_to_speq_fp16(bf);
            assert_eq!(f16_bits_to_f32(fp), bf16_to_f32(bf), "value {v}");
        }
    }

    #[test]
    fn tiny_exponents_round_up_to_112() {
        let tiny = f32_to_bf16(1e-9); // exponent << 112
        let fp = bf16_to_speq_fp16(tiny);
        let back = f16_bits_to_f32(fp);
        // Clamped into the 2^-15 band: small but non-zero.
        assert!(back.abs() >= 2.0f32.powi(-15) && back.abs() < 6.2e-5,
                "clamped magnitude: {back}");
    }

    #[test]
    fn signed_zero_preserved() {
        assert_eq!(bf16_to_speq_fp16(f32_to_bf16(0.0)) & 0x7fff, 0);
        assert_eq!(bf16_to_speq_fp16(f32_to_bf16(-0.0)) >> 15, 1);
    }

    #[test]
    fn bsfp_pipeline_losslessly_roundtrips_converted_bf16() {
        // The paper's property: converted BF16 weights flow through the
        // same quantize/reconstruct path, bit-exactly.
        let mut rng = crate::util::rng::Rng::seed_from_u64(42);
        for _ in 0..1000 {
            let v = (rng.gen_f32() - 0.5) * 3.9;
            let bf = f32_to_bf16(v);
            if bf16_to_f32(bf).abs() >= 2.0 || bf16_to_f32(bf).abs() < 3.1e-5 {
                continue;
            }
            let fp = bf16_to_speq_fp16(bf);
            let rec = decode_full_bits(encode_bits(fp));
            assert_eq!(rec, fp);
            // And back to BF16 exactly (mantissa tail is still zero).
            assert_eq!(speq_fp16_to_bf16(rec), bf);
        }
    }

    #[test]
    fn exhaustive_bf16_in_range_roundtrip() {
        // Every BF16 pattern with exponent in [112, 127]: convert -> BSFP
        // encode -> decode -> convert back == identity.
        for sign in 0..2u16 {
            for exp in 112..=127u16 {
                for man in 0..128u16 {
                    let bf = (sign << 15) | (exp << 7) | man;
                    let fp = bf16_to_speq_fp16(bf);
                    let rec = decode_full_bits(encode_bits(fp));
                    assert_eq!(speq_fp16_to_bf16(rec), bf, "bf16 {bf:#06x}");
                }
            }
        }
    }
}
