//! Bit-plane split of a BSFP-quantized tensor — the packed weight store.
//!
//! A quantized linear `(k, n)` is kept as two tightly packed planes that
//! together hold exactly the 16 bits of every FP16 weight (zero storage
//! overhead, matching the paper's `W_q ∥ W_r` layout):
//!
//! * **prefix plane** — the 4-bit `W_q` codes (sign + remapped E3M0
//!   exponent), nibble-packed along the in-dimension: `(k/2, n)` bytes.
//!   The draft pass streams *only* this plane (plus the Eq. 4 group
//!   scales) — a quarter of the full pass's weight traffic.
//! * **residual plane** — the 12-bit `W_r` remainders (flag, `e0`,
//!   mantissa), packed two-per-three-bytes along the in-dimension:
//!   `(k/2, n) * 3` bytes.  The full/verify pass streams prefix +
//!   residual and reconstructs the original FP16 bits losslessly through
//!   the Fig. 5(b) decoder.
//!
//! Both planes pair rows `2p` and `2p+1` at the same column, mirroring the
//! nibble layout the Pallas `qmatmul` kernel expects, so the on-the-fly
//! decode kernels (`runtime::kernels`) walk them with unit stride.

use super::codec::QuantizedTensor;
use super::pack::{pack_nibbles, unpack_nibbles};
use super::simd::{decode_full_row_pair, SimdLevel};

/// Pack a `(k, n)` row-major `W_r` matrix (12 significant bits per entry)
/// into `(k/2, n)` 3-byte little-endian pairs: rows `2p` (low 12 bits) and
/// `2p+1` (high 12 bits) share the 3 bytes at `3 * (p*n + j)`.  `k` must
/// be even.
pub fn pack_residuals(w_r: &[u16], k: usize, n: usize) -> Vec<u8> {
    assert_eq!(w_r.len(), k * n, "w_r length mismatch");
    assert_eq!(k % 2, 0, "in-dim must be even to pair-pack residuals");
    let mut out = vec![0u8; k / 2 * n * 3];
    for p in 0..k / 2 {
        let lo_row = &w_r[(2 * p) * n..(2 * p + 1) * n];
        let hi_row = &w_r[(2 * p + 1) * n..(2 * p + 2) * n];
        for j in 0..n {
            let r0 = lo_row[j] & 0xfff;
            let r1 = hi_row[j] & 0xfff;
            let base = 3 * (p * n + j);
            out[base] = (r0 & 0xff) as u8;
            out[base + 1] = ((r0 >> 8) as u8 & 0xf) | (((r1 & 0xf) as u8) << 4);
            out[base + 2] = (r1 >> 4) as u8;
        }
    }
    out
}

/// Inverse of [`pack_residuals`].
pub fn unpack_residuals(packed: &[u8], k: usize, n: usize) -> Vec<u16> {
    assert_eq!(packed.len(), k / 2 * n * 3, "packed residual length mismatch");
    let mut out = vec![0u16; k * n];
    for p in 0..k / 2 {
        for j in 0..n {
            let base = 3 * (p * n + j);
            let (b0, b1, b2) = (packed[base] as u16, packed[base + 1] as u16, packed[base + 2] as u16);
            out[(2 * p) * n + j] = b0 | ((b1 & 0xf) << 8);
            out[(2 * p + 1) * n + j] = (b1 >> 4) | (b2 << 4);
        }
    }
    out
}

/// The two bit planes of one quantized linear, row-major `(k, n)`.
///
/// Total size is `k * n * 2` bytes — exactly the FP16 footprint — of which
/// the draft pass touches the `k * n / 2`-byte prefix plane only.
#[derive(Debug, Clone)]
pub struct PlanePair {
    /// Nibble-packed 4-bit `W_q` codes, `(k/2, n)` bytes.
    pub prefix: Vec<u8>,
    /// 12-bit `W_r` remainders packed 2-per-3-bytes, `(k/2, n) * 3` bytes.
    pub residual: Vec<u8>,
    pub k: usize,
    pub n: usize,
}

impl PlanePair {
    /// Split a quantized tensor into its planes.
    pub fn from_quantized(qt: &QuantizedTensor) -> Self {
        Self {
            prefix: pack_nibbles(&qt.w_q, qt.k, qt.n),
            residual: pack_residuals(&qt.w_r, qt.k, qt.n),
            k: qt.k,
            n: qt.n,
        }
    }

    /// Bytes the draft pass streams (prefix plane only).
    pub fn prefix_bytes(&self) -> usize {
        self.prefix.len()
    }

    /// Bytes the full/verify pass streams (prefix + residual planes).
    pub fn full_bytes(&self) -> usize {
        self.prefix.len() + self.residual.len()
    }

    /// Decode the row pair `(2p, 2p+1)` of the full-precision view into
    /// `lo`/`hi` (each of length `n`) — the hot-loop primitive of the
    /// cache-blocked full GEMM kernel.
    #[inline]
    pub fn decode_row_pair_full(&self, p: usize, lo: &mut [f32], hi: &mut [f32]) {
        self.decode_row_pair_full_cols(p, 0, self.n, lo, hi)
    }

    /// Column-ranged variant of [`PlanePair::decode_row_pair_full`]:
    /// decode columns `j0..j1` of row pair `(2p, 2p+1)` into `lo`/`hi`
    /// (each of length `j1 - j0`).  Both planes are column-independent, so
    /// a parallel kernel shard touches only its own columns' bytes — the
    /// per-column decode arithmetic is identical to the full-width call.
    #[inline]
    pub fn decode_row_pair_full_cols(
        &self,
        p: usize,
        j0: usize,
        j1: usize,
        lo: &mut [f32],
        hi: &mut [f32],
    ) {
        self.decode_row_pair_full_cols_with(SimdLevel::Scalar, p, j0, j1, lo, hi)
    }

    /// [`PlanePair::decode_row_pair_full_cols`] through a chosen SIMD
    /// dispatch tier.  Every tier is bitwise identical to scalar (see
    /// `bsfp::simd`), so callers pick a level purely for speed.
    #[inline]
    pub fn decode_row_pair_full_cols_with(
        &self,
        level: SimdLevel,
        p: usize,
        j0: usize,
        j1: usize,
        lo: &mut [f32],
        hi: &mut [f32],
    ) {
        let n = self.n;
        debug_assert!(j0 <= j1 && j1 <= n);
        debug_assert!(lo.len() == j1 - j0 && hi.len() == j1 - j0);
        let prow = &self.prefix[p * n + j0..p * n + j1];
        let rrow = &self.residual[3 * (p * n + j0)..3 * (p * n + j1)];
        decode_full_row_pair(level, prow, rrow, lo, hi);
    }

    /// The unpacked 4-bit codes, row-major `(k, n)` (diagnostics/tests).
    pub fn codes(&self) -> Vec<u8> {
        unpack_nibbles(&self.prefix, self.k, self.n)
    }

    /// The unpacked 12-bit remainders, row-major `(k, n)` (diagnostics/tests).
    pub fn residuals(&self) -> Vec<u16> {
        unpack_residuals(&self.residual, self.k, self.n)
    }

    /// Decode the entire full-precision view to f32 (diagnostics/tests —
    /// the kernels decode blockwise instead of materializing this).
    pub fn decode_full_f32(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k * self.n];
        let mut lo = vec![0.0f32; self.n];
        let mut hi = vec![0.0f32; self.n];
        for p in 0..self.k / 2 {
            self.decode_row_pair_full(p, &mut lo, &mut hi);
            out[(2 * p) * self.n..(2 * p + 1) * self.n].copy_from_slice(&lo);
            out[(2 * p + 1) * self.n..(2 * p + 2) * self.n].copy_from_slice(&hi);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsfp::codec::quantize_tensor;
    use crate::bsfp::fp16::{f16_bits_to_f32, f32_to_f16_bits};
    use crate::util::rng::Rng;

    #[test]
    fn residual_pack_roundtrip() {
        let k = 6;
        let n = 4;
        let w_r: Vec<u16> = (0..k * n).map(|i| ((i * 2731) % 4096) as u16).collect();
        let packed = pack_residuals(&w_r, k, n);
        assert_eq!(packed.len(), k / 2 * n * 3);
        assert_eq!(unpack_residuals(&packed, k, n), w_r);
    }

    #[test]
    fn residual_layout_is_little_endian_pairs() {
        // r0 = 0xABC (row 0), r1 = 0x123 (row 1) -> bytes [0xBC, 0x3A, 0x12].
        let packed = pack_residuals(&[0xABC, 0x123], 2, 1);
        assert_eq!(packed, vec![0xBC, 0x3A, 0x12]);
    }

    #[test]
    fn planes_reconstruct_the_quantized_tensor_bitwise() {
        let w = Rng::seed_from_u64(7).uniform_vec(256 * 6, 0.3);
        let qt = quantize_tensor(&w, 256, 6);
        let planes = PlanePair::from_quantized(&qt);
        assert_eq!(planes.codes(), qt.w_q);
        assert_eq!(planes.residuals(), qt.w_r);
        // Full decode through the planes == the codec's reconstruction.
        let decoded = planes.decode_full_f32();
        let expect = qt.reconstruct_fp16_bits();
        for (i, (&d, &b)) in decoded.iter().zip(&expect).enumerate() {
            assert_eq!(d.to_bits(), f16_bits_to_f32(b).to_bits(), "idx {i}");
        }
        // And (tensor_scale == 1 here) == the original weights after FP16 cast.
        for (i, (&d, &orig)) in decoded.iter().zip(&w).enumerate() {
            assert_eq!(d.to_bits(), f16_bits_to_f32(f32_to_f16_bits(orig)).to_bits(), "idx {i}");
        }
    }

    #[test]
    fn column_ranged_decode_matches_full_width_bitwise() {
        let (k, n) = (64usize, 13usize); // odd n: exercises uneven ranges
        let w = Rng::seed_from_u64(31).uniform_vec(k * n, 0.25);
        let qt = quantize_tensor(&w, k, n);
        let planes = PlanePair::from_quantized(&qt);
        let mut lo = vec![0.0f32; n];
        let mut hi = vec![0.0f32; n];
        for p in 0..k / 2 {
            planes.decode_row_pair_full(p, &mut lo, &mut hi);
            for (j0, j1) in [(0usize, 5usize), (5, 6), (6, n), (0, n)] {
                let w = j1 - j0;
                let mut clo = vec![0.0f32; w];
                let mut chi = vec![0.0f32; w];
                planes.decode_row_pair_full_cols(p, j0, j1, &mut clo, &mut chi);
                for jj in 0..w {
                    assert_eq!(clo[jj].to_bits(), lo[j0 + jj].to_bits(), "p {p} col {}", j0 + jj);
                    assert_eq!(chi[jj].to_bits(), hi[j0 + jj].to_bits(), "p {p} col {}", j0 + jj);
                }
            }
        }
    }

    #[test]
    fn plane_sizes_are_quarter_and_full() {
        let w = Rng::seed_from_u64(9).uniform_vec(128 * 8, 0.2);
        let qt = quantize_tensor(&w, 128, 8);
        let planes = PlanePair::from_quantized(&qt);
        // FP16 footprint: 2 bytes per weight; prefix alone: 1/2 byte.
        assert_eq!(planes.full_bytes(), 128 * 8 * 2);
        assert_eq!(planes.prefix_bytes() * 4, planes.full_bytes());
    }
}
