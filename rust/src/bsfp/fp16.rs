//! FP16 bit-level utilities (substrate S1).

/// Decomposed FP16 bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fp16Fields {
    pub sign: u8,
    /// Biased exponent, 5 bits `[0, 31]`.
    pub exp: u8,
    /// Mantissa, 10 bits.
    pub man: u16,
}

/// Split an FP16 bit pattern into (sign, exponent, mantissa).
#[inline]
pub fn split_fields(bits: u16) -> Fp16Fields {
    Fp16Fields { sign: (bits >> 15) as u8, exp: ((bits >> 10) & 0x1f) as u8, man: bits & 0x3ff }
}

/// Reassemble an FP16 bit pattern.
#[inline]
pub fn join_fields(f: Fp16Fields) -> u16 {
    ((f.sign as u16) << 15) | ((f.exp as u16) << 10) | (f.man & 0x3ff)
}

/// f32 -> FP16 bit pattern (round-to-nearest-even, matching numpy/IEEE).
#[inline]
pub fn f32_to_f16_bits(v: f32) -> u16 {
    crate::util::f16::f32_to_f16(v)
}

/// FP16 bit pattern -> f32 (exact).
#[inline]
pub fn f16_bits_to_f32(bits: u16) -> f32 {
    crate::util::f16::f16_to_f32(bits)
}

/// Histogram of the biased exponent values `[0, 31]` — the Fig. 2(c)
/// analysis that motivates BSFP: trained LLM weights leave `[16, 31]` empty.
pub fn exponent_histogram(values: impl IntoIterator<Item = f32>) -> [u64; 32] {
    let mut hist = [0u64; 32];
    for v in values {
        let f = split_fields(f32_to_f16_bits(v));
        hist[f.exp as usize] += 1;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_join_roundtrip_all_patterns() {
        for bits in 0..=u16::MAX {
            assert_eq!(join_fields(split_fields(bits)), bits);
        }
    }

    #[test]
    fn f16_conversion_matches_known_values() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        // 1.999 (the Algorithm-1 target) fits below exponent 16.
        let f = split_fields(f32_to_f16_bits(1.999));
        assert_eq!(f.exp, 15);
    }

    #[test]
    fn exponent_histogram_confined_for_small_values() {
        let vals = [0.5f32, -0.25, 0.03, 1.5, -1.999, 0.0001];
        let hist = exponent_histogram(vals.iter().copied());
        assert_eq!(hist[16..].iter().sum::<u64>(), 0);
        assert_eq!(hist.iter().sum::<u64>(), vals.len() as u64);
    }
}
