//! Model geometries for hardware evaluation.
//!
//! The engine measures accept rates / draft lengths on the tiny trained
//! analogs; the accelerator replays those traces against the *paper-scale*
//! dimensions below (the actual Llama/Vicuna geometries), so the hardware
//! numbers in Tables III–IV and Figs. 7–9 are computed for the models the
//! paper evaluates.

/// Transformer geometry as seen by the accelerator (linear shapes only).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelDims {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    /// MLP hidden size (SwiGLU: three d_model x d_ff projections).
    pub d_ff: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub vocab: usize,
}

impl ModelDims {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Per-token linear GEMV shapes (k, n), weights streamed once each.
    pub fn token_linears(&self) -> Vec<(usize, usize)> {
        let d = self.d_model;
        let kv = self.n_kv_heads * self.head_dim();
        let mut v = Vec::new();
        for _ in 0..self.n_layers {
            v.push((d, d)); // wq
            v.push((d, kv)); // wk
            v.push((d, kv)); // wv
            v.push((d, d)); // wo
            v.push((d, self.d_ff)); // gate
            v.push((d, self.d_ff)); // up
            v.push((self.d_ff, d)); // down
        }
        v.push((d, self.vocab)); // lm head
        v
    }

    /// Total weight elements in the linear layers.
    pub fn weight_elems(&self) -> u64 {
        self.token_linears().iter().map(|&(k, n)| (k * n) as u64).sum()
    }

    /// KV bytes read for one token's attention at context length `ctx`
    /// (keys + values, all layers, FP16).
    pub fn kv_read_bytes(&self, ctx: usize, kv_elem_bytes: f64) -> f64 {
        let kv_width = self.n_kv_heads * self.head_dim();
        2.0 * self.n_layers as f64 * ctx as f64 * kv_width as f64 * kv_elem_bytes
    }
}

/// The five paper models at their real published geometries.
pub const PAPER_MODELS: [ModelDims; 5] = [
    ModelDims {
        name: "Vicuna-7b",
        n_layers: 32,
        d_model: 4096,
        d_ff: 11008,
        n_heads: 32,
        n_kv_heads: 32,
        vocab: 32000,
    },
    ModelDims {
        name: "Llama2-7b",
        n_layers: 32,
        d_model: 4096,
        d_ff: 11008,
        n_heads: 32,
        n_kv_heads: 32,
        vocab: 32000,
    },
    ModelDims {
        name: "Llama3.1-8b",
        n_layers: 32,
        d_model: 4096,
        d_ff: 14336,
        n_heads: 32,
        n_kv_heads: 8,
        vocab: 128256,
    },
    ModelDims {
        name: "Llama3.2-3b",
        n_layers: 28,
        d_model: 3072,
        d_ff: 8192,
        n_heads: 24,
        n_kv_heads: 8,
        vocab: 128256,
    },
    ModelDims {
        name: "Llama2-13b",
        n_layers: 40,
        d_model: 5120,
        d_ff: 13824,
        n_heads: 40,
        n_kv_heads: 40,
        vocab: 32000,
    },
];

/// Look up paper dims by the analog name used in the manifest
/// (e.g. "vicuna-7b-tiny" -> Vicuna-7b) or by the paper name itself.
pub fn paper_dims(name: &str) -> Option<&'static ModelDims> {
    let needle = name.trim_end_matches("-tiny").to_ascii_lowercase().replace('_', ".");
    PAPER_MODELS.iter().find(|m| m.name.to_ascii_lowercase() == needle)
}

/// Dims of a tiny trained analog, from its manifest config (for running the
/// accel model against the testbed-scale geometry when wanted).
pub fn tiny_dims(cfg: &crate::model::ModelConfig) -> ModelDims {
    ModelDims {
        name: "tiny",
        n_layers: cfg.n_layers,
        d_model: cfg.d_model,
        d_ff: cfg.d_ff,
        n_heads: cfg.n_heads,
        n_kv_heads: cfg.n_heads,
        vocab: cfg.vocab,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_7b_parameter_count_is_right() {
        let m = paper_dims("llama2-7b-tiny").unwrap();
        let linear = m.weight_elems();
        // Linear params of Llama2-7B ~ 6.5e9 (6.74B total incl. embeddings).
        assert!(linear > 6_200_000_000 && linear < 6_800_000_000, "{linear}");
    }

    #[test]
    fn lookup_accepts_both_name_forms() {
        assert!(paper_dims("Vicuna-7b").is_some());
        assert!(paper_dims("vicuna-7b-tiny").is_some());
        assert!(paper_dims("llama3.1-8b-tiny").is_some());
        assert!(paper_dims("nope").is_none());
    }

    #[test]
    fn gqa_shrinks_kv_traffic() {
        let mha = paper_dims("Llama2-7b").unwrap();
        let gqa = paper_dims("Llama3.1-8b").unwrap();
        assert!(gqa.kv_read_bytes(1024, 2.0) < mha.kv_read_bytes(1024, 2.0));
    }
}
