//! SPEQ accelerator model (§IV) — cycle accounting + 28 nm energy/area.
//!
//! The simulator reproduces the paper's hardware evaluation:
//!
//! * [`config`] — the accelerator instance of Fig. 4: a 32×32 reconfigurable
//!   PE array (8 tiles × 128 PEs), 3 × 512 KiB SRAM buffers, a DRAM channel,
//!   SFU/VPU, and the BSFP decoders.
//! * [`pe`] — the two PE-array modes of Fig. 6: full (1 FP16 MAC/PE/cycle)
//!   and quantize (3 exponent-add MACs/PE/cycle on 5-bit weights).
//! * [`sim`] — per-op cycle accounting (`max(compute, DRAM)` per tile, the
//!   decode stage being weight-bandwidth-bound per Fig. 2(a)), composed into
//!   decode/verify/prefill steps and full [`crate::specdec::SpecTrace`]
//!   replays.
//! * [`energy`] — 28 nm per-op energies calibrated against Table IV's
//!   breakdown; area uses the paper's synthesis split.
//! * [`dims`] — the *paper-scale* model geometries (Llama2-7B etc.): traces
//!   measured on the tiny analogs are replayed against real-model dimensions
//!   to regenerate Tables III–IV and Figs. 7–9.
//! * [`baselines`] — Olive-4/8b, Tender-4/8b, the FP16 array, and the
//!   Medusa/Swift analytic points of §V-D.

mod baselines;
mod config;
mod dims;
mod energy;
mod pe;
mod sim;

pub use baselines::{speedup_vs_fp16, BaselineKind, DesignPoint, SPECDEC_BASELINES};
pub use config::AccelConfig;
pub use dims::{paper_dims, tiny_dims, ModelDims, PAPER_MODELS};
pub use energy::{power_report, table4_area, EnergyBreakdown, EnergyParams, PowerReport};
pub use pe::{ArrayMode, PeArray};
pub use sim::{Accel, OpCost, TraceCost};
