//! The accelerator simulator: per-op cycle accounting composed into decode
//! steps, verification passes, and full speculative-decoding trace replays.
//!
//! Cost model (the decode stage is weight-bandwidth-bound, Fig. 2(a)):
//! each linear streams its weights DRAM -> W-buffer -> PEs once per pass;
//! with double buffering the op takes `max(compute, dram)` cycles.  The
//! verification pass scores all drafted tokens against ONE weight stream —
//! that is the asymmetry speculative decoding exploits, and quantize mode
//! shrinks the draft's stream by 3.2x on top.

use super::config::AccelConfig;
use super::dims::ModelDims;
use super::energy::{EnergyBreakdown, EnergyParams};
use super::pe::{ArrayMode, PeActivity, PeArray};
use crate::specdec::SpecTrace;

/// Cost of one operation or composed step.
#[derive(Debug, Default, Clone, Copy)]
pub struct OpCost {
    pub cycles: u64,
    pub compute_cycles: u64,
    pub dram_cycles: u64,
    pub energy: EnergyBreakdown,
}

impl OpCost {
    pub fn add(&mut self, o: &OpCost) {
        self.cycles += o.cycles;
        self.compute_cycles += o.compute_cycles;
        self.dram_cycles += o.dram_cycles;
        self.energy.add(&o.energy);
    }

    pub fn time_s(&self, cfg: &AccelConfig) -> f64 {
        self.cycles as f64 / cfg.freq_hz
    }
}

/// Aggregate cost of replaying a generation trace.
#[derive(Debug, Clone)]
pub struct TraceCost {
    pub spec: OpCost,
    pub ar: OpCost,
    pub tokens: usize,
}

impl TraceCost {
    /// Wall-clock speedup of speculative decoding vs autoregressive FP16.
    pub fn speedup(&self) -> f64 {
        self.ar.cycles as f64 / self.spec.cycles.max(1) as f64
    }

    /// Energy-efficiency gain (tokens/J ratio) vs autoregressive FP16.
    pub fn energy_efficiency_gain(&self) -> f64 {
        self.ar.energy.total_pj() / self.spec.energy.total_pj().max(1e-9)
    }
}

/// The simulator.
#[derive(Debug, Clone)]
pub struct Accel {
    pub cfg: AccelConfig,
    pub energy: EnergyParams,
    pe: PeArray,
}

impl Default for Accel {
    fn default() -> Self {
        Self::new(AccelConfig::default(), EnergyParams::default())
    }
}

impl Accel {
    pub fn new(cfg: AccelConfig, energy: EnergyParams) -> Self {
        let pe = PeArray::new(&cfg);
        Self { cfg, energy, pe }
    }

    /// One linear: `tokens x (k, n)`, weights streamed from DRAM once.
    ///
    /// `weight_bytes_per_elem` lets baseline designs (INT4/8 etc.) reuse the
    /// same machinery with their own weight formats.
    pub fn gemm_cost(
        &self,
        tokens: usize,
        k: usize,
        n: usize,
        mode: ArrayMode,
        weight_bytes_per_elem: f64,
    ) -> OpCost {
        let compute = self.pe.gemm_cycles(tokens, k, n, mode);
        let weight_bytes = (k * n) as f64 * weight_bytes_per_elem;
        // Activations in/out through the A/O buffers (FP16).
        let act_bytes = (tokens * (k + n)) as f64 * 2.0;
        let dram = (weight_bytes / self.cfg.dram_bytes_per_cycle()).ceil() as u64;
        let cycles = compute.max(dram);
        let act = self.pe.gemm_activity(tokens, k, n, mode);
        let sram_bytes = weight_bytes + act_bytes;
        let energy =
            self.energy.energy(&act, sram_bytes, weight_bytes, cycles, self.cfg.freq_hz);
        OpCost { cycles, compute_cycles: compute, dram_cycles: dram, energy }
    }

    /// Attention for `tokens` query positions at context length `ctx`:
    /// KV cache streamed from DRAM once (shared across the token batch),
    /// scores + weighted sum on the PE array, softmax on the VPU.
    pub fn attention_cost(&self, dims: &ModelDims, ctx: usize, tokens: usize) -> OpCost {
        let kv_bytes = dims.kv_read_bytes(ctx, self.cfg.kv_bytes);
        let kv_width = dims.n_kv_heads * dims.head_dim();
        // q.K^T and attn.V per layer: 2 * ctx * d_model MACs per token
        // (GQA shares keys across query heads; score compute still spans
        // all query heads).
        let macs_per_token =
            (2 * ctx * dims.d_model * dims.n_layers) as u64;
        let compute = (macs_per_token * tokens as u64)
            .div_ceil(self.cfg.full_macs_per_cycle())
            + self.cfg.tile_fill_cycles;
        // Softmax on the VPU: ~3 passes over ctx * heads elements.
        let vpu_elems = (3 * ctx * dims.n_heads * dims.n_layers * tokens) as u64;
        let vpu_cycles = vpu_elems.div_ceil(self.cfg.vpu_lanes as u64);
        let dram = (kv_bytes / self.cfg.dram_bytes_per_cycle()).ceil() as u64;
        let compute_total = compute + vpu_cycles;
        let cycles = compute_total.max(dram);
        let act = PeActivity {
            full_macs: macs_per_token * tokens as u64,
            cycles_busy: compute,
            ..Default::default()
        };
        // KV writes for the new tokens.
        let kv_write = (tokens * dims.n_layers * 2 * kv_width) as f64 * self.cfg.kv_bytes;
        let energy = self.energy.energy(
            &act,
            kv_bytes + kv_write,
            kv_bytes + kv_write,
            cycles,
            self.cfg.freq_hz,
        );
        OpCost { cycles, compute_cycles: compute_total, dram_cycles: dram, energy }
    }

    /// One decode step over all linears + attention, in the given mode.
    pub fn decode_step_cost(&self, dims: &ModelDims, ctx: usize, mode: ArrayMode) -> OpCost {
        let wb = match mode {
            ArrayMode::Full => self.cfg.full_weight_bytes,
            ArrayMode::Quant => self.cfg.quant_weight_bytes,
        };
        let mut total = OpCost::default();
        for (k, n) in dims.token_linears() {
            total.add(&self.gemm_cost(1, k, n, mode, wb));
        }
        total.add(&self.attention_cost(dims, ctx, 1));
        total
    }

    /// One parallel verification pass over `tokens` positions.
    pub fn verify_cost(&self, dims: &ModelDims, ctx: usize, tokens: usize) -> OpCost {
        let mut total = OpCost::default();
        for (k, n) in dims.token_linears() {
            total.add(&self.gemm_cost(tokens, k, n, ArrayMode::Full, self.cfg.full_weight_bytes));
        }
        total.add(&self.attention_cost(dims, ctx, tokens));
        total
    }

    /// Replay a speculative trace at paper-scale dims; also computes the
    /// autoregressive FP16 cost for the same number of tokens.
    pub fn run_trace(&self, dims: &ModelDims, trace: &SpecTrace, ctx0: usize) -> TraceCost {
        let mut spec = OpCost::default();
        let mut ctx = ctx0;
        let mut produced = 0usize;
        for it in &trace.iterations {
            for d in 0..it.drafted {
                spec.add(&self.decode_step_cost(dims, ctx + d as usize, ArrayMode::Quant));
            }
            // Hardware verifies drafted + 1 positions (carry + drafts).
            spec.add(&self.verify_cost(dims, ctx, it.drafted as usize + 1));
            ctx += it.accepted as usize + 1;
            produced += it.accepted as usize + 1;
        }
        let mut ar = OpCost::default();
        let mut ctx_ar = ctx0;
        for _ in 0..produced.max(1) {
            ar.add(&self.decode_step_cost(dims, ctx_ar, ArrayMode::Full));
            ctx_ar += 1;
        }
        TraceCost { spec, ar, tokens: produced }
    }

    /// Tokens/second of plain autoregressive decoding at a context length.
    pub fn ar_tokens_per_s(&self, dims: &ModelDims, ctx: usize) -> f64 {
        let c = self.decode_step_cost(dims, ctx, ArrayMode::Full);
        self.cfg.freq_hz / c.cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::dims::paper_dims;
    use crate::specdec::IterRecord;

    fn llama7b() -> &'static ModelDims {
        paper_dims("Llama2-7b").unwrap()
    }

    #[test]
    fn decode_is_dram_bound_in_both_modes() {
        let a = Accel::default();
        for mode in [ArrayMode::Full, ArrayMode::Quant] {
            let c = a.gemm_cost(1, 4096, 4096, mode, 2.0);
            assert!(c.dram_cycles > c.compute_cycles, "{mode:?} not DRAM bound");
            assert_eq!(c.cycles, c.dram_cycles);
        }
    }

    #[test]
    fn draft_step_is_about_3x_cheaper() {
        let a = Accel::default();
        let full = a.decode_step_cost(llama7b(), 1024, ArrayMode::Full);
        let quant = a.decode_step_cost(llama7b(), 1024, ArrayMode::Quant);
        let ratio = full.cycles as f64 / quant.cycles as f64;
        // Weight stream ratio is 3.2; attention (unquantized KV) pulls the
        // end-to-end ratio slightly below that.
        assert!(ratio > 2.3 && ratio <= 3.2, "draft cost ratio {ratio}");
    }

    #[test]
    fn verify_pass_costs_about_one_ar_step() {
        // The parallel verification insight: 17 tokens, one weight stream.
        let a = Accel::default();
        let ar = a.decode_step_cost(llama7b(), 1024, ArrayMode::Full);
        let ver = a.verify_cost(llama7b(), 1024, 17);
        let ratio = ver.cycles as f64 / ar.cycles as f64;
        assert!(ratio < 1.35, "verify/ar {ratio}");
    }

    #[test]
    fn perfect_trace_reaches_paper_speedup_zone() {
        // r = 1 trace: every iteration drafts 16, accepts 16.
        let iters =
            vec![IterRecord { drafted: 16, accepted: 16, early_exit: false }; 15];
        let trace = SpecTrace { iterations: iters, produced: 255, prompt_len: 1024 };
        let tc = Accel::default().run_trace(llama7b(), &trace, 1024);
        let s = tc.speedup();
        assert!(s > 1.8 && s < 3.2, "speedup {s}");
    }

    #[test]
    fn rejecting_trace_is_slower_than_ar() {
        // r = 0: drafts always rejected -> pure overhead.
        let iters = vec![IterRecord { drafted: 16, accepted: 0, early_exit: false }; 16];
        let trace = SpecTrace { iterations: iters, produced: 16, prompt_len: 1024 };
        let tc = Accel::default().run_trace(llama7b(), &trace, 1024);
        assert!(tc.speedup() < 1.0, "speedup {}", tc.speedup());
    }

    #[test]
    fn energy_gain_positive_for_good_traces() {
        let iters =
            vec![IterRecord { drafted: 16, accepted: 15, early_exit: false }; 15];
        let trace = SpecTrace { iterations: iters, produced: 240, prompt_len: 1024 };
        let tc = Accel::default().run_trace(llama7b(), &trace, 1024);
        let g = tc.energy_efficiency_gain();
        assert!(g > 1.2 && g < 3.0, "energy gain {g}");
    }

    #[test]
    fn longer_context_costs_more() {
        let a = Accel::default();
        let short = a.decode_step_cost(llama7b(), 128, ArrayMode::Full);
        let long = a.decode_step_cost(llama7b(), 2048, ArrayMode::Full);
        assert!(long.cycles > short.cycles);
    }
}
