//! Baseline accelerators (Figs. 7–8) and specdec baselines (§V-D).
//!
//! Every design shares the SPEQ substrate (same array size, buffers, DRAM
//! channel) so comparisons isolate the *design* differences:
//!
//! * **FP16** — the same array, full mode only, plain autoregressive.
//! * **Olive-4/8b** (ISCA'23) — INT PEs with outlier-victim pairs.  Weight
//!   stream is `bits/8 (1 + index overhead)` bytes/elem; the outlier
//!   machinery costs array utilization (OVP pairs serialize on outliers).
//!   4-bit Olive is *lossy* (ppl 44.2 on Llama2-7b per the paper) — marked.
//! * **Tender-4/8b** (ISCA'24) — decomposed INT with runtime requantization;
//!   a shift-requant pass after each tile costs additional utilization.
//! * **Medusa / Swift** — speculative baselines modeled analytically from
//!   their published operating points, for the §V-D comparison.

use super::dims::ModelDims;
use super::pe::ArrayMode;
use super::sim::{Accel, OpCost};
use crate::specdec::{expected_accept_length, SpecTrace};

/// Which design a point describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineKind {
    Fp16,
    Olive4,
    Olive8,
    Tender4,
    Tender8,
    Speq,
}

/// A design point for the Fig. 7/8 comparison.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub kind: BaselineKind,
    pub label: &'static str,
    /// Weight stream bytes per element.
    pub weight_bytes: f64,
    /// MAC energy, pJ (INT MACs are cheaper than FP16).
    pub mac_pj: f64,
    /// Array utilization factor (<1 models OVP serialization / requant
    /// stalls; 1.0 for clean datapaths).
    pub utilization: f64,
    /// Whether the design degrades model quality (grayed out in Fig. 7).
    pub lossy: bool,
}

impl DesignPoint {
    pub fn get(kind: BaselineKind) -> DesignPoint {
        match kind {
            BaselineKind::Fp16 => DesignPoint {
                kind,
                label: "FP16",
                weight_bytes: 2.0,
                mac_pj: 0.4375,
                utilization: 1.0,
                lossy: false,
            },
            // Olive: 4/8-bit weights + ~6% outlier-victim index overhead;
            // OVP handling costs ~12% utilization (outlier lanes serialize).
            BaselineKind::Olive4 => DesignPoint {
                kind,
                label: "Olive-4b",
                weight_bytes: 0.5 * 1.06,
                mac_pj: 0.10,
                utilization: 0.88,
                lossy: true,
            },
            BaselineKind::Olive8 => DesignPoint {
                kind,
                label: "Olive-8b",
                weight_bytes: 1.0 * 1.06,
                mac_pj: 0.18,
                utilization: 0.88,
                lossy: false,
            },
            // Tender: decomposed INT + runtime requantization pass (~18%
            // of tile time) between magnitude clusters.
            BaselineKind::Tender4 => DesignPoint {
                kind,
                label: "Tender-4b",
                weight_bytes: 0.5 * 1.04,
                mac_pj: 0.10,
                utilization: 0.82,
                lossy: true,
            },
            BaselineKind::Tender8 => DesignPoint {
                kind,
                label: "Tender-8b",
                weight_bytes: 1.0 * 1.04,
                mac_pj: 0.18,
                utilization: 0.82,
                lossy: false,
            },
            BaselineKind::Speq => DesignPoint {
                kind,
                label: "SPEQ",
                weight_bytes: 2.0, // full-mode stream; draft uses 0.625
                mac_pj: 0.4375,
                utilization: 1.0,
                lossy: false,
            },
        }
    }

    /// Cost of one decode token for this (non-speculative) design.
    pub fn token_cost(&self, accel: &Accel, dims: &ModelDims, ctx: usize) -> OpCost {
        let mut total = OpCost::default();
        for (k, n) in dims.token_linears() {
            let mut c = accel.gemm_cost(1, k, n, ArrayMode::Full, self.weight_bytes);
            // Utilization stretch on the compute component; energy scales
            // with the design's MAC cost.
            let stretched = (c.compute_cycles as f64 / self.utilization) as u64;
            c.cycles = c.dram_cycles.max(stretched);
            c.energy.pe_pj *= self.mac_pj / 0.4375;
            total.add(&c);
        }
        total.add(&accel.attention_cost(dims, ctx, 1));
        total
    }
}

/// Speedup of a design over the FP16 baseline for one decode token stream.
///
/// For SPEQ, pass the measured trace (its draft/verify pattern defines the
/// cost); for the INT designs the speedup is per-token.
pub fn speedup_vs_fp16(
    kind: BaselineKind,
    accel: &Accel,
    dims: &ModelDims,
    ctx: usize,
    trace: Option<&SpecTrace>,
) -> f64 {
    let fp16 = DesignPoint::get(BaselineKind::Fp16).token_cost(accel, dims, ctx);
    match kind {
        BaselineKind::Speq => {
            let trace = trace.expect("SPEQ speedup needs a measured trace");
            accel.run_trace(dims, trace, ctx).speedup()
        }
        _ => {
            let c = DesignPoint::get(kind).token_cost(accel, dims, ctx);
            fp16.cycles as f64 / c.cycles as f64
        }
    }
}

/// §V-D speculative-decoding baselines (analytic operating points from the
/// respective papers, all verified on the same FP16 substrate):
///
/// * Medusa: head-based drafts — cheap draft (one extra-head pass ≈ 10% of
///   an AR step) but lower alignment (r ≈ 0.80, effective L ≈ 4) and +11%
///   weight memory on every pass.
/// * Swift: layer-skip drafts — draft = half the layers (T_d ≈ 0.5 T_ar),
///   r ≈ 0.88 after its dynamic-skip optimization, L ≈ 8.
pub struct SpecdecBaseline {
    pub name: &'static str,
    pub accept_rate: f64,
    pub draft_len: usize,
    /// T_d / T_ar.
    pub td_ratio: f64,
    /// T_v / T_ar.
    pub tv_ratio: f64,
    /// Extra training required (paper Table in Fig. 2(b)).
    pub needs_training: bool,
    /// Extra memory overhead fraction.
    pub memory_overhead: f64,
}

pub const SPECDEC_BASELINES: [SpecdecBaseline; 2] = [
    SpecdecBaseline {
        name: "Medusa",
        accept_rate: 0.80,
        draft_len: 4,
        td_ratio: 0.10,
        tv_ratio: 1.11, // +11% weights on the verification stream
        needs_training: true,
        memory_overhead: 0.11,
    },
    SpecdecBaseline {
        name: "Swift",
        accept_rate: 0.88,
        draft_len: 8,
        td_ratio: 0.50,
        tv_ratio: 1.0,
        needs_training: false,
        memory_overhead: 0.0,
    },
];

impl SpecdecBaseline {
    /// Analytic speedup via Eq. 2.
    pub fn speedup(&self) -> f64 {
        expected_accept_length(self.accept_rate, self.draft_len)
            / (self.draft_len as f64 * self.td_ratio + self.tv_ratio)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::dims::paper_dims;
    use crate::specdec::IterRecord;

    fn good_trace() -> SpecTrace {
        SpecTrace {
            iterations: vec![IterRecord { drafted: 16, accepted: 15, early_exit: false }; 16],
            produced: 256,
            prompt_len: 1024,
        }
    }

    #[test]
    fn fig7_ordering_holds() {
        // SPEQ > Tender-8b >= Olive-8b > FP16; SPEQ ~ Olive-4b.  (The
        // paper's 1.53x-vs-Olive8 > 1.45x-vs-Tender8 implies Tender-8b is
        // the slightly faster 8-bit design.)
        let a = Accel::default();
        let dims = paper_dims("Llama2-7b").unwrap();
        let trace = good_trace();
        let speq = speedup_vs_fp16(BaselineKind::Speq, &a, dims, 1024, Some(&trace));
        let o8 = speedup_vs_fp16(BaselineKind::Olive8, &a, dims, 1024, None);
        let t8 = speedup_vs_fp16(BaselineKind::Tender8, &a, dims, 1024, None);
        let o4 = speedup_vs_fp16(BaselineKind::Olive4, &a, dims, 1024, None);
        assert!(speq > o8, "SPEQ {speq} vs Olive8 {o8}");
        assert!(speq > t8, "SPEQ {speq} vs Tender8 {t8}");
        assert!(t8 >= o8, "Tender8 {t8} vs Olive8 {o8}");
        assert!(o8 > 1.0);
        // SPEQ within +-35% of lossy Olive-4b (paper: "similar speedup").
        assert!((speq / o4) > 0.65 && (speq / o4) < 1.35, "SPEQ {speq} vs Olive4 {o4}");
    }

    #[test]
    fn lossy_designs_are_marked() {
        assert!(DesignPoint::get(BaselineKind::Olive4).lossy);
        assert!(DesignPoint::get(BaselineKind::Tender4).lossy);
        assert!(!DesignPoint::get(BaselineKind::Olive8).lossy);
        assert!(!DesignPoint::get(BaselineKind::Speq).lossy);
    }

    #[test]
    fn specdec_baseline_ordering_matches_section_vd() {
        // Paper: SPEQ 2.03x > Medusa (~1.9x) > Swift (~1.35x) on Vicuna-7b.
        let medusa = SPECDEC_BASELINES[0].speedup();
        let swift = SPECDEC_BASELINES[1].speedup();
        assert!(medusa > swift, "medusa {medusa} swift {swift}");
        assert!(swift > 1.0 && swift < 1.8, "swift {swift}");
        assert!(medusa > 1.5 && medusa < 2.3, "medusa {medusa}");
    }

    #[test]
    fn olive8_beats_fp16_but_less_than_2x() {
        let a = Accel::default();
        let dims = paper_dims("Llama2-7b").unwrap();
        let s = speedup_vs_fp16(BaselineKind::Olive8, &a, dims, 1024, None);
        assert!(s > 1.2 && s < 2.0, "olive8 {s}");
    }
}
