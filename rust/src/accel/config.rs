//! Accelerator instance parameters (Fig. 4).

/// Hardware configuration of one SPEQ accelerator instance.
#[derive(Debug, Clone)]
pub struct AccelConfig {
    /// PE array rows (paper: 32).
    pub pe_rows: usize,
    /// PE array cols (paper: 32; 8 tiles x 128 PEs).
    pub pe_cols: usize,
    /// Quantized weights processed per PE per cycle in quantize mode
    /// (paper: 3 five-bit weights share one PE's datapath).
    pub quant_lanes: usize,
    /// Clock, Hz (paper: 500 MHz).
    pub freq_hz: f64,
    /// Weight/Activation/Output buffer sizes, bytes (paper: 3 x 512 KiB).
    pub w_buf_bytes: usize,
    pub a_buf_bytes: usize,
    pub o_buf_bytes: usize,
    /// Sustained DRAM bandwidth, bytes/s.  25.6 GB/s — a single LPDDR5
    /// channel, the class of memory a 6.3 mm^2 28 nm edge accelerator pairs
    /// with.  All designs in the comparison share this value, so speedup
    /// *ratios* are insensitive to it (decode is bandwidth-bound everywhere).
    pub dram_bytes_per_s: f64,
    /// Stored bits per weight element in full mode (15 data bits stored in
    /// 16; traffic is 2 bytes per paper §IV-C).
    pub full_weight_bytes: f64,
    /// Stored bits per weight element in quantize mode: the 4-bit W_q plus
    /// the 1/128-amortized group scale -> 4.25 bits. The paper streams the
    /// 5-bit [sign|code|flag-slot] lane, so we use 5 bits = 0.625 B.
    pub quant_weight_bytes: f64,
    /// KV cache element bytes (FP16).
    pub kv_bytes: f64,
    /// VPU lanes (softmax/norm throughput, elements per cycle).
    pub vpu_lanes: usize,
    /// Pipeline fill overhead per GEMM tile, cycles.
    pub tile_fill_cycles: u64,
}

impl Default for AccelConfig {
    fn default() -> Self {
        Self {
            pe_rows: 32,
            pe_cols: 32,
            quant_lanes: 3,
            freq_hz: 500e6,
            w_buf_bytes: 512 << 10,
            a_buf_bytes: 512 << 10,
            o_buf_bytes: 512 << 10,
            dram_bytes_per_s: 25.6e9,
            full_weight_bytes: 2.0,
            quant_weight_bytes: 0.625,
            kv_bytes: 2.0,
            vpu_lanes: 128,
            tile_fill_cycles: 64,
        }
    }
}

impl AccelConfig {
    pub fn pes(&self) -> usize {
        self.pe_rows * self.pe_cols
    }

    /// MACs per cycle in full mode.
    pub fn full_macs_per_cycle(&self) -> u64 {
        self.pes() as u64
    }

    /// MACs per cycle in quantize mode (3 weights per PE).
    pub fn quant_macs_per_cycle(&self) -> u64 {
        (self.pes() * self.quant_lanes) as u64
    }

    /// DRAM bytes deliverable per clock cycle.
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram_bytes_per_s / self.freq_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_instance_numbers() {
        let c = AccelConfig::default();
        assert_eq!(c.pes(), 1024);
        assert_eq!(c.full_macs_per_cycle(), 1024);
        assert_eq!(c.quant_macs_per_cycle(), 3072);
        // 25.6 GB/s at 500 MHz = 51.2 B/cycle.
        assert!((c.dram_bytes_per_cycle() - 51.2).abs() < 1e-9);
    }

    #[test]
    fn quant_mode_bandwidth_advantage_is_3_2x() {
        let c = AccelConfig::default();
        let ratio = c.full_weight_bytes / c.quant_weight_bytes;
        assert!((ratio - 3.2).abs() < 1e-9, "weight-stream ratio {ratio}");
    }
}
