//! 28 nm energy/area model, calibrated against the paper's Table IV.
//!
//! Per-op energies are chosen so that a compute-saturated PE array at
//! 500 MHz reproduces the paper's measured power split (quantize mode
//! 508 mW, full mode 559 mW, with PE/decoder/SRAM/VPU/others fractions as
//! published).  The calibration is *consistent*: one set of constants
//! reproduces both modes, which is the property the comparisons rely on.
//! DRAM energy (off-chip, not part of Table IV's on-chip power) uses the
//! standard ~8 pJ/bit LPDDR figure.

use super::config::AccelConfig;
use super::pe::PeActivity;

/// Per-operation energy constants (pJ) and constant-power components (mW).
#[derive(Debug, Clone)]
pub struct EnergyParams {
    /// Full-mode FP16 MAC (two 5-bit Wallace-tree halves + FP32 accum).
    pub mac_full_pj: f64,
    /// Quantize-mode MAC (exponent add + FP32 accumulate only — "only the
    /// exponents are added", §V-C).
    pub mac_quant_pj: f64,
    /// Fig. 5(a) draft decoder per weight.
    pub dec_draft_pj: f64,
    /// Fig. 5(b) full decoder per weight (MUX path).
    pub dec_full_pj: f64,
    /// On-chip SRAM, per byte moved (write+read through a 512 KiB bank).
    pub sram_pj_per_byte: f64,
    /// VPU constant power while busy (softmax/norm/rope lanes), mW.
    pub vpu_mw: f64,
    /// Control/NoC/clock-tree constant power, mW.
    pub others_mw: f64,
    /// Off-chip DRAM, per byte (~8 pJ/bit).
    pub dram_pj_per_byte: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self {
            mac_full_pj: 0.4375,
            mac_quant_pj: 0.1204,
            dec_draft_pj: 0.0106,
            dec_full_pj: 0.0338,
            sram_pj_per_byte: 0.17,
            vpu_mw: 78.0,
            others_mw: 67.0,
            dram_pj_per_byte: 64.0,
        }
    }
}

/// Energy totals, pJ, by Table IV component (plus off-chip DRAM).
#[derive(Debug, Default, Clone, Copy)]
pub struct EnergyBreakdown {
    pub pe_pj: f64,
    pub decoder_pj: f64,
    pub sram_pj: f64,
    pub vpu_pj: f64,
    pub others_pj: f64,
    pub dram_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.pe_pj + self.decoder_pj + self.sram_pj + self.vpu_pj + self.others_pj + self.dram_pj
    }

    pub fn on_chip_pj(&self) -> f64 {
        self.total_pj() - self.dram_pj
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.pe_pj += o.pe_pj;
        self.decoder_pj += o.decoder_pj;
        self.sram_pj += o.sram_pj;
        self.vpu_pj += o.vpu_pj;
        self.others_pj += o.others_pj;
        self.dram_pj += o.dram_pj;
    }

    pub fn scale(&self, f: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            pe_pj: self.pe_pj * f,
            decoder_pj: self.decoder_pj * f,
            sram_pj: self.sram_pj * f,
            vpu_pj: self.vpu_pj * f,
            others_pj: self.others_pj * f,
            dram_pj: self.dram_pj * f,
        }
    }
}

impl EnergyParams {
    /// Energy of a PE-array activity interval plus the byte traffic it
    /// implies.  `sram_bytes` covers weight/activation/KV movement through
    /// the on-chip buffers; `dram_bytes` the off-chip transfers; `cycles`
    /// the wall-clock for constant-power components.
    pub fn energy(
        &self,
        act: &PeActivity,
        sram_bytes: f64,
        dram_bytes: f64,
        cycles: u64,
        freq_hz: f64,
    ) -> EnergyBreakdown {
        let time_s = cycles as f64 / freq_hz;
        EnergyBreakdown {
            pe_pj: act.full_macs as f64 * self.mac_full_pj
                + act.quant_macs as f64 * self.mac_quant_pj,
            decoder_pj: act.draft_decodes as f64 * self.dec_draft_pj
                + act.full_decodes as f64 * self.dec_full_pj,
            sram_pj: sram_bytes * self.sram_pj_per_byte,
            vpu_pj: self.vpu_mw * 1e-3 * time_s * 1e12,
            others_pj: self.others_mw * 1e-3 * time_s * 1e12,
            dram_pj: dram_bytes * self.dram_pj_per_byte,
        }
    }
}

/// One row of the Table IV power report.
#[derive(Debug, Clone)]
pub struct PowerReport {
    pub mode: &'static str,
    pub total_mw: f64,
    pub pe_pct: f64,
    pub decoder_pct: f64,
    pub sram_pct: f64,
    pub vpu_pct: f64,
    pub others_pct: f64,
}

/// On-chip power in a compute-saturated interval (the paper's VCS scenario).
pub fn power_report(cfg: &AccelConfig, p: &EnergyParams, quantize_mode: bool) -> PowerReport {
    let f = cfg.freq_hz;
    let (pe_mw, dec_mw, sram_mw) = if quantize_mode {
        let macs = cfg.quant_macs_per_cycle() as f64;
        (
            macs * p.mac_quant_pj * f * 1e-9,
            macs * p.dec_draft_pj * f * 1e-9,
            macs * cfg.quant_weight_bytes * p.sram_pj_per_byte * f * 1e-9,
        )
    } else {
        let macs = cfg.full_macs_per_cycle() as f64;
        (
            macs * p.mac_full_pj * f * 1e-9,
            macs * p.dec_full_pj * f * 1e-9,
            macs * cfg.full_weight_bytes * p.sram_pj_per_byte * f * 1e-9,
        )
    };
    let total = pe_mw + dec_mw + sram_mw + p.vpu_mw + p.others_mw;
    PowerReport {
        mode: if quantize_mode { "quantize" } else { "full" },
        total_mw: total,
        pe_pct: 100.0 * pe_mw / total,
        decoder_pct: 100.0 * dec_mw / total,
        sram_pct: 100.0 * sram_mw / total,
        vpu_pct: 100.0 * p.vpu_mw / total,
        others_pct: 100.0 * p.others_mw / total,
    }
}

/// Area split, mm² — the paper's synthesis result (28 nm, 6.3 mm² total).
/// The decoder's 3.5% is the entire area overhead of bit-sharing.
pub fn table4_area() -> [(&'static str, f64); 6] {
    let total: f64 = 6.3;
    [
        ("PE", total * 0.394),
        ("Decoder", total * 0.035),
        ("SRAM", total * 0.351),
        ("VPU", total * 0.148),
        ("Others", total * 0.072),
        ("Total", total),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_matches_table4_quantize_mode() {
        let r = power_report(&AccelConfig::default(), &EnergyParams::default(), true);
        // Paper: 508 mW; PE 36.5%, decoder 3.2%, SRAM 32.1%.
        assert!((r.total_mw - 508.0).abs() < 25.0, "total {}", r.total_mw);
        assert!((r.pe_pct - 36.5).abs() < 3.0, "pe {}", r.pe_pct);
        assert!((r.decoder_pct - 3.2).abs() < 1.0, "dec {}", r.decoder_pct);
        assert!((r.sram_pct - 32.1).abs() < 3.0, "sram {}", r.sram_pct);
    }

    #[test]
    fn power_matches_table4_full_mode() {
        let r = power_report(&AccelConfig::default(), &EnergyParams::default(), false);
        // Paper: 559 mW; PE 40.0%, decoder 3.1%, SRAM 30.2%.
        assert!((r.total_mw - 559.0).abs() < 25.0, "total {}", r.total_mw);
        assert!((r.pe_pct - 40.0).abs() < 3.0, "pe {}", r.pe_pct);
        assert!((r.decoder_pct - 3.1).abs() < 1.5, "dec {}", r.decoder_pct);
    }

    #[test]
    fn modes_draw_similar_power() {
        // The paper's high-utilization claim: 508 vs 559 mW.
        let q = power_report(&AccelConfig::default(), &EnergyParams::default(), true);
        let f = power_report(&AccelConfig::default(), &EnergyParams::default(), false);
        let ratio = q.total_mw / f.total_mw;
        assert!(ratio > 0.85 && ratio < 1.0, "ratio {ratio}");
    }

    #[test]
    fn decoder_area_is_small() {
        let area = table4_area();
        let dec = area.iter().find(|(n, _)| *n == "Decoder").unwrap().1;
        let total = area.iter().find(|(n, _)| *n == "Total").unwrap().1;
        assert!(dec / total < 0.04);
    }

    #[test]
    fn breakdown_arithmetic() {
        let mut a = EnergyBreakdown { pe_pj: 1.0, dram_pj: 2.0, ..Default::default() };
        let b = EnergyBreakdown { pe_pj: 3.0, sram_pj: 1.0, ..Default::default() };
        a.add(&b);
        assert_eq!(a.pe_pj, 4.0);
        assert_eq!(a.total_pj(), 7.0);
        assert_eq!(a.on_chip_pj(), 5.0);
        assert_eq!(a.scale(2.0).total_pj(), 14.0);
    }
}
