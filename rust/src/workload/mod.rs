//! Workloads: task prompt sets, held-out evaluation windows, and trace
//! persistence.
//!
//! The three task families are the paper's benchmark analogs (DESIGN.md §2):
//! `math` -> GSM8K, `code` -> HumanEval, `chat` -> MT-bench.  Prompts are
//! generated at artifact-build time by `python/compile/corpus.py`; this
//! module loads them and provides the held-out stream for the Table I
//! perplexity harness.

mod tasks;
mod traces;

pub use tasks::{
    builtin_task, heldout_windows, load_task, load_task_or_builtin, task_names, TaskSet,
};
pub use traces::{load_trace, save_trace, TraceRecord};
