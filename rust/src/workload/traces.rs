//! Trace persistence: measured engine traces are cached under
//! `artifacts/results/` so the hardware experiments (Tables III–IV,
//! Figs. 7–9) can be regenerated without re-running the engine.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::specdec::{IterRecord, SpecTrace};
use crate::util::json::{self, Value};

/// A persisted trace with its provenance.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    pub model: String,
    pub task: String,
    pub max_draft: usize,
    pub gamma: f32,
    pub gen_len: usize,
    pub trace: SpecTrace,
}

impl TraceRecord {
    pub fn file_name(model: &str, task: &str, max_draft: usize, gamma: f32) -> String {
        format!("trace_{model}_{task}_L{max_draft}_g{:02}.json", (gamma * 10.0).round() as u32)
    }
}

/// Save a trace record as JSON.
pub fn save_trace(dir: &Path, rec: &TraceRecord) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut obj = BTreeMap::new();
    obj.insert("model".into(), Value::Str(rec.model.clone()));
    obj.insert("task".into(), Value::Str(rec.task.clone()));
    obj.insert("max_draft".into(), Value::Num(rec.max_draft as f64));
    obj.insert("gamma".into(), Value::Num(rec.gamma as f64));
    obj.insert("gen_len".into(), Value::Num(rec.gen_len as f64));
    obj.insert("produced".into(), Value::Num(rec.trace.produced as f64));
    obj.insert("prompt_len".into(), Value::Num(rec.trace.prompt_len as f64));
    let iters: Vec<Value> = rec
        .trace
        .iterations
        .iter()
        .map(|it| {
            Value::Arr(vec![
                Value::Num(it.drafted as f64),
                Value::Num(it.accepted as f64),
                Value::Num(if it.early_exit { 1.0 } else { 0.0 }),
            ])
        })
        .collect();
    obj.insert("iterations".into(), Value::Arr(iters));
    let path = dir.join(TraceRecord::file_name(&rec.model, &rec.task, rec.max_draft, rec.gamma));
    std::fs::write(&path, json::write(&Value::Obj(obj)))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Load a trace record if present.
pub fn load_trace(
    dir: &Path,
    model: &str,
    task: &str,
    max_draft: usize,
    gamma: f32,
) -> Option<TraceRecord> {
    let path = dir.join(TraceRecord::file_name(model, task, max_draft, gamma));
    let text = std::fs::read_to_string(path).ok()?;
    let v = json::parse(&text).ok()?;
    let mut iterations = Vec::new();
    for it in v.get("iterations")?.as_arr()? {
        let row = it.as_arr()?;
        iterations.push(IterRecord {
            drafted: row.first()?.as_f64()? as u32,
            accepted: row.get(1)?.as_f64()? as u32,
            early_exit: row.get(2)?.as_f64()? != 0.0,
        });
    }
    Some(TraceRecord {
        model: model.to_string(),
        task: task.to_string(),
        max_draft,
        gamma,
        gen_len: v.get("gen_len")?.as_usize()?,
        trace: SpecTrace {
            iterations,
            produced: v.get("produced")?.as_usize()?,
            prompt_len: v.get("prompt_len")?.as_usize()?,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("speq_trace_test");
        let rec = TraceRecord {
            model: "m".into(),
            task: "math".into(),
            max_draft: 16,
            gamma: 0.6,
            gen_len: 256,
            trace: SpecTrace {
                iterations: vec![
                    IterRecord { drafted: 16, accepted: 12, early_exit: false },
                    IterRecord { drafted: 3, accepted: 3, early_exit: true },
                ],
                produced: 17,
                prompt_len: 128,
            },
        };
        save_trace(&dir, &rec).unwrap();
        let back = load_trace(&dir, "m", "math", 16, 0.6).unwrap();
        assert_eq!(back.trace.iterations, rec.trace.iterations);
        assert_eq!(back.trace.produced, 17);
        assert_eq!(back.gen_len, 256);
        assert!(load_trace(&dir, "m", "code", 16, 0.6).is_none());
    }
}
