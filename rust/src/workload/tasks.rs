//! Task prompt sets and held-out windows.

use anyhow::{Context, Result};

use crate::model::Manifest;
use crate::util::json::{self, Value};

/// A loaded task family.
#[derive(Debug, Clone)]
pub struct TaskSet {
    pub task: String,
    /// Paper benchmark this family substitutes for.
    pub paper_analog: String,
    pub prompt_len: usize,
    /// Byte-token prompts, each exactly `prompt_len` long.
    pub prompts: Vec<Vec<u8>>,
}

/// Canonical task order (matches the paper's table columns:
/// Humaneval, MT-bench, GSM8K -> code, chat, math).
pub fn task_names() -> [&'static str; 3] {
    ["code", "chat", "math"]
}

/// Load one task family from the artifacts.
pub fn load_task(manifest: &Manifest, task: &str) -> Result<TaskSet> {
    let rel = manifest
        .tasks
        .get(task)
        .with_context(|| format!("task {task:?} not in manifest"))?;
    let text = std::fs::read_to_string(manifest.path(rel))
        .with_context(|| format!("reading task file {rel}"))?;
    let v = json::parse(&text).context("parsing task file")?;
    let prompt_len = v.req("prompt_len").ok().and_then(Value::as_usize).unwrap_or(0);
    let paper_analog =
        v.get("paper_analog").and_then(Value::as_str).unwrap_or("?").to_string();
    let mut prompts = Vec::new();
    for p in v.get("prompts").and_then(Value::as_arr).context("task missing prompts")? {
        let toks: Vec<u8> = p
            .as_arr()
            .context("prompt must be an array")?
            .iter()
            .map(|t| t.as_usize().unwrap_or(32) as u8)
            .collect();
        anyhow::ensure!(toks.len() == prompt_len, "prompt length mismatch");
        prompts.push(toks);
    }
    anyhow::ensure!(!prompts.is_empty(), "task {task:?} has no prompts");
    Ok(TaskSet { task: task.to_string(), paper_analog, prompt_len, prompts })
}

/// Synthetic prompt set for `task` — the builtin fallback when no
/// artifacts directory exists.  Emits the same three families as the
/// artifact corpus generator (`python/compile/corpus.py` analogs), each
/// prompt left-padded with spaces to exactly `prompt_len` bytes.
pub fn builtin_task(task: &str, prompt_len: usize, n_prompts: usize) -> Result<TaskSet> {
    anyhow::ensure!(n_prompts >= 1, "need at least one prompt");
    let paper_analog = match task {
        "math" => "GSM8K",
        "code" => "Humaneval",
        "chat" => "MT-bench",
        other => anyhow::bail!("task {other:?} not a builtin family (have {:?})", task_names()),
    };
    let names = ["ada", "bob", "carol", "dan", "eve", "fred", "grace", "hugo"];
    let items = ["apples", "coins", "books", "cups", "pens", "cards"];
    let topics = ["music", "books", "travel", "games", "cooking", "film"];
    let mut prompts = Vec::with_capacity(n_prompts);
    for i in 0..n_prompts {
        let text = match task {
            "math" => format!(
                "Q: {} has {} {} and finds {} more. how many {} now?\nA: ",
                names[i % names.len()],
                2 + i % 9,
                items[i % items.len()],
                1 + i % 7,
                items[i % items.len()],
            ),
            "code" => format!("def add_{}(x):\n    return ", 1 + i % 9),
            _ => format!(
                "USER: hello, can we talk about {}?\nBOT: ",
                topics[i % topics.len()]
            ),
        };
        let mut p = text.into_bytes();
        p.truncate(prompt_len);
        let mut padded = vec![b' '; prompt_len - p.len()];
        padded.extend_from_slice(&p);
        prompts.push(padded);
    }
    Ok(TaskSet { task: task.to_string(), paper_analog: paper_analog.to_string(), prompt_len, prompts })
}

/// Load a task from the manifest when one is available, else fall back to
/// the builtin synthetic prompts.
pub fn load_task_or_builtin(
    manifest: Option<&Manifest>,
    task: &str,
    prompt_len: usize,
    n_prompts: usize,
) -> Result<TaskSet> {
    match manifest {
        Some(m) => load_task(m, task),
        None => builtin_task(task, prompt_len, n_prompts),
    }
}

/// Slice the held-out stream into non-overlapping windows of `window`
/// tokens (the wikitext2-perplexity analog for Table I).
pub fn heldout_windows(manifest: &Manifest, window: usize, max_windows: usize) -> Result<Vec<Vec<u8>>> {
    let bytes = std::fs::read(manifest.path(&manifest.heldout))
        .with_context(|| format!("reading {}", manifest.heldout))?;
    let mut windows = Vec::new();
    let mut off = 0;
    while off + window <= bytes.len() && windows.len() < max_windows {
        windows.push(bytes[off..off + window].to_vec());
        off += window;
    }
    anyhow::ensure!(!windows.is_empty(), "held-out stream too short");
    Ok(windows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&root).ok()
    }

    #[test]
    fn loads_all_three_tasks() {
        let Some(m) = manifest() else {
            crate::log_info!("speq::workload::tasks", "skipping (no artifacts)");
            return;
        };
        for t in task_names() {
            let ts = load_task(&m, t).unwrap();
            assert!(!ts.prompts.is_empty());
            assert_eq!(ts.prompt_len, m.prompt_len);
            for p in &ts.prompts {
                assert_eq!(p.len(), ts.prompt_len);
            }
        }
    }

    #[test]
    fn paper_analog_mapping() {
        let Some(m) = manifest() else {
            crate::log_info!("speq::workload::tasks", "skipping (no artifacts)");
            return;
        };
        assert_eq!(load_task(&m, "math").unwrap().paper_analog, "GSM8K");
        assert_eq!(load_task(&m, "code").unwrap().paper_analog, "Humaneval");
        assert_eq!(load_task(&m, "chat").unwrap().paper_analog, "MT-bench");
    }

    #[test]
    fn builtin_tasks_cover_all_families_without_artifacts() {
        for t in task_names() {
            let ts = builtin_task(t, 64, 5).unwrap();
            assert_eq!(ts.prompts.len(), 5);
            assert!(ts.prompts.iter().all(|p| p.len() == 64));
            assert_ne!(ts.prompts[0], ts.prompts[1]);
        }
        assert_eq!(builtin_task("math", 64, 2).unwrap().paper_analog, "GSM8K");
        assert!(builtin_task("poetry", 64, 2).is_err());
        // The fallback path selects builtin when no manifest is given.
        let ts = load_task_or_builtin(None, "code", 48, 3).unwrap();
        assert_eq!(ts.prompt_len, 48);
    }

    #[test]
    fn heldout_windows_are_disjoint_and_sized() {
        let Some(m) = manifest() else {
            crate::log_info!("speq::workload::tasks", "skipping (no artifacts)");
            return;
        };
        let w = heldout_windows(&m, 256, 8).unwrap();
        assert_eq!(w.len(), 8);
        assert!(w.iter().all(|x| x.len() == 256));
        assert_ne!(w[0], w[1]);
    }
}
