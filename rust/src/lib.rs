//! # SPEQ — lossless speculative LLM decoding via bit-sharing quantization
//!
//! Reproduction of *"From Quarter to All: Accelerating Speculative LLM
//! Decoding via Floating-Point Exponent Remapping and Parameter Sharing"*
//! (CS.AR 2025) as a layered Rust + JAX + Pallas stack.
//!
//! ## Module map
//!
//! Algorithm layer:
//! * [`bsfp`] — the Bit-Sharing Floating Point codec (the paper's §III
//!   algorithm): exponent remapping, Algorithm-1 outlier handling, Eq. 4
//!   group scales, the Fig. 5 hardware decoders, and the bit-plane split
//!   (`bsfp::PlanePair`: nibble-packed `W_q` prefix plane + 12-bit-packed
//!   `W_r` residual plane — the packed weight store's resident layout).
//! * [`quant`] — baseline quantizers (FP4 variants for Table I, INT4/8
//!   Olive/Tender analogs for the accelerator comparison).
//!
//! Execution layer (the [`runtime::Backend`] abstraction):
//! * [`runtime`] — the `Backend` trait every layer above is written
//!   against: the single-sequence ops (prefill / decode_full /
//!   decode_draft / verify / eval with opaque state threading), the
//!   batched serving ops (`prefill_batch` / `decode_full_batch` /
//!   `decode_draft_batch` / `verify_batch`) over a backend-owned
//!   `SeqSlot`-indexed KV arena, and the weight-traffic accounting
//!   surface (`runtime::TrafficSnapshot` via `Backend::traffic` /
//!   `drain_traffic`); the always-available pure-Rust
//!   [`runtime::NativeBackend`] keeps every quantizable linear once, in
//!   a bit-plane packed store, and the cache-blocked kernels in
//!   `runtime::kernels` decode it on the fly — the draft GEMV streams
//!   only the prefix plane (a quarter of the full pass's weight bytes),
//!   the full/verify GEMV streams prefix + residual (the FP16
//!   footprint), and both share one accumulation order so outputs are
//!   bit-identical to dense execution.  Kernels run column-sharded on a
//!   std-only persistent worker pool (`runtime::pool`), attention runs
//!   parallel over (sequence, head) pairs, and activations live in a
//!   flat reusable workspace (no per-step allocation after warm-up).
//!   The plane decoders and per-element updates run through
//!   runtime-dispatched SIMD tiers ([`runtime::SimdLevel`]:
//!   AVX2/SSE4.1/NEON behind an always-available scalar reference,
//!   forced via `--simd` / `SPEQ_SIMD`); because vector code is confined
//!   to element-wise work and each output element keeps its exact
//!   ascending-index accumulation order, results are bitwise identical
//!   for every thread count *and* dispatch tier
//!   ([`runtime::NativeConfig`], `--threads`, `SPEQ_THREADS`).
//!   KV history is paged, not dense: `runtime::paging` leases 16-token
//!   refcounted pages (`PageAllocator`, generation-stamped ids, typed
//!   double-free/stale-table errors, copy-on-write via `make_unique`),
//!   and `runtime::prefix` keys a radix tree on token streams so
//!   sequences sharing a prompt prefix reference the same physical
//!   pages — prefill of a cached prefix computes only the novel suffix,
//!   and decode COWs exactly the written page.  Paged gather/scatter
//!   keeps the ascending-index accumulation order, so outputs stay
//!   bitwise identical to the dense layout (`rust/tests/kv_paging.rs`).
//!   Also here: the [`runtime::ModelSource`] factory, and — behind the
//!   non-default `pjrt` cargo feature — the PJRT client wrapper that
//!   executes AOT-compiled HLO graphs buffer-to-buffer.
//! * [`model`] — manifests, weight loading, logits post-processing; with
//!   `pjrt`, the `model::ModelRuntime` PJRT backend implementation.
//!
//! Decoding + serving layer:
//! * [`specdec`] — the speculative decoding engine over any backend:
//!   quantized draft pass, full verification pass, shared KV cache, early
//!   exit (§III-C), the Eq. 1–2 analytic model, the per-sequence adaptive
//!   draft-length controller (censoring-corrected EWMA accept-rate
//!   estimate + Eq. 2 argmax over traffic-measured cost ratios), and the
//!   step-driven continuous-batching engine (`SpecSession`/`ArSession`
//!   state machines driven in lockstep by `BatchEngine`, bit-identical to
//!   sequential decoding).
//! * [`coordinator`] — serving layer: bounded priority queue with
//!   age-based anti-starvation, continuous-batching scheduler threads,
//!   streaming chunked responses, per-request deadlines + cooperative
//!   cancellation (retired sequences free their KV slots between engine
//!   steps), graceful drain/shutdown, sessions, metrics (failures,
//!   cancellations, batch occupancy, throughput, per-pass weight traffic
//!   and KV-paging stats drained from the backends after every engine
//!   step); admission is prefix-aware — the per-round budget counts only
//!   tokens the prefix cache can't serve — the production wrapper around
//!   the engine.
//! * [`net`] — the std-only HTTP/1.1 front end over the coordinator:
//!   `POST /v1/generate`, `POST /v1/stream` (Server-Sent Events over
//!   chunked transfer), `GET /healthz`, `GET /metrics` (Prometheus
//!   exposition with TTFT / inter-token / total latency histograms);
//!   admission control (bounded queue → `429 + Retry-After`), deadline
//!   and client-disconnect cancellation, graceful drain; plus the
//!   closed/open-loop (Poisson) load generator behind `speq loadgen`.
//!   Streamed tokens are bit-identical to offline generation.
//!
//! Robustness + observability layer:
//! * [`faults`] — deterministic fault injection for the serving stack: a
//!   seeded, schedule-driven `FaultPlan` (`SPEQ_FAULTS` / `--faults`)
//!   arming named probe sites — batched-step errors/panics/stalls, KV
//!   page exhaustion, scheduler-admission stalls, socket slow-writes and
//!   resets — plus the typed [`faults::FailureKind`] taxonomy the
//!   scheduler attaches when it contains a failure.  Disabled sites cost
//!   one relaxed atomic load; the blast-radius isolation, degradation
//!   ladder, and watchdog that consume these probes live in
//!   [`coordinator`] and [`net`].
//! * [`trace`] — always-compiled structured tracing (same disarmed-cost
//!   discipline as [`faults`]): per-request async spans (enqueue → admit
//!   → terminal outcome with per-phase latency attribution), per-step
//!   engine phase spans and scheduler step events with traffic/KV args,
//!   and per-iteration speculation instants, recorded into fixed-capacity
//!   per-thread rings and exported as Chrome trace-event JSON
//!   (Perfetto-loadable) via `GET /debug/trace` or `--trace-out`; the
//!   recorded accept histograms feed the `--exp accel-replay` projection.
//!
//! Evaluation layer:
//! * [`accel`] — cycle-level simulator of the SPEQ accelerator (§IV):
//!   reconfigurable PE array, BSFP decoders, SRAM buffers, DRAM channel,
//!   28 nm area/energy model, and the Olive/Tender/FP16 baselines.
//! * [`workload`] — task workloads (GSM8K/HumanEval/MT-bench analogs,
//!   from artifacts or builtin), trace capture.
//! * [`report`] — regenerates every table and figure of the paper's
//!   evaluation (see DESIGN.md §5 for the experiment index).
//! * [`util`] — in-tree substrates for the offline build (f16, JSON, RNG,
//!   CLI, bench, property testing).
//!
//! ## Backends at a glance
//!
//! The default build has **zero** external requirements: `cargo test`
//! exercises the full draft → verify → accept loop on the native backend
//! with builtin synthetic models, and greedy speculative decoding is
//! asserted bit-identical to the autoregressive baseline.  Artifacts
//! (trained weights) upgrade fidelity; the `pjrt` feature swaps in
//! compiled-graph execution.  See README.md for the architecture diagram.

pub mod accel;
pub mod bsfp;
pub mod coordinator;
pub mod faults;
pub mod model;
pub mod net;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod specdec;
pub mod trace;
pub mod util;
pub mod workload;

/// Crate version (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
