//! # SPEQ — lossless speculative LLM decoding via bit-sharing quantization
//!
//! Reproduction of *"From Quarter to All: Accelerating Speculative LLM
//! Decoding via Floating-Point Exponent Remapping and Parameter Sharing"*
//! (CS.AR 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * [`bsfp`] — the Bit-Sharing Floating Point codec (the paper's §III
//!   algorithm): exponent remapping, Algorithm-1 outlier handling, Eq. 4
//!   group scales, and the Fig. 5 hardware decoders.
//! * [`quant`] — baseline quantizers (FP4 variants for Table I, INT4/8
//!   Olive/Tender analogs for the accelerator comparison).
//! * [`runtime`] — PJRT CPU client wrapper: loads the AOT-compiled HLO
//!   graphs from `artifacts/` and executes them buffer-to-buffer.
//! * [`model`] — model manifests, weight loading, logits post-processing.
//! * [`specdec`] — the speculative decoding engine: quantized draft pass,
//!   full verification pass, shared KV cache, early exit (§III-C), plus the
//!   Eq. 1–2 analytic model.
//! * [`coordinator`] — serving layer: request queue, scheduler, sessions,
//!   metrics — the production wrapper around the engine.
//! * [`accel`] — cycle-level simulator of the SPEQ accelerator (§IV):
//!   reconfigurable PE array, BSFP decoders, SRAM buffers, DRAM channel,
//!   28 nm area/energy model, and the Olive/Tender/FP16 baselines.
//! * [`workload`] — synthetic task workloads (GSM8K/HumanEval/MT-bench
//!   analogs) and trace capture.
//! * [`report`] — regenerates every table and figure of the paper's
//!   evaluation (see DESIGN.md §5 for the experiment index).

pub mod accel;
pub mod bsfp;
pub mod coordinator;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod specdec;
pub mod util;
pub mod workload;

/// Crate version (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
