//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a seeded, schedule-driven list of rules, each naming
//! a *site* (a probe point compiled into the stack) and an *action* to
//! take when that site is hit.  Plans come from the `SPEQ_FAULTS` env var
//! or the `--faults` CLI flag (see [`FaultPlan::parse`] for the grammar),
//! or are built programmatically by the chaos tests.
//!
//! Sites are hot-path probes, so the disabled cost is one relaxed atomic
//! load and a branch ([`hit`] returns `None` immediately); no lock is
//! taken and no site counter is maintained unless a plan is installed.
//! With a plan installed, every decision is deterministic: per-site hit
//! counters drive `@n` triggers, and probabilistic `%p` triggers draw from
//! the plan's own SplitMix64 stream, so the same plan against the same
//! request sequence injects the same faults.
//!
//! Fault sites (the names accepted by the plan grammar):
//!
//! | site           | where it fires                                  | actions        |
//! |----------------|--------------------------------------------------|---------------|
//! | `step.prefill` | batched prefill op in [`BatchEngine::step`]      | `error`, `panic`, `stall<ms>` |
//! | `step.draft`   | batched draft-decode op                          | `error`, `panic`, `stall<ms>` |
//! | `step.verify`  | batched verify op                                | `error`, `panic`, `stall<ms>` |
//! | `step.decode`  | batched full-decode (AR) op                      | `error`, `panic`, `stall<ms>` |
//! | `worker.shard` | inside the native backend's sharded kernel loop  | `panic`        |
//! | `page.alloc`   | [`PageAllocator::try_alloc`]                     | `exhaust`      |
//! | `sched.admit`  | scheduler admission, after the cancel check      | `stall<ms>`    |
//! | `sock.write`   | before each SSE chunk write in the net server    | `slow<ms>`, `reset` |
//!
//! The failure taxonomy surfaced to clients is [`FailureKind`]; the
//! blast-radius containment that turns an injected (or organic) fault
//! into per-request typed errors lives in the coordinator scheduler.
//!
//! [`BatchEngine::step`]: crate::specdec::BatchEngine::step
//! [`PageAllocator::try_alloc`]: crate::runtime::paging::PageAllocator::try_alloc

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

/// A named probe point in the serving stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    StepPrefill,
    StepDraft,
    StepVerify,
    StepDecode,
    WorkerShard,
    PageAlloc,
    SchedAdmit,
    SockWrite,
}

const N_SITES: usize = 8;

impl FaultSite {
    fn index(self) -> usize {
        match self {
            FaultSite::StepPrefill => 0,
            FaultSite::StepDraft => 1,
            FaultSite::StepVerify => 2,
            FaultSite::StepDecode => 3,
            FaultSite::WorkerShard => 4,
            FaultSite::PageAlloc => 5,
            FaultSite::SchedAdmit => 6,
            FaultSite::SockWrite => 7,
        }
    }

    /// The name used by the plan grammar (and `--faults` docs).
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::StepPrefill => "step.prefill",
            FaultSite::StepDraft => "step.draft",
            FaultSite::StepVerify => "step.verify",
            FaultSite::StepDecode => "step.decode",
            FaultSite::WorkerShard => "worker.shard",
            FaultSite::PageAlloc => "page.alloc",
            FaultSite::SchedAdmit => "sched.admit",
            FaultSite::SockWrite => "sock.write",
        }
    }

    fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "step.prefill" => FaultSite::StepPrefill,
            "step.draft" => FaultSite::StepDraft,
            "step.verify" => FaultSite::StepVerify,
            "step.decode" => FaultSite::StepDecode,
            "worker.shard" => FaultSite::WorkerShard,
            "page.alloc" => FaultSite::PageAlloc,
            "sched.admit" => FaultSite::SchedAdmit,
            "sock.write" => FaultSite::SockWrite,
            _ => return None,
        })
    }
}

/// What an armed site does when its trigger fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Return a typed error from the probed operation.
    Error,
    /// Panic (exercises the `catch_unwind` + worker-pool panic plumbing).
    Panic,
    /// Report KV page exhaustion (only meaningful at `page.alloc`).
    Exhaust,
    /// Sleep this many milliseconds, then proceed (watchdog fodder).
    Stall(u64),
    /// Sleep this many milliseconds before a socket write (slow client /
    /// slow network emulation).
    Slow(u64),
    /// Hard-close the socket mid-stream.
    Reset,
}

/// The typed failure taxonomy surfaced to clients when a fault (injected
/// or organic) is contained by the scheduler.  Stringified into the
/// request's `Done(Err)` payload, so both the in-process API and the HTTP
/// error body carry it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// A batched backend op returned an error.
    StepError,
    /// A panic unwound out of an engine step (e.g. a kernel worker shard).
    WorkerPanic,
    /// The KV page budget was exhausted mid-decode.
    PageExhausted,
    /// The watchdog declared the engine step stuck past its deadline.
    StepTimeout,
}

impl FailureKind {
    pub fn as_str(self) -> &'static str {
        match self {
            FailureKind::StepError => "step_error",
            FailureKind::WorkerPanic => "worker_panic",
            FailureKind::PageExhausted => "page_exhausted",
            FailureKind::StepTimeout => "step_timeout",
        }
    }
}

impl std::fmt::Display for FailureKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// When a rule fires, relative to its site's hit counter.
#[derive(Debug, Clone, Copy)]
enum Trigger {
    /// Every hit.
    Always,
    /// Hits `n .. n + count` (1-based).
    Nth { n: u64, count: u64 },
    /// Each hit independently with probability `p` (seeded stream).
    Prob(f64),
}

#[derive(Debug, Clone)]
struct FaultRule {
    site: FaultSite,
    trigger: Trigger,
    action: FaultAction,
}

/// A seeded, schedule-driven fault plan.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
    rng: Rng,
    hits: [u64; N_SITES],
}

impl FaultPlan {
    /// An empty plan (no rules) with the given seed for `%p` triggers.
    pub fn seeded(seed: u64) -> Self {
        Self { rules: Vec::new(), rng: Rng::seed_from_u64(seed), hits: [0; N_SITES] }
    }

    /// Arm `site` to take `action` on its `n`th hit (1-based).
    pub fn on_nth(mut self, site: FaultSite, n: u64, action: FaultAction) -> Self {
        self.rules.push(FaultRule { site, trigger: Trigger::Nth { n, count: 1 }, action });
        self
    }

    /// Arm `site` to take `action` on hits `n .. n + count` (1-based).
    pub fn on_range(mut self, site: FaultSite, n: u64, count: u64, action: FaultAction) -> Self {
        self.rules.push(FaultRule { site, trigger: Trigger::Nth { n, count }, action });
        self
    }

    /// Arm `site` to take `action` on each hit with probability `p`.
    pub fn with_prob(mut self, site: FaultSite, p: f64, action: FaultAction) -> Self {
        self.rules.push(FaultRule { site, trigger: Trigger::Prob(p), action });
        self
    }

    /// Parse the `SPEQ_FAULTS` / `--faults` grammar: `;`-separated rules,
    /// optionally starting with `seed=<u64>`.  Each rule is
    /// `<site>[@<n>[x<count>]][%<p>]=<action>` where `<action>` is one of
    /// `error`, `panic`, `exhaust`, `stall<ms>`, `slow<ms>`, `reset`.
    /// No trigger means "every hit".  Examples:
    ///
    /// ```text
    /// seed=7;step.verify@2=error
    /// page.alloc@5x3=exhaust;sock.write%0.1=slow25
    /// ```
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan::seeded(0);
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            if let Some(seed) = part.strip_prefix("seed=") {
                plan.rng = Rng::seed_from_u64(
                    seed.parse::<u64>().with_context(|| format!("bad fault seed {seed:?}"))?,
                );
                continue;
            }
            let (lhs, rhs) = part
                .split_once('=')
                .with_context(|| format!("fault rule {part:?} missing '=<action>'"))?;
            let action = parse_action(rhs.trim())
                .with_context(|| format!("fault rule {part:?}: bad action {rhs:?}"))?;
            let (site_part, trigger) = parse_trigger(lhs.trim())
                .with_context(|| format!("fault rule {part:?}: bad trigger"))?;
            let site = FaultSite::from_name(site_part)
                .with_context(|| format!("unknown fault site {site_part:?}"))?;
            match (site, action) {
                (FaultSite::PageAlloc, FaultAction::Exhaust)
                | (FaultSite::WorkerShard, FaultAction::Panic)
                | (FaultSite::SchedAdmit, FaultAction::Stall(_))
                | (FaultSite::SockWrite, FaultAction::Slow(_) | FaultAction::Reset)
                | (
                    FaultSite::StepPrefill
                    | FaultSite::StepDraft
                    | FaultSite::StepVerify
                    | FaultSite::StepDecode,
                    FaultAction::Error | FaultAction::Panic | FaultAction::Stall(_),
                ) => {}
                _ => bail!(
                    "fault rule {part:?}: action not valid at site {}",
                    site.name()
                ),
            }
            plan.rules.push(FaultRule { site, trigger, action });
        }
        Ok(plan)
    }

    /// Evaluate one hit of `site` (increments the site counter).
    fn eval(&mut self, site: FaultSite) -> Option<FaultAction> {
        self.hits[site.index()] += 1;
        let hit = self.hits[site.index()];
        for rule in &self.rules {
            if rule.site != site {
                continue;
            }
            let fire = match rule.trigger {
                Trigger::Always => true,
                Trigger::Nth { n, count } => hit >= n && hit < n + count,
                Trigger::Prob(p) => self.rng.gen_f64() < p,
            };
            if fire {
                return Some(rule.action);
            }
        }
        None
    }
}

fn parse_trigger(lhs: &str) -> Result<(&str, Trigger)> {
    if let Some((site, prob)) = lhs.split_once('%') {
        let p: f64 = prob.parse().with_context(|| format!("bad probability {prob:?}"))?;
        anyhow::ensure!((0.0..=1.0).contains(&p), "probability {p} outside [0, 1]");
        return Ok((site, Trigger::Prob(p)));
    }
    if let Some((site, nth)) = lhs.split_once('@') {
        let (n, count) = match nth.split_once('x') {
            Some((n, c)) => (
                n.parse::<u64>().with_context(|| format!("bad hit index {n:?}"))?,
                c.parse::<u64>().with_context(|| format!("bad repeat count {c:?}"))?,
            ),
            None => (nth.parse::<u64>().with_context(|| format!("bad hit index {nth:?}"))?, 1),
        };
        anyhow::ensure!(n >= 1, "hit indices are 1-based");
        return Ok((site, Trigger::Nth { n, count }));
    }
    Ok((lhs, Trigger::Always))
}

fn parse_action(s: &str) -> Result<FaultAction> {
    Ok(match s {
        "error" => FaultAction::Error,
        "panic" => FaultAction::Panic,
        "exhaust" => FaultAction::Exhaust,
        "reset" => FaultAction::Reset,
        _ if s.starts_with("stall") => FaultAction::Stall(parse_ms(&s["stall".len()..])?),
        _ if s.starts_with("slow") => FaultAction::Slow(parse_ms(&s["slow".len()..])?),
        _ => bail!("unknown action {s:?}"),
    })
}

fn parse_ms(s: &str) -> Result<u64> {
    if s.is_empty() {
        return Ok(50); // default stall/slow duration
    }
    s.parse::<u64>().with_context(|| format!("bad millisecond count {s:?}"))
}

// ---- global plan registry ----

/// Fast-path guard: `false` means no plan is installed and [`hit`] is one
/// relaxed load + branch.
static ENABLED: AtomicBool = AtomicBool::new(false);
static ACTIVE: Mutex<Option<FaultPlan>> = Mutex::new(None);
static INJECTED: AtomicU64 = AtomicU64::new(0);
static RECOVERED: AtomicU64 = AtomicU64::new(0);
/// Serializes tests that install global plans (the plan registry is
/// process-wide; `cargo test` runs test fns concurrently).
static TEST_LOCK: Mutex<()> = Mutex::new(());

/// Install `plan` process-wide (replacing any prior plan).
pub fn install(plan: FaultPlan) {
    *ACTIVE.lock().unwrap_or_else(|e| e.into_inner()) = Some(plan);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Remove the installed plan; every site goes back to the no-op fast path.
pub fn clear() {
    ENABLED.store(false, Ordering::SeqCst);
    *ACTIVE.lock().unwrap_or_else(|e| e.into_inner()) = None;
}

/// Whether a plan is installed.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Parse and install `SPEQ_FAULTS` if set.  Called once from the CLI
/// entry point; library embedders call [`install`] directly.
pub fn init_from_env() -> Result<()> {
    if let Ok(spec) = std::env::var("SPEQ_FAULTS") {
        if !spec.trim().is_empty() {
            install(FaultPlan::parse(&spec).context("parsing SPEQ_FAULTS")?);
        }
    }
    Ok(())
}

/// Probe a fault site.  Returns the action to take, if the installed
/// plan's trigger fires on this hit.  `Stall`/`Slow` sleeps are performed
/// by the *caller* (the probe itself never blocks), so call sites can
/// honor them where sleeping is safe.
pub fn hit(site: FaultSite) -> Option<FaultAction> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let action = ACTIVE
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .as_mut()
        .and_then(|plan| plan.eval(site));
    if action.is_some() {
        INJECTED.fetch_add(1, Ordering::Relaxed);
    }
    action
}

/// Total faults whose trigger fired since process start (monotonic; spans
/// plan reinstalls).
pub fn injected_total() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Record that a fault's blast radius was contained (the scheduler kept
/// serving after handling it).
pub fn note_recovered() {
    RECOVERED.fetch_add(1, Ordering::Relaxed);
}

/// Total contained faults since process start.
pub fn recovered_total() -> u64 {
    RECOVERED.load(Ordering::Relaxed)
}

/// Serialize a test that installs global plans.  Hold the guard for the
/// whole test; the returned guard clears any leftover plan on drop.
pub fn test_guard() -> TestGuard {
    let guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    clear();
    TestGuard { _guard: guard }
}

pub struct TestGuard {
    _guard: MutexGuard<'static, ()>,
}

impl Drop for TestGuard {
    fn drop(&mut self) {
        clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_the_readme_examples() {
        let plan = FaultPlan::parse("seed=7;step.verify@2=error").unwrap();
        assert_eq!(plan.rules.len(), 1);
        let plan = FaultPlan::parse("page.alloc@5x3=exhaust;sock.write%0.1=slow25").unwrap();
        assert_eq!(plan.rules.len(), 2);
        assert_eq!(plan.rules[1].action, FaultAction::Slow(25));
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("nonsite@1=error").is_err());
        assert!(FaultPlan::parse("step.verify@1").is_err());
        assert!(FaultPlan::parse("step.verify@0=error").is_err());
        assert!(FaultPlan::parse("step.verify%1.5=error").is_err());
        assert!(FaultPlan::parse("page.alloc@1=panic").is_err(), "action/site mismatch");
        assert!(FaultPlan::parse("sock.write@1=error").is_err(), "action/site mismatch");
    }

    #[test]
    fn nth_trigger_fires_exactly_once() {
        let mut plan =
            FaultPlan::seeded(1).on_nth(FaultSite::StepVerify, 3, FaultAction::Error);
        let fired: Vec<bool> =
            (0..6).map(|_| plan.eval(FaultSite::StepVerify).is_some()).collect();
        assert_eq!(fired, vec![false, false, true, false, false, false]);
        // Other sites never fire.
        assert!(plan.eval(FaultSite::StepDraft).is_none());
    }

    #[test]
    fn range_trigger_fires_count_times() {
        let mut plan =
            FaultPlan::seeded(1).on_range(FaultSite::PageAlloc, 2, 3, FaultAction::Exhaust);
        let fired: Vec<bool> =
            (0..6).map(|_| plan.eval(FaultSite::PageAlloc).is_some()).collect();
        assert_eq!(fired, vec![false, true, true, true, false, false]);
    }

    #[test]
    fn prob_trigger_is_seed_deterministic() {
        let decisions = |seed: u64| -> Vec<bool> {
            let mut plan =
                FaultPlan::seeded(seed).with_prob(FaultSite::SockWrite, 0.5, FaultAction::Reset);
            (0..32).map(|_| plan.eval(FaultSite::SockWrite).is_some()).collect()
        };
        assert_eq!(decisions(9), decisions(9), "same seed, same schedule");
        assert_ne!(decisions(9), decisions(10), "different seeds diverge");
        assert!(decisions(9).iter().any(|&f| f) && decisions(9).iter().any(|&f| !f));
    }

    #[test]
    fn disabled_fast_path_returns_none() {
        let _g = test_guard();
        assert!(hit(FaultSite::StepVerify).is_none());
        install(FaultPlan::seeded(0).on_nth(FaultSite::StepVerify, 1, FaultAction::Error));
        assert_eq!(hit(FaultSite::StepVerify), Some(FaultAction::Error));
        clear();
        assert!(hit(FaultSite::StepVerify).is_none());
    }
}
