//! Bounded priority request queue with backpressure.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::model::SamplingParams;
use crate::specdec::SpecTrace;

/// Request priority class; within a class, strict FIFO.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Interactive,
    Batch,
}

/// Decoding mode for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// SPEQ speculative decoding (the default).
    Speculative,
    /// Full-precision autoregressive (baseline / comparison).
    Autoregressive,
}

/// A generation request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub gen_len: usize,
    pub max_draft: usize,
    pub gamma: f32,
    pub sampling: SamplingParams,
    pub mode: Mode,
    pub priority: Priority,
    /// Session to append this exchange to (multi-turn), if any.
    pub session: Option<u64>,
    pub submitted: Instant,
    pub respond_to: mpsc::Sender<Response>,
}

/// A finished generation (or an error).
pub struct Response {
    pub id: u64,
    pub result: anyhow::Result<ResponseBody>,
}

pub struct ResponseBody {
    pub tokens: Vec<u8>,
    pub trace: SpecTrace,
    /// Queue wait + execution, seconds.
    pub latency_s: f64,
    /// Execution only, seconds.
    pub exec_s: f64,
    pub worker: usize,
}

/// Errors surfaced to submitters.
#[derive(Debug, PartialEq, Eq)]
pub enum QueueError {
    /// Backpressure: the queue is at capacity.
    Full,
    /// The server is shutting down.
    Closed,
}

impl std::fmt::Display for QueueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueueError::Full => write!(f, "queue full (backpressure)"),
            QueueError::Closed => write!(f, "queue closed"),
        }
    }
}

impl std::error::Error for QueueError {}

struct Inner {
    interactive: VecDeque<Request>,
    batch: VecDeque<Request>,
    closed: bool,
}

/// MPMC bounded queue: any thread may submit; workers pop.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cond: Condvar,
    capacity: usize,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner {
                interactive: VecDeque::new(),
                batch: VecDeque::new(),
                closed: false,
            }),
            cond: Condvar::new(),
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.interactive.len() + g.batch.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking submit; `Err(Full)` signals backpressure to the client.
    pub fn submit(&self, req: Request) -> Result<(), QueueError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(QueueError::Closed);
        }
        if g.interactive.len() + g.batch.len() >= self.capacity {
            return Err(QueueError::Full);
        }
        match req.priority {
            Priority::Interactive => g.interactive.push_back(req),
            Priority::Batch => g.batch.push_back(req),
        }
        drop(g);
        self.cond.notify_one();
        Ok(())
    }

    /// Blocking pop: interactive first, then batch; `None` on shutdown.
    pub fn pop(&self) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.interactive.pop_front() {
                return Some(r);
            }
            if let Some(r) = g.batch.pop_front() {
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.cond.wait(g).unwrap();
        }
    }

    /// Close the queue; wakes all waiting workers.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn dummy_request(id: u64, priority: Priority) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id,
                prompt: vec![b'x'],
                gen_len: 1,
                max_draft: 16,
                gamma: 0.6,
                sampling: SamplingParams::greedy(),
                mode: Mode::Speculative,
                priority,
                session: None,
                submitted: Instant::now(),
                respond_to: tx,
            },
            rx,
        )
    }

    #[test]
    fn fifo_within_priority_and_interactive_first() {
        let q = RequestQueue::new(8);
        let (r1, _k1) = dummy_request(1, Priority::Batch);
        let (r2, _k2) = dummy_request(2, Priority::Interactive);
        let (r3, _k3) = dummy_request(3, Priority::Interactive);
        q.submit(r1).unwrap();
        q.submit(r2).unwrap();
        q.submit(r3).unwrap();
        assert_eq!(q.pop().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn capacity_backpressure() {
        let q = RequestQueue::new(2);
        let (r1, _k1) = dummy_request(1, Priority::Batch);
        let (r2, _k2) = dummy_request(2, Priority::Batch);
        let (r3, _k3) = dummy_request(3, Priority::Batch);
        q.submit(r1).unwrap();
        q.submit(r2).unwrap();
        let err = q.submit(r3).unwrap_err();
        assert_eq!(err, QueueError::Full);
    }

    #[test]
    fn close_unblocks_poppers() {
        let q = Arc::new(RequestQueue::new(2));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap(), "pop should return None after close");
    }

    #[test]
    fn submit_after_close_fails() {
        let q = RequestQueue::new(2);
        q.close();
        let (r, _k) = dummy_request(1, Priority::Batch);
        assert_eq!(q.submit(r).unwrap_err(), QueueError::Closed);
    }
}
