//! Bounded priority request queue with backpressure, plus the streaming
//! response protocol between the scheduler and submitters.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::model::SamplingParams;
use crate::specdec::SpecTrace;

/// Batch requests older than this are served ahead of interactive traffic
/// (anti-starvation), unless the queue overrides it.
pub const DEFAULT_BATCH_PROMOTE_AFTER: Duration = Duration::from_millis(500);

/// Request priority class; within a class, strict FIFO.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Interactive,
    Batch,
}

/// Decoding mode for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// SPEQ speculative decoding (the default).
    Speculative,
    /// Full-precision autoregressive (baseline / comparison).
    Autoregressive,
}

/// Cooperative cancellation handle shared between a submitter (or the
/// network front end, on client disconnect) and the scheduler.  Cheap to
/// clone; setting it asks the scheduler to retire the request *between*
/// engine steps — the sequence frees its KV slot instead of occupying a
/// batch slot to completion.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation (idempotent).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Why a request was retired without completing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelKind {
    /// The request's deadline expired before generation finished.
    Deadline,
    /// The submitter cancelled it (e.g. the HTTP client disconnected).
    Cancelled,
}

impl std::fmt::Display for CancelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CancelKind::Deadline => write!(f, "deadline exceeded"),
            CancelKind::Cancelled => write!(f, "cancelled by client"),
        }
    }
}

/// A generation request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub gen_len: usize,
    pub max_draft: usize,
    pub gamma: f32,
    /// Run the adaptive draft-length controller for this request
    /// (speculative mode only; static `max_draft` when false).
    pub adaptive: bool,
    pub sampling: SamplingParams,
    pub mode: Mode,
    pub priority: Priority,
    /// Session to append this exchange to (multi-turn), if any.
    pub session: Option<u64>,
    /// Retire the request between engine steps once this instant passes.
    pub deadline: Option<Instant>,
    /// Cooperative cancellation (client disconnect); checked with the
    /// deadline between steps.
    pub cancel: CancelToken,
    pub submitted: Instant,
    pub respond_to: mpsc::Sender<Response>,
}

/// The single source of truth for cancellation precedence (explicit
/// cancel beats deadline), shared by queued, held, and in-flight checks.
pub(crate) fn cancel_reason(cancel: &CancelToken, deadline: Option<Instant>) -> Option<CancelKind> {
    if cancel.is_cancelled() {
        return Some(CancelKind::Cancelled);
    }
    match deadline {
        Some(d) if Instant::now() >= d => Some(CancelKind::Deadline),
        _ => None,
    }
}

impl Request {
    /// Whether the request should be retired now instead of (further)
    /// occupying a batch slot, and why.
    pub fn cancel_reason(&self) -> Option<CancelKind> {
        cancel_reason(&self.cancel, self.deadline)
    }
}

/// One message on a request's response channel.
pub struct Response {
    pub id: u64,
    pub event: ResponseEvent,
}

/// The streaming response protocol: zero or more `Chunk`s followed by
/// exactly one terminal event (`Done` or `Cancelled`).
pub enum ResponseEvent {
    /// Tokens accepted since the last chunk (clients can render these
    /// incrementally instead of waiting for the full generation).
    Chunk(Vec<u8>),
    /// Generation finished (the body repeats the full token stream) or
    /// failed.
    Done(anyhow::Result<ResponseBody>),
    /// The request was retired between engine steps (deadline expired or
    /// the submitter cancelled); its KV slot has been freed.  Terminal.
    Cancelled(CancelKind),
}

pub struct ResponseBody {
    pub tokens: Vec<u8>,
    pub trace: SpecTrace,
    /// Queue wait + execution, seconds.
    pub latency_s: f64,
    /// Time in the batch engine (admission to completion), seconds.
    pub exec_s: f64,
    /// Per-phase latency attribution; the buckets sum to `latency_s` by
    /// construction (see [`super::metrics::RequestPhases`]).
    pub phases: super::metrics::RequestPhases,
    pub worker: usize,
}

/// Client-side handle for one request's response stream.
pub struct ResponseStream {
    rx: mpsc::Receiver<Response>,
    cancel: CancelToken,
}

impl ResponseStream {
    pub(crate) fn new(rx: mpsc::Receiver<Response>, cancel: CancelToken) -> Self {
        Self { rx, cancel }
    }

    /// The request's cancellation handle (clone it to cancel from another
    /// thread, e.g. when an HTTP client disconnects mid-stream).
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Next event (a token chunk or a terminal event).
    pub fn recv(&self) -> anyhow::Result<Response> {
        self.rx.recv().context("server dropped the request")
    }

    /// [`ResponseStream::recv`] with a timeout: `Ok(None)` means no event
    /// arrived yet (the caller can poll other work — e.g. the network
    /// front end probes its socket for client disconnect between waits).
    pub fn recv_timeout(&self, timeout: Duration) -> anyhow::Result<Option<Response>> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Ok(Some(r)),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                anyhow::bail!("server dropped the request")
            }
        }
    }

    /// Drain the stream to completion and return the final body
    /// (cancellation surfaces as an error).
    pub fn wait(self) -> anyhow::Result<ResponseBody> {
        loop {
            match self.recv()?.event {
                ResponseEvent::Chunk(_) => {}
                ResponseEvent::Done(result) => return result,
                ResponseEvent::Cancelled(kind) => anyhow::bail!("request cancelled: {kind}"),
            }
        }
    }
}

/// Errors surfaced to submitters.
#[derive(Debug, PartialEq, Eq)]
pub enum QueueError {
    /// Backpressure: the queue is at capacity.
    Full,
    /// The server is shutting down.
    Closed,
}

impl std::fmt::Display for QueueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueueError::Full => write!(f, "queue full (backpressure)"),
            QueueError::Closed => write!(f, "queue closed"),
        }
    }
}

impl std::error::Error for QueueError {}

struct Inner {
    interactive: VecDeque<Request>,
    batch: VecDeque<Request>,
    closed: bool,
}

impl Inner {
    /// Scheduling policy: an aged batch request first (anti-starvation),
    /// then interactive, then batch.
    fn pick(&mut self, promote_after: Duration) -> Option<Request> {
        if let Some(front) = self.batch.front() {
            if front.submitted.elapsed() >= promote_after {
                return self.batch.pop_front();
            }
        }
        if let Some(r) = self.interactive.pop_front() {
            return Some(r);
        }
        self.batch.pop_front()
    }
}

/// MPMC bounded queue: any thread may submit; scheduler workers pop.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cond: Condvar,
    capacity: usize,
    /// Age at which a waiting batch request outranks interactive traffic.
    promote_after: Duration,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> Self {
        Self::with_promotion(capacity, DEFAULT_BATCH_PROMOTE_AFTER)
    }

    /// A queue whose batch-starvation threshold is `promote_after`.
    pub fn with_promotion(capacity: usize, promote_after: Duration) -> Self {
        Self {
            inner: Mutex::new(Inner {
                interactive: VecDeque::new(),
                batch: VecDeque::new(),
                closed: false,
            }),
            cond: Condvar::new(),
            capacity,
            promote_after,
        }
    }

    pub fn len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.interactive.len() + g.batch.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking submit; `Err(Full)` signals backpressure to the client.
    pub fn submit(&self, req: Request) -> Result<(), QueueError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(QueueError::Closed);
        }
        if g.interactive.len() + g.batch.len() >= self.capacity {
            return Err(QueueError::Full);
        }
        match req.priority {
            Priority::Interactive => g.interactive.push_back(req),
            Priority::Batch => g.batch.push_back(req),
        }
        drop(g);
        self.cond.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` on shutdown (after draining queued requests).
    pub fn pop(&self) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.pick(self.promote_after) {
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.cond.wait(g).unwrap();
        }
    }

    /// Non-blocking pop — the continuous-batching scheduler uses this to
    /// admit queued requests between engine steps without stalling the
    /// in-flight batch.
    pub fn try_pop(&self) -> Option<Request> {
        self.inner.lock().unwrap().pick(self.promote_after)
    }

    /// Close the queue; wakes all waiting workers.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn dummy_request(id: u64, priority: Priority) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id,
                prompt: vec![b'x'],
                gen_len: 1,
                max_draft: 16,
                gamma: 0.6,
                adaptive: false,
                sampling: SamplingParams::greedy(),
                mode: Mode::Speculative,
                priority,
                session: None,
                deadline: None,
                cancel: CancelToken::new(),
                submitted: Instant::now(),
                respond_to: tx,
            },
            rx,
        )
    }

    #[test]
    fn cancel_token_is_shared_and_idempotent() {
        let (r, _k) = dummy_request(1, Priority::Interactive);
        let handle = r.cancel.clone();
        assert!(r.cancel_reason().is_none());
        handle.cancel();
        handle.cancel();
        assert_eq!(r.cancel_reason(), Some(CancelKind::Cancelled));
    }

    #[test]
    fn expired_deadline_reports_deadline_kind() {
        let (mut r, _k) = dummy_request(1, Priority::Interactive);
        r.deadline = Some(Instant::now() - Duration::from_millis(1));
        assert_eq!(r.cancel_reason(), Some(CancelKind::Deadline));
        // Explicit cancellation outranks the deadline (it is checked first).
        r.cancel.cancel();
        assert_eq!(r.cancel_reason(), Some(CancelKind::Cancelled));
    }

    #[test]
    fn fifo_within_priority_and_interactive_first() {
        let q = RequestQueue::new(8);
        let (r1, _k1) = dummy_request(1, Priority::Batch);
        let (r2, _k2) = dummy_request(2, Priority::Interactive);
        let (r3, _k3) = dummy_request(3, Priority::Interactive);
        q.submit(r1).unwrap();
        q.submit(r2).unwrap();
        q.submit(r3).unwrap();
        assert_eq!(q.pop().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn aged_batch_request_is_served_before_interactive() {
        // A steady interactive stream must not starve batch traffic: once a
        // batch request crosses the promotion threshold it is served next.
        let q = RequestQueue::with_promotion(8, Duration::from_millis(25));
        let (rb, _kb) = dummy_request(1, Priority::Batch);
        q.submit(rb).unwrap();
        std::thread::sleep(Duration::from_millis(40));
        let (ri, _ki) = dummy_request(2, Priority::Interactive);
        q.submit(ri).unwrap();
        assert_eq!(q.pop().unwrap().id, 1, "aged batch request must be promoted");
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn young_batch_request_still_yields_to_interactive() {
        let q = RequestQueue::with_promotion(8, Duration::from_secs(60));
        let (rb, _kb) = dummy_request(1, Priority::Batch);
        let (ri, _ki) = dummy_request(2, Priority::Interactive);
        q.submit(rb).unwrap();
        q.submit(ri).unwrap();
        assert_eq!(q.pop().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn try_pop_is_non_blocking() {
        let q = RequestQueue::new(4);
        assert!(q.try_pop().is_none());
        let (r, _k) = dummy_request(1, Priority::Interactive);
        q.submit(r).unwrap();
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn capacity_backpressure() {
        let q = RequestQueue::new(2);
        let (r1, _k1) = dummy_request(1, Priority::Batch);
        let (r2, _k2) = dummy_request(2, Priority::Batch);
        let (r3, _k3) = dummy_request(3, Priority::Batch);
        q.submit(r1).unwrap();
        q.submit(r2).unwrap();
        let err = q.submit(r3).unwrap_err();
        assert_eq!(err, QueueError::Full);
    }

    #[test]
    fn close_unblocks_poppers() {
        let q = Arc::new(RequestQueue::new(2));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap(), "pop should return None after close");
    }

    #[test]
    fn submit_after_close_fails() {
        let q = RequestQueue::new(2);
        q.close();
        let (r, _k) = dummy_request(1, Priority::Batch);
        assert_eq!(q.submit(r).unwrap_err(), QueueError::Closed);
    }
}
