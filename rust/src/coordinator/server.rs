//! Worker pool + dispatch loop.
//!
//! Execution backends are not `Send` (PJRT handles pin to their thread),
//! so each worker thread builds its own backend + `Engine` stack from the
//! configured [`ModelSource`] and pulls requests from the shared queue.
//! Responses flow back through the per-request channel.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::metrics::Metrics;
use super::queue::{Mode, Priority, Request, RequestQueue, Response, ResponseBody};
use super::session::SessionStore;
use crate::model::{Manifest, SamplingParams};
use crate::runtime::{builtin_config, load_backend, Backend, ModelSource};
use crate::specdec::{Engine, SpecConfig};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Where model weights come from (artifacts dir or the builtin zoo).
    pub source: ModelSource,
    pub model: String,
    pub workers: usize,
    pub queue_capacity: usize,
    /// Trailing bytes of history kept per session.
    pub session_history: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            source: ModelSource::auto(),
            model: "vicuna-7b-tiny".to_string(),
            workers: 2,
            queue_capacity: 64,
            session_history: 96,
        }
    }
}

/// A running SPEQ serving instance.
pub struct Server {
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    sessions: Arc<SessionStore>,
    workers: Vec<JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Start the worker pool.  Each worker loads the model on its own
    /// backend stack before serving (cold-start happens here, not on the
    /// request path).
    pub fn start(cfg: ServerConfig) -> Result<Self> {
        // Fail fast if the model source is unusable before spawning threads.
        match &cfg.source {
            ModelSource::Builtin => {
                builtin_config(&cfg.model)?;
            }
            ModelSource::Artifacts(root) => {
                let manifest = Manifest::load(root)?;
                manifest.model(&cfg.model)?;
            }
        }

        let queue = Arc::new(RequestQueue::new(cfg.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let sessions = Arc::new(SessionStore::new(cfg.session_history));

        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for wid in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let sessions = sessions.clone();
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                worker_main(wid, cfg, queue, metrics, sessions, ready);
            }));
        }
        drop(ready_tx);
        // Wait for all workers to finish loading (or fail).
        for _ in 0..cfg.workers.max(1) {
            ready_rx.recv().context("worker died during startup")??;
        }
        Ok(Self {
            queue,
            metrics,
            sessions,
            workers,
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }

    /// Submit a generation request; returns `(id, receiver)`.
    #[allow(clippy::too_many_arguments)]
    pub fn submit(
        &self,
        prompt: &[u8],
        gen_len: usize,
        mode: Mode,
        priority: Priority,
        sampling: SamplingParams,
        session: Option<u64>,
        max_draft: usize,
        gamma: f32,
    ) -> Result<(u64, mpsc::Receiver<Response>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            prompt: prompt.to_vec(),
            gen_len,
            max_draft,
            gamma,
            sampling,
            mode,
            priority,
            session,
            submitted: Instant::now(),
            respond_to: tx,
        };
        if let Err(e) = self.queue.submit(req) {
            self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("submit failed: {e}");
        }
        Ok((id, rx))
    }

    /// Convenience: submit with defaults and wait for the reply.
    pub fn generate(&self, prompt: &[u8], gen_len: usize) -> Result<ResponseBody> {
        let (_, rx) = self.submit(
            prompt,
            gen_len,
            Mode::Speculative,
            Priority::Interactive,
            SamplingParams::greedy(),
            None,
            16,
            0.6,
        )?;
        let resp = rx.recv().context("server dropped the request")?;
        resp.result
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn sessions(&self) -> &SessionStore {
        &self.sessions
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(
    wid: usize,
    cfg: ServerConfig,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    sessions: Arc<SessionStore>,
    ready: mpsc::Sender<Result<()>>,
) {
    // Build the per-worker backend stack.
    let backend: Box<dyn Backend> = match load_backend(&cfg.source, &cfg.model) {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let engine = Engine::new(backend.as_ref());

    while let Some(req) = queue.pop() {
        let exec_start = Instant::now();
        let prompt = sessions.effective_prompt(req.session, &req.prompt);
        let result = match req.mode {
            Mode::Speculative => engine.generate_spec(
                &prompt,
                &SpecConfig {
                    max_draft: req.max_draft,
                    gamma: req.gamma,
                    sampling: req.sampling,
                    gen_len: req.gen_len,
                },
            ),
            Mode::Autoregressive => engine.generate_ar(&prompt, req.gen_len, req.sampling),
        };
        let exec_s = exec_start.elapsed().as_secs_f64();
        let latency_s = req.submitted.elapsed().as_secs_f64();
        let body = result.map(|r| {
            metrics.record_completion(
                r.tokens.len() as u64,
                r.trace.draft_steps(),
                r.trace.verify_passes(),
                latency_s,
                exec_s,
            );
            if let Some(sid) = req.session {
                sessions.append(sid, &req.prompt, &r.tokens);
            }
            ResponseBody {
                tokens: r.tokens,
                trace: r.trace,
                latency_s,
                exec_s,
                worker: wid,
            }
        });
        // The submitter may have gone away; that's fine.
        let _ = req.respond_to.send(Response { id: req.id, result: body });
    }
}
