//! Scheduler loop + continuous batching dispatch.
//!
//! Execution backends are not `Send` (PJRT handles pin to their thread),
//! so each scheduler thread builds its own backend + [`BatchEngine`] stack
//! from the configured [`ModelSource`] and runs a continuous-batching loop:
//! admit queued requests into the active batch (up to `max_batch`) between
//! engine steps, step every in-flight session in lockstep, stream newly
//! accepted tokens to each submitter as [`ResponseEvent::Chunk`]s, and
//! retire completed sessions with a final [`ResponseEvent::Done`].

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::Metrics;
use super::queue::{
    CancelKind, CancelToken, Mode, Priority, QueueError, Request, RequestQueue, Response,
    ResponseBody, ResponseEvent, ResponseStream, DEFAULT_BATCH_PROMOTE_AFTER,
};
use super::session::SessionStore;
use crate::model::{Manifest, SamplingParams};
use crate::runtime::{builtin_config, load_backend_with, Backend, ModelSource, NativeConfig};
use crate::specdec::{
    AdaptiveConfig, ArSession, BatchEngine, BatchSpecPolicy, GenSession, SpecConfig, SpecSession,
};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Where model weights come from (artifacts dir or the builtin zoo).
    pub source: ModelSource,
    pub model: String,
    /// Scheduler threads, each owning one backend stack.
    pub workers: usize,
    pub queue_capacity: usize,
    /// Trailing bytes of history kept per session.
    pub session_history: usize,
    /// Maximum sequences batched per scheduler engine step.
    pub max_batch: usize,
    /// Age at which a waiting batch-priority request outranks interactive
    /// traffic (anti-starvation).
    pub batch_promote_after: Duration,
    /// Kernel worker-pool width per scheduler backend (`0` = auto-detect;
    /// default from `SPEQ_THREADS`, else serial).  Purely a wall-clock
    /// knob: generated tokens are bit-identical for every value.
    pub threads: NativeConfig,
    /// Hard cap on live KV pages per scheduler backend (`None` =
    /// unbounded).  Allocation past the budget fails with a typed
    /// `PageExhausted`, which the scheduler contains per-request and
    /// answers with the degradation ladder instead of crashing.
    pub kv_page_budget: Option<u64>,
    /// Watchdog deadline for a single engine step.  A step that runs
    /// longer is declared stuck: once it returns, the whole batch is
    /// failed with `FailureKind::StepTimeout` (its KV state is suspect)
    /// and the scheduler keeps serving.  Default 30s, overridable with
    /// `SPEQ_STEP_DEADLINE_MS`.
    pub step_deadline: Duration,
}

/// Default watchdog deadline: `SPEQ_STEP_DEADLINE_MS` or 30 seconds.
fn default_step_deadline() -> Duration {
    std::env::var("SPEQ_STEP_DEADLINE_MS")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_secs(30))
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            source: ModelSource::auto(),
            model: "vicuna-7b-tiny".to_string(),
            workers: 2,
            queue_capacity: 64,
            session_history: 96,
            max_batch: 8,
            batch_promote_after: DEFAULT_BATCH_PROMOTE_AFTER,
            threads: NativeConfig::default(),
            kv_page_budget: None,
            step_deadline: default_step_deadline(),
        }
    }
}

/// Everything about a submission except the prompt; `Default` gives the
/// common case (greedy speculative decoding, interactive priority).
#[derive(Debug, Clone)]
pub struct SubmitParams {
    pub gen_len: usize,
    pub mode: Mode,
    pub priority: Priority,
    pub sampling: SamplingParams,
    /// Session to append this exchange to (multi-turn), if any.
    pub session: Option<u64>,
    pub max_draft: usize,
    pub gamma: f32,
    /// Run the per-sequence adaptive draft-length controller (speculative
    /// mode only).  Off by default: static sessions are bit-identical to
    /// the pre-controller engine and ignore the batch speculation policy.
    pub adaptive: bool,
    /// Absolute deadline: once it passes, the scheduler retires the
    /// request between engine steps (freeing its batch slot) and sends a
    /// terminal [`ResponseEvent::Cancelled`].
    pub deadline: Option<Instant>,
}

impl Default for SubmitParams {
    fn default() -> Self {
        Self {
            gen_len: 64,
            mode: Mode::Speculative,
            priority: Priority::Interactive,
            sampling: SamplingParams::greedy(),
            session: None,
            max_draft: 16,
            gamma: 0.6,
            adaptive: false,
            deadline: None,
        }
    }
}

/// One scheduler's step-in-progress marker for the watchdog.
struct WatchSlot {
    /// Milliseconds since watchdog origin when the in-flight step began,
    /// plus one (so 0 can mean "idle, nothing to time").
    step_start: std::sync::atomic::AtomicU64,
    /// Set by the watchdog thread when the in-flight step overruns the
    /// deadline; consumed by the scheduler when the step finally returns.
    timed_out: std::sync::atomic::AtomicBool,
}

/// Detects stuck engine steps.  Scheduler threads bracket every step with
/// [`Watchdog::begin_step`] / [`Watchdog::end_step`]; a monitor thread
/// polls the slots and flags any step older than the deadline.  The
/// flagged batch is failed *by its own scheduler* once the step returns —
/// the watchdog never touches backend state from outside (backends are
/// not `Sync`), it only renders the verdict.
struct Watchdog {
    origin: Instant,
    deadline: Duration,
    slots: Vec<WatchSlot>,
    stop: std::sync::atomic::AtomicBool,
}

impl Watchdog {
    fn new(workers: usize, deadline: Duration) -> Self {
        let slots = (0..workers)
            .map(|_| WatchSlot {
                step_start: std::sync::atomic::AtomicU64::new(0),
                timed_out: std::sync::atomic::AtomicBool::new(false),
            })
            .collect();
        Self { origin: Instant::now(), deadline, slots, stop: std::sync::atomic::AtomicBool::new(false) }
    }

    fn now_ms(&self) -> u64 {
        self.origin.elapsed().as_millis() as u64
    }

    fn begin_step(&self, wid: usize) {
        self.slots[wid].step_start.store(self.now_ms() + 1, Ordering::Release);
    }

    /// Clear the in-progress marker; returns `true` when the watchdog
    /// declared this step stuck while it ran.
    fn end_step(&self, wid: usize) -> bool {
        self.slots[wid].step_start.store(0, Ordering::Release);
        self.slots[wid].timed_out.swap(false, Ordering::AcqRel)
    }

    /// Monitor loop body (runs on its own thread until `stop`).
    fn run(&self) {
        let deadline_ms = self.deadline.as_millis() as u64;
        while !self.stop.load(Ordering::Acquire) {
            let now = self.now_ms();
            for slot in &self.slots {
                let started = slot.step_start.load(Ordering::Acquire);
                if started != 0 && now.saturating_sub(started - 1) > deadline_ms {
                    slot.timed_out.store(true, Ordering::Release);
                }
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

/// A running SPEQ serving instance.
pub struct Server {
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    sessions: Arc<SessionStore>,
    workers: Vec<JoinHandle<()>>,
    watchdog: Arc<Watchdog>,
    watchdog_thread: Option<JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Start the scheduler pool.  Each scheduler thread loads the model on
    /// its own backend stack before serving (cold-start happens here, not
    /// on the request path).
    pub fn start(cfg: ServerConfig) -> Result<Self> {
        // Fail fast if the model source is unusable before spawning threads.
        match &cfg.source {
            ModelSource::Builtin => {
                builtin_config(&cfg.model)?;
            }
            ModelSource::Artifacts(root) => {
                let manifest = Manifest::load(root)?;
                manifest.model(&cfg.model)?;
            }
        }

        let queue =
            Arc::new(RequestQueue::with_promotion(cfg.queue_capacity, cfg.batch_promote_after));
        let metrics = Arc::new(Metrics::new());
        let sessions = Arc::new(SessionStore::new(cfg.session_history));
        let watchdog = Arc::new(Watchdog::new(cfg.workers.max(1), cfg.step_deadline));
        let watchdog_thread = {
            let w = watchdog.clone();
            std::thread::spawn(move || w.run())
        };

        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for wid in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let sessions = sessions.clone();
            let watchdog = watchdog.clone();
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                scheduler_main(wid, cfg, queue, metrics, sessions, watchdog, ready);
            }));
        }
        drop(ready_tx);
        // Wait for all workers to finish loading (or fail).  On failure the
        // queue must be closed before returning, otherwise workers that
        // *did* load successfully would block on `pop()` forever (leaked
        // threads on a startup error).
        let mut startup_err: Option<anyhow::Error> = None;
        for _ in 0..cfg.workers.max(1) {
            match ready_rx.recv().context("worker died during startup") {
                Ok(Ok(())) => {}
                Ok(Err(e)) | Err(e) => {
                    startup_err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = startup_err {
            queue.close();
            for h in workers {
                let _ = h.join();
            }
            watchdog.stop.store(true, Ordering::Release);
            let _ = watchdog_thread.join();
            return Err(e);
        }
        Ok(Self {
            queue,
            metrics,
            sessions,
            workers,
            watchdog,
            watchdog_thread: Some(watchdog_thread),
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }

    /// Submit a generation request; returns `(id, stream)`.  The stream
    /// yields incremental token chunks followed by the final body.
    pub fn submit(&self, prompt: &[u8], params: SubmitParams) -> Result<(u64, ResponseStream)> {
        self.try_submit(prompt, params)
            .map_err(|e| anyhow::anyhow!("submit failed: {e}"))
    }

    /// [`Server::submit`] with a typed rejection: callers that must map
    /// backpressure onto a protocol (HTTP 429 vs 503) need to distinguish
    /// `Full` from `Closed`, which the stringly `anyhow` path cannot.
    pub fn try_submit(
        &self,
        prompt: &[u8],
        params: SubmitParams,
    ) -> Result<(u64, ResponseStream), QueueError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        let cancel = CancelToken::new();
        let req = Request {
            id,
            prompt: prompt.to_vec(),
            gen_len: params.gen_len,
            max_draft: params.max_draft,
            gamma: params.gamma,
            adaptive: params.adaptive,
            sampling: params.sampling,
            mode: params.mode,
            priority: params.priority,
            session: params.session,
            deadline: params.deadline,
            cancel: cancel.clone(),
            submitted: Instant::now(),
            respond_to: tx,
        };
        if let Err(e) = self.queue.submit(req) {
            self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        // The request span opens only once the queue accepted it (rejected
        // submissions never enter the lifecycle), and closes at whichever
        // terminal event retires it: done, cancelled, failed, quarantined.
        crate::trace::request_begin(id, &[("gen_len", params.gen_len as f64)]);
        Ok((id, ResponseStream::new(rx, cancel)))
    }

    /// Convenience: submit with defaults and wait for the reply.
    pub fn generate(&self, prompt: &[u8], gen_len: usize) -> Result<ResponseBody> {
        let (_, stream) = self.submit(prompt, SubmitParams { gen_len, ..Default::default() })?;
        stream.wait()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn sessions(&self) -> &SessionStore {
        &self.sessions
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Requests accepted but not yet terminally answered (queued, held, or
    /// in-flight in a scheduler batch).  Computed from the monotonic
    /// metrics counters, so it is eventually consistent — exact once the
    /// queue is closed and the schedulers go idle.
    pub fn pending_requests(&self) -> u64 {
        let m = &self.metrics;
        let submitted = m.requests_submitted.load(Ordering::Relaxed);
        let terminal = m.requests_rejected.load(Ordering::Relaxed)
            + m.requests_completed.load(Ordering::Relaxed)
            + m.requests_failed.load(Ordering::Relaxed)
            + m.requests_cancelled.load(Ordering::Relaxed);
        submitted.saturating_sub(terminal)
    }

    /// Stop accepting new requests and wait (up to `timeout`) for every
    /// accepted request to reach a terminal event — completed, failed, or
    /// cancelled.  Returns `true` when fully drained; `false` means work
    /// was still in flight at the timeout (the workers keep running — call
    /// [`Server::shutdown`] to join them).  Idempotent; the graceful path
    /// for the network front end is `drain(timeout)` then `shutdown()`.
    pub fn drain(&self, timeout: Duration) -> bool {
        self.queue.close();
        let t0 = Instant::now();
        loop {
            if self.pending_requests() == 0 {
                return true;
            }
            if t0.elapsed() >= timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Drain in-flight sequences to completion, then stop and join all
    /// workers.  Closing the queue lets `pop()` hand out every request
    /// already accepted, and each scheduler keeps stepping its active
    /// batch until every session reaches a terminal event — so joining
    /// the workers *is* the drain barrier: no accepted request is dropped
    /// mid-generation.
    pub fn shutdown(mut self) {
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.watchdog.stop.store(true, Ordering::Release);
        if let Some(h) = self.watchdog_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

/// One request in the scheduler's active batch.
struct ActiveReq {
    id: u64,
    session: GenSession,
    /// Conversation to append the exchange to on completion.
    conversation: Option<u64>,
    /// The submitted prompt (session history excluded), for the store.
    prompt: Vec<u8>,
    deadline: Option<Instant>,
    cancel: CancelToken,
    submitted: Instant,
    admitted: Instant,
    respond_to: mpsc::Sender<Response>,
}

impl ActiveReq {
    fn cancel_reason(&self) -> Option<CancelKind> {
        super::queue::cancel_reason(&self.cancel, self.deadline)
    }
}

/// Retire a request without completing it: free its KV slot, count it,
/// and send the terminal [`ResponseEvent::Cancelled`].
fn cancel_active(mut a: ActiveReq, kind: CancelKind, backend: &dyn Backend, metrics: &Metrics) {
    a.session.release(backend);
    metrics.requests_cancelled.fetch_add(1, Ordering::Relaxed);
    crate::trace::request_end(a.id, "cancelled", &[]);
    let _ = a.respond_to.send(Response { id: a.id, event: ResponseEvent::Cancelled(kind) });
}

/// Graceful-degradation ladder state (per scheduler thread; each thread
/// owns one backend and therefore one KV pool).
///
/// Rungs: 0 healthy → 1 evict prefix-cache LRU leaves → 2 cap/disable
/// speculation → 3 shed new network admissions (the net front end turns
/// the shared gauge into `503 + Retry-After`).  KV pressure — a
/// `PageExhausted` failure — escalates one rung per failing step; a run
/// of clean steps walks back down one rung at a time.
struct Ladder {
    level: u64,
    clean_steps: u32,
}

/// Consecutive clean engine steps required to step one rung back down.
const LADDER_RECOVER_STEPS: u32 = 32;
/// Prefix-cache pages evicted per rung-1 relief attempt.
const LADDER_EVICT_PAGES: usize = 8;

impl Ladder {
    fn new() -> Self {
        Self { level: 0, clean_steps: 0 }
    }

    /// KV pressure observed this step: climb one rung and apply its
    /// relief action.  Returns the new level.
    fn escalate(&mut self, backend: &dyn Backend, metrics: &Metrics) -> u64 {
        self.clean_steps = 0;
        self.level = (self.level + 1).min(3);
        if self.level >= 1 {
            // Rung 1: give pages back before anything else degrades —
            // cached prefixes are strictly recomputable.
            backend.relieve_kv_pressure(LADDER_EVICT_PAGES);
        }
        metrics.degradation_level.store(self.level, Ordering::Relaxed);
        self.level
    }

    /// A step finished without KV pressure: after enough of them, walk
    /// one rung back down (and count the recovery).
    fn step_clean(&mut self, metrics: &Metrics) {
        if self.level == 0 {
            return;
        }
        self.clean_steps += 1;
        if self.clean_steps >= LADDER_RECOVER_STEPS {
            self.clean_steps = 0;
            self.level -= 1;
            metrics.degradation_level.store(self.level, Ordering::Relaxed);
            crate::faults::note_recovered();
        }
    }
}

fn scheduler_main(
    wid: usize,
    cfg: ServerConfig,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    sessions: Arc<SessionStore>,
    watchdog: Arc<Watchdog>,
    ready: mpsc::Sender<Result<()>>,
) {
    // Build the per-scheduler backend stack.
    let backend: Box<dyn Backend> = match load_backend_with(&cfg.source, &cfg.model, &cfg.threads)
    {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    backend.set_kv_page_budget(cfg.kv_page_budget);
    let engine = BatchEngine::new(backend.as_ref());
    let max_batch = cfg.max_batch.max(1);
    let spec_policy = BatchSpecPolicy::default();
    let mut ladder = Ladder::new();
    let mut active: Vec<ActiveReq> = Vec::new();
    // Requests whose conversation already has an in-flight turn: co-batching
    // them would read session history before the earlier turn appends it,
    // so they wait here until the conflict retires.
    let mut held: Vec<Request> = Vec::new();

    loop {
        // ---- cancellation: retire expired/cancelled work between steps ----
        // Cancelled sequences free their KV slots *here*, before admission,
        // so an expired request never blocks a queued one from taking its
        // batch slot.  Held requests are purged the same way (their
        // deadline keeps ticking while they wait out a session conflict).
        let mut i = 0;
        while i < active.len() {
            match active[i].cancel_reason() {
                Some(kind) => {
                    let a = active.swap_remove(i);
                    cancel_active(a, kind, backend.as_ref(), &metrics);
                }
                None => i += 1,
            }
        }
        let mut i = 0;
        while i < held.len() {
            match held[i].cancel_reason() {
                Some(kind) => {
                    let req = held.remove(i);
                    metrics.requests_cancelled.fetch_add(1, Ordering::Relaxed);
                    crate::trace::request_end(req.id, "cancelled", &[]);
                    let _ = req
                        .respond_to
                        .send(Response { id: req.id, event: ResponseEvent::Cancelled(kind) });
                }
                None => i += 1,
            }
        }

        // ---- admission: refill the batch (held conflicts first) ----
        // Prefix-aware pacing: each admission round may start at most
        // `novel_budget` tokens of *fresh* prefill work — prompt tokens not
        // already resident in the backend's prefix cache.  Cache-hot
        // prompts (shared system prefixes, repeated turns) are nearly free
        // to admit; a burst of cold prompts is spread across rounds so it
        // cannot stall the in-flight batch behind one giant prefill wave.
        // At least one request is always admitted per round (liveness),
        // and deferred requests park in `held` for the next round.
        let mut novel_budget = 2 * backend.prefill_len();
        let mut admitted_this_round = 0usize;
        let mut h = 0;
        while h < held.len() && active.len() < max_batch {
            if session_conflicts(&active, held[h].session) {
                h += 1;
                continue;
            }
            let novel = novel_prompt_tokens(&held[h], backend.as_ref(), &sessions);
            if admitted_this_round > 0 && novel > novel_budget {
                h += 1; // cold prompt over budget: retry next round
                continue;
            }
            let req = held.remove(h);
            novel_budget = novel_budget.saturating_sub(novel);
            admitted_this_round += 1;
            admit(req, backend.as_ref(), &sessions, &metrics, &mut active);
        }
        if active.is_empty() && held.is_empty() {
            // Idle: block until a request arrives (or shutdown).
            match queue.pop() {
                Some(req) => {
                    novel_budget = novel_budget
                        .saturating_sub(novel_prompt_tokens(&req, backend.as_ref(), &sessions));
                    admitted_this_round += 1;
                    admit(req, backend.as_ref(), &sessions, &metrics, &mut active);
                }
                None => return, // closed and drained
            }
        }
        while active.len() < max_batch {
            match queue.try_pop() {
                Some(req) => {
                    if session_conflicts(&active, req.session) {
                        held.push(req);
                        continue;
                    }
                    let novel = novel_prompt_tokens(&req, backend.as_ref(), &sessions);
                    if admitted_this_round > 0 && novel > novel_budget {
                        held.push(req); // over budget: admit next round
                        continue;
                    }
                    novel_budget = novel_budget.saturating_sub(novel);
                    admitted_this_round += 1;
                    admit(req, backend.as_ref(), &sessions, &metrics, &mut active);
                }
                None => break,
            }
        }
        if active.is_empty() {
            continue; // admission rejected everything it popped
        }
        metrics.record_batch_step(active.len());

        // ---- batch-level speculation policy ----
        // At high occupancy the shared verification pass amortizes the
        // full-weight stream across the batch, so long drafts stop paying;
        // cap (or at full occupancy disable) the draft budget of adaptive
        // sessions for the coming step.  Static sessions ignore the cap —
        // their token streams must stay bit-identical to the policy-free
        // engine.
        // Rung 2 of the degradation ladder overrides the occupancy cap:
        // under sustained KV pressure speculation is disabled outright
        // (draft chains are the most page-hungry transient allocation).
        // Static sessions still ignore the cap, preserving their
        // bit-identical contract.
        let cap = if ladder.level >= 2 { 0 } else { spec_policy.draft_cap(active.len(), max_batch) };
        for a in &mut active {
            a.session.apply_spec_policy(cap);
        }

        // ---- one lockstep engine step over the whole batch ----
        let step_start = if crate::trace::armed() { crate::trace::now_us() } else { 0 };
        watchdog.begin_step(wid);
        let report = {
            let mut refs: Vec<&mut GenSession> =
                active.iter_mut().map(|a| &mut a.session).collect();
            engine.step_report(&mut refs)
        };
        let step_stuck = watchdog.end_step(wid);
        // Fold this step's weight traffic into the shared sink (the drain
        // keeps per-backend counters from double-counting across workers;
        // backends without accounting report zeros).
        let traffic_delta = backend.drain_traffic();
        metrics.record_traffic(&traffic_delta);
        // Refresh the paged-KV occupancy/prefix-cache snapshot alongside it
        // (point-in-time, so replace rather than merge).
        let kv_stats = backend.kv_stats();
        metrics.record_kv(&kv_stats);
        // One complete ("X") event per engine step, carrying the batch
        // occupancy, this step's drained weight-byte deltas, and the KV
        // page gauge — the per-step view that the per-request spans can't
        // show (a step serves the whole batch at once).
        crate::trace::complete(
            "sched",
            "step",
            step_start,
            &[
                ("n", active.len() as f64),
                ("draft_bytes", traffic_delta.draft_bytes as f64),
                ("full_bytes", traffic_delta.full_bytes as f64),
                ("kv_pages", kv_stats.pages_in_use as f64),
            ],
        );
        // Aggregate live adaptive-controller state (chosen draft budget +
        // accept-rate estimate) across the batch for the gauges; replaced,
        // not merged, like the KV snapshot.
        let mut n = 0u64;
        let (mut sum_budget, mut sum_rate) = (0f64, 0f64);
        for a in &active {
            if let Some((budget, rate)) = a.session.adaptive_state() {
                n += 1;
                sum_budget += budget as f64;
                sum_rate += rate;
            }
        }
        metrics.record_spec_adaptive(n, sum_budget, sum_rate);

        // ---- watchdog verdict: a stuck step poisons the whole batch ----
        // The step did eventually return (we only get here afterwards),
        // but a step that blew the deadline points at wedged backend state
        // (or an injected stall); every in-flight sequence is failed with
        // a typed `StepTimeout` and the scheduler keeps serving.
        if step_stuck {
            for mut a in active.drain(..) {
                a.session.release(backend.as_ref());
                metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
                metrics.requests_quarantined.fetch_add(1, Ordering::Relaxed);
                crate::trace::request_end(a.id, "quarantined", &[]);
                let _ = a.respond_to.send(Response {
                    id: a.id,
                    event: ResponseEvent::Done(Err(anyhow::anyhow!(
                        "request failed ({}): engine step exceeded the {}ms watchdog deadline",
                        crate::faults::FailureKind::StepTimeout,
                        cfg.step_deadline.as_millis(),
                    ))),
                });
            }
            crate::faults::note_recovered();
            ladder.step_clean(&metrics);
            continue;
        }

        // ---- quarantine: contain step failures to the sessions they hit ----
        // `step_report` attributes each failed batched op to exactly the
        // sessions it was operating on; those (and only those) are evicted
        // from the batch with a typed error while the survivors keep their
        // bit-identical token streams.  Removal walks indices descending so
        // `swap_remove` never disturbs a still-pending failure index.
        if !report.failures.is_empty() {
            let mut failures = report.failures;
            failures.sort_by(|x, y| y.session.cmp(&x.session));
            let mut kv_pressure = false;
            for f in failures {
                kv_pressure |= f.kind == crate::faults::FailureKind::PageExhausted;
                let mut a = active.swap_remove(f.session);
                a.session.release(backend.as_ref());
                metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
                metrics.requests_quarantined.fetch_add(1, Ordering::Relaxed);
                crate::trace::request_end(a.id, "quarantined", &[]);
                let _ = a.respond_to.send(Response {
                    id: a.id,
                    event: ResponseEvent::Done(Err(anyhow::anyhow!(
                        "request failed ({}): {}",
                        f.kind,
                        f.detail
                    ))),
                });
            }
            // The fault is contained: survivors keep stepping, the
            // scheduler thread is still alive.
            crate::faults::note_recovered();
            if kv_pressure {
                ladder.escalate(backend.as_ref(), &metrics);
            } else {
                ladder.step_clean(&metrics);
            }
        } else {
            ladder.step_clean(&metrics);
        }

        // ---- stream chunks; retire completed sessions ----
        let mut i = 0;
        while i < active.len() {
            let a = &mut active[i];
            let chunk = a.session.take_new_tokens();
            if !chunk.is_empty() {
                let _ = a
                    .respond_to
                    .send(Response { id: a.id, event: ResponseEvent::Chunk(chunk) });
            }
            if a.session.is_done() {
                let done = active.swap_remove(i);
                finalize(done, wid, &metrics, &sessions);
            } else {
                i += 1;
            }
        }
    }
}

/// Whether `session` already has an in-flight turn in the active batch.
fn session_conflicts(active: &[ActiveReq], session: Option<u64>) -> bool {
    match session {
        Some(sid) => active.iter().any(|a| a.conversation == Some(sid)),
        None => false,
    }
}

/// How many prompt tokens this request would have to compute from
/// scratch, after consulting the backend's prefix cache.  Mirrors
/// `pad_prompt`'s windowing: the prompt is clipped to the trailing
/// `prefill_len()` bytes, one byte per token.  Backends without a prefix
/// cache report zero cached tokens, so the whole window counts as novel.
fn novel_prompt_tokens(req: &Request, backend: &dyn Backend, sessions: &SessionStore) -> usize {
    let effective = sessions.effective_prompt(req.session, &req.prompt);
    let window = effective.len().min(backend.prefill_len());
    let toks: Vec<i32> =
        effective[effective.len() - window..].iter().map(|&b| b as i32).collect();
    window.saturating_sub(backend.prefix_cached_tokens(&toks))
}

/// Validate the prompt window at admission: predictably bad input must be
/// failed per-request here, never inside a batched engine step (where it
/// would fail every co-batched request).
fn validate_prompt(effective: &[u8], backend: &dyn Backend) -> Result<()> {
    anyhow::ensure!(!effective.is_empty(), "empty prompt");
    let vocab = backend.vocab();
    let window = effective.len().min(backend.prefill_len());
    if let Some(&bad) = effective[effective.len() - window..]
        .iter()
        .find(|&&b| (b as usize) >= vocab)
    {
        anyhow::bail!("prompt byte {bad} outside model vocab {vocab}");
    }
    Ok(())
}

/// Turn a queued request into an in-flight session (or fail it fast).
fn admit(
    req: Request,
    backend: &dyn Backend,
    sessions: &SessionStore,
    metrics: &Metrics,
    active: &mut Vec<ActiveReq>,
) {
    // A request that expired (or was cancelled) while queued is retired
    // without ever leasing a KV slot.
    if let Some(kind) = req.cancel_reason() {
        metrics.requests_cancelled.fetch_add(1, Ordering::Relaxed);
        crate::trace::request_end(req.id, "cancelled", &[]);
        let _ = req
            .respond_to
            .send(Response { id: req.id, event: ResponseEvent::Cancelled(kind) });
        return;
    }
    // Fault site `sched.admit`: an injected stall here widens the window
    // between the cancel check above and the session build below, making
    // the cancel-during-admission race deterministically testable.
    if crate::faults::enabled() {
        if let Some(crate::faults::FaultAction::Stall(ms)) =
            crate::faults::hit(crate::faults::FaultSite::SchedAdmit)
        {
            std::thread::sleep(Duration::from_millis(ms));
        }
    }
    let effective = sessions.effective_prompt(req.session, &req.prompt);
    if let Err(e) = validate_prompt(&effective, backend) {
        metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
        crate::trace::request_end(req.id, "failed", &[]);
        let _ = req
            .respond_to
            .send(Response { id: req.id, event: ResponseEvent::Done(Err(e)) });
        return;
    }
    let built = match req.mode {
        Mode::Speculative => SpecSession::new(
            backend,
            &effective,
            SpecConfig {
                max_draft: req.max_draft,
                gamma: req.gamma,
                sampling: req.sampling,
                gen_len: req.gen_len,
                adaptive: if req.adaptive {
                    AdaptiveConfig::enabled()
                } else {
                    AdaptiveConfig::default()
                },
            },
        )
        .map(GenSession::Spec),
        Mode::Autoregressive => {
            ArSession::new(backend, &effective, req.gen_len, req.sampling).map(GenSession::Ar)
        }
    };
    match built {
        Ok(mut session) => {
            // Re-check cancellation *after* the session build: admission
            // runs a prefill-sized amount of work, and a request cancelled
            // during it (client disconnect racing `Server::drain`) used to
            // slip into the batch anyway and burn an engine step.  Release
            // the KV slot the build just leased and retire it here instead.
            if let Some(kind) = req.cancel_reason() {
                session.release(backend);
                metrics.requests_cancelled.fetch_add(1, Ordering::Relaxed);
                crate::trace::request_end(req.id, "cancelled", &[]);
                let _ = req
                    .respond_to
                    .send(Response { id: req.id, event: ResponseEvent::Cancelled(kind) });
                return;
            }
            crate::trace::request_instant(req.id, "admit");
            active.push(ActiveReq {
                id: req.id,
                session,
                conversation: req.session,
                prompt: req.prompt,
                deadline: req.deadline,
                cancel: req.cancel,
                submitted: req.submitted,
                admitted: Instant::now(),
                respond_to: req.respond_to,
            });
        }
        Err(e) => {
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            crate::trace::request_end(req.id, "failed", &[]);
            let _ = req
                .respond_to
                .send(Response { id: req.id, event: ResponseEvent::Done(Err(e)) });
        }
    }
}

/// Record metrics + session history and send the final response.
fn finalize(a: ActiveReq, wid: usize, metrics: &Metrics, sessions: &SessionStore) {
    let exec_s = a.admitted.elapsed().as_secs_f64();
    let latency_s = a.submitted.elapsed().as_secs_f64();
    // Latency attribution: the batch engine charged each batched op's wall
    // time to this session's compute buckets; queue wait is everything
    // before admission, and the stall bucket absorbs the batch-residency
    // remainder (lockstep waits on co-batched sequences, chunk streaming,
    // scheduler bookkeeping) so the five buckets sum to `latency_s`.
    let compute = a.session.phase_seconds();
    let phases = super::metrics::RequestPhases {
        queue_wait_s: (latency_s - exec_s).max(0.0),
        prefill_s: compute.prefill_s,
        draft_s: compute.draft_s,
        verify_s: compute.verify_s,
        stall_s: (exec_s - compute.total()).max(0.0),
    };
    let r = a.session.into_result();
    metrics.record_completion(
        r.tokens.len() as u64,
        r.trace.draft_steps(),
        r.trace.verify_passes(),
        latency_s,
        exec_s,
        &phases,
    );
    if let Some(sid) = a.conversation {
        sessions.append(sid, &a.prompt, &r.tokens);
    }
    crate::trace::request_end(
        a.id,
        "done",
        &[
            ("tokens", r.tokens.len() as f64),
            ("queue_wait_ms", phases.queue_wait_s * 1e3),
            ("prefill_ms", phases.prefill_s * 1e3),
            ("draft_ms", phases.draft_s * 1e3),
            ("verify_ms", phases.verify_s * 1e3),
            ("stall_ms", phases.stall_s * 1e3),
        ],
    );
    let body = ResponseBody {
        tokens: r.tokens,
        trace: r.trace,
        latency_s,
        exec_s,
        phases,
        worker: wid,
    };
    let _ = a.respond_to.send(Response { id: a.id, event: ResponseEvent::Done(Ok(body)) });
}
