//! Serving coordinator — the production wrapper around the SPEQ engine.
//!
//! Architecture (vLLM-router-like, scaled to a CPU testbed):
//!
//! ```text
//!   clients ──submit──► RequestQueue (bounded, priority FIFO)
//!                           │ pop (scheduler policy)
//!              ┌────────────┼────────────┐
//!           worker 0     worker 1     worker N-1        (threads)
//!           Engine+model Engine+model Engine+model      (one Backend stack each;
//!              │            │            │               backends are not Send)
//!              └───────────►└───responses►└──► per-request channel
//! ```
//!
//! Workers are backend-agnostic: each builds its model from the configured
//! [`ModelSource`] — the builtin synthetic zoo (default, zero artifacts) or
//! an artifacts directory (trained weights; PJRT graphs with the `pjrt`
//! feature).
//!
//! * [`queue`] — bounded priority queue with backpressure and FIFO fairness
//!   within a priority class.
//! * [`server`] — worker pool, dispatch loop, graceful shutdown.
//! * [`session`] — multi-turn conversation state (token histories).
//! * [`metrics`] — counters and latency percentiles for the serving report.

mod metrics;
mod queue;
mod server;
mod session;

pub use metrics::{Metrics, MetricsSnapshot};
pub use queue::{Mode, Priority, QueueError, Request, RequestQueue, Response, ResponseBody};
pub use server::{Server, ServerConfig};
pub use session::SessionStore;

// Re-exported for convenience: server configs name their model source.
pub use crate::runtime::ModelSource;
