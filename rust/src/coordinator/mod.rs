//! Serving coordinator — the production wrapper around the SPEQ engine.
//!
//! Architecture (continuous batching, vLLM-style, scaled to a CPU testbed):
//!
//! ```text
//!   clients ──submit──► RequestQueue (bounded, priority FIFO + age promotion)
//!                           │ pop / try_pop (admission between steps)
//!              ┌────────────┼────────────┐
//!         scheduler 0  scheduler 1  scheduler N-1         (threads)
//!         BatchEngine  BatchEngine  BatchEngine           (one Backend stack each;
//!          + sessions   + sessions   + sessions            backends are not Send)
//!              │            │            │
//!        SeqSlot KV arena  (batched prefill/draft/verify, ≤ max_batch seqs)
//!              │            │            │
//!              └──Chunk*, Done──► per-request response channel (streaming)
//! ```
//!
//! Each scheduler thread owns one backend and steps its active batch in
//! lockstep: newly queued requests are admitted *between* engine steps (so
//! a long generation never blocks admission), every step streams each
//! weight once for the whole batch, and each accepted token chunk is pushed
//! to the submitter immediately.  Schedulers are backend-agnostic: the
//! builtin synthetic zoo (default, zero artifacts) or an artifacts
//! directory (trained weights; PJRT graphs with the `pjrt` feature).
//!
//! * [`queue`] — bounded priority queue with backpressure, FIFO fairness
//!   within a class, and age-based promotion so batch traffic cannot
//!   starve; plus the streaming `Chunk* / (Done|Cancelled)` response
//!   protocol and the [`CancelToken`] cooperative-cancellation handle.
//! * [`server`] — scheduler pool, continuous-batching loop, per-request
//!   deadlines and cancellation (expired or client-cancelled sequences
//!   free their KV slots between engine steps), graceful drain +
//!   shutdown, [`SubmitParams`].
//! * [`session`] — multi-turn conversation state (token histories).
//! * [`metrics`] — counters, latency percentiles, failure counts, batch
//!   occupancy histogram, and throughput for the serving report.

mod metrics;
mod queue;
mod server;
mod session;

pub use metrics::{Metrics, MetricsSnapshot, RequestPhases};
pub use queue::{
    CancelKind, CancelToken, Mode, Priority, QueueError, Request, RequestQueue, Response,
    ResponseBody, ResponseEvent, ResponseStream, DEFAULT_BATCH_PROMOTE_AFTER,
};
pub use server::{Server, ServerConfig, SubmitParams};
pub use session::SessionStore;

// Re-exported for convenience: server configs name their model source.
pub use crate::runtime::ModelSource;
