//! Serving metrics: counters + latency percentiles.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics sink (cheap atomic counters; latencies under a mutex).
#[derive(Default)]
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub draft_steps: AtomicU64,
    pub verify_passes: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
    exec_us: Mutex<Vec<u64>>,
}

/// Point-in-time view with computed percentiles.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub tokens: u64,
    pub draft_steps: u64,
    pub verify_passes: u64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub exec_p50_ms: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_completion(&self, tokens: u64, drafts: u64, verifies: u64, latency_s: f64, exec_s: f64) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens, Ordering::Relaxed);
        self.draft_steps.fetch_add(drafts, Ordering::Relaxed);
        self.verify_passes.fetch_add(verifies, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().push((latency_s * 1e6) as u64);
        self.exec_us.lock().unwrap().push((exec_s * 1e6) as u64);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let pct = |v: &mut Vec<u64>, p: f64| -> f64 {
            if v.is_empty() {
                return 0.0;
            }
            v.sort_unstable();
            let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
            v[idx] as f64 / 1e3
        };
        let mut lat = self.latencies_us.lock().unwrap().clone();
        let mut exec = self.exec_us.lock().unwrap().clone();
        MetricsSnapshot {
            submitted: self.requests_submitted.load(Ordering::Relaxed),
            completed: self.requests_completed.load(Ordering::Relaxed),
            rejected: self.requests_rejected.load(Ordering::Relaxed),
            tokens: self.tokens_generated.load(Ordering::Relaxed),
            draft_steps: self.draft_steps.load(Ordering::Relaxed),
            verify_passes: self.verify_passes.load(Ordering::Relaxed),
            latency_p50_ms: pct(&mut lat, 0.50),
            latency_p95_ms: pct(&mut lat, 0.95),
            latency_p99_ms: pct(&mut lat, 0.99),
            exec_p50_ms: pct(&mut exec, 0.50),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_from_recorded_latencies() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record_completion(10, 5, 2, i as f64 / 1000.0, i as f64 / 2000.0);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.tokens, 1000);
        assert!((s.latency_p50_ms - 50.0).abs() <= 2.0, "{}", s.latency_p50_ms);
        assert!((s.latency_p95_ms - 95.0).abs() <= 2.0, "{}", s.latency_p95_ms);
        assert!(s.exec_p50_ms < s.latency_p50_ms);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.latency_p50_ms, 0.0);
    }
}
