//! Serving metrics: counters, latency percentiles, batch-occupancy
//! histogram, throughput, and weight-traffic accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::runtime::{KvStats, TrafficSnapshot};

/// Latency reservoirs keep at most this many samples — a sliding window
/// over the most recent completions — so a long-running server's snapshot
/// cost and memory stay bounded.
const LATENCY_SAMPLE_CAP: usize = 65_536;

/// Append to a bounded reservoir: grow until the cap, then overwrite in
/// ring order by completion index (keeps the newest `LATENCY_SAMPLE_CAP`
/// observations).
fn push_capped(v: &mut Vec<u64>, val: u64, nth: u64) {
    if v.len() < LATENCY_SAMPLE_CAP {
        v.push(val);
    } else {
        v[(nth as usize) % LATENCY_SAMPLE_CAP] = val;
    }
}

/// Per-request latency attribution: where one completed request's wall
/// time went.  The scheduler constructs this at finalize so that
/// `queue_wait_s + prefill_s + draft_s + verify_s + stall_s == latency_s`
/// by construction: the compute buckets come from the batch engine's
/// per-phase charging (each batched op's full wall duration, charged to
/// every participant), `queue_wait_s` is submission→admission, and
/// `stall_s` is the batch-engine residency remainder — lockstep waits on
/// co-batched sequences, chunk streaming, scheduler bookkeeping.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RequestPhases {
    /// Submission to admission (queued and/or held), seconds.
    pub queue_wait_s: f64,
    /// Batched prefill ops this request participated in, seconds.
    pub prefill_s: f64,
    /// Batched quantized-draft ops, seconds.
    pub draft_s: f64,
    /// Batched verify / full-precision decode ops, seconds.
    pub verify_s: f64,
    /// Batch residency not covered by a compute op, seconds.
    pub stall_s: f64,
}

impl RequestPhases {
    /// Sum of every bucket — equals total request latency by construction.
    pub fn total_s(&self) -> f64 {
        self.queue_wait_s + self.prefill_s + self.draft_s + self.verify_s + self.stall_s
    }
}

/// Shared metrics sink (cheap atomic counters; latencies and the batch
/// histogram under mutexes).
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    /// Generations that errored (admission failure or an engine-step
    /// failure) — previously invisible in the serving report.
    pub requests_failed: AtomicU64,
    /// Requests retired between engine steps without completing (deadline
    /// expired or client cancelled); their KV slots were freed.
    pub requests_cancelled: AtomicU64,
    /// Requests evicted from a live batch because an engine-step op they
    /// were part of failed or panicked (blast-radius isolation); always a
    /// subset of `requests_failed`.
    pub requests_quarantined: AtomicU64,
    /// Current rung on the graceful-degradation ladder: 0 = healthy,
    /// 1 = prefix-cache eviction, 2 = speculation capped, 3 = shedding
    /// new admissions.  A gauge, not a counter.
    pub degradation_level: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub draft_steps: AtomicU64,
    pub verify_passes: AtomicU64,
    /// Accumulated per-phase latency attribution across completed
    /// requests, microseconds (see [`RequestPhases`]); the snapshot turns
    /// these into per-request means.
    phase_queue_wait_us: AtomicU64,
    phase_prefill_us: AtomicU64,
    phase_draft_us: AtomicU64,
    phase_verify_us: AtomicU64,
    phase_stall_us: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
    exec_us: Mutex<Vec<u64>>,
    /// `occupancy[b]` = number of engine steps that ran with `b` active
    /// sequences in the batch.
    batch_occupancy: Mutex<Vec<u64>>,
    /// Accumulated weight traffic drained from the backends after each
    /// scheduler engine step (the quarter-to-all accounting).
    traffic: Mutex<TrafficSnapshot>,
    /// Latest paged-KV occupancy/sharing snapshot from the full backend
    /// (point-in-time gauges plus monotonic prefix-cache counters; the
    /// scheduler refreshes it wholesale after every engine step).
    kv: Mutex<KvStats>,
    /// Latest adaptive-speculation state across the active batch:
    /// `(sessions, summed draft budget, summed accept-rate estimate)`.
    /// Replaced after every engine step, like the KV snapshot.
    spec_adaptive: Mutex<(u64, f64, f64)>,
    started: Instant,
}

/// Point-in-time view with computed percentiles.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Failed requests that were quarantined out of a live batch while the
    /// rest of the batch kept stepping (subset of `failed`).
    pub quarantined: u64,
    /// Current graceful-degradation rung (0 healthy .. 3 shedding).
    pub degradation_level: u64,
    /// Faults fired by the process-wide injection plan (0 without one).
    pub faults_injected: u64,
    /// Fault events the serving stack contained and recovered from.
    pub faults_recovered: u64,
    pub tokens: u64,
    pub draft_steps: u64,
    pub verify_passes: u64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub exec_p50_ms: f64,
    /// Mean per-completed-request phase attribution, milliseconds (zeros
    /// when nothing completed).  The five buckets sum to the mean total
    /// latency by construction (see [`RequestPhases`]).
    pub phase_queue_wait_mean_ms: f64,
    pub phase_prefill_mean_ms: f64,
    pub phase_draft_mean_ms: f64,
    pub phase_verify_mean_ms: f64,
    pub phase_stall_mean_ms: f64,
    /// Tokens generated per wall-clock second since the sink was created.
    pub tokens_per_s: f64,
    /// Histogram of engine-step batch occupancy (`[b]` = steps at size b).
    pub batch_occupancy: Vec<u64>,
    /// Mean sequences per engine step (0 when no steps ran).
    pub batch_occupancy_mean: f64,
    /// Accumulated weight traffic (zeros on backends without accounting).
    pub traffic: TrafficSnapshot,
    /// Draft-pass weight bytes per decoded token.
    pub bytes_per_token_draft: f64,
    /// Full-pass weight bytes per decoded token.
    pub bytes_per_token_full: f64,
    /// The measured quarter-to-all ratio (draft / full bytes per token).
    pub draft_traffic_ratio: f64,
    /// Raw paged-KV snapshot (zeros on backends without paging).
    pub kv: KvStats,
    /// KV pages currently allocated to live sequences or the prefix tree.
    pub kv_pages_allocated: u64,
    /// KV pages mapped by more than one owner (prefix sharing in effect).
    pub kv_pages_shared: u64,
    /// Pages copied on write into a shared page (monotonic).
    pub kv_cow_copies: u64,
    /// Prompt tokens served from the prefix cache instead of recomputed.
    pub prefix_cache_hit_tokens: u64,
    /// Prompt tokens that missed the prefix cache and ran the full pass.
    pub prefix_cache_miss_tokens: u64,
    /// Hit fraction over all prefill tokens (0 when nothing prefilled).
    pub prefix_cache_hit_rate: f64,
    /// Active sessions currently running the adaptive controller.
    pub adaptive_sessions: u64,
    /// Mean draft budget those sessions chose for the current iteration
    /// (0 when none are adaptive).
    pub adaptive_draft_len_mean: f64,
    /// Mean live EWMA accept-rate estimate across them (0 when none).
    pub adaptive_accept_rate_mean: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            requests_submitted: AtomicU64::new(0),
            requests_completed: AtomicU64::new(0),
            requests_rejected: AtomicU64::new(0),
            requests_failed: AtomicU64::new(0),
            requests_cancelled: AtomicU64::new(0),
            requests_quarantined: AtomicU64::new(0),
            degradation_level: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            draft_steps: AtomicU64::new(0),
            verify_passes: AtomicU64::new(0),
            phase_queue_wait_us: AtomicU64::new(0),
            phase_prefill_us: AtomicU64::new(0),
            phase_draft_us: AtomicU64::new(0),
            phase_verify_us: AtomicU64::new(0),
            phase_stall_us: AtomicU64::new(0),
            latencies_us: Mutex::new(Vec::new()),
            exec_us: Mutex::new(Vec::new()),
            batch_occupancy: Mutex::new(Vec::new()),
            traffic: Mutex::new(TrafficSnapshot::default()),
            kv: Mutex::new(KvStats::default()),
            spec_adaptive: Mutex::new((0, 0.0, 0.0)),
            started: Instant::now(),
        }
    }

    /// Fold one drained per-step traffic delta into the running totals
    /// (the scheduler calls `backend.drain_traffic()` after every engine
    /// step and reports the delta here).
    pub fn record_traffic(&self, delta: &TrafficSnapshot) {
        self.traffic.lock().unwrap().merge(delta);
    }

    /// Replace the stored paged-KV snapshot with the backend's latest.
    /// Unlike traffic deltas this is not merged: `KvStats` is already a
    /// point-in-time view (gauges) carrying its own monotonic counters.
    pub fn record_kv(&self, stats: &KvStats) {
        *self.kv.lock().unwrap() = *stats;
    }

    /// Replace the adaptive-speculation aggregate for the current batch:
    /// `sessions` adaptive sessions whose chosen draft budgets sum to
    /// `sum_budget` and whose accept-rate estimates sum to `sum_rate`.
    /// Point-in-time like [`Metrics::record_kv`].
    pub fn record_spec_adaptive(&self, sessions: u64, sum_budget: f64, sum_rate: f64) {
        *self.spec_adaptive.lock().unwrap() = (sessions, sum_budget, sum_rate);
    }

    pub fn record_completion(
        &self,
        tokens: u64,
        drafts: u64,
        verifies: u64,
        latency_s: f64,
        exec_s: f64,
        phases: &RequestPhases,
    ) {
        let nth = self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens, Ordering::Relaxed);
        self.draft_steps.fetch_add(drafts, Ordering::Relaxed);
        self.verify_passes.fetch_add(verifies, Ordering::Relaxed);
        let us = |s: f64| if s.is_finite() && s > 0.0 { (s * 1e6) as u64 } else { 0 };
        self.phase_queue_wait_us.fetch_add(us(phases.queue_wait_s), Ordering::Relaxed);
        self.phase_prefill_us.fetch_add(us(phases.prefill_s), Ordering::Relaxed);
        self.phase_draft_us.fetch_add(us(phases.draft_s), Ordering::Relaxed);
        self.phase_verify_us.fetch_add(us(phases.verify_s), Ordering::Relaxed);
        self.phase_stall_us.fetch_add(us(phases.stall_s), Ordering::Relaxed);
        push_capped(&mut self.latencies_us.lock().unwrap(), (latency_s * 1e6) as u64, nth);
        push_capped(&mut self.exec_us.lock().unwrap(), (exec_s * 1e6) as u64, nth);
    }

    /// The three per-token traffic numbers without building a full
    /// snapshot — cheap enough to read per completed request (a snapshot
    /// clones and sorts the latency reservoirs; see [`Metrics::snapshot`]).
    pub fn traffic_fields(&self) -> (f64, f64, f64) {
        let t = *self.traffic.lock().unwrap();
        (t.draft_bytes_per_token(), t.full_bytes_per_token(), t.draft_full_ratio())
    }

    /// Record one scheduler engine step running `occupancy` sequences.
    pub fn record_batch_step(&self, occupancy: usize) {
        let mut h = self.batch_occupancy.lock().unwrap();
        if h.len() <= occupancy {
            h.resize(occupancy + 1, 0);
        }
        h[occupancy] += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        // Shared nearest-rank percentile (util::bench::percentile), µs → ms.
        let pct = |v: &mut [f64], p: f64| -> f64 { crate::util::bench::percentile(v, p) / 1e3 };
        let mut lat: Vec<f64> =
            self.latencies_us.lock().unwrap().iter().map(|&v| v as f64).collect();
        let mut exec: Vec<f64> =
            self.exec_us.lock().unwrap().iter().map(|&v| v as f64).collect();
        let occupancy = self.batch_occupancy.lock().unwrap().clone();
        let traffic = *self.traffic.lock().unwrap();
        let kv = *self.kv.lock().unwrap();
        let (ad_n, ad_budget, ad_rate) = *self.spec_adaptive.lock().unwrap();
        let prefill_tokens = kv.prefix_hit_tokens + kv.prefix_miss_tokens;
        let steps: u64 = occupancy.iter().sum();
        let weighted: u64 = occupancy.iter().enumerate().map(|(b, &n)| b as u64 * n).sum();
        let tokens = self.tokens_generated.load(Ordering::Relaxed);
        let elapsed_s = self.started.elapsed().as_secs_f64();
        let completed = self.requests_completed.load(Ordering::Relaxed);
        // Phase totals µs → per-completed-request mean ms.
        let phase_mean_ms = |total: &AtomicU64| -> f64 {
            if completed > 0 {
                total.load(Ordering::Relaxed) as f64 / completed as f64 / 1e3
            } else {
                0.0
            }
        };
        MetricsSnapshot {
            submitted: self.requests_submitted.load(Ordering::Relaxed),
            completed,
            rejected: self.requests_rejected.load(Ordering::Relaxed),
            failed: self.requests_failed.load(Ordering::Relaxed),
            cancelled: self.requests_cancelled.load(Ordering::Relaxed),
            quarantined: self.requests_quarantined.load(Ordering::Relaxed),
            degradation_level: self.degradation_level.load(Ordering::Relaxed),
            faults_injected: crate::faults::injected_total(),
            faults_recovered: crate::faults::recovered_total(),
            tokens,
            draft_steps: self.draft_steps.load(Ordering::Relaxed),
            verify_passes: self.verify_passes.load(Ordering::Relaxed),
            latency_p50_ms: pct(&mut lat, 0.50),
            latency_p95_ms: pct(&mut lat, 0.95),
            latency_p99_ms: pct(&mut lat, 0.99),
            exec_p50_ms: pct(&mut exec, 0.50),
            phase_queue_wait_mean_ms: phase_mean_ms(&self.phase_queue_wait_us),
            phase_prefill_mean_ms: phase_mean_ms(&self.phase_prefill_us),
            phase_draft_mean_ms: phase_mean_ms(&self.phase_draft_us),
            phase_verify_mean_ms: phase_mean_ms(&self.phase_verify_us),
            phase_stall_mean_ms: phase_mean_ms(&self.phase_stall_us),
            tokens_per_s: if elapsed_s > 0.0 { tokens as f64 / elapsed_s } else { 0.0 },
            batch_occupancy: occupancy,
            batch_occupancy_mean: if steps > 0 { weighted as f64 / steps as f64 } else { 0.0 },
            traffic,
            bytes_per_token_draft: traffic.draft_bytes_per_token(),
            bytes_per_token_full: traffic.full_bytes_per_token(),
            draft_traffic_ratio: traffic.draft_full_ratio(),
            kv,
            kv_pages_allocated: kv.pages_in_use,
            kv_pages_shared: kv.pages_shared,
            kv_cow_copies: kv.cow_copies,
            prefix_cache_hit_tokens: kv.prefix_hit_tokens,
            prefix_cache_miss_tokens: kv.prefix_miss_tokens,
            prefix_cache_hit_rate: if prefill_tokens > 0 {
                kv.prefix_hit_tokens as f64 / prefill_tokens as f64
            } else {
                0.0
            },
            adaptive_sessions: ad_n,
            adaptive_draft_len_mean: if ad_n > 0 { ad_budget / ad_n as f64 } else { 0.0 },
            adaptive_accept_rate_mean: if ad_n > 0 { ad_rate / ad_n as f64 } else { 0.0 },
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_from_recorded_latencies() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record_completion(
                10,
                5,
                2,
                i as f64 / 1000.0,
                i as f64 / 2000.0,
                &RequestPhases::default(),
            );
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.tokens, 1000);
        assert!((s.latency_p50_ms - 50.0).abs() <= 2.0, "{}", s.latency_p50_ms);
        assert!((s.latency_p95_ms - 95.0).abs() <= 2.0, "{}", s.latency_p95_ms);
        assert!(s.exec_p50_ms < s.latency_p50_ms);
        assert!(s.tokens_per_s > 0.0);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.failed, 0);
        assert_eq!(s.latency_p50_ms, 0.0);
        assert_eq!(s.batch_occupancy_mean, 0.0);
        assert!(s.batch_occupancy.is_empty());
    }

    #[test]
    fn failures_are_counted() {
        let m = Metrics::new();
        m.requests_failed.fetch_add(3, Ordering::Relaxed);
        assert_eq!(m.snapshot().failed, 3);
    }

    #[test]
    fn cancellations_are_counted() {
        let m = Metrics::new();
        m.requests_cancelled.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.cancelled, 2);
        assert_eq!(s.completed, 0);
    }

    #[test]
    fn latency_reservoir_is_bounded() {
        let mut v = Vec::new();
        for nth in 0..(LATENCY_SAMPLE_CAP as u64 + 10) {
            push_capped(&mut v, nth, nth);
        }
        assert_eq!(v.len(), LATENCY_SAMPLE_CAP);
        // The overflow overwrote ring slots 0..10 with the newest values.
        assert_eq!(v[0], LATENCY_SAMPLE_CAP as u64);
        assert_eq!(v[9], LATENCY_SAMPLE_CAP as u64 + 9);
        assert_eq!(v[10], 10);
    }

    #[test]
    fn traffic_fields_match_the_snapshot() {
        let m = Metrics::new();
        m.record_traffic(&TrafficSnapshot {
            draft_bytes: 100,
            draft_tokens: 4,
            full_bytes: 400,
            full_tokens: 4,
            ..Default::default()
        });
        let (d, f, r) = m.traffic_fields();
        let s = m.snapshot();
        assert_eq!(d, s.bytes_per_token_draft);
        assert_eq!(f, s.bytes_per_token_full);
        assert_eq!(r, s.draft_traffic_ratio);
        assert!((r - 0.25).abs() < 1e-12);
    }

    #[test]
    fn traffic_deltas_accumulate_into_the_snapshot() {
        let m = Metrics::new();
        let d1 = TrafficSnapshot {
            draft_bytes: 100,
            draft_tokens: 4,
            full_bytes: 400,
            full_tokens: 4,
            ..Default::default()
        };
        let d2 = TrafficSnapshot { draft_bytes: 100, draft_tokens: 4, ..Default::default() };
        m.record_traffic(&d1);
        m.record_traffic(&d2);
        let s = m.snapshot();
        assert_eq!(s.traffic.draft_bytes, 200);
        assert_eq!(s.traffic.draft_tokens, 8);
        assert!((s.bytes_per_token_draft - 25.0).abs() < 1e-12);
        assert!((s.bytes_per_token_full - 100.0).abs() < 1e-12);
        assert!((s.draft_traffic_ratio - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_traffic_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert!(s.traffic.is_empty());
        assert_eq!(s.draft_traffic_ratio, 0.0);
    }

    #[test]
    fn kv_snapshot_is_replaced_not_merged() {
        let m = Metrics::new();
        m.record_kv(&KvStats {
            pages_in_use: 10,
            pages_shared: 4,
            cow_copies: 1,
            prefix_hit_tokens: 30,
            prefix_miss_tokens: 10,
            ..Default::default()
        });
        m.record_kv(&KvStats {
            pages_in_use: 6,
            pages_shared: 2,
            cow_copies: 3,
            prefix_hit_tokens: 60,
            prefix_miss_tokens: 20,
            ..Default::default()
        });
        let s = m.snapshot();
        assert_eq!(s.kv_pages_allocated, 6, "gauges track the latest snapshot");
        assert_eq!(s.kv_pages_shared, 2);
        assert_eq!(s.kv_cow_copies, 3);
        assert_eq!(s.prefix_cache_hit_tokens, 60);
        assert_eq!(s.prefix_cache_miss_tokens, 20);
        assert!((s.prefix_cache_hit_rate - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empty_kv_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.kv_pages_allocated, 0);
        assert_eq!(s.prefix_cache_hit_rate, 0.0);
    }

    #[test]
    fn adaptive_gauges_are_replaced_and_averaged() {
        let m = Metrics::new();
        m.record_spec_adaptive(4, 24.0, 3.2);
        m.record_spec_adaptive(2, 6.0, 1.0);
        let s = m.snapshot();
        assert_eq!(s.adaptive_sessions, 2, "point-in-time, not merged");
        assert!((s.adaptive_draft_len_mean - 3.0).abs() < 1e-12);
        assert!((s.adaptive_accept_rate_mean - 0.5).abs() < 1e-12);
        // Empty batch zeroes the means without dividing by zero.
        m.record_spec_adaptive(0, 0.0, 0.0);
        let s = m.snapshot();
        assert_eq!(s.adaptive_sessions, 0);
        assert_eq!(s.adaptive_draft_len_mean, 0.0);
        assert_eq!(s.adaptive_accept_rate_mean, 0.0);
    }

    #[test]
    fn phase_attribution_means_and_sum_identity() {
        let m = Metrics::new();
        let p1 = RequestPhases {
            queue_wait_s: 0.010,
            prefill_s: 0.020,
            draft_s: 0.030,
            verify_s: 0.040,
            stall_s: 0.100,
        };
        let p2 = RequestPhases {
            queue_wait_s: 0.030,
            prefill_s: 0.040,
            draft_s: 0.050,
            verify_s: 0.060,
            stall_s: 0.020,
        };
        m.record_completion(8, 4, 2, p1.total_s(), p1.total_s() - p1.queue_wait_s, &p1);
        m.record_completion(8, 4, 2, p2.total_s(), p2.total_s() - p2.queue_wait_s, &p2);
        let s = m.snapshot();
        assert!((s.phase_queue_wait_mean_ms - 20.0).abs() < 0.01, "{}", s.phase_queue_wait_mean_ms);
        assert!((s.phase_prefill_mean_ms - 30.0).abs() < 0.01);
        assert!((s.phase_draft_mean_ms - 40.0).abs() < 0.01);
        assert!((s.phase_verify_mean_ms - 50.0).abs() < 0.01);
        assert!((s.phase_stall_mean_ms - 60.0).abs() < 0.01);
        // The five mean buckets reconstruct the mean total latency.
        let sum = s.phase_queue_wait_mean_ms
            + s.phase_prefill_mean_ms
            + s.phase_draft_mean_ms
            + s.phase_verify_mean_ms
            + s.phase_stall_mean_ms;
        let mean_latency_ms = (p1.total_s() + p2.total_s()) / 2.0 * 1e3;
        assert!((sum - mean_latency_ms).abs() < 0.01, "{sum} vs {mean_latency_ms}");
        // Non-finite or negative buckets are dropped, not poisoning totals.
        m.record_completion(
            1,
            1,
            1,
            0.001,
            0.001,
            &RequestPhases { queue_wait_s: f64::NAN, stall_s: -5.0, ..Default::default() },
        );
        let s = m.snapshot();
        assert!(s.phase_queue_wait_mean_ms.is_finite());
        assert!(s.phase_stall_mean_ms >= 0.0);
    }

    #[test]
    fn empty_phase_means_are_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.phase_queue_wait_mean_ms, 0.0);
        assert_eq!(s.phase_stall_mean_ms, 0.0);
    }

    #[test]
    fn batch_occupancy_histogram_and_mean() {
        let m = Metrics::new();
        m.record_batch_step(3);
        m.record_batch_step(3);
        m.record_batch_step(1);
        let s = m.snapshot();
        assert_eq!(s.batch_occupancy[3], 2);
        assert_eq!(s.batch_occupancy[1], 1);
        assert!((s.batch_occupancy_mean - 7.0 / 3.0).abs() < 1e-12);
    }
}
