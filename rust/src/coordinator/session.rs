//! Multi-turn session store: rolling token histories per conversation.

use std::collections::HashMap;
use std::sync::Mutex;

/// Conversation state shared across workers.
pub struct SessionStore {
    sessions: Mutex<HashMap<u64, Vec<u8>>>,
    /// Keep at most this many trailing tokens per session (prompt window).
    max_history: usize,
}

impl SessionStore {
    pub fn new(max_history: usize) -> Self {
        Self { sessions: Mutex::new(HashMap::new()), max_history }
    }

    /// Build the effective prompt for a request: history + new prompt,
    /// truncated to the trailing `max_history` bytes.
    pub fn effective_prompt(&self, session: Option<u64>, prompt: &[u8]) -> Vec<u8> {
        let mut full = Vec::new();
        if let Some(sid) = session {
            if let Some(hist) = self.sessions.lock().unwrap().get(&sid) {
                full.extend_from_slice(hist);
            }
        }
        full.extend_from_slice(prompt);
        if full.len() > self.max_history {
            full.drain(..full.len() - self.max_history);
        }
        full
    }

    /// Record an exchange into the session history.
    pub fn append(&self, session: u64, prompt: &[u8], reply: &[u8]) {
        let mut g = self.sessions.lock().unwrap();
        let hist = g.entry(session).or_default();
        hist.extend_from_slice(prompt);
        hist.extend_from_slice(reply);
        if hist.len() > self.max_history {
            hist.drain(..hist.len() - self.max_history);
        }
    }

    pub fn len(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self, session: u64) {
        self.sessions.lock().unwrap().remove(&session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_accumulates_and_truncates() {
        let s = SessionStore::new(10);
        s.append(1, b"hello ", b"world ");
        // 12 bytes of history + "x", truncated to the trailing 10 bytes.
        let p = s.effective_prompt(Some(1), b"x");
        assert_eq!(p, b"lo world x".to_vec());
        assert!(p.len() <= 10);
        assert!(p.ends_with(b"x"));
    }

    #[test]
    fn sessions_are_isolated() {
        let s = SessionStore::new(100);
        s.append(1, b"a", b"b");
        s.append(2, b"c", b"d");
        assert_eq!(s.effective_prompt(Some(1), b"!"), b"ab!".to_vec());
        assert_eq!(s.effective_prompt(Some(2), b"!"), b"cd!".to_vec());
        assert_eq!(s.effective_prompt(None, b"!"), b"!".to_vec());
        s.clear(1);
        assert_eq!(s.effective_prompt(Some(1), b"!"), b"!".to_vec());
    }
}
