//! Perplexity harness (Table I): next-token cross-entropy over held-out
//! windows, with pluggable weight transforms for the quantization variants.
//!
//! Backend-agnostic: variants are realized through
//! [`Backend::with_transformed_weights`], so the same harness runs on the
//! native interpreter and (with the `pjrt` feature) on compiled graphs.

use anyhow::Result;

use crate::model::log_softmax;
use crate::runtime::Backend;

/// Perplexity of the resident (FP16) weights.
pub fn perplexity(model: &dyn Backend, windows: &[Vec<u8>]) -> Result<f64> {
    ppl_over(model, windows)
}

/// Perplexity with every linear weight transformed (quantization variant).
pub fn perplexity_with_transform(
    model: &dyn Backend,
    windows: &[Vec<u8>],
    mut transform: impl FnMut(&str, &[f32], usize, usize) -> Result<Vec<f32>>,
) -> Result<f64> {
    let variant = model.with_transformed_weights(&mut transform)?;
    ppl_over(variant.as_ref(), windows)
}

fn ppl_over(model: &dyn Backend, windows: &[Vec<u8>]) -> Result<f64> {
    let p = model.prefill_len();
    let v = model.vocab();
    let mut nll = 0.0f64;
    let mut count = 0u64;
    for w in windows {
        anyhow::ensure!(w.len() == p, "window must be prefill_len={p} tokens");
        let toks: Vec<i32> = w.iter().map(|&b| b as i32).collect();
        let logits = model.eval_logits(&toks, p)?;
        // Position i predicts token i+1.
        for i in 0..p - 1 {
            let row = &logits[i * v..(i + 1) * v];
            let lp = log_softmax(row);
            nll -= lp[w[i + 1] as usize] as f64;
            count += 1;
        }
    }
    Ok((nll / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::runtime::{InitStyle, NativeBackend};

    fn tiny() -> NativeBackend {
        let cfg = ModelConfig {
            name: "ppl-tiny".into(),
            paper_analog: "none".into(),
            n_layers: 1,
            d_model: 128,
            d_ff: 128,
            n_heads: 4,
            head_dim: 32,
            vocab: 64,
            cache_len: 64,
            prefill_len: 32,
            param_count: 0,
        };
        NativeBackend::synthetic(cfg, 5, 42, InitStyle::Confident).expect("synthetic")
    }

    #[test]
    fn identity_transform_matches_baseline() {
        let model = tiny();
        let windows: Vec<Vec<u8>> = (0..2)
            .map(|s| (0..32).map(|i| ((i * 7 + s * 13) % 64) as u8).collect())
            .collect();
        let base = perplexity(&model, &windows).expect("ppl");
        let same = perplexity_with_transform(&model, &windows, |_, w, _, _| Ok(w.to_vec()))
            .expect("ppl");
        assert!(base.is_finite() && base > 0.0);
        assert_eq!(base, same, "identity transform changed perplexity");
    }

    #[test]
    fn bsfp_draft_ppl_is_finite_and_close() {
        let model = tiny();
        // Byte-successor windows: in-distribution for the Confident init,
        // so both full and draft models predict confidently and the ratio
        // is meaningful.
        let windows: Vec<Vec<u8>> = (0..2)
            .map(|s| (0..32).map(|i| ((i + s * 11) % 64) as u8).collect())
            .collect();
        let base = perplexity(&model, &windows).expect("ppl");
        let draft = perplexity_with_transform(&model, &windows, |_, w, k, n| {
            let qt = crate::bsfp::quantize_tensor(w, k, n);
            let mut out = qt.dequant_draft();
            for o in out.iter_mut() {
                *o /= qt.tensor_scale;
            }
            Ok(out)
        })
        .expect("ppl");
        assert!(draft.is_finite() && draft > 0.0);
        // The BSFP draft tracks the full model (paper Table I: ~FP16 ppl);
        // allow a loose factor for the synthetic testbed.
        assert!(draft < base * 4.0, "draft ppl {draft} vs full {base}");
    }
}
