//! Perplexity harness (Table I): next-token cross-entropy over held-out
//! windows, with pluggable weight transforms for the quantization variants.

use anyhow::Result;

use crate::model::{log_softmax, ModelRuntime};

/// Perplexity of the resident (FP16) weights.
pub fn perplexity(model: &ModelRuntime, windows: &[Vec<u8>]) -> Result<f64> {
    ppl_with_bufs(model, model.full_param_buffers(), windows)
}

/// Perplexity with every linear weight transformed (quantization variant).
pub fn perplexity_with_transform(
    model: &ModelRuntime,
    windows: &[Vec<u8>],
    transform: impl FnMut(&str, &[f32], usize, usize) -> Result<Vec<f32>>,
) -> Result<f64> {
    let bufs = model.build_transformed_params(transform)?;
    ppl_with_bufs(model, &bufs, windows)
}

fn ppl_with_bufs(
    model: &ModelRuntime,
    bufs: &[xla::PjRtBuffer],
    windows: &[Vec<u8>],
) -> Result<f64> {
    let p = model.prefill_len();
    let v = model.vocab();
    let mut nll = 0.0f64;
    let mut count = 0u64;
    for w in windows {
        anyhow::ensure!(w.len() == p, "window must be prefill_len={p} tokens");
        let toks: Vec<i32> = w.iter().map(|&b| b as i32).collect();
        let logits = model.eval_logits_with(bufs, &toks, p)?;
        // Position i predicts token i+1.
        for i in 0..p - 1 {
            let row = &logits[i * v..(i + 1) * v];
            let lp = log_softmax(row);
            nll -= lp[w[i + 1] as usize] as f64;
            count += 1;
        }
    }
    Ok((nll / count as f64).exp())
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end by rust/tests/integration_goldens.rs and the
    // table1 experiment; unit coverage for log_softmax lives in
    // model::sampling.
}
